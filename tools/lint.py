#!/usr/bin/env python
"""Repo-rule lint CLI over ``repro.analysis.astlint``.

    python tools/lint.py src examples benchmarks tools
    python tools/lint.py --list-rules

Prints one ``path:line:col: [rule] message`` per finding and exits 1 when
anything is flagged (0 on a clean run). Suppress a genuine false positive
inline with ``# repro: allow[rule-id]`` plus a reason. Rule definitions
and rationale: docs/static-analysis.md. The generic-lint floor (syntax
errors, undefined names) is ruff's job — see pyproject ``[tool.ruff]``;
this pass carries only the repo-specific rules.

Pure AST analysis: no jax import, no tracing — fast enough to gate every
CI run before the test suite.
"""
import argparse
import os
import sys

_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
_SRC = os.path.join(_ROOT, 'src')
if _SRC not in sys.path:
    sys.path.insert(0, _SRC)

from repro.analysis.astlint import RULES, lint_paths  # noqa: E402


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument('paths', nargs='*', default=['src'],
                    help='files or directories to lint (default: src)')
    ap.add_argument('--list-rules', action='store_true',
                    help='print the rule table and exit')
    args = ap.parse_args(argv)

    if args.list_rules:
        width = max(len(r) for r in RULES)
        for rule, blurb in sorted(RULES.items()):
            print(f'{rule:<{width}}  {blurb}')
        return 0

    findings = lint_paths(args.paths or ['src'])
    for finding in findings:
        print(finding.render())
    if findings:
        print(f'{len(findings)} finding(s). Suppress a false positive with '
              "'# repro: allow[rule]' plus a reason.", file=sys.stderr)
        return 1
    return 0


if __name__ == '__main__':
    sys.exit(main())
