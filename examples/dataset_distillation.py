"""Dataset distillation (paper §5.2): learn 50 synthetic images whose
training signal reproduces the full 10-class digit-GMM dataset.

Uses the typed problem API: ``build_distillation`` returns a
``BilevelProblem`` (paper-protocol defaults: inner reset every outer step)
and ``solve`` drives it end to end; the ``distilled_accuracy`` metric trains
a fresh model on the distilled images only.

    python examples/dataset_distillation.py
"""
import argparse
import pathlib
import sys

try:
    import repro  # noqa: F401  (pip install -e .  /  PYTHONPATH=src)
except ImportError:
    sys.path.insert(0, str(pathlib.Path(__file__).resolve().parents[1] / 'src'))

from repro.core import HypergradConfig, solve                # noqa: E402
from repro.tasks import build_distillation                   # noqa: E402


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument('--solver', default='nystrom')
    ap.add_argument('--outer-steps', type=int, default=30)
    args = ap.parse_args()

    problem = build_distillation()
    result = solve(problem,
                   HypergradConfig(solver=args.solver, k=10, rho=1e-2),
                   n_outer=args.outer_steps, log_every=5)
    print(f'test accuracy from 50 distilled images: '
          f'{result.metrics["distilled_accuracy"]:.3f} '
          f'[{result.hvp_count} HVPs, {result.seconds:.1f}s]')


if __name__ == '__main__':
    main()
