"""Dataset distillation (paper §5.2): learn 50 synthetic images whose
training signal reproduces the full 10-class digit-GMM dataset.

Uses the high-level ``BilevelTrainer`` (whose outer step differentiates
through the ``implicit_root`` solution map — see docs/implicit-api.md).

    python examples/dataset_distillation.py
"""
import argparse
import pathlib
import sys

try:
    import repro  # noqa: F401  (pip install -e .  /  PYTHONPATH=src)
except ImportError:
    sys.path.insert(0, str(pathlib.Path(__file__).resolve().parents[1] / 'src'))

import jax                                               # noqa: E402
import jax.numpy as jnp                                  # noqa: E402

from repro.core import BilevelTrainer, HypergradConfig   # noqa: E402
from repro.optim import adam, sgd                        # noqa: E402
from repro.tasks import build_distillation               # noqa: E402


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument('--solver', default='nystrom')
    ap.add_argument('--outer-steps', type=int, default=30)
    args = ap.parse_args()

    task = build_distillation()
    trainer = BilevelTrainer(
        inner_loss=task['inner'], outer_loss=task['outer'],
        inner_opt=sgd(0.01), outer_opt=adam(1e-3),
        hypergrad=HypergradConfig(solver=args.solver, k=10, rho=1e-2),
        init_params=task['init_params'], reset_inner=True)

    rng = jax.random.PRNGKey(0)
    state = trainer.init(rng, task['init_params'](rng), task['init_hparams']())
    Xt, yt = task['train']

    def batches(X, y, start):
        i = start
        while True:
            idx = jax.random.randint(jax.random.PRNGKey(i), (256,), 0,
                                     X.shape[0])
            yield (X[idx], y[idx])
            i += 1

    state, hist = trainer.run(state, batches(Xt, yt, 0), batches(Xt, yt, 9000),
                              steps_per_outer=100, n_outer=args.outer_steps,
                              log_every=5)

    # evaluate: train a fresh model on the distilled images only
    params = task['init_params'](jax.random.PRNGKey(7))
    opt = sgd(0.01)
    st = opt.init(params)
    for i in range(100):
        g = jax.grad(task['inner'])(params, state.hparams, None)
        params, st = opt.apply(g, st, params, jnp.int32(i))
    print(f'test accuracy from 50 distilled images: '
          f'{task["accuracy"](params):.3f}')


if __name__ == '__main__':
    main()
