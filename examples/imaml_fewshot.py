"""iMAML few-shot meta learning (paper §5.3) on the implicit_root API:
per-task hypergradients are ``jax.grad`` through the adaptation map, and a
meta-batch of tasks is ``jax.vmap`` over it (one batched program instead of
a per-task Python loop — the benchmark emits the measured speedup row).

    python examples/imaml_fewshot.py --episodes 60 --meta-batch 4
"""
import argparse
import pathlib
import sys

_ROOT = pathlib.Path(__file__).resolve().parents[1]
sys.path.insert(0, str(_ROOT))          # the benchmarks/ package lives at root
try:
    import repro  # noqa: F401  (pip install -e .  /  PYTHONPATH=src)
except ImportError:
    sys.path.insert(0, str(_ROOT / 'src'))


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument('--episodes', type=int, default=60)
    ap.add_argument('--meta-batch', type=int, default=4,
                    help='tasks per vmapped meta-step')
    ap.add_argument('--bench-tasks', type=int, default=8,
                    help='meta-batch size for the vmap-vs-loop speedup '
                         'benchmark (0 disables)')
    ap.add_argument('--shared-sketch', action='store_true',
                    help='share one Nyström sketch (built at the meta-init '
                         'on pooled support data) across the meta-batch: '
                         'k HVPs per meta-batch instead of per task')
    args = ap.parse_args()
    from benchmarks import tab3_imaml
    accs = tab3_imaml.run(n_episodes=args.episodes, n_eval=20,
                          meta_batch=args.meta_batch,
                          bench_tasks=args.bench_tasks,
                          shared_sketch=args.shared_sketch)
    for method, acc in accs.items():
        print(f'{method}: 1-shot test accuracy {acc:.3f}')


if __name__ == '__main__':
    main()
