"""iMAML few-shot meta learning (paper §5.3) with a pluggable IHVP backend.

    PYTHONPATH=src python examples/imaml_fewshot.py --episodes 60
"""
import argparse
import sys

sys.path.insert(0, 'src')


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument('--episodes', type=int, default=60)
    args = ap.parse_args()
    from benchmarks import tab3_imaml
    accs = tab3_imaml.run(n_episodes=args.episodes, n_eval=20)
    for method, acc in accs.items():
        print(f'{method}: 1-shot test accuracy {acc:.3f}')


if __name__ == '__main__':
    main()
