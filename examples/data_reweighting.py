"""Data reweighting (paper §5.4): a weight-net learns to down-weight
head-class examples on long-tailed data; outer loss is balanced validation.

Uses the high-level ``BilevelTrainer`` (whose outer step differentiates
through the ``implicit_root`` solution map — see docs/implicit-api.md).

    python examples/data_reweighting.py --imbalance 100
"""
import argparse
import pathlib
import sys

try:
    import repro  # noqa: F401  (pip install -e .  /  PYTHONPATH=src)
except ImportError:
    sys.path.insert(0, str(pathlib.Path(__file__).resolve().parents[1] / 'src'))

import jax                                               # noqa: E402

from repro.core import BilevelTrainer, HypergradConfig   # noqa: E402
from repro.optim import adam, momentum                   # noqa: E402
from repro.tasks import build_reweighting                # noqa: E402


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument('--solver', default='nystrom')
    ap.add_argument('--imbalance', type=int, default=100)
    ap.add_argument('--outer-steps', type=int, default=40)
    args = ap.parse_args()

    task = build_reweighting(imbalance=args.imbalance)
    data = task['data']
    trainer = BilevelTrainer(
        inner_loss=task['inner'], outer_loss=task['outer'],
        inner_opt=momentum(0.1, 0.9), outer_opt=adam(1e-3),
        hypergrad=HypergradConfig(solver=args.solver, k=10, rho=1e-2))

    rng = jax.random.PRNGKey(0)
    state = trainer.init(rng, task['init_params'](rng),
                         task['init_hparams'](jax.random.PRNGKey(1)))

    def train_batches():
        i = 0
        while True:
            yield data.train_batch(i, 128)
            i += 1

    def val_batches():
        i = 0
        while True:
            yield data.val_batch(i, 128)
            i += 1

    state, hist = trainer.run(state, train_batches(), val_batches(),
                              steps_per_outer=20, n_outer=args.outer_steps,
                              log_every=10)
    print(f'balanced test accuracy (imbalance={args.imbalance}, '
          f'solver={args.solver}): {task["accuracy"](state.params):.3f}')


if __name__ == '__main__':
    main()
