"""Data reweighting (paper §5.4): a weight-net learns to down-weight
head-class examples on long-tailed data; outer loss is balanced validation.

Uses the typed problem API: ``build_reweighting`` returns a
``BilevelProblem`` and ``solve`` drives it end to end (the outer step
differentiates through the ``implicit_root`` solution map — see
docs/implicit-api.md). ``--sketch-refresh-every N`` amortizes one Nyström
sketch across N warm-start outer steps (k HVPs per refresh instead of per
step).

    python examples/data_reweighting.py --imbalance 100
"""
import argparse
import pathlib
import sys

try:
    import repro  # noqa: F401  (pip install -e .  /  PYTHONPATH=src)
except ImportError:
    sys.path.insert(0, str(pathlib.Path(__file__).resolve().parents[1] / 'src'))

from repro.core import HypergradConfig, solve                # noqa: E402
from repro.tasks import build_reweighting                    # noqa: E402


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument('--solver', default='nystrom')
    ap.add_argument('--imbalance', type=int, default=100)
    ap.add_argument('--outer-steps', type=int, default=40)
    ap.add_argument('--sketch-refresh-every', type=int, default=None,
                    help='outer steps between Nyström sketch rebuilds '
                         '(default 1 = fresh every step)')
    args = ap.parse_args()

    problem = build_reweighting(imbalance=args.imbalance)
    result = solve(problem,
                   HypergradConfig(solver=args.solver, k=10, rho=1e-2),
                   n_outer=args.outer_steps, log_every=10,
                   sketch_refresh_every=args.sketch_refresh_every)
    print(f'balanced test accuracy (imbalance={args.imbalance}, '
          f'solver={args.solver}): {result.metrics["accuracy"]:.3f} '
          f'[{result.hvp_count} HVPs, {result.seconds:.1f}s]')


if __name__ == '__main__':
    main()
