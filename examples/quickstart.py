"""Quickstart (paper §5.1): per-parameter weight-decay HPO on logistic
regression, written the natural JAX way — the inner training run is an
``implicit_root`` solution map, and the hypergradient is plain ``jax.grad``
through it (the custom_vjp backward runs the Nyström IHVP). ~30 s on CPU.

    python examples/quickstart.py [--solver cg|neumann|nystrom|exact]
"""
import argparse
import pathlib
import sys

try:
    import repro  # noqa: F401  (pip install -e .  /  PYTHONPATH=src)
except ImportError:
    sys.path.insert(0, str(pathlib.Path(__file__).resolve().parents[1] / 'src'))

import jax                                               # noqa: E402
import jax.numpy as jnp                                  # noqa: E402

from repro.core import (config_from_cli, hypergradient,  # noqa: E402
                        implicit_root, sgd_solver,
                        unrolled_hypergradient)
from repro.optim import momentum                         # noqa: E402
from repro.tasks import build_logreg_weight_decay        # noqa: E402

INNER_STEPS = 100
INNER_LR = 0.1


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument('--solver', default='nystrom',
                    choices=['nystrom', 'cg', 'neumann', 'exact'])
    ap.add_argument('--k', type=int, default=None,
                    help='sketch rank / iterations (default 5)')
    ap.add_argument('--rho', type=float, default=None,
                    help='damping (default 1e-2)')
    ap.add_argument('--outer-steps', type=int, default=10)
    ap.add_argument('--legacy-check', action='store_true',
                    help='also compute one hypergradient via the legacy '
                         'hypergradient() wrapper and print the deviation')
    args = ap.parse_args()

    problem = build_logreg_weight_decay()
    # registry-driven flag forwarding: explicitly-passed flags the solver
    # does not consume are rejected loudly by build(), never silently dropped
    hypergrad = config_from_cli(args.solver,
                                flags={'k': args.k, 'rho': args.rho},
                                defaults={'k': 5, 'rho': 1e-2})

    # INNER_STEPS SGD steps from zero init (§5.1 reset protocol)
    inner_solver = sgd_solver(problem.inner_loss, INNER_STEPS, INNER_LR,
                              init=lambda phi, b: {'w': jnp.zeros_like(
                                  phi['wd'])})

    solve = implicit_root(inner_solver, problem.inner_loss, hypergrad)
    opt = momentum(0.1, 0.9)

    @jax.jit
    def outer_step(phi, ost, step, rng):
        def obj(phi):
            theta = solve(phi, problem.data.train, rng=rng)
            return problem.outer_loss(theta, phi, problem.data.val)
        val, g = jax.value_and_grad(obj)(phi)
        phi, ost = opt.apply(g, ost, phi, step)
        return phi, ost, val

    phi = problem.init_hparams(jax.random.PRNGKey(0))
    ost = opt.init(phi)
    for i in range(args.outer_steps):
        phi, ost, val = outer_step(phi, ost, jnp.int32(i),
                                   jax.random.PRNGKey(i))
        print(f'[quickstart] outer {i + 1}/{args.outer_steps} '
              f'val={float(val):.4f} (pre-update)')

    if args.legacy_check:
        # distinct from the hparam-init key: both paths below share THIS rng
        # (that sameness is the point), but neither should reuse the init key
        rng = jax.random.PRNGKey(1234)
        theta = inner_solver(phi, problem.data.train)
        new = jax.grad(lambda p: problem.outer_loss(
            solve(p, problem.data.train, rng=rng), p, problem.data.val))(phi)
        # API-compat: the legacy imperative entry point (now a wrapper over
        # implicit_root) still accepts its old signature and agrees exactly
        legacy = hypergradient(problem.inner_loss, problem.outer_loss, theta, phi,
                               problem.data.train, problem.data.val,
                               hypergrad.build(), rng)
        dev = max(float(jnp.abs(a - b).max()) for a, b in
                  zip(jax.tree.leaves(legacy), jax.tree.leaves(new)))
        print(f'[quickstart] legacy hypergradient() max deviation: {dev:.2e}')
        # numerics: validate the custom_vjp assembly itself against an
        # *independent* oracle (differentiating through the inner unroll —
        # no implicit_root code shared). The exact solver isolates the
        # plumbing: at k≪p the Nyström estimate legitimately differs from
        # the oracle by its rank-truncation error, which is not a bug.
        exact_solve = implicit_root(inner_solver, problem.inner_loss,
                                    config_from_cli('exact',
                                                    flags={'rho': args.rho},
                                                    defaults={'rho': 1e-2}))
        via_exact = jax.grad(lambda p: problem.outer_loss(
            exact_solve(p, problem.data.train), p, problem.data.val))(phi)
        oracle = unrolled_hypergradient(
            problem.inner_loss, problem.outer_loss, theta, phi, problem.data.train,
            problem.data.val, steps=INNER_STEPS, lr=INNER_LR)
        rel = (max(float(jnp.abs(a - b).max()) for a, b in
                   zip(jax.tree.leaves(oracle), jax.tree.leaves(via_exact)))
               / max(float(jnp.abs(x).max())
                     for x in jax.tree.leaves(oracle)))
        print(f'[quickstart] custom_vjp (exact solver) vs unrolled oracle: '
              f'relative deviation {rel:.2e}')

    theta = jax.jit(inner_solver)(phi, problem.data.train)
    final = float(problem.outer_loss(theta, phi, problem.data.val))
    print(f'final validation loss: {final:.4f} (solver={args.solver})')


if __name__ == '__main__':
    main()
