"""Quickstart (paper §5.1): per-parameter weight-decay HPO on logistic
regression with the Nyström hypergradient — runs in ~30 s on CPU.

    PYTHONPATH=src python examples/quickstart.py [--solver cg|neumann|nystrom]
"""
import argparse
import sys

import jax

sys.path.insert(0, 'src')

from repro.core import BilevelTrainer, HypergradConfig   # noqa: E402
from repro.optim import momentum, sgd                    # noqa: E402
from repro.tasks import build_logreg_weight_decay        # noqa: E402


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument('--solver', default='nystrom',
                    choices=['nystrom', 'cg', 'neumann', 'exact'])
    ap.add_argument('--k', type=int, default=5)
    ap.add_argument('--rho', type=float, default=1e-2)
    ap.add_argument('--outer-steps', type=int, default=10)
    args = ap.parse_args()

    task = build_logreg_weight_decay()
    trainer = BilevelTrainer(
        inner_loss=task['inner'], outer_loss=task['outer'],
        inner_opt=sgd(0.1), outer_opt=momentum(0.1, 0.9),
        hypergrad=HypergradConfig(solver=args.solver, k=args.k, rho=args.rho),
        init_params=task['init_params'], reset_inner=True)

    rng = jax.random.PRNGKey(0)
    state = trainer.init(rng, task['init_params'](rng), task['init_hparams']())

    def repeat(b):
        while True:
            yield b

    state, hist = trainer.run(state, repeat(task['train']),
                              repeat(task['val']),
                              steps_per_outer=100,
                              n_outer=args.outer_steps, log_every=1)
    print(f"final validation loss: {hist['outer_loss'][-1]:.4f} "
          f"(solver={args.solver})")


if __name__ == '__main__':
    main()
