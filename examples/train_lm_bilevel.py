"""End-to-end driver: train an LM with bilevel data reweighting.

The corpus is a domain mixture where two domains are pure noise; every
``--outer-every`` steps a Nyström-IHVP hypergradient updates per-domain loss
weights against a clean validation stream — watch the "noisy-domain weight"
fall below uniform as the outer loop learns to discard the junk.

Defaults are CPU-sized (a ~1M-param yi-family model, a few hundred steps);
scale with e.g.:

  PYTHONPATH=src python examples/train_lm_bilevel.py \
      --arch yi_9b --no-reduced --steps 500 --batch 32 --seq 2048 \
      --ckpt-dir /tmp/lm_ckpt          # ~100M-class run on real hardware

Kill it mid-run and relaunch with the same --ckpt-dir to exercise the
checkpoint/restart path.
"""
import argparse
import pathlib
import sys

try:
    import repro  # noqa: F401  (pip install -e .  /  PYTHONPATH=src)
except ImportError:
    sys.path.insert(0, str(pathlib.Path(__file__).resolve().parents[1] / 'src'))

from repro.launch import train  # noqa: E402


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument('--arch', default='yi_9b')
    ap.add_argument('--no-reduced', action='store_true')
    ap.add_argument('--steps', type=int, default=300)
    ap.add_argument('--batch', type=int, default=8)
    ap.add_argument('--seq', type=int, default=128)
    ap.add_argument('--outer-every', type=int, default=50)
    ap.add_argument('--ckpt-dir', default=None)
    args = ap.parse_args()

    argv = ['--arch', args.arch, '--steps', str(args.steps),
            '--batch', str(args.batch), '--seq', str(args.seq),
            '--outer-every', str(args.outer_every)]
    if not args.no_reduced:
        argv.append('--reduced')
    if args.ckpt_dir:
        argv += ['--ckpt-dir', args.ckpt_dir]
    train.main(argv)


if __name__ == '__main__':
    main()
