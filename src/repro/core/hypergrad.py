"""Hypergradient assembly (Eq. 3) — solver-agnostic implicit differentiation.

    dg/dφ = −(∂g/∂θ) (∇²_θ f + ρI)⁻¹ (∂²f/∂φ∂θ) + ∂g/∂φ

The mixed second derivative is never materialized: with u = IHVP(∂g/∂θ), the
first term is the φ-gradient of ⟨∇_θ f, stop_grad(u)⟩ (one VJP through the
inner gradient). Total cost per hypergradient:

  * Nyström: k + 1 batched-parallel HVPs (sketch, reusable) + 1 VJP
  * CG/Neumann: l *sequential* HVPs + 1 VJP

The assembly itself lives in ``repro.core.implicit``: the inner solution is a
``jax.custom_vjp`` map whose backward pass *is* the IHVP + mixed-term VJP, so
Eq. 3 falls out of plain ``jax.grad`` composition. ``hypergradient`` below is
the original imperative entry point, kept as a thin compatibility wrapper
(see docs/implicit-api.md for the migration table).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable

import jax
import jax.numpy as jnp

from repro.core.tree_util import PyTree, PyTreeIndexer

InnerLoss = Callable[..., jax.Array]   # f(params, hparams, batch) -> scalar
OuterLoss = Callable[..., jax.Array]   # g(params, hparams, batch) -> scalar


def hypergradient(inner_loss: InnerLoss,
                  outer_loss: OuterLoss,
                  params: PyTree,
                  hparams: PyTree,
                  inner_batch: Any,
                  outer_batch: Any,
                  solver,
                  rng: jax.Array,
                  indexer: PyTreeIndexer | None = None,
                  sketch=None) -> PyTree:
    """Approximate dg/dφ at (params, hparams) via implicit differentiation.

    Compatibility wrapper: treats ``params`` as the (already-computed) inner
    solution, wraps it in the ``implicit_root`` solution map, and
    differentiates ``g(θ*(φ), φ)`` — new code should use
    ``repro.core.implicit.implicit_root`` directly, which also composes with
    ``jax.vmap`` over task batches.

    ``sketch``: an optional pre-built solver state (e.g. a ``NystromSketch``)
    — production trainers amortize one sketch over several outer steps (see
    BilevelTrainer).
    """
    from repro.core.implicit import implicit_root
    del indexer   # the implicit map rebuilds it from θ*; kept for API compat

    solve = implicit_root(lambda phi, batch: params, inner_loss, solver)

    def outer_obj(phi):
        theta = solve(phi, inner_batch, rng=rng, state=sketch)
        return outer_loss(theta, phi, outer_batch)

    return jax.grad(outer_obj)(hparams)


def unrolled_hypergradient(inner_loss: InnerLoss,
                           outer_loss: OuterLoss,
                           params: PyTree,
                           hparams: PyTree,
                           inner_batch: Any,
                           outer_batch: Any,
                           steps: int,
                           lr: float) -> PyTree:
    """Oracle baseline: differentiate through ``steps`` unrolled SGD steps.

    O(steps × activations) memory — tiny problems only; used in tests to
    validate the implicit estimates, and as the paper's §2.5 fallback for
    hyperparameters that do not influence the training loss directly.
    """
    def inner_sgd(phi):
        def step(p, _):
            g = jax.grad(inner_loss, argnums=0)(p, phi, inner_batch)
            p = jax.tree.map(lambda w, gw: w - lr * gw, p, g)
            return p, None
        final, _ = jax.lax.scan(step, params, None, length=steps)
        return outer_loss(final, phi, outer_batch)

    return jax.grad(inner_sgd)(hparams)


def config_from_cli(solver: str, flags: dict, defaults: dict,
                    **consumed_extras) -> 'HypergradConfig':
    """Build a HypergradConfig from CLI flags, registry-driven (shared by
    ``launch/train.py`` and ``examples/quickstart.py``).

    ``flags`` maps field → parsed value with ``None`` meaning "flag not
    passed" (use argparse ``default=None`` sentinels). An explicitly passed
    flag the chosen solver does not consume raises here — never a silent
    drop, even when the value coincides with the config default (which
    ``build()``'s own strictness check could not distinguish). Unpassed
    flags fall back to ``defaults`` when (and only when) the solver consumes
    them. ``consumed_extras`` are script-level tunings (e.g.
    ``column_chunk``) forwarded only to solvers that consume them and
    *silently dropped* otherwise — they are the solver-agnostic channel; put
    anything the user typed in ``flags`` so it gets the strictness check.

    "Consumes" is the same notion ``build()`` enforces: the solver's
    ``SolverSpec.fields``, plus the backend-selection family (``backend``,
    ``sketch_dtype``, ``mesh``, ``param_specs``) for solvers that build a
    backend, plus the trainer-level fields (``sketch_refresh_every``) which
    every solver's config carries:

    >>> config_from_cli('nystrom', flags={'backend': 'flat'},
    ...                 defaults={}).backend
    'flat'
    >>> config_from_cli('cg', flags={'backend': 'flat'}, defaults={})
    Traceback (most recent call last):
        ...
    ValueError: --backend=flat is not consumed by solver='cg' (it consumes: \
k, rho, sketch_refresh_every)
    """
    from repro.core.solvers import SOLVERS
    if solver not in SOLVERS:
        raise ValueError(f'unknown solver {solver!r}; registered: '
                         f'{sorted(SOLVERS)}')
    spec = SOLVERS[solver]
    consumed = set(spec.fields) | (set(_TRAINER_FIELDS) - {'solver'})
    if spec.builds_backend:
        consumed |= set(_BACKEND_FIELDS)
    kwargs = {'solver': solver}
    for name, value in flags.items():
        if value is not None:
            if name not in consumed:
                raise ValueError(
                    f'--{name}={value} is not consumed by solver='
                    f'{solver!r} (it consumes: '
                    f'{", ".join(sorted(consumed))})')
            kwargs[name] = value
        elif name in consumed and name in defaults:
            kwargs[name] = defaults[name]
    for name, value in consumed_extras.items():
        if name in consumed:
            kwargs[name] = value
    return HypergradConfig(**kwargs)


# Config fields consumed outside solver construction: ``solver`` selects the
# registry entry. ``sketch_refresh_every`` is the sketch-lifecycle cadence
# consumed by the trainer layer — BilevelTrainer.run and launch/train.py
# rebuild the amortized sketch every that-many outer steps (SketchPolicy);
# it is trainer-level by design, so it stays exempt from the solver-field
# strictness rather than erroring for every solver (run() itself raises when
# asked to amortize an iterative solver).
_TRAINER_FIELDS = ('solver', 'sketch_refresh_every')
# Backend-selection fields, consumed via _build_backend() by solvers whose
# SolverSpec sets builds_backend (today: nystrom).
_BACKEND_FIELDS = ('backend', 'mesh', 'param_specs', 'sketch_dtype')


@dataclasses.dataclass
class HypergradConfig:
    """Config-system entry for the hypergradient feature (see configs/).

    Backend selection (full decision table: README.md / docs/backends.md):
    ``backend`` names a contraction backend; ``flat_sharded`` additionally
    needs ``mesh`` (the jax.sharding.Mesh the step runs under) and
    ``param_specs`` (the PartitionSpec pytree for the parameters, e.g.
    ``repro.distributed.sharding.param_specs(cfg, mesh)``) — ``build()``
    constructs the bound backend instance from them. ``sketch_dtype``
    ('bfloat16' halves sketch memory; contractions accumulate f32) applies
    to the flat family and is rejected for ``tree``, which never builds a
    fused buffer.

    >>> cfg = HypergradConfig(solver='nystrom', k=4, backend='flat')
    >>> solver = cfg.build()
    >>> (solver.k, solver.backend)
    (4, 'flat')
    >>> import jax, numpy as np
    >>> from jax.sharding import Mesh, PartitionSpec as P
    >>> mesh = Mesh(np.array(jax.devices()[:1]), ('model',))
    >>> sharded = HypergradConfig(backend='flat_sharded', mesh=mesh,
    ...                           sketch_dtype='bfloat16').build()
    >>> sharded.backend.name
    'flat_sharded'
    """
    solver: str = 'nystrom'       # nystrom | cg | neumann | exact
    k: int = 10                   # Nyström rank / iterations l for baselines
    rho: float = 1e-2             # damping (Nyström/exact) or CG Tikhonov
    alpha: float = 1e-2           # Neumann step size
    kappa: int | None = None      # Alg. 1 chunk width (None = Eq. 6)
    column_chunk: int | None = None
    sketch_refresh_every: int = 1  # outer steps between sketch rebuilds
    importance_sampling: bool = False
    backend: str = 'tree'         # tree | flat | flat_sharded | pallas
    #   tree         = pytree einsums, sharding-transparent, the default
    #   flat         = fused (k, p) buffer, one XLA matmul per contraction
    #   flat_sharded = per-device fused shards + psum (needs mesh/specs)
    #   pallas       = flat buffer + TPU kernels (interpret fallback off-TPU)
    mesh: Any = None              # flat_sharded: the step's jax Mesh
    param_specs: Any = None       # flat_sharded: PartitionSpec pytree
    sketch_dtype: str | None = None  # flat family: 'bfloat16' halves sketch
    #   memory; contractions still accumulate in f32
    refine: int = 1               # residual sweeps on the stabilized apply:
    #   0 = literal two-C-pass apply; each sweep adds 4 C-passes and drives
    #   the f32 cancellation error (~eps·λmax/ρ) down to roundoff
    stabilized: bool = True       # False = the literal Eq. 6 apply (the
    #   paper-faithful 'nystrom_eq6' benchmark variant); True = the
    #   whitened-Woodbury apply (backward-stable; see NystromIHVP)

    def _build_backend(self):
        from repro.core.backend import get_backend
        if not isinstance(self.backend, str):
            if (self.sketch_dtype is not None or self.mesh is not None
                    or self.param_specs is not None):
                raise ValueError(
                    'backend is a pre-built instance: set sketch_dtype / '
                    'mesh / param_specs on the instance itself — the config '
                    'fields would be silently ignored')
            return self.backend            # pre-built instance passes through
        kwargs = {}
        if self.sketch_dtype is not None:
            if self.backend == 'tree':
                raise ValueError(
                    "sketch_dtype has no effect on backend='tree' (it never "
                    'builds a fused buffer); pick a flat-family backend')
            kwargs['sketch_dtype'] = jnp.dtype(self.sketch_dtype).type
        if self.backend == 'flat_sharded':
            kwargs.update(mesh=self.mesh, specs=self.param_specs)
        elif self.mesh is not None or self.param_specs is not None:
            raise ValueError(
                "mesh/param_specs are only consumed by backend='flat_sharded'")
        return get_backend(self.backend, **kwargs) if kwargs else self.backend

    def build(self):
        """Construct the configured solver via the ``SOLVERS`` registry.

        Each registry entry records which config fields its solver consumes;
        a field set to a non-default value that the chosen solver ignores is
        an error here — matching the backend-field strictness — instead of a
        silently dead knob:

        >>> HypergradConfig(solver='cg', alpha=0.5).build()
        Traceback (most recent call last):
            ...
        ValueError: HypergradConfig.alpha=0.5 is not consumed by \
solver='cg' (it consumes: k, rho) — it would be silently ignored
        """
        from repro.core.solvers import SOLVERS
        spec = SOLVERS.get(self.solver)
        if spec is None:
            raise ValueError(f'unknown solver {self.solver!r}; registered: '
                             f'{sorted(SOLVERS)}')
        consumed = set(spec.fields) | set(_TRAINER_FIELDS)
        if spec.builds_backend:
            consumed |= set(_BACKEND_FIELDS)
        for f in dataclasses.fields(self):
            if f.name in consumed:
                continue
            if getattr(self, f.name) != f.default:
                raise ValueError(
                    f'HypergradConfig.{f.name}={getattr(self, f.name)!r} is '
                    f'not consumed by solver={self.solver!r} (it consumes: '
                    f'{", ".join(sorted(spec.fields))}) — it would be '
                    'silently ignored')
        kwargs = {kw: getattr(self, name) for name, kw in spec.fields.items()}
        if spec.builds_backend:
            kwargs['backend'] = self._build_backend()
        return spec.cls(**kwargs)
