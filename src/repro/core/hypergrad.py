"""Hypergradient assembly (Eq. 3) — solver-agnostic implicit differentiation.

    dg/dφ = −(∂g/∂θ) (∇²_θ f + ρI)⁻¹ (∂²f/∂φ∂θ) + ∂g/∂φ

The mixed second derivative is never materialized: with u = IHVP(∂g/∂θ), the
first term is the φ-gradient of ⟨∇_θ f, stop_grad(u)⟩ (one VJP through the
inner gradient). Total cost per hypergradient:

  * Nyström: k + 1 batched-parallel HVPs (sketch, reusable) + 1 VJP
  * CG/Neumann: l *sequential* HVPs + 1 VJP
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable

import jax
import jax.numpy as jnp

from repro.core.hvp import make_hvp
from repro.core.tree_util import PyTree, PyTreeIndexer, tree_sub

InnerLoss = Callable[..., jax.Array]   # f(params, hparams, batch) -> scalar
OuterLoss = Callable[..., jax.Array]   # g(params, hparams, batch) -> scalar


def hypergradient(inner_loss: InnerLoss,
                  outer_loss: OuterLoss,
                  params: PyTree,
                  hparams: PyTree,
                  inner_batch: Any,
                  outer_batch: Any,
                  solver,
                  rng: jax.Array,
                  indexer: PyTreeIndexer | None = None,
                  sketch=None) -> PyTree:
    """Approximate dg/dφ at (params, hparams) via implicit differentiation.

    ``sketch``: an optional pre-built ``NystromSketch`` — production trainers
    amortize one sketch over several outer steps (see BilevelTrainer).
    """
    indexer = indexer or PyTreeIndexer(params)

    # v = ∂g/∂θ
    v = jax.grad(outer_loss, argnums=0)(params, hparams, outer_batch)

    # u = (H + ρI)⁻¹ v
    hvp = make_hvp(inner_loss, params, hparams, inner_batch)
    if sketch is not None and hasattr(solver, 'apply'):
        u = solver.apply(sketch, v)
    else:
        u = solver.solve(hvp, indexer, v, rng)
    u = jax.lax.stop_gradient(u)

    # mixed term: ∇_φ ⟨∇_θ f(θ, φ), u⟩  (= (∂²f/∂φ∂θ)ᵀ u)
    def inner_grad_dot_u(phi):
        g_theta = jax.grad(inner_loss, argnums=0)(params, phi, inner_batch)
        leaves = jax.tree.leaves(jax.tree.map(
            lambda a, b: jnp.vdot(a.astype(jnp.float32),
                                  b.astype(jnp.float32)), g_theta, u))
        return sum(leaves)

    mixed = jax.grad(inner_grad_dot_u)(hparams)

    # direct term: ∂g/∂φ (zero for e.g. regularization hyperparameters)
    direct = jax.grad(outer_loss, argnums=1)(params, hparams, outer_batch)

    return tree_sub(direct, mixed)


def unrolled_hypergradient(inner_loss: InnerLoss,
                           outer_loss: OuterLoss,
                           params: PyTree,
                           hparams: PyTree,
                           inner_batch: Any,
                           outer_batch: Any,
                           steps: int,
                           lr: float) -> PyTree:
    """Oracle baseline: differentiate through ``steps`` unrolled SGD steps.

    O(steps × activations) memory — tiny problems only; used in tests to
    validate the implicit estimates, and as the paper's §2.5 fallback for
    hyperparameters that do not influence the training loss directly.
    """
    def inner_sgd(phi):
        def step(p, _):
            g = jax.grad(inner_loss, argnums=0)(p, phi, inner_batch)
            p = jax.tree.map(lambda w, gw: w - lr * gw, p, g)
            return p, None
        final, _ = jax.lax.scan(step, params, None, length=steps)
        return outer_loss(final, phi, outer_batch)

    return jax.grad(inner_sgd)(hparams)


@dataclasses.dataclass
class HypergradConfig:
    """Config-system entry for the hypergradient feature (see configs/).

    Backend selection (full decision table: README.md / docs/backends.md):
    ``backend`` names a contraction backend; ``flat_sharded`` additionally
    needs ``mesh`` (the jax.sharding.Mesh the step runs under) and
    ``param_specs`` (the PartitionSpec pytree for the parameters, e.g.
    ``repro.distributed.sharding.param_specs(cfg, mesh)``) — ``build()``
    constructs the bound backend instance from them. ``sketch_dtype``
    ('bfloat16' halves sketch memory; contractions accumulate f32) applies
    to the flat family and is rejected for ``tree``, which never builds a
    fused buffer.

    >>> cfg = HypergradConfig(solver='nystrom', k=4, backend='flat')
    >>> solver = cfg.build()
    >>> (solver.k, solver.backend)
    (4, 'flat')
    >>> import jax, numpy as np
    >>> from jax.sharding import Mesh, PartitionSpec as P
    >>> mesh = Mesh(np.array(jax.devices()[:1]), ('model',))
    >>> sharded = HypergradConfig(backend='flat_sharded', mesh=mesh,
    ...                           sketch_dtype='bfloat16').build()
    >>> sharded.backend.name
    'flat_sharded'
    """
    solver: str = 'nystrom'       # nystrom | cg | neumann | exact
    k: int = 10                   # Nyström rank / iterations l for baselines
    rho: float = 1e-2             # damping (Nyström/exact) or CG Tikhonov
    alpha: float = 1e-2           # Neumann step size
    kappa: int | None = None      # Alg. 1 chunk width (None = Eq. 6)
    column_chunk: int | None = None
    sketch_refresh_every: int = 1  # outer steps between sketch rebuilds
    importance_sampling: bool = False
    backend: str = 'tree'         # tree | flat | flat_sharded | pallas
    #   tree         = pytree einsums, sharding-transparent, the default
    #   flat         = fused (k, p) buffer, one XLA matmul per contraction
    #   flat_sharded = per-device fused shards + psum (needs mesh/specs)
    #   pallas       = flat buffer + TPU kernels (interpret fallback off-TPU)
    mesh: Any = None              # flat_sharded: the step's jax Mesh
    param_specs: Any = None       # flat_sharded: PartitionSpec pytree
    sketch_dtype: str | None = None  # flat family: 'bfloat16' halves sketch
    #   memory; contractions still accumulate in f32
    refine: int = 1               # residual sweeps on the stabilized apply:
    #   0 = literal two-C-pass apply; each sweep adds 4 C-passes and drives
    #   the f32 cancellation error (~eps·λmax/ρ) down to roundoff

    def _build_backend(self):
        from repro.core.backend import get_backend
        if not isinstance(self.backend, str):
            if (self.sketch_dtype is not None or self.mesh is not None
                    or self.param_specs is not None):
                raise ValueError(
                    'backend is a pre-built instance: set sketch_dtype / '
                    'mesh / param_specs on the instance itself — the config '
                    'fields would be silently ignored')
            return self.backend            # pre-built instance passes through
        kwargs = {}
        if self.sketch_dtype is not None:
            if self.backend == 'tree':
                raise ValueError(
                    "sketch_dtype has no effect on backend='tree' (it never "
                    'builds a fused buffer); pick a flat-family backend')
            kwargs['sketch_dtype'] = jnp.dtype(self.sketch_dtype).type
        if self.backend == 'flat_sharded':
            kwargs.update(mesh=self.mesh, specs=self.param_specs)
        elif self.mesh is not None or self.param_specs is not None:
            raise ValueError(
                "mesh/param_specs are only consumed by backend='flat_sharded'")
        return get_backend(self.backend, **kwargs) if kwargs else self.backend

    def build(self):
        from repro.core.solvers import (CGIHVP, ExactIHVP, NeumannIHVP,
                                        NystromIHVP)
        if self.solver == 'nystrom':
            return NystromIHVP(k=self.k, rho=self.rho, kappa=self.kappa,
                               column_chunk=self.column_chunk,
                               importance_sampling=self.importance_sampling,
                               backend=self._build_backend(),
                               refine=self.refine)
        if self.solver == 'cg':
            return CGIHVP(iters=self.k, rho=self.rho)
        if self.solver == 'neumann':
            return NeumannIHVP(iters=self.k, alpha=self.alpha)
        if self.solver == 'exact':
            return ExactIHVP(rho=self.rho)
        raise ValueError(f'unknown solver {self.solver!r}')
