"""Pluggable tall-skinny contraction backends for the Nyström solver.

Everything expensive the Nyström IHVP does after the sketch HVPs is one of
four contractions against the tall-skinny operand C (p × k, p up to
billions, k ≤ a few hundred):

    ctv        t = Cᵀ v       → (k,)      (apply pass 1)
    cv         u = C w        → p-vector  (apply pass 2)
    gram       G = CᵀC        → (k, k)    (prepare / Eq. 6 core)
    mul_right  B = C M        → p × j     (spectral whitening, Alg. 1 U-mix)

The seed implementation ran each of these as a per-leaf ``jnp.einsum`` over
the parameter pytree plus a Python-level sum — n_leaves kernel launches and
n_leaves partial results per contraction, which is exactly the overhead the
paper's "matrix operations without iterations" claim says we should not pay.
A backend owns the operand representation and fuses the p-pass:

* ``tree``   — the seed behavior: C stays a parameter pytree with a leading
  k axis, contractions are per-leaf einsums. The ONLY backend that never
  flattens a leaf, so multi-axis pjit shardings pass through untouched —
  required for sharded params (flattening a sharded leaf all-gathers it),
  and the default.
* ``flat``   — the pytree is fused ONCE (at ``prepare()``) into a single
  (p, k) f32 buffer; every contraction is then one XLA matmul over the
  fused buffer. One p-pass per contraction regardless of leaf count; wins
  on CPU/GPU/single-chip TPU whenever the tree has more than a few leaves.
* ``pallas`` — the same flat buffer, with ``gram``/``ctv`` and the fused
  Woodbury pass-2 (``v/ρ + C w``) dispatched to the hand-tiled TPU kernels
  in ``repro.kernels`` (one HBM read of C per pass, VMEM-resident k-tile
  accumulator). Off-TPU the kernels execute in interpret mode — bit-faithful
  but slow; select it off-TPU only in tests.

Vectors travel in the backend's native form: ``vec()`` converts a parameter
pytree once per apply, ``unvec()`` converts the result back (identity for
``tree``). ``NystromIHVP`` threads a backend instance through prepare/apply;
see ``repro.core.solvers``.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from repro.core.tree_util import PyTree, tree_axpy, tree_scale, tree_sub

# ---------------------------------------------------------------------------
# pytree <-> fused-buffer conversion (the one-time cost of the flat backends)
# ---------------------------------------------------------------------------


def flatten_sketch(C: PyTree) -> jax.Array:
    """Fuse a leading-k pytree (leaves (k, *shape)) into one (k, p) f32
    buffer, leaves concatenated in ``jax.tree.leaves`` order.

    Sketch-major (k, p) is the cache-friendly layout for XLA-on-CPU/GPU:
    every contraction streams contiguous p-rows (measured 35× over the
    transposed layout for Cᵀv at p=8M on CPU). The Pallas kernels tile the
    transposed (p, k) layout instead — PallasBackend transposes once at
    prepare()."""
    cols = [c.astype(jnp.float32).reshape(c.shape[0], -1)
            for c in jax.tree.leaves(C)]
    return jnp.concatenate(cols, axis=1)


def flatten_vec(v: PyTree) -> jax.Array:
    """Parameter pytree → (p,) f32, same leaf order as ``flatten_sketch``."""
    return jnp.concatenate([x.astype(jnp.float32).ravel()
                            for x in jax.tree.leaves(v)])


def unflatten_vec(u: jax.Array, like: PyTree) -> PyTree:
    """(p,) → pytree shaped/dtyped like ``like`` (the unflatten spec is read
    off the reference tree, so sketches never store shape metadata)."""
    leaves, treedef = jax.tree.flatten(like)
    outs, off = [], 0
    for l in leaves:
        outs.append(u[off:off + l.size].reshape(l.shape).astype(l.dtype))
        off += l.size
    return treedef.unflatten(outs)


# ---------------------------------------------------------------------------
# backends
# ---------------------------------------------------------------------------
@dataclasses.dataclass(frozen=True)
class TreeBackend:
    """Per-leaf einsum contractions on the parameter pytree (seed behavior,
    pjit/sharding-transparent)."""
    name = 'tree'

    def prepare_operand(self, C: PyTree):
        return C

    def vec(self, v: PyTree):
        return v

    def unvec(self, u, like: PyTree) -> PyTree:
        del like
        return u

    def ctv(self, C, v) -> jax.Array:
        parts = jax.tree.leaves(jax.tree.map(
            lambda c, x: jnp.einsum('k...,...->k', c.astype(jnp.float32),
                                    x.astype(jnp.float32)), C, v))
        return sum(parts)

    def cv(self, C, w: jax.Array):
        return jax.tree.map(
            lambda c: jnp.einsum('k...,k->...', c.astype(jnp.float32), w), C)

    def gram(self, C) -> jax.Array:
        return self.cross(C, C)

    def cross(self, A, B) -> jax.Array:
        parts = jax.tree.leaves(jax.tree.map(
            lambda a, b: jnp.einsum('k...,j...->kj', a.astype(jnp.float32),
                                    b.astype(jnp.float32)), A, B))
        return sum(parts)

    def mul_right(self, C, M: jax.Array):
        return jax.tree.map(
            lambda c: jnp.einsum('k...,kj->j...', c.astype(jnp.float32), M), C)

    def slice_k(self, C, start: int, width: int):
        return jax.tree.map(
            lambda c: jax.lax.slice_in_dim(c, start, start + width, axis=0), C)

    def scale(self, x, s):
        return tree_scale(x, s)

    def sub(self, a, b):
        return tree_sub(a, b)

    def add(self, a, b):
        return jax.tree.map(jnp.add, a, b)

    def combine(self, C, w: jax.Array, v, rho: float):
        """u = v/ρ + C w (the fused Woodbury pass 2)."""
        return tree_axpy(1.0, self.cv(C, w), tree_scale(v, 1.0 / rho))


@dataclasses.dataclass(frozen=True)
class FlatBackend:
    """One fused XLA matmul per contraction over the sketch-major (k, p)
    buffer (contiguous p-rows — see ``flatten_sketch``)."""
    name = 'flat'

    def prepare_operand(self, C: PyTree) -> jax.Array:
        return flatten_sketch(C)

    def vec(self, v: PyTree) -> jax.Array:
        return flatten_vec(v)

    def unvec(self, u: jax.Array, like: PyTree) -> PyTree:
        return unflatten_vec(u, like)

    def ctv(self, Ckp: jax.Array, vf: jax.Array) -> jax.Array:
        return Ckp @ vf

    def cv(self, Ckp: jax.Array, w: jax.Array) -> jax.Array:
        return w @ Ckp

    def gram(self, Ckp: jax.Array) -> jax.Array:
        return Ckp @ Ckp.T

    def cross(self, Akp: jax.Array, Bkp: jax.Array) -> jax.Array:
        return Akp @ Bkp.T

    def mul_right(self, Ckp: jax.Array, M: jax.Array) -> jax.Array:
        return M.T @ Ckp                                  # (j, p)

    def slice_k(self, Ckp: jax.Array, start: int, width: int) -> jax.Array:
        return jax.lax.slice_in_dim(Ckp, start, start + width, axis=0)

    def scale(self, x: jax.Array, s) -> jax.Array:
        return x * s

    def sub(self, a: jax.Array, b: jax.Array) -> jax.Array:
        return a - b

    def add(self, a: jax.Array, b: jax.Array) -> jax.Array:
        return a + b

    def combine(self, Ckp: jax.Array, w: jax.Array, vf: jax.Array,
                rho: float) -> jax.Array:
        return vf / rho + w @ Ckp


@dataclasses.dataclass(frozen=True)
class PallasBackend(FlatBackend):
    """Fused buffer + Pallas TPU kernels for the C-streaming passes.

    The operand is the kernel-tiled (p, k) layout (k padded to the 128-lane
    width inside the kernels) — the transpose of FlatBackend's buffer, taken
    once at prepare(). ``interpret=None`` lets the kernel wrappers pick
    (compiled on TPU, interpret elsewhere); ``block_p`` is the p-tile the
    grid streams. ``cv``/``mul_right``/``cross`` stay on XLA: they are
    p-output or k×k-output matmuls XLA already tiles well; gram/ctv/combine
    are the C-streaming reduction passes the kernels were built for.
    """
    name = 'pallas'
    interpret: bool | None = None
    block_p: int = 1024

    def prepare_operand(self, C: PyTree) -> jax.Array:
        return flatten_sketch(C).T                        # (p, k)

    def ctv(self, Cpk: jax.Array, vf: jax.Array) -> jax.Array:
        from repro.kernels import ops
        return ops.woodbury_ctv(Cpk, vf, block_p=self.block_p,
                                interpret=self.interpret)

    def cv(self, Cpk: jax.Array, w: jax.Array) -> jax.Array:
        return Cpk @ w

    def gram(self, Cpk: jax.Array) -> jax.Array:
        from repro.kernels import ops
        return ops.nystrom_gram(Cpk, block_p=self.block_p,
                                interpret=self.interpret)

    def cross(self, Apk: jax.Array, Bpk: jax.Array) -> jax.Array:
        return Apk.T @ Bpk

    def mul_right(self, Cpk: jax.Array, M: jax.Array) -> jax.Array:
        return Cpk @ M                                    # (p, j)

    def slice_k(self, Cpk: jax.Array, start: int, width: int) -> jax.Array:
        return jax.lax.slice_in_dim(Cpk, start, start + width, axis=1)

    def combine(self, Cpk: jax.Array, w: jax.Array, vf: jax.Array,
                rho: float) -> jax.Array:
        from repro.kernels import ops
        # woodbury_apply computes v/ρ − C w̃/ρ²; w̃ = −ρ² w gives v/ρ + C w.
        return ops.woodbury_apply(Cpk, -(rho * rho) * w, vf, rho,
                                  block_p=self.block_p,
                                  interpret=self.interpret)


BACKENDS = {'tree': TreeBackend, 'flat': FlatBackend, 'pallas': PallasBackend}


def get_backend(name: str, **kwargs):
    """'tree' | 'flat' | 'pallas' → backend instance. kwargs reach the
    backend constructor (e.g. ``interpret=True`` for pallas in tests)."""
    try:
        cls = BACKENDS[name]
    except KeyError:
        raise ValueError(
            f'unknown backend {name!r}; expected one of {sorted(BACKENDS)}')
    return cls(**kwargs)
