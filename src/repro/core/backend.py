"""Pluggable tall-skinny contraction backends for the Nyström solver.

Everything expensive the Nyström IHVP does after the sketch HVPs is one of
four contractions against the tall-skinny operand C (p × k, p up to
billions, k ≤ a few hundred):

    ctv        t = Cᵀ v       → (k,)      (apply pass 1)
    cv         u = C w        → p-vector  (apply pass 2)
    gram       G = CᵀC        → (k, k)    (prepare / Eq. 6 core)
    mul_right  B = C M        → p × j     (spectral whitening, Alg. 1 U-mix)

The seed implementation ran each of these as a per-leaf ``jnp.einsum`` over
the parameter pytree plus a Python-level sum — n_leaves kernel launches and
n_leaves partial results per contraction, which is exactly the overhead the
paper's "matrix operations without iterations" claim says we should not pay.
A backend owns the operand representation and fuses the p-pass. Four ship
(full design doc: ``docs/backends.md``):

* ``tree``         — the seed behavior: C stays a parameter pytree with a
  leading k axis, contractions are per-leaf einsums. Never flattens a leaf,
  so multi-axis pjit shardings pass through untouched; the default and the
  parity oracle for the others.
* ``flat``         — the pytree is fused ONCE (at ``prepare()``) into a
  single sketch-major (k, p) buffer; every contraction is then one XLA
  matmul. One p-pass per contraction regardless of leaf count; wins on
  CPU/GPU/single-chip TPU whenever the tree has more than a few leaves.
  Flattening a pjit-sharded leaf all-gathers it — unsharded steps only.
* ``flat_sharded`` — ``flat``'s fusion under GSPMD sharding: each device
  fuses only its *local* parameter shards into a per-device (k, p_local)
  buffer inside ``shard_map`` (PartitionSpec rules from
  ``repro.distributed.sharding``), contractions run on the local buffer,
  and the reductions (``ctv``/``gram``/``cross``) finish with a psum of
  k (resp. k×k) floats across the mesh. Leaves replicated along some mesh
  axes are down-weighted by 1/replication so the psum never overcounts.
  No parameter leaf is ever all-gathered.
* ``pallas``       — the flat buffer in the kernel-tiled (p, k) transpose;
  ``gram``/``ctv`` and the fused Woodbury pass-2 (``v/ρ + C w``) dispatch
  to the hand-tiled TPU kernels in ``repro.kernels`` (one HBM read of C per
  pass, VMEM-resident k-tile accumulator). Off-TPU the kernels execute in
  interpret mode — bit-faithful but slow; select it off-TPU only in tests.

All flat-family backends take ``sketch_dtype=`` (default f32): the fused
sketch buffer — the dominant O(kp) state — may be stored in bf16 while
every contraction still *accumulates* in f32 (XLA ``preferred_element_type``
/ the Pallas kernels' in-kernel upcast), halving sketch HBM at large p.

Vectors travel in the backend's native form: ``vec()`` converts a parameter
pytree once per apply, ``unvec()`` converts the result back (identity for
``tree``). ``NystromIHVP`` threads a backend instance through prepare/apply;
see ``repro.core.solvers``.

Matrix-valued queries ride the same contract with an ``m`` suffix: a *query
block* is a pytree whose every leaf carries the parameter shape plus one
trailing (m,) axis (m stacked cotangents). ``vecm``/``unvecm`` fuse it to the
backend's (p, m) form, ``ctm`` is CᵀV → (k, m), ``cm`` is C·W → block, and
``combinem`` the fused Woodbury pass 2 for all m queries in one C-read.
``flat_sharded`` finishes ``ctm`` with a single (k, m) psum — one collective
per apply pass regardless of m (contract details: ``docs/backends.md``).

Examples
--------
Fuse a two-leaf sketch (k=2) and run contractions under ``flat``
(``jax.tree.leaves`` orders dict keys, so 'b' precedes 'w'):

>>> import jax.numpy as jnp
>>> from repro.core.backend import get_backend
>>> C = {'b': jnp.ones((2, 2)), 'w': jnp.arange(6.0).reshape(2, 3)}
>>> v = {'b': jnp.full((2,), 2.0), 'w': jnp.ones((3,))}
>>> be = get_backend('flat')
>>> op = be.prepare_operand(C)          # fused sketch-major (k, p) buffer
>>> op.shape
(2, 5)
>>> [float(t) for t in be.ctv(op, be.vec(v))]                   # Cᵀv
[7.0, 16.0]
>>> [[float(g) for g in row] for row in be.gram(op)]            # CᵀC
[[7.0, 16.0], [16.0, 52.0]]

``flat_sharded`` produces the same numbers from per-device local buffers —
here on a trivial 1-device mesh; on a real mesh each device only ever
touches its own parameter shards:

>>> import jax, numpy as np
>>> from jax.sharding import Mesh, PartitionSpec as P
>>> mesh = Mesh(np.array(jax.devices()[:1]), ('model',))
>>> sb = get_backend('flat_sharded', mesh=mesh,
...                  specs={'b': P(), 'w': P(None, 'model')})
>>> sop = sb.prepare_operand(C)
>>> [float(t) for t in sb.ctv(sop, sb.vec(v))]
[7.0, 16.0]

bf16 sketch storage halves the buffer; contractions still accumulate f32:

>>> bf = get_backend('flat', sketch_dtype=jnp.bfloat16)
>>> str(bf.prepare_operand(C).dtype)
'bfloat16'
>>> [float(t) for t in bf.ctv(bf.prepare_operand(C), bf.vec(v))]
[7.0, 16.0]
"""
from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

from repro.analysis.auditor import Contract
from repro.core.tree_util import PyTree, tree_axpy, tree_scale, tree_sub

# ---------------------------------------------------------------------------
# Declared structural contracts.  These are the docstring guarantees above,
# written as checkable objects: tests (and any caller holding a lowered
# apply) audit the real program against them instead of grepping HLO text.
# ---------------------------------------------------------------------------

#: ``flat_sharded`` apply / apply_matrix (refine=0): the k-output reduction
#: is exactly ONE psum — a (k,) or (k, m) all-reduce — per apply pass, no
#: parameter leaf is ever all-gathered (in lowered StableHLO or in the
#: GSPMD-partitioned HLO), every contraction accumulates f32 even under
#: bf16 sketch storage, and nothing round-trips through the host.
FLAT_SHARDED_CONTRACT = Contract(
    name='flat_sharded apply',
    no_all_gather=True,
    exact_collectives={'psum': 1},
    min_accum_dtype='float32',
    min_reduction_dtype='float32',
    no_host_transfer=True,
)

#: bf16 sketch storage (any flat-family backend): the buffer may be bf16
#: but every dot accumulates f32 (``preferred_element_type``) and every
#: cross-device reduction carries f32 — storage precision never leaks into
#: accumulation.
BF16_SKETCH_CONTRACT = Contract(
    name='bf16 sketch contraction',
    min_accum_dtype='float32',
    min_reduction_dtype='float32',
)

# ---------------------------------------------------------------------------
# pytree <-> fused-buffer conversion (the one-time cost of the flat backends)
# ---------------------------------------------------------------------------


def flatten_sketch(C: PyTree, dtype=jnp.float32) -> jax.Array:
    """Fuse a leading-k pytree (leaves (k, *shape)) into one (k, p) buffer
    of ``dtype``, leaves concatenated in ``jax.tree.leaves`` order.

    Sketch-major (k, p) is the cache-friendly layout for XLA-on-CPU/GPU:
    every contraction streams contiguous p-rows (measured 35× over the
    transposed layout for Cᵀv at p=8M on CPU). The Pallas kernels tile the
    transposed (p, k) layout instead — PallasBackend transposes once at
    prepare()."""
    cols = [c.astype(dtype).reshape(c.shape[0], -1)
            for c in jax.tree.leaves(C)]
    return jnp.concatenate(cols, axis=1)


def flatten_vec(v: PyTree) -> jax.Array:
    """Parameter pytree → (p,) f32, same leaf order as ``flatten_sketch``."""
    return jnp.concatenate([x.astype(jnp.float32).ravel()
                            for x in jax.tree.leaves(v)])


def unflatten_vec(u: jax.Array, like: PyTree) -> PyTree:
    """(p,) → pytree shaped/dtyped like ``like`` (the unflatten spec is read
    off the reference tree, so sketches never store shape metadata)."""
    leaves, treedef = jax.tree.flatten(like)
    outs, off = [], 0
    for l in leaves:
        outs.append(u[off:off + l.size].reshape(l.shape).astype(l.dtype))
        off += l.size
    return treedef.unflatten(outs)


def flatten_vecm(V: PyTree) -> jax.Array:
    """Query-block pytree (every leaf = param shape + trailing (m,)) →
    (p, m) f32, rows in ``flatten_vec``'s leaf order.

    A *query block* is the matrix-apply form of a parameter vector: m
    cotangents stacked on one trailing axis, so leaf ``(27, 37)`` travels as
    ``(27, 37, m)`` and a scalar leaf as ``(m,)``."""
    return jnp.concatenate(
        [x.astype(jnp.float32).reshape(-1, x.shape[-1])
         for x in jax.tree.leaves(V)], axis=0)


def unflatten_vecm(U: jax.Array, like: PyTree) -> PyTree:
    """(p, m) → query-block pytree shaped/dtyped like ``like`` (a reference
    block whose leaves already carry the trailing query axis)."""
    leaves, treedef = jax.tree.flatten(like)
    outs, off = [], 0
    for l in leaves:
        rows = l.size // l.shape[-1]
        outs.append(U[off:off + rows].reshape(l.shape).astype(l.dtype))
        off += rows
    return treedef.unflatten(outs)


# ---------------------------------------------------------------------------
# backends
# ---------------------------------------------------------------------------
@dataclasses.dataclass(frozen=True)
class TreeBackend:
    """Per-leaf einsum contractions on the parameter pytree (seed behavior,
    pjit/sharding-transparent)."""
    name = 'tree'

    def prepare_operand(self, C: PyTree):
        return C

    def vec(self, v: PyTree):
        return v

    def unvec(self, u, like: PyTree) -> PyTree:
        del like
        return u

    def ctv(self, C, v) -> jax.Array:
        parts = jax.tree.leaves(jax.tree.map(
            lambda c, x: jnp.einsum('k...,...->k', c.astype(jnp.float32),
                                    x.astype(jnp.float32)), C, v))
        return sum(parts)

    def cv(self, C, w: jax.Array):
        return jax.tree.map(
            lambda c: jnp.einsum('k...,k->...', c.astype(jnp.float32), w), C)

    def gram(self, C) -> jax.Array:
        return self.cross(C, C)

    def cross(self, A, B) -> jax.Array:
        parts = jax.tree.leaves(jax.tree.map(
            lambda a, b: jnp.einsum('k...,j...->kj', a.astype(jnp.float32),
                                    b.astype(jnp.float32)), A, B))
        return sum(parts)

    def mul_right(self, C, M: jax.Array):
        return jax.tree.map(
            lambda c: jnp.einsum('k...,kj->j...', c.astype(jnp.float32), M), C)

    def slice_k(self, C, start: int, width: int):
        return jax.tree.map(
            lambda c: jax.lax.slice_in_dim(c, start, start + width, axis=0), C)

    def scale(self, x, s):
        return tree_scale(x, s)

    def sub(self, a, b):
        return tree_sub(a, b)

    def add(self, a, b):
        return jax.tree.map(jnp.add, a, b)

    def combine(self, C, w: jax.Array, v, rho: float):
        """u = v/ρ + C w (the fused Woodbury pass 2)."""
        return tree_axpy(1.0, self.cv(C, w), tree_scale(v, 1.0 / rho))

    # -- matrix-valued queries: trailing (m,) axis on every leaf ------------
    def vecm(self, V: PyTree):
        return V

    def unvecm(self, U, like: PyTree) -> PyTree:
        del like
        return U

    def ctm(self, C, V) -> jax.Array:
        """CᵀV over an m-query block → (k, m)."""
        parts = jax.tree.leaves(jax.tree.map(
            lambda c, x: jnp.einsum('k...,...m->km', c.astype(jnp.float32),
                                    x.astype(jnp.float32)), C, V))
        return sum(parts)

    def cm(self, C, W: jax.Array):
        """C W for W (k, m) → a query-block pytree."""
        return jax.tree.map(
            lambda c: jnp.einsum('k...,km->...m', c.astype(jnp.float32), W), C)

    def combinem(self, C, W: jax.Array, V, rho: float):
        """U = V/ρ + C W (the fused Woodbury pass 2, m queries at once)."""
        return tree_axpy(1.0, self.cm(C, W), tree_scale(V, 1.0 / rho))


@dataclasses.dataclass(frozen=True)
class FlatBackend:
    """One fused XLA matmul per contraction over the sketch-major (k, p)
    buffer (contiguous p-rows — see ``flatten_sketch``).

    ``sketch_dtype``: storage dtype of the fused buffer (bf16 halves sketch
    HBM); every contraction accumulates f32 via ``preferred_element_type``.
    """
    name = 'flat'
    sketch_dtype: Any = jnp.float32

    def prepare_operand(self, C: PyTree) -> jax.Array:
        return flatten_sketch(C, dtype=self.sketch_dtype)

    def vec(self, v: PyTree) -> jax.Array:
        return flatten_vec(v)

    def unvec(self, u: jax.Array, like: PyTree) -> PyTree:
        return unflatten_vec(u, like)

    def ctv(self, Ckp: jax.Array, vf: jax.Array) -> jax.Array:
        return jnp.einsum('kp,p->k', Ckp, vf,
                          preferred_element_type=jnp.float32)

    def cv(self, Ckp: jax.Array, w: jax.Array) -> jax.Array:
        return jnp.einsum('kp,k->p', Ckp, w,
                          preferred_element_type=jnp.float32)

    def gram(self, Ckp: jax.Array) -> jax.Array:
        return self.cross(Ckp, Ckp)

    def cross(self, Akp: jax.Array, Bkp: jax.Array) -> jax.Array:
        return jnp.einsum('kp,jp->kj', Akp, Bkp,
                          preferred_element_type=jnp.float32)

    def mul_right(self, Ckp: jax.Array, M: jax.Array) -> jax.Array:
        out = jnp.einsum('kp,kj->jp', Ckp, M,               # (j, p)
                         preferred_element_type=jnp.float32)
        return out.astype(self.sketch_dtype)

    def slice_k(self, Ckp: jax.Array, start: int, width: int) -> jax.Array:
        return jax.lax.slice_in_dim(Ckp, start, start + width, axis=0)

    def scale(self, x: jax.Array, s) -> jax.Array:
        return x * s

    def sub(self, a: jax.Array, b: jax.Array) -> jax.Array:
        return a - b

    def add(self, a: jax.Array, b: jax.Array) -> jax.Array:
        return a + b

    def combine(self, Ckp: jax.Array, w: jax.Array, vf: jax.Array,
                rho: float) -> jax.Array:
        return vf / rho + self.cv(Ckp, w)

    # -- matrix-valued queries: (p, m) fused blocks -------------------------
    def vecm(self, V: PyTree) -> jax.Array:
        return flatten_vecm(V)

    def unvecm(self, U: jax.Array, like: PyTree) -> PyTree:
        return unflatten_vecm(U, like)

    def ctm(self, Ckp: jax.Array, Vm: jax.Array) -> jax.Array:
        return jnp.einsum('kp,pm->km', Ckp, Vm,
                          preferred_element_type=jnp.float32)

    def cm(self, Ckp: jax.Array, W: jax.Array) -> jax.Array:
        return jnp.einsum('kp,km->pm', Ckp, W,
                          preferred_element_type=jnp.float32)

    def combinem(self, Ckp: jax.Array, W: jax.Array, Vm: jax.Array,
                 rho: float) -> jax.Array:
        return Vm / rho + self.cm(Ckp, W)


@dataclasses.dataclass(frozen=True)
class PallasBackend(FlatBackend):
    """Fused buffer + Pallas TPU kernels for the C-streaming passes.

    The operand is the kernel-tiled (p, k) layout (k padded to the 128-lane
    width inside the kernels) — the transpose of FlatBackend's buffer, taken
    once at prepare(). ``interpret=None`` lets the kernel wrappers pick
    (compiled on TPU, interpret elsewhere); ``block_p`` is the p-tile the
    grid streams. ``cv``/``mul_right``/``cross`` stay on XLA: they are
    p-output or k×k-output matmuls XLA already tiles well; gram/ctv/combine
    are the C-streaming reduction passes the kernels were built for.
    ``sketch_dtype=bf16`` composes: the kernels upcast each streamed slab to
    f32 in VMEM, so HBM traffic and storage halve while accumulation stays
    f32.
    """
    name = 'pallas'
    interpret: bool | None = None
    block_p: int = 1024

    def prepare_operand(self, C: PyTree) -> jax.Array:
        return flatten_sketch(C, dtype=self.sketch_dtype).T   # (p, k)

    def ctv(self, Cpk: jax.Array, vf: jax.Array) -> jax.Array:
        from repro.kernels import ops
        return ops.woodbury_ctv(Cpk, vf, block_p=self.block_p,
                                interpret=self.interpret)

    def cv(self, Cpk: jax.Array, w: jax.Array) -> jax.Array:
        return jnp.einsum('pk,k->p', Cpk, w,
                          preferred_element_type=jnp.float32)

    def gram(self, Cpk: jax.Array) -> jax.Array:
        from repro.kernels import ops
        return ops.nystrom_gram(Cpk, block_p=self.block_p,
                                interpret=self.interpret)

    def cross(self, Apk: jax.Array, Bpk: jax.Array) -> jax.Array:
        return jnp.einsum('pk,pj->kj', Apk, Bpk,
                          preferred_element_type=jnp.float32)

    def mul_right(self, Cpk: jax.Array, M: jax.Array) -> jax.Array:
        out = jnp.einsum('pk,kj->pj', Cpk, M,                 # (p, j)
                         preferred_element_type=jnp.float32)
        return out.astype(self.sketch_dtype)

    def slice_k(self, Cpk: jax.Array, start: int, width: int) -> jax.Array:
        return jax.lax.slice_in_dim(Cpk, start, start + width, axis=1)

    def combine(self, Cpk: jax.Array, w: jax.Array, vf: jax.Array,
                rho: float) -> jax.Array:
        from repro.kernels import ops
        # woodbury_apply computes v/ρ − C w̃/ρ²; w̃ = −ρ² w gives v/ρ + C w.
        return ops.woodbury_apply(Cpk, -(rho * rho) * w, vf, rho,
                                  block_p=self.block_p,
                                  interpret=self.interpret)

    # -- matrix-valued queries: the kernels take (p, m) blocks natively -----
    def ctm(self, Cpk: jax.Array, Vm: jax.Array) -> jax.Array:
        from repro.kernels import ops
        return ops.woodbury_ctv(Cpk, Vm, block_p=self.block_p,
                                interpret=self.interpret)

    def cm(self, Cpk: jax.Array, W: jax.Array) -> jax.Array:
        return jnp.einsum('pk,km->pm', Cpk, W,
                          preferred_element_type=jnp.float32)

    def combinem(self, Cpk: jax.Array, W: jax.Array, Vm: jax.Array,
                 rho: float) -> jax.Array:
        from repro.kernels import ops
        return ops.woodbury_apply(Cpk, -(rho * rho) * W, Vm, rho,
                                  block_p=self.block_p,
                                  interpret=self.interpret)


# ---------------------------------------------------------------------------
# flat_sharded: per-device fused buffers + psum reductions
# ---------------------------------------------------------------------------
@jax.tree_util.register_dataclass
@dataclasses.dataclass
class ShardedOperand:
    """FlatShardedBackend's operand: per-device fused buffer + psum weights.

    ``buf`` is (n_dev, k, p_local), sharded so device d holds exactly the
    (1, k, p_local) row it fused from its own parameter shards — the global
    leading axis is the mesh itself (P(mesh.axis_names, None, None)).
    ``w`` is the (p_local,) reduction-weight vector: column j carries
    1/replication(leaf(j)), so a psum over every mesh axis counts each
    *distinct* parameter exactly once even when some leaves are replicated
    along some axes. The weights ride with the operand (not the backend) so
    a prepared sketch is self-describing across applies.
    """
    buf: jax.Array
    w: jax.Array


@dataclasses.dataclass(frozen=True, eq=False)
class FlatShardedBackend:
    """``flat``'s one-matmul-per-contraction fusion under GSPMD sharding.

    ``prepare_operand`` runs inside ``shard_map``: each device flattens and
    concatenates only its local blocks of each sketch leaf into a
    (k, p_local) buffer — a pjit-sharded leaf is never all-gathered (the
    failure mode that forced sharded steps onto the ``tree`` backend).
    Contractions then run on the local buffer; the k-output reductions
    (``ctv``, and ``gram``/``cross`` at k×k) finish with one
    ``jax.lax.psum`` over every mesh axis, down-weighting columns of
    replicated leaves by 1/replication so nothing is overcounted. p-output
    passes (``cv``/``mul_right``/``combine``) are purely local and their
    results stay sharded exactly like the parameters.

    ``specs`` is a PartitionSpec pytree matching the parameter structure
    (e.g. ``repro.distributed.sharding.param_specs(cfg, mesh)``); entries
    that cannot shard a leaf on ``mesh`` degrade to replication via
    ``sanitize_spec`` — never error — so any (arch × mesh) combination is
    accepted, including the non-divisible-leaf fallback. ``specs=None``
    replicates everything (correct, no memory win). ``sketch_dtype=bf16``
    stores the per-device buffers half-size; reductions accumulate f32.
    """
    name = 'flat_sharded'
    mesh: Any = None
    specs: Any = None
    sketch_dtype: Any = jnp.float32

    def __post_init__(self):
        if self.mesh is None:
            raise ValueError(
                "flat_sharded requires a mesh: get_backend('flat_sharded', "
                "mesh=mesh, specs=param_spec_tree)")

    # -- static shard planning (host-side; specs × mesh × leaf shapes) ------
    def _axes(self) -> tuple:
        return tuple(self.mesh.axis_names)

    def _plan(self, tree, lead: int, trail: int = 0):
        """Per-leaf (sanitized spec, local shape/size, psum weight), in
        ``jax.tree.leaves`` order; ``lead`` leading unsharded dims (the
        sketch's k axis) and ``trail`` trailing unsharded dims (a query
        block's m axis) are stripped before planning."""
        from jax.sharding import PartitionSpec as P

        from repro.distributed.sharding import (local_shape,
                                                replication_factor,
                                                sanitize_spec)
        leaves = jax.tree.leaves(tree)
        if self.specs is None:
            spec_leaves = [P()] * len(leaves)
        else:
            spec_leaves = jax.tree.structure(tree).flatten_up_to(self.specs)
        plan = []
        for leaf, sp in zip(leaves, spec_leaves):
            gshape = tuple(leaf.shape)[lead:len(leaf.shape) - trail]
            sp = sanitize_spec(gshape, sp, self.mesh)
            lshape = local_shape(gshape, sp, self.mesh)
            lsize = int(np.prod(lshape, dtype=np.int64)) if lshape else 1
            weight = 1.0 / replication_factor(sp, self.mesh)
            plan.append((sp, lshape, lsize, weight))
        return plan

    def _weight_vec(self, plan) -> jax.Array:
        segs = [jnp.full((lsize,), weight, jnp.float32)
                for _, _, lsize, weight in plan if lsize]
        return jnp.concatenate(segs)

    def _smap(self, f, in_specs, out_specs):
        from repro.distributed.ctx import shard_map_unchecked
        return shard_map_unchecked(f, self.mesh, in_specs, out_specs)

    def _op_spec(self, ndim: int):
        from jax.sharding import PartitionSpec as P
        return P(self._axes(), *([None] * (ndim - 1)))

    # -- pytree <-> per-device fused form -----------------------------------
    def prepare_operand(self, C: PyTree) -> ShardedOperand:
        from jax.sharding import PartitionSpec as P
        plan = self._plan(C, lead=1)
        leaves = jax.tree.leaves(C)

        def fuse(*ls):
            cols = [l.astype(self.sketch_dtype).reshape(l.shape[0], -1)
                    for l in ls]
            return jnp.concatenate(cols, axis=1)[None]      # (1, k, p_local)

        buf = self._smap(fuse,
                         tuple(P(None, *sp) for sp, _, _, _ in plan),
                         self._op_spec(3))(*leaves)
        return ShardedOperand(buf=buf, w=self._weight_vec(plan))

    def vec(self, v: PyTree) -> jax.Array:
        from jax.sharding import PartitionSpec as P
        plan = self._plan(v, lead=0)
        leaves = jax.tree.leaves(v)

        def fuse(*ls):
            return jnp.concatenate(
                [l.astype(jnp.float32).reshape(-1) for l in ls])[None]

        return self._smap(fuse, tuple(P(*sp) for sp, _, _, _ in plan),
                          self._op_spec(2))(*leaves)

    def unvec(self, u: jax.Array, like: PyTree) -> PyTree:
        from jax.sharding import PartitionSpec as P
        plan = self._plan(like, lead=0)
        leaves, treedef = jax.tree.flatten(like)
        dtypes = [l.dtype for l in leaves]

        def split(ub):
            u1, outs, off = ub[0], [], 0
            for (_, lshape, lsize, _), dt in zip(plan, dtypes):
                outs.append(u1[off:off + lsize].reshape(lshape).astype(dt))
                off += lsize
            return tuple(outs)

        outs = self._smap(split, (self._op_spec(2),),
                          tuple(P(*sp) for sp, _, _, _ in plan))(u)
        return treedef.unflatten(list(outs))

    # -- reductions: local fused contraction + k-float (k×k) psum -----------
    def ctv(self, C: ShardedOperand, vf: jax.Array) -> jax.Array:
        from jax.sharding import PartitionSpec as P
        axes = self._axes()

        def local(s, w, v):
            t = jnp.einsum('kp,p->k', s[0], v[0] * w,
                           preferred_element_type=jnp.float32)
            return jax.lax.psum(t, axes)

        return self._smap(local, (self._op_spec(3), P(None),
                                  self._op_spec(2)), P())(C.buf, C.w, vf)

    def gram(self, C: ShardedOperand) -> jax.Array:
        return self.cross(C, C)

    def cross(self, A: ShardedOperand, B: ShardedOperand) -> jax.Array:
        from jax.sharding import PartitionSpec as P
        axes = self._axes()

        def local(a, w, b):
            g = jnp.einsum('kp,jp->kj', a[0] * w, b[0],
                           preferred_element_type=jnp.float32)
            return jax.lax.psum(g, axes)

        return self._smap(local, (self._op_spec(3), P(None),
                                  self._op_spec(3)), P())(A.buf, A.w, B.buf)

    # -- p-output passes: purely local, results stay parameter-sharded ------
    def cv(self, C: ShardedOperand, w: jax.Array) -> jax.Array:
        from jax.sharding import PartitionSpec as P

        def local(s, wk):
            return jnp.einsum('kp,k->p', s[0], wk,
                              preferred_element_type=jnp.float32)[None]

        return self._smap(local, (self._op_spec(3), P(None)),
                          self._op_spec(2))(C.buf, w)

    def mul_right(self, C: ShardedOperand, M: jax.Array) -> ShardedOperand:
        from jax.sharding import PartitionSpec as P

        def local(s, m):
            out = jnp.einsum('kp,kj->jp', s[0], m,
                             preferred_element_type=jnp.float32)
            return out[None].astype(self.sketch_dtype)

        buf = self._smap(local, (self._op_spec(3), P(None, None)),
                         self._op_spec(3))(C.buf, M)
        return ShardedOperand(buf=buf, w=C.w)

    def combine(self, C: ShardedOperand, w: jax.Array, vf: jax.Array,
                rho: float) -> jax.Array:
        from jax.sharding import PartitionSpec as P

        def local(s, wk, v):
            u = v[0] / rho + jnp.einsum('kp,k->p', s[0], wk,
                                        preferred_element_type=jnp.float32)
            return u[None]

        return self._smap(local, (self._op_spec(3), P(None),
                                  self._op_spec(2)),
                          self._op_spec(2))(C.buf, w, vf)

    # -- matrix-valued queries: local (p_local, m) blocks, ONE (k, m) psum --
    def vecm(self, V: PyTree) -> jax.Array:
        """Query-block pytree (leaves = param shape + (m,)) → per-device
        (1, p_local, m) fused block; the trailing m axis is never sharded."""
        from jax.sharding import PartitionSpec as P
        plan = self._plan(V, lead=0, trail=1)
        leaves = jax.tree.leaves(V)

        def fuse(*ls):
            return jnp.concatenate(
                [l.astype(jnp.float32).reshape(-1, l.shape[-1])
                 for l in ls], axis=0)[None]

        return self._smap(fuse, tuple(P(*sp, None) for sp, _, _, _ in plan),
                          self._op_spec(3))(*leaves)

    def unvecm(self, U: jax.Array, like: PyTree) -> PyTree:
        from jax.sharding import PartitionSpec as P
        plan = self._plan(like, lead=0, trail=1)
        leaves, treedef = jax.tree.flatten(like)
        dtypes = [l.dtype for l in leaves]

        def split(ub):
            u1, outs, off = ub[0], [], 0
            for (_, lshape, lsize, _), dt in zip(plan, dtypes):
                outs.append(u1[off:off + lsize]
                            .reshape(lshape + (u1.shape[-1],)).astype(dt))
                off += lsize
            return tuple(outs)

        outs = self._smap(split, (self._op_spec(3),),
                          tuple(P(*sp, None) for sp, _, _, _ in plan))(U)
        return treedef.unflatten(list(outs))

    def ctm(self, C: ShardedOperand, Vm: jax.Array) -> jax.Array:
        """CᵀV over an m-query block → (k, m): the local contraction covers
        the whole block, so exactly ONE psum of (k, m) floats crosses the
        mesh per apply pass — not m separate k-float psums."""
        from jax.sharding import PartitionSpec as P
        axes = self._axes()

        def local(s, w, v):
            t = jnp.einsum('kp,pm->km', s[0], v[0] * w[:, None],
                           preferred_element_type=jnp.float32)
            return jax.lax.psum(t, axes)

        return self._smap(local, (self._op_spec(3), P(None),
                                  self._op_spec(3)), P())(C.buf, C.w, Vm)

    def cm(self, C: ShardedOperand, W: jax.Array) -> jax.Array:
        from jax.sharding import PartitionSpec as P

        def local(s, wm):
            return jnp.einsum('kp,km->pm', s[0], wm,
                              preferred_element_type=jnp.float32)[None]

        return self._smap(local, (self._op_spec(3), P(None, None)),
                          self._op_spec(3))(C.buf, W)

    def combinem(self, C: ShardedOperand, W: jax.Array, Vm: jax.Array,
                 rho: float) -> jax.Array:
        from jax.sharding import PartitionSpec as P

        def local(s, wm, v):
            u = v[0] / rho + jnp.einsum('kp,km->pm', s[0], wm,
                                        preferred_element_type=jnp.float32)
            return u[None]

        return self._smap(local, (self._op_spec(3), P(None, None),
                                  self._op_spec(3)),
                          self._op_spec(3))(C.buf, W, Vm)

    # -- structural helpers (operand- and vector-form aware) ----------------
    def slice_k(self, C: ShardedOperand, start: int,
                width: int) -> ShardedOperand:
        return ShardedOperand(
            buf=jax.lax.slice_in_dim(C.buf, start, start + width, axis=1),
            w=C.w)

    def scale(self, x, s):
        if isinstance(x, ShardedOperand):
            return ShardedOperand(buf=x.buf * s, w=x.w)
        return x * s

    def sub(self, a, b):
        if isinstance(a, ShardedOperand):
            return ShardedOperand(buf=a.buf - b.buf, w=a.w)
        return a - b

    def add(self, a, b):
        if isinstance(a, ShardedOperand):
            return ShardedOperand(buf=a.buf + b.buf, w=a.w)
        return a + b


BACKENDS = {'tree': TreeBackend, 'flat': FlatBackend,
            'flat_sharded': FlatShardedBackend, 'pallas': PallasBackend}


def get_backend(name: str, **kwargs):
    """'tree' | 'flat' | 'flat_sharded' | 'pallas' → backend instance.
    kwargs reach the backend constructor (``mesh=``/``specs=`` for
    flat_sharded, ``sketch_dtype=`` for the flat family, ``interpret=True``
    for pallas in tests).

    >>> get_backend('flat').name
    'flat'
    >>> get_backend('flat_sharded')
    Traceback (most recent call last):
        ...
    ValueError: flat_sharded requires a mesh: get_backend('flat_sharded', \
mesh=mesh, specs=param_spec_tree)
    """
    try:
        cls = BACKENDS[name]
    except KeyError:
        raise ValueError(
            f'unknown backend {name!r}; expected one of {sorted(BACKENDS)}')
    return cls(**kwargs)
