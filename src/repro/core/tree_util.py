"""Pytree linear-algebra helpers.

Everything in ``repro.core`` treats model parameters as arbitrary pytrees; the
hypergradient math only ever needs the vector-space operations below, so that
a parameter tree sharded over a (pod, data, model) mesh behaves exactly like a
flat vector without ever being flattened on-device.
"""
from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

PyTree = Any


def tree_vdot(a: PyTree, b: PyTree) -> jax.Array:
    """<a, b> over all leaves (float32 accumulation).

    Uses elementwise-multiply + full reduce, NOT jnp.vdot: vdot flattens to
    1-D first, and flattening a multi-axis-sharded array forces GSPMD to
    all-gather the whole operand per device (measured: ~35 GB/chip on the
    yi-9b dry-run before this was fixed)."""
    leaves = jax.tree.leaves(jax.tree.map(
        lambda x, y: jnp.sum(x.astype(jnp.float32) * y.astype(jnp.float32)),
        a, b))
    return jnp.sum(jnp.stack(leaves)) if leaves else jnp.float32(0.0)


def tree_add(a: PyTree, b: PyTree) -> PyTree:
    return jax.tree.map(jnp.add, a, b)


def tree_sub(a: PyTree, b: PyTree) -> PyTree:
    return jax.tree.map(jnp.subtract, a, b)


def tree_scale(a: PyTree, s) -> PyTree:
    return jax.tree.map(lambda x: x * s, a)


def tree_axpy(alpha, x: PyTree, y: PyTree) -> PyTree:
    """alpha * x + y."""
    return jax.tree.map(lambda xi, yi: alpha * xi + yi, x, y)


def tree_zeros_like(a: PyTree) -> PyTree:
    return jax.tree.map(jnp.zeros_like, a)


def tree_norm(a: PyTree) -> jax.Array:
    return jnp.sqrt(tree_vdot(a, a))


def tree_size(a: PyTree) -> int:
    """Total number of scalar parameters (static)."""
    return sum(int(x.size) for x in jax.tree.leaves(a))


def tree_cast(a: PyTree, dtype) -> PyTree:
    return jax.tree.map(lambda x: x.astype(dtype), a)


def tree_random_like(rng: jax.Array, a: PyTree, scale: float = 1.0) -> PyTree:
    """Gaussian pytree with the same structure/shapes as ``a``."""
    leaves, treedef = jax.tree.flatten(a)
    keys = jax.random.split(rng, len(leaves))
    out = [scale * jax.random.normal(k, l.shape, l.dtype) for k, l in zip(keys, leaves)]
    return treedef.unflatten(out)


# ---------------------------------------------------------------------------
# Global flat indexing across a pytree (used for Nyström column selection).
# ---------------------------------------------------------------------------
class PyTreeIndexer:
    """Maps parameter coordinates to one-hot tangent pytrees.

    Indices are *structured* — ``{'leaf': (k,) int32, 'dims': (k, R) int32}``
    with R = max leaf rank — never a global flat offset, so the scheme is
    int32-safe at any parameter count (a flat index overflows int32 beyond
    2.1B params; yi-9b already has 8.8B). The mapping is static shape
    information, so one-hots trace into jit with *dynamic* index values: a
    new random index set per outer step does not retrace.
    """

    def __init__(self, tree: PyTree):
        leaves, self.treedef = jax.tree.flatten(tree)
        self.shapes = [tuple(l.shape) for l in leaves]
        self.dtypes = [l.dtype for l in leaves]
        self.sizes = [int(np.prod(s, dtype=np.int64)) for s in self.shapes]
        self.total = sum(self.sizes)
        self.max_rank = max((len(s) for s in self.shapes), default=1) or 1
        # (n_leaves, R) dim-size + row-major stride tables, padded with 1s
        L = len(self.shapes)
        self._dim_table = np.ones((L, self.max_rank), np.int32)
        self._stride_table = np.ones((L, self.max_rank), np.int32)
        for i, s in enumerate(self.shapes):
            for d, n in enumerate(s):
                assert n < 2 ** 31, (
                    f'leaf dim {n} exceeds int32; reshape the leaf — the '
                    'structured indexer is per-dimension int32')
                self._dim_table[i, d] = n
            stride = 1
            for d in range(len(s) - 1, -1, -1):
                self._stride_table[i, d] = stride
                stride *= s[d]

    # -- representation helpers -------------------------------------------
    def from_flat(self, flat: np.ndarray | list[int]) -> dict:
        """Concrete global flat indices → structured (host-side; tests/Exact)."""
        leaf_ids, dims = [], []
        offs = np.cumsum([0] + self.sizes)
        for f in np.asarray(flat, np.int64):
            lid = int(np.searchsorted(offs, f, 'right') - 1)
            local = int(f - offs[lid])
            coord = np.unravel_index(local, self.shapes[lid] or (1,))
            coord = list(coord) + [0] * (self.max_rank - len(coord))
            leaf_ids.append(lid)
            dims.append(coord)
        return {'leaf': jnp.asarray(leaf_ids, jnp.int32),
                'dims': jnp.asarray(dims, jnp.int32)}

    def one_hot(self, idx: dict) -> PyTree:
        """One-hot pytree for a single structured index
        ({'leaf': () int32, 'dims': (R,) int32}); traced values ok."""
        outs = []
        for lid, (shape, dtype) in enumerate(zip(self.shapes, self.dtypes)):
            mask = (idx['leaf'] == lid)
            oh = jnp.ones(shape or (), dtype)
            for d, n in enumerate(shape):
                eq = (jnp.arange(n, dtype=jnp.int32) == idx['dims'][d])
                oh = oh * eq.astype(dtype).reshape(
                    (1,) * d + (n,) + (1,) * (len(shape) - d - 1))
            outs.append(oh * mask.astype(dtype))
        return self.treedef.unflatten(outs)

    def one_hots(self, indices: dict) -> PyTree:
        """Batched one-hots: leaves carry a leading k axis."""
        return jax.vmap(self.one_hot)(indices)

    def gather(self, batched_tree: PyTree, indices: dict) -> jax.Array:
        """Entries of each batched-tree column at the structured indices:
        (k_batch, k_idx) — computed as a cross-contraction against the
        one-hot batch (fuses; no flat reshape of sharded leaves)."""
        oh = self.one_hots(indices)
        parts = jax.tree.leaves(jax.tree.map(
            lambda c, o: jnp.einsum('k...,j...->kj', c.astype(jnp.float32),
                                    o.astype(jnp.float32)), batched_tree, oh))
        return sum(parts)

    def _structure_flat_traced(self, flat: jax.Array) -> dict:
        """Traced flat→structured conversion (valid while p < 2³¹)."""
        offs = jnp.asarray(np.cumsum([0] + self.sizes[:-1]), jnp.int32)
        leaf = jnp.searchsorted(offs, flat, side='right') - 1
        local = flat - offs[leaf]
        strides = jnp.asarray(self._stride_table)[leaf]      # (k, R)
        sizes_k = jnp.asarray(self._dim_table)[leaf]
        dims = (local[:, None] // strides) % sizes_k
        return {'leaf': leaf.astype(jnp.int32), 'dims': dims.astype(jnp.int32)}

    def sample_indices(self, rng: jax.Array, k: int,
                       weights: jax.Array | None = None) -> dict:
        """k structured indices, uniform over all parameters.

        p < 2³¹: distinct flat indices (replace=False), converted with
        traced int32 math. Beyond int32 range: leaf ∝ size + per-dim uniform
        coordinates — with-replacement across the whole space (collision
        probability ≤ k²/2p, negligible at p ≥ 10⁹ and harmless: a duplicate
        column only lowers the sketch rank by one).

        ``weights`` (Drineas–Mahoney diag² sampling, Remark 1) requires a
        flat weight vector and is only supported when p < 2³¹."""
        if self.total < 2 ** 31:
            p = None if weights is None else weights / weights.sum()
            kk = min(k, self.total)
            flat = jax.random.choice(rng, self.total, (kk,), replace=False,
                                     p=p).astype(jnp.int32)
            return self._structure_flat_traced(flat)
        if weights is not None:
            raise ValueError('importance sampling needs p < 2^31')
        k_leaf, k_dims = jax.random.split(rng)
        # sizes exceed int32 here — go through float64 numpy, never jnp ints
        probs = jnp.asarray(np.asarray(self.sizes, np.float64)
                            / float(self.total), jnp.float32)
        leaf = jax.random.choice(k_leaf, len(self.sizes), (k,), p=probs)
        table = jnp.asarray(self._dim_table)                 # (L, R)
        sizes_k = table[leaf]                                # (k, R)
        u = jax.random.uniform(k_dims, (k, self.max_rank))
        dims = jnp.minimum((u * sizes_k).astype(jnp.int32), sizes_k - 1)
        return {'leaf': leaf.astype(jnp.int32), 'dims': dims}

    def all_indices(self) -> dict:
        """Every parameter (tiny models only — ExactIHVP)."""
        assert self.total < 2 ** 31
        return self.from_flat(np.arange(self.total))
