"""Hessian-vector products and Nyström column extraction.

An HVP against a one-hot tangent e_j yields the j-th *column* of the Hessian;
k of them form the Nyström sketch C = H[:, K] (Eq. 4 of the paper). Columns
are parameter-pytrees, so C is a pytree whose leaves carry a leading k axis —
it shards exactly like a stack of gradients (the key to pod-scale operation).
"""
from __future__ import annotations

import functools
from typing import Any, Callable

import jax
import jax.numpy as jnp

from repro.core.tree_util import PyTree, PyTreeIndexer

LossFn = Callable[..., jax.Array]  # loss(params, *args) -> scalar


def make_hvp(loss_fn: LossFn, params: PyTree, *args) -> Callable[[PyTree], PyTree]:
    """v ↦ (∇²_θ loss) v via forward-over-reverse (jvp of grad).

    Forward-over-reverse costs one extra forward pass over plain grad and has
    the same memory profile as backprop — the right choice on TPU where the
    tangent rides along the forward pass in-register.
    """
    grad_fn = jax.grad(loss_fn)

    def hvp(v: PyTree) -> PyTree:
        return jax.jvp(lambda p: grad_fn(p, *args), (params,), (v,))[1]

    return hvp


def make_hvp_fn(loss_fn: LossFn) -> Callable[..., Callable[[PyTree], PyTree]]:
    """Partial-friendly variant: make_hvp_fn(f)(params, *args) -> hvp."""
    return functools.partial(make_hvp, loss_fn)


def extract_columns(hvp: Callable[[PyTree], PyTree],
                    indexer: PyTreeIndexer,
                    indices,
                    column_chunk: int | None = None) -> PyTree:
    """C = H[:, K] as a pytree with leading axis k = #indices (structured
    index dict — see PyTreeIndexer).

    ``column_chunk`` bounds how many HVPs are vmapped simultaneously — the
    extraction-phase analogue of the paper's κ dial: peak activation memory is
    O(chunk · activations) instead of O(k · activations).
    """
    def col(j) -> PyTree:
        return hvp(indexer.one_hot(j))

    k = indices['leaf'].shape[0]
    chunk = k if column_chunk is None else min(column_chunk, k)
    if chunk >= k:
        return jax.vmap(col)(indices)
    # lax.map with batch_size = chunked vmap; remainder handled by lax.map.
    return jax.lax.map(col, indices, batch_size=chunk)


def gauss_newton_hvp(loss_fn: LossFn, params: PyTree, *args,
                     damping: float = 0.0) -> Callable[[PyTree], PyTree]:
    """Gauss-Newton (PSD) surrogate HVP: J^T (H_out) J v.

    Provided because Theorem 1 assumes PSD curvature; for non-converged inner
    problems the GGN is the standard PSD stand-in. Implemented as
    vjp(jvp(loss)) through the scalar loss — for a scalar loss this equals
    g g^T v + damping * v with g = ∇loss, which is the rank-1 outer-product
    curvature; callers with structured losses should pass a model-split loss.
    """
    grad_fn = jax.grad(loss_fn)

    def hvp(v: PyTree) -> PyTree:
        g = grad_fn(params, *args)
        from repro.core.tree_util import tree_vdot, tree_axpy, tree_scale
        coef = tree_vdot(g, v)
        return tree_axpy(damping, v, tree_scale(g, coef))

    return hvp


def hessian_diagonal_estimate(hvp: Callable[[PyTree], PyTree],
                              indexer: PyTreeIndexer,
                              rng: jax.Array,
                              n_probes: int = 8) -> jax.Array:
    """Hutchinson-style |diag(H)| estimate (length p, flattened order).

    Used for the Drineas–Mahoney importance-weighted column sampling variant
    (Remark 1): picking column i ∝ H_ii² tightens the Nyström error bound.
    """
    def probe(key):
        z_leaves = []
        leaves, treedef = jax.tree.flatten(indexer.treedef.unflatten(
            [jnp.zeros(s, d) for s, d in zip(indexer.shapes, indexer.dtypes)]))
        keys = jax.random.split(key, len(leaves))
        for kk, l in zip(keys, leaves):
            z_leaves.append(jax.random.rademacher(kk, l.shape, jnp.float32).astype(l.dtype))
        z = treedef.unflatten(z_leaves)
        hz = hvp(z)
        prod = jax.tree.map(lambda a, b: (a.astype(jnp.float32) * b.astype(jnp.float32)).ravel(), z, hz)
        return jnp.concatenate(jax.tree.leaves(prod))

    keys = jax.random.split(rng, n_probes)
    est = jax.lax.map(probe, keys).mean(axis=0)
    return jnp.abs(est)
