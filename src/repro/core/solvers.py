"""IHVP solvers: the paper's Nyström method plus the baselines it compares to.

Every solver approximates  u ≈ (H + ρI)⁻¹ v  where H = ∇²_θ f is accessed only
through Hessian-vector products (HVPs).

* ``NystromIHVP`` — the paper's contribution (Eq. 4/6, Alg. 1). Non-iterative:
  k parallel HVPs build the sketch once, then every apply is two tall-skinny
  contractions and one k×k solve. The κ dial selects the time/space tradeoff
  (κ=k: Eq. 6 "time-efficient"; κ=1: Eq. 9 "space-efficient"; in between:
  Alg. 1 hybrid) with bit-identical results.
* ``CGIHVP`` — conjugate gradient (Pedregosa 2016; Rajeswaran et al. 2019).
* ``NeumannIHVP`` — Neumann series (Lorraine et al. 2020).
* ``ExactIHVP`` — dense solve, for tiny problems / oracles in tests.

Sharding: solvers are pure jax; under pjit, C (leading-k parameter pytree)
inherits the parameter sharding, CᵀC / Cᵀv lower to per-shard contractions +
one psum of k² / k floats, and the k×k solve is replicated. No solver holds
any p×p object.
"""
from __future__ import annotations

import dataclasses
from typing import Callable

import jax
import jax.numpy as jnp

from repro.core.hvp import extract_columns
from repro.core.tree_util import (PyTree, PyTreeIndexer, tree_axpy, tree_scale,
                                  tree_sub, tree_vdot, tree_zeros_like)

HVP = Callable[[PyTree], PyTree]

# Eigenvalues below this (relative) threshold are deactivated by sending them
# to SAFE_BIG, which makes their rank-1/rank-κ Woodbury contribution vanish —
# the static-shape analogue of a truncated pseudo-inverse (paper §5: zero
# Hessian columns under ReLU break the plain inverse).
_EIG_REL_TOL = 1e-7
_SAFE_BIG = 1e30


# ---------------------------------------------------------------------------
# tall-skinny pytree contractions (the only dense math the solver needs)
# ---------------------------------------------------------------------------
def _ctv(C: PyTree, v: PyTree) -> jax.Array:
    """t = Cᵀ v ∈ R^k.  C leaves: (k, *shape); v leaves: (*shape)."""
    parts = jax.tree.leaves(jax.tree.map(
        lambda c, x: jnp.einsum('k...,...->k', c.astype(jnp.float32),
                                x.astype(jnp.float32)), C, v))
    return sum(parts)


def _cv(C: PyTree, w: jax.Array) -> PyTree:
    """u = C w: contract the leading k axis with w ∈ R^k."""
    return jax.tree.map(
        lambda c: jnp.einsum('k...,k->...', c.astype(jnp.float32), w), C)


def _gram(C: PyTree) -> jax.Array:
    """CᵀC ∈ R^{k×k}."""
    parts = jax.tree.leaves(jax.tree.map(
        lambda c: jnp.einsum('k...,j...->kj', c.astype(jnp.float32),
                             c.astype(jnp.float32)), C))
    return sum(parts)


def _cross(A: PyTree, B: PyTree) -> jax.Array:
    """Aᵀ B for two leading-axis pytrees → (ka, kb)."""
    parts = jax.tree.leaves(jax.tree.map(
        lambda a, b: jnp.einsum('k...,j...->kj', a.astype(jnp.float32),
                                b.astype(jnp.float32)), A, B))
    return sum(parts)


def _sym_solve(M: jax.Array, t: jax.Array) -> jax.Array:
    """Solve M w = t for symmetric (possibly indefinite) k×k M.

    Jacobi (diagonal) preconditioning: M = H_KK + CᵀC/ρ mixes scales of H and
    H²/ρ, which costs ~3 digits in f32; symmetric diagonal scaling restores
    them (measured in tests/test_solvers.py). Jitter handles the zero-column
    degeneracy the paper works around with leaky-ReLU.
    """
    M = 0.5 * (M + M.T)
    d = jnp.sqrt(jnp.clip(jnp.abs(jnp.diagonal(M)), 1e-30, None))
    Ms = M / d[:, None] / d[None, :]
    jitter = 1e-7
    k = M.shape[0]
    w = jnp.linalg.solve(Ms + jitter * jnp.eye(k, dtype=M.dtype), t / d)
    return w / d


# ---------------------------------------------------------------------------
# Nyström (the paper)
# ---------------------------------------------------------------------------
@jax.tree_util.register_dataclass
@dataclasses.dataclass
class NystromSketch:
    """Prepared sketch: reusable across many IHVP applies (and outer steps).

    ``W``/``sig2`` is the numerically-stable spectral form of H_k
    (H_k = W diag(σ²) Wᵀ, W orthonormal p×k): present when the solver was
    built with ``stabilized=True``.
    """
    C: PyTree           # H[:, K], leaves (k, *param_shape)
    H_KK: jax.Array     # (k, k), symmetrized
    indices: dict       # structured {'leaf', 'dims'} (PyTreeIndexer)
    rho: jax.Array      # scalar
    W: PyTree | None = None
    sig2: jax.Array | None = None


@dataclasses.dataclass(frozen=True)
class NystromIHVP:
    """The paper's method. κ=None ⇒ Eq. 6 (time-efficient).

    ``stabilized=True`` (default) applies the inverse through the spectral
    form of H_k (Frangella–Tropp–Udell-style): Eq. 6's k×k system
    H_KK + CᵀC/ρ carries cond(H)² and costs ~3 digits in f32; the spectral
    form is backward-stable and makes each apply *cheaper* (no solve at apply
    time). ``stabilized=False`` is the literal Eq. 6 for paper-faithful
    benchmarking; both agree to solver tolerance on well-conditioned H
    (tests/test_solvers.py).
    """
    k: int
    rho: float = 1e-2
    kappa: int | None = None
    column_chunk: int | None = None
    importance_sampling: bool = False  # Remark 1 (Drineas–Mahoney weights)
    stabilized: bool = True

    # -- sketch construction (k HVPs; the only part that touches the model) --
    def prepare(self, hvp: HVP, indexer: PyTreeIndexer, rng: jax.Array,
                diag_weights: jax.Array | None = None) -> NystromSketch:
        weights = diag_weights if self.importance_sampling else None
        idx = indexer.sample_indices(rng, self.k, weights)
        C = extract_columns(hvp, indexer, idx, self.column_chunk)
        H_KK = indexer.gather(C, idx)
        H_KK = 0.5 * (H_KK + H_KK.T)
        W, sig2 = (None, None)
        if self.stabilized:
            W, sig2 = _spectral_form(C, H_KK)
        return NystromSketch(C=C, H_KK=H_KK, indices=idx,
                             rho=jnp.float32(self.rho), W=W, sig2=sig2)

    # -- apply (no HVPs; two tall-skinny contractions + tiny replicated math)
    def apply(self, sketch: NystromSketch, v: PyTree) -> PyTree:
        if self.kappa is not None and self.kappa < self.k:
            return _apply_woodbury_chunked(sketch, v, self.kappa)
        if self.stabilized and sketch.W is not None:
            return _apply_spectral(sketch, v)
        return _apply_woodbury_direct(sketch, v)

    def solve(self, hvp: HVP, indexer: PyTreeIndexer, v: PyTree,
              rng: jax.Array) -> PyTree:
        return self.apply(self.prepare(hvp, indexer, rng), v)


def _spectral_form(C: PyTree, H_KK: jax.Array):
    """H_k = C H_KK† Cᵀ = W diag(σ²) Wᵀ with orthonormal W, via two k×k eighs.

    B = C · U diag(λ†^(1/2)) gives H_k = BBᵀ; the SVD of the distributed B is
    recovered from its k×k Gram (BᵀB = Q diag(σ²) Qᵀ), so every p-sized op is
    a pytree einsum and every decomposition is replicated k×k math.
    """
    lam, U = jnp.linalg.eigh(H_KK)
    lam_max = jnp.max(jnp.abs(lam)) + 1e-30
    tol = _EIG_REL_TOL * lam_max * H_KK.shape[0]
    inv_sqrt = jnp.where(lam > tol, 1.0 / jnp.sqrt(jnp.clip(lam, tol, None)), 0.0)
    S = U * inv_sqrt[None, :]
    B = jax.tree.map(lambda c: jnp.einsum('k...,kj->j...',
                                          c.astype(jnp.float32), S), C)
    mu, Q = jnp.linalg.eigh(_gram(B))          # mu = σ² ≥ 0
    sig2 = jnp.clip(mu, 0.0, None)
    sig = jnp.sqrt(sig2)
    inv_sig = jnp.where(sig > _EIG_REL_TOL * (sig[-1] + 1e-30), 1.0 / sig, 0.0)
    QS = Q * inv_sig[None, :]
    W = jax.tree.map(lambda b: jnp.einsum('k...,kj->j...', b, QS), B)
    return W, sig2


def _apply_spectral(s: NystromSketch, v: PyTree) -> PyTree:
    """u = v/ρ + W diag(1/(σ²+ρ) − 1/ρ) Wᵀ v  (exact inverse of H_k + ρI)."""
    rho = s.rho
    t = _ctv(s.W, v)                           # (k,) [psum of k floats]
    coef = 1.0 / (s.sig2 + rho) - 1.0 / rho    # ≤ 0; exactly 0 on dropped dirs
    return tree_axpy(1.0, _cv(s.W, coef * t), tree_scale(v, 1.0 / rho))


def _apply_woodbury_direct(s: NystromSketch, v: PyTree) -> PyTree:
    """Eq. 6:  u = v/ρ − C (H_KK + CᵀC/ρ)⁻¹ (Cᵀv) / ρ²."""
    rho = s.rho
    t = _ctv(s.C, v)                       # (k,)   [psum of k floats]
    M = s.H_KK + _gram(s.C) / rho          # (k,k)  [psum of k² floats]
    w = _sym_solve(M, t)                   # replicated tiny solve
    correction = _cv(s.C, w / (rho * rho))
    return tree_sub(tree_scale(v, 1.0 / rho), correction)


def _eig_factors(s: NystromSketch):
    """L = C·U and deactivated-eigenvalue diagonal for Alg. 1 paths."""
    lam, U = jnp.linalg.eigh(s.H_KK)
    scale = jnp.max(jnp.abs(lam)) + 1e-30
    lam_safe = jnp.where(jnp.abs(lam) < _EIG_REL_TOL * scale, _SAFE_BIG, lam)
    L = jax.tree.map(lambda c: jnp.einsum('k...,kj->j...',
                                          c.astype(jnp.float32), U), s.C)
    return L, lam_safe


def _apply_woodbury_chunked(s: NystromSketch, v: PyTree, kappa: int) -> PyTree:
    """Alg. 1: recursive rank-κ Woodbury updates, applied in operator form.

    State after chunk m: Ĥ_m x = x/ρ − Σ_{j≤m} G_j R_j (G_jᵀ x), held as the
    factor list {(G_j, R_j)}. Per chunk: apply Ĥ_m to the κ new columns, solve
    a κ×κ system, append a factor. Bit-equivalent to Eq. 6 for every κ.
    """
    k = s.indices['leaf'].shape[0]
    rho = s.rho
    L, lam = _eig_factors(s)
    factors: list[tuple[PyTree, jax.Array]] = []

    def apply_running(x: PyTree) -> PyTree:
        out = tree_scale(x, 1.0 / rho)
        for G, R in factors:
            out = tree_sub(out, _cv(G, R @ _ctv(G, x)))
        return out

    for start in range(0, k, kappa):
        width = min(kappa, k - start)
        Lm = jax.tree.map(lambda l: jax.lax.slice_in_dim(l, start, start + width, axis=0), L)
        Jm = jnp.diag(lam[start:start + width])
        # Ĥ_m applied to each of the κ columns (vmap over the leading axis).
        HmL = jax.vmap(apply_running)(Lm)
        S = Jm + _cross(Lm, HmL)
        S = 0.5 * (S + S.T)
        jitter = 1e-8 * (jnp.trace(jnp.abs(S)) / width + 1.0)
        R = jnp.linalg.inv(S + jitter * jnp.eye(width, dtype=S.dtype))
        factors.append((HmL, 0.5 * (R + R.T)))

    return apply_running(v)


def nystrom_inverse_dense(H: jax.Array, k: int, rho: float,
                          rng: jax.Array) -> jax.Array:
    """Dense-matrix Nyström inverse (Fig. 1 oracle / tests): returns
    (H_k + ρI)⁻¹ as an explicit p×p matrix. Test-scale only."""
    p = H.shape[0]
    idx = jax.random.choice(rng, p, (min(k, p),), replace=False)
    C = H[:, idx]                      # (p, k)
    H_KK = 0.5 * (C[idx, :] + C[idx, :].T)
    M = H_KK + C.T @ C / rho
    M = 0.5 * (M + M.T) + 1e-8 * jnp.eye(M.shape[0])
    return jnp.eye(p) / rho - C @ jnp.linalg.solve(M, C.T) / rho**2


# ---------------------------------------------------------------------------
# Iterative baselines
# ---------------------------------------------------------------------------
@dataclasses.dataclass(frozen=True)
class CGIHVP:
    """Truncated conjugate gradient on (H + ρI) x = v.

    ρ=0 reproduces the paper's baseline exactly; ρ>0 is Tikhonov damping.
    """
    iters: int = 5
    rho: float = 0.0

    def solve(self, hvp: HVP, indexer: PyTreeIndexer, v: PyTree,
              rng: jax.Array | None = None) -> PyTree:
        del indexer, rng

        def matvec(x: PyTree) -> PyTree:
            return tree_axpy(self.rho, x, hvp(x))

        x = tree_zeros_like(v)
        r = v
        p = v
        rs = tree_vdot(r, r)

        def body(_, carry):
            x, r, p, rs = carry
            Ap = matvec(p)
            denom = tree_vdot(p, Ap)
            alpha = rs / jnp.where(jnp.abs(denom) < 1e-30, 1e-30, denom)
            x = tree_axpy(alpha, p, x)
            r = tree_axpy(-alpha, Ap, r)
            rs_new = tree_vdot(r, r)
            beta = rs_new / jnp.where(rs < 1e-30, 1e-30, rs)
            p = tree_axpy(beta, p, r)
            return x, r, p, rs_new

        x, _, _, _ = jax.lax.fori_loop(0, self.iters, body, (x, r, p, rs))
        return x


@dataclasses.dataclass(frozen=True)
class NeumannIHVP:
    """Truncated Neumann series (Lorraine et al. 2020):
    (H)⁻¹ ≈ α Σ_{j=0}^{l} (I − αH)^j, requires ‖αH‖ < 1 to converge."""
    iters: int = 5
    alpha: float = 1e-2

    def solve(self, hvp: HVP, indexer: PyTreeIndexer, v: PyTree,
              rng: jax.Array | None = None) -> PyTree:
        del indexer, rng

        def body(_, carry):
            p, acc = carry
            p = tree_axpy(-self.alpha, hvp(p), p)   # p ← (I − αH) p
            acc = tree_axpy(1.0, p, acc)
            return p, acc

        p, acc = jax.lax.fori_loop(0, self.iters, body, (v, v))
        return tree_scale(acc, self.alpha)


@dataclasses.dataclass(frozen=True)
class ExactIHVP:
    """Materialize H column-by-column and dense-solve (tests / tiny models)."""
    rho: float = 1e-2

    def solve(self, hvp: HVP, indexer: PyTreeIndexer, v: PyTree,
              rng: jax.Array | None = None) -> PyTree:
        del rng
        p = indexer.total
        idx = indexer.all_indices()                     # flat-order structured
        C = extract_columns(hvp, indexer, idx)          # full H, (p, ...) tree
        H = indexer.gather(C, idx)                      # (p, p)
        H = 0.5 * (H + H.T)
        v_flat = jnp.concatenate([x.astype(jnp.float32).ravel()
                                  for x in jax.tree.leaves(v)])
        u_flat = jnp.linalg.solve(H + self.rho * jnp.eye(p), v_flat)
        # unflatten back into the parameter structure
        outs, off = [], 0
        for shape, dtype, size in zip(indexer.shapes, indexer.dtypes,
                                      indexer.sizes):
            outs.append(u_flat[off:off + size].reshape(shape).astype(dtype))
            off += size
        return indexer.treedef.unflatten(outs)


SOLVERS = {
    'nystrom': NystromIHVP,
    'cg': CGIHVP,
    'neumann': NeumannIHVP,
    'exact': ExactIHVP,
}
