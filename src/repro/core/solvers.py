"""IHVP solvers: the paper's Nyström method plus the baselines it compares to.

Every solver approximates  u ≈ (H + ρI)⁻¹ v  where H = ∇²_θ f is accessed only
through Hessian-vector products (HVPs).

Uniform solver protocol: every solver implements

    prepare(hvp, indexer, rng) -> state     # touches the model (HVPs)
    apply(state, v)            -> u         # touches only the state
    apply_matrix(state, V)     -> U         # m queries per state pass
    solve(hvp, indexer, v, rng) == apply(prepare(hvp, indexer, rng), v)

``apply_matrix`` takes a *query block*: a pytree shaped like v with one
trailing (m,) axis on every leaf (m stacked cotangents / query gradients).
One prepared state then serves all m queries per pass — for Nyström that
means the tall-skinny contractions become genuine GEMMs ((k, p) × (p, m))
instead of m separate matvecs, and under ``flat_sharded`` the cross-device
reduction is a single (k, m) psum instead of m k-float psums. m = 1
dispatches statically to the vector ``apply``, so a width-1 block is
bit-identical to the vector path on every backend.

``prepare`` does all the work that can be amortized across right-hand sides
(and, for the Nyström sketch / dense factor, across outer steps); ``apply``
is the per-v cost. For the iterative baselines (CG/Neumann) there is nothing
to amortize — their ``prepare`` returns a thin :class:`IterativeOperator`
that closes over the traced hvp, so it is valid only inside the enclosing
trace and cannot be shipped across a jit boundary the way a
:class:`NystromSketch` (pure pytree-of-arrays) can. The class attribute
``amortizable`` declares which kind a solver is: True means ``prepare``
returns a pytree-of-arrays state that survives jit boundaries and outer
steps (Nyström, exact); False means the state is trace-local (CG, Neumann).
The protocol is what ``repro.core.implicit.implicit_root`` drives in its
custom_vjp backward pass; it replaces the previous
``hasattr(solver, 'apply')`` duck-typing.

The *lifecycle* of an amortizable state — build it at a linearization point,
reuse it for a few outer steps, rebuild when stale — is owned by
:class:`SketchPolicy` (bottom of this module): ``BilevelTrainer``'s loop,
the manual ``build_sketch``/``outer_step_with_sketch`` pair, and the
shared-sketch meta-batch path of ``implicit_root`` all drive the same
policy object instead of hand-rolling refresh logic.

* ``NystromIHVP`` — the paper's contribution (Eq. 4/6, Alg. 1). Non-iterative:
  k parallel HVPs build the sketch once, then every apply is two tall-skinny
  contractions and one k×k solve. The κ dial selects the time/space tradeoff
  (κ=k: Eq. 6 "time-efficient"; κ=1: Eq. 9 "space-efficient"; in between:
  Alg. 1 hybrid) with bit-identical results.
* ``CGIHVP`` — conjugate gradient (Pedregosa 2016; Rajeswaran et al. 2019).
* ``NeumannIHVP`` — Neumann series (Lorraine et al. 2020).
* ``ExactIHVP`` — dense solve, for tiny problems / oracles in tests.

Contraction backends: every tall-skinny contraction in the Nyström hot path
(Cᵀv, Cw, CᵀC, CᵀB) goes through a pluggable backend
(``repro.core.backend``), selected by ``NystromIHVP(backend=...)``:

  'tree'         per-leaf pytree einsums — the default and the parity
                 oracle; sharding-transparent but pays n_leaves dispatches
                 per contraction.
  'flat'         the sketch is fused once at prepare() into a single (k, p)
                 buffer; each contraction is then ONE fused XLA matmul
                 instead of n_leaves einsums + a Python sum. Fastest on
                 CPU/GPU/single-chip; unsharded steps only.
  'flat_sharded' flat's fusion under GSPMD sharding: per-device local
                 (k, p_local) buffers built inside shard_map, reductions
                 finished by a k-float (k×k) psum. Needs mesh + param
                 PartitionSpecs; never all-gathers a parameter leaf.
  'pallas'       flat buffer with the gram / Cᵀv / fused-apply passes in
                 the hand-tiled Pallas TPU kernels (repro.kernels) — one
                 HBM read of C per pass. Interpret-mode fallback off-TPU.

Sharding: solvers are pure jax; under pjit with backend='tree', C (leading-k
parameter pytree) inherits the parameter sharding and CᵀC / Cᵀv lower to
per-shard contractions + one psum. backend='flat_sharded' keeps that
sharding story while also fusing the per-device p-pass into one matmul —
the fast path for sharded steps (docs/backends.md has the full design and
measured numbers). No solver holds any p×p object.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, ClassVar

import jax
import jax.numpy as jnp

from repro.core.backend import get_backend
from repro.core.hvp import extract_columns, make_hvp
from repro.core.tree_util import (PyTree, PyTreeIndexer, tree_axpy, tree_scale,
                                  tree_size, tree_vdot, tree_zeros_like)

HVP = Callable[[PyTree], PyTree]

# Eigenvalues below this (relative) threshold are deactivated by sending them
# to SAFE_BIG, which makes their rank-1/rank-κ Woodbury contribution vanish —
# the static-shape analogue of a truncated pseudo-inverse (paper §5: zero
# Hessian columns under ReLU break the plain inverse).
_EIG_REL_TOL = 1e-7
_SAFE_BIG = 1e30


def _sym_solve(M: jax.Array, t: jax.Array) -> jax.Array:
    """Solve M w = t for symmetric (possibly indefinite) k×k M; t may be a
    (k,) vector or a (k, m) block of right-hand sides.

    Jacobi (diagonal) preconditioning: M = H_KK + CᵀC/ρ mixes scales of H and
    H²/ρ, which costs ~3 digits in f32; symmetric diagonal scaling restores
    them (measured in tests/test_solvers.py). Jitter handles the zero-column
    degeneracy the paper works around with leaky-ReLU.
    """
    M = 0.5 * (M + M.T)
    d = jnp.sqrt(jnp.clip(jnp.abs(jnp.diagonal(M)), 1e-30, None))
    Ms = M / d[:, None] / d[None, :]
    jitter = 1e-7
    k = M.shape[0]
    ds = d if t.ndim == 1 else d[:, None]
    w = jnp.linalg.solve(Ms + jitter * jnp.eye(k, dtype=M.dtype), t / ds)
    return w / ds


def query_width(V: PyTree) -> int:
    """The m of a query block: the shared trailing-axis width of every leaf.

    Raises ValueError when leaves disagree (the usual symptom of passing a
    plain parameter vector where a block was expected — a block leaf is the
    parameter shape *plus* one trailing (m,) axis, even at m = 1).
    """
    leaves = jax.tree.leaves(V)
    if not leaves:
        raise ValueError('query block has no leaves')
    widths = {l.shape[-1] if l.ndim else None for l in leaves}
    if len(widths) != 1 or None in widths:
        raise ValueError(
            'inconsistent query block: every leaf must carry the same '
            f'trailing (m,) query axis, got widths {sorted(map(str, widths))}')
    return leaves[0].shape[-1]


def _matrix_via_vector(apply_fn, V: PyTree) -> PyTree:
    """m = 1 static dispatch: strip the query axis, run the vector apply,
    restore the axis — bit-identical to the vector path by construction."""
    u = apply_fn(jax.tree.map(lambda x: x[..., 0], V))
    return jax.tree.map(lambda x: x[..., None], u)


# ---------------------------------------------------------------------------
# Nyström (the paper)
# ---------------------------------------------------------------------------
@jax.tree_util.register_dataclass
@dataclasses.dataclass
class NystromSketch:
    """Prepared sketch: reusable across many IHVP applies (and outer steps).

    ``C`` is the backend-native sketch operand: a leading-k parameter pytree
    for backend='tree', the fused sketch-major (k, p) buffer for
    backend='flat', the per-device ``ShardedOperand`` (local fused buffer +
    psum weights) for backend='flat_sharded', or the kernel-tiled (p, k)
    transpose for backend='pallas' — there is no separate unflatten spec;
    apply() reads the output structure off the incoming ``v``.

    ``B``/``gram_B`` is the numerically-stable whitened form of H_k
    (H_k = B Bᵀ with B = C·U diag(λ†^(1/2)); gram_B = BᵀB): present when the
    solver was built with ``stabilized=True`` and the whitened apply is
    reachable (``kappa`` unset or ≥ k — the Alg. 1 chunked apply never
    consults it, so those sketches skip it). ``B`` uses the same
    backend-native representation as ``C``; ``gram_C`` = CᵀC is cached
    instead otherwise (the Eq. 6 apply's k×k system needs it, and it is
    ρ-independent).

    The sketch is ρ-free: every apply path solves against the *applying*
    solver's rho (the k×k system (gram + ρI-ish) w = t is re-solved per
    apply — O(k³) replicated flops, negligible), so one sketch can be
    reused across a damping sweep. ``rho`` records the prepare-time value
    for reference only.
    """
    C: Any              # H[:, K], backend-native (see class docstring)
    H_KK: jax.Array     # (k, k), symmetrized
    indices: dict       # structured {'leaf', 'dims'} (PyTreeIndexer)
    rho: jax.Array      # scalar (prepare-time record; applies use solver rho)
    B: Any = None
    gram_B: jax.Array | None = None
    gram_C: jax.Array | None = None


@dataclasses.dataclass(frozen=True)
class NystromIHVP:
    """The paper's method. κ=None ⇒ Eq. 6 (time-efficient).

    ``stabilized=True`` (default) applies the inverse through the whitened
    factor of H_k (Frangella–Tropp–Udell-style): Eq. 6's k×k system
    H_KK + CᵀC/ρ carries cond(H)² and costs ~3 digits in f32; the whitened
    Woodbury identity is backward-stable (its k×k system BᵀB + ρI carries
    cond(H), not cond(H)²). Either way the apply's rho is *this solver's*
    rho — a sketch is ρ-free and retargets across damping values
    (tests/test_solvers.py::test_sketch_retargets_across_rho).
    ``stabilized=False`` is the literal Eq. 6 for paper-faithful
    benchmarking; both agree to solver tolerance on well-conditioned H.

    ``backend`` selects the contraction backend ('tree' | 'flat' |
    'flat_sharded' | 'pallas', see module docstring), or accepts a
    pre-built backend instance (e.g. ``PallasBackend(interpret=True)`` in
    tests, or a ``FlatShardedBackend(mesh=..., specs=...)`` — the string
    form of flat_sharded cannot carry its mesh, so sharded steps pass the
    instance or go through ``HypergradConfig``). A sketch prepared under
    one backend must be applied under the same backend.

    ``refine``: iterative-refinement sweeps on the apply. An f32 Woodbury
    apply bottoms out at ~eps·λmax/ρ absolute error (the v/ρ-scale
    cancellation); each sweep re-applies the inverse to the residual
    v − (H_k + ρI)u — four extra C-passes, still zero HVPs — and drives the
    error to f32 roundoff (measured: 3e-3 → 5e-6 at ρ=1e-3 on the analytic
    quadratic). refine=0 restores the literal two-pass apply.

    Precedence when ``kappa < k`` (Alg. 1 requested): the chunked apply is
    the *literal* recursive-Woodbury path and takes precedence over
    ``stabilized`` — it carries its own deactivated-eigenvalue handling (the
    ``_SAFE_BIG`` truncation), so the whitened factor is never consulted and
    ``prepare`` does not build it (it caches ``gram_C`` instead, keeping the
    Eq. 6 fallback two-pass). ``refine`` *is* honored on the chunked path:
    the residual sweeps only need C-passes against the eigen-factor, not the
    whitened form. Asserted in
    tests/test_solvers.py::TestNystrom::test_kappa_precedence_over_stabilized.

    At full rank (k = p) the Nyström inverse is exact — the quickest
    end-to-end check:

    >>> import jax, jax.numpy as jnp
    >>> from repro.core.hvp import make_hvp
    >>> from repro.core.tree_util import PyTreeIndexer
    >>> params = {'w': jnp.zeros((6,))}
    >>> d = 1.0 + jnp.arange(6.0)                       # H = diag(d)
    >>> hvp = make_hvp(lambda p, hp, b: 0.5 * jnp.sum(d * p['w'] ** 2),
    ...                params, None, None)
    >>> solver = NystromIHVP(k=6, rho=1e-3, backend='flat')
    >>> u = solver.solve(hvp, PyTreeIndexer(params), {'w': jnp.ones((6,))},
    ...                  jax.random.PRNGKey(0))
    >>> bool(jnp.allclose(u['w'], 1.0 / (d + 1e-3), rtol=1e-3))
    True
    """
    amortizable: ClassVar[bool] = True   # NystromSketch is pytree-of-arrays

    k: int
    rho: float = 1e-2
    kappa: int | None = None
    column_chunk: int | None = None
    importance_sampling: bool = False  # Remark 1 (Drineas–Mahoney weights)
    stabilized: bool = True
    backend: Any = 'tree'
    refine: int = 1

    def _be(self):
        if isinstance(self.backend, str):
            return get_backend(self.backend)
        return self.backend

    # -- sketch construction (k HVPs; the only part that touches the model) --
    def prepare(self, hvp: HVP, indexer: PyTreeIndexer, rng: jax.Array,
                diag_weights: jax.Array | None = None) -> NystromSketch:
        be = self._be()
        weights = diag_weights if self.importance_sampling else None
        idx = indexer.sample_indices(rng, self.k, weights)
        C_tree = extract_columns(hvp, indexer, idx, self.column_chunk)
        H_KK = indexer.gather(C_tree, idx)
        H_KK = 0.5 * (H_KK + H_KK.T)
        C_op = be.prepare_operand(C_tree)
        B, gram_B, gram_C = (None, None, None)
        # kappa<k selects the Alg. 1 chunked apply, which never consults the
        # whitened factor (precedence — see class docstring): skip building it.
        if self.stabilized and not (self.kappa is not None
                                    and self.kappa < self.k):
            B, gram_B = _whitened_form(be, C_op, H_KK)
        else:
            # ρ-independent, so cached here: the Eq. 6 apply stays 2-pass.
            gram_C = be.gram(C_op)
        return NystromSketch(C=C_op, H_KK=H_KK, indices=idx,
                             rho=jnp.float32(self.rho), B=B,
                             gram_B=gram_B, gram_C=gram_C)

    # -- apply (no HVPs; two tall-skinny contractions + tiny replicated math)
    def apply(self, sketch: NystromSketch, v: PyTree) -> PyTree:
        be = self._be()
        if self.kappa is not None and self.kappa < self.k:
            return _apply_woodbury_chunked(be, sketch, v, self.kappa,
                                           self.rho, self.refine)
        if self.stabilized and sketch.B is not None:
            return _apply_whitened(be, sketch, v, self.rho, self.refine)
        return _apply_woodbury_direct(be, sketch, v, self.rho)

    def apply_matrix(self, sketch: NystromSketch, V: PyTree) -> PyTree:
        """m IHVPs per sketch pass: every contraction of the vector apply
        widens to a (·, m) GEMM (same dispatch precedence — chunked >
        whitened > direct), so m queries cost one set of C-reads, not m."""
        if query_width(V) == 1:
            return _matrix_via_vector(lambda v: self.apply(sketch, v), V)
        be = self._be()
        if self.kappa is not None and self.kappa < self.k:
            return _apply_woodbury_chunked_m(be, sketch, V, self.kappa,
                                             self.rho, self.refine)
        if self.stabilized and sketch.B is not None:
            return _apply_whitened_m(be, sketch, V, self.rho, self.refine)
        return _apply_woodbury_direct_m(be, sketch, V, self.rho)

    def solve(self, hvp: HVP, indexer: PyTreeIndexer, v: PyTree,
              rng: jax.Array) -> PyTree:
        return self.apply(self.prepare(hvp, indexer, rng), v)


def _whitened_form(be, C_op, H_KK: jax.Array):
    """H_k = C H_KK† Cᵀ = B Bᵀ with B = C · U diag(λ†^(1/2)), via k×k eighs.

    Every p-sized op is one backend contraction; every decomposition is
    replicated k×k math. The apply then uses the *exact* Woodbury identity

        (B Bᵀ + ρI)⁻¹ = (I − B (BᵀB + ρI)⁻¹ Bᵀ) / ρ

    which holds for any B — unlike the previous spectral form it never needs
    an orthonormal p×k basis, so f32 eigenvector error is not amplified by
    1/ρ (that error cost ~1% at ρ=1e-3 on the full-rank analytic test; this
    form is ~1e-4 there, ~1e-6 with one refinement sweep). Directions with
    λ(H_KK) below the relative threshold are dropped from B (zero columns),
    reproducing the truncated pseudo-inverse semantics for the ReLU
    dead-column pathology (§5). ρ enters only at apply time.
    """
    lam, U = jnp.linalg.eigh(H_KK)
    lam_max = jnp.max(jnp.abs(lam)) + 1e-30
    tol = _EIG_REL_TOL * lam_max * H_KK.shape[0]
    inv_sqrt = jnp.where(lam > tol, 1.0 / jnp.sqrt(jnp.clip(lam, tol, None)),
                         0.0)
    B = be.mul_right(C_op, U * inv_sqrt[None, :])
    G = be.gram(B)                              # (k, k)  [psum of k² floats]
    return B, 0.5 * (G + G.T)


def _apply_whitened(be, s: NystromSketch, v: PyTree, rho: float,
                    refine: int = 1) -> PyTree:
    """u = v/ρ − B (BᵀB + ρI)⁻¹ (Bᵀ v) / ρ  with BᵀB stored in the sketch
    (ρ enters only here, so the sketch retargets across damping values),
    plus ``refine`` residual-correction sweeps against H_k = BBᵀ."""
    vf = be.vec(v)
    k = s.gram_B.shape[0]
    M = s.gram_B + rho * jnp.eye(k, dtype=s.gram_B.dtype)

    def woodbury(x):
        t = be.ctv(s.B, x)                     # (k,) [psum of k floats]
        w = -jnp.linalg.solve(M, t) / rho      # tiny replicated math
        return be.combine(s.B, w, x, rho)

    u = woodbury(vf)
    for _ in range(refine):
        h_u = be.cv(s.B, be.ctv(s.B, u))       # H_k u
        r = be.sub(be.sub(vf, be.scale(u, rho)), h_u)
        u = be.add(u, woodbury(r))
    return be.unvec(u, v)


def _apply_whitened_m(be, s: NystromSketch, V: PyTree, rho: float,
                      refine: int = 1) -> PyTree:
    """The whitened apply over an m-query block: identical algebra with every
    k-vector widened to (k, m) and every p-vector to the backend's (p, m)
    block form — one C-read per pass for all m queries, and under
    flat_sharded exactly one (k, m) psum per ``ctm``."""
    Vm = be.vecm(V)
    k = s.gram_B.shape[0]
    M = s.gram_B + rho * jnp.eye(k, dtype=s.gram_B.dtype)

    def woodbury(X):
        T = be.ctm(s.B, X)                     # (k, m)  [ONE psum]
        W = -jnp.linalg.solve(M, T) / rho      # tiny replicated math
        return be.combinem(s.B, W, X, rho)

    U = woodbury(Vm)
    for _ in range(refine):
        h_u = be.cm(s.B, be.ctm(s.B, U))       # H_k U
        r = be.sub(be.sub(Vm, be.scale(U, rho)), h_u)
        U = be.add(U, woodbury(r))
    return be.unvecm(U, V)


def _apply_woodbury_direct(be, s: NystromSketch, v: PyTree,
                           rho: float) -> PyTree:
    """Eq. 6:  u = v/ρ − C (H_KK + CᵀC/ρ)⁻¹ (Cᵀv) / ρ²."""
    vf = be.vec(v)
    t = be.ctv(s.C, vf)                    # (k,)   [psum of k floats]
    # gram_C is cached at prepare() for stabilized=False sketches; fall back
    # to one extra C-pass when applying a stabilized sketch Eq. 6-style.
    gram_C = s.gram_C if s.gram_C is not None else be.gram(s.C)
    M = s.H_KK + gram_C / rho              # (k,k)
    w = _sym_solve(M, t)                   # replicated tiny solve
    return be.unvec(be.combine(s.C, -w / (rho * rho), vf, rho), v)


def _apply_woodbury_direct_m(be, s: NystromSketch, V: PyTree,
                             rho: float) -> PyTree:
    """Eq. 6 over an m-query block: the k×k system is solved once against m
    right-hand sides (multi-RHS ``_sym_solve``)."""
    Vm = be.vecm(V)
    T = be.ctm(s.C, Vm)                    # (k, m)  [ONE psum]
    gram_C = s.gram_C if s.gram_C is not None else be.gram(s.C)
    M = s.H_KK + gram_C / rho
    W = _sym_solve(M, T)
    return be.unvecm(be.combinem(s.C, -W / (rho * rho), Vm, rho), V)


def _eig_factors(be, s: NystromSketch):
    """L = C·U and deactivated-eigenvalue diagonal for Alg. 1 paths."""
    lam, U = jnp.linalg.eigh(s.H_KK)
    scale = jnp.max(jnp.abs(lam)) + 1e-30
    lam_safe = jnp.where(jnp.abs(lam) < _EIG_REL_TOL * scale, _SAFE_BIG, lam)
    return be.mul_right(s.C, U), lam_safe


def _chunk_factors(be, s: NystromSketch, kappa: int, rho: float):
    """Alg. 1 factor construction, shared by the vector and block appliers.

    State after chunk m: Ĥ_m x = x/ρ − Σ_{j≤m} G_j R_j (G_jᵀ x), held as the
    factor list {(G_j, R_j)}. Per chunk: apply Ĥ_m to the κ new columns
    (one block of backend contractions — no vmap), solve a κ×κ system,
    append a factor. Bit-equivalent to Eq. 6 for every κ. Returns
    (L, λ_safe, factors) — L = C·U with deactivated eigenvalues sent to
    _SAFE_BIG so their reciprocal contribution vanishes
    (truncated-pseudo-inverse semantics)."""
    k = s.indices['leaf'].shape[0]
    L, lam = _eig_factors(be, s)
    factors: list[tuple[Any, jax.Array]] = []

    def apply_running_block(X):
        """Ĥ_m applied to a tall-skinny block (backend-native layout)."""
        out = be.scale(X, 1.0 / rho)
        for G, R in factors:
            out = be.sub(out, be.mul_right(G, R @ be.cross(G, X)))
        return out

    for start in range(0, k, kappa):
        width = min(kappa, k - start)
        Lm = be.slice_k(L, start, width)
        Jm = jnp.diag(lam[start:start + width])
        HmL = apply_running_block(Lm)
        S = Jm + be.cross(Lm, HmL)
        S = 0.5 * (S + S.T)
        jitter = 1e-8 * (jnp.trace(jnp.abs(S)) / width + 1.0)
        R = jnp.linalg.inv(S + jitter * jnp.eye(width, dtype=S.dtype))
        factors.append((HmL, 0.5 * (R + R.T)))
    return L, lam, factors


def _apply_woodbury_chunked(be, s: NystromSketch, v: PyTree, kappa: int,
                            rho: float, refine: int = 0) -> PyTree:
    """Alg. 1: recursive rank-κ Woodbury updates, applied in operator form
    (factor construction: :func:`_chunk_factors`).

    ``refine`` residual sweeps correct u against H_k + ρI exactly as on the
    whitened path, with H_k u = L diag(λ_safe⁻¹) (Lᵀ u) — deactivated
    eigenvalues were sent to _SAFE_BIG, so their reciprocal contribution
    vanishes, matching the truncated-pseudo-inverse semantics.
    """
    L, lam, factors = _chunk_factors(be, s, kappa, rho)

    def apply_factors(x):
        out = be.scale(x, 1.0 / rho)
        for G, R in factors:
            out = be.sub(out, be.cv(G, R @ be.ctv(G, x)))
        return out

    vf = be.vec(v)
    u = apply_factors(vf)
    for _ in range(refine):
        h_u = be.cv(L, be.ctv(L, u) / lam)     # H_k u (λ_safe⁻¹ ≈ λ† trunc.)
        r = be.sub(be.sub(vf, be.scale(u, rho)), h_u)
        u = be.add(u, apply_factors(r))
    return be.unvec(u, v)


def _apply_woodbury_chunked_m(be, s: NystromSketch, V: PyTree, kappa: int,
                              rho: float, refine: int = 0) -> PyTree:
    """Alg. 1 over an m-query block: the factor list is built once (it is
    query-independent — the expensive part of the chunked apply) and each
    factor's rank-κ correction hits all m queries as one GEMM pair."""
    L, lam, factors = _chunk_factors(be, s, kappa, rho)

    def apply_factors(X):
        out = be.scale(X, 1.0 / rho)
        for G, R in factors:
            out = be.sub(out, be.cm(G, R @ be.ctm(G, X)))
        return out

    Vm = be.vecm(V)
    U = apply_factors(Vm)
    for _ in range(refine):
        h_u = be.cm(L, be.ctm(L, U) / lam[:, None])   # H_k U, truncated λ†
        r = be.sub(be.sub(Vm, be.scale(U, rho)), h_u)
        U = be.add(U, apply_factors(r))
    return be.unvecm(U, V)


def nystrom_inverse_dense(H: jax.Array, k: int, rho: float,
                          rng: jax.Array) -> jax.Array:
    """Dense-matrix Nyström inverse (Fig. 1 oracle / tests): returns
    (H_k + ρI)⁻¹ as an explicit p×p matrix. Test-scale only."""
    p = H.shape[0]
    idx = jax.random.choice(rng, p, (min(k, p),), replace=False)
    C = H[:, idx]                      # (p, k)
    H_KK = 0.5 * (C[idx, :] + C[idx, :].T)
    M = H_KK + C.T @ C / rho
    M = 0.5 * (M + M.T) + 1e-8 * jnp.eye(M.shape[0])
    return jnp.eye(p) / rho - C @ jnp.linalg.solve(M, C.T) / rho**2


# ---------------------------------------------------------------------------
# Iterative baselines
# ---------------------------------------------------------------------------
@dataclasses.dataclass(frozen=True)
class IterativeOperator:
    """Prepared state of an iterative solver: a thin operator handle.

    Iterative methods have no sketch to amortize — ``prepare`` just closes
    over the hvp so that ``apply`` fits the uniform protocol. Because the
    handle holds a *callable over traced values*, it lives only within the
    trace that built it: it cannot be checkpointed, donated, or reused after
    the parameters change (unlike a :class:`NystromSketch` or
    :class:`DenseFactor`, which are pytrees of arrays)."""
    hvp: HVP


@jax.tree_util.register_dataclass
@dataclasses.dataclass
class DenseFactor:
    """ExactIHVP's prepared state: the materialized, symmetrized Hessian.

    ρ-free like the Nyström sketch — ``apply`` adds the *applying* solver's
    ρI, so one factor serves a whole damping sweep (tests / Fig. 1 oracles).
    """
    H: jax.Array    # (p, p)


@dataclasses.dataclass(frozen=True)
class CGIHVP:
    """Truncated conjugate gradient on (H + ρI) x = v.

    ρ=0 reproduces the paper's baseline exactly; ρ>0 is Tikhonov damping.
    """
    amortizable: ClassVar[bool] = False  # IterativeOperator is trace-local

    iters: int = 5
    rho: float = 0.0

    def prepare(self, hvp: HVP, indexer: PyTreeIndexer,
                rng: jax.Array | None = None) -> IterativeOperator:
        del indexer, rng
        return IterativeOperator(hvp=hvp)

    def apply(self, state: IterativeOperator, v: PyTree) -> PyTree:
        hvp = state.hvp

        def matvec(x: PyTree) -> PyTree:
            return tree_axpy(self.rho, x, hvp(x))

        x = tree_zeros_like(v)
        r = v
        p = v
        rs = tree_vdot(r, r)

        def body(_, carry):
            x, r, p, rs = carry
            Ap = matvec(p)
            denom = tree_vdot(p, Ap)
            alpha = rs / jnp.where(jnp.abs(denom) < 1e-30, 1e-30, denom)
            x = tree_axpy(alpha, p, x)
            r = tree_axpy(-alpha, Ap, r)
            rs_new = tree_vdot(r, r)
            beta = rs_new / jnp.where(rs < 1e-30, 1e-30, rs)
            p = tree_axpy(beta, p, r)
            return x, r, p, rs_new

        x, _, _, _ = jax.lax.fori_loop(0, self.iters, body, (x, r, p, rs))
        return x

    def apply_matrix(self, state: IterativeOperator, V: PyTree) -> PyTree:
        """vmap over the trailing query axis: CG's recurrence couples the
        scalars (α, β) to each right-hand side, so the m solves stay
        independent — but the HVPs inside batch across queries under vmap
        (one batched fwd+bwd per iteration instead of m)."""
        if query_width(V) == 1:
            return _matrix_via_vector(lambda v: self.apply(state, v), V)
        return jax.vmap(lambda v: self.apply(state, v),
                        in_axes=-1, out_axes=-1)(V)

    def solve(self, hvp: HVP, indexer: PyTreeIndexer, v: PyTree,
              rng: jax.Array | None = None) -> PyTree:
        return self.apply(self.prepare(hvp, indexer, rng), v)


@dataclasses.dataclass(frozen=True)
class NeumannIHVP:
    """Truncated Neumann series (Lorraine et al. 2020):
    (H)⁻¹ ≈ α Σ_{j=0}^{l} (I − αH)^j, requires ‖αH‖ < 1 to converge."""
    amortizable: ClassVar[bool] = False  # IterativeOperator is trace-local

    iters: int = 5
    alpha: float = 1e-2

    def prepare(self, hvp: HVP, indexer: PyTreeIndexer,
                rng: jax.Array | None = None) -> IterativeOperator:
        del indexer, rng
        return IterativeOperator(hvp=hvp)

    def apply(self, state: IterativeOperator, v: PyTree) -> PyTree:
        hvp = state.hvp

        def body(_, carry):
            p, acc = carry
            p = tree_axpy(-self.alpha, hvp(p), p)   # p ← (I − αH) p
            acc = tree_axpy(1.0, p, acc)
            return p, acc

        p, acc = jax.lax.fori_loop(0, self.iters, body, (v, v))
        return tree_scale(acc, self.alpha)

    def apply_matrix(self, state: IterativeOperator, V: PyTree) -> PyTree:
        """vmap over the trailing query axis (the series recursion is
        per-query, but the inner HVPs batch under vmap)."""
        if query_width(V) == 1:
            return _matrix_via_vector(lambda v: self.apply(state, v), V)
        return jax.vmap(lambda v: self.apply(state, v),
                        in_axes=-1, out_axes=-1)(V)

    def solve(self, hvp: HVP, indexer: PyTreeIndexer, v: PyTree,
              rng: jax.Array | None = None) -> PyTree:
        return self.apply(self.prepare(hvp, indexer, rng), v)


@dataclasses.dataclass(frozen=True)
class ExactIHVP:
    """Materialize H column-by-column and dense-solve (tests / tiny models)."""
    amortizable: ClassVar[bool] = True   # DenseFactor is pytree-of-arrays

    rho: float = 1e-2

    def prepare(self, hvp: HVP, indexer: PyTreeIndexer,
                rng: jax.Array | None = None) -> DenseFactor:
        del rng
        idx = indexer.all_indices()                     # flat-order structured
        C = extract_columns(hvp, indexer, idx)          # full H, (p, ...) tree
        H = indexer.gather(C, idx)                      # (p, p)
        return DenseFactor(H=0.5 * (H + H.T))

    def apply(self, state: DenseFactor, v: PyTree) -> PyTree:
        leaves, treedef = jax.tree.flatten(v)
        v_flat = jnp.concatenate([x.astype(jnp.float32).ravel()
                                  for x in leaves])
        p = state.H.shape[0]
        u_flat = jnp.linalg.solve(state.H + self.rho * jnp.eye(p), v_flat)
        # unflatten back into v's structure (no indexer needed at apply time)
        outs, off = [], 0
        for leaf in leaves:
            outs.append(u_flat[off:off + leaf.size].reshape(leaf.shape)
                        .astype(leaf.dtype))
            off += leaf.size
        return treedef.unflatten(outs)

    def apply_matrix(self, state: DenseFactor, V: PyTree) -> PyTree:
        """One factorization against m right-hand sides (multi-RHS solve)."""
        if query_width(V) == 1:
            return _matrix_via_vector(lambda v: self.apply(state, v), V)
        from repro.core.backend import flatten_vecm, unflatten_vecm
        Vm = flatten_vecm(V)                            # (p, m)
        p = state.H.shape[0]
        Um = jnp.linalg.solve(state.H + self.rho * jnp.eye(p), Vm)
        return unflatten_vecm(Um, V)

    def solve(self, hvp: HVP, indexer: PyTreeIndexer, v: PyTree,
              rng: jax.Array | None = None) -> PyTree:
        return self.apply(self.prepare(hvp, indexer, rng), v)


# ---------------------------------------------------------------------------
# Tangent-system apply — the solver as a transposable linear-solve op
# ---------------------------------------------------------------------------
def tangent_apply(solver, state, hvp: HVP, w: PyTree) -> PyTree:
    """Apply the solver's IHVP to ``w`` as a *linear-system solve*:
    ``u ≈ (H + ρI)⁻¹ w``, expressed through ``jax.lax.custom_linear_solve``.

    This is the same estimator as ``solver.apply(state, w)`` — bit-identical
    at first order — but packaged as a linear op JAX knows how to
    differentiate and transpose:

      * transposition (reverse mode over a forward-mode rule) re-invokes
        ``solver.apply`` on the cotangent — the system is symmetric, so the
        transpose solve IS the solve, exactly the backward pass
        :func:`repro.core.implicit._implicit_phi_vjp` runs;
      * further forward differentiation (hyper-Hessian products) gets the
        linear-system JVP ``du = solve(dw − dH·u)``, with ``dH`` taken
        through ``hvp`` — the true system matvec — rather than through the
        sketch, matching the AID convention of differentiating at a frozen
        linearization point.

    ``hvp`` must be the inner Hessian-vector product at the linearization
    point (``make_hvp(inner_loss, theta, phi, batch)``); ``solver.rho``
    (when present) supplies the damping of the system matvec. Iterative
    solvers pass their trace-local ``IterativeOperator`` state; amortizable
    solvers pass a prepared sketch/factor.
    """
    rho = float(getattr(solver, 'rho', 0.0))

    def matvec(u: PyTree) -> PyTree:
        return tree_axpy(rho, u, hvp(u))

    def _solve(mv, b: PyTree) -> PyTree:
        del mv
        return solver.apply(state, b)

    return jax.lax.custom_linear_solve(matvec, w, _solve, symmetric=True)


# ---------------------------------------------------------------------------
# State sizing + identity — what a serving cache needs from a solver
# ---------------------------------------------------------------------------
def build_hvp_bill(solver, params_like: PyTree) -> int:
    """HVPs ONE prepared-state build bills for ``solver`` at this size:
    Nyström rank ``k``, or the full parameter count for the exact solver's
    column scan. ``params_like`` may be concrete params or the shape structs
    from ``jax.eval_shape`` — only sizes are read.

    This is the single definition every accounting surface shares —
    ``influence()``'s ``hvp_count``, the engine's per-edge bills
    (``repro.engine.engine_edge_bills``), and the store's per-entry
    ``build_hvps`` — so a warm cache hit billing zero means the same thing
    everywhere and the cold bills are comparable across paths.
    """
    k = getattr(solver, 'k', None)
    if k is not None:
        return int(k)
    return tree_size(params_like)


def state_nbytes(state) -> int:
    """Byte footprint of a prepared solver state (its pytree-of-arrays leaves).

    The sketch-size accounting a byte-budgeted cache
    (:class:`repro.serve.SketchStore`) evicts against: a NystromSketch is
    dominated by its C/B operands (~2 · k · p · itemsize with the whitened
    form), a DenseFactor by its p×p Hessian. Trace-local states
    (:class:`IterativeOperator`) have no array footprint to account and are
    rejected — they cannot outlive their trace, let alone sit in a cache.

    >>> import jax, jax.numpy as jnp
    >>> from repro.core.hvp import make_hvp
    >>> from repro.core.tree_util import PyTreeIndexer
    >>> params = {'w': jnp.zeros((6,))}
    >>> hvp = make_hvp(lambda p, hp, b: jnp.sum(p['w'] ** 2), params,
    ...                None, None)
    >>> s = NystromIHVP(k=4, backend='flat').prepare(
    ...     hvp, PyTreeIndexer(params), jax.random.PRNGKey(0))
    >>> state_nbytes(s) >= 4 * 6 * 4      # at least the (k, p) f32 buffer
    True
    """
    total = 0
    for leaf in jax.tree.leaves(state):
        nbytes = getattr(leaf, 'nbytes', None)
        if nbytes is None:
            raise TypeError(
                f'{type(state).__name__} holds a non-array leaf '
                f'({type(leaf).__name__}) — only amortizable solver states '
                '(pytrees of arrays) have a byte footprint; trace-local '
                'IterativeOperator states cannot be sized or cached')
        total += int(nbytes)
    return total


def _backend_tag(backend) -> str:
    """A stable content tag for a backend selection (string or instance)."""
    if isinstance(backend, str):
        return backend
    tag = getattr(backend, 'name', type(backend).__name__)
    dtype = getattr(backend, 'sketch_dtype', None)
    if dtype is not None:
        tag += f':{jnp.dtype(dtype).name}'
    return tag


def solver_fingerprint(solver) -> str:
    """Content fingerprint of the *prepared-state identity* of a solver.

    Two solvers with equal fingerprints prepare interchangeable states from
    the same (params, data) point — the solver half of a serving-cache key
    (:func:`repro.serve.sketch_key`). Fields that do not change the prepared
    state are deliberately excluded:

    * ``rho`` — sketches and dense factors are ρ-free (every apply re-solves
      the k×k system against the *applying* solver's damping), so one cached
      state serves a whole damping sweep;
    * ``refine`` — apply-time residual sweeps, not state content.

    Iterative solvers raise: their prepared state is trace-local, so it has
    no cacheable identity.

    >>> solver_fingerprint(NystromIHVP(k=8, rho=1e-3)) == \\
    ...     solver_fingerprint(NystromIHVP(k=8, rho=1e-1))
    True
    >>> solver_fingerprint(NystromIHVP(k=8)) == \\
    ...     solver_fingerprint(NystromIHVP(k=16))
    False
    """
    if not getattr(type(solver), 'amortizable', False):
        raise TypeError(
            f'{type(solver).__name__} prepares a trace-local state — it has '
            'no cacheable identity (nothing survives the trace to cache)')
    rho_free = {'rho', 'refine'}
    parts = [type(solver).__name__]
    for f in sorted(dataclasses.fields(solver), key=lambda f: f.name):
        if f.name in rho_free:
            continue
        value = getattr(solver, f.name)
        if f.name == 'backend':
            value = _backend_tag(value)
        parts.append(f'{f.name}={value!r}')
    return ';'.join(parts)


# ---------------------------------------------------------------------------
# Sketch lifecycle — build / refresh / invalidate of amortizable states
# ---------------------------------------------------------------------------
@jax.tree_util.register_dataclass
@dataclasses.dataclass
class SketchState:
    """A prepared solver state plus its age, carried across outer steps.

    ``sketch`` is whatever the solver's ``prepare`` returns (a
    :class:`NystromSketch` / :class:`DenseFactor` — pytree-of-arrays, so the
    whole SketchState crosses jit boundaries and can be checkpointed).
    ``age`` counts outer steps served since the last rebuild (int32, traced),
    which is what makes the refresh decision ``lax.cond``-friendly.
    """
    sketch: Any
    age: jax.Array      # int32 scalar: steps served since last build


@dataclasses.dataclass(frozen=True)
class SketchPolicy:
    """Owns the lifecycle of an amortizable solver state.

    One policy object serves every consumer of sketch amortization — the
    ``BilevelTrainer`` loop (automatic ``sketch_refresh_every`` cadence), the
    manual ``build_sketch``/``outer_step_with_sketch`` pair, and the
    shared-sketch meta-batch path (``implicit_root``'s ``prepare_state``) —
    so there is exactly one definition of "build", "stale", and "refresh".

    ``refresh_every=N`` rebuilds the state every N uses: N=1 is the
    always-fresh cadence (trajectory-identical to preparing inside the
    backward pass), larger N trades hypergradient accuracy (the backward
    linearizes at a stale θ — the approximation error analyzed by Grazzi et
    al. 2020) for k fewer HVPs on N−1 of every N outer steps.

    Construction rejects solvers whose prepared state is trace-local
    (``amortizable = False``: CG/Neumann return an :class:`IterativeOperator`
    closing over the step's hvp) — reusing one across steps would only fail
    later, opaquely, inside the next jitted step.
    """
    solver: Any                      # built solver (uniform protocol)
    inner_loss: Callable[..., jax.Array]   # f(theta, phi, batch) -> scalar
    refresh_every: int = 1

    def __post_init__(self):
        if self.refresh_every < 1:
            raise ValueError(
                f'refresh_every must be >= 1, got {self.refresh_every}')
        if not getattr(type(self.solver), 'amortizable', False):
            raise TypeError(
                f'{type(self.solver).__name__}.prepare returns a trace-local '
                'IterativeOperator — iterative solvers have nothing to '
                'amortize across outer steps; use the fresh-prepare path '
                '(sketch_refresh_every=1 / outer_step_fn) instead')

    # ------------------------------------------------------------- build
    def build(self, params: PyTree, hparams: PyTree, batch: Any,
              rng: jax.Array):
        """Prepare the solver state at the linearization point
        (params, hparams, batch) — the only lifecycle stage that runs HVPs."""
        hvp = make_hvp(self.inner_loss, params, hparams, batch)
        return self.solver.prepare(hvp, PyTreeIndexer(params), rng)

    def init_state(self, params: PyTree, hparams: PyTree, batch: Any,
                   rng: jax.Array) -> SketchState:
        """A structurally-correct *stale* SketchState (zero arrays, age =
        refresh_every) — the first ``refresh`` rebuilds it, so initialization
        costs no HVPs and the refresh cadence stays uniform from step 0."""
        shapes = jax.eval_shape(self.build, params, hparams, batch, rng)
        sketch0 = jax.tree.map(lambda s: jnp.zeros(s.shape, s.dtype), shapes)
        return SketchState(sketch=sketch0,
                           age=jnp.int32(self.refresh_every))

    # ----------------------------------------------------------- refresh
    def refresh(self, state: SketchState, params: PyTree, hparams: PyTree,
                batch: Any, rng: jax.Array) -> tuple[SketchState, jax.Array]:
        """Advance the lifecycle by one outer step: rebuild under
        ``lax.cond`` when the state has served ``refresh_every`` steps, else
        keep it and age it. Returns (state', rebuilt) where ``rebuilt`` is a
        traced bool — callers that thread an rng stream consume their split
        only when it fires (``jnp.where(rebuilt, new_rng, old_rng)``), so
        cadence changes do not shift the stream on non-refresh steps."""
        rebuilt = state.age >= self.refresh_every
        sketch = jax.lax.cond(
            rebuilt,
            lambda: self.build(params, hparams, batch, rng),
            lambda: state.sketch)
        age = jnp.where(rebuilt, jnp.int32(1), state.age + 1)
        return SketchState(sketch=sketch, age=age), rebuilt

    # -------------------------------------------------------- invalidate
    def invalidate(self, state: SketchState) -> SketchState:
        """Mark the state stale (age = refresh_every) so the next
        ``refresh`` rebuilds regardless of cadence — e.g. after
        ``reset_inner`` re-initializes θ and the curvature jumps."""
        return SketchState(sketch=state.sketch,
                           age=jnp.int32(self.refresh_every))


# ---------------------------------------------------------------------------
# Registry — drives HypergradConfig.build()
# ---------------------------------------------------------------------------
@dataclasses.dataclass(frozen=True)
class SolverSpec:
    """Registry entry: constructor + which HypergradConfig fields it consumes.

    ``fields`` maps config-field name → constructor kwarg (the paper reuses
    ``k`` as the iteration count l for the iterative baselines, hence the
    renames). ``builds_backend`` marks the solvers that additionally consume
    the backend-selection fields (``backend`` / ``mesh`` / ``param_specs`` /
    ``sketch_dtype``) via ``HypergradConfig._build_backend()``. Any config
    field set to a non-default value that the chosen solver does not consume
    is an error at ``build()`` — never silently ignored."""
    cls: type
    fields: dict[str, str]
    builds_backend: bool = False


SOLVERS = {
    'nystrom': SolverSpec(NystromIHVP,
                          {'k': 'k', 'rho': 'rho', 'kappa': 'kappa',
                           'column_chunk': 'column_chunk',
                           'importance_sampling': 'importance_sampling',
                           'refine': 'refine', 'stabilized': 'stabilized'},
                          builds_backend=True),
    'cg': SolverSpec(CGIHVP, {'k': 'iters', 'rho': 'rho'}),
    'neumann': SolverSpec(NeumannIHVP, {'k': 'iters', 'alpha': 'alpha'}),
    'exact': SolverSpec(ExactIHVP, {'rho': 'rho'}),
}
