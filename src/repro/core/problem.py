"""BilevelProblem: one typed problem API from task definition to hypergradient.

The paper's claim is that the Nyström IHVP "works stably in various tasks"
(HPO, reweighting, distillation, meta-learning). This module is where a
*task* becomes a first-class object instead of a stringly-typed dict:

    problem = build_reweighting(imbalance=100)        # a BilevelProblem
    result  = solve(problem, HypergradConfig(solver='nystrom', k=10),
                    n_outer=40, sketch_refresh_every=5)
    result.metrics['accuracy'], result.hvp_count, result.seconds

One specification — ``inner_loss``/``outer_loss``/``init_params``/
``init_hparams``/``data`` (+ optional ``metrics``/``baseline_loss``/
``reference``) — consumed by one entry point. ``solve`` internally builds
the ``implicit_root`` solution map, the solver via the ``SOLVERS`` registry,
and a ``SketchPolicy`` (through :class:`~repro.core.bilevel.BilevelTrainer`),
so every workload gets the sketch-amortization knobs
(``sketch_refresh_every``, shared meta-batch sketches) for free.

Layers:

    BilevelProblem (this module)        what the task *is*
      └─ solve() / BilevelTrainer       how it is optimized (alternating or
         (bilevel.py)                   vmapped meta-batches)
           └─ implicit_root             how the hypergradient is assembled
                └─ solver protocol      how the IHVP is computed

``data`` is any :class:`BatchSource` (structural protocol below) — the
concrete sources over the synthetic loaders live in ``repro.data.sources``.
Meta-problems (iMAML) carry an episode source instead and are driven by
``solve(..., vmap_tasks=N)``: per-task hypergradients under ``jax.vmap``,
optionally sharing one sketch across the meta-batch
(``shared_sketch=True`` — k HVPs per meta-batch instead of per task).

Migration: builders in ``repro.tasks`` now return ``BilevelProblem``s. Old
dict consumers keep working for one release through the deprecated adapter —
``problem['inner']`` / ``problem.as_legacy_dict()`` emit a
``DeprecationWarning`` and map the old keys onto the typed fields.
"""
from __future__ import annotations

import dataclasses
import itertools
import math
import time
import warnings
from typing import Any, Callable, Protocol, runtime_checkable

import jax
import jax.numpy as jnp

from repro.core.bilevel import BilevelState, BilevelTrainer
from repro.core.hypergrad import HypergradConfig
from repro.core.implicit import implicit_root, sgd_solver
from repro.core.tree_util import PyTree
from repro.optim import adam, chain, clip_by_global_norm, momentum, sgd


@runtime_checkable
class BatchSource(Protocol):
    """Deterministic step-indexed batch streams (see ``repro.data.sources``).

    ``train_batch`` feeds the inner problem, ``val_batch`` the outer loss.
    Meta-problem sources raise from these and expose
    ``task_batch(step, n_tasks)`` instead (the ``vmap_tasks=`` path).
    """

    def train_batch(self, step: int, batch_size: int) -> Any: ...

    def val_batch(self, step: int, batch_size: int) -> Any: ...


# Training-hyperparameter defaults a problem may override via its
# ``defaults`` dict; ``solve()`` kwargs override both.
_TRAIN_DEFAULTS: dict[str, Any] = dict(
    inner_lr=0.1, inner_momentum=0.0, outer_lr=1e-3, outer_opt='adam',
    steps_per_outer=20, batch_size=128, reset_inner=False)

@dataclasses.dataclass
class BilevelProblem:
    """A typed bilevel task specification.

    ``inner_loss``/``outer_loss`` follow the repo-wide signature
    ``f(params, hparams, batch) -> scalar``. ``init_params`` and
    ``init_hparams`` both take an rng key (builders that used to take zero
    args are normalized — they simply ignore it). ``metrics`` maps a name to
    ``fn(params, hparams) -> float``, evaluated on the solved state by
    ``solve``. ``baseline_loss`` is the task's plain (hparam-free) training
    loss ``(params, batch) -> scalar`` where one exists — what a
    no-bilevel baseline run minimizes (tab4's baseline row). ``reference``
    holds task-specific extras (episode sampler, distilled labels, the
    underlying dataset object). ``defaults`` overrides ``solve``'s training
    hyperparameters (inner_lr, outer_opt, steps_per_outer, ...).
    """
    name: str
    inner_loss: Callable[..., jax.Array]
    outer_loss: Callable[..., jax.Array]
    init_params: Callable[[jax.Array], PyTree]
    init_hparams: Callable[[jax.Array], PyTree]
    data: BatchSource | None = None
    metrics: dict[str, Callable[..., float]] = dataclasses.field(
        default_factory=dict)
    baseline_loss: Callable[..., jax.Array] | None = None
    reference: dict[str, Any] = dataclasses.field(default_factory=dict)
    defaults: dict[str, Any] = dataclasses.field(default_factory=dict)

    # ------------------------------------------------- legacy dict adapter
    def _legacy_map(self) -> dict[str, Any]:
        d = {'inner': self.inner_loss, 'outer': self.outer_loss,
             'init_params': self.init_params,
             'init_hparams': self.init_hparams,
             # old dicts carried the raw dataset object under 'data'
             # (task['data'].X / .train_batch with its np.RandomState
             # stream) — keep that contract; the BatchSource is what *new*
             # code reaches via problem.data
             'data': self.reference.get('dataset', self.data)}
        for key in ('train', 'val'):
            if hasattr(self.data, key):
                d[key] = getattr(self.data, key)
        if 'accuracy' in self.metrics:
            acc = self.metrics['accuracy']
            d['accuracy'] = lambda params: acc(params, None)
        d.update(self.reference)
        return d

    def as_legacy_dict(self) -> dict[str, Any]:
        """The old ``repro.tasks`` dict shape, for unported call sites.

        Deprecated: new code should use the typed fields (and ``solve``)
        directly. Note ``init_hparams`` is the normalized rng-taking
        callable even for tasks whose legacy builder took zero args.
        """
        warnings.warn(
            f'as_legacy_dict() on problem {self.name!r} is deprecated; use '
            'the typed BilevelProblem fields / solve() instead',
            DeprecationWarning, stacklevel=2)
        return self._legacy_map()

    def __getitem__(self, key: str):
        legacy = self._legacy_map()
        if key not in legacy:
            raise KeyError(key)
        warnings.warn(
            f'task[{key!r}] dict access on problem {self.name!r} is '
            'deprecated; use the typed BilevelProblem fields / solve() '
            'instead', DeprecationWarning, stacklevel=2)
        return legacy[key]

    def __contains__(self, key: str) -> bool:
        return key in self._legacy_map()

    @classmethod
    def from_legacy_dict(cls, task: dict, name: str = 'legacy') -> \
            'BilevelProblem':
        """Adapt an old-style task dict (the pre-ISSUE-5 builder output)."""
        from repro.data.sources import ArraySource
        hp = task['init_hparams']
        if callable(hp) and hp.__code__.co_argcount == 0:
            init_hparams = lambda rng, _hp=hp: _hp()    # noqa: E731
        else:
            init_hparams = hp
        data = task.get('data')
        if data is None and 'train' in task:
            data = ArraySource(train=task['train'],
                               val=task.get('val', task['train']))
        metrics = {}
        if 'accuracy' in task:
            acc = task['accuracy']
            metrics['accuracy'] = lambda params, hparams: acc(params)
        reference = {k: v for k, v in task.items()
                     if k not in ('inner', 'outer', 'init_params',
                                  'init_hparams', 'data', 'train', 'val',
                                  'accuracy')}
        return cls(name=name, inner_loss=task['inner'],
                   outer_loss=task['outer'], init_params=task['init_params'],
                   init_hparams=init_hparams, data=data, metrics=metrics,
                   reference=reference)


@dataclasses.dataclass
class BilevelResult:
    """What ``solve`` hands back.

    ``hvp_count`` is the accounted number of Hessian-vector products the
    hypergradient machinery ran (sketch builds × k for amortizable solvers —
    honoring the refresh cadence and reset-invalidation — or outer steps ×
    iterations for CG/Neumann; the mixed-term VJPs are not HVPs and are not
    counted). ``seconds`` is measured wall time of the optimization loop.
    ``params`` is None on the ``vmap_tasks`` meta path, where the outer
    variable (``hparams``) is the meta-initialization and per-task adapted
    parameters are transient.
    """
    problem: str
    params: PyTree | None
    hparams: PyTree
    history: dict[str, list[float]]
    metrics: dict[str, float]
    hvp_count: int
    seconds: float
    state: BilevelState | None = None


# ---------------------------------------------------------------------------
# Registry
# ---------------------------------------------------------------------------
PROBLEMS: dict[str, Callable[..., BilevelProblem]] = {}


def register_problem(name: str):
    """Decorator: register a ``(**kwargs) -> BilevelProblem`` builder."""
    def deco(builder):
        PROBLEMS[name] = builder
        return builder
    return deco


def get_problem(name: str, **kwargs) -> BilevelProblem:
    """Build a registered problem by name (``launch/train.py --problem``)."""
    if name not in PROBLEMS:
        import repro.tasks  # noqa: F401  (registers the paper's builders)
    if name not in PROBLEMS:
        raise ValueError(f'unknown problem {name!r}; registered: '
                         f'{sorted(PROBLEMS)}')
    return PROBLEMS[name](**kwargs)


# ---------------------------------------------------------------------------
# Optimizer construction shared by solve() and BilevelTrainer.from_problem
# ---------------------------------------------------------------------------
def resolved_defaults(problem: BilevelProblem, **overrides) -> dict[str, Any]:
    """_TRAIN_DEFAULTS ← problem.defaults ← non-None solve() kwargs."""
    d = {**_TRAIN_DEFAULTS, **problem.defaults}
    d.update({k: v for k, v in overrides.items() if v is not None})
    return d


def default_optimizers(problem: BilevelProblem, d: dict | None = None):
    """(inner_opt, outer_opt) from the problem's resolved defaults.

    Mirrors the benchmark runner's construction: momentum/plain SGD inner,
    clipped Adam or SGD-momentum outer (hypergradient clipping is uniform
    outer-loop hygiene — Nyström's more-accurate IHVP takes larger raw steps
    than truncated CG/Neumann and needs the same guard rail).
    """
    d = resolved_defaults(problem) if d is None else d
    inner = (momentum(d['inner_lr'], d['inner_momentum'])
             if d['inner_momentum'] else sgd(d['inner_lr']))
    base = (adam(d['outer_lr']) if d['outer_opt'] == 'adam'
            else momentum(d['outer_lr'], 0.9))
    return inner, chain(clip_by_global_norm(10.0), base)


# ---------------------------------------------------------------------------
# HVP accounting
# ---------------------------------------------------------------------------
def _params_size(problem: BilevelProblem) -> int:
    shapes = jax.eval_shape(problem.init_params, jax.random.PRNGKey(0))
    return sum(int(math.prod(s.shape)) for s in jax.tree.leaves(shapes))


def accounted_hvps(solver, problem: BilevelProblem, n_outer: int,
                   refresh_every: int = 1, reset_inner: bool = False,
                   vmap_tasks: int | None = None,
                   shared_sketch: bool = False) -> int:
    """HVPs the hypergradient machinery runs over ``n_outer`` outer steps.

    Amortizable solvers pay per sketch *build*: ``k`` HVPs (Nyström) or
    ``p`` (exact). Builds follow the lifecycle — every
    ``refresh_every``-th step, every step under ``reset_inner`` (the policy
    invalidates after each reset), per task per meta-step on the vmapped
    path unless ``shared_sketch``. Iterative solvers pay their iteration
    count in *sequential* HVPs on every apply. The same arithmetic tab3's
    shared-sketch row quotes (k vs tasks × k per meta-batch), available
    uniformly so every benchmark can emit an HVP-count column.
    """
    amortizable = getattr(type(solver), 'amortizable', False)
    if amortizable:
        per_build = getattr(solver, 'k', None)
        if per_build is None:                    # ExactIHVP: full column scan
            per_build = _params_size(problem)
        if vmap_tasks:
            per_step = per_build if shared_sketch else vmap_tasks * per_build
            return n_outer * per_step
        builds = (n_outer if reset_inner
                  else math.ceil(n_outer / max(1, refresh_every)))
        return builds * per_build
    iters = getattr(solver, 'iters', 0)
    return n_outer * iters * (vmap_tasks or 1)


# ---------------------------------------------------------------------------
# solve() — the single entry point
# ---------------------------------------------------------------------------
def solve(problem: BilevelProblem, config: HypergradConfig | Any = None, *,
          n_outer: int, steps_per_outer: int | None = None,
          batch_size: int | None = None, inner_opt=None, outer_opt=None,
          reset_inner: bool | None = None, seed: int = 0,
          sketch_refresh_every: int | None = None,
          vmap_tasks: int | None = None, shared_sketch: bool = False,
          log_every: int = 0, jit: bool = True) -> BilevelResult:
    """Optimize a :class:`BilevelProblem` end to end → :class:`BilevelResult`.

    Two drive modes:

    * default — the alternating warm-start loop: ``steps_per_outer`` inner
      optimizer steps per hypergradient update, batches drawn from
      ``problem.data``'s train/val streams, the sketch lifecycle handled by
      the trainer's :class:`~repro.core.solvers.SketchPolicy`
      (``sketch_refresh_every`` overrides the config's cadence; N > 1
      amortizes one sketch over N outer steps).
    * ``vmap_tasks=N`` — meta-batched: each outer step draws N tasks from
      ``problem.data.task_batch``, adapts each with ``steps_per_outer``
      inner-SGD steps from the meta-init (φ), and averages the N per-task
      hypergradients computed under one ``jax.vmap``.
      ``shared_sketch=True`` prepares one sketch at the meta-init on the
      pooled support data and broadcasts it to every task's backward pass —
      k HVPs per meta-batch instead of per task.

    ``config`` is a :class:`HypergradConfig` (or a built solver instance, or
    None for the default Nyström configuration). Training hyperparameters
    (``inner_opt``/``outer_opt``/``steps_per_outer``/``batch_size``/
    ``reset_inner``) default from ``problem.defaults``.
    """
    if config is None:
        config = HypergradConfig()
    d = resolved_defaults(problem, steps_per_outer=steps_per_outer,
                          batch_size=batch_size, reset_inner=reset_inner)
    solver = (config.build() if isinstance(config, HypergradConfig)
              else config)
    if vmap_tasks:
        if not hasattr(problem.data, 'task_batch'):
            raise TypeError(
                f'solve(vmap_tasks={vmap_tasks}) needs a meta-problem data '
                'source exposing task_batch(step, n_tasks) (e.g. '
                f'EpisodeSource); problem {problem.name!r} carries '
                f'{type(problem.data).__name__}')
        return _solve_meta(problem, solver, d, n_outer=n_outer,
                           vmap_tasks=vmap_tasks, shared_sketch=shared_sketch,
                           outer_opt=outer_opt, seed=seed,
                           log_every=log_every, jit=jit)

    d_inner, d_outer = default_optimizers(problem, d)
    trainer = BilevelTrainer.from_problem(
        problem, config, inner_opt=inner_opt or d_inner,
        outer_opt=outer_opt or d_outer, reset_inner=d['reset_inner'])
    rng = jax.random.PRNGKey(seed)
    state = trainer.init(rng, problem.init_params(rng),
                         problem.init_hparams(rng))

    bs = d['batch_size']
    train_it = (problem.data.train_batch(i, bs) for i in itertools.count())
    val_it = (problem.data.val_batch(i, bs) for i in itertools.count())

    t0 = time.time()
    state, history = trainer.run(
        state, train_it, val_it, steps_per_outer=d['steps_per_outer'],
        n_outer=n_outer, log_every=log_every, jit=jit,
        sketch_refresh_every=sketch_refresh_every)
    seconds = time.time() - t0

    refresh = (sketch_refresh_every if sketch_refresh_every is not None
               else (config.sketch_refresh_every
                     if isinstance(config, HypergradConfig) else 1))
    hvps = accounted_hvps(solver, problem, n_outer, refresh_every=refresh,
                          reset_inner=d['reset_inner'])
    metrics = {name: float(fn(state.params, state.hparams))
               for name, fn in problem.metrics.items()}
    return BilevelResult(problem=problem.name, params=state.params,
                         hparams=state.hparams, history=history,
                         metrics=metrics, hvp_count=hvps, seconds=seconds,
                         state=state)


def _solve_meta(problem: BilevelProblem, solver, d: dict, *, n_outer: int,
                vmap_tasks: int, shared_sketch: bool, outer_opt, seed: int,
                log_every: int, jit: bool) -> BilevelResult:
    """The ``vmap_tasks=`` meta-batch drive mode (iMAML-style problems)."""
    adapt = sgd_solver(problem.inner_loss, d['steps_per_outer'],
                       d['inner_lr'])
    solution = implicit_root(adapt, problem.inner_loss, solver)
    shared = shared_sketch and getattr(type(solver), 'amortizable', False)
    if shared_sketch and not shared:
        raise TypeError(
            f'shared_sketch needs an amortizable solver; '
            f'{type(solver).__name__} prepares a trace-local state that '
            'cannot be broadcast across the meta-batch')
    if outer_opt is None:
        outer_opt = (adam(d['outer_lr']) if d['outer_opt'] == 'adam'
                     else momentum(d['outer_lr'], 0.9))

    def meta_step(meta, ost, inner_b, outer_b, keys, step):
        if shared:
            # one sketch at the meta-init on the pooled support data,
            # broadcast to every task's backward pass: k HVPs per meta-batch
            pooled = jax.tree.map(lambda x: x.reshape((-1,) + x.shape[2:]),
                                  inner_b)
            sketch = solution.prepare_state(meta, meta, pooled, keys[0])

            def task_vg(ib, ob):
                def obj(m):
                    return problem.outer_loss(
                        solution(m, ib, state=sketch), m, ob)
                return jax.value_and_grad(obj)(meta)

            losses, hg = jax.vmap(task_vg)(inner_b, outer_b)
        else:
            def task_vg(ib, ob, key):
                def obj(m):
                    return problem.outer_loss(
                        solution(m, ib, rng=key), m, ob)
                return jax.value_and_grad(obj)(meta)

            losses, hg = jax.vmap(task_vg)(inner_b, outer_b, keys)
        hg = jax.tree.map(lambda x: x.mean(0), hg)
        meta, ost = outer_opt.apply(hg, ost, meta, step)
        return meta, ost, losses.mean()

    step_fn = jax.jit(meta_step) if jit else meta_step
    rng = jax.random.PRNGKey(seed)
    meta = problem.init_hparams(rng)
    ost = outer_opt.init(meta)
    history: dict[str, list[float]] = {'outer_loss': [], 'inner_loss': []}
    pending = []
    t0 = time.time()
    for s in range(n_outer):
        inner_b, outer_b = problem.data.task_batch(s, vmap_tasks)
        keys = jax.random.split(jax.random.fold_in(rng, s), vmap_tasks)
        meta, ost, loss = step_fn(meta, ost, inner_b, outer_b, keys,
                                  jnp.int32(s))
        pending.append(loss)
        if log_every and (s + 1) % log_every == 0:
            history['outer_loss'].extend(float(x) for x in pending)
            pending.clear()
            print(f'[solve:{problem.name}] meta-step {s + 1}/{n_outer} '
                  f'g={history["outer_loss"][-1]:.4f} (pre-update, '
                  f'{vmap_tasks} tasks)')
    history['outer_loss'].extend(float(x) for x in pending)
    seconds = time.time() - t0

    hvps = accounted_hvps(solver, problem, n_outer, vmap_tasks=vmap_tasks,
                          shared_sketch=shared)
    metrics = {name: float(fn(None, meta))
               for name, fn in problem.metrics.items()}
    return BilevelResult(problem=problem.name, params=None, hparams=meta,
                         history=history, metrics=metrics, hvp_count=hvps,
                         seconds=seconds)
