"""BilevelProblem: one typed problem API from task definition to hypergradient.

The paper's claim is that the Nyström IHVP "works stably in various tasks"
(HPO, reweighting, distillation, meta-learning). This module is where a
*task* becomes a first-class object instead of a stringly-typed dict:

    problem = build_reweighting(imbalance=100)        # a BilevelProblem
    result  = solve(problem, HypergradConfig(solver='nystrom', k=10),
                    n_outer=40, sketch_refresh_every=5)
    result.metrics['accuracy'], result.hvp_count, result.seconds

One specification — ``inner_loss``/``outer_loss``/``init_params``/
``init_hparams``/``data`` (+ optional ``metrics``/``baseline_loss``/
``reference``) — consumed by one entry point. ``solve`` internally builds
the ``implicit_root`` solution map, the solver via the ``SOLVERS`` registry,
and a ``SketchPolicy`` (through :class:`~repro.core.bilevel.BilevelTrainer`),
so every workload gets the sketch-amortization knobs
(``sketch_refresh_every``, shared meta-batch sketches) for free.

Layers:

    BilevelProblem (this module)        what the task *is*
      └─ solve() / BilevelTrainer       how it is optimized (alternating or
         (bilevel.py)                   vmapped meta-batches)
           └─ implicit_root             how the hypergradient is assembled
                └─ solver protocol      how the IHVP is computed

``data`` is any :class:`BatchSource` (structural protocol below) — the
concrete sources over the synthetic loaders live in ``repro.data.sources``.
Meta-problems (iMAML) carry an episode source instead and are driven by
``solve(..., vmap_tasks=N)``: per-task hypergradients under ``jax.vmap``,
optionally sharing one sketch across the meta-batch
(``shared_sketch=True`` — k HVPs per meta-batch instead of per task).

The module also hosts the influence-function service built on the
matrix-valued apply path: an :class:`InfluenceProblem` is a *single-level*
training problem (loss + params + data), and :func:`influence` scores every
training example against a block of m query examples with ONE prepared
sketch — the per-query IHVPs ride ``solver.apply_matrix`` as a (p, m) block,
and the scores stream over the training set (running top-k, never a full
n_train × m score matrix in memory).
"""
from __future__ import annotations

import dataclasses
import itertools
import math
import time
from typing import Any, Callable, Protocol, runtime_checkable

import jax
import jax.numpy as jnp

from repro.core.bilevel import BilevelState, BilevelTrainer
from repro.core.hypergrad import HypergradConfig
from repro.core.implicit import implicit_root, sgd_solver
from repro.core.tree_util import PyTree
from repro.optim import adam, chain, clip_by_global_norm, momentum, sgd


@runtime_checkable
class BatchSource(Protocol):
    """Deterministic step-indexed batch streams (see ``repro.data.sources``).

    ``train_batch`` feeds the inner problem, ``val_batch`` the outer loss.
    Meta-problem sources raise from these and expose
    ``task_batch(step, n_tasks)`` instead (the ``vmap_tasks=`` path).
    """

    def train_batch(self, step: int, batch_size: int) -> Any: ...

    def val_batch(self, step: int, batch_size: int) -> Any: ...


# Training-hyperparameter defaults a problem may override via its
# ``defaults`` dict; ``solve()`` kwargs override both.
_TRAIN_DEFAULTS: dict[str, Any] = dict(
    inner_lr=0.1, inner_momentum=0.0, outer_lr=1e-3, outer_opt='adam',
    steps_per_outer=20, batch_size=128, reset_inner=False)

@dataclasses.dataclass
class BilevelProblem:
    """A typed bilevel task specification.

    ``inner_loss``/``outer_loss`` follow the repo-wide signature
    ``f(params, hparams, batch) -> scalar``. ``init_params`` and
    ``init_hparams`` both take an rng key (builders that used to take zero
    args are normalized — they simply ignore it). ``metrics`` maps a name to
    ``fn(params, hparams) -> float``, evaluated on the solved state by
    ``solve``. ``baseline_loss`` is the task's plain (hparam-free) training
    loss ``(params, batch) -> scalar`` where one exists — what a
    no-bilevel baseline run minimizes (tab4's baseline row). ``reference``
    holds task-specific extras (episode sampler, distilled labels, the
    underlying dataset object). ``defaults`` overrides ``solve``'s training
    hyperparameters (inner_lr, outer_opt, steps_per_outer, ...).
    """
    name: str
    inner_loss: Callable[..., jax.Array]
    outer_loss: Callable[..., jax.Array]
    init_params: Callable[[jax.Array], PyTree]
    init_hparams: Callable[[jax.Array], PyTree]
    data: BatchSource | None = None
    metrics: dict[str, Callable[..., float]] = dataclasses.field(
        default_factory=dict)
    baseline_loss: Callable[..., jax.Array] | None = None
    reference: dict[str, Any] = dataclasses.field(default_factory=dict)
    defaults: dict[str, Any] = dataclasses.field(default_factory=dict)


@dataclasses.dataclass
class BilevelResult:
    """What ``solve`` hands back.

    ``hvp_count`` is the accounted number of Hessian-vector products the
    hypergradient machinery ran (sketch builds × k for amortizable solvers —
    honoring the refresh cadence and reset-invalidation — or outer steps ×
    iterations for CG/Neumann; the mixed-term VJPs are not HVPs and are not
    counted). ``seconds`` is measured wall time of the optimization loop.
    ``params`` is None on the ``vmap_tasks`` meta path, where the outer
    variable (``hparams``) is the meta-initialization and per-task adapted
    parameters are transient.
    """
    problem: str
    params: PyTree | None
    hparams: PyTree
    history: dict[str, list[float]]
    metrics: dict[str, float]
    hvp_count: int
    seconds: float
    state: BilevelState | None = None
    hypergrad_error: float | None = None    # vs the exact-IHVP oracle, when
    #   requested via solve(with_hypergrad_error=True); None otherwise


# ---------------------------------------------------------------------------
# Registry
# ---------------------------------------------------------------------------
PROBLEMS: dict[str, Callable[..., BilevelProblem]] = {}


def register_problem(name: str):
    """Decorator: register a ``(**kwargs) -> BilevelProblem`` builder."""
    def deco(builder):
        PROBLEMS[name] = builder
        return builder
    return deco


def get_problem(name: str, **kwargs) -> BilevelProblem:
    """Build a registered problem by name (``launch/train.py --problem``)."""
    if name not in PROBLEMS:
        import repro.tasks  # noqa: F401  (registers the paper's builders)
    if name not in PROBLEMS:
        raise ValueError(f'unknown problem {name!r}; registered: '
                         f'{sorted(PROBLEMS)}')
    return PROBLEMS[name](**kwargs)


# ---------------------------------------------------------------------------
# Optimizer construction shared by solve() and BilevelTrainer.from_problem
# ---------------------------------------------------------------------------
def resolved_defaults(problem: BilevelProblem, **overrides) -> dict[str, Any]:
    """_TRAIN_DEFAULTS ← problem.defaults ← non-None solve() kwargs."""
    d = {**_TRAIN_DEFAULTS, **problem.defaults}
    d.update({k: v for k, v in overrides.items() if v is not None})
    return d


def default_optimizers(problem: BilevelProblem, d: dict | None = None):
    """(inner_opt, outer_opt) from the problem's resolved defaults.

    Mirrors the benchmark runner's construction: momentum/plain SGD inner,
    clipped Adam or SGD-momentum outer (hypergradient clipping is uniform
    outer-loop hygiene — Nyström's more-accurate IHVP takes larger raw steps
    than truncated CG/Neumann and needs the same guard rail).
    """
    d = resolved_defaults(problem) if d is None else d
    inner = (momentum(d['inner_lr'], d['inner_momentum'])
             if d['inner_momentum'] else sgd(d['inner_lr']))
    base = (adam(d['outer_lr']) if d['outer_opt'] == 'adam'
            else momentum(d['outer_lr'], 0.9))
    return inner, chain(clip_by_global_norm(10.0), base)


# ---------------------------------------------------------------------------
# HVP accounting
# ---------------------------------------------------------------------------
def _params_size(problem: BilevelProblem) -> int:
    shapes = jax.eval_shape(problem.init_params, jax.random.PRNGKey(0))
    return sum(int(math.prod(s.shape)) for s in jax.tree.leaves(shapes))


def accounted_hvps(solver, problem: BilevelProblem, n_outer: int,
                   refresh_every: int = 1, reset_inner: bool = False,
                   vmap_tasks: int | None = None,
                   shared_sketch: bool = False) -> int:
    """HVPs the hypergradient machinery runs over ``n_outer`` outer steps.

    Amortizable solvers pay per sketch *build*: ``k`` HVPs (Nyström) or
    ``p`` (exact). Builds follow the lifecycle — every
    ``refresh_every``-th step, every step under ``reset_inner`` (the policy
    invalidates after each reset), per task per meta-step on the vmapped
    path unless ``shared_sketch``. Iterative solvers pay their iteration
    count in *sequential* HVPs on every apply. The same arithmetic tab3's
    shared-sketch row quotes (k vs tasks × k per meta-batch), available
    uniformly so every benchmark can emit an HVP-count column.
    """
    amortizable = getattr(type(solver), 'amortizable', False)
    if amortizable:
        per_build = getattr(solver, 'k', None)
        if per_build is None:                    # ExactIHVP: full column scan
            per_build = _params_size(problem)
        if vmap_tasks:
            per_step = per_build if shared_sketch else vmap_tasks * per_build
            return n_outer * per_step
        builds = (n_outer if reset_inner
                  else math.ceil(n_outer / max(1, refresh_every)))
        return builds * per_build
    iters = getattr(solver, 'iters', 0)
    return n_outer * iters * (vmap_tasks or 1)


# ---------------------------------------------------------------------------
# Hypergradient oracle — solver accuracy against the exact IHVP
# ---------------------------------------------------------------------------
def hypergrad_at(problem: BilevelProblem, config: HypergradConfig | Any,
                 params: PyTree, hparams: PyTree, inner_batch: Any,
                 outer_batch: Any, *, rng: jax.Array | None = None) -> PyTree:
    """One implicit hypergradient at an explicit linearization point.

    Treats ``params`` as the (already-computed) inner solution θ* and
    differentiates ``outer_loss(θ*(φ), φ)`` through ``implicit_root`` with
    the given solver — the same backward pass ``solve`` runs, isolated to a
    single evaluation so solvers can be compared at an identical
    (θ*, φ, batch) point. ``config`` is a :class:`HypergradConfig` or a
    built solver instance; ``rng`` seeds the sketch-column sampling.

    This is the measurement primitive of the solver observatory
    (``benchmarks/observatory.py``): per-cell error is
    ``hypergrad_error(hypergrad_at(...), hypergrad_reference(...))``.
    Vmappable — stacking (params, hparams, batches, rng) over a leading
    task axis measures a whole problem population in one program.
    """
    if config is None:
        config = HypergradConfig()
    solver = (config.build() if isinstance(config, HypergradConfig)
              else config)
    if rng is None:
        rng = jax.random.PRNGKey(0)
    solution = implicit_root(lambda phi, batch: params, problem.inner_loss,
                             solver)

    def obj(phi):
        theta = solution(phi, inner_batch, rng=rng)
        return problem.outer_loss(theta, phi, outer_batch)

    return jax.grad(obj)(hparams)


def hypergrad_reference(problem: BilevelProblem, params: PyTree,
                        hparams: PyTree, inner_batch: Any, outer_batch: Any,
                        *, rho: float = 0.0) -> PyTree:
    """Exact-IHVP oracle hypergradient at (``params``, ``hparams``).

    Materializes the full inner Hessian (p HVPs) and dense-solves — the
    ground truth every approximate solver is scored against. ``rho=0.0``
    (default) is the true implicit hypergradient; pass the solver's damping
    to isolate sketch/truncation error from damping bias. Test-scale
    problems only (cost is p HVPs + one p×p factorization).
    """
    from repro.core.solvers import ExactIHVP
    return hypergrad_at(problem, ExactIHVP(rho=rho), params, hparams,
                        inner_batch, outer_batch)


def hypergrad_error(hg: PyTree, reference: PyTree) -> jax.Array:
    """Relative L2 error ‖hg − ref‖ / ‖ref‖ over the flattened pytrees
    (f32 accumulation; guards a zero-norm reference)."""
    from repro.core.tree_util import tree_norm
    num = tree_norm(jax.tree.map(lambda a, b: a.astype(jnp.float32)
                                 - b.astype(jnp.float32), hg, reference))
    den = tree_norm(jax.tree.map(lambda b: b.astype(jnp.float32), reference))
    return num / jnp.maximum(den, 1e-30)


# ---------------------------------------------------------------------------
# solve() — the single entry point
# ---------------------------------------------------------------------------
def solve(problem: BilevelProblem, config: HypergradConfig | Any = None, *,
          n_outer: int, steps_per_outer: int | None = None,
          batch_size: int | None = None, inner_opt=None, outer_opt=None,
          reset_inner: bool | None = None, seed: int = 0,
          sketch_refresh_every: int | None = None,
          vmap_tasks: int | None = None, shared_sketch: bool = False,
          log_every: int = 0, jit: bool = True,
          with_hypergrad_error: bool = False,
          oracle_rho: float = 0.0) -> BilevelResult:
    """Optimize a :class:`BilevelProblem` end to end → :class:`BilevelResult`.

    Two drive modes:

    * default — the alternating warm-start loop: ``steps_per_outer`` inner
      optimizer steps per hypergradient update, batches drawn from
      ``problem.data``'s train/val streams, the sketch lifecycle handled by
      the trainer's :class:`~repro.core.solvers.SketchPolicy`
      (``sketch_refresh_every`` overrides the config's cadence; N > 1
      amortizes one sketch over N outer steps).
    * ``vmap_tasks=N`` — meta-batched: each outer step draws N tasks from
      ``problem.data.task_batch``, adapts each with ``steps_per_outer``
      inner-SGD steps from the meta-init (φ), and averages the N per-task
      hypergradients computed under one ``jax.vmap``.
      ``shared_sketch=True`` prepares one sketch at the meta-init on the
      pooled support data and broadcasts it to every task's backward pass —
      k HVPs per meta-batch instead of per task.

    ``config`` is a :class:`HypergradConfig` (or a built solver instance, or
    None for the default Nyström configuration). Training hyperparameters
    (``inner_opt``/``outer_opt``/``steps_per_outer``/``batch_size``/
    ``reset_inner``) default from ``problem.defaults``.

    ``with_hypergrad_error=True`` additionally scores the configured solver
    against the exact-IHVP oracle at the *solved* state (one extra
    hypergradient with each, on fresh step-``n_outer`` batches — p oracle
    HVPs, so test-scale problems only) and records the relative error on
    ``BilevelResult.hypergrad_error``; ``oracle_rho`` is the oracle's
    damping (0.0 = the true implicit hypergradient). Not available on the
    ``vmap_tasks`` meta path, whose per-task adapted parameters are
    transient.
    """
    if config is None:
        config = HypergradConfig()
    if with_hypergrad_error and vmap_tasks:
        raise ValueError(
            'with_hypergrad_error is not supported on the vmap_tasks meta '
            'path (per-task adapted parameters are transient); measure via '
            'repro.bench.observatory, which owns the population axis')
    d = resolved_defaults(problem, steps_per_outer=steps_per_outer,
                          batch_size=batch_size, reset_inner=reset_inner)
    solver = (config.build() if isinstance(config, HypergradConfig)
              else config)
    if vmap_tasks:
        if not hasattr(problem.data, 'task_batch'):
            raise TypeError(
                f'solve(vmap_tasks={vmap_tasks}) needs a meta-problem data '
                'source exposing task_batch(step, n_tasks) (e.g. '
                f'EpisodeSource); problem {problem.name!r} carries '
                f'{type(problem.data).__name__}')
        return _solve_meta(problem, solver, d, n_outer=n_outer,
                           vmap_tasks=vmap_tasks, shared_sketch=shared_sketch,
                           outer_opt=outer_opt, seed=seed,
                           log_every=log_every, jit=jit)

    d_inner, d_outer = default_optimizers(problem, d)
    trainer = BilevelTrainer.from_problem(
        problem, config, inner_opt=inner_opt or d_inner,
        outer_opt=outer_opt or d_outer, reset_inner=d['reset_inner'])
    rng = jax.random.PRNGKey(seed)
    state = trainer.init(rng, problem.init_params(rng),
                         problem.init_hparams(rng))

    bs = d['batch_size']
    train_it = (problem.data.train_batch(i, bs) for i in itertools.count())
    val_it = (problem.data.val_batch(i, bs) for i in itertools.count())

    t0 = time.time()
    state, history = trainer.run(
        state, train_it, val_it, steps_per_outer=d['steps_per_outer'],
        n_outer=n_outer, log_every=log_every, jit=jit,
        sketch_refresh_every=sketch_refresh_every)
    seconds = time.time() - t0

    refresh = (sketch_refresh_every if sketch_refresh_every is not None
               else (config.sketch_refresh_every
                     if isinstance(config, HypergradConfig) else 1))
    hvps = accounted_hvps(solver, problem, n_outer, refresh_every=refresh,
                          reset_inner=d['reset_inner'])
    metrics = {name: float(fn(state.params, state.hparams))
               for name, fn in problem.metrics.items()}
    hg_err = None
    if with_hypergrad_error:
        ib = problem.data.train_batch(n_outer, bs)
        ob = problem.data.val_batch(n_outer, bs)
        hg = hypergrad_at(problem, solver, state.params, state.hparams,
                          ib, ob, rng=jax.random.fold_in(rng, n_outer))
        ref = hypergrad_reference(problem, state.params, state.hparams,
                                  ib, ob, rho=oracle_rho)
        hg_err = float(hypergrad_error(hg, ref))
    return BilevelResult(problem=problem.name, params=state.params,
                         hparams=state.hparams, history=history,
                         metrics=metrics, hvp_count=hvps, seconds=seconds,
                         state=state, hypergrad_error=hg_err)


def _solve_meta(problem: BilevelProblem, solver, d: dict, *, n_outer: int,
                vmap_tasks: int, shared_sketch: bool, outer_opt, seed: int,
                log_every: int, jit: bool) -> BilevelResult:
    """The ``vmap_tasks=`` meta-batch drive mode (iMAML-style problems)."""
    adapt = sgd_solver(problem.inner_loss, d['steps_per_outer'],
                       d['inner_lr'])
    solution = implicit_root(adapt, problem.inner_loss, solver)
    shared = shared_sketch and getattr(type(solver), 'amortizable', False)
    if shared_sketch and not shared:
        raise TypeError(
            f'shared_sketch needs an amortizable solver; '
            f'{type(solver).__name__} prepares a trace-local state that '
            'cannot be broadcast across the meta-batch')
    if outer_opt is None:
        outer_opt = (adam(d['outer_lr']) if d['outer_opt'] == 'adam'
                     else momentum(d['outer_lr'], 0.9))

    def meta_step(meta, ost, inner_b, outer_b, keys, step):
        if shared:
            # one sketch at the meta-init on the pooled support data,
            # broadcast to every task's backward pass: k HVPs per meta-batch
            pooled = jax.tree.map(lambda x: x.reshape((-1,) + x.shape[2:]),
                                  inner_b)
            sketch = solution.prepare_state(meta, meta, pooled, keys[0])

            def task_vg(ib, ob):
                def obj(m):
                    return problem.outer_loss(
                        solution(m, ib, state=sketch), m, ob)
                return jax.value_and_grad(obj)(meta)

            losses, hg = jax.vmap(task_vg)(inner_b, outer_b)
        else:
            def task_vg(ib, ob, key):
                def obj(m):
                    return problem.outer_loss(
                        solution(m, ib, rng=key), m, ob)
                return jax.value_and_grad(obj)(meta)

            losses, hg = jax.vmap(task_vg)(inner_b, outer_b, keys)
        hg = jax.tree.map(lambda x: x.mean(0), hg)
        meta, ost = outer_opt.apply(hg, ost, meta, step)
        return meta, ost, losses.mean()

    step_fn = jax.jit(meta_step) if jit else meta_step
    rng = jax.random.PRNGKey(seed)
    meta = problem.init_hparams(rng)
    ost = outer_opt.init(meta)
    history: dict[str, list[float]] = {'outer_loss': [], 'inner_loss': []}
    pending = []
    t0 = time.time()
    for s in range(n_outer):
        inner_b, outer_b = problem.data.task_batch(s, vmap_tasks)
        keys = jax.random.split(jax.random.fold_in(rng, s), vmap_tasks)
        meta, ost, loss = step_fn(meta, ost, inner_b, outer_b, keys,
                                  jnp.int32(s))
        pending.append(loss)
        if log_every and (s + 1) % log_every == 0:
            history['outer_loss'].extend(float(x) for x in pending)
            pending.clear()
            print(f'[solve:{problem.name}] meta-step {s + 1}/{n_outer} '
                  f'g={history["outer_loss"][-1]:.4f} (pre-update, '
                  f'{vmap_tasks} tasks)')
    history['outer_loss'].extend(float(x) for x in pending)
    seconds = time.time() - t0

    hvps = accounted_hvps(solver, problem, n_outer, vmap_tasks=vmap_tasks,
                          shared_sketch=shared)
    metrics = {name: float(fn(None, meta))
               for name, fn in problem.metrics.items()}
    return BilevelResult(problem=problem.name, params=None, hparams=meta,
                         history=history, metrics=metrics, hvp_count=hvps,
                         seconds=seconds)


# ---------------------------------------------------------------------------
# Influence functions — the matrix-valued apply path as a service
# ---------------------------------------------------------------------------
@dataclasses.dataclass
class InfluenceProblem:
    """A single-level training problem posed for influence-function queries.

    Unlike :class:`BilevelProblem` there is no outer loss and no hparams —
    just ``loss(params, batch) -> scalar`` (mean over the batch's leading
    axis), an ``init_params(rng)``, and a ``data`` source. The source must
    expose the ordered-streaming protocol (``n_train`` /
    ``train_slice(start, size)``, see ``repro.data.sources.ArraySource``) in
    addition to the step-indexed ``train_batch`` used for training.
    ``defaults`` may override ``influence``'s training hyperparameters
    (``inner_lr``, ``batch_size``, ``train_steps``).
    """
    name: str
    loss: Callable[..., jax.Array]
    init_params: Callable[[jax.Array], PyTree]
    data: Any = None
    defaults: dict[str, Any] = dataclasses.field(default_factory=dict)
    reference: dict[str, Any] = dataclasses.field(default_factory=dict)


@dataclasses.dataclass
class InfluenceResult:
    """``influence``'s output: per-query top-k training examples.

    ``scores`` is (m, top_k) — s(q, i) = −∇L(q)ᵀ (H+ρI)⁻¹ ∇L(zᵢ), sorted
    descending per query — and ``indices`` the matching (m, top_k) global
    training-example indices. ``self_scores`` (m,) is the queries' own
    ∇L(q)ᵀ (H+ρI)⁻¹ ∇L(q) when requested. ``hvp_count`` follows the same
    accounting as :class:`BilevelResult` — k sketch HVPs total, amortized
    over all m queries and the whole training sweep.
    """
    problem: str
    scores: jax.Array
    indices: jax.Array
    self_scores: jax.Array | None
    params: PyTree
    hvp_count: int
    seconds: float


def _per_example_grads(loss, params, batch):
    """(b,)+param-shaped gradient stack: each example re-batched to size 1 so
    ``loss``'s mean-over-batch contract holds per example."""
    def one(ex):
        return jax.grad(lambda p: loss(p, jax.tree.map(
            lambda x: x[None], ex)))(params)
    return jax.vmap(one)(batch)


def make_topk_scanner(loss, params, source, batch_size: int):
    """The streamed top-k scorer, factored so it amortizes across calls.

    Returns ``scan(S, top_k) -> (vals, idxs)``: given the solved query block
    S = (H+ρI)⁻¹∇L(q) (a param pytree with a trailing (m,) axis), sweeps the
    ordered training stream in ``batch_size`` slices, folds each (m, b)
    influence tile into a running ``jax.lax.top_k`` merge, and returns the
    (m, top_k) descending scores plus matching global indices — the full
    n_train × m score matrix never materializes.

    The jitted tile/merge kernels close over (loss, params, source) ONCE and
    take S as an argument, so a long-lived consumer — the influence *service*
    (``repro.serve``), which answers many query flushes against one trained
    model — pays tracing/compilation per block *width*, not per call.
    ``influence()`` drives the same scanner for its one-shot path.
    """
    @jax.jit
    def score_tile(S, batch):
        """(m, b) influence tile for one ordered training slice."""
        G = _per_example_grads(loss, params, batch)
        parts = jax.tree.leaves(jax.tree.map(
            lambda s, g: jnp.einsum('...m,b...->mb', s.astype(jnp.float32),
                                    g.astype(jnp.float32)), S, G))
        return -sum(parts)

    @jax.jit
    def merge(vals, idxs, tile, base):
        m, b = tile.shape
        gidx = base + jnp.broadcast_to(jnp.arange(b, dtype=jnp.int32), (m, b))
        cand_v = jnp.concatenate([vals, tile], axis=1)
        cand_i = jnp.concatenate([idxs, gidx], axis=1)
        v, sel = jax.lax.top_k(cand_v, vals.shape[1])
        return v, jnp.take_along_axis(cand_i, sel, axis=1)

    n = source.n_train

    def scan(S, top_k: int):
        m = jax.tree.leaves(S)[0].shape[-1]
        kk = min(top_k, n)
        vals = jnp.full((m, kk), -jnp.inf, jnp.float32)
        idxs = jnp.full((m, kk), -1, jnp.int32)
        for start in range(0, n, batch_size):
            batch = source.train_slice(start, batch_size)
            vals, idxs = merge(vals, idxs, score_tile(S, batch),
                               jnp.int32(start))
        return vals, idxs

    return scan


def train_influence_params(problem: InfluenceProblem, *,
                           train_steps: int | None = None,
                           batch_size: int | None = None,
                           seed: int = 0) -> PyTree:
    """Plain-SGD training of an :class:`InfluenceProblem`'s model — the
    params every influence query is scored at. Factored out of
    :func:`influence` so long-lived consumers (the serving tier, benchmark
    sweeps) train once and share the result across many calls."""
    from repro.optim import sgd
    d = {**_TRAIN_DEFAULTS, **problem.defaults}
    bs = batch_size if batch_size is not None else d['batch_size']
    steps = (train_steps if train_steps is not None
             else d.get('train_steps', 200))
    params = problem.init_params(jax.random.PRNGKey(seed))
    opt = sgd(d['inner_lr'])
    ost = opt.init(params)

    @jax.jit
    def train_step(p, s, b, i):
        g = jax.grad(problem.loss)(p, b)
        return opt.apply(g, s, p, i)

    for i in range(steps):
        params, ost = train_step(params, ost,
                                 problem.data.train_batch(i, bs),
                                 jnp.int32(i))
    return params


def influence_curvature_hvp(problem: InfluenceProblem, params: PyTree,
                            source: Any, batch_size: int):
    """The curvature HVP every influence apply solves against: the loss
    Hessian at ``params`` over one large ordered training slice (shared by
    :func:`influence` and the serving tier so both linearize identically)."""
    from repro.core.hvp import make_hvp
    n = source.n_train
    curv = source.train_slice(0, min(n, max(batch_size, 1024)))
    return make_hvp(lambda p, hp, b: problem.loss(p, b), params, None, curv)


def influence_build_hvps(solver, params: PyTree) -> int:
    """HVPs one state build bills: k (Nyström) or p (exact column scan).
    Delegates to :func:`repro.core.solvers.build_hvp_bill` — the ONE bill
    definition shared with the engine's per-edge accounting, so influence
    and engine ``hvp_count`` are comparable by construction."""
    from repro.core.solvers import build_hvp_bill
    return build_hvp_bill(solver, params)


def influence(problem: InfluenceProblem, config: HypergradConfig | Any = None,
              queries: Any = None, source: Any = None, *,
              params: PyTree | None = None, top_k: int = 10,
              batch_size: int | None = None, train_steps: int | None = None,
              self_influence: bool = False, seed: int = 0,
              store: Any = None) -> InfluenceResult:
    """Score training examples against m queries with one prepared sketch.

    For each query example q (a row of ``queries``, a batch pytree with
    leading axis m) and each training example zᵢ streamed from ``source``
    (default ``problem.data``), computes the influence score

        s(q, i) = −∇L(q)ᵀ (H + ρI)⁻¹ ∇L(zᵢ)

    and returns the top-``top_k`` (score, index) pairs per query. The m
    query IHVPs sᵩ = (H+ρI)⁻¹∇L(q) ride ``solver.apply_matrix`` as ONE
    (p, m) block — k sketch HVPs total, then two GEMM passes — and the
    training sweep is a streamed contraction (:func:`make_topk_scanner`):
    per ``batch_size`` slice, an (m, b) score tile is folded into a running
    ``jax.lax.top_k`` merge, so the full n_train × m score matrix never
    materializes.

    ``params=None`` first trains the model (plain SGD, ``train_steps``
    steps on ``problem.data.train_batch``); pass trained params to skip.
    ``config`` is a HypergradConfig or built solver (uniform protocol).

    ``store``: an optional :class:`repro.serve.SketchStore`. When given (and
    the solver is amortizable), the prepared state is fetched by content key
    — a digest of ``params`` plus the solver's state fingerprint — instead
    of rebuilt: a warm hit answers all m queries with ZERO sketch-build HVPs
    (``result.hvp_count == 0``), which is the serving tier's whole point.
    The key is ρ-free, so one cached sketch serves a damping sweep.
    """
    from repro.core.tree_util import PyTreeIndexer

    if config is None:
        config = HypergradConfig()
    solver = (config.build() if isinstance(config, HypergradConfig)
              else config)
    source = problem.data if source is None else source
    if queries is None:
        raise ValueError('influence() needs a queries batch (leading axis m)')
    for attr in ('n_train', 'train_slice'):
        if not hasattr(source, attr):
            raise TypeError(
                f'influence() needs an ordered-streaming source exposing '
                f'n_train/train_slice (see ArraySource); '
                f'{type(source).__name__} lacks {attr!r}')
    d = {**_TRAIN_DEFAULTS, **problem.defaults}
    bs = batch_size if batch_size is not None else d['batch_size']
    rng = jax.random.PRNGKey(seed)

    t0 = time.time()
    if params is None:
        params = train_influence_params(problem, train_steps=train_steps,
                                        batch_size=bs, seed=seed)

    # curvature at the trained params, over one large ordered slice
    hvp = influence_curvature_hvp(problem, params, source, bs)
    amortizable = getattr(type(solver), 'amortizable', False)
    built = True
    if store is not None and amortizable:
        from repro.serve import sketch_key
        key = sketch_key(params, solver)
        build = lambda: solver.prepare(hvp, PyTreeIndexer(params), rng)
        # a store with a disk tier resolves restarts too: hand it the state
        # template (shape-only, zero HVPs) so a spilled sketch re-enters
        # warm — a disk hit, like a memory hit, bills hvp_count == 0
        like = (jax.eval_shape(build)
                if getattr(store, 'spill_dir', None) is not None else None)
        state, built = store.get_or_build(
            key, build, like=like,
            build_hvps=influence_build_hvps(solver, params))
    else:
        state = solver.prepare(hvp, PyTreeIndexer(params), rng)

    # m query gradients → one (p, m) block → one apply_matrix
    G_q = _per_example_grads(problem.loss, params, queries)
    V = jax.tree.map(lambda g: jnp.moveaxis(g, 0, -1), G_q)
    S = solver.apply_matrix(state, V)
    m = jax.tree.leaves(S)[0].shape[-1]

    self_scores = None
    if self_influence:
        self_scores = sum(jax.tree.leaves(jax.tree.map(
            lambda v, s: jnp.einsum('...m,...m->m', v.astype(jnp.float32),
                                    s.astype(jnp.float32)), V, S)))

    scan = make_topk_scanner(problem.loss, params, source, bs)
    vals, idxs = scan(S, top_k)

    if amortizable:
        # one state build amortized over all m queries and the whole sweep;
        # a warm store hit ran no build at all — the bill is genuinely zero
        hvps = influence_build_hvps(solver, params) if built else 0
    else:
        hvps = getattr(solver, 'iters', 0) * m  # per-query iterative solves
    return InfluenceResult(problem=problem.name, scores=vals, indices=idxs,
                           self_scores=self_scores, params=params,
                           hvp_count=int(hvps), seconds=time.time() - t0)
