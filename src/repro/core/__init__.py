"""repro.core — the paper's contribution: Nyström implicit differentiation.

Public API:
  BilevelProblem / solve / PROBLEMS               — typed problem API (one
                                                    entry point task → result)
  InfluenceProblem / influence                    — per-example influence
                                                    scores (matrix-IHVP service)
  hypergrad_at / hypergrad_reference /            — per-point hypergradient +
    hypergrad_error                                 exact-IHVP oracle (observatory)
  implicit_root / phi_vjp_block                   — differentiable θ*(φ) map
                                                    (+ m-query cotangent block)
  NystromIHVP / CGIHVP / NeumannIHVP / ExactIHVP  — IHVP solvers
  hypergradient / unrolled_hypergradient          — Eq. 3 assembly (legacy)
  BilevelTrainer / BilevelState                   — warm-start bilevel loop
  SketchPolicy / SketchState                      — sketch lifecycle (amortization)
  make_hvp / extract_columns / PyTreeIndexer      — HVP substrate
"""
from repro.core.backend import (BACKENDS, BF16_SKETCH_CONTRACT,
                                FLAT_SHARDED_CONTRACT, FlatBackend,
                                FlatShardedBackend, PallasBackend,
                                ShardedOperand, TreeBackend, flatten_sketch,
                                flatten_vec, flatten_vecm, get_backend,
                                unflatten_vec, unflatten_vecm)
from repro.core.bilevel import BilevelState, BilevelTrainer
from repro.core.hvp import extract_columns, make_hvp, make_hvp_fn
from repro.core.hypergrad import (HypergradConfig, config_from_cli,
                                  hypergradient, unrolled_hypergradient)
from repro.core.implicit import implicit_root, phi_vjp_block, sgd_solver
from repro.core.problem import (BatchSource, BilevelProblem, BilevelResult,
                                InfluenceProblem, InfluenceResult, PROBLEMS,
                                accounted_hvps, get_problem, hypergrad_at,
                                hypergrad_error, hypergrad_reference,
                                influence, influence_build_hvps,
                                influence_curvature_hvp, make_topk_scanner,
                                register_problem, solve,
                                train_influence_params)
from repro.core.solvers import (SOLVERS, CGIHVP, DenseFactor, ExactIHVP,
                                IterativeOperator, NeumannIHVP, NystromIHVP,
                                NystromSketch, SketchPolicy, SketchState,
                                SolverSpec, nystrom_inverse_dense,
                                build_hvp_bill, query_width,
                                solver_fingerprint, state_nbytes,
                                tangent_apply)
from repro.core.tree_util import (PyTreeIndexer, tree_add, tree_axpy,
                                  tree_cast, tree_norm, tree_random_like,
                                  tree_scale, tree_size, tree_sub, tree_vdot,
                                  tree_zeros_like)

__all__ = [
    'BACKENDS', 'BF16_SKETCH_CONTRACT', 'FLAT_SHARDED_CONTRACT',
    'BatchSource', 'BilevelProblem', 'BilevelResult',
    'BilevelState', 'BilevelTrainer', 'DenseFactor', 'PROBLEMS',
    'InfluenceProblem', 'InfluenceResult', 'influence',
    'influence_build_hvps', 'influence_curvature_hvp', 'make_topk_scanner',
    'train_influence_params',
    'accounted_hvps', 'get_problem', 'hypergrad_at', 'hypergrad_error',
    'hypergrad_reference', 'register_problem', 'solve',
    'build_hvp_bill', 'solver_fingerprint', 'state_nbytes',
    'FlatBackend', 'FlatShardedBackend', 'HypergradConfig',
    'IterativeOperator', 'PallasBackend', 'ShardedOperand', 'SOLVERS',
    'SketchPolicy', 'SketchState', 'SolverSpec', 'TreeBackend',
    'CGIHVP', 'ExactIHVP', 'NeumannIHVP', 'NystromIHVP', 'NystromSketch',
    'PyTreeIndexer', 'extract_columns', 'flatten_sketch', 'flatten_vec',
    'flatten_vecm', 'phi_vjp_block', 'query_width',
    'config_from_cli', 'get_backend', 'hypergradient', 'implicit_root',
    'make_hvp',
    'make_hvp_fn', 'nystrom_inverse_dense', 'sgd_solver', 'tangent_apply',
    'tree_add', 'tree_axpy',
    'tree_cast', 'tree_norm', 'tree_random_like', 'tree_scale', 'tree_size',
    'tree_sub', 'tree_vdot', 'tree_zeros_like', 'unflatten_vec',
    'unflatten_vecm', 'unrolled_hypergradient',
]
