"""First-class differentiable implicit solutions (the repo's public API).

The paper's estimator is an *inverse-Hessian-vector product*; what users
actually want to write is the natural JAX thing

    theta_star = solve(phi, batch)                  # inner optimization
    jax.grad(lambda phi: g(solve(phi, batch), phi)) # hypergradient, Eq. 3

``implicit_root`` makes that work: it wraps an inner solver in a
``jax.custom_jvp`` whose tangent rule solves the implicit-function-theorem
tangent system ``(H + ρI) θ̇ = −(∂²f/∂θ∂φ) φ̇`` with the Nyström (or CG /
Neumann / exact) IHVP. Reverse mode falls out by transposition: the tangent
solve is staged through ``jax.lax.custom_linear_solve(symmetric=True)``, so
transposing it re-invokes the *same* ``solver.apply`` on the cotangent and
the backward pass is exactly the IHVP-plus-mixed-term VJP of Grazzi et al.
2020 — the ``jax.custom_vjp`` formula the repo has always run (and still
ships, as the ``forward_mode=False`` escape hatch), now derived rather than
hand-written. Because the solution map is a plain JAX primitive-like
function, it composes for free:

  * ``jax.grad``  → Eq. 3 hypergradients (direct term included, since φ also
    flows into the outer loss directly);
  * ``jax.jvp`` / ``jax.jacfwd`` → oracle tangents ``dθ*/dφ`` — the forward
    path of approximate implicit differentiation, and the enabler for
    *nested* solution maps: an HVP of a loss that contains an
    ``implicit_root`` is jvp-of-grad, which needs both modes at once
    (see ``repro.engine`` for the multi-level machinery built on this);
  * ``jax.vmap``  → batched per-task hypergradients (iMAML meta-batches: the
    k sketch HVPs of every task run as one batched program instead of a
    per-task Python loop — see benchmarks/tab3_imaml.py);
  * ``jax.jit`` / pjit → compiles once; fresh ``rng`` / batch values do not
    retrace (index sampling is traced, not staged out).

Backward-pass cost is exactly the solver's ``prepare`` + ``apply`` + one VJP
through the inner gradient; the forward pass is whatever ``inner_solver_fn``
does (typically T optimizer steps, run *without* differentiation through the
unroll — that is the point of implicit differentiation).

Example — a quadratic inner problem with an analytic solution map
(``f = ½·Σ d·θ² − θ·φ`` has ``θ*(φ) = φ/d``, so ``dθ*/dφ = 1/d``):

>>> import jax, jax.numpy as jnp
>>> from repro.core.implicit import implicit_root
>>> from repro.core.hypergrad import HypergradConfig
>>> d = jnp.array([1.0, 2.0, 4.0])
>>> def inner(theta, phi, batch):
...     return 0.5 * jnp.sum(d * theta ** 2) - jnp.sum(theta * phi)
>>> solve = implicit_root(lambda phi, batch: phi / d, inner,
...                       HypergradConfig(solver='exact', rho=0.0))
>>> g = jax.grad(lambda phi: jnp.sum(solve(phi, None)))(jnp.ones(3))
>>> bool(jnp.allclose(g, 1.0 / d, atol=1e-5))
True

``jax.vmap`` over a task axis gives per-task hypergradients in one program:

>>> phis = jnp.stack([jnp.ones(3), 2.0 * jnp.ones(3)])
>>> per_task = jax.vmap(
...     jax.grad(lambda phi: jnp.sum(solve(phi, None))))(phis)
>>> per_task.shape
(2, 3)

Shared-sketch meta-batches: by default every task in a vmapped meta-batch
re-prepares its own sketch in the backward pass (tasks × k HVPs per
meta-batch). ``solve.prepare_state`` builds one amortizable state at a
single linearization point (e.g. the meta-initialization); closing the
vmapped function over it broadcasts the state across tasks, cutting the
meta-batch cost to k HVPs total (see benchmarks/tab3_imaml.py and the
sketch-lifecycle section of docs/implicit-api.md):

>>> shared = solve.prepare_state(jnp.ones(3), jnp.ones(3), None,
...                              jax.random.PRNGKey(0))
>>> shared_task = jax.vmap(jax.grad(
...     lambda phi: jnp.sum(solve(phi, None, state=shared))))(phis)
>>> bool(jnp.allclose(shared_task, per_task, atol=1e-5))
True

Forward mode gives the oracle tangent of the solution map (here
``dθ*/dφ = 1/d``, so the jvp along ``v`` is ``v/d``):

>>> v = jnp.array([3.0, 2.0, 4.0])
>>> _, tangent = jax.jvp(lambda phi: solve(phi, None), (jnp.ones(3),), (v,))
>>> bool(jnp.allclose(tangent, v / d, atol=1e-5))
True
"""
from __future__ import annotations

from typing import Any, Callable

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.hvp import make_hvp
from repro.core.tree_util import PyTree, PyTreeIndexer, tree_scale

InnerSolver = Callable[[PyTree, Any], PyTree]   # (phi, batch) -> theta*
InnerLoss = Callable[..., jax.Array]            # f(theta, phi, batch) -> scalar


def _zeros_cotangent(tree: PyTree) -> PyTree:
    """Zero cotangents for a non-differentiated argument pytree.

    Inexact leaves get ordinary zeros; integer / PRNG-key leaves get the
    ``float0`` zeros JAX expects as their tangent type (a plain ``jnp.zeros``
    there would fail custom_vjp's output-type check)."""
    def z(x):
        aval = jax.core.get_aval(x)
        if jnp.issubdtype(aval.dtype, jnp.inexact):
            return jnp.zeros(aval.shape, aval.dtype)
        return np.zeros(aval.shape, jax.dtypes.float0)
    return jax.tree.map(z, tree)


def _implicit_phi_vjp(solver, inner_loss: InnerLoss, theta: PyTree,
                      phi: PyTree, batch: Any, v: PyTree,
                      rng: jax.Array, state) -> PyTree:
    """The φ-cotangent of the solution map θ*(φ): −(∂²f/∂φ∂θ)ᵀ (H+ρI)⁻¹ v.

    ``state`` is an optional pre-built solver state (e.g. an amortized
    ``NystromSketch``); when absent the solver's ``prepare`` runs here —
    inside the backward pass, so under ``jax.vmap`` the per-task sketch HVPs
    batch across tasks."""
    if state is None:
        hvp = make_hvp(inner_loss, theta, phi, batch)
        state = solver.prepare(hvp, PyTreeIndexer(theta), rng)
    u = jax.lax.stop_gradient(solver.apply(state, v))

    # mixed term: ∇_φ ⟨∇_θ f(θ*, φ), u⟩  (= (∂²f/∂φ∂θ)ᵀ u); f32 accumulation
    def inner_grad_dot_u(p):
        g_theta = jax.grad(inner_loss, argnums=0)(theta, p, batch)
        leaves = jax.tree.leaves(jax.tree.map(
            lambda a, b: jnp.vdot(a.astype(jnp.float32),
                                  b.astype(jnp.float32)), g_theta, u))
        return sum(leaves)

    return tree_scale(jax.grad(inner_grad_dot_u)(phi), -1.0)


def _stop_gradient_arrays(tree) -> PyTree:
    """``stop_gradient`` on every array leaf, passing non-array leaves (the
    closures of a trace-local ``IterativeOperator``) through untouched."""
    return jax.tree.map(
        lambda x: jax.lax.stop_gradient(x)
        if isinstance(x, (jax.Array, np.ndarray)) else x, tree)


def _implicit_phi_tangent(solver, inner_loss: InnerLoss, theta: PyTree,
                          phi: PyTree, batch: Any, phi_dot: PyTree,
                          rng: jax.Array, state) -> PyTree:
    """The φ-tangent of the solution map θ*(φ): −(H+ρI)⁻¹ (∂²f/∂θ∂φ) φ̇.

    The forward-mode mirror of :func:`_implicit_phi_vjp`: differentiate the
    stationarity condition ``∇_θ f(θ*(φ), φ) = 0`` to get the tangent system
    ``(H + ρI) θ̇ = −M φ̇``, build ``M φ̇`` as a jvp of the inner gradient in
    the φ slot, and solve with the same solver ``apply`` the backward pass
    uses — via :func:`~repro.core.solvers.tangent_apply`, so the solve is a
    transposable linear op (reverse mode over this rule reproduces the vjp)
    and further differentiation (hyper-Hessian products) stays correct.

    ``state`` semantics match the vjp: None prepares here (k sketch HVPs,
    batched under ``jax.vmap``); a pre-built state amortizes them away. The
    linearization point is frozen (``stop_gradient`` on θ and the state
    arrays) — AID differentiates the implicit map, never the sketch."""
    from repro.core.solvers import tangent_apply
    theta_c = jax.lax.stop_gradient(theta)
    if state is None:
        hvp = make_hvp(inner_loss, theta_c, phi, batch)
        state = solver.prepare(hvp, PyTreeIndexer(theta_c), rng)
    state = _stop_gradient_arrays(state)

    def inner_grad(p):
        return jax.grad(inner_loss, argnums=0)(theta_c, p, batch)

    m_dot = jax.jvp(inner_grad, (phi,), (phi_dot,))[1]
    hvp_sys = make_hvp(inner_loss, theta_c, phi, batch)
    return tree_scale(tangent_apply(solver, state, hvp_sys, m_dot), -1.0)


def phi_vjp_block(solver, inner_loss: InnerLoss, theta: PyTree,
                  phi: PyTree, batch: Any, V: PyTree,
                  rng: jax.Array | None = None, state=None) -> PyTree:
    """The φ-cotangent of θ*(φ) for an m-query block of cotangents.

    ``V`` is a query block: every leaf is the matching θ-leaf's shape plus a
    trailing (m,) axis (m stacked cotangents — e.g. the per-query gradients
    of an influence-function sweep). Returns the φ-shaped block
    −(∂²f/∂φ∂θ)ᵀ (H+ρI)⁻¹ V with the same trailing axis.

    One solver state serves all m queries: the IHVP runs through
    ``solver.apply_matrix`` (a single set of sketch passes — GEMMs, not m
    matvecs), and only the mixed-term VJP — whose cost is a fwd+bwd of the
    inner gradient, independent of the sketch — is vmapped per query.
    ``state=None`` prepares here (k HVPs); pass a prepared state to amortize
    across blocks. m = 1 matches ``m`` separate vector VJPs bit-for-bit on
    the IHVP side (see ``Solver.apply_matrix``).
    """
    if rng is None:
        rng = jax.random.PRNGKey(0)
    if state is None:
        hvp = make_hvp(inner_loss, theta, phi, batch)
        state = solver.prepare(hvp, PyTreeIndexer(theta), rng)
    U = jax.lax.stop_gradient(solver.apply_matrix(state, V))

    def phi_bar(u):
        def inner_grad_dot_u(p):
            g_theta = jax.grad(inner_loss, argnums=0)(theta, p, batch)
            leaves = jax.tree.leaves(jax.tree.map(
                lambda a, b: jnp.vdot(a.astype(jnp.float32),
                                      b.astype(jnp.float32)), g_theta, u))
            return sum(leaves)
        return tree_scale(jax.grad(inner_grad_dot_u)(phi), -1.0)

    return jax.vmap(phi_bar, in_axes=-1, out_axes=-1)(U)


def implicit_root(inner_solver_fn: InnerSolver, inner_loss: InnerLoss,
                  hypergrad=None, forward_mode: bool = True) -> Callable:
    """Wrap an inner solver into a differentiable solution map ``φ, batch → θ*``.

    Args:
      inner_solver_fn: ``(phi, batch) -> theta_star`` — any approximate inner
        optimization (T optimizer steps, a warm-started closure over the
        current parameters, or an analytic solve). It is *not* differentiated
        through; the returned map's derivatives come from the implicit
        function theorem at the point it returns.
      inner_loss: ``f(theta, phi, batch) -> scalar`` — the inner objective
        whose stationarity defines θ*. Its Hessian (through HVPs only) and
        mixed partial drive the derivative rules.
      hypergrad: a ``HypergradConfig`` (built once here), a solver instance
        implementing the uniform protocol (``prepare``/``apply``), or None
        for the default Nyström configuration.
      forward_mode: True (default) wraps the map in ``jax.custom_jvp`` — the
        tangent rule solves the IFT tangent system with the solver's
        ``apply``, and reverse mode is its transpose (numerically the same
        IHVP + mixed-term VJP, staged through
        ``jax.lax.custom_linear_solve``). Both ``jax.grad`` and
        ``jax.jvp``/``jax.jacfwd`` compose, which nested solution maps
        (``repro.engine``) require. False restores the legacy
        ``jax.custom_vjp``-only wrapper (reverse mode only) — the escape
        hatch if a workflow depends on the hand-written backward trace.

    Returns:
      ``solve(phi, batch=None, rng=None, state=None)`` — a function returning
      θ*, differentiable in ``phi``:

      * ``rng`` seeds the derivative pass's sketch-column sampling (Nyström);
        pass a fresh key per outer step for fresh columns, or reuse one to
        pin them. Defaults to ``PRNGKey(0)``.
      * ``state`` optionally injects a pre-built solver state (an amortized
        ``NystromSketch`` / ``DenseFactor``) so the derivative pass skips
        ``prepare`` — the sketch-amortization story of BilevelTrainer, and
        the shared-sketch meta-batch mode under ``jax.vmap`` (an unbatched
        state closed over by the vmapped function broadcasts across tasks:
        k HVPs per meta-batch instead of per task).
      * ``batch`` and ``rng`` receive zero cotangents (and contribute zero
        tangents): the map is treated as non-differentiable in the data (see
        docs/implicit-api.md for the residual caveats). θ* carries no
        residual connection to the forward unroll — gradients flow *only*
        through the implicit rules.

      The returned function also carries
      ``solve.prepare_state(theta, phi, batch=None, rng=None)`` — it builds
      such a state at an explicit linearization point via the shared
      :class:`~repro.core.solvers.SketchPolicy` code path (k HVPs; raises
      TypeError for iterative solvers, whose state is trace-local).
    """
    from repro.core.hypergrad import HypergradConfig
    if hypergrad is None:
        hypergrad = HypergradConfig()
    solver = (hypergrad.build() if isinstance(hypergrad, HypergradConfig)
              else hypergrad)

    # ``state`` is an ordinary pytree argument: None (the fresh-prepare path)
    # flattens to an empty subtree, a NystromSketch/DenseFactor flattens to
    # arrays — switching between them retraces once, as any structure change
    # does.
    if forward_mode:
        @jax.custom_jvp
        def _solve(phi, batch, rng, state):
            return inner_solver_fn(phi, batch)

        @_solve.defjvp
        def _solve_jvp(primals, tangents):
            phi, batch, rng, state = primals
            # batch/rng/state tangents are ignored by contract (the map is
            # non-differentiable in them); the self-call keeps higher-order
            # differentiation re-entering this rule instead of the unroll.
            phi_dot = tangents[0]
            theta = _solve(phi, batch, rng, state)
            theta_dot = _implicit_phi_tangent(solver, inner_loss, theta, phi,
                                              batch, phi_dot, rng, state)
            return theta, theta_dot
    else:
        @jax.custom_vjp
        def _solve(phi, batch, rng, state):
            return inner_solver_fn(phi, batch)

        def _solve_fwd(phi, batch, rng, state):
            theta = inner_solver_fn(phi, batch)
            return theta, (theta, phi, batch, rng, state)

        def _solve_bwd(res, v):
            theta, phi, batch, rng, state = res
            phi_bar = _implicit_phi_vjp(solver, inner_loss, theta, phi,
                                        batch, v, rng, state)
            return (phi_bar, _zeros_cotangent(batch), _zeros_cotangent(rng),
                    _zeros_cotangent(state))

        _solve.defvjp(_solve_fwd, _solve_bwd)

    def solve(phi: PyTree, batch: Any = None, rng: jax.Array | None = None,
              state=None) -> PyTree:
        if rng is None:
            rng = jax.random.PRNGKey(0)
        return _solve(phi, batch, rng, state)

    def prepare_state(theta: PyTree, phi: PyTree, batch: Any = None,
                      rng: jax.Array | None = None):
        """Build an amortizable solver state at (theta, phi, batch), for the
        ``state=`` argument — one sketch shared across a vmapped meta-batch
        or across outer steps. theta is the linearization point (e.g. the
        meta-initialization); the k sketch HVPs run here, once."""
        from repro.core.solvers import SketchPolicy
        if rng is None:
            rng = jax.random.PRNGKey(0)
        return SketchPolicy(solver=solver, inner_loss=inner_loss).build(
            theta, phi, batch, rng)

    solve.prepare_state = prepare_state
    return solve


def sgd_solver(inner_loss: InnerLoss, steps: int, lr: float,
               init: Callable[[PyTree, Any], PyTree] | None = None
               ) -> InnerSolver:
    """Canonical ``inner_solver_fn``: ``steps`` plain-SGD steps on
    ``inner_loss``, unrolled with ``lax.scan`` (no differentiation through
    the unroll — that is ``implicit_root``'s job).

    ``init``: ``(phi, batch) → θ0``. The default starts from φ itself — the
    iMAML pattern, where φ is the meta-initialization (and typically also
    the proximal anchor inside ``inner_loss``). Pass an explicit ``init``
    when θ and φ live in different spaces (e.g. §5.1 weight-decay HPO).
    """
    def solve(phi: PyTree, batch: Any) -> PyTree:
        theta0 = phi if init is None else init(phi, batch)

        def step(p, _):
            g = jax.grad(inner_loss)(p, phi, batch)
            return jax.tree.map(lambda w, gw: w - lr * gw, p, g), None

        theta, _ = jax.lax.scan(step, theta0, None, length=steps)
        return theta

    return solve
