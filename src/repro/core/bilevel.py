"""Warm-start alternating bilevel driver (Eq. 1–2 of the paper).

Inner: θ_t = Θ(θ_{t-1}, ∇_θ f(θ_{t-1}, φ, T), φ) for T steps.
Outer: φ ← φ − η · (approximate dg/dφ via implicit differentiation).

The driver is jit-friendly: ``inner_step`` and ``outer_step`` are pure
functions over an explicit ``BilevelState`` pytree, so the trainer in
``launch/train.py`` can pjit them over the production mesh and the
checkpoint manager can snapshot the whole state atomically.

Outer steps differentiate through the ``implicit_root`` solution map
(``repro.core.implicit``): the warm-started θ is wrapped as θ*(φ) and the
hypergradient is literally ``jax.grad`` of ``g(θ*(φ), φ)``. Two RNG streams
live in the state: ``rng`` drives everything user-visible (inner resets),
``vjp_rng`` exclusively seeds the backward pass's Nyström column sampling —
keeping sketch randomness reproducible independent of the training stream.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable

import jax
import jax.numpy as jnp

from repro.core.hvp import make_hvp
from repro.core.hypergrad import HypergradConfig
from repro.core.implicit import implicit_root
from repro.core.solvers import IterativeOperator
from repro.core.tree_util import PyTree, PyTreeIndexer
from repro.optim.optimizers import Optimizer


@jax.tree_util.register_dataclass
@dataclasses.dataclass
class BilevelState:
    params: PyTree
    hparams: PyTree
    inner_opt_state: PyTree
    outer_opt_state: PyTree
    inner_step: jax.Array   # int32 scalar
    outer_step: jax.Array   # int32 scalar
    rng: jax.Array
    vjp_rng: jax.Array      # seeds implicit-root backward (sketch columns)


@dataclasses.dataclass
class BilevelTrainer:
    """Alternating warm-start bilevel optimization with pluggable IHVP solver.

    ``reset_inner`` mirrors the paper's §5.1/§5.2 protocol (re-initialize θ at
    every outer update); production LM training keeps warm starts
    (reset_inner=False, §5.4 protocol).
    """
    inner_loss: Callable[..., jax.Array]   # f(params, hparams, batch)
    outer_loss: Callable[..., jax.Array]   # g(params, hparams, batch)
    inner_opt: Optimizer
    outer_opt: Optimizer
    hypergrad: HypergradConfig
    init_params: Callable[[jax.Array], PyTree] | None = None
    reset_inner: bool = False

    def init(self, rng: jax.Array, params: PyTree, hparams: PyTree) -> BilevelState:
        rng, vjp_rng = jax.random.split(rng)
        return BilevelState(
            params=params,
            hparams=hparams,
            inner_opt_state=self.inner_opt.init(params),
            outer_opt_state=self.outer_opt.init(hparams),
            inner_step=jnp.int32(0),
            outer_step=jnp.int32(0),
            rng=rng,
            vjp_rng=vjp_rng,
        )

    # ------------------------------------------------------------------ inner
    def inner_step_fn(self, state: BilevelState, batch: Any) -> tuple[BilevelState, jax.Array]:
        loss, grads = jax.value_and_grad(self.inner_loss)(
            state.params, state.hparams, batch)
        params, opt_state = self.inner_opt.apply(
            grads, state.inner_opt_state, state.params, state.inner_step)
        return dataclasses.replace(
            state, params=params, inner_opt_state=opt_state,
            inner_step=state.inner_step + 1), loss

    # ------------------------------------------------------------------ outer
    def _solution_map(self, params: PyTree):
        """The warm-started θ viewed as an ``implicit_root`` solution map.

        The inner unroll already happened (inner_step_fn); the map's forward
        just returns its endpoint, and its custom_vjp backward supplies the
        implicit hypergradient."""
        return implicit_root(lambda phi, batch: params, self.inner_loss,
                             self.hypergrad)

    def outer_step_fn(self, state: BilevelState, inner_batch: Any,
                      outer_batch: Any) -> tuple[BilevelState, jax.Array]:
        """One hypergradient update on φ.

        Returns the *pre-update* outer loss g(θ, φ_t) — the value the
        hypergradient was computed at (it falls out of value_and_grad for
        free), not the loss after the φ update."""
        vjp_rng, sub = jax.random.split(state.vjp_rng)
        solve = self._solution_map(state.params)

        def outer_obj(phi):
            theta = solve(phi, inner_batch, rng=sub)
            return self.outer_loss(theta, phi, outer_batch)

        outer_loss_pre, hgrad = jax.value_and_grad(outer_obj)(state.hparams)
        hparams, outer_opt_state = self.outer_opt.apply(
            hgrad, state.outer_opt_state, state.hparams, state.outer_step)

        state = dataclasses.replace(
            state, hparams=hparams, outer_opt_state=outer_opt_state,
            outer_step=state.outer_step + 1, vjp_rng=vjp_rng)

        if self.reset_inner:
            assert self.init_params is not None, 'reset_inner needs init_params'
            rng, sub = jax.random.split(state.rng)
            params = self.init_params(sub)
            state = dataclasses.replace(
                state, params=params,
                inner_opt_state=self.inner_opt.init(params),
                inner_step=jnp.int32(0), rng=rng)
        return state, outer_loss_pre

    # ------------------------------------------- amortized-sketch outer step
    def build_sketch(self, state: BilevelState, inner_batch: Any):
        """Prepare the solver state once; reuse for ``sketch_refresh_every``
        outer steps (beyond-paper amortization — see EXPERIMENTS.md §Perf).

        Only amortizable (pytree-of-arrays) states survive across steps —
        NystromSketch, DenseFactor. Iterative solvers return a trace-local
        ``IterativeOperator`` (it closes over this step's hvp), which would
        only fail later and opaquely inside the next jitted outer step, so
        it is rejected here instead."""
        solver = self.hypergrad.build()
        indexer = PyTreeIndexer(state.params)
        hvp = make_hvp(self.inner_loss, state.params, state.hparams, inner_batch)
        vjp_rng, sub = jax.random.split(state.vjp_rng)
        prepared = solver.prepare(hvp, indexer, sub)
        if isinstance(prepared, IterativeOperator):
            raise TypeError(
                f'{type(solver).__name__}.prepare returns a trace-local '
                'IterativeOperator — iterative solvers have nothing to '
                'amortize across outer steps; use outer_step_fn instead of '
                'the sketch path')
        return prepared, dataclasses.replace(state, vjp_rng=vjp_rng)

    def outer_step_with_sketch(self, state: BilevelState, sketch,
                               inner_batch: Any, outer_batch: Any):
        """``outer_step_fn`` with the backward pass's ``prepare`` replaced by
        a pre-built sketch. Returns the pre-update outer loss, like
        ``outer_step_fn``."""
        solve = self._solution_map(state.params)

        def outer_obj(phi):
            theta = solve(phi, inner_batch, state=sketch)
            return self.outer_loss(theta, phi, outer_batch)

        outer_loss_pre, hgrad = jax.value_and_grad(outer_obj)(state.hparams)
        hparams, outer_opt_state = self.outer_opt.apply(
            hgrad, state.outer_opt_state, state.hparams, state.outer_step)
        return dataclasses.replace(
            state, hparams=hparams, outer_opt_state=outer_opt_state,
            outer_step=state.outer_step + 1), outer_loss_pre

    # ------------------------------------------------------------------ loop
    def run(self, state: BilevelState, inner_batches, outer_batches,
            steps_per_outer: int, n_outer: int, log_every: int = 0,
            jit: bool = True):
        """Host-side loop (examples / tests). Production loop lives in
        launch/train.py with pjit + checkpointing.

        Losses are buffered as device arrays and materialized (one host
        sync for the whole buffer) only at ``log_every`` boundaries and at
        the end — a ``float()`` per inner step would force a device sync
        per step and serialize the async dispatch pipeline."""
        inner = jax.jit(self.inner_step_fn) if jit else self.inner_step_fn
        outer = jax.jit(self.outer_step_fn) if jit else self.outer_step_fn
        history = {'inner_loss': [], 'outer_loss': []}
        pending_inner: list[jax.Array] = []
        pending_outer: list[jax.Array] = []

        def flush():
            history['inner_loss'].extend(float(x) for x in pending_inner)
            history['outer_loss'].extend(float(x) for x in pending_outer)
            pending_inner.clear()
            pending_outer.clear()

        it_in, it_out = iter(inner_batches), iter(outer_batches)
        for o in range(n_outer):
            for _ in range(steps_per_outer):
                state, li = inner(state, next(it_in))
                pending_inner.append(li)
            ib, ob = next(it_in), next(it_out)
            state, lo = outer(state, ib, ob)
            pending_outer.append(lo)
            if log_every and (o + 1) % log_every == 0:
                flush()
                print(f'[bilevel] outer {o + 1}/{n_outer} '
                      f'g={history["outer_loss"][-1]:.4f} '
                      f'(pre-update) f={history["inner_loss"][-1]:.4f}')
        flush()
        return state, history
