"""Warm-start alternating bilevel driver (Eq. 1–2 of the paper).

Inner: θ_t = Θ(θ_{t-1}, ∇_θ f(θ_{t-1}, φ, T), φ) for T steps.
Outer: φ ← φ − η · (approximate dg/dφ via implicit differentiation).

The driver is jit-friendly: ``inner_step`` and ``outer_step`` are pure
functions over an explicit ``BilevelState`` pytree, so the trainer in
``launch/train.py`` can pjit them over the production mesh and the
checkpoint manager can snapshot the whole state atomically.

Outer steps differentiate through the ``implicit_root`` solution map
(``repro.core.implicit``): the warm-started θ is wrapped as θ*(φ) and the
hypergradient is literally ``jax.grad`` of ``g(θ*(φ), φ)``. Two RNG streams
live in the state: ``rng`` drives everything user-visible (inner resets),
``vjp_rng`` exclusively seeds the backward pass's Nyström column sampling —
keeping sketch randomness reproducible independent of the training stream.

Sketch lifecycle: the amortizable solvers (Nyström/exact) prepare a
pytree-of-arrays state that can serve several outer steps. ``run`` drives
that automatically — a :class:`~repro.core.solvers.SketchPolicy` rebuilds
the sketch every ``sketch_refresh_every`` outer steps (the
``HypergradConfig`` knob) under ``lax.cond``-friendly staleness tracking;
``build_sketch`` / ``outer_step_with_sketch`` remain as the manual
hand-driven pair and share the same policy code path.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable

import jax
import jax.numpy as jnp

from repro.core.hypergrad import HypergradConfig
from repro.core.implicit import implicit_root
from repro.core.solvers import SketchPolicy, SketchState
from repro.core.tree_util import PyTree
from repro.optim.optimizers import Optimizer


@jax.tree_util.register_dataclass
@dataclasses.dataclass
class BilevelState:
    params: PyTree
    hparams: PyTree
    inner_opt_state: PyTree
    outer_opt_state: PyTree
    inner_step: jax.Array   # int32 scalar
    outer_step: jax.Array   # int32 scalar
    rng: jax.Array
    vjp_rng: jax.Array      # seeds implicit-root backward (sketch columns)


@dataclasses.dataclass
class BilevelTrainer:
    """Alternating warm-start bilevel optimization with pluggable IHVP solver.

    ``reset_inner`` mirrors the paper's §5.1/§5.2 protocol (re-initialize θ at
    every outer update); production LM training keeps warm starts
    (reset_inner=False, §5.4 protocol).
    """
    inner_loss: Callable[..., jax.Array]   # f(params, hparams, batch)
    outer_loss: Callable[..., jax.Array]   # g(params, hparams, batch)
    inner_opt: Optimizer
    outer_opt: Optimizer
    hypergrad: HypergradConfig
    init_params: Callable[[jax.Array], PyTree] | None = None
    reset_inner: bool = False

    @classmethod
    def from_problem(cls, problem, hypergrad=None, *, inner_opt=None,
                     outer_opt=None, reset_inner: bool | None = None
                     ) -> 'BilevelTrainer':
        """Construct a trainer from a :class:`~repro.core.problem.BilevelProblem`.

        Optimizers default from the problem's ``defaults`` (via
        ``repro.core.problem.default_optimizers``); ``reset_inner`` defaults
        from the task's paper protocol. ``solve()`` is the higher-level entry
        point that also drives the loop and accounts HVPs — this constructor
        is for callers who want the trainer's step functions directly.
        """
        from repro.core.problem import default_optimizers, resolved_defaults
        d = resolved_defaults(problem, reset_inner=reset_inner)
        d_inner, d_outer = default_optimizers(problem, d)
        return cls(inner_loss=problem.inner_loss,
                   outer_loss=problem.outer_loss,
                   inner_opt=inner_opt or d_inner,
                   outer_opt=outer_opt or d_outer,
                   hypergrad=(hypergrad if hypergrad is not None
                              else HypergradConfig()),
                   init_params=problem.init_params,
                   reset_inner=bool(d['reset_inner']))

    def init(self, rng: jax.Array, params: PyTree, hparams: PyTree) -> BilevelState:
        rng, vjp_rng = jax.random.split(rng)
        return BilevelState(
            params=params,
            hparams=hparams,
            inner_opt_state=self.inner_opt.init(params),
            outer_opt_state=self.outer_opt.init(hparams),
            inner_step=jnp.int32(0),
            outer_step=jnp.int32(0),
            rng=rng,
            vjp_rng=vjp_rng,
        )

    # ------------------------------------------------------------------ inner
    def inner_step_fn(self, state: BilevelState, batch: Any) -> tuple[BilevelState, jax.Array]:
        loss, grads = jax.value_and_grad(self.inner_loss)(
            state.params, state.hparams, batch)
        params, opt_state = self.inner_opt.apply(
            grads, state.inner_opt_state, state.params, state.inner_step)
        return dataclasses.replace(
            state, params=params, inner_opt_state=opt_state,
            inner_step=state.inner_step + 1), loss

    # ------------------------------------------------------------------ outer
    def _solution_map(self, params: PyTree):
        """The warm-started θ viewed as an ``implicit_root`` solution map.

        The inner unroll already happened (inner_step_fn); the map's forward
        just returns its endpoint, and its custom_vjp backward supplies the
        implicit hypergradient."""
        return implicit_root(lambda phi, batch: params, self.inner_loss,
                             self.hypergrad)

    def _outer_update(self, state: BilevelState, inner_batch: Any,
                      outer_batch: Any, rng: jax.Array | None = None,
                      sketch=None) -> tuple[BilevelState, jax.Array]:
        """Shared core of every outer step: one hypergradient update on φ,
        with the backward pass either preparing fresh (``rng`` seeds the
        column sampling) or reusing a pre-built ``sketch``. Handles the
        ``reset_inner`` protocol uniformly across both paths."""
        solve = self._solution_map(state.params)

        def outer_obj(phi):
            theta = solve(phi, inner_batch, rng=rng, state=sketch)
            return self.outer_loss(theta, phi, outer_batch)

        outer_loss_pre, hgrad = jax.value_and_grad(outer_obj)(state.hparams)
        hparams, outer_opt_state = self.outer_opt.apply(
            hgrad, state.outer_opt_state, state.hparams, state.outer_step)
        state = dataclasses.replace(
            state, hparams=hparams, outer_opt_state=outer_opt_state,
            outer_step=state.outer_step + 1)

        if self.reset_inner:
            assert self.init_params is not None, 'reset_inner needs init_params'
            rng, sub = jax.random.split(state.rng)
            params = self.init_params(sub)
            state = dataclasses.replace(
                state, params=params,
                inner_opt_state=self.inner_opt.init(params),
                inner_step=jnp.int32(0), rng=rng)
        return state, outer_loss_pre

    def outer_step_fn(self, state: BilevelState, inner_batch: Any,
                      outer_batch: Any) -> tuple[BilevelState, jax.Array]:
        """One hypergradient update on φ with a fresh backward-pass prepare.

        Returns the *pre-update* outer loss g(θ, φ_t) — the value the
        hypergradient was computed at (it falls out of value_and_grad for
        free), not the loss after the φ update."""
        vjp_rng, sub = jax.random.split(state.vjp_rng)
        state = dataclasses.replace(state, vjp_rng=vjp_rng)
        return self._outer_update(state, inner_batch, outer_batch, rng=sub)

    # ------------------------------------------- amortized-sketch outer step
    def _built_solver(self):
        """The configured solver instance (built from the HypergradConfig,
        or the bare instance the trainer was handed)."""
        return (self.hypergrad.build()
                if isinstance(self.hypergrad, HypergradConfig)
                else self.hypergrad)

    def _default_refresh_every(self) -> int:
        return (self.hypergrad.sketch_refresh_every
                if isinstance(self.hypergrad, HypergradConfig) else 1)

    def sketch_policy(self, refresh_every: int | None = None) -> SketchPolicy:
        """The trainer's sketch lifecycle policy. ``refresh_every`` defaults
        to the config's ``sketch_refresh_every`` (1 when ``hypergrad`` is a
        bare solver instance). Raises TypeError for iterative solvers, whose
        prepared state is trace-local (nothing to amortize)."""
        if refresh_every is None:
            refresh_every = self._default_refresh_every()
        return SketchPolicy(solver=self._built_solver(),
                            inner_loss=self.inner_loss,
                            refresh_every=refresh_every)

    def build_sketch(self, state: BilevelState, inner_batch: Any):
        """Manually prepare the solver state once (k HVPs); reuse via
        ``outer_step_with_sketch``. ``run`` does this automatically — this
        pair stays for callers that drive their own loop. Delegates to
        :class:`SketchPolicy`, which rejects iterative solvers up front
        (their trace-local state would only fail later, opaquely, inside the
        next jitted outer step)."""
        policy = self.sketch_policy()
        vjp_rng, sub = jax.random.split(state.vjp_rng)
        prepared = policy.build(state.params, state.hparams, inner_batch, sub)
        return prepared, dataclasses.replace(state, vjp_rng=vjp_rng)

    def outer_step_with_sketch(self, state: BilevelState, sketch,
                               inner_batch: Any, outer_batch: Any):
        """``outer_step_fn`` with the backward pass's ``prepare`` replaced by
        a pre-built sketch. Returns the pre-update outer loss, like
        ``outer_step_fn`` (and, like it, honors ``reset_inner``)."""
        return self._outer_update(state, inner_batch, outer_batch,
                                  sketch=sketch)

    def outer_step_with_policy(self, state: BilevelState,
                               sketch_state: SketchState, inner_batch: Any,
                               outer_batch: Any,
                               policy: SketchPolicy | None = None):
        """One outer step under the automatic sketch lifecycle: refresh the
        sketch if it has gone stale (a ``lax.cond`` — k HVPs only on refresh
        steps), then update φ against it. jit-friendly: ``sketch_state`` is
        a pytree carried across steps; its structure never changes.

        The vjp_rng stream is split every step but *consumed* only when the
        refresh fires, so at ``refresh_every=1`` the stream — and hence the
        sampled sketch columns and the whole trajectory — matches
        ``outer_step_fn`` exactly (asserted in
        tests/test_sketch_lifecycle.py)."""
        if policy is None:
            policy = self.sketch_policy()
        vjp_rng, sub = jax.random.split(state.vjp_rng)
        sketch_state, rebuilt = policy.refresh(
            sketch_state, state.params, state.hparams, inner_batch, sub)
        state = dataclasses.replace(
            state, vjp_rng=jnp.where(rebuilt, vjp_rng, state.vjp_rng))
        state, outer_loss_pre = self._outer_update(
            state, inner_batch, outer_batch, sketch=sketch_state.sketch)
        if self.reset_inner:
            # θ just jumped to a fresh init: the sketch's curvature is void
            sketch_state = policy.invalidate(sketch_state)
        return state, sketch_state, outer_loss_pre

    # ------------------------------------------------------------------ loop
    def run(self, state: BilevelState, inner_batches, outer_batches,
            steps_per_outer: int, n_outer: int, log_every: int = 0,
            jit: bool = True, sketch_refresh_every: int | None = None,
            fresh_inner_batch: bool = False):
        """Host-side loop (examples / tests). Production loop lives in
        launch/train.py with pjit + checkpointing.

        Sketch lifecycle: for amortizable solvers (Nyström/exact) the loop
        drives ``outer_step_with_policy`` — the sketch is rebuilt every
        ``sketch_refresh_every`` outer steps (argument overrides the
        ``HypergradConfig`` field; both default to 1 = fresh every step,
        which reproduces the ``outer_step_fn`` trajectory exactly) and
        reused in between, saving k HVPs per reuse step at the cost of
        linearizing the backward pass at a stale θ. Iterative solvers
        (CG/Neumann) have nothing to amortize and always prepare fresh;
        asking them for ``sketch_refresh_every > 1`` raises.

        Batch alignment: the outer step's Hessian is evaluated on the batch
        the inner unroll *ended* on — reusing it keeps the curvature aligned
        with the final θ. ``fresh_inner_batch=True`` opts into drawing one
        extra inner batch per outer step instead (the pre-fix behavior;
        decorrelates the Hessian estimate from the last inner step at the
        cost of k extra-batch HVPs off the optimization path).

        Losses are buffered as device arrays and materialized (one host
        sync for the whole buffer) only at ``log_every`` boundaries and at
        the end — a ``float()`` per inner step would force a device sync
        per step and serialize the async dispatch pipeline."""
        if sketch_refresh_every is None:
            sketch_refresh_every = self._default_refresh_every()
        solver = self._built_solver()
        if getattr(type(solver), 'amortizable', False):
            policy = SketchPolicy(solver=solver, inner_loss=self.inner_loss,
                                  refresh_every=sketch_refresh_every)
            step_fn = lambda st, ss, ib, ob: self.outer_step_with_policy(
                st, ss, ib, ob, policy)   # noqa: E731
            outer = jax.jit(step_fn) if jit else step_fn
        else:
            if sketch_refresh_every > 1:
                raise TypeError(
                    f'sketch_refresh_every={sketch_refresh_every} needs an '
                    f'amortizable solver; {type(solver).__name__} prepares a '
                    'trace-local state with nothing to reuse across steps')
            policy = None
            outer = jax.jit(self.outer_step_fn) if jit else self.outer_step_fn

        inner = jax.jit(self.inner_step_fn) if jit else self.inner_step_fn
        history = {'inner_loss': [], 'outer_loss': []}
        pending_inner: list[jax.Array] = []
        pending_outer: list[jax.Array] = []

        def flush():
            history['inner_loss'].extend(float(x) for x in pending_inner)
            history['outer_loss'].extend(float(x) for x in pending_outer)
            pending_inner.clear()
            pending_outer.clear()

        it_in, it_out = iter(inner_batches), iter(outer_batches)
        sketch_state = None
        no_batch = object()     # sentinel: None is a legitimate batch value
        for o in range(n_outer):
            ib = no_batch
            for _ in range(steps_per_outer):
                ib = next(it_in)
                state, li = inner(state, ib)
                pending_inner.append(li)
            if fresh_inner_batch or ib is no_batch:
                ib = next(it_in)
            ob = next(it_out)
            if policy is not None:
                if sketch_state is None:   # structural init: no HVPs
                    sketch_state = policy.init_state(
                        state.params, state.hparams, ib, state.vjp_rng)
                state, sketch_state, lo = outer(state, sketch_state, ib, ob)
            else:
                state, lo = outer(state, ib, ob)
            pending_outer.append(lo)
            if log_every and (o + 1) % log_every == 0:
                flush()
                f_last = (f'f={history["inner_loss"][-1]:.4f}'
                          if history['inner_loss'] else 'f=n/a')
                print(f'[bilevel] outer {o + 1}/{n_outer} '
                      f'g={history["outer_loss"][-1]:.4f} '
                      f'(pre-update) {f_last}')
        flush()
        return state, history
