"""Warm-start alternating bilevel driver (Eq. 1–2 of the paper).

Inner: θ_t = Θ(θ_{t-1}, ∇_θ f(θ_{t-1}, φ, T), φ) for T steps.
Outer: φ ← φ − η · (approximate dg/dφ via implicit differentiation).

The driver is jit-friendly: ``inner_step`` and ``outer_step`` are pure
functions over an explicit ``BilevelState`` pytree, so the trainer in
``launch/train.py`` can pjit them over the production mesh and the
checkpoint manager can snapshot the whole state atomically.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable

import jax
import jax.numpy as jnp

from repro.core.hvp import make_hvp
from repro.core.hypergrad import HypergradConfig, hypergradient
from repro.core.solvers import NystromIHVP
from repro.core.tree_util import PyTree, PyTreeIndexer
from repro.optim.optimizers import Optimizer


@jax.tree_util.register_dataclass
@dataclasses.dataclass
class BilevelState:
    params: PyTree
    hparams: PyTree
    inner_opt_state: PyTree
    outer_opt_state: PyTree
    inner_step: jax.Array   # int32 scalar
    outer_step: jax.Array   # int32 scalar
    rng: jax.Array


@dataclasses.dataclass
class BilevelTrainer:
    """Alternating warm-start bilevel optimization with pluggable IHVP solver.

    ``reset_inner`` mirrors the paper's §5.1/§5.2 protocol (re-initialize θ at
    every outer update); production LM training keeps warm starts
    (reset_inner=False, §5.4 protocol).
    """
    inner_loss: Callable[..., jax.Array]   # f(params, hparams, batch)
    outer_loss: Callable[..., jax.Array]   # g(params, hparams, batch)
    inner_opt: Optimizer
    outer_opt: Optimizer
    hypergrad: HypergradConfig
    init_params: Callable[[jax.Array], PyTree] | None = None
    reset_inner: bool = False

    def init(self, rng: jax.Array, params: PyTree, hparams: PyTree) -> BilevelState:
        return BilevelState(
            params=params,
            hparams=hparams,
            inner_opt_state=self.inner_opt.init(params),
            outer_opt_state=self.outer_opt.init(hparams),
            inner_step=jnp.int32(0),
            outer_step=jnp.int32(0),
            rng=rng,
        )

    # ------------------------------------------------------------------ inner
    def inner_step_fn(self, state: BilevelState, batch: Any) -> tuple[BilevelState, jax.Array]:
        loss, grads = jax.value_and_grad(self.inner_loss)(
            state.params, state.hparams, batch)
        params, opt_state = self.inner_opt.apply(
            grads, state.inner_opt_state, state.params, state.inner_step)
        return dataclasses.replace(
            state, params=params, inner_opt_state=opt_state,
            inner_step=state.inner_step + 1), loss

    # ------------------------------------------------------------------ outer
    def outer_step_fn(self, state: BilevelState, inner_batch: Any,
                      outer_batch: Any) -> tuple[BilevelState, jax.Array]:
        rng, sub = jax.random.split(state.rng)
        solver = self.hypergrad.build()
        indexer = PyTreeIndexer(state.params)
        hgrad = hypergradient(self.inner_loss, self.outer_loss,
                              state.params, state.hparams,
                              inner_batch, outer_batch, solver, sub, indexer)
        hparams, outer_opt_state = self.outer_opt.apply(
            hgrad, state.outer_opt_state, state.hparams, state.outer_step)
        outer_loss = self.outer_loss(state.params, state.hparams, outer_batch)

        state = dataclasses.replace(
            state, hparams=hparams, outer_opt_state=outer_opt_state,
            outer_step=state.outer_step + 1, rng=rng)

        if self.reset_inner:
            assert self.init_params is not None, 'reset_inner needs init_params'
            rng, sub = jax.random.split(state.rng)
            params = self.init_params(sub)
            state = dataclasses.replace(
                state, params=params,
                inner_opt_state=self.inner_opt.init(params),
                inner_step=jnp.int32(0), rng=rng)
        return state, outer_loss

    # ------------------------------------------- amortized-sketch outer step
    def build_sketch(self, state: BilevelState, inner_batch: Any):
        """Build a Nyström sketch once; reuse for ``sketch_refresh_every``
        outer steps (beyond-paper amortization — see EXPERIMENTS.md §Perf)."""
        solver = self.hypergrad.build()
        assert isinstance(solver, NystromIHVP)
        indexer = PyTreeIndexer(state.params)
        hvp = make_hvp(self.inner_loss, state.params, state.hparams, inner_batch)
        rng, sub = jax.random.split(state.rng)
        return solver.prepare(hvp, indexer, sub), dataclasses.replace(state, rng=rng)

    def outer_step_with_sketch(self, state: BilevelState, sketch,
                               inner_batch: Any, outer_batch: Any):
        solver = self.hypergrad.build()
        indexer = PyTreeIndexer(state.params)
        rng, sub = jax.random.split(state.rng)
        hgrad = hypergradient(self.inner_loss, self.outer_loss,
                              state.params, state.hparams,
                              inner_batch, outer_batch, solver, sub, indexer,
                              sketch=sketch)
        hparams, outer_opt_state = self.outer_opt.apply(
            hgrad, state.outer_opt_state, state.hparams, state.outer_step)
        outer_loss = self.outer_loss(state.params, state.hparams, outer_batch)
        return dataclasses.replace(
            state, hparams=hparams, outer_opt_state=outer_opt_state,
            outer_step=state.outer_step + 1, rng=rng), outer_loss

    # ------------------------------------------------------------------ loop
    def run(self, state: BilevelState, inner_batches, outer_batches,
            steps_per_outer: int, n_outer: int, log_every: int = 0,
            jit: bool = True):
        """Host-side loop (examples / tests). Production loop lives in
        launch/train.py with pjit + checkpointing."""
        inner = jax.jit(self.inner_step_fn) if jit else self.inner_step_fn
        outer = jax.jit(self.outer_step_fn) if jit else self.outer_step_fn
        history = {'inner_loss': [], 'outer_loss': []}
        it_in, it_out = iter(inner_batches), iter(outer_batches)
        for o in range(n_outer):
            for _ in range(steps_per_outer):
                state, li = inner(state, next(it_in))
                history['inner_loss'].append(float(li))
            ib, ob = next(it_in), next(it_out)
            state, lo = outer(state, ib, ob)
            history['outer_loss'].append(float(lo))
            if log_every and (o + 1) % log_every == 0:
                print(f'[bilevel] outer {o + 1}/{n_outer} '
                      f'g={float(lo):.4f} f={history["inner_loss"][-1]:.4f}')
        return state, history
