"""InfluenceService: an instrumented request/response loop over the store
and the batcher.

The in-process serving API used by ``launch/train.py --serve`` and
``benchmarks/bench_serve.py``:

    service = InfluenceService(problem, solver, params=trained)
    t = service.submit(query_example)          # parks the query
    service.pump()                             # flushes due blocks
    resp = service.result(t)                   # scores/indices + metrics

Request lifecycle: ``submit`` computes the query's per-example gradient
(one jitted grad, reused across requests), parks the vector in the
:class:`QueryBatcher`, and applies backpressure — a bounded queue raises
:class:`ServiceOverloaded` instead of growing without bound. ``pump``
flushes every due block: the prepared solver state comes from the
:class:`SketchStore` (warm hit → ZERO build HVPs billed), the block rides
``solver.apply_matrix`` as one (p, m) GEMM pass, and the streamed top-k
scan (``repro.core.make_topk_scanner``) takes the IHVP block as a jit
*argument*, so its compiled computation is reused flush after flush.

Degradation: if the sketch build fails (numerically or structurally), the
service logs a warning and falls back to a fresh per-flush CG solve — the
slow-but-dependable path — marking affected responses ``degraded=True``.

Every response carries latency/cache/batching metadata, and
``bench_rows()`` aggregates the run into schema-v2 bench rows (latency
percentiles, queue depth, cache hit rate, HVP bill) for
``benchmarks/compare_runs.py`` gating.
"""
from __future__ import annotations

import dataclasses
import logging
import time
from typing import Any, Callable

import jax
import jax.numpy as jnp

from repro.analysis.auditor import Contract, ProgramReport, audit
from repro.core.hypergrad import HypergradConfig
from repro.core.problem import (InfluenceProblem, influence_build_hvps,
                                influence_curvature_hvp, make_topk_scanner,
                                train_influence_params, _TRAIN_DEFAULTS)
from repro.core.solvers import CGIHVP
from repro.core.tree_util import PyTreeIndexer
from repro.serve.batcher import PendingQuery, QueryBatcher, calibrate_block_size, split_block
from repro.serve.store import SketchStore, sketch_key

log = logging.getLogger(__name__)

#: The docstring's hot-path claims, checkable: the flush computation —
#: ``apply_matrix`` over the (p, m) block plus the streamed top-k scan —
#: accumulates f32 everywhere and never round-trips through the host
#: (no callback may hide inside a served program).
SERVE_QUERY_CONTRACT = Contract(
    name='serve query path',
    min_accum_dtype='float32',
    no_host_transfer=True,
)


class ServiceOverloaded(RuntimeError):
    """Raised by ``submit`` when the bounded request queue is full.

    Backpressure, not buffering: the caller decides whether to retry,
    shed, or pump — the service never parks unbounded work.
    """


@dataclasses.dataclass(frozen=True)
class InfluenceRequest:
    """Bookkeeping for one in-flight query."""
    ticket: int
    t_submit: float
    deadline: float | None


@dataclasses.dataclass
class InfluenceResponse:
    """One answered query: scores plus serving metadata."""
    ticket: int
    scores: jax.Array            # (top_k,) influence scores, descending
    indices: jax.Array           # (top_k,) training-example indices
    latency_s: float             # submit → answer wall time
    batched_m: int               # width of the flush that answered it
    cache_hit: bool              # sketch came warm from the store
    degraded: bool               # answered via the CG fallback path
    deadline_missed: bool        # answered after its deadline


class InfluenceService:
    """Serve top-k influence queries against one trained model.

    Parameters
    ----------
    problem:
        The :class:`InfluenceProblem` being served.
    config:
        ``HypergradConfig`` or a built solver (uniform protocol). Must be
        amortizable for the store to engage; iterative solvers serve every
        flush fresh (and the store is bypassed).
    params:
        Trained parameters; ``None`` trains via
        ``repro.core.train_influence_params`` first.
    store:
        A shared :class:`SketchStore`; ``None`` builds a private one.
    top_k / batch_size:
        Top-k width per query and training-stream tile size (defaults from
        the problem's training defaults).
    block_size / max_delay / deadline_slack:
        Batching knobs, forwarded to :class:`QueryBatcher`. ``warmup()``
        overrides ``block_size`` with the calibrated optimum.
    max_queue:
        Bounded-queue capacity; ``submit`` past it raises
        :class:`ServiceOverloaded`.
    clock:
        Injectable time source shared with the batcher (tests drive
        deadline flushes without sleeping).
    """

    def __init__(self, problem: InfluenceProblem,
                 config: HypergradConfig | Any = None, *,
                 params: Any = None, source: Any = None,
                 store: SketchStore | None = None,
                 top_k: int = 10, batch_size: int | None = None,
                 block_size: int = 8, max_delay: float = 0.01,
                 deadline_slack: float = 0.0, max_queue: int = 64,
                 train_steps: int | None = None, seed: int = 0,
                 clock: Callable[[], float] = time.monotonic):
        if config is None:
            config = HypergradConfig()
        self.solver = (config.build() if isinstance(config, HypergradConfig)
                       else config)
        self.problem = problem
        self.source = problem.data if source is None else source
        d = {**_TRAIN_DEFAULTS, **problem.defaults}
        self.batch_size = batch_size if batch_size is not None else d['batch_size']
        self.top_k = top_k
        self.store = store if store is not None else SketchStore()
        self.clock = clock
        self.max_queue = max_queue
        self._rng = jax.random.PRNGKey(seed)

        if params is None:
            params = train_influence_params(problem, train_steps=train_steps,
                                            batch_size=self.batch_size,
                                            seed=seed)
        self.params = params
        self._indexer = PyTreeIndexer(params)
        self._hvp = influence_curvature_hvp(problem, params, self.source,
                                            self.batch_size)
        self._amortizable = getattr(type(self.solver), 'amortizable', False)
        self._key = (sketch_key(params, self.solver)
                     if self._amortizable else None)
        self._fallback = CGIHVP(rho=getattr(self.solver, 'rho', 1e-3))
        self._scan = make_topk_scanner(problem.loss, params, self.source,
                                       self.batch_size)

        loss = problem.loss

        @jax.jit
        def qgrad(p, example):
            # one example (no leading axis) → its gradient vector pytree
            return jax.grad(lambda pp: loss(
                pp, jax.tree.map(lambda x: x[None], example)))(p)

        self._qgrad = qgrad
        self.batcher = QueryBatcher(block_size=block_size,
                                    max_delay=max_delay,
                                    deadline_slack=deadline_slack,
                                    clock=clock)
        self._requests: dict[int, InfluenceRequest] = {}
        self._responses: dict[int, InfluenceResponse] = {}

        # ----- run metrics (feed bench_rows) -----
        self.latencies: list[float] = []
        self.queue_depths: list[int] = []
        self.flush_ms: list[float] = []
        self.total_queries = 0
        self.total_build_hvps = 0
        self.total_fallback_hvps = 0
        self.degraded_flushes = 0
        self.deadline_misses = 0
        self.busy_seconds = 0.0

    # ------------------------------------------------------------ submit
    def submit(self, example: Any, *, deadline_s: float | None = None) -> int:
        """Park one query example (a single unbatched pytree); returns its
        ticket. ``deadline_s`` is a relative latency budget in seconds.
        Raises :class:`ServiceOverloaded` when the queue is full."""
        if len(self.batcher) >= self.max_queue:
            raise ServiceOverloaded(
                f'request queue full ({self.max_queue} pending); '
                'pump() or shed load')
        now = self.clock()
        deadline = None if deadline_s is None else now + deadline_s
        vec = self._qgrad(self.params, example)
        ticket = self.batcher.submit(vec, deadline=deadline)
        self._requests[ticket] = InfluenceRequest(ticket=ticket,
                                                  t_submit=now,
                                                  deadline=deadline)
        self.queue_depths.append(len(self.batcher))
        self.total_queries += 1
        return ticket

    # ------------------------------------------------------------- serve
    def _prepared_state(self) -> tuple[Any, bool, bool]:
        """(state, cache_hit, degraded). Amortizable solvers go through the
        store; a failed build degrades to the CG fallback."""
        if not self._amortizable:
            return (self.solver.prepare(self._hvp, self._indexer, self._rng),
                    False, False)
        try:
            state, built = self.store.get_or_build(
                self._key,
                lambda: self.solver.prepare(self._hvp, self._indexer,
                                            self._rng),
                build_hvps=influence_build_hvps(self.solver, self.params))
            if built:
                self.total_build_hvps += influence_build_hvps(
                    self.solver, self.params)
            return state, not built, False
        except Exception:
            log.warning(
                'sketch build failed for %s; degrading this flush to fresh '
                'per-request CG', self._key, exc_info=True)
            return (self._fallback.prepare(self._hvp, self._indexer,
                                           self._rng), False, True)

    def _flush_one(self) -> int:
        """Answer one block; returns the number of queries answered."""
        t0 = self.clock()
        V, taken = self.batcher.take_block()
        m = len(taken)
        state, cache_hit, degraded = self._prepared_state()
        solver = self._fallback if degraded else self.solver
        if degraded:
            self.degraded_flushes += 1
            self.total_fallback_hvps += getattr(solver, 'iters', 0) * m
        elif not self._amortizable:
            self.total_fallback_hvps += getattr(solver, 'iters', 0) * m
        S = solver.apply_matrix(state, V)
        vals, idxs = self._scan(S, self.top_k)
        vals, idxs = jax.block_until_ready((vals, idxs))
        now = self.clock()
        for j, q in enumerate(taken):
            req = self._requests.pop(q.ticket)
            missed = req.deadline is not None and now > req.deadline
            if missed:
                self.deadline_misses += 1
            latency = now - req.t_submit
            self.latencies.append(latency)
            self._responses[q.ticket] = InfluenceResponse(
                ticket=q.ticket, scores=vals[j], indices=idxs[j],
                latency_s=latency, batched_m=m, cache_hit=cache_hit,
                degraded=degraded, deadline_missed=missed)
        self.flush_ms.append((now - t0) * 1e3)
        self.busy_seconds += now - t0
        self.queue_depths.append(len(self.batcher))
        return m

    def pump(self) -> int:
        """Flush every *due* block (full, aged out, or deadline-imminent).
        Returns queries answered. The caller's event loop invokes this
        between submissions; it never blocks waiting for block-mates."""
        n = 0
        while self.batcher.due():
            n += self._flush_one()
        return n

    def flush(self) -> int:
        """Force-flush everything pending regardless of due-ness."""
        n = 0
        while len(self.batcher):
            n += self._flush_one()
        return n

    def result(self, ticket: int) -> InfluenceResponse:
        """Pop the response for ``ticket``; raises KeyError if it has not
        been flushed yet (pump()/flush() first)."""
        if ticket not in self._responses:
            raise KeyError(
                f'ticket {ticket} not answered yet '
                f'({len(self.batcher)} queries pending — pump() or flush())')
        return self._responses.pop(ticket)

    def audit_query_path(self, m: int | None = None) -> ProgramReport:
        """Audit the warm flush computation — ``apply_matrix`` over an
        m-wide zero block followed by the top-k scan — against
        :data:`SERVE_QUERY_CONTRACT`, raising ``ContractViolation`` with
        the offending ops if the served program ever grows a host
        round-trip or a low-precision accumulation. Returns the report so
        callers can inspect collective/dot structure further."""
        m = self.batcher.block_size if m is None else m
        state, _, degraded = self._prepared_state()
        solver = self._fallback if degraded else self.solver
        Vm = jax.tree.map(
            lambda x: jnp.zeros(x.shape + (m,), jnp.float32), self.params)

        def flush(V):
            # state stays closed over: fallback states need not be pytrees
            return self._scan(solver.apply_matrix(state, V), self.top_k)

        return SERVE_QUERY_CONTRACT.enforce(audit(flush, Vm))

    # ------------------------------------------------------------ warmup
    def prepare(self) -> bool:
        """Build (or fetch) the sketch ahead of traffic, off the request
        path; returns whether it came warm from the store."""
        _, cache_hit, _ = self._prepared_state()
        return cache_hit

    def reset_metrics(self) -> None:
        """Zero the run metrics (latencies, HVP bill, queue depths) without
        touching the store or the batcher config — benchmarks call this
        after warmup so their rows measure only the serving phase."""
        self.latencies.clear()
        self.queue_depths.clear()
        self.flush_ms.clear()
        self.total_queries = 0
        self.total_build_hvps = 0
        self.total_fallback_hvps = 0
        self.degraded_flushes = 0
        self.deadline_misses = 0
        self.busy_seconds = 0.0
        self.batcher.flushes = 0
        self.batcher.flushed_queries = 0

    def warmup(self, candidates: tuple[int, ...] = (1, 2, 4, 8, 16),
               reps: int = 3) -> dict[int, float]:
        """Build (or fetch) the sketch and calibrate ``block_size`` from a
        tiny throughput sweep; returns the {m: queries/sec} profile."""
        state, _, degraded = self._prepared_state()
        solver = self._fallback if degraded else self.solver
        template = jax.tree.map(jnp.zeros_like, self.params)
        best, rates = calibrate_block_size(
            lambda V: solver.apply_matrix(state, V), template,
            candidates=candidates, reps=reps)
        self.batcher.block_size = best
        log.info('calibrated block_size=%d from sweep %s', best,
                 {m: f'{r:.1f} q/s' for m, r in rates.items()})
        return rates

    # ------------------------------------------------------------- stats
    def stats(self) -> dict[str, Any]:
        """Run-level metric snapshot (plus the store's counters)."""
        lat = sorted(self.latencies)

        def pct(p: float) -> float:
            if not lat:
                return 0.0
            return lat[min(len(lat) - 1, int(p * len(lat)))]

        depths = self.queue_depths or [0]
        return {
            'queries': self.total_queries,
            'answered': len(self.latencies),
            'flushes': self.batcher.flushes,
            'latency_mean_ms': (sum(lat) / len(lat) * 1e3) if lat else 0.0,
            'latency_p50_ms': pct(0.50) * 1e3,
            'latency_p95_ms': pct(0.95) * 1e3,
            'latency_max_ms': (lat[-1] * 1e3) if lat else 0.0,
            'queue_depth_mean': sum(depths) / len(depths),
            'queue_depth_max': max(depths),
            'build_hvps': self.total_build_hvps,
            'fallback_hvps': self.total_fallback_hvps,
            'degraded_flushes': self.degraded_flushes,
            'deadline_misses': self.deadline_misses,
            'busy_seconds': self.busy_seconds,
            'store': self.store.stats(),
        }

    def bench_rows(self, *, phase: str = 'serve') -> list[dict[str, Any]]:
        """The run as schema-v2 bench rows (one row per run).

        Identity fields (solver/backend/m/problem/phase/cache_hit_rate)
        pin the cell for ``compare_runs.py``; measurement fields (latency
        percentiles, queue depth, throughput, hvp_count) are gated or
        waived per ``repro.bench.compare.MEASURE_KEYS``.
        """
        s = self.stats()
        backend = getattr(self.solver, 'backend', 'tree')
        backend = backend if isinstance(backend, str) else getattr(
            backend, 'name', type(backend).__name__)
        qps = (s['answered'] / s['busy_seconds']
               if s['busy_seconds'] > 0 else 0.0)
        # repro: allow[bench-row-literal] — src/ cannot import benchmarks/;
        # write_bench validates these rows against the same schema contract
        return [{
            'solver': type(self.solver).__name__,
            'backend': backend,
            'm': self.batcher.block_size,
            'problem': self.problem.name,
            'phase': phase,
            'applies_per_sec': qps,
            'wall_seconds': s['busy_seconds'],
            'hvp_count': s['build_hvps'] + s['fallback_hvps'],
            'cache_hit_rate': round(self.store.hit_rate, 6),
            'latency_mean_ms': s['latency_mean_ms'],
            'latency_p50_ms': s['latency_p50_ms'],
            'latency_p95_ms': s['latency_p95_ms'],
            'latency_max_ms': s['latency_max_ms'],
            'queue_depth_mean': s['queue_depth_mean'],
            'queue_depth_max': s['queue_depth_max'],
            'degraded_flushes': self.degraded_flushes,
            'deadline_misses': self.deadline_misses,
        }]
