"""SketchStore: a content-addressed cache of prepared solver states.

The cache key is *content*, not identity: the params half is a sha256
digest of the parameter pytree (``repro.checkpoint.params_digest`` — the
same bytes a checkpoint save would write), and the solver half is
``repro.core.solver_fingerprint`` — the subset of solver config that
changes the prepared state (k, backend, sketch_dtype, ...). Crucially the
fingerprint is ρ-free: the whitened Woodbury apply retargets one sketch
across damping values, so a store hit survives a ρ sweep.

Eviction is LRU under a byte budget, with byte accounting from
``repro.core.state_nbytes`` (a NystromSketch is ~2·k·p·itemsize; a
DenseFactor p²). Staleness is serve-count based: entries wired to a
``SketchPolicy`` inherit its ``refresh_every`` as a max-serves bound, so
"rebuild every N uses" means the same thing in the trainer loop and the
serving tier.

Everything here is bookkeeping — no JAX tracing, no HVPs. The only
expensive call the store ever makes is the ``build`` thunk handed to
``get_or_build``, and the hit/miss counters plus per-entry ``build_hvps``
make the amortization auditable: a warm hit bills zero HVPs, and the
regression test in tests/test_serve.py pins that.
"""
from __future__ import annotations

import dataclasses
from collections import OrderedDict
from pathlib import Path
from typing import Any, Callable

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import params_digest
from repro.core.solvers import SketchPolicy, solver_fingerprint, state_nbytes


@dataclasses.dataclass(frozen=True)
class SketchKey:
    """Content address of a prepared solver state.

    ``params``: 16-hex digest of the parameter pytree (checkpoint identity).
    ``solver``: fingerprint of the solver's prepared-state config (ρ-free).
    """
    params: str
    solver: str

    def __str__(self) -> str:
        return f'{self.params}/{self.solver}'


def sketch_key(params: Any, solver: Any) -> SketchKey:
    """The cache key for ``solver.prepare(...)`` at ``params``.

    Raises TypeError for non-amortizable solvers (their "state" is a
    trace-local operator — there is nothing to cache).
    """
    return SketchKey(params=params_digest(params),
                     solver=solver_fingerprint(solver))


@dataclasses.dataclass
class CacheEntry:
    """One cached state plus its accounting."""
    state: Any
    nbytes: int
    build_hvps: int
    serves: int = 0


class SketchStore:
    """LRU cache of prepared solver states under a byte budget.

    Parameters
    ----------
    byte_budget:
        Soft ceiling on total cached bytes. Inserting past it evicts
        least-recently-used entries until the new total fits; the entry
        being inserted is always kept, even if it alone exceeds the budget
        (a cache that cannot hold one sketch would silently disable
        amortization — better to hold exactly one).
    max_serves:
        Optional staleness bound: an entry that has answered this many
        ``get_or_build`` hits is discarded and rebuilt on the next request.
        ``None`` (default) means entries never age out by use.
    policy:
        Optional :class:`~repro.core.SketchPolicy`; wiring one in adopts its
        ``refresh_every`` as ``max_serves`` (unless ``refresh_every == 1``,
        the always-fresh trainer cadence, which would defeat caching — the
        store treats it as "no staleness bound" and leaves invalidation to
        the explicit hooks). This keeps ONE definition of "stale" across
        the trainer loop and the serving tier.

    spill_dir:
        Optional directory for the disk tier. When set, :meth:`save_entry`
        spills cached states to ``<params>__<solver>.npz`` files there, and
        ``get_or_build`` (given a ``like`` template) resolves memory misses
        from disk before paying for a build — a disk hit bills **zero**
        HVPs and returns ``built=False`` exactly like a warm memory hit.

    Counters (``hits``/``misses``/``disk_hits``/``evictions``/
    ``invalidations``/``expirations``) and ``hit_rate`` feed the schema-v2
    bench rows.
    """

    def __init__(self, byte_budget: int = 1 << 30, *,
                 max_serves: int | None = None,
                 policy: SketchPolicy | None = None,
                 spill_dir: str | Path | None = None):
        if byte_budget <= 0:
            raise ValueError(f'byte_budget must be positive, got {byte_budget}')
        if policy is not None and max_serves is None and policy.refresh_every > 1:
            max_serves = policy.refresh_every
        if max_serves is not None and max_serves < 1:
            raise ValueError(f'max_serves must be >= 1, got {max_serves}')
        self.byte_budget = byte_budget
        self.max_serves = max_serves
        self.spill_dir = Path(spill_dir) if spill_dir is not None else None
        self._entries: OrderedDict[SketchKey, CacheEntry] = OrderedDict()
        self.hits = 0
        self.misses = 0
        self.disk_hits = 0
        self.evictions = 0
        self.invalidations = 0
        self.expirations = 0

    # ------------------------------------------------------------ lookup
    def get_or_build(self, key: SketchKey, build: Callable[[], Any], *,
                     build_hvps: int = 0, like: Any = None) -> tuple[Any, bool]:
        """Return ``(state, built)`` for ``key``.

        On a hit: moves the entry to most-recently-used, bumps its serve
        count, returns ``(state, False)`` — zero HVPs ran. On a memory miss
        with a disk tier (``spill_dir`` set *and* a ``like`` template, e.g.
        ``jax.eval_shape(build)``): a matching spill file re-enters the
        memory tier with ``build_hvps=0`` and returns ``(state, False)`` —
        a disk hit never re-bills the sketch HVPs. Otherwise: calls
        ``build()`` (the k sketch HVPs), inserts under the byte budget,
        returns ``(state, True)``. A failed ``build`` propagates and caches
        nothing.
        """
        entry = self._entries.get(key)
        if entry is not None:
            if self.max_serves is not None and entry.serves >= self.max_serves:
                del self._entries[key]
                self.expirations += 1
            else:
                self._entries.move_to_end(key)
                entry.serves += 1
                self.hits += 1
                return entry.state, False
        if self.spill_dir is not None and like is not None:
            state = self.load_entry(key, like, missing_ok=True)
            if state is not None:
                self.disk_hits += 1
                self._insert(key, CacheEntry(
                    state=state, nbytes=state_nbytes(state),
                    build_hvps=0, serves=1))
                return state, False
        self.misses += 1
        state = build()
        self._insert(key, CacheEntry(state=state, nbytes=state_nbytes(state),
                                     build_hvps=int(build_hvps), serves=1))
        return state, True

    # ---------------------------------------------------------- disk tier
    def _spill_path(self, key: SketchKey) -> Path:
        if self.spill_dir is None:
            raise ValueError('store has no spill_dir — pass one to spill '
                             'entries to disk')
        return self.spill_dir / f'{key.params}__{key.solver}.npz'

    def save_entry(self, key: SketchKey) -> Path:
        """Spill one cached entry to ``spill_dir`` and return the file path.

        The file is content-addressed by the same digest×fingerprint pair as
        the memory tier, so a later process (or a later :class:`SketchStore`
        pointed at the same directory) resolves the identical key without
        re-running the build HVPs. Leaves are stored positionally; the
        pytree structure is reimposed by the ``like`` template at load time.
        Raises ``KeyError`` if the key is not cached in memory.
        """
        path = self._spill_path(key)
        entry = self._entries[key]
        path.parent.mkdir(parents=True, exist_ok=True)
        leaves = jax.tree.leaves(entry.state)
        arrays = {f'leaf{i}': np.asarray(v) for i, v in enumerate(leaves)}
        tmp = path.with_suffix('.npz.tmp')
        with open(tmp, 'wb') as f:
            np.savez(f, **arrays)
        tmp.replace(path)          # atomic publish: readers never see a torn file
        return path

    def load_entry(self, key: SketchKey, like: Any, *,
                   missing_ok: bool = False) -> Any:
        """Load a spilled state for ``key``, shaped by the ``like`` template.

        ``like`` supplies the pytree structure and leaf shapes/dtypes —
        ``jax.eval_shape(build)`` gives one without running any HVPs. A
        shape or dtype mismatch (a stale spill from a different config that
        somehow collided) raises ``ValueError`` rather than returning a
        corrupt sketch. Returns ``None`` on a missing file when
        ``missing_ok`` is set, else raises ``FileNotFoundError``.
        """
        path = self._spill_path(key)
        if not path.exists():
            if missing_ok:
                return None
            raise FileNotFoundError(f'no spilled entry at {path}')
        like_leaves, treedef = jax.tree.flatten(like)
        with np.load(path) as data:
            if len(data.files) != len(like_leaves):
                raise ValueError(
                    f'spill {path.name} holds {len(data.files)} leaves, '
                    f'template has {len(like_leaves)}')
            leaves = []
            for i, tmpl in enumerate(like_leaves):
                arr = data[f'leaf{i}']
                if tuple(arr.shape) != tuple(tmpl.shape) \
                        or arr.dtype != tmpl.dtype:
                    raise ValueError(
                        f'spill {path.name} leaf{i} is '
                        f'{arr.dtype}{list(arr.shape)}, template expects '
                        f'{tmpl.dtype}{list(tmpl.shape)}')
                leaves.append(jnp.asarray(arr))
        return jax.tree.unflatten(treedef, leaves)

    def _insert(self, key: SketchKey, entry: CacheEntry) -> None:
        self._entries.pop(key, None)
        self._entries[key] = entry
        while (self.total_bytes > self.byte_budget
               and next(iter(self._entries)) is not key):
            self._entries.popitem(last=False)
            self.evictions += 1

    # ------------------------------------------------------- invalidation
    def invalidate(self, key: SketchKey) -> bool:
        """Drop one entry (e.g. its params were re-trained). Returns whether
        anything was dropped."""
        if self._entries.pop(key, None) is not None:
            self.invalidations += 1
            return True
        return False

    def invalidate_params(self, digest: str) -> int:
        """Drop every entry prepared at the given params digest — the hook a
        checkpoint refresh calls: new params, every sketch at the old ones
        is wrong regardless of solver config. Returns the count dropped."""
        doomed = [k for k in self._entries if k.params == digest]
        for k in doomed:
            del self._entries[k]
        self.invalidations += len(doomed)
        return len(doomed)

    def clear(self) -> int:
        """Drop everything (counts as invalidations). Returns count."""
        n = len(self._entries)
        self._entries.clear()
        self.invalidations += n
        return n

    # ------------------------------------------------------------- stats
    @property
    def total_bytes(self) -> int:
        return sum(e.nbytes for e in self._entries.values())

    @property
    def hit_rate(self) -> float:
        total = self.hits + self.misses
        return self.hits / total if total else 0.0

    def keys(self) -> list[SketchKey]:
        """Cached keys, least-recently-used first (eviction order)."""
        return list(self._entries)

    def __len__(self) -> int:
        return len(self._entries)

    def __contains__(self, key: SketchKey) -> bool:
        return key in self._entries

    def stats(self) -> dict[str, Any]:
        """Counter snapshot for bench rows / logs."""
        return {
            'entries': len(self._entries),
            'total_bytes': self.total_bytes,
            'hits': self.hits,
            'misses': self.misses,
            'hit_rate': self.hit_rate,
            'evictions': self.evictions,
            'invalidations': self.invalidations,
            'expirations': self.expirations,
        }
