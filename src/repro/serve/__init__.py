"""repro.serve — the influence serving tier.

The paper's amortization story, taken to its operational conclusion: a
Nyström sketch is k HVPs to build and then answers IHVP queries as pure
matrix arithmetic, so a *serving* layer should build it once and reuse it
across every query that shares a linearization point. Three layers:

  SketchStore       content-addressed LRU cache of prepared solver states,
                    keyed by (params digest, solver fingerprint) — a warm
                    hit answers queries with ZERO build HVPs
  QueryBatcher      adaptive micro-batching of single query vectors into
                    the (p, m) blocks ``apply_matrix`` is throughput-
                    optimal at, flushing on deadline or block-size
  InfluenceService  an in-process request/response loop over both, with
                    bounded-queue backpressure, per-request deadlines,
                    CG degradation on sketch-build failure, and schema-v2
                    bench metrics

See docs/serving.md for the quickstart and the metrics schema.
"""
from repro.serve.batcher import PendingQuery, QueryBatcher, calibrate_block_size
from repro.serve.service import (InfluenceRequest, InfluenceResponse,
                                 InfluenceService, ServiceOverloaded)
from repro.serve.store import CacheEntry, SketchKey, SketchStore, sketch_key

__all__ = [
    'CacheEntry', 'InfluenceRequest', 'InfluenceResponse', 'InfluenceService',
    'PendingQuery', 'QueryBatcher', 'ServiceOverloaded', 'SketchKey',
    'SketchStore', 'calibrate_block_size', 'sketch_key',
]
