"""QueryBatcher: adaptive micro-batching of IHVP queries into (p, m) blocks.

``apply_matrix`` answers m queries against one sketch in two GEMM passes —
near-flat cost in m until the GEMMs saturate — so a serving loop should
batch aggressively. But batching trades latency: a query parked waiting
for block-mates is a query not answered. This module makes that trade a
config field instead of a caller decision:

  * queries accumulate until the block is FULL (``block_size``), the
    oldest query has waited ``max_delay`` seconds, or a per-query deadline
    is about to expire — whichever comes first;
  * ``block_size`` itself can be calibrated from a tiny warmup sweep
    (:func:`calibrate_block_size`) that measures actual per-query
    throughput at candidate widths against the live sketch.

The clock is injectable (``clock=``) so tests drive deadline/delay flushes
deterministically without sleeping.

Blocks are built by stacking query pytrees along a new trailing axis
(``jax.tree.map(lambda *xs: jnp.stack(xs, axis=-1), *vecs)``) — exactly
the (p, m) layout ``apply_matrix`` takes — and results are scattered back
per query by slicing that axis. At m=1 the solvers statically dispatch the
block apply to the vector apply, so a single query flushed through the
batcher is *bitwise* identical to calling ``solver.apply`` directly
(tests/test_serve.py pins this, reusing the m=1 machinery from
tests/test_block_apply.py).
"""
from __future__ import annotations

import dataclasses
import time
from typing import Any, Callable

import jax
import jax.numpy as jnp


@dataclasses.dataclass
class PendingQuery:
    """One parked query vector awaiting a flush."""
    ticket: int
    vector: Any                       # pytree, same structure as params
    t_submit: float
    deadline: float | None = None     # absolute clock time, or None

    def latest_flush(self, max_delay: float, slack: float) -> float:
        """The clock time by which this query must be in a flush."""
        t = self.t_submit + max_delay
        if self.deadline is not None:
            t = min(t, self.deadline - slack)
        return t


def stack_block(vectors: list[Any]) -> Any:
    """Stack m query pytrees into one (p, m) block (new trailing axis)."""
    return jax.tree.map(lambda *xs: jnp.stack(xs, axis=-1), *vectors)


def split_block(block: Any, m: int) -> list[Any]:
    """Inverse of :func:`stack_block`: the m per-query columns."""
    return [jax.tree.map(lambda x: x[..., j], block) for j in range(m)]


class QueryBatcher:
    """Accumulates query vectors; decides when a (p, m) flush is due.

    Parameters
    ----------
    block_size:
        Target m. A flush is due the moment this many queries are parked.
    max_delay:
        Seconds the *oldest* parked query may wait before a partial flush.
        0 means flush-on-submit (no batching).
    deadline_slack:
        Seconds before a query's deadline at which a flush is forced —
        headroom for the apply itself. Only matters for queries submitted
        with explicit deadlines.
    clock:
        Monotonic time source; injectable for deterministic tests.
    """

    def __init__(self, block_size: int = 8, max_delay: float = 0.01, *,
                 deadline_slack: float = 0.0,
                 clock: Callable[[], float] = time.monotonic):
        if block_size < 1:
            raise ValueError(f'block_size must be >= 1, got {block_size}')
        if max_delay < 0:
            raise ValueError(f'max_delay must be >= 0, got {max_delay}')
        self.block_size = block_size
        self.max_delay = max_delay
        self.deadline_slack = deadline_slack
        self.clock = clock
        self._pending: list[PendingQuery] = []
        self._next_ticket = 0
        self.flushes = 0
        self.flushed_queries = 0

    def __len__(self) -> int:
        return len(self._pending)

    def submit(self, vector: Any, *, deadline: float | None = None) -> int:
        """Park one query vector; returns its ticket."""
        ticket = self._next_ticket
        self._next_ticket += 1
        self._pending.append(PendingQuery(ticket=ticket, vector=vector,
                                          t_submit=self.clock(),
                                          deadline=deadline))
        return ticket

    def due(self, now: float | None = None) -> bool:
        """Is a flush due? Full block, aged-out oldest query, or an
        imminent deadline."""
        if not self._pending:
            return False
        if len(self._pending) >= self.block_size:
            return True
        now = self.clock() if now is None else now
        return any(q.latest_flush(self.max_delay, self.deadline_slack) <= now
                   for q in self._pending)

    def next_due_at(self) -> float | None:
        """Clock time of the next forced flush (None when queue is empty).
        A pump loop sleeps until min(this, next submission)."""
        if not self._pending:
            return None
        return min(q.latest_flush(self.max_delay, self.deadline_slack)
                   for q in self._pending)

    def take_block(self) -> tuple[Any, list[PendingQuery]]:
        """Pop the oldest ≤ block_size queries as one (p, m) block.

        Returns ``(block, taken)``; callers apply the block and scatter the
        result columns back to ``taken`` in order (``split_block``). Raises
        if the queue is empty — guard with ``len(batcher)``.
        """
        if not self._pending:
            raise ValueError('take_block() on an empty batcher')
        taken = self._pending[:self.block_size]
        self._pending = self._pending[self.block_size:]
        self.flushes += 1
        self.flushed_queries += len(taken)
        return stack_block([q.vector for q in taken]), taken


def calibrate_block_size(apply_block: Callable[[Any], Any], template: Any,
                         candidates: tuple[int, ...] = (1, 2, 4, 8, 16),
                         reps: int = 3) -> tuple[int, dict[int, float]]:
    """Pick the throughput-optimal m from a tiny warmup sweep.

    ``apply_block(V)`` is the service's block apply closed over the live
    sketch state; ``template`` is one query-shaped pytree used to build
    synthetic blocks. Each candidate m is timed over ``reps`` applies
    (after one untimed warmup that absorbs compilation) and scored as
    queries/sec; returns ``(best_m, {m: queries_per_sec})``.

    The sweep is O(len(candidates) · reps) block applies against an
    already-built sketch — no HVPs, a few milliseconds at serving scale —
    and is run once at service start, not per request.
    """
    rates: dict[int, float] = {}
    for m in candidates:
        block = jax.tree.map(
            lambda x: jnp.broadcast_to(x[..., None],
                                       x.shape + (m,)).astype(x.dtype),
            template)
        out = apply_block(block)                      # warmup / compile
        jax.block_until_ready(out)
        t0 = time.perf_counter()
        for _ in range(reps):
            jax.block_until_ready(apply_block(block))
        dt = time.perf_counter() - t0
        rates[m] = (m * reps) / dt if dt > 0 else float('inf')
    best = max(rates, key=lambda m: (rates[m], -m))
    return best, rates
