"""Program auditor: structured reports over the programs this repo compiles.

The structural guarantees the paper's "matrix operations, no iterations"
claim rests on — one (k, m) psum per ``flat_sharded`` apply pass, no
all-gather of a parameter leaf, f32 accumulation under bf16 sketch storage,
no host round-trips on the hot path — used to live as substring greps over
lowered HLO text. ``audit`` replaces the grep: it lowers a function and
walks **three layers** of the same program,

  * the **jaxpr** (recursively, through pjit/scan/shard_map/custom_vjp/
    pallas_call sub-jaxprs): collective eqns with their mesh axes and
    reduction dtypes, ``dot_general``/conv accumulation dtypes
    (``preferred_element_type`` vs operand dtypes), host callbacks, and
    ``custom_vjp`` boundaries;
  * the **lowered StableHLO** text: collective op counts (shard_map
    collectives appear here exactly as written, pre-optimization), host
    callback custom-calls, and materialized constant sizes;
  * optionally the **compiled HLO** text (``compile=True``): the
    collectives that actually execute, including any GSPMD-inserted
    all-gathers that exist in no earlier layer (byte totals via
    ``repro.launch.analysis.collective_bytes`` — the same parser the
    roofline dry-runs use).

A :class:`Contract` is the declarative check over the resulting
:class:`ProgramReport`: ``Contract(no_all_gather=True,
exact_collectives={'psum': 1}, min_accum_dtype='float32')`` renders precise
violations (op kind, shape, dtype, mesh axes, source layer) instead of a
substring miss. See docs/static-analysis.md.
"""
from __future__ import annotations

import dataclasses
import re
from typing import Any, Callable, Mapping

import jax

__all__ = ['OpRecord', 'DotRecord', 'TransferRecord', 'ConstRecord',
           'ProgramReport', 'Contract', 'ContractViolation', 'Violation',
           'audit', 'audit_jaxpr', 'canonical_collective']

# ---------------------------------------------------------------------------
# Canonical collective naming.  Three spellings reach us: jaxpr primitive
# names (psum / psum2 / all_gather ...), StableHLO ops (stablehlo.all_reduce),
# and compiled-HLO ops (all-reduce).  Contracts accept any alias.
# ---------------------------------------------------------------------------
_CANONICAL = {
    'psum': 'all-reduce', 'psum2': 'all-reduce', 'all_reduce': 'all-reduce',
    'all-reduce': 'all-reduce', 'pmax': 'all-reduce', 'pmin': 'all-reduce',
    'all_gather': 'all-gather', 'all-gather': 'all-gather',
    'reduce_scatter': 'reduce-scatter', 'reduce-scatter': 'reduce-scatter',
    'psum_scatter': 'reduce-scatter',
    'all_to_all': 'all-to-all', 'all-to-all': 'all-to-all',
    'ppermute': 'collective-permute', 'collective_permute':
        'collective-permute', 'collective-permute': 'collective-permute',
}

# jaxpr primitives that are host round-trips
_CALLBACK_PRIMS = ('pure_callback', 'io_callback', 'debug_callback',
                   'callback')
# StableHLO custom_call targets that are host round-trips (sharding
# annotations etc. are also custom_calls — only these leave the device)
_HOST_CALL_TARGETS = ('xla_python_cpu_callback', 'xla_ffi_python_cpu_callback',
                      'xla_python_gpu_callback', 'xla_ffi_partitioned_callback')

_CUSTOM_VJP_PRIMS = ('custom_vjp_call', 'custom_vjp_call_jaxpr')

# float dtype -> precision rank for min_accum_dtype ordering
_FLOAT_BITS = {'bfloat16': 16, 'float16': 16, 'float8_e4m3fn': 8,
               'float8_e5m2': 8, 'float32': 32, 'float64': 64}


def canonical_collective(name: str) -> str:
    """Canonical kind for any spelling ('psum' → 'all-reduce'); unknown
    names pass through unchanged so contracts fail loudly, not silently."""
    return _CANONICAL.get(name, name)


def _float_bits(dtype: Any) -> int | None:
    return _FLOAT_BITS.get(str(dtype))


# ---------------------------------------------------------------------------
# Report records
# ---------------------------------------------------------------------------
@dataclasses.dataclass(frozen=True)
class OpRecord:
    """One collective op: canonical kind, result dtype/shape, where it was
    seen ('jaxpr' | 'stablehlo' | 'hlo'), and detail (mesh axes for jaxpr
    collectives, the source line for HLO ones)."""
    kind: str
    dtype: str
    shape: tuple[int, ...]
    source: str
    detail: str = ''

    def render(self) -> str:
        extra = f' [{self.detail}]' if self.detail else ''
        return (f'{self.kind} {self.dtype}{list(self.shape)} '
                f'({self.source}){extra}')


@dataclasses.dataclass(frozen=True)
class DotRecord:
    """One dot/conv: operand dtypes and the dtype it accumulates in
    (``preferred_element_type`` when set, else the output dtype)."""
    primitive: str
    operand_dtypes: tuple[str, ...]
    accum_dtype: str
    out_shape: tuple[int, ...]
    preferred: bool          # accumulation dtype was explicitly requested

    def render(self) -> str:
        pref = 'preferred' if self.preferred else 'implicit'
        return (f'{self.primitive}({" x ".join(self.operand_dtypes)}) '
                f'-> {self.accum_dtype}{list(self.out_shape)} ({pref})')


@dataclasses.dataclass(frozen=True)
class TransferRecord:
    """One host round-trip (callback primitive or host custom-call)."""
    kind: str
    source: str
    detail: str = ''

    def render(self) -> str:
        extra = f' [{self.detail}]' if self.detail else ''
        return f'{self.kind} ({self.source}){extra}'


@dataclasses.dataclass(frozen=True)
class ConstRecord:
    """One materialized StableHLO constant."""
    dtype: str
    shape: tuple[int, ...]
    nbytes: int


@dataclasses.dataclass
class ProgramReport:
    """Everything a :class:`Contract` checks, from one lowered program."""
    collectives: list[OpRecord]
    dots: list[DotRecord]
    host_transfers: list[TransferRecord]
    custom_vjp_calls: int
    constants: list[ConstRecord]
    stablehlo: str = ''
    hlo: str | None = None
    collective_nbytes: dict[str, int] | None = None   # compiled HLO only

    def records(self, kind: str | None = None,
                source: str | None = None) -> list[OpRecord]:
        kind = canonical_collective(kind) if kind is not None else None
        return [r for r in self.collectives
                if (kind is None or r.kind == kind)
                and (source is None or r.source == source)]

    def count(self, kind: str, source: str = 'stablehlo') -> int:
        """Collective count by canonical kind (aliases accepted) in one
        source layer — 'stablehlo' is the stable pre-optimization count."""
        return len(self.records(kind, source))

    def counts(self, source: str = 'stablehlo') -> dict[str, int]:
        out: dict[str, int] = {}
        for r in self.records(source=source):
            out[r.kind] = out.get(r.kind, 0) + 1
        return out

    @property
    def sources(self) -> tuple[str, ...]:
        seen = []
        for s in ('jaxpr', 'stablehlo', 'hlo'):
            if s == 'hlo' and self.hlo is None:
                continue
            seen.append(s)
        return tuple(seen)

    def constant_bytes(self) -> int:
        return sum(c.nbytes for c in self.constants)


# ---------------------------------------------------------------------------
# jaxpr walking
# ---------------------------------------------------------------------------
def _sub_jaxprs(params: Mapping[str, Any]):
    from jax import core as jcore
    for value in params.values():
        items = value if isinstance(value, (list, tuple)) else (value,)
        for item in items:
            if isinstance(item, jcore.ClosedJaxpr):
                yield item.jaxpr
            elif isinstance(item, jcore.Jaxpr):
                yield item


def _walk_jaxpr(jaxpr, report: ProgramReport) -> None:
    for eqn in jaxpr.eqns:
        name = eqn.primitive.name
        if name in _CANONICAL:
            axes = eqn.params.get('axes') or eqn.params.get('axis_name')
            out = eqn.outvars[0].aval
            report.collectives.append(OpRecord(
                kind=canonical_collective(name), dtype=str(out.dtype),
                shape=tuple(out.shape), source='jaxpr',
                detail=f'axes={tuple(axes)}' if axes else ''))
        elif name in ('dot_general', 'conv_general_dilated'):
            out = eqn.outvars[0].aval
            pet = eqn.params.get('preferred_element_type')
            report.dots.append(DotRecord(
                primitive=name,
                operand_dtypes=tuple(str(v.aval.dtype) for v in eqn.invars),
                accum_dtype=str(pet) if pet is not None else str(out.dtype),
                out_shape=tuple(out.shape), preferred=pet is not None))
        elif name in _CALLBACK_PRIMS:
            report.host_transfers.append(TransferRecord(
                kind=name, source='jaxpr'))
        if name in _CUSTOM_VJP_PRIMS:
            report.custom_vjp_calls += 1
        _walk_params = eqn.params
        for sub in _sub_jaxprs(_walk_params):
            _walk_jaxpr(sub, report)


# ---------------------------------------------------------------------------
# StableHLO / compiled-HLO text parsing
# ---------------------------------------------------------------------------
_STABLEHLO_COLL_RE = re.compile(
    r'stablehlo\.(all_reduce|all_gather|reduce_scatter|all_to_all|'
    r'collective_permute)\b')
_TENSOR_RE = re.compile(r'tensor<((?:\d+x)*)([a-z0-9_]+)>')
_CONST_RE = re.compile(r'stablehlo\.constant\b')
_CUSTOM_CALL_RE = re.compile(r'stablehlo\.custom_call\s+@(\w+)')

_MLIR_DTYPE_BYTES = {'f64': 8, 'f32': 4, 'f16': 2, 'bf16': 2, 'i64': 8,
                     'ui64': 8, 'i32': 4, 'ui32': 4, 'i16': 2, 'ui16': 2,
                     'i8': 1, 'ui8': 1, 'i1': 1, 'f8e4m3fn': 1, 'f8e5m2': 1}

_HLO_COLLECTIVES = ('all-reduce', 'all-gather', 'reduce-scatter',
                    'all-to-all', 'collective-permute')
_HLO_LINE_RE = re.compile(
    r'^\s*(?:ROOT\s+)?%[\w.-]+\s*=\s*(\([^)]*\)|[^=(]+?)\s+('
    + '|'.join(_HLO_COLLECTIVES) + r')(?:-start|-done)?\(')
_HLO_SHAPE_RE = re.compile(r'(\w+)\[([\d,]*)\]')


def _tensor_on_line(line: str) -> tuple[tuple[int, ...], str]:
    """Best-effort (shape, dtype) from an MLIR line: the result type after
    '->' when the type signature is on this line, else unknown (ops with
    regions — all_reduce — close their signature lines later; attribute
    tensors like replica_groups must not be mistaken for the result)."""
    _, arrow, result = line.partition('->')
    if arrow:
        matches = _TENSOR_RE.findall(result)
    elif 'constant' in line:
        matches = _TENSOR_RE.findall(line)
    else:
        matches = []
    if not matches:
        return (), '?'
    dims, dtype = matches[-1]
    shape = tuple(int(d) for d in dims.split('x') if d)
    return shape, dtype


def _parse_stablehlo(text: str, report: ProgramReport) -> None:
    for line in text.splitlines():
        m = _STABLEHLO_COLL_RE.search(line)
        if m:
            shape, dtype = _tensor_on_line(line)
            report.collectives.append(OpRecord(
                kind=canonical_collective(m.group(1)), dtype=dtype,
                shape=shape, source='stablehlo', detail=line.strip()[:120]))
        cc = _CUSTOM_CALL_RE.search(line)
        if cc and cc.group(1) in _HOST_CALL_TARGETS:
            report.host_transfers.append(TransferRecord(
                kind=cc.group(1), source='stablehlo',
                detail=line.strip()[:120]))
        if _CONST_RE.search(line):
            shape, dtype = _tensor_on_line(line)
            n = 1
            for d in shape:
                n *= d
            report.constants.append(ConstRecord(
                dtype=dtype, shape=shape,
                nbytes=n * _MLIR_DTYPE_BYTES.get(dtype, 4)))


def _parse_hlo(text: str, report: ProgramReport) -> None:
    for line in text.splitlines():
        if '-done(' in line:
            continue                      # same transfer as its -start
        m = _HLO_LINE_RE.match(line)
        if not m:
            continue
        sm = _HLO_SHAPE_RE.search(m.group(1))
        shape: tuple[int, ...] = ()
        dtype = '?'
        if sm:
            dtype = sm.group(1)
            shape = tuple(int(d) for d in sm.group(2).split(',') if d)
        report.collectives.append(OpRecord(
            kind=canonical_collective(m.group(2)), dtype=dtype, shape=shape,
            source='hlo', detail=line.strip()[:120]))


# ---------------------------------------------------------------------------
# audit
# ---------------------------------------------------------------------------
def audit_jaxpr(closed_jaxpr) -> ProgramReport:
    """Walk an already-built ClosedJaxpr into a (text-less) report."""
    report = ProgramReport(collectives=[], dots=[], host_transfers=[],
                           custom_vjp_calls=0, constants=[])
    _walk_jaxpr(closed_jaxpr.jaxpr, report)
    return report


def audit(fn: Callable, *args, compile: bool = False,
          static_argnums=(), **kwargs) -> ProgramReport:
    """Lower ``fn(*args, **kwargs)`` and walk jaxpr + StableHLO (and, with
    ``compile=True``, the compiled HLO — the only layer where
    GSPMD-inserted collectives exist) into a :class:`ProgramReport`.

    ``fn`` is traced as-is (wrap in ``functools.partial`` for static
    configuration); sharded operands placed with ``jax.device_put`` carry
    their shardings into the lowering exactly as ``jax.jit(fn).lower``
    would see them.
    """
    jitted = jax.jit(fn, static_argnums=static_argnums)
    report = ProgramReport(collectives=[], dots=[], host_transfers=[],
                           custom_vjp_calls=0, constants=[])
    _walk_jaxpr(jax.make_jaxpr(fn, static_argnums=static_argnums)(
        *args, **kwargs).jaxpr, report)
    lowered = jitted.lower(*args, **kwargs)
    report.stablehlo = lowered.as_text()
    _parse_stablehlo(report.stablehlo, report)
    if compile:
        report.hlo = lowered.compile().as_text()
        _parse_hlo(report.hlo, report)
        from repro.launch.analysis import collective_bytes
        report.collective_nbytes = collective_bytes(report.hlo)['bytes']
    return report


# ---------------------------------------------------------------------------
# Contracts
# ---------------------------------------------------------------------------
@dataclasses.dataclass(frozen=True)
class Violation:
    """One broken guarantee, renderable with full op context."""
    rule: str
    message: str

    def __str__(self) -> str:
        return f'[{self.rule}] {self.message}'


class ContractViolation(AssertionError):
    """Raised by ``Contract.enforce`` — carries every violation."""

    def __init__(self, contract: 'Contract', violations: list[Violation]):
        self.contract = contract
        self.violations = violations
        name = contract.name or 'program contract'
        super().__init__(
            f'{name}: {len(violations)} violation(s)\n  '
            + '\n  '.join(str(v) for v in violations))


@dataclasses.dataclass(frozen=True)
class Contract:
    """A declarative set of structural guarantees over a ProgramReport.

    Fields (all optional — unset fields check nothing):

    ``no_all_gather``
        No all-gather in ANY layer (lowered StableHLO *and*, when the
        report was compiled, optimized HLO — where GSPMD inserts the
        gathers that exist nowhere else).
    ``max_collectives`` / ``exact_collectives`` / ``min_collectives``
        {kind: count} bounds on the **lowered StableHLO** collective
        counts (the stable pre-optimization layer — compiled-HLO op counts
        move under fusion). Kinds accept aliases: 'psum' == 'all-reduce'.
    ``min_accum_dtype``
        Every float dot/conv must accumulate in at least this dtype
        (bf16-operand dots must carry ``preferred_element_type``).
    ``min_reduction_dtype``
        Every collective's result dtype must be at least this wide
        (bf16 operands may ride a psum only after widening to f32).
    ``no_host_transfer``
        No callback primitives / host custom-calls anywhere.
    ``max_constant_bytes``
        Cap on total bytes of materialized StableHLO constants (a baked-in
        operand that should have been an argument).
    """
    name: str = ''
    no_all_gather: bool = False
    max_collectives: Mapping[str, int] | None = None
    exact_collectives: Mapping[str, int] | None = None
    min_collectives: Mapping[str, int] | None = None
    min_accum_dtype: str | None = None
    min_reduction_dtype: str | None = None
    no_host_transfer: bool = False
    max_constant_bytes: int | None = None

    # ------------------------------------------------------------- checks
    def check(self, report: ProgramReport) -> list[Violation]:
        """Every violated guarantee, precisely rendered; [] when clean."""
        v: list[Violation] = []
        if self.no_all_gather:
            for src in ('stablehlo', 'hlo'):
                for rec in report.records('all-gather', src):
                    v.append(Violation(
                        'no_all_gather',
                        f'all-gather of {rec.dtype}{list(rec.shape)} in '
                        f'{src}: {rec.detail or rec.render()}'))
        for bound_name, bounds, cmp in (
                ('max_collectives', self.max_collectives, 'max'),
                ('exact_collectives', self.exact_collectives, 'exact'),
                ('min_collectives', self.min_collectives, 'min')):
            if not bounds:
                continue
            counts = report.counts('stablehlo')
            for kind, bound in bounds.items():
                got = counts.get(canonical_collective(kind), 0)
                bad = (got > bound if cmp == 'max'
                       else got != bound if cmp == 'exact'
                       else got < bound)
                if bad:
                    ops = ', '.join(
                        r.render() for r in
                        report.records(kind, 'stablehlo')) or 'none'
                    v.append(Violation(bound_name, (
                        f'{canonical_collective(kind)}: {got} in lowered '
                        f'StableHLO, {cmp} {bound} allowed; ops: {ops}')))
        if self.min_accum_dtype is not None:
            need = _float_bits(self.min_accum_dtype)
            for dot in report.dots:
                bits = _float_bits(dot.accum_dtype)
                if bits is not None and need is not None and bits < need:
                    v.append(Violation(
                        'min_accum_dtype',
                        f'{dot.render()} accumulates below '
                        f'{self.min_accum_dtype}'))
        if self.min_reduction_dtype is not None:
            need = _float_bits(self.min_reduction_dtype)
            for rec in report.records(source='jaxpr'):
                bits = _float_bits(rec.dtype)
                if bits is not None and need is not None and bits < need:
                    v.append(Violation(
                        'min_reduction_dtype',
                        f'{rec.render()} reduces below '
                        f'{self.min_reduction_dtype}'))
        if self.no_host_transfer and report.host_transfers:
            for t in report.host_transfers:
                v.append(Violation('no_host_transfer',
                                   f'host round-trip: {t.render()}'))
        if self.max_constant_bytes is not None:
            total = report.constant_bytes()
            if total > self.max_constant_bytes:
                big = sorted(report.constants, key=lambda c: -c.nbytes)[:3]
                v.append(Violation('max_constant_bytes', (
                    f'{total} bytes of baked constants '
                    f'(max {self.max_constant_bytes}); largest: '
                    + ', '.join(f'{c.dtype}{list(c.shape)}' for c in big))))
        return v

    def enforce(self, report: ProgramReport) -> ProgramReport:
        """Raise :class:`ContractViolation` on any violation; returns the
        report so audits chain."""
        violations = self.check(report)
        if violations:
            raise ContractViolation(self, violations)
        return report

    def check_fn(self, fn: Callable, *args, compile: bool = False,
                 **kwargs) -> ProgramReport:
        """``audit`` + ``enforce`` in one call."""
        return self.enforce(audit(fn, *args, compile=compile, **kwargs))
