"""Repo-rule AST lint: the checks generic linters cannot know to make.

Four rules, each encoding a correctness convention this codebase relies
on (ruff carries the generic floor — see pyproject ``[tool.ruff]``):

``prng-key-reuse``
    The same PRNG key constructed twice in one scope
    (``jax.random.PRNGKey(0)`` ... ``jax.random.PRNGKey(0)``): two
    consumers of one key produce correlated randomness. Split or fold_in
    instead.
``traced-host-sync``
    ``float()`` / ``int()`` / ``.item()`` / ``np.asarray`` inside a
    jit-decorated function or a ``lax.scan``/``while_loop``/``fori_loop``/
    ``cond`` body: a host sync inside a traced region either fails under
    trace or (at top level of a re-entered jit) silently serializes the
    dispatch pipeline.
``bench-row-literal``
    A hand-rolled dict literal with the bench-row identity keys
    (``solver``/``backend``/``applies_per_sec``): rows must go through
    ``benchmarks.common.bench_row`` so schema-v2 required keys and typing
    stay enforced in one place.
``solver-protocol``
    A ``SOLVERS`` registry entry whose class is missing the solver
    protocol: ``prepare`` / ``apply`` / ``apply_matrix`` methods and the
    ``amortizable`` class flag — the registry is only useful if every
    entry honors the protocol ``SketchPolicy``/the store dispatch on.

Suppression: append ``# repro: allow[rule-id]`` (with a reason!) to the
flagged line; ``allow[*]`` waives all rules on that line. Findings print
as ``path:line:col: [rule] message``; ``tools/lint.py`` is the CLI.
"""
from __future__ import annotations

import ast
import dataclasses
import os
import re
from typing import Iterable

__all__ = ['Finding', 'RULES', 'lint_source', 'lint_file', 'lint_paths']

RULES = {
    'prng-key-reuse': 'same PRNG key constructed twice in one scope',
    'traced-host-sync': 'host sync (float/int/.item/np.asarray) inside a '
                        'traced/scan body',
    'bench-row-literal': 'hand-rolled bench row dict; use '
                         'benchmarks.common.bench_row()',
    'solver-protocol': 'SOLVERS entry missing prepare/apply/apply_matrix/'
                       'amortizable',
    'parse-error': 'file does not parse',
}

_ALLOW_RE = re.compile(r'#\s*repro:\s*allow\[([\w*,\s-]+)\]')

_HOST_SYNC_NAMES = {'float', 'int', 'bool'}
_HOST_SYNC_ATTRS = {'item', 'tolist'}
_HOST_SYNC_NP = {'asarray', 'array'}
_CONTROL_FLOW = {'scan', 'while_loop', 'fori_loop', 'cond', 'switch', 'map'}
_BENCH_ROW_KEYS = {'solver', 'backend', 'applies_per_sec'}
_SOLVER_PROTOCOL_METHODS = ('prepare', 'apply', 'apply_matrix')


@dataclasses.dataclass(frozen=True)
class Finding:
    path: str
    line: int
    col: int
    rule: str
    message: str

    def render(self) -> str:
        return f'{self.path}:{self.line}:{self.col}: [{self.rule}] ' \
               f'{self.message}'


def _dotted(node: ast.AST) -> str:
    """'jax.random.PRNGKey' for an Attribute/Name chain, '' otherwise."""
    parts = []
    while isinstance(node, ast.Attribute):
        parts.append(node.attr)
        node = node.value
    if isinstance(node, ast.Name):
        parts.append(node.id)
        return '.'.join(reversed(parts))
    return ''


def _is_jit(node: ast.AST) -> bool:
    """Does this decorator/callee expression name a jit transform?"""
    dotted = _dotted(node)
    if dotted.split('.')[-1] == 'jit':
        return True
    if isinstance(node, ast.Call):           # partial(jax.jit, ...) / jit(...)
        if _is_jit(node.func):
            return True
        if _dotted(node.func).split('.')[-1] == 'partial' and node.args:
            return _is_jit(node.args[0])
    return False


# ---------------------------------------------------------------------------
# rule: prng-key-reuse
# ---------------------------------------------------------------------------
def _check_prng_reuse(tree: ast.AST, path: str) -> list[Finding]:
    findings = []
    seen: dict[tuple[int, str], ast.Call] = {}

    def visit(node: ast.AST, scope: int) -> None:
        if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef,
                             ast.Lambda)):
            scope = id(node)
        if (isinstance(node, ast.Call)
                and _dotted(node.func).split('.')[-1] == 'PRNGKey'
                and node.args):
            sig = (scope, ast.dump(node.args[0]))
            if sig in seen:
                first = seen[sig]
                findings.append(Finding(
                    path, node.lineno, node.col_offset, 'prng-key-reuse',
                    f'PRNGKey({ast.unparse(node.args[0])}) already '
                    f'constructed at line {first.lineno} in this scope — '
                    'two consumers of one key correlate; split or fold_in'))
            else:
                seen[sig] = node
        for child in ast.iter_child_nodes(node):
            visit(child, scope)

    visit(tree, 0)
    return findings


# ---------------------------------------------------------------------------
# rule: traced-host-sync
# ---------------------------------------------------------------------------
def _traced_bodies(tree: ast.AST) -> list[ast.AST]:
    """Function/lambda nodes whose bodies execute under trace: jit-decorated
    defs, plus lambdas/named functions handed to lax control flow."""
    defs_by_name: dict[str, ast.AST] = {}
    for node in ast.walk(tree):
        if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
            defs_by_name.setdefault(node.name, node)
    traced: list[ast.AST] = []
    for node in ast.walk(tree):
        if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
            if any(_is_jit(d) for d in node.decorator_list):
                traced.append(node)
        elif isinstance(node, ast.Call):
            dotted = _dotted(node.func)
            parts = dotted.split('.')
            # require an explicit `lax` component: jax.lax.scan / lax.scan
            # trace their bodies, jax.tree.map / builtins.map do not
            if parts[-1] in _CONTROL_FLOW and 'lax' in parts[:-1]:
                for arg in node.args:
                    if isinstance(arg, ast.Lambda):
                        traced.append(arg)
                    elif (isinstance(arg, ast.Name)
                          and arg.id in defs_by_name):
                        traced.append(defs_by_name[arg.id])
    return traced


def _check_host_sync(tree: ast.AST, path: str) -> list[Finding]:
    findings = []
    for body in _traced_bodies(tree):
        for node in ast.walk(body):
            if not isinstance(node, ast.Call):
                continue
            label = None
            if (isinstance(node.func, ast.Name)
                    and node.func.id in _HOST_SYNC_NAMES and node.args):
                label = f'{node.func.id}()'
            elif isinstance(node.func, ast.Attribute):
                dotted = _dotted(node.func)
                head, _, tail = dotted.rpartition('.')
                if tail in _HOST_SYNC_ATTRS:
                    label = f'.{tail}()'
                elif (tail in _HOST_SYNC_NP
                        and head.split('.')[-1] in ('np', 'numpy', 'onp')):
                    label = dotted
                elif dotted.endswith('device_get'):
                    label = dotted
            if label:
                findings.append(Finding(
                    path, node.lineno, node.col_offset, 'traced-host-sync',
                    f'{label} inside a traced/scan body forces a host '
                    'sync (or fails under trace); keep values on device'))
    return findings


# ---------------------------------------------------------------------------
# rule: bench-row-literal
# ---------------------------------------------------------------------------
def _check_bench_row(tree: ast.AST, path: str) -> list[Finding]:
    if os.path.basename(path) == 'common.py':
        return []                            # bench_row's own home
    findings = []
    for node in ast.walk(tree):
        if not isinstance(node, ast.Dict):
            continue
        keys = {k.value for k in node.keys
                if isinstance(k, ast.Constant) and isinstance(k.value, str)}
        if _BENCH_ROW_KEYS <= keys:
            findings.append(Finding(
                path, node.lineno, node.col_offset, 'bench-row-literal',
                'dict literal with bench-row identity keys '
                f'({sorted(_BENCH_ROW_KEYS)}); build rows with '
                'benchmarks.common.bench_row() so the schema stays '
                'enforced in one place'))
    return findings


# ---------------------------------------------------------------------------
# rule: solver-protocol
# ---------------------------------------------------------------------------
def _class_members(cls: ast.ClassDef) -> tuple[set, set]:
    methods, attrs = set(), set()
    for stmt in cls.body:
        if isinstance(stmt, (ast.FunctionDef, ast.AsyncFunctionDef)):
            methods.add(stmt.name)
        elif isinstance(stmt, ast.AnnAssign) and isinstance(stmt.target,
                                                            ast.Name):
            attrs.add(stmt.target.id)
        elif isinstance(stmt, ast.Assign):
            attrs.update(t.id for t in stmt.targets
                         if isinstance(t, ast.Name))
    return methods, attrs


def _check_solver_protocol(tree: ast.AST, path: str) -> list[Finding]:
    classes = {n.name: n for n in ast.walk(tree)
               if isinstance(n, ast.ClassDef)}
    findings = []
    for node in ast.walk(tree):
        if not (isinstance(node, ast.Assign)
                and any(isinstance(t, ast.Name) and t.id == 'SOLVERS'
                        for t in node.targets)
                and isinstance(node.value, ast.Dict)):
            continue
        for key, value in zip(node.value.keys, node.value.values):
            if not (isinstance(value, ast.Call) and value.args
                    and isinstance(value.args[0], ast.Name)):
                continue
            cls_name = value.args[0].id
            cls = classes.get(cls_name)
            if cls is None:
                continue                     # defined elsewhere: not checkable
            methods, attrs = _class_members(cls)
            entry = (key.value if isinstance(key, ast.Constant)
                     else cls_name)
            missing = [m for m in _SOLVER_PROTOCOL_METHODS
                       if m not in methods]
            if 'amortizable' not in attrs and 'amortizable' not in methods:
                missing.append('amortizable')
            if missing:
                findings.append(Finding(
                    path, value.lineno, value.col_offset, 'solver-protocol',
                    f'SOLVERS[{entry!r}] class {cls_name} is missing '
                    f'protocol member(s): {missing}'))
    return findings


# ---------------------------------------------------------------------------
# engine
# ---------------------------------------------------------------------------
_CHECKS = (_check_prng_reuse, _check_host_sync, _check_bench_row,
           _check_solver_protocol)


def _suppressed(finding: Finding, lines: list[str]) -> bool:
    """True when the flagged line — or the contiguous block of comment
    lines directly above it — carries a matching ``# repro: allow[rule]``
    marker."""
    def matches(line: str) -> bool:
        m = _ALLOW_RE.search(line)
        if not m:
            return False
        allowed = {part.strip() for part in m.group(1).split(',')}
        return '*' in allowed or finding.rule in allowed

    if 1 <= finding.line <= len(lines) and matches(lines[finding.line - 1]):
        return True
    lineno = finding.line - 1
    while 1 <= lineno <= len(lines) and lines[lineno - 1].lstrip().startswith('#'):
        if matches(lines[lineno - 1]):
            return True
        lineno -= 1
    return False


def lint_source(source: str, path: str = '<source>') -> list[Finding]:
    """All unsuppressed findings for one source text."""
    try:
        tree = ast.parse(source)
    except SyntaxError as e:
        return [Finding(path, e.lineno or 0, e.offset or 0, 'parse-error',
                        str(e.msg))]
    lines = source.splitlines()
    findings: list[Finding] = []
    for check in _CHECKS:
        findings.extend(check(tree, path))
    findings = [f for f in findings if not _suppressed(f, lines)]
    return sorted(findings, key=lambda f: (f.line, f.col, f.rule))


def lint_file(path: str) -> list[Finding]:
    with open(path, encoding='utf-8') as f:
        return lint_source(f.read(), path)


def lint_paths(paths: Iterable[str]) -> list[Finding]:
    """Lint every ``*.py`` under the given files/directories (sorted,
    __pycache__ and hidden dirs skipped)."""
    files: list[str] = []
    for path in paths:
        if os.path.isdir(path):
            for root, dirs, names in os.walk(path):
                dirs[:] = sorted(d for d in dirs
                                 if not d.startswith('.')
                                 and d != '__pycache__')
                files.extend(os.path.join(root, n) for n in sorted(names)
                             if n.endswith('.py'))
        elif path.endswith('.py'):
            files.append(path)
    findings: list[Finding] = []
    for f in files:
        findings.extend(lint_file(f))
    return findings
