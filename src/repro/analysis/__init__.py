"""Static analysis over the programs this repo compiles.

Three instruments (design doc: docs/static-analysis.md):

  * the **program auditor** — ``audit(fn, *args)`` walks jaxpr +
    StableHLO (+ compiled HLO) into a :class:`ProgramReport`; a
    declarative :class:`Contract` checks it and renders precise
    violations (``repro.analysis.auditor``);
  * the **retrace sentinel** — :class:`CompileMonitor` /
    :func:`assert_compiles` pin compile-once guarantees on hot loops
    (``repro.analysis.sentinel``);
  * the **repo AST lint** — rules for PRNG key reuse, traced host syncs,
    hand-rolled bench rows, and SOLVERS protocol drift, with
    ``# repro: allow[rule]`` suppressions (``repro.analysis.astlint``;
    CLI: ``tools/lint.py``).
"""
from repro.analysis.auditor import (Contract, ContractViolation, DotRecord,
                                    OpRecord, ProgramReport, TransferRecord,
                                    Violation, audit, audit_jaxpr,
                                    canonical_collective)
from repro.analysis.sentinel import (CompileMonitor, RetraceError,
                                     assert_compiles, count_compiles)
from repro.analysis.astlint import Finding, lint_file, lint_paths, lint_source

__all__ = [
    'Contract', 'ContractViolation', 'DotRecord', 'OpRecord',
    'ProgramReport', 'TransferRecord', 'Violation', 'audit', 'audit_jaxpr',
    'canonical_collective',
    'CompileMonitor', 'RetraceError', 'assert_compiles', 'count_compiles',
    'Finding', 'lint_file', 'lint_paths', 'lint_source',
]
