"""Retrace sentinel: count compilations, pin compile-once guarantees.

The hot loops this repo ships — ``BilevelTrainer.run``'s jitted step pair,
``solve()``'s outer loop, the warm ``InfluenceService`` query path — are
fast *because* they compile once and then replay. Nothing in jax fails
when that property silently breaks; the program just quietly recompiles
every step (a shape-dependent Python branch, a non-weak-typed scalar, a
fresh closure per call) and the "amortized" path costs a compile per use.

This module makes the property assertable:

  * :class:`CompileMonitor` — a context manager counting XLA backend
    compilations while it is active, via the ``jax.monitoring``
    ``'/jax/core/compile/backend_compile_duration'`` event (one per
    executable actually built; cache hits emit nothing);
  * :func:`count_compiles` — compilations during one thunk;
  * :func:`assert_compiles` — call ``fn`` several times and assert that
    compilation happened during exactly the first ``times`` calls and
    never again — ``assert_compiles(step, times=1, calls=3)`` is a
    three-step loop pinned to compile once. For jitted callables the
    per-callable tracing-cache delta (``_cache_size``) is cross-checked
    too, so a retrace that hits a warm executable cache still fails.

See docs/static-analysis.md for usage next to the program auditor.
"""
from __future__ import annotations

import contextlib
from typing import Callable

import jax

__all__ = ['CompileMonitor', 'RetraceError', 'assert_compiles',
           'count_compiles']

_COMPILE_EVENT = '/jax/core/compile/backend_compile_duration'

# jax.monitoring has no unregister; register one module-level listener
# lazily and fan out to whichever monitors are active.
_active: list['CompileMonitor'] = []
_listener_installed = False


def _listener(event: str, duration: float, **kwargs) -> None:
    if event == _COMPILE_EVENT:
        for monitor in _active:
            monitor._events.append(event)


def _install_listener() -> None:
    global _listener_installed
    if not _listener_installed:
        jax.monitoring.register_event_duration_secs_listener(_listener)
        _listener_installed = True


class CompileMonitor(contextlib.AbstractContextManager):
    """Counts backend compilations (executables built, not cache hits)
    between ``__enter__`` and ``__exit__``::

        with CompileMonitor() as mon:
            step(state)
        assert mon.compiles == 0   # warm path stayed warm
    """

    def __init__(self) -> None:
        self._events: list[str] = []

    def __enter__(self) -> 'CompileMonitor':
        _install_listener()
        _active.append(self)
        return self

    def __exit__(self, *exc) -> None:
        _active.remove(self)

    @property
    def compiles(self) -> int:
        return len(self._events)


def count_compiles(thunk: Callable[[], object]) -> int:
    """Backend compilations triggered by one call of ``thunk``."""
    with CompileMonitor() as monitor:
        thunk()
    return monitor.compiles


class RetraceError(AssertionError):
    """A compile-once guarantee failed (details name the offending calls)."""


def _cache_size(fn) -> int | None:
    try:
        return fn._cache_size()          # jitted callables (pjit)
    except Exception:
        return None


def assert_compiles(fn: Callable, *args, times: int = 1,
                    calls: int | None = None, warmup: int = 0,
                    **kwargs) -> None:
    """Assert ``fn`` compiles during exactly its first ``times`` calls.

    ``fn(*args, **kwargs)`` is invoked ``calls`` times (default
    ``times + 2``); compilation — of anything, including the tiny
    executables eager ops build — must occur during the first ``times``
    calls only. ``times=1`` pins the classic loop property: the first
    iteration pays the compile, every later iteration replays.
    ``times=0`` with ``warmup=1`` asserts an already-warm path stays warm.

    When ``fn`` is itself a jitted callable its tracing-cache size is also
    required to grow by at most ``times`` — a retrace served from a warm
    executable cache (no backend compile) still fails.
    """
    calls = times + 2 if calls is None else calls
    if calls < times:
        raise ValueError(f'calls={calls} < times={times}')
    for _ in range(warmup):
        fn(*args, **kwargs)
    cache_before = _cache_size(fn)
    compiled_during: list[int] = []
    counts: list[int] = []
    for i in range(calls):
        with CompileMonitor() as monitor:
            fn(*args, **kwargs)
        counts.append(monitor.compiles)
        if monitor.compiles:
            compiled_during.append(i)
    expected = list(range(times))
    if compiled_during != expected:
        label = getattr(fn, '__name__', repr(fn))
        raise RetraceError(
            f'{label}: expected compilation during exactly the first '
            f'{times} of {calls} calls, but calls {compiled_during} '
            f'compiled (per-call compile counts: {counts}). A compile '
            f'after call {times - 1 if times else 0} means the program '
            'retraces instead of replaying.')
    cache_after = _cache_size(fn)
    if cache_before is not None and cache_after is not None:
        grown = cache_after - cache_before
        if grown > times:
            raise RetraceError(
                f'{getattr(fn, "__name__", repr(fn))}: tracing cache grew '
                f'by {grown} entries over {calls} calls (max {times} '
                'expected) — the callable retraces per call.')
