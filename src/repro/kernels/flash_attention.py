"""Pallas TPU kernel: causal flash-attention forward (serving/prefill path).

Grid (B·H, S/q_block): each program owns one query block and streams KV
blocks through VMEM with the online-softmax recurrence — running max `m`,
normalizer `l`, and the f32 accumulator live in VMEM scratch for the whole
KV sweep. Causality skips whole KV blocks past the diagonal via masking
(`pl.when` guards the compute so skipped blocks cost no MXU work when the
grid dimension is serialized, which is the TPU default for the minor grid
axis).

Contract matches ref.flash_attention: q/k/v are (B, S, H, hd) with KV heads
already GQA-expanded; hd must be ≤ 256 (one VREG tile column).

Training uses the XLA online-softmax twin (models/attention.py) because the
dry-run roofline must see real HLO FLOPs; this kernel is the TPU serving
fast path (cfg.use_pallas).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _make_kernel(q_block: int, k_block: int, n_kv: int, scale: float,
                 causal: bool):
    def kernel(q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref):
        qi = pl.program_id(1)
        ki = pl.program_id(2)

        @pl.when(ki == 0)
        def _init():
            m_ref[...] = jnp.full_like(m_ref, NEG_INF)
            l_ref[...] = jnp.zeros_like(l_ref)
            acc_ref[...] = jnp.zeros_like(acc_ref)

        # whole-block causal skip (past-diagonal KV blocks do no MXU work)
        run = ((ki * k_block) <= (qi * q_block + q_block - 1)) if causal \
            else (ki >= 0)

        @pl.when(run)
        def _compute():
            q = q_ref[0].astype(jnp.float32)            # (qb, hd)
            k = k_ref[0].astype(jnp.float32)            # (kb, hd)
            v = v_ref[0].astype(jnp.float32)
            s = jax.lax.dot_general(
                q, k, (((1,), (1,)), ((), ())),
                preferred_element_type=jnp.float32) * scale   # (qb, kb)
            if causal:
                qpos = qi * q_block + jax.lax.broadcasted_iota(
                    jnp.int32, s.shape, 0)
                kpos = ki * k_block + jax.lax.broadcasted_iota(
                    jnp.int32, s.shape, 1)
                s = jnp.where(qpos >= kpos, s, NEG_INF)
            m_prev = m_ref[...]
            m_new = jnp.maximum(m_prev, s.max(axis=1, keepdims=True))
            alpha = jnp.exp(m_prev - m_new)
            p = jnp.exp(s - m_new)
            l_ref[...] = l_ref[...] * alpha + p.sum(axis=1, keepdims=True)
            m_ref[...] = m_new
            acc_ref[...] = acc_ref[...] * alpha + jax.lax.dot_general(
                p, v, (((1,), (0,)), ((), ())),
                preferred_element_type=jnp.float32)

        @pl.when(ki == n_kv - 1)
        def _finish():
            denom = jnp.maximum(l_ref[...], 1e-30)
            o_ref[0] = (acc_ref[...] / denom).astype(o_ref.dtype)

    return kernel


@functools.partial(jax.jit, static_argnames=('causal', 'scale', 'q_block',
                                             'k_block', 'interpret'))
def flash_attention(q: jax.Array, k: jax.Array, v: jax.Array, *,
                    causal: bool = True, scale: float | None = None,
                    q_block: int = 512, k_block: int = 512,
                    interpret: bool = False) -> jax.Array:
    """q/k/v: (B, S, H, hd), H GQA-expanded. Returns (B, S, H, hd)."""
    B, S, H, hd = q.shape
    T = k.shape[1]
    scale = hd ** -0.5 if scale is None else scale
    q_block = min(q_block, S)
    k_block = min(k_block, T)
    assert S % q_block == 0 and T % k_block == 0, 'seq must divide block'

    # (B, S, H, hd) → (B·H, S, hd)
    def bh(t):
        return t.transpose(0, 2, 1, 3).reshape(B * H, t.shape[1], hd)

    qb, kb, vb = bh(q), bh(k), bh(v)
    n_q = S // q_block
    n_kv = T // k_block
    out = pl.pallas_call(
        _make_kernel(q_block, k_block, n_kv, scale, causal),
        grid=(B * H, n_q, n_kv),
        in_specs=[
            pl.BlockSpec((1, q_block, hd), lambda b, i, j: (b, i, 0)),
            pl.BlockSpec((1, k_block, hd), lambda b, i, j: (b, j, 0)),
            pl.BlockSpec((1, k_block, hd), lambda b, i, j: (b, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, q_block, hd), lambda b, i, j: (b, i, 0)),
        out_shape=jax.ShapeDtypeStruct((B * H, S, hd), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((q_block, 1), jnp.float32),   # running max m
            pltpu.VMEM((q_block, 1), jnp.float32),   # normalizer l
            pltpu.VMEM((q_block, hd), jnp.float32),  # output accumulator
        ],
        interpret=interpret,
    )(qb, kb, vb)
    return out.reshape(B, H, S, hd).transpose(0, 2, 1, 3)
