"""Pallas TPU kernels for the Woodbury IHVP apply (Eq. 6's two p-passes).

Pass 1 — ``ctv``:    t = Cᵀ v           (p, k) × (p,)  → (k,)
Pass 2 — ``apply``:  u = v/ρ − C w/ρ²   (p, k) × (k,)  → (p,)

Both stream C over a p-blocked grid exactly like nystrom_gram (one HBM read
of C per pass, VMEM-resident k-vector), so a full Nyström IHVP apply costs
2 C-reads — the paper's "no iterations" property in memory-traffic form;
compare l sequential HVPs (l full fwd+bwd passes) for CG/Neumann.

The k-vectors are carried as (1, k_pad) 2-D tiles (TPU VREG lanes want the
trailing dim = 128-multiple; rank-1 arrays don't map to the vector unit).

Matrix-valued queries: both entry points also take a (p, m) query block
(``v.ndim == 2``), turning each pass into a genuine GEMM — pass 1 routes to
``nystrom_cross`` (the gram kernel's two-operand form), pass 2 to a block
kernel tiling (block_p, m_pad) output slabs. m = 1 (a 1-D ``v``) takes the
original vector kernels untouched, so existing callers see identical bits.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _ctv_kernel(c_ref, v_ref, out_ref):
    step = pl.program_id(0)

    @pl.when(step == 0)
    def _init():
        out_ref[...] = jnp.zeros_like(out_ref)

    c = c_ref[...].astype(jnp.float32)              # (block_p, k_pad)
    v = v_ref[...].astype(jnp.float32)              # (1, block_p)
    out_ref[...] += jax.lax.dot_general(
        v, c, (((1,), (0,)), ((), ())),             # (1,bp) @ (bp,k) → (1,k)
        preferred_element_type=jnp.float32)


@functools.partial(jax.jit, static_argnames=('block_p', 'interpret'))
def woodbury_ctv(C: jax.Array, v: jax.Array, *, block_p: int = 1024,
                 interpret: bool = False) -> jax.Array:
    """t = Cᵀv. v (p,) → (k,) via the vector kernel; v (p, m) → (k, m) via
    the two-operand gram kernel (one C-read for the whole query block)."""
    if v.ndim == 2:
        from repro.kernels.nystrom_gram import nystrom_cross
        return nystrom_cross(C, v, block_p=block_p, interpret=interpret)
    p, k = C.shape
    k_pad = max(128, ((k + 127) // 128) * 128)
    p_pad = ((p + block_p - 1) // block_p) * block_p
    if (p_pad, k_pad) != (p, k):
        C = jnp.pad(C, ((0, p_pad - p), (0, k_pad - k)))
    if p_pad != p:
        v = jnp.pad(v, (0, p_pad - p))
    out = pl.pallas_call(
        _ctv_kernel,
        grid=(p_pad // block_p,),
        in_specs=[pl.BlockSpec((block_p, k_pad), lambda i: (i, 0)),
                  pl.BlockSpec((1, block_p), lambda i: (0, i))],
        out_specs=pl.BlockSpec((1, k_pad), lambda i: (0, 0)),
        out_shape=jax.ShapeDtypeStruct((1, k_pad), jnp.float32),
        interpret=interpret,
    )(C, v[None, :])
    return out[0, :k]


def _make_apply_kernel(rho: float):
    inv_rho = 1.0 / rho
    inv_rho2 = 1.0 / (rho * rho)

    def kernel(c_ref, v_ref, w_ref, out_ref):
        c = c_ref[...].astype(jnp.float32)          # (block_p, k_pad)
        v = v_ref[...].astype(jnp.float32)          # (1, block_p)
        w = w_ref[...].astype(jnp.float32)          # (1, k_pad)
        corr = jax.lax.dot_general(
            c, w, (((1,), (1,)), ((), ())),         # (bp,k) @ (k,1)ᵀ → (bp,1)
            preferred_element_type=jnp.float32)
        out_ref[...] = v * inv_rho - corr.T * inv_rho2

    return kernel


def _make_apply_block_kernel(rho: float):
    inv_rho = 1.0 / rho
    inv_rho2 = 1.0 / (rho * rho)

    def kernel(c_ref, v_ref, w_ref, out_ref):
        c = c_ref[...].astype(jnp.float32)          # (block_p, k_pad)
        v = v_ref[...].astype(jnp.float32)          # (block_p, m_pad)
        w = w_ref[...].astype(jnp.float32)          # (k_pad, m_pad)
        corr = jax.lax.dot_general(
            c, w, (((1,), (0,)), ((), ())),         # (bp,k) @ (k,m) → (bp,m)
            preferred_element_type=jnp.float32)
        out_ref[...] = v * inv_rho - corr * inv_rho2

    return kernel


@functools.partial(jax.jit, static_argnames=('rho', 'block_p', 'interpret'))
def woodbury_apply(C: jax.Array, w: jax.Array, v: jax.Array, rho: float, *,
                   block_p: int = 1024, interpret: bool = False) -> jax.Array:
    """u = v/ρ − C w / ρ². ρ is a compile-time constant (hyperparam).

    Vector form: w (k,), v (p,) → (p,). Block form (``v.ndim == 2``):
    w (k, m), v (p, m) → (p, m) — the correction becomes one
    (block_p, k) @ (k, m) MXU matmul per grid step, still one C-read total.
    """
    if v.ndim == 2:
        return _woodbury_apply_block(C, w, v, rho, block_p=block_p,
                                     interpret=interpret)
    p, k = C.shape
    k_pad = max(128, ((k + 127) // 128) * 128)
    p_pad = ((p + block_p - 1) // block_p) * block_p
    if (p_pad, k_pad) != (p, k):
        C = jnp.pad(C, ((0, p_pad - p), (0, k_pad - k)))
    if p_pad != p:
        v = jnp.pad(v, (0, p_pad - p))
    if k_pad != k:
        w = jnp.pad(w, (0, k_pad - k))
    out = pl.pallas_call(
        _make_apply_kernel(rho),
        grid=(p_pad // block_p,),
        in_specs=[pl.BlockSpec((block_p, k_pad), lambda i: (i, 0)),
                  pl.BlockSpec((1, block_p), lambda i: (0, i)),
                  pl.BlockSpec((1, k_pad), lambda i: (0, 0))],
        out_specs=pl.BlockSpec((1, block_p), lambda i: (0, i)),
        out_shape=jax.ShapeDtypeStruct((1, p_pad), jnp.float32),
        interpret=interpret,
    )(C, v[None, :], w[None, :])
    return out[0, :p]


def _woodbury_apply_block(C: jax.Array, w: jax.Array, v: jax.Array,
                          rho: float, *, block_p: int,
                          interpret: bool) -> jax.Array:
    p, k = C.shape
    m = v.shape[1]
    k_pad = max(128, ((k + 127) // 128) * 128)
    m_pad = max(128, ((m + 127) // 128) * 128)
    p_pad = ((p + block_p - 1) // block_p) * block_p
    if (p_pad, k_pad) != (p, k):
        C = jnp.pad(C, ((0, p_pad - p), (0, k_pad - k)))
    if (p_pad, m_pad) != v.shape:
        v = jnp.pad(v, ((0, p_pad - p), (0, m_pad - m)))
    if (k_pad, m_pad) != w.shape:
        w = jnp.pad(w, ((0, k_pad - k), (0, m_pad - m)))
    out = pl.pallas_call(
        _make_apply_block_kernel(rho),
        grid=(p_pad // block_p,),
        in_specs=[pl.BlockSpec((block_p, k_pad), lambda i: (i, 0)),
                  pl.BlockSpec((block_p, m_pad), lambda i: (i, 0)),
                  pl.BlockSpec((k_pad, m_pad), lambda i: (0, 0))],
        out_specs=pl.BlockSpec((block_p, m_pad), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((p_pad, m_pad), jnp.float32),
        interpret=interpret,
    )(C, v, w)
    return out[:p, :m]
