"""Pallas TPU kernel: tall-skinny Gram matrix CᵀC for the Nyström sketch.

C is (p, k) with p up to billions (a sharded parameter pytree flattens to a
local p-shard per device) and k ≤ 128. TPU mapping:

  * k is padded to the 128-lane width so the (k, k) accumulator is one MXU
    tile held in VMEM across the whole grid;
  * the grid walks p in ``block_p`` rows; each step streams a (block_p, k)
    slab HBM→VMEM and issues one (k × block_p) @ (block_p × k) MXU matmul;
  * the accumulator is an output whose index_map is constant (0, 0) — Pallas
    keeps it resident in VMEM and the kernel accumulates into it, writing
    HBM exactly once (arithmetic intensity ≈ k FLOPs/byte, the roofline
    optimum for this shape).

f32 accumulation regardless of input dtype (bf16 C is the production case).

``nystrom_cross`` is the same kernel with a second operand: AᵀB for a
(p, m) query block B — the batched Cᵀ·[v₁…v_m] pass of the matrix-valued
IHVP apply, one C-read for m queries.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _gram_kernel(c_ref, out_ref):
    step = pl.program_id(0)

    @pl.when(step == 0)
    def _init():
        out_ref[...] = jnp.zeros_like(out_ref)

    c = c_ref[...].astype(jnp.float32)              # (block_p, k_pad)
    out_ref[...] += jax.lax.dot_general(
        c, c, (((0,), (0,)), ((), ())),             # contract over block_p
        preferred_element_type=jnp.float32)


def _cross_kernel(a_ref, b_ref, out_ref):
    step = pl.program_id(0)

    @pl.when(step == 0)
    def _init():
        out_ref[...] = jnp.zeros_like(out_ref)

    a = a_ref[...].astype(jnp.float32)              # (block_p, k_pad)
    b = b_ref[...].astype(jnp.float32)              # (block_p, m_pad)
    out_ref[...] += jax.lax.dot_general(
        a, b, (((0,), (0,)), ((), ())),             # contract over block_p
        preferred_element_type=jnp.float32)


@functools.partial(jax.jit, static_argnames=('block_p', 'interpret'))
def nystrom_gram(C: jax.Array, *, block_p: int = 1024,
                 interpret: bool = False) -> jax.Array:
    """CᵀC for C (p, k) → (k, k) f32."""
    p, k = C.shape
    k_pad = max(128, ((k + 127) // 128) * 128)
    p_pad = ((p + block_p - 1) // block_p) * block_p
    if (p_pad, k_pad) != (p, k):
        C = jnp.pad(C, ((0, p_pad - p), (0, k_pad - k)))
    grid = (p_pad // block_p,)
    out = pl.pallas_call(
        _gram_kernel,
        grid=grid,
        in_specs=[pl.BlockSpec((block_p, k_pad), lambda i: (i, 0))],
        out_specs=pl.BlockSpec((k_pad, k_pad), lambda i: (0, 0)),
        out_shape=jax.ShapeDtypeStruct((k_pad, k_pad), jnp.float32),
        interpret=interpret,
    )(C)
    return out[:k, :k]


@functools.partial(jax.jit, static_argnames=('block_p', 'interpret'))
def nystrom_cross(A: jax.Array, B: jax.Array, *, block_p: int = 1024,
                  interpret: bool = False) -> jax.Array:
    """AᵀB for tall-skinny A (p, k) against a query block B (p, m) → (k, m).

    The gram kernel generalized to a second operand: the same p-blocked grid
    streams both slabs HBM→VMEM and accumulates one (k_pad, m_pad) MXU tile
    (constant index_map, one HBM write). With B = A this is CᵀC; with B a
    (p, m) query block it is the batched Cᵀv of the matrix-valued IHVP apply
    — m query vectors per C-read instead of one. f32 accumulation regardless
    of input dtypes.
    """
    p, k = A.shape
    pb, m = B.shape
    assert p == pb, f'row mismatch: A has p={p}, B has p={pb}'
    k_pad = max(128, ((k + 127) // 128) * 128)
    m_pad = max(128, ((m + 127) // 128) * 128)
    p_pad = ((p + block_p - 1) // block_p) * block_p
    if (p_pad, k_pad) != (p, k):
        A = jnp.pad(A, ((0, p_pad - p), (0, k_pad - k)))
    if (p_pad, m_pad) != (p, m):
        B = jnp.pad(B, ((0, p_pad - p), (0, m_pad - m)))
    out = pl.pallas_call(
        _cross_kernel,
        grid=(p_pad // block_p,),
        in_specs=[pl.BlockSpec((block_p, k_pad), lambda i: (i, 0)),
                  pl.BlockSpec((block_p, m_pad), lambda i: (i, 0))],
        out_specs=pl.BlockSpec((k_pad, m_pad), lambda i: (0, 0)),
        out_shape=jax.ShapeDtypeStruct((k_pad, m_pad), jnp.float32),
        interpret=interpret,
    )(A, B)
    return out[:k, :m]
