"""Pallas TPU kernel: tall-skinny Gram matrix CᵀC for the Nyström sketch.

C is (p, k) with p up to billions (a sharded parameter pytree flattens to a
local p-shard per device) and k ≤ 128. TPU mapping:

  * k is padded to the 128-lane width so the (k, k) accumulator is one MXU
    tile held in VMEM across the whole grid;
  * the grid walks p in ``block_p`` rows; each step streams a (block_p, k)
    slab HBM→VMEM and issues one (k × block_p) @ (block_p × k) MXU matmul;
  * the accumulator is an output whose index_map is constant (0, 0) — Pallas
    keeps it resident in VMEM and the kernel accumulates into it, writing
    HBM exactly once (arithmetic intensity ≈ k FLOPs/byte, the roofline
    optimum for this shape).

f32 accumulation regardless of input dtype (bf16 C is the production case).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _gram_kernel(c_ref, out_ref):
    step = pl.program_id(0)

    @pl.when(step == 0)
    def _init():
        out_ref[...] = jnp.zeros_like(out_ref)

    c = c_ref[...].astype(jnp.float32)              # (block_p, k_pad)
    out_ref[...] += jax.lax.dot_general(
        c, c, (((0,), (0,)), ((), ())),             # contract over block_p
        preferred_element_type=jnp.float32)


@functools.partial(jax.jit, static_argnames=('block_p', 'interpret'))
def nystrom_gram(C: jax.Array, *, block_p: int = 1024,
                 interpret: bool = False) -> jax.Array:
    """CᵀC for C (p, k) → (k, k) f32."""
    p, k = C.shape
    k_pad = max(128, ((k + 127) // 128) * 128)
    p_pad = ((p + block_p - 1) // block_p) * block_p
    if (p_pad, k_pad) != (p, k):
        C = jnp.pad(C, ((0, p_pad - p), (0, k_pad - k)))
    grid = (p_pad // block_p,)
    out = pl.pallas_call(
        _gram_kernel,
        grid=grid,
        in_specs=[pl.BlockSpec((block_p, k_pad), lambda i: (i, 0))],
        out_specs=pl.BlockSpec((k_pad, k_pad), lambda i: (0, 0)),
        out_shape=jax.ShapeDtypeStruct((k_pad, k_pad), jnp.float32),
        interpret=interpret,
    )(C)
    return out[:k, :k]
