"""Pure-jnp oracles for every Pallas kernel (the allclose ground truth)."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def nystrom_gram(C: jax.Array) -> jax.Array:
    """CᵀC for tall-skinny C (p, k) → (k, k), f32 accumulation."""
    Cf = C.astype(jnp.float32)
    return Cf.T @ Cf


def nystrom_cross(A: jax.Array, B: jax.Array) -> jax.Array:
    """AᵀB : (p, k), (p, m) → (k, m), f32 accumulation."""
    return A.astype(jnp.float32).T @ B.astype(jnp.float32)


def woodbury_ctv(C: jax.Array, v: jax.Array) -> jax.Array:
    """t = Cᵀ v : (p, k), (p,) → (k,) — or (p, m) → (k, m) for a block."""
    return C.astype(jnp.float32).T @ v.astype(jnp.float32)


def woodbury_apply(C: jax.Array, w: jax.Array, v: jax.Array,
                   rho: float) -> jax.Array:
    """u = v/ρ − C w / ρ² : the p-dimensional Woodbury correction apply
    (vector w (k,), v (p,) — or block w (k, m), v (p, m))."""
    vf = v.astype(jnp.float32)
    corr = C.astype(jnp.float32) @ w.astype(jnp.float32)
    return vf / rho - corr / (rho * rho)


def rmsnorm(x: jax.Array, scale: jax.Array, eps: float = 1e-5) -> jax.Array:
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    return ((xf * jax.lax.rsqrt(var + eps)).astype(x.dtype)
            * scale.astype(x.dtype))


def flash_attention(q: jax.Array, k: jax.Array, v: jax.Array, *,
                    causal: bool = True, scale: float | None = None
                    ) -> jax.Array:
    """Dense-softmax attention. q/k/v: (B, S, H, hd) with H already
    GQA-expanded (matches the kernel's contract)."""
    B, S, H, hd = q.shape
    scale = hd ** -0.5 if scale is None else scale
    logits = jnp.einsum('bshd,bthd->bhst', q.astype(jnp.float32),
                        k.astype(jnp.float32)) * scale
    if causal:
        T = k.shape[1]
        mask = jnp.tril(jnp.ones((S, T), bool), T - S)
        logits = jnp.where(mask, logits, -jnp.inf)
    w = jax.nn.softmax(logits, axis=-1)
    out = jnp.einsum('bhst,bthd->bshd', w, v.astype(jnp.float32))
    return out.astype(q.dtype)
