"""Pallas TPU kernel: fused RMSNorm (one HBM round-trip instead of three).

Rows (all leading dims flattened) are tiled ``block_rows`` at a time with the
full feature dim resident in VMEM; mean-of-squares, rsqrt and the scale
multiply all fuse into the single pass. d must be lane-aligned (it is a
multiple of 128 for every assigned arch; we pad otherwise — padded columns
are excluded from the variance via the true-d divisor).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _make_kernel(eps: float, true_d: int):
    def kernel(x_ref, s_ref, o_ref):
        x = x_ref[...].astype(jnp.float32)          # (block_rows, d_pad)
        var = jnp.sum(x * x, axis=-1, keepdims=True) / true_d
        y = x * jax.lax.rsqrt(var + eps)
        o_ref[...] = (y.astype(o_ref.dtype)
                      * s_ref[...].astype(o_ref.dtype))
    return kernel


@functools.partial(jax.jit, static_argnames=('eps', 'block_rows', 'interpret'))
def rmsnorm(x: jax.Array, scale: jax.Array, eps: float = 1e-5, *,
            block_rows: int = 256, interpret: bool = False) -> jax.Array:
    orig_shape = x.shape
    d = orig_shape[-1]
    n = 1
    for s in orig_shape[:-1]:
        n *= s
    x2 = x.reshape(n, d)
    d_pad = ((d + 127) // 128) * 128
    n_pad = ((n + block_rows - 1) // block_rows) * block_rows
    if (n_pad, d_pad) != (n, d):
        x2 = jnp.pad(x2, ((0, n_pad - n), (0, d_pad - d)))
    s2 = jnp.pad(scale, (0, d_pad - d)) if d_pad != d else scale
    out = pl.pallas_call(
        _make_kernel(eps, d),
        grid=(n_pad // block_rows,),
        in_specs=[pl.BlockSpec((block_rows, d_pad), lambda i: (i, 0)),
                  pl.BlockSpec((1, d_pad), lambda i: (0, 0))],
        out_specs=pl.BlockSpec((block_rows, d_pad), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((n_pad, d_pad), x.dtype),
        interpret=interpret,
    )(x2, s2[None, :])
    return out[:n, :d].reshape(orig_shape)
