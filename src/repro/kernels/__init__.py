"""Pallas TPU kernels for the perf-critical compute layers.

<name>.py      pl.pallas_call + explicit BlockSpec VMEM tiling
ops.py         jit'd public wrappers (interpret=True off-TPU)
ref.py         pure-jnp oracles (the allclose ground truth in tests)

Kernels: nystrom_gram (tall-skinny CᵀC), woodbury (Cᵀv / Woodbury apply),
flash_attention (causal GQA forward), rmsnorm. The dry-run keeps the XLA
twins so HLO cost analysis sees real FLOPs (DESIGN.md §3).

The Nyström kernels are wired into the solver hot path through
``repro.core.backend.PallasBackend`` (``NystromIHVP(backend='pallas')``):
gram / Cᵀv / the fused Woodbury pass-2 stream the (p, k) sketch once per
pass with the k-tile accumulator VMEM-resident.
"""
