"""jit'd public wrappers for the Pallas kernels.

``interpret`` defaults to True off-TPU so the same call sites work in CI;
on TPU backends the kernels compile to Mosaic. ``use_pallas`` model configs
route through here (serving fast path); the dry-run keeps the XLA twins so
cost_analysis sees real FLOPs (DESIGN.md §3).
"""
from __future__ import annotations

import functools

import jax

from repro.analysis.auditor import Contract
from repro.kernels.flash_attention import flash_attention as _flash
from repro.kernels.nystrom_gram import nystrom_cross as _cross
from repro.kernels.nystrom_gram import nystrom_gram as _gram
from repro.kernels.rmsnorm import rmsnorm as _rmsnorm
from repro.kernels.woodbury import woodbury_apply as _wapply
from repro.kernels.woodbury import woodbury_ctv as _wctv


#: Every kernel wrapper here — Pallas grid or XLA twin — accumulates f32
#: (bf16 slabs are upcast in VMEM before the MXU dot) and never leaves the
#: device. The jaxpr auditor recurses into ``pallas_call`` kernel jaxprs,
#: so this is checkable on the *kernel body's* dots, not just the wrapper.
KERNEL_CONTRACT = Contract(
    name='pallas kernel accumulation',
    min_accum_dtype='float32',
    no_host_transfer=True,
)


@functools.cache
def _default_interpret() -> bool:
    return jax.default_backend() != 'tpu'


def nystrom_gram(C, *, block_p: int = 1024, interpret: bool | None = None):
    return _gram(C, block_p=block_p,
                 interpret=_default_interpret() if interpret is None else interpret)


def nystrom_cross(A, B, *, block_p: int = 1024, interpret: bool | None = None):
    """AᵀB for A (p, k), B (p, m) → (k, m): the gram kernel's two-operand
    form (batched Cᵀv over an m-query block, one C-read)."""
    return _cross(A, B, block_p=block_p,
                  interpret=_default_interpret() if interpret is None else interpret)


def woodbury_ctv(C, v, *, block_p: int = 1024, interpret: bool | None = None):
    """Cᵀv. v may be (p,) → (k,) or a (p, m) query block → (k, m)."""
    return _wctv(C, v, block_p=block_p,
                 interpret=_default_interpret() if interpret is None else interpret)


def woodbury_apply(C, w, v, rho: float, *, block_p: int = 1024,
                   interpret: bool | None = None):
    """v/ρ − Cw/ρ². Vector (w (k,), v (p,)) or block (w (k, m), v (p, m))."""
    return _wapply(C, w, v, rho, block_p=block_p,
                   interpret=_default_interpret() if interpret is None else interpret)


def rmsnorm(x, scale, eps: float = 1e-5, *, interpret: bool | None = None):
    return _rmsnorm(x, scale, eps,
                    interpret=_default_interpret() if interpret is None else interpret)


def flash_attention(q, k, v, *, causal: bool = True, scale: float | None = None,
                    q_block: int = 512, k_block: int = 512,
                    interpret: bool | None = None):
    return _flash(q, k, v, causal=causal, scale=scale, q_block=q_block,
                  k_block=k_block,
                  interpret=_default_interpret() if interpret is None else interpret)


def nystrom_ihvp_apply(C, H_KK, v, rho: float, *, interpret: bool | None = None):
    """Full Eq. 6 apply through the kernel pipeline:
    t = Cᵀv (kernel) → w = solve(H_KK + CᵀC/ρ, t) (replicated k×k) →
    u = v/ρ − C w/ρ² (kernel). One C-read per pass."""
    import jax.numpy as jnp
    t = woodbury_ctv(C, v, interpret=interpret)
    gram = nystrom_gram(C, interpret=interpret)
    M = H_KK + gram / rho
    M = 0.5 * (M + M.T)
    d = jnp.sqrt(jnp.clip(jnp.abs(jnp.diagonal(M)), 1e-30, None))
    w = jnp.linalg.solve(M / d[:, None] / d[None, :]
                         + 1e-7 * jnp.eye(M.shape[0]), t / d) / d
    return woodbury_apply(C, w, v, rho, interpret=interpret)
