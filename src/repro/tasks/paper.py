"""The paper's experiment tasks as registered problems.

Each bilevel builder returns a typed ``BilevelProblem`` (inner/outer losses,
init functions, a ``BatchSource``, metrics, paper-protocol training
defaults) — consumed uniformly by ``repro.core.problem.solve``,
``benchmarks/`` (paper tables) and ``examples/`` (runnable scripts). The
``influence`` builder returns an :class:`InfluenceProblem` instead (a
single-level loss over the long-tail data), driven by
``repro.core.problem.influence`` — the matrix-valued IHVP service. Models
use leaky-ReLU exactly as §5 prescribes (ReLU zeroes Hessian columns and
breaks the plain Eq. 6 inverse).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.problem import (BilevelProblem, InfluenceProblem,
                                register_problem)
from repro.data.sources import ArraySource, EpisodeSource
from repro.data.synthetic import (DistillationTask, FewShotSampler,
                                  LongTailDataset, make_logreg_problem)
from repro.optim import sgd

ACT = lambda x: jax.nn.leaky_relu(x, 0.01)   # noqa: E731  (paper §5 setup)


# --------------------------------------------------------------- tiny MLP
def mlp_init(rng, sizes):
    params = []
    for i, (a, b) in enumerate(zip(sizes[:-1], sizes[1:])):
        k1, rng = jax.random.split(rng)
        params.append({'w': jax.random.normal(k1, (a, b)) * (2.0 / a) ** 0.5,
                       'b': jnp.zeros((b,))})
    return params


def mlp_apply(params, x):
    h = x.reshape(x.shape[0], -1)
    for i, layer in enumerate(params):
        h = h @ layer['w'] + layer['b']
        if i < len(params) - 1:
            h = ACT(h)
    return h


def _xent(logits, labels):
    return -jnp.mean(jnp.take_along_axis(jax.nn.log_softmax(logits),
                                         labels[:, None], 1))


def _plain_xent_loss(params, batch):
    """The hparam-free training loss shared by the classification tasks —
    what a no-bilevel baseline minimizes (``problem.baseline_loss``)."""
    X, y = batch
    return _xent(mlp_apply(params, X), y)


# ----------------------------------------------------------------- §5.1
@register_problem('logreg_wd')
def build_logreg_weight_decay(D: int = 100, n: int = 500,
                              seed: int = 0) -> BilevelProblem:
    """Per-parameter weight decay for logistic regression (Fig. 2/3)."""
    (Xt, yt), (Xv, yv) = make_logreg_problem(D, n, seed)

    def inner(params, hparams, batch):
        X, y = batch
        logit = X @ params['w']
        bce = jnp.mean(jnp.maximum(logit, 0) - logit * y
                       + jnp.log1p(jnp.exp(-jnp.abs(logit))))
        # |φ|: identical gradient for φ>0 (the paper's regime) and keeps
        # the inner problem bounded if the outer momentum overshoots below 0
        return bce + jnp.sum(jnp.abs(hparams['wd']) * params['w'] ** 2)

    def outer(params, hparams, batch):
        X, y = batch
        logit = X @ params['w']
        return jnp.mean(jnp.maximum(logit, 0) - logit * y
                        + jnp.log1p(jnp.exp(-jnp.abs(logit))))

    return BilevelProblem(
        name='logreg_wd', inner_loss=inner, outer_loss=outer,
        init_params=lambda rng: {'w': jnp.zeros((D,))},
        init_hparams=lambda rng: {'wd': jnp.ones((D,))},
        data=ArraySource(train=(Xt, yt), val=(Xv, yv)),
        defaults=dict(inner_lr=0.1, outer_lr=0.1, outer_opt='sgd_momentum',
                      steps_per_outer=100, batch_size=500, reset_inner=True))


# ----------------------------------------------------------------- §5.2
@register_problem('distillation')
def build_distillation(n_per_class: int = 5, seed: int = 0,
                       width: int = 64, image_size: int = 28,
                       ) -> BilevelProblem:
    """Dataset distillation (Tab. 2): φ = C synthetic images + labels fixed.

    ``width``/``image_size`` scale the model and data down from the paper
    protocol (defaults unchanged) — the observatory sweeps a toy size whose
    exact-IHVP oracle is affordable.
    """
    task = DistillationTask(seed=seed, image_size=image_size)
    C = task.n_classes * n_per_class
    s = task.image_size
    Xt, yt = task.train()
    Xs, ys = task.test()
    distill_labels = jnp.tile(jnp.arange(task.n_classes), n_per_class)
    sizes = (s * s, width, task.n_classes)

    def inner(params, hparams, batch):
        logits = mlp_apply(params, hparams['images'])
        return _xent(logits, distill_labels)

    def outer(params, hparams, batch):
        X, y = batch
        return _xent(mlp_apply(params, X), y)

    def accuracy(params, hparams):
        pred = mlp_apply(params, Xs).argmax(-1)
        return float((pred == ys).mean())

    def distilled_accuracy(params, hparams):
        """Tab. 2's actual score: train a *fresh* model on the distilled
        images only, evaluate on the held-out test set."""
        prm = mlp_init(jax.random.PRNGKey(7), sizes)
        opt = sgd(0.01)
        st = opt.init(prm)
        for i in range(100):
            g = jax.grad(inner)(prm, hparams, None)
            prm, st = opt.apply(g, st, prm, jnp.int32(i))
        return accuracy(prm, hparams)

    return BilevelProblem(
        name='distillation', inner_loss=inner, outer_loss=outer,
        init_params=lambda rng: mlp_init(rng, sizes),
        init_hparams=lambda rng: {'images': jnp.zeros((C, s, s, 1))},
        data=ArraySource(train=(Xt, yt), val=(Xt, yt)),
        metrics={'accuracy': accuracy,
                 'distilled_accuracy': distilled_accuracy},
        baseline_loss=_plain_xent_loss,
        reference={'distill_labels': distill_labels, 'dataset': task},
        defaults=dict(inner_lr=0.01, outer_lr=1e-3, steps_per_outer=100,
                      batch_size=256, reset_inner=True))


# ----------------------------------------------------------------- §5.3
@register_problem('imaml')
def build_imaml(n_way: int = 5, k_shot: int = 1, seed: int = 0,
                reg: float = 1.0, width: int = 64, image_size: int = 20,
                ) -> BilevelProblem:
    """iMAML (Tab. 3): inner adapts to a task with a proximal term to the
    meta-initialization; outer moves the initialization. A meta-problem:
    drive it through ``solve(..., vmap_tasks=N)`` (its ``EpisodeSource``
    has no flat train/val stream). ``width``/``image_size`` scale the model
    and episodes down to observatory toy size (defaults unchanged)."""
    sampler = FewShotSampler(n_way=n_way, k_shot=k_shot, seed=seed,
                             image_size=image_size)
    s = sampler.image_size
    sizes = (s * s, width, width, n_way)

    def inner(params, hparams, batch):
        sx, sy = batch
        ce = _xent(mlp_apply(params, sx), sy)
        prox = sum(jnp.sum((p['w'] - h['w']) ** 2) + jnp.sum((p['b'] - h['b']) ** 2)
                   for p, h in zip(params, hparams))
        return ce + 0.5 * reg * prox

    def outer(params, hparams, batch):
        qx, qy = batch
        return _xent(mlp_apply(params, qx), qy)

    return BilevelProblem(
        name='imaml', inner_loss=inner, outer_loss=outer,
        init_params=lambda rng: mlp_init(rng, sizes),
        init_hparams=lambda rng: mlp_init(rng, sizes),
        data=EpisodeSource(sampler),
        reference={'sampler': sampler},
        defaults=dict(inner_lr=0.1, outer_lr=1e-3, steps_per_outer=10))


# ----------------------------------------------------------------- §5.4
@register_problem('reweighting')
def build_reweighting(imbalance: int = 100, seed: int = 0,
                      d: int = 64, width: int = 128) -> BilevelProblem:
    """Data reweighting (Tab. 4/5/6): μ_φ maps per-example loss → weight.

    ``width`` scales the classifier down from the WRN-28 stand-in (default
    unchanged) — the observatory's toy size keeps the oracle affordable.
    """
    data = LongTailDataset(imbalance_factor=imbalance, seed=seed, d=d)
    n_cls = data.n_classes
    sizes = (d, width, width, n_cls)       # stand-in for WRN-28 (DESIGN §6.3)

    def weight_net(hparams, losses):
        h = ACT(losses[:, None] @ hparams['w1'] + hparams['b1'])
        return jax.nn.sigmoid(h @ hparams['w2'] + hparams['b2'])[:, 0]

    def inner(params, hparams, batch):
        X, y = batch
        logits = mlp_apply(params, X)
        per = -jnp.take_along_axis(jax.nn.log_softmax(logits), y[:, None], 1)[:, 0]
        w = weight_net(hparams, jax.lax.stop_gradient(per))
        return jnp.mean(per * w)

    def outer(params, hparams, batch):
        X, y = batch
        return _xent(mlp_apply(params, X), y)

    def init_hparams(rng):
        k1, k2 = jax.random.split(rng)
        return {'w1': jax.random.normal(k1, (1, 100)) * 0.1,
                'b1': jnp.zeros((100,)),
                'w2': jax.random.normal(k2, (100, 1)) * 0.1,
                'b2': jnp.zeros((1,))}

    def accuracy(params, hparams):
        pred = mlp_apply(params, data.Xv).argmax(-1)
        return float((pred == data.yv).mean())

    return BilevelProblem(
        name='reweighting', inner_loss=inner, outer_loss=outer,
        init_params=lambda rng: mlp_init(rng, sizes),
        init_hparams=init_hparams,
        data=ArraySource(train=(data.X, data.y), val=(data.Xv, data.yv)),
        metrics={'accuracy': accuracy},
        baseline_loss=_plain_xent_loss,
        reference={'dataset': data},
        defaults=dict(inner_lr=0.1, inner_momentum=0.9, outer_lr=1e-3,
                      steps_per_outer=20, batch_size=128))


# -------------------------------------------------- influence functions
@register_problem('influence')
def build_influence(imbalance: int = 100, seed: int = 0,
                    d: int = 64, width: int = 128) -> InfluenceProblem:
    """Influence queries over the long-tail classification substrate.

    The single-level counterpart of ``reweighting``: the same MLP and
    LongTailDataset, but the question is per-example — which training
    examples move a query's loss, scored by
    ``repro.core.problem.influence`` through one Nyström sketch. The val
    split is the natural query pool (``reference['queries'](m)`` draws the
    first m val examples as a query batch). ``width`` sets the MLP hidden
    size — shrink it (with ``d``) when an exact-IHVP oracle must be
    affordable (its cost is p HVPs), e.g. the attribution-quality
    benchmark and the serving smoke tests.
    """
    data = LongTailDataset(imbalance_factor=imbalance, seed=seed, d=d)
    sizes = (d, width, width, data.n_classes)

    def queries(m: int):
        return data.Xv[:m], data.yv[:m]

    return InfluenceProblem(
        name='influence', loss=_plain_xent_loss,
        init_params=lambda rng: mlp_init(rng, sizes),
        data=ArraySource(train=(data.X, data.y), val=(data.Xv, data.yv)),
        defaults=dict(inner_lr=0.1, batch_size=128, train_steps=200),
        reference={'dataset': data, 'queries': queries})
