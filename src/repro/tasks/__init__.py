from repro.tasks.paper import (build_distillation, build_imaml,
                               build_logreg_weight_decay, build_reweighting,
                               mlp_apply, mlp_init)

__all__ = ['build_distillation', 'build_imaml', 'build_logreg_weight_decay',
           'build_reweighting', 'mlp_apply', 'mlp_init']
