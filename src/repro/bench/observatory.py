"""The solver observatory: PROBLEMS × SOLVERS × accuracy-knob sweeps.

One measurement primitive — ``hypergrad_at`` at a fixed linearization point
(θ_T, φ), scored against the exact-IHVP oracle — swept over

  * the **problem axis**: any registered ``PROBLEMS`` builder at toy size
    (``parse_problem_spec``'s ``name:kw=v`` syntax picks the size),
  * the **population axis**: T variants of the problem (seeds by default,
    or an explicit ``--vary`` axis such as imbalance factors), measured
    under ONE ``jax.vmap`` — one compiled program per cell, not T,
  * the **solver axis**: any subset of the ``SOLVERS`` registry, and
  * the **grid axis**: accuracy knobs (Nyström k, CG/Neumann iterations,
    damping ρ, Neumann α). Each solver sweeps exactly the grid keys its
    ``SolverSpec`` consumes — ``exact`` ignores ``k``, a newly registered
    solver opts into the sweep by listing its knobs in its spec.

Each cell yields a :class:`SweepCell`: relative hypergradient error vs the
oracle (mean and max over the population), the per-hypergradient HVP bill
(``accounted_hvps`` — the same arithmetic ``solve`` reports), and measured
wall time. ``benchmarks/observatory.py`` is the CLI that persists cells as
schema-v2 BENCH rows; ``benchmarks/compare_runs.py`` diffs two such files.

The population is built once per problem (inner-SGD adaptation to θ_T and
the p-HVP oracle are shared by every cell), so adding a solver or a grid
point costs only that cell's own measurement.
"""
from __future__ import annotations

import dataclasses
import itertools
import math
import time
from typing import Any, Callable

import jax
import jax.numpy as jnp

from repro.core.hypergrad import HypergradConfig
from repro.core.problem import (BilevelProblem, accounted_hvps, get_problem,
                                hypergrad_at, hypergrad_error,
                                hypergrad_reference, resolved_defaults)
from repro.core.solvers import SOLVERS
from repro.core.tree_util import PyTree

# Toy-size default sweep set: small enough that the exact-IHVP oracle
# (p HVPs + a dense p×p solve, per population member) runs in CI on CPU.
DEFAULT_PROBLEM_SPECS = (
    'logreg_wd:D=8:n=60',
    'distillation:n_per_class=1:image_size=8:width=16',
    'reweighting:d=8:width=16',
)

# Accuracy knobs swept by default. Keys are HypergradConfig field names:
# ``k`` doubles as the iteration count l for CG/Neumann (the registry's
# field renames), ``rho`` reaches nystrom/cg/exact, ``alpha`` neumann only.
DEFAULT_GRID: dict[str, tuple] = {'k': (2, 5, 10), 'rho': (1e-2,)}

# The oracle materializes the full inner Hessian: p HVPs + an O(p³) solve
# per population member. Refuse quietly-quadratic mistakes above this.
DEFAULT_MAX_ORACLE_P = 20_000


# ---------------------------------------------------------------------------
# Spec mini-language (shared by the CLI and tests)
# ---------------------------------------------------------------------------
def _parse_value(text: str):
    """int → float → bool → str, first that parses."""
    for cast in (int, float):
        try:
            return cast(text)
        except ValueError:
            pass
    if text.lower() in ('true', 'false'):
        return text.lower() == 'true'
    return text


def parse_problem_spec(spec: str) -> tuple[str, dict]:
    """``'name:kw=v:kw=v'`` → (name, builder kwargs).

    Colons separate the kwargs so commas stay free as the list separator in
    ``--problems a,b,c``:

    >>> parse_problem_spec('logreg_wd:D=8:n=60')
    ('logreg_wd', {'D': 8, 'n': 60})
    >>> parse_problem_spec('reweighting')
    ('reweighting', {})
    """
    name, *parts = spec.split(':')
    kwargs = {}
    for part in parts:
        if '=' not in part:
            raise ValueError(
                f'bad problem spec part {part!r} in {spec!r} '
                "(expected 'name:kw=v:kw=v')")
        key, _, val = part.partition('=')
        kwargs[key] = _parse_value(val)
    return name, kwargs


def parse_grid(text: str) -> dict[str, tuple]:
    """``'k=2:4:8,rho=0.01'`` → ``{'k': (2, 4, 8), 'rho': (0.01,)}``.

    Commas separate axes, colons separate an axis's values:

    >>> parse_grid('k=2:4,rho=0.01:0.1')
    {'k': (2, 4), 'rho': (0.01, 0.1)}
    """
    grid = {}
    for axis in filter(None, text.split(',')):
        if '=' not in axis:
            raise ValueError(f'bad grid axis {axis!r} in {text!r} '
                             "(expected 'key=v1:v2:...')")
        key, _, vals = axis.partition('=')
        grid[key] = tuple(_parse_value(v) for v in vals.split(':'))
    return grid


def parse_vary(text: str) -> tuple[str, tuple]:
    """``'imbalance=10,100'`` → ``('imbalance', (10, 100))`` — an explicit
    population axis (builder kwarg × values) instead of the seed default.

    >>> parse_vary('imbalance=10,100')
    ('imbalance', (10, 100))
    """
    if '=' not in text:
        raise ValueError(f'bad vary spec {text!r} '
                         "(expected 'builder_kwarg=v1,v2,...')")
    key, _, vals = text.partition('=')
    return key, tuple(_parse_value(v) for v in vals.split(','))


def solver_grid_points(solver: str, grid: dict[str, tuple]) -> list[dict]:
    """The grid cells a solver actually sweeps: the product of the grid axes
    whose keys its ``SolverSpec`` consumes (others are simply not its dials).

    >>> solver_grid_points('exact', {'k': (2, 4), 'rho': (0.01,)})
    [{'rho': 0.01}]
    >>> solver_grid_points('neumann', {'k': (2, 4), 'rho': (0.01,)})
    [{'k': 2}, {'k': 4}]
    """
    if solver not in SOLVERS:
        raise ValueError(
            f'unknown solver {solver!r}; registered: {sorted(SOLVERS)}')
    axes = [(key, vals) for key, vals in grid.items()
            if key in SOLVERS[solver].fields]
    if not axes:
        return [{}]
    keys = [k for k, _ in axes]
    return [dict(zip(keys, combo))
            for combo in itertools.product(*(vals for _, vals in axes))]


# ---------------------------------------------------------------------------
# Population construction (shared across every cell of a problem)
# ---------------------------------------------------------------------------
@dataclasses.dataclass
class PopulationBundle:
    """A measured problem population, frozen at its linearization points.

    ``theta``/``phi``/``inner_b``/``outer_b``/``keys`` all carry a leading
    task axis of size ``tasks``; ``reference`` is the stacked exact-IHVP
    oracle hypergradient at those points (computed once, reused by every
    cell). ``problem`` is the variant-0 build — its loss *functions* are
    shared by all variants (data enters only through the stacked batches).
    """
    problem: BilevelProblem
    spec: str                 # the 'name:kw=v' spec this was built from
    tasks: int
    p: int                    # inner parameter count (the oracle's HVP bill)
    theta: PyTree             # adapted inner params θ_T, stacked
    phi: PyTree               # outer variables φ, stacked
    inner_b: Any
    outer_b: Any
    keys: jax.Array           # per-task sketch-sampling keys
    reference: PyTree         # oracle hypergradients, stacked
    oracle_rho: float


def _stack(trees: list) -> PyTree:
    return jax.tree.map(lambda *xs: jnp.stack(xs), *trees)


def _params_size(problem: BilevelProblem) -> int:
    shapes = jax.eval_shape(problem.init_params, jax.random.PRNGKey(0))
    return sum(int(math.prod(s.shape)) for s in jax.tree.leaves(shapes))


def build_population(spec: str, *, tasks: int = 3,
                     vary: tuple[str, tuple] | None = None,
                     steps: int | None = None, batch_size: int | None = None,
                     seed: int = 0, oracle_rho: float = 0.0,
                     max_oracle_p: int = DEFAULT_MAX_ORACLE_P,
                     ) -> PopulationBundle:
    """Build a problem population and its oracle references.

    Variants: ``vary=None`` sweeps the builder's ``seed`` over
    ``seed+0..seed+tasks-1``; ``vary=('imbalance', (10, 100))`` sweeps that
    builder kwarg instead (``tasks`` is then its value count). Each variant
    contributes one population member: fresh (θ₀, φ) from its init
    functions, step-``t`` batches from its data source, and θ_T from
    ``steps`` full-batch inner-SGD steps on its inner batch (defaults from
    ``resolved_defaults`` — the problem's own training protocol). The
    adaptation matters: several tasks are degenerate at θ₀ (e.g. logreg's
    mixed term vanishes at w=0), so errors are only meaningful at θ_T.

    Meta-problems (``EpisodeSource``) draw the population from
    ``task_batch`` instead: ``tasks`` episodes, θ₀ = φ = the meta-init,
    per-episode proximal adaptation — the same geometry ``solve``'s
    ``vmap_tasks`` path differentiates through.
    """
    name, kwargs = parse_problem_spec(spec)
    if vary is not None:
        key, values = vary
        variants = [{**kwargs, key: v} for v in values]
        tasks = len(variants)          # the vary axis IS the population
    else:
        variants = [{**kwargs, 'seed': seed + t} for t in range(tasks)]

    problems = [get_problem(name, **v) for v in variants]
    problem = problems[0]
    p = _params_size(problem)
    if p > max_oracle_p:
        raise ValueError(
            f'problem {spec!r} has p={p} inner parameters; the exact-IHVP '
            f'oracle costs p HVPs + a dense p×p solve per task '
            f'(max_oracle_p={max_oracle_p}). Sweep a toy size '
            f"(e.g. {DEFAULT_PROBLEM_SPECS[0]!r}) or raise max_oracle_p")
    d = resolved_defaults(problem, steps_per_outer=steps,
                          batch_size=batch_size)
    rng = jax.random.PRNGKey(seed)

    if hasattr(problem.data, 'task_batch'):
        if vary is not None:
            raise ValueError(
                f'--vary is not supported for meta-problem {name!r}: its '
                'population axis is the episode draw from task_batch')
        inner_b, outer_b = problem.data.task_batch(0, tasks)
        phi0 = problem.init_hparams(rng)
        phi = jax.tree.map(
            lambda x: jnp.broadcast_to(x, (tasks,) + x.shape), phi0)
        theta0 = phi                      # adapt from the meta-init, as iMAML
    else:
        inner_b = _stack([pb.data.train_batch(t, d['batch_size'])
                          for t, pb in enumerate(problems)])
        outer_b = _stack([pb.data.val_batch(t, d['batch_size'])
                          for t, pb in enumerate(problems)])
        theta0 = _stack([pb.init_params(jax.random.fold_in(rng, t))
                         for t, pb in enumerate(problems)])
        phi = _stack([pb.init_hparams(jax.random.fold_in(rng, 10_000 + t))
                      for t, pb in enumerate(problems)])

    lr, n_steps = d['inner_lr'], d['steps_per_outer']

    def adapt(th, ph, batch):
        def sgd_step(prm, _):
            g = jax.grad(problem.inner_loss)(prm, ph, batch)
            return jax.tree.map(lambda w, gw: w - lr * gw, prm, g), None
        out, _ = jax.lax.scan(sgd_step, th, None, length=n_steps)
        return out

    theta = jax.jit(jax.vmap(adapt))(theta0, phi, inner_b)
    reference = jax.jit(jax.vmap(
        lambda th, ph, ib, ob: hypergrad_reference(
            problem, th, ph, ib, ob, rho=oracle_rho)))(
                theta, phi, inner_b, outer_b)
    keys = jax.random.split(jax.random.fold_in(rng, 777), tasks)
    return PopulationBundle(problem=problem, spec=spec, tasks=tasks, p=p,
                            theta=theta, phi=phi, inner_b=inner_b,
                            outer_b=outer_b, keys=keys, reference=reference,
                            oracle_rho=oracle_rho)


# ---------------------------------------------------------------------------
# Cell measurement
# ---------------------------------------------------------------------------
@dataclasses.dataclass
class SweepCell:
    """One observatory measurement: (problem, solver, grid point) over the
    population. ``problem`` is the full ``'name:kw=v'`` spec (two sizes of
    one builder are different cells). ``hypergrad_error`` is the population
    mean of the relative
    error vs the oracle (``err_max`` the worst member); ``hvp_count`` is
    the per-hypergradient analytic bill (k for Nyström, l for CG/Neumann,
    p for exact); ``wall_seconds`` is the best-of-``reps`` wall time of the
    whole vmapped population program (compile excluded),
    ``applies_per_sec`` = tasks / wall_seconds.

    The two ``None``-default fields are the optional program-structure
    audit (``measure_cell(..., audit=True)``): ``collective_count`` is the
    number of collectives in the lowered StableHLO of the measured program,
    ``accum_dtype_ok`` whether every matmul in it accumulates at float32 or
    wider. They ride into BENCH rows as typed-optional measurements so
    ``compare_runs.py`` can flag structure regressions between runs."""
    problem: str
    solver: str
    grid: dict
    tasks: int
    hypergrad_error: float
    err_max: float
    hvp_count: int
    wall_seconds: float
    applies_per_sec: float
    backend: str = 'tree'
    collective_count: int | None = None
    accum_dtype_ok: bool | None = None


def _audit_cell(fn, batched) -> tuple[int, bool]:
    """(collective_count, accum_dtype_ok) for the measured program: total
    collectives in the lowered StableHLO, and whether every dot accumulates
    at float32 or wider (the BF16_SKETCH_CONTRACT accumulation rule)."""
    from repro.analysis import Contract, audit
    report = audit(fn, *batched)
    count = len(report.records(source='stablehlo'))
    ok = Contract(name='observatory accumulation',
                  min_accum_dtype='float32').check(report) == []
    return count, ok


def measure_cell(bundle: PopulationBundle, solver_name: str, point: dict,
                 *, backend: str = 'tree', reps: int = 2,
                 audit: bool = False) -> SweepCell:
    """Measure one (solver, grid point, backend) cell against a built
    population. ``backend`` reaches the solver only when its ``SolverSpec``
    declares ``builds_backend`` (Nyström's operand layouts); for the others
    it is recorded as-is in the cell — they have no backend dial. With
    ``audit=True`` the exact program being timed is also audited
    (:func:`repro.analysis.audit`) and the cell carries its
    ``collective_count`` / ``accum_dtype_ok``."""
    cfg = dict(point)
    if SOLVERS[solver_name].builds_backend:
        cfg['backend'] = backend
    solver = HypergradConfig(solver=solver_name, **cfg).build()
    fn = jax.jit(jax.vmap(
        lambda th, ph, ib, ob, key: hypergrad_at(
            bundle.problem, solver, th, ph, ib, ob, rng=key)))
    batched = (bundle.theta, bundle.phi, bundle.inner_b, bundle.outer_b,
               bundle.keys)
    collective_count = accum_dtype_ok = None
    if audit:
        collective_count, accum_dtype_ok = _audit_cell(fn, batched)
    hg = jax.block_until_ready(fn(*batched))     # compile + warm
    wall = math.inf
    for _ in range(max(1, reps)):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(*batched))
        wall = min(wall, time.perf_counter() - t0)
    errs = jax.vmap(hypergrad_error)(hg, bundle.reference)
    return SweepCell(
        problem=bundle.spec, solver=solver_name, grid=dict(point),
        tasks=bundle.tasks, hypergrad_error=float(jnp.mean(errs)),
        err_max=float(jnp.max(errs)),
        hvp_count=accounted_hvps(solver, bundle.problem, 1),
        wall_seconds=wall, applies_per_sec=bundle.tasks / max(wall, 1e-12),
        backend=backend, collective_count=collective_count,
        accum_dtype_ok=accum_dtype_ok)


def run_sweep(problem_specs=DEFAULT_PROBLEM_SPECS,
              solvers=('nystrom', 'cg', 'neumann', 'exact'),
              grid: dict[str, tuple] | None = None, *, tasks: int = 3,
              backends: tuple[str, ...] = ('tree',),
              vary: tuple[str, tuple] | None = None, steps: int | None = None,
              batch_size: int | None = None, seed: int = 0,
              oracle_rho: float = 0.0, reps: int = 2,
              max_oracle_p: int = DEFAULT_MAX_ORACLE_P,
              audit: bool = False,
              progress: Callable[[str], None] | None = None,
              ) -> list[SweepCell]:
    """The full sweep: problems × solvers × per-solver grid points ×
    backends.

    Unknown solver names raise before any measurement (the CLI's
    ``--solvers`` filter therefore selects exactly registry entries). The
    ``backends`` axis applies only to solvers whose ``SolverSpec`` declares
    ``builds_backend`` (Nyström); backend-less solvers measure each grid
    point once, tagged 'tree'. The population (adaptation + oracle) is
    built once per problem and shared by all its cells. ``audit=True``
    additionally audits each cell's timed program and fills the cells'
    ``collective_count`` / ``accum_dtype_ok``.
    """
    say = progress or (lambda msg: None)
    grid = DEFAULT_GRID if grid is None else grid
    points = {s: solver_grid_points(s, grid) for s in solvers}
    for s in solvers:                     # validate before any measurement
        if not SOLVERS[s].builds_backend and len(backends) > 1:
            say(f'[observatory] note: {s} has no backend dial; measuring '
                f"its cells once (tagged 'tree')")
    if vary is not None:
        tasks = len(vary[1])
    cells = []
    for spec in problem_specs:
        bundle = build_population(
            spec, tasks=tasks, vary=vary, steps=steps,
            batch_size=batch_size, seed=seed, oracle_rho=oracle_rho,
            max_oracle_p=max_oracle_p)
        say(f'[observatory] {spec}: population of {bundle.tasks} built '
            f'(p={bundle.p}, oracle rho={oracle_rho})')
        for solver_name in solvers:
            solver_backends = (tuple(backends)
                               if SOLVERS[solver_name].builds_backend
                               else ('tree',))
            for point in points[solver_name]:
                for backend in solver_backends:
                    cell = measure_cell(bundle, solver_name, point,
                                        backend=backend, reps=reps,
                                        audit=audit)
                    cells.append(cell)
                    knobs = ','.join(f'{k}={v}'
                                     for k, v in point.items()) or '-'
                    say(f'[observatory]   {solver_name:<8} {knobs:<16} '
                        f'be={backend:<6} err={cell.hypergrad_error:.3e} '
                        f'hvps={cell.hvp_count} '
                        f'wall={cell.wall_seconds:.3f}s')
    return cells
