"""repro.bench — the solver observatory's measurement substrate.

Public API:
  run_sweep / SweepCell / build_population    — PROBLEMS × SOLVERS × knob-grid
                                                complexity sweeps (vmapped
                                                population axis, error vs the
                                                exact-IHVP oracle)
  parse_grid / parse_problem_spec /           — the observatory CLI's spec
    parse_vary                                  mini-language
  solver_grid_points                          — registry-driven grid axes (a
                                                solver sweeps exactly the
                                                knobs its SolverSpec consumes)
  compare_docs / CompareError / format_report — two-run regression diffing
                                                (benchmarks/compare_runs.py)
  fit_rates / RateFit / format_rates          — Grazzi-style empirical rate
                                                fits (log-error vs log-HVP
                                                bill per cell ladder)

The CLI lives in ``benchmarks/observatory.py`` (persistence via
``benchmarks/common.py``); this package holds everything importable —
and therefore unit-testable — without the benchmarks tree.
"""
from repro.bench.compare import (CellDiff, CompareError, CompareReport,
                                 compare_docs, format_report)
from repro.bench.observatory import (DEFAULT_GRID, DEFAULT_PROBLEM_SPECS,
                                     PopulationBundle, SweepCell,
                                     build_population, parse_grid,
                                     parse_problem_spec, parse_vary,
                                     run_sweep, solver_grid_points)
from repro.bench.rates import (RateFit, fit_rates, fit_rates_file,
                               format_rates)

__all__ = [
    'CellDiff', 'CompareError', 'CompareReport', 'DEFAULT_GRID',
    'DEFAULT_PROBLEM_SPECS', 'PopulationBundle', 'RateFit', 'SweepCell',
    'build_population', 'compare_docs', 'fit_rates', 'fit_rates_file',
    'format_rates', 'format_report', 'parse_grid', 'parse_problem_spec',
    'parse_vary', 'run_sweep', 'solver_grid_points',
]
