"""Regression diffing of two persisted BENCH_*.json runs.

The observatory (and every other bench writing schema-v2 rows) persists a
perf trajectory; this module is what makes it *enforceable*: given a
baseline document and a new run, match cells by identity, compare the
measurement fields under configurable tolerances, and name every offender.
``benchmarks/compare_runs.py`` is the CLI (nonzero exit on regression);
the functions here are pure so tests drive them directly.

Cell identity is every row field that is NOT a measurement — solver,
backend, problem, m, the grid dict, and any bench-specific extras — so two
runs line up cell-for-cell without a hand-maintained key list, and a new
knob added to the rows automatically splits the cells it distinguishes.

Regressions (vs the baseline cell):
  * ``wall_seconds`` above baseline by more than ``tol_wall`` (relative),
    and ``applies_per_sec`` below by the same margin — both skipped under
    ``check_wall=False`` (cross-machine comparisons, e.g. CI vs the
    committed baseline fixture);
  * ``hypergrad_error`` above baseline by more than ``tol_error`` relative
    plus ``atol_error`` absolute (the absolute floor keeps near-zero
    baselines from flagging roundoff);
  * ``hvp_count`` increased at all — the bill is analytic, so any growth
    is a real complexity regression, never noise;
  * a baseline cell missing from the new run (silent coverage loss).

Cells only the new run has are reported as additions, never failures.
Documents with different ``schema_version`` refuse to diff (a v1 baseline
cannot be compared field-for-field against v2 rows — regenerate it).
"""
from __future__ import annotations

import dataclasses
import json

# Fields that are measured outcomes rather than cell identity. Includes the
# legacy/extra measurement names some benches emit (err_max, seconds, ...)
# so they never end up splitting cell identity. The serving tier's latency/
# queue metrics are machine-varying measurements; its deterministic fields
# (phase, cache_hit_rate) are deliberately NOT listed — they are identity,
# so a changed hit rate or a vanished warm cell fails the diff as MISSING.
MEASURE_KEYS = frozenset({
    'applies_per_sec', 'wall_seconds', 'hypergrad_error', 'hvp_count',
    'err_max', 'hvps', 'sketch_mb', 'seconds', 'us_per_apply',
    'latency_mean_ms', 'latency_p50_ms', 'latency_p95_ms', 'latency_max_ms',
    'queue_depth_mean', 'queue_depth_max', 'degraded_flushes',
    'deadline_misses', 'jaccard_vs_exact',
    # program-structure audit (observatory --audit): typed-optional, only
    # present when the run was audited
    'collective_count', 'accum_dtype_ok',
})


class CompareError(ValueError):
    """A comparison that cannot be made (schema mismatch, malformed doc) —
    distinct from a comparison that *fails* (regressions found)."""


@dataclasses.dataclass
class CellDiff:
    """One measurement delta for one matched cell."""
    cell: str          # human-readable cell identity
    field: str
    base: float
    new: float
    regressed: bool
    note: str = ''


@dataclasses.dataclass
class CompareReport:
    diffs: list[CellDiff]
    missing: list[str]             # baseline cells absent from the new run
    added: list[str]               # new-run cells absent from the baseline

    @property
    def regressions(self) -> list[CellDiff]:
        return [d for d in self.diffs if d.regressed]

    @property
    def ok(self) -> bool:
        return not self.regressions and not self.missing


def _freeze(value):
    if isinstance(value, dict):
        return tuple(sorted((k, _freeze(v)) for k, v in value.items()))
    if isinstance(value, list):
        return tuple(_freeze(v) for v in value)
    return value


def _cell_key(row: dict):
    return tuple(sorted((k, _freeze(v)) for k, v in row.items()
                        if k not in MEASURE_KEYS))


def _cell_label(row: dict) -> str:
    parts = [f"problem={row.get('problem', '?')}",
             f"solver={row.get('solver', '?')}"]
    grid = row.get('grid')
    if grid:
        parts.append('grid[' + ','.join(f'{k}={v}'
                                        for k, v in sorted(grid.items()))
                     + ']')
    for k in sorted(row):
        if k in MEASURE_KEYS or k in ('problem', 'solver', 'grid'):
            continue
        parts.append(f'{k}={row[k]}')
    return ' '.join(parts)


def _index(doc: dict) -> dict:
    index = {}
    for i, row in enumerate(doc.get('rows', [])):
        key = _cell_key(row)
        if key in index:
            raise CompareError(
                f'duplicate cell in {doc.get("name", "?")!r}: '
                f'{_cell_label(row)} (rows {index[key][0]} and {i}) — '
                'cells must be unique to diff runs')
        index[key] = (i, row)
    return index


def compare_docs(base: dict, new: dict, *, tol_wall: float = 0.25,
                 tol_error: float = 0.25, atol_error: float = 1e-6,
                 check_wall: bool = True) -> CompareReport:
    """Diff two parsed BENCH documents → :class:`CompareReport`."""
    bv, nv = base.get('schema_version'), new.get('schema_version')
    if bv != nv:
        raise CompareError(
            f'schema_version mismatch: baseline is v{bv}, new run is v{nv} '
            '— rows cannot be compared field-for-field across schema '
            'versions; regenerate the baseline with the current bench')
    base_idx, new_idx = _index(base), _index(new)

    diffs: list[CellDiff] = []
    missing = [_cell_label(row) for key, (_, row) in base_idx.items()
               if key not in new_idx]
    added = [_cell_label(row) for key, (_, row) in new_idx.items()
             if key not in base_idx]

    for key, (_, b) in base_idx.items():
        if key not in new_idx:
            continue
        n = new_idx[key][1]
        cell = _cell_label(b)
        if check_wall and 'wall_seconds' in b and 'wall_seconds' in n:
            bad = n['wall_seconds'] > b['wall_seconds'] * (1 + tol_wall)
            diffs.append(CellDiff(
                cell, 'wall_seconds', b['wall_seconds'], n['wall_seconds'],
                bad, note=f'tol={tol_wall:.0%} relative'))
        if check_wall and 'applies_per_sec' in b and 'applies_per_sec' in n:
            bad = n['applies_per_sec'] < b['applies_per_sec'] / (1 + tol_wall)
            diffs.append(CellDiff(
                cell, 'applies_per_sec', b['applies_per_sec'],
                n['applies_per_sec'], bad, note=f'tol={tol_wall:.0%}'))
        if 'hypergrad_error' in b and 'hypergrad_error' in n:
            limit = b['hypergrad_error'] * (1 + tol_error) + atol_error
            diffs.append(CellDiff(
                cell, 'hypergrad_error', b['hypergrad_error'],
                n['hypergrad_error'], n['hypergrad_error'] > limit,
                note=f'limit={limit:.3e}'))
        if 'jaccard_vs_exact' in b and 'jaccard_vs_exact' in n:
            floor = b['jaccard_vs_exact'] * (1 - tol_error) - atol_error
            diffs.append(CellDiff(
                cell, 'jaccard_vs_exact', b['jaccard_vs_exact'],
                n['jaccard_vs_exact'], n['jaccard_vs_exact'] < floor,
                note=f'floor={floor:.3f} (retrieval quality vs exact)'))
        if check_wall and 'latency_p95_ms' in b and 'latency_p95_ms' in n:
            bad = n['latency_p95_ms'] > b['latency_p95_ms'] * (1 + tol_wall)
            diffs.append(CellDiff(
                cell, 'latency_p95_ms', b['latency_p95_ms'],
                n['latency_p95_ms'], bad, note=f'tol={tol_wall:.0%}'))
        if 'hvp_count' in b and 'hvp_count' in n:
            diffs.append(CellDiff(
                cell, 'hvp_count', b['hvp_count'], n['hvp_count'],
                n['hvp_count'] > b['hvp_count'],
                note='any increase regresses (analytic bill)'))
        if 'collective_count' in b and 'collective_count' in n:
            diffs.append(CellDiff(
                cell, 'collective_count', b['collective_count'],
                n['collective_count'],
                n['collective_count'] > b['collective_count'],
                note='any increase regresses (program structure)'))
        if 'accum_dtype_ok' in b and 'accum_dtype_ok' in n:
            diffs.append(CellDiff(
                cell, 'accum_dtype_ok', float(b['accum_dtype_ok']),
                float(n['accum_dtype_ok']),
                bool(b['accum_dtype_ok']) and not n['accum_dtype_ok'],
                note='True->False regresses (low-precision accumulation '
                     'crept in)'))
    return CompareReport(diffs=diffs, missing=missing, added=added)


def compare_files(base_path: str, new_path: str, **kwargs) -> CompareReport:
    with open(base_path) as f:
        base = json.load(f)
    with open(new_path) as f:
        new = json.load(f)
    return compare_docs(base, new, **kwargs)


def format_report(report: CompareReport, *, verbose: bool = False) -> str:
    """Human-readable report; regressions and missing cells always named."""
    lines = []
    for d in report.diffs:
        if d.regressed:
            lines.append(f'REGRESSION {d.cell}: {d.field} '
                         f'{d.base:.6g} -> {d.new:.6g} ({d.note})')
        elif verbose:
            lines.append(f'ok         {d.cell}: {d.field} '
                         f'{d.base:.6g} -> {d.new:.6g}')
    for cell in report.missing:
        lines.append(f'MISSING    {cell}: present in baseline, absent from '
                     'new run')
    for cell in report.added:
        lines.append(f'added      {cell}: new cell (no baseline)')
    n_reg = len(report.regressions) + len(report.missing)
    matched = len({d.cell for d in report.diffs})
    lines.append(f'compared {matched} cells: '
                 + ('clean' if report.ok else f'{n_reg} regression(s)'))
    return '\n'.join(lines)
