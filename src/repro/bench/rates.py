"""Empirical convergence-rate fits over observatory ladders.

Grazzi et al. (2020) characterize hypergradient approximation error as a
function of inner-solver effort; the observatory measures exactly that
surface — per-cell ``hypergrad_error`` against the analytic ``hvp_count``
bill. This module compresses each **cell ladder** (the rows sharing one
(problem, solver, backend) identity and differing only in the swept effort
knob — k for Nyström, l for CG/Neumann) into a power-law fit

    log10(error) ≈ slope · log10(hvp_count) + intercept

by least squares. The slope is the empirical rate: how many decades of
accuracy one decade of HVP budget buys. A CG ladder on a well-conditioned
quadratic fits steeply negative; a Nyström ladder's slope tracks the
spectral decay the paper's bounds are written in terms of; a flat slope
on a solver that should converge is a regression worth staring at.

Fits are descriptive, not gated: ``compare_runs.py --fit-rates`` prints
them for both runs side by side so a rate collapse is visible in the same
report that enforces the per-cell tolerances.
"""
from __future__ import annotations

import dataclasses
import json
import math
from typing import Any, Iterable, Mapping


@dataclasses.dataclass(frozen=True)
class RateFit:
    """One fitted ladder: ``error ≈ 10^intercept · hvps^slope``."""
    problem: str
    solver: str
    backend: str
    points: int              # distinct (hvps, error) pairs behind the fit
    slope: float             # d log10(err) / d log10(hvps)
    intercept: float
    r2: float                # goodness of the log-log fit

    def __str__(self) -> str:
        return (f'{self.problem} {self.solver}/{self.backend}: '
                f'slope {self.slope:+.2f} (r²={self.r2:.3f}, '
                f'n={self.points})')


def _ladder_rows(rows: Iterable[Mapping[str, Any]]):
    """Group rows into ladders keyed by (problem, solver, backend). Rows
    without an error measurement or with a zero/invalid bill are skipped —
    they carry no rate information."""
    ladders: dict[tuple, list[tuple[float, float]]] = {}
    for row in rows:
        err = row.get('hypergrad_error')
        hvps = row.get('hvp_count')
        if err is None or hvps is None:
            continue
        err, hvps = float(err), float(hvps)
        if not (err > 0.0 and math.isfinite(err) and hvps > 0.0):
            continue
        key = (str(row.get('problem', '?')), str(row.get('solver', '?')),
               str(row.get('backend', '?')))
        ladders.setdefault(key, []).append((hvps, err))
    return ladders


def _least_squares(xs: list[float], ys: list[float]) -> tuple[float, float, float]:
    n = len(xs)
    mx, my = sum(xs) / n, sum(ys) / n
    sxx = sum((x - mx) ** 2 for x in xs)
    sxy = sum((x - mx) * (y - my) for x, y in zip(xs, ys))
    slope = sxy / sxx
    intercept = my - slope * mx
    ss_res = sum((y - (slope * x + intercept)) ** 2
                 for x, y in zip(xs, ys))
    ss_tot = sum((y - my) ** 2 for y in ys)
    r2 = 1.0 - ss_res / ss_tot if ss_tot > 0.0 else 1.0
    return slope, intercept, r2


def fit_rates(doc_or_rows: Mapping[str, Any] | Iterable[Mapping[str, Any]],
              min_points: int = 3) -> list[RateFit]:
    """Fit a log-error vs log-HVP-bill line per cell ladder.

    Accepts a full BENCH document (``{'rows': [...]}``) or a bare row list.
    Ladders with fewer than ``min_points`` *distinct* bills are skipped —
    two points always fit a line, which is a rate measurement in name only.
    Duplicate bills (e.g. population repeats) are averaged in log space
    before fitting. Returns fits sorted by (problem, solver, backend).
    """
    rows = doc_or_rows.get('rows', []) if isinstance(doc_or_rows, Mapping) \
        else list(doc_or_rows)
    fits = []
    for key, pairs in sorted(_ladder_rows(rows).items()):
        by_bill: dict[float, list[float]] = {}
        for hvps, err in pairs:
            by_bill.setdefault(hvps, []).append(math.log10(err))
        if len(by_bill) < min_points:
            continue
        xs = [math.log10(h) for h in sorted(by_bill)]
        ys = [sum(by_bill[h]) / len(by_bill[h]) for h in sorted(by_bill)]
        slope, intercept, r2 = _least_squares(xs, ys)
        problem, solver, backend = key
        fits.append(RateFit(problem=problem, solver=solver, backend=backend,
                            points=len(by_bill), slope=slope,
                            intercept=intercept, r2=r2))
    return fits


def fit_rates_file(path: str, min_points: int = 3) -> list[RateFit]:
    """``fit_rates`` over a persisted BENCH_*.json document."""
    with open(path) as f:
        return fit_rates(json.load(f), min_points=min_points)


def format_rates(baseline: list[RateFit], new: list[RateFit] | None = None
                 ) -> str:
    """Render fits as a report section; with two runs, matched ladders are
    printed side by side (baseline → new) so rate drift is scannable."""
    if new is None:
        lines = ['rate fits (log10 err vs log10 HVPs):']
        lines += [f'  {f}' for f in baseline] or ['  (no fittable ladders)']
        return '\n'.join(lines)
    lines = ['rate fits, baseline -> new:']
    base = {(f.problem, f.solver, f.backend): f for f in baseline}
    seen = set()
    for f in new:
        key = (f.problem, f.solver, f.backend)
        seen.add(key)
        b = base.get(key)
        if b is None:
            lines.append(f'  {f}   [new ladder]')
        else:
            lines.append(f'  {f.problem} {f.solver}/{f.backend}: '
                         f'slope {b.slope:+.2f} -> {f.slope:+.2f} '
                         f'(r² {b.r2:.3f} -> {f.r2:.3f}, n={f.points})')
    for key, b in base.items():
        if key not in seen:
            lines.append(f'  {b}   [ladder gone in new run]')
    if len(lines) == 1:
        lines.append('  (no fittable ladders)')
    return '\n'.join(lines)
