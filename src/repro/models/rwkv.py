"""RWKV6 "Finch" block: data-dependent decay linear attention + channel mix.

The headline RWKV6 feature — per-channel, per-token decay w_t produced from
the input via a low-rank MLP — is kept faithfully; token-shift mixing uses
static μ coefficients (RWKV5-style) for the non-decay streams. The wkv state
is (H, hd, hd) per sequence: O(1) decode memory, which is why rwkv6 runs the
long_500k shape.

Same chunked-checkpoint scan strategy as ssm.py (boundaries saved, interiors
recomputed) to bound training activation memory at O(S/chunk) states.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.config import ModelConfig
from repro.models.layers import cdtype, dense_init, pdtype


def _n_heads(cfg: ModelConfig) -> int:
    return cfg.d_model // 64          # RWKV6 uses fixed 64-dim heads


def init_rwkv_block(cfg: ModelConfig, rng) -> dict:
    d, f = cfg.d_model, cfg.d_ff
    lora = 64
    ks = jax.random.split(rng, 10)
    dt = pdtype(cfg)
    return {
        # time mix
        'mu': 0.5 * jnp.ones((5, d), dt),          # shift-mix for r,k,v,g,w
        'w_lora_a': dense_init(ks[0], (d, lora), dt),
        'w_lora_b': dense_init(ks[1], (lora, d), dt, scale=1e-2),
        'w0': jnp.full((d,), -5.0, dt),            # decay bias (slow decay)
        'bonus': jnp.zeros((_n_heads(cfg), 64), dt),  # "u" current-token bonus
        'wr': dense_init(ks[2], (d, d), dt),
        'wk': dense_init(ks[3], (d, d), dt),
        'wv': dense_init(ks[4], (d, d), dt),
        'wg': dense_init(ks[5], (d, d), dt),
        'wo': dense_init(ks[6], (d, d), dt),
        'ln_scale': jnp.ones((_n_heads(cfg), 64), dt),  # per-head groupnorm
        # channel mix
        'mu_cm': 0.5 * jnp.ones((2, d), dt),
        'ck': dense_init(ks[7], (d, f), dt),
        'cv': dense_init(ks[8], (f, d), dt),
        'cr': dense_init(ks[9], (d, d), dt),
    }


def _shift(x, prev):
    """Token shift: x_{t-1} with ``prev`` as the t=0 predecessor. (B,S,d)."""
    return jnp.concatenate([prev[:, None, :], x[:, :-1, :]], axis=1)


def _time_mix_streams(params, x, x_prev, cfg: ModelConfig):
    ct = cdtype(cfg)
    H = _n_heads(cfg)
    xs = _shift(x, x_prev)
    mu = params['mu'].astype(ct)
    mix = [x + (xs - x) * mu[i] for i in range(5)]
    r = mix[0] @ params['wr'].astype(ct)
    k = mix[1] @ params['wk'].astype(ct)
    v = mix[2] @ params['wv'].astype(ct)
    g = jax.nn.silu(mix[3] @ params['wg'].astype(ct))
    # data-dependent decay (the RWKV6 novelty): w ∈ (0,1) per channel/token
    w_raw = params['w0'].astype(jnp.float32) + (
        jnp.tanh(mix[4] @ params['w_lora_a'].astype(ct)).astype(jnp.float32)
        @ params['w_lora_b'].astype(jnp.float32))
    w = jnp.exp(-jnp.exp(w_raw))                        # (B,S,d)

    def heads(t):
        B, S, _ = t.shape
        return t.reshape(B, S, H, 64)
    return heads(r), heads(k), heads(v), g, heads(w)


def _wkv_step(state, rkvw, bonus):
    """state: (B,H,64,64) keyed [k, v]; returns y_t (B,H,64)."""
    r, k, v, w = rkvw                                   # (B,H,64) each
    att = state + jnp.einsum('bhk,bhv->bhkv', bonus * k, v)
    y = jnp.einsum('bhk,bhkv->bhv', r, att)
    state = state * w[..., None] + jnp.einsum('bhk,bhv->bhkv', k, v)
    return state, y


def rwkv_time_mix(params, x, x_prev, state, cfg: ModelConfig,
                  chunk: int = 64):
    """x: (B,S,d). Returns (out, new_x_prev, new_state)."""
    ct = cdtype(cfg)
    B, S, d = x.shape
    H = _n_heads(cfg)
    r, k, v, g, w = _time_mix_streams(params, x, x_prev, cfg)
    bonus = jnp.exp(params['bonus'].astype(jnp.float32))

    def step(st, inp):
        return _wkv_step(st, inp, bonus)

    xs = jax.tree.map(lambda t: t.transpose(1, 0, 2, 3).astype(jnp.float32),
                      (r, k, v, w))
    if S % chunk == 0 and S > chunk:
        xs = jax.tree.map(lambda a: a.reshape(S // chunk, chunk, *a.shape[1:]), xs)

        def chunk_body(st, inp):
            return jax.checkpoint(
                lambda ss, ii: jax.lax.scan(step, ss, ii))(st, inp)

        state, ys = jax.lax.scan(chunk_body, state, xs)
        ys = ys.reshape(S, B, H, 64)
    else:
        state, ys = jax.lax.scan(step, state, xs)
    y = ys.transpose(1, 0, 2, 3)                        # (B,S,H,64)

    # per-head groupnorm, then gate and project
    mean = y.mean(-1, keepdims=True)
    var = y.var(-1, keepdims=True)
    y = (y - mean) * jax.lax.rsqrt(var + 1e-5) * params['ln_scale'].astype(jnp.float32)
    y = (y.reshape(B, S, d).astype(ct) * g) @ params['wo'].astype(ct)
    return y, x[:, -1, :], state


def rwkv_channel_mix(params, x, x_prev, cfg: ModelConfig):
    ct = cdtype(cfg)
    xs = _shift(x, x_prev)
    mu = params['mu_cm'].astype(ct)
    xk = x + (xs - x) * mu[0]
    xr = x + (xs - x) * mu[1]
    k = jnp.square(jax.nn.relu(xk @ params['ck'].astype(ct)))
    r = jax.nn.sigmoid(xr @ params['cr'].astype(ct))
    return r * (k @ params['cv'].astype(ct)), x[:, -1, :]


def init_rwkv_state(cfg: ModelConfig, batch: int) -> dict:
    """Decode carry per block: token-shift predecessors + wkv matrix state.
    (Prefill/train start from zeros; the transformer block threads these —
    decode is just the S=1 case of rwkv_time_mix/rwkv_channel_mix.)"""
    H = _n_heads(cfg)
    return {'tm_prev': jnp.zeros((batch, cfg.d_model), jnp.float32),
            'cm_prev': jnp.zeros((batch, cfg.d_model), jnp.float32),
            'wkv': jnp.zeros((batch, H, 64, 64), jnp.float32)}
