"""Unified model configuration covering every assigned architecture family.

One dataclass describes dense GQA transformers, MoE variants, M-RoPE VLM
backbones, encoder-decoder audio models, Mamba/attention hybrids and RWKV6 —
the per-arch files in ``repro/configs`` only fill in numbers from the
published configs.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Literal

Family = Literal['dense', 'vlm', 'moe', 'audio', 'hybrid', 'ssm']


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: Family
    n_layers: int
    d_model: int
    n_heads: int                 # query heads; 0 for attention-free archs
    n_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 128

    # attention details
    qkv_bias: bool = False
    rope_theta: float = 500_000.0
    mrope: bool = False                       # Qwen2-VL 3-axis rotary
    mrope_sections: tuple[int, int, int] = (16, 24, 24)   # t/h/w pairs, sums to hd/2

    # MoE
    n_experts: int = 0
    top_k: int = 0
    moe_every: int = 1            # MoE FFN on layers with (i % moe_every == moe_every-1)
    shared_expert: bool = False   # Llama-4-style always-on expert
    router_aux_coef: float = 0.01

    # hybrid / SSM
    attn_every: int = 1           # attention on layers with (i % attn_every == attn_offset)
    attn_offset: int = 0
    ssm_kind: Literal['mamba', 'rwkv6', None] = None
    d_state: int = 16
    d_conv: int = 4
    expand: int = 2

    # encoder-decoder
    n_enc_layers: int = 0         # > 0 ⇒ enc-dec (decoder depth = n_layers)
    cross_len: int = 4096         # encoder length assumed during decode shapes

    # frontend: False ⇒ inputs are precomputed embeddings (audio frames /
    # vision patches), the modality frontend is a stub per the assignment.
    embed_inputs: bool = True

    act: str = 'silu'
    norm_eps: float = 1e-5
    tie_embeddings: bool = False

    # numerics / distribution
    param_dtype: str = 'float32'
    compute_dtype: str = 'bfloat16'
    fsdp: bool = True             # ZeRO-3-style param sharding over the data axis
    seq_shard: bool = True        # Megatron-SP: residual stream S-sharded over 'model'
    remat: str = 'full'           # 'none' | 'full' | 'dots'
    scan_layers: bool = True      # stack layer params, lax.scan over depth
    attn_chunk: int = 1024        # online-softmax q/kv chunking threshold+size
    use_pallas: bool = False      # TPU runtime kernels (off for dry-run/roofline)

    # ------------------------------------------------------------------ derived
    @property
    def padded_vocab(self) -> int:
        """Vocab padded to a lane-aligned multiple of 128 (Megatron-style);
        pad logits are masked to -inf so the math is unchanged."""
        return int(math.ceil(self.vocab_size / 128) * 128)

    @property
    def group_size(self) -> int:
        return self.n_heads // max(self.n_kv_heads, 1)

    @property
    def d_inner(self) -> int:
        return self.expand * self.d_model

    @property
    def is_encdec(self) -> bool:
        return self.n_enc_layers > 0

    @property
    def is_attention_free(self) -> bool:
        return self.ssm_kind == 'rwkv6'

    @property
    def block_period(self) -> int:
        """Scan block period: lcm of the per-layer-kind cycles."""
        return math.lcm(max(self.attn_every, 1), max(self.moe_every, 1))

    @property
    def subquadratic(self) -> bool:
        """True iff decode-time state is o(S²): SSM / hybrid families."""
        return self.family in ('ssm', 'hybrid')

    def layer_kinds(self) -> list[tuple[str, str]]:
        """[(mixer, ffn)] per layer within one scan block:
        mixer ∈ {attn, mamba, rwkv}, ffn ∈ {dense, moe}."""
        kinds = []
        for i in range(self.block_period):
            if self.ssm_kind == 'rwkv6':
                mixer = 'rwkv'
            elif self.ssm_kind == 'mamba' and i % self.attn_every != self.attn_offset:
                mixer = 'mamba'
            else:
                mixer = 'attn'
            ffn = 'moe' if (self.n_experts > 0
                            and i % self.moe_every == self.moe_every - 1) else 'dense'
            kinds.append((mixer, ffn))
        return kinds

    @property
    def n_blocks(self) -> int:
        assert self.n_layers % self.block_period == 0, \
            f'{self.name}: n_layers {self.n_layers} % period {self.block_period} != 0'
        return self.n_layers // self.block_period

    # parameter counts (for MODEL_FLOPS and memory budgeting)
    def param_count(self, active_only: bool = False) -> int:
        d, f, hd = self.d_model, self.d_ff, self.head_dim
        total = 0
        emb = self.padded_vocab * d
        total += emb * (1 if (self.tie_embeddings or not self.embed_inputs) else 2)
        if not self.embed_inputs:
            total += emb  # output head only; input embeddings replaced by stub

        def attn_params():
            return d * self.n_heads * hd + 2 * d * self.n_kv_heads * hd \
                + self.n_heads * hd * d \
                + (self.qkv_bias and (self.n_heads + 2 * self.n_kv_heads) * hd or 0)

        def dense_ffn():
            return 3 * d * f

        def moe_ffn(active):
            routed = self.top_k if active else self.n_experts
            p = routed * 3 * d * f + d * self.n_experts  # experts + router
            if self.shared_expert:
                p += 3 * d * f
            return p

        def mamba_params():
            di, ds = self.d_inner, self.d_state
            return (d * 2 * di            # in_proj (x and z)
                    + di * self.d_conv    # depthwise conv
                    + di * (2 * ds + 1)   # B,C,dt projections (x-dependent)
                    + di * ds + di        # A_log, D
                    + di * d)             # out_proj

        def rwkv_params():
            # time-mix (r,k,v,o,gate ≈ 5d²) incl. decay LoRA + channel-mix (2df + d²)
            return 5 * d * d + 2 * d * f

        for (mixer, ffn) in self.layer_kinds():
            n_such = self.n_layers // self.block_period
            if mixer == 'attn':
                total += attn_params() * n_such
            elif mixer == 'mamba':
                total += mamba_params() * n_such
            else:  # rwkv blocks bundle their channel-mix FFN
                total += rwkv_params() * n_such
                continue
            if ffn == 'dense':
                total += dense_ffn() * n_such
            else:
                total += moe_ffn(active_only) * n_such
        if self.is_encdec:
            # encoder layers: attention + dense FFN + cross-attn in decoder
            total += self.n_enc_layers * (attn_params() + dense_ffn())
            total += self.n_layers * attn_params()  # cross-attention
        return int(total)

    def reduced(self, **overrides) -> 'ModelConfig':
        """Tiny same-family config for CPU smoke tests."""
        hd = 16
        small = dict(
            n_layers=self.block_period * 2,
            d_model=64,
            n_heads=0 if self.n_heads == 0 else 4,
            n_kv_heads=0 if self.n_kv_heads == 0 else 2,
            head_dim=hd,
            d_ff=128,
            vocab_size=256,
            n_experts=min(self.n_experts, 4),
            top_k=min(self.top_k, 2),
            n_enc_layers=2 if self.is_encdec else 0,
            cross_len=16,
            d_state=4,
            d_conv=4,
            attn_chunk=32,
            # t/h/w frequency sections scale with head_dim (sum = hd/2)
            mrope_sections=(hd // 8, 3 * hd // 16, 3 * hd // 16),
            param_dtype='float32',
            compute_dtype='float32',
            name=self.name + '-smoke',
            fsdp=False,
            remat='none',
        )
        small.update(overrides)
        return dataclasses.replace(self, **small)
