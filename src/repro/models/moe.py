"""Dropless sort-based Mixture-of-Experts with `lax.ragged_dot`.

Design (DESIGN.md §4): tokens are argsorted by assigned expert and hit their
expert's weights through `ragged_dot`, so compiled FLOPs equal the *active*
FLOPs (6·N_active·D shows up cleanly in the MODEL_FLOPS/HLO_FLOPs roofline
ratio — no capacity-factor waste, no dropped tokens). Expert weights are
tensor-parallel on d_ff over the ``model`` axis, so there is **no all-to-all**:
the only collective is the usual row-parallel psum on the second matmul,
identical to a dense FFN. (An EP/all-to-all layout is a recorded hillclimb
alternative for decode, where per-device token counts are tiny.)
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.config import ModelConfig
from repro.models.layers import _act, cdtype, dense_init, pdtype


def init_moe(cfg: ModelConfig, rng) -> dict:
    d, f, E = cfg.d_model, cfg.d_ff, cfg.n_experts
    ks = jax.random.split(rng, 5)
    dt = pdtype(cfg)
    p = {'router': dense_init(ks[0], (d, E), dt, scale=d ** -0.5),
         'w1': dense_init(ks[1], (E, d, f), dt),
         'w3': dense_init(ks[2], (E, d, f), dt),
         'w2': dense_init(ks[3], (E, f, d), dt)}
    if cfg.shared_expert:
        k1, k2, k3 = jax.random.split(ks[4], 3)
        p['shared'] = {'w1': dense_init(k1, (d, f), dt),
                       'w3': dense_init(k2, (d, f), dt),
                       'w2': dense_init(k3, (f, d), dt)}
    return p


import functools


@functools.partial(jax.custom_vjp, nondiff_argnums=(3, 4))
def _rdot(x, w, group_sizes, dx_reduce=(), dw_reduce=()):
    """ragged_dot with a memory-sane VJP.

    jax's built-in ragged_dot transpose materializes a dense (E, N, d)
    one-hot/select tensor (measured 172 GB × several on llama4 train); both
    cotangents are themselves ragged contractions:
      dx = ragged_dot(dy, wᵀ)            (same grouping)
      dw = ragged_dot_general(x, dy)     (ragged dim contracting → (E, d, f))

    ``dx_reduce``/``dw_reduce``: mesh axes to psum the cotangents over when
    running inside shard_map — a cotangent must match its primal's varying
    axes (x is model-invariant ⇒ dx psums over 'model'; w is batch-invariant
    ⇒ dw psums over the batch axes). Empty tuples outside shard_map.
    """
    return jax.lax.ragged_dot(x, w, group_sizes)


def _rdot_fwd(x, w, group_sizes, dx_reduce=(), dw_reduce=()):
    return jax.lax.ragged_dot(x, w, group_sizes), (x, w, group_sizes)


def _rdot_bwd(dx_reduce, dw_reduce, res, dy):
    x, w, group_sizes = res
    dx = jax.lax.ragged_dot(dy, jnp.swapaxes(w, 1, 2), group_sizes)
    if hasattr(jax.lax, 'ragged_dot_general'):
        rdn = jax.lax.RaggedDotDimensionNumbers(
            dot_dimension_numbers=(((0,), (0,)), ((), ())),
            lhs_ragged_dimensions=[0],
            rhs_group_dimensions=[])
        dw = jax.lax.ragged_dot_general(x.astype(jnp.float32),
                                        dy.astype(jnp.float32), group_sizes,
                                        rdn)
    else:
        # jax < 0.5 has no ragged_dot_general: contract each expert's token
        # segment with a masked dense matmul, one expert at a time via
        # lax.map. O(N·d) temps (no (E, N, d) one-hot), E× dense FLOPs —
        # the compat cost of the old API, paid only on old jax.
        bounds = jnp.cumsum(group_sizes)
        starts = bounds - group_sizes
        rows = jnp.arange(x.shape[0])
        xf, dyf = x.astype(jnp.float32), dy.astype(jnp.float32)

        def one_expert(e):
            m = ((rows >= starts[e]) & (rows < bounds[e])).astype(jnp.float32)
            return (xf * m[:, None]).T @ dyf

        dw = jax.lax.map(one_expert, jnp.arange(group_sizes.shape[0]))
    if dx_reduce:
        dx = jax.lax.psum(dx, dx_reduce)
    if dw_reduce:
        dw = jax.lax.psum(dw, dw_reduce)
    return dx.astype(x.dtype), dw.astype(w.dtype), None


_rdot.defvjp(_rdot_fwd, _rdot_bwd)


def _moe_local(params, xt, cfg: ModelConfig, axis_names=(), impl='ragged'):
    """Per-shard MoE body. xt: (N_local, d) with the *full* d; expert weights
    are the local d_ff slice. ``axis_names``: (model_axes, batch_axes) when
    running under shard_map — the w2 partial is psum'd over model, the aux
    statistics pmean'd over batch.
    """
    ct = cdtype(cfg)
    N, d = xt.shape
    E, k = cfg.n_experts, cfg.top_k

    router_logits = (xt.astype(jnp.float32)
                     @ params['router'].astype(jnp.float32))        # (N, E)
    probs = jax.nn.softmax(router_logits, axis=-1)
    gate, expert_idx = jax.lax.top_k(probs, k)                      # (N, k)
    gate = gate / jnp.clip(gate.sum(-1, keepdims=True), 1e-9)       # renormalize

    # ---- load-balance aux (Switch-style): E · <fraction, prob> ----
    frac = jnp.mean(jax.nn.one_hot(expert_idx, E, dtype=jnp.float32),
                    axis=(0, 1))
    mean_probs = probs.mean(0)
    model_axes, batch_axes = (axis_names or ((), ()))
    if batch_axes:
        frac = jax.lax.pmean(frac, batch_axes)
        mean_probs = jax.lax.pmean(mean_probs, batch_axes)
    aux = E * jnp.sum(frac * mean_probs) * cfg.router_aux_coef

    if impl == 'ragged':
        # -- dropless: sort token-replicas by expert id (local, no comms) --
        flat_expert = expert_idx.reshape(N * k)                     # (Nk,)
        order = jnp.argsort(flat_expert, stable=True)
        inv_order = jnp.argsort(order)
        token_of = order // k                                       # source token
        xs = jnp.take(xt, token_of, axis=0).astype(ct)              # (Nk, d)
        group_sizes = jnp.bincount(flat_expert, length=E)
        # xs is model-invariant (dx psums over model); weights batch-
        # invariant (dw psums over batch); h varies on both (no reduce).
        h = _rdot(xs, params['w1'].astype(ct), group_sizes,
                  model_axes, batch_axes)
        g = _rdot(xs, params['w3'].astype(ct), group_sizes,
                  model_axes, batch_axes)
        h = _act(cfg.act)(h) * g
        out_sorted = _rdot(h, params['w2'].astype(ct), group_sizes,
                           (), batch_axes)
        out = jnp.take(out_sorted, inv_order, axis=0).reshape(N, k, d)
        out = jnp.einsum('nkd,nk->nd', out.astype(jnp.float32), gate)
    else:
        # -- fixed-capacity dispatch (GShard/Switch): scatter → batched
        # einsum → gather. Pure dense ops ⇒ partitions on every backend
        # (ragged_dot's non-TPU lowering materializes dense (E,N,d) masks —
        # measured 730 GB/chip on llama4 before this). cap·E ≈ 1.25·N·k
        # slots; overflow tokens fall back to their gate-weighted residual.
        Nk = N * k
        cap = Nk if Nk <= 8 * E else min(
            Nk, max(8, int(1.25 * Nk / E + 7) // 8 * 8))
        flat_expert = expert_idx.reshape(Nk)
        onehot = jax.nn.one_hot(flat_expert, E, dtype=jnp.int32)    # (Nk, E)
        pos = jnp.cumsum(onehot, axis=0) - onehot                   # pre-count
        slot = jnp.take_along_axis(pos, flat_expert[:, None], 1)[:, 0]
        keep = (slot < cap).astype(jnp.float32)
        token_of = jnp.arange(Nk) // k
        buf = jnp.zeros((E, cap, d), ct).at[flat_expert, slot].add(
            jnp.take(xt, token_of, axis=0).astype(ct)
            * keep[:, None].astype(ct))
        h = jnp.einsum('ecd,edf->ecf', buf, params['w1'].astype(ct))
        g = jnp.einsum('ecd,edf->ecf', buf, params['w3'].astype(ct))
        h = _act(cfg.act)(h) * g
        y = jnp.einsum('ecf,efd->ecd', h, params['w2'].astype(ct))
        picked = y[flat_expert, slot] * keep[:, None]               # (Nk, d)
        out = jnp.einsum('nkd,nk->nd', picked.reshape(N, k, d)
                         .astype(jnp.float32), gate)

    if cfg.shared_expert:
        sp = params['shared']
        hs = _act(cfg.act)(xt.astype(ct) @ sp['w1'].astype(ct)) \
            * (xt.astype(ct) @ sp['w3'].astype(ct))
        out = out + (hs @ sp['w2'].astype(ct)).astype(jnp.float32)

    if model_axes:
        # row-parallel second matmul: one activation psum, same as dense FFN
        out = jax.lax.psum(out, model_axes)
    return out.astype(ct), aux


def moe_ffn(params, x, cfg: ModelConfig):
    """x: (B, S, d) → (B, S, d), plus router load-balancing aux loss.

    Distribution: GSPMD cannot partition `ragged_dot` (it replicates the
    (E, d, d_ff) expert weights — measured 2 TB/chip on llama4 before this),
    so under a mesh the expert compute runs inside an explicit `shard_map`:
    tokens stay on their (pod, data) shard (dispatch/sort is shard-local —
    zero collective), expert weights are TP-split on d_ff over 'model', and
    the only communication is the dense-FFN-equivalent psum of the output.
    """
    from repro.distributed.ctx import current_mesh, shard_map
    B, S, d = x.shape
    N = B * S
    xt = x.reshape(N, d)
    mesh = current_mesh()
    if mesh is None:
        out, aux = _moe_local(params, xt, cfg)
        return out.reshape(B, S, d), aux

    from jax.sharding import PartitionSpec as P
    batch_axes = tuple(a for a in ('pod', 'data') if a in mesh.axis_names)
    n_batch = 1
    for a in batch_axes:
        n_batch *= mesh.shape[a]
    if N % n_batch != 0:
        batch_axes = ()
    model_axes = ('model',) if ('model' in mesh.axis_names
                                and cfg.d_ff % mesh.shape['model'] == 0) else ()
    tok_spec = P(batch_axes if batch_axes else None, None)
    w_col = P(None, None, model_axes[0]) if model_axes else P(None, None, None)
    w_row = P(None, model_axes[0], None) if model_axes else P(None, None, None)
    pspec = {'router': P(None, None), 'w1': w_col, 'w3': w_col, 'w2': w_row}
    if cfg.shared_expert:
        m0 = model_axes[0] if model_axes else None
        pspec['shared'] = {'w1': P(None, m0), 'w3': P(None, m0),
                           'w2': P(m0, None)}

    out, aux = shard_map(
        lambda p_, x_: _moe_local(p_, x_, cfg, (model_axes, batch_axes),
                                  impl='capacity'),
        mesh=mesh,
        in_specs=(pspec, tok_spec),
        out_specs=(tok_spec, P()),
    )(params, xt)
    return out.reshape(B, S, d), aux
