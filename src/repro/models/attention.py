"""GQA attention: full, chunked (online-softmax), and decode-with-cache paths.

Three execution regimes, one math:

* ``S ≤ cfg.attn_chunk``      → plain softmax einsum (small/smoke).
* ``S  > cfg.attn_chunk``     → chunked online-softmax over query/kv blocks —
  the XLA twin of the Pallas flash kernel (never materializes S×S logits;
  required for the 32k prefill shapes).
* decode                      → single-query attention against a KV cache
  whose sequence axis is sharded over the ``model`` mesh axis
  (flash-decoding-style: reductions over the sharded axis lower to
  local-reduce + tiny all-reduce of (B,H) stats under GSPMD).

GQA is computed by repeating KV heads to the query-head count; under a
head-sharded layout the repeat is a per-shard slice of a broadcast (no
communication, no global materialization).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.config import ModelConfig
from repro.models.layers import (apply_mrope, apply_rope, cdtype, dense_init,
                                 pdtype)

NEG_INF = -1e30


# --------------------------------------------------------------------- params
def init_attention(cfg: ModelConfig, rng, cross: bool = False) -> dict:
    d, hd = cfg.d_model, cfg.head_dim
    H, KV = cfg.n_heads, cfg.n_kv_heads
    ks = jax.random.split(rng, 4)
    dt = pdtype(cfg)
    p = {'wq': dense_init(ks[0], (d, H * hd), dt),
         'wk': dense_init(ks[1], (d, KV * hd), dt),
         'wv': dense_init(ks[2], (d, KV * hd), dt),
         'wo': dense_init(ks[3], (H * hd, d), dt)}
    if cfg.qkv_bias and not cross:
        p['bq'] = jnp.zeros((H * hd,), dt)
        p['bk'] = jnp.zeros((KV * hd,), dt)
        p['bv'] = jnp.zeros((KV * hd,), dt)
    return p


def _project_qkv(params, xq, xkv, cfg: ModelConfig, positions, rope: bool,
                 head_shard: bool = True):
    """Returns q: (B,Sq,H,hd), k/v: (B,Skv,KV,hd).

    ``head_shard=False`` (decode): the KV cache is *sequence*-sharded over
    'model' (flash-decoding layout), so head-TP on q would force GSPMD to
    reshard the whole cache per layer (observed as "involuntary full
    rematerialization" on llama3 decode); decode keeps heads replicated and
    lets the softmax statistics reduce over the sharded S axis instead.
    """
    ct = cdtype(cfg)
    B, Sq, _ = xq.shape
    Skv = xkv.shape[1]
    wq = params['wq'].astype(ct)
    q = xq @ wq
    k = xkv @ params['wk'].astype(ct)
    v = xkv @ params['wv'].astype(ct)
    if 'bq' in params:
        q = q + params['bq'].astype(ct)
        k = k + params['bk'].astype(ct)
        v = v + params['bv'].astype(ct)
    from repro.distributed.ctx import constrain, current_mesh
    n_heads = cfg.n_heads
    mesh = current_mesh()
    if (head_shard and mesh is not None and 'model' in mesh.axis_names
            and n_heads % mesh.shape['model'] != 0):
        # §Perf hillclimb (qwen2 28H / llama4 40H vs model=16): zero-pad the
        # query-head axis to the next multiple of the TP width. Padded heads
        # have zero queries AND zero wo rows (see below), so the math is
        # exact and their wq/wo gradients are identically zero; cost is
        # H_pad/H extra attention FLOPs (≤ +20%) versus 16×-replicated
        # attention compute without it (measured useful ratio 0.068).
        m = mesh.shape['model']
        n_heads = (n_heads + m - 1) // m * m
        q = jnp.pad(q, ((0, 0), (0, 0),
                        (0, (n_heads - cfg.n_heads) * cfg.head_dim)))
    q = q.reshape(B, Sq, n_heads, cfg.head_dim)
    k = k.reshape(B, Skv, cfg.n_kv_heads, cfg.head_dim)
    v = v.reshape(B, Skv, cfg.n_kv_heads, cfg.head_dim)
    # pin batch + head-TP layout ('model' drops automatically when H ∤ mesh)
    head_ax = 'model' if head_shard else None
    q = constrain(q, 'batch', None, head_ax, None)
    k = constrain(k, 'batch', None, head_ax, None)
    v = constrain(v, 'batch', None, head_ax, None)
    if rope:
        if cfg.mrope:
            q = apply_mrope(q, positions, cfg.rope_theta, cfg.mrope_sections)
            k = apply_mrope(k, positions, cfg.rope_theta, cfg.mrope_sections)
        else:
            pos2d = positions if positions.ndim == 2 else positions[:, 0]
            q = apply_rope(q, pos2d, cfg.rope_theta)
            k = apply_rope(k, pos2d, cfg.rope_theta)
    return q, k, v


def _expand_kv(k: jax.Array, group: int) -> jax.Array:
    """(B,S,KV,hd) → (B,S,KV*group,hd). Slice-of-broadcast under sharding."""
    if group == 1:
        return k
    B, S, KV, hd = k.shape
    return jnp.broadcast_to(k[:, :, :, None, :], (B, S, KV, group, hd)) \
              .reshape(B, S, KV * group, hd)


# ------------------------------------------------------------- core attention
def _full_attention(q, k, v, causal: bool, scale: float):
    """(B,S,H,hd) × (B,T,H,hd) — materializes (B,H,S,T); small-S path."""
    logits = jnp.einsum('bshd,bthd->bhst', q, k).astype(jnp.float32) * scale
    if causal:
        S, T = logits.shape[-2], logits.shape[-1]
        mask = jnp.tril(jnp.ones((S, T), bool), T - S)
        logits = jnp.where(mask, logits, NEG_INF)
    w = jax.nn.softmax(logits, axis=-1).astype(q.dtype)
    return jnp.einsum('bhst,bthd->bshd', w, v)


def _chunked_attention(q, k, v, causal: bool, scale: float, chunk: int):
    """Online-softmax flash-style attention in pure XLA (scan over q blocks,
    inner scan over kv blocks with running (max, sum, acc) stats)."""
    B, S, H, hd = q.shape
    T = k.shape[1]
    qc = min(chunk, S)
    kc = min(chunk, T)
    assert S % qc == 0 and T % kc == 0, 'sequence must divide attn_chunk'
    nq, nk = S // qc, T // kc

    q = q.reshape(B, nq, qc, H, hd).transpose(1, 0, 3, 2, 4)   # (nq,B,H,qc,hd)
    k = k.reshape(B, nk, kc, H, hd).transpose(1, 0, 3, 2, 4)
    v = v.reshape(B, nk, kc, H, hd).transpose(1, 0, 3, 2, 4)

    # Nested remat: the backward of each q-block recomputes its kv scan, so
    # only (qc, hd)-sized q-block inputs are saved — without this, the scan
    # transpose saves every (qc×kc) logit block = the full S² matrix
    # (measured 2.15 GB × blocks on the 4k dry-run).
    @jax.checkpoint
    def q_block(qi_and_blk):
        qi, qb = qi_and_blk                                     # (B,H,qc,hd)

        def kv_block(carry, ki_and_blk):
            m, l, acc = carry
            ki, kb, vb = ki_and_blk
            logits = jnp.einsum('bhqd,bhkd->bhqk', qb, kb).astype(jnp.float32) * scale
            if causal:
                qpos = qi * qc + jnp.arange(qc)
                kpos = ki * kc + jnp.arange(kc)
                logits = jnp.where(qpos[:, None] >= kpos[None, :], logits, NEG_INF)
            m_new = jnp.maximum(m, logits.max(-1))
            alpha = jnp.exp(m - m_new)
            p = jnp.exp(logits - m_new[..., None])
            l = l * alpha + p.sum(-1)
            acc = acc * alpha[..., None] + jnp.einsum(
                'bhqk,bhkd->bhqd', p.astype(qb.dtype), vb).astype(jnp.float32)
            return (m_new, l, acc), None

        init = (jnp.full((B, H, qc), NEG_INF, jnp.float32),
                jnp.zeros((B, H, qc), jnp.float32),
                jnp.zeros((B, H, qc, hd), jnp.float32))
        (m, l, acc), _ = jax.lax.scan(
            kv_block, init, (jnp.arange(nk), k, v))
        return (acc / jnp.clip(l, 1e-30)[..., None]).astype(qb.dtype)

    out = jax.lax.map(q_block, (jnp.arange(nq), q))             # (nq,B,H,qc,hd)
    return out.transpose(1, 0, 3, 2, 4).reshape(B, S, H, hd)


def multihead_attention(params, xq, cfg: ModelConfig, *, xkv=None,
                        positions=None, causal=True, rope=True):
    """Training/prefill attention. xq: (B,S,d). Returns (B,S,d)."""
    xkv = xq if xkv is None else xkv
    B, S, _ = xq.shape
    if positions is None:
        positions = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32), (B, S))
    q, k, v = _project_qkv(params, xq, xkv, cfg, positions, rope)
    group = q.shape[2] // cfg.n_kv_heads    # padded-head aware (see _project_qkv)
    k = _expand_kv(k, group)
    v = _expand_kv(v, group)
    scale = cfg.head_dim ** -0.5
    if cfg.use_pallas and S > cfg.attn_chunk:
        from repro.kernels.ops import flash_attention
        out = flash_attention(q, k, v, causal=causal, scale=scale)
    elif S > cfg.attn_chunk or k.shape[1] > cfg.attn_chunk:
        out = _chunked_attention(q, k, v, causal, scale, cfg.attn_chunk)
    else:
        out = _full_attention(q, k, v, causal, scale)
    ct = cdtype(cfg)
    wo = params['wo'].astype(ct)
    pad_rows = q.shape[2] * cfg.head_dim - wo.shape[0]
    if pad_rows:   # zero rows ⇒ padded heads contribute nothing (exact math)
        wo = jnp.pad(wo, ((0, pad_rows), (0, 0)))
    return out.reshape(B, S, -1) @ wo


# ----------------------------------------------------------------- decoding
def init_kv_cache(cfg: ModelConfig, n_layers: int, batch: int, max_len: int,
                  dtype=jnp.bfloat16) -> dict:
    """Cache layout (layers, B, S, KV, hd): S is sharded over `model`,
    B over (`pod`,`data`) — see distributed/sharding.py rules."""
    shape = (n_layers, batch, max_len, cfg.n_kv_heads, cfg.head_dim)
    return {'k': jnp.zeros(shape, dtype), 'v': jnp.zeros(shape, dtype),
            'pos': jnp.zeros((), jnp.int32)}


def decode_attention(params, x, cache_k, cache_v, pos, cfg: ModelConfig):
    """Single-token decode. x: (B,1,d); cache_k/v: (B,Smax,KV,hd); pos: ().

    Returns (out (B,1,d), new_k, new_v). Softmax statistics reduce over the
    sharded S axis (local reduce + (B,H) all-reduce under GSPMD) — the XLA
    formulation of flash-decoding.
    """
    B, _, _ = x.shape
    Smax = cache_k.shape[1]
    positions = jnp.full((B, 1), pos, jnp.int32)
    if cfg.mrope:
        positions = jnp.broadcast_to(positions[:, None, :], (B, 3, 1))
    q, k_new, v_new = _project_qkv(params, x, x, cfg, positions, rope=True,
                                   head_shard=False)

    cache_k = jax.lax.dynamic_update_slice_in_dim(
        cache_k, k_new.astype(cache_k.dtype), pos, axis=1)
    cache_v = jax.lax.dynamic_update_slice_in_dim(
        cache_v, v_new.astype(cache_v.dtype), pos, axis=1)

    from repro.distributed.ctx import constrain
    kx = _expand_kv(cache_k.astype(q.dtype), cfg.group_size)   # (B,Smax,H,hd)
    vx = _expand_kv(cache_v.astype(q.dtype), cfg.group_size)
    # flash-decoding layout: keep the S axis of everything derived from the
    # cache on 'model' — otherwise the einsum partitioner flips to kv-head
    # sharding and "involuntary full rematerialization" replicates (and
    # f32-copies) the entire cache per layer (measured on llama3 decode).
    kx = constrain(kx, 'batch', 'model', None, None)
    vx = constrain(vx, 'batch', 'model', None, None)
    scale = cfg.head_dim ** -0.5
    logits = jnp.einsum('bshd,bthd->bhst', q, kx).astype(jnp.float32) * scale
    logits = constrain(logits, 'batch', None, None, 'model')
    valid = (jnp.arange(Smax) <= pos)[None, None, None, :]
    logits = jnp.where(valid, logits, NEG_INF)
    w = jax.nn.softmax(logits, axis=-1).astype(q.dtype)
    out = jnp.einsum('bhst,bthd->bshd', w, vx)
    ct = cdtype(cfg)
    out = out.reshape(B, 1, -1) @ params['wo'].astype(ct)
    return out, cache_k, cache_v


def cross_attention_cache(params, enc_out, cfg: ModelConfig):
    """Precompute encoder-side K/V once for the whole decode."""
    ct = cdtype(cfg)
    B, T, _ = enc_out.shape
    k = (enc_out @ params['wk'].astype(ct)).reshape(B, T, cfg.n_kv_heads, cfg.head_dim)
    v = (enc_out @ params['wv'].astype(ct)).reshape(B, T, cfg.n_kv_heads, cfg.head_dim)
    return k, v


def cross_attention(params, xq, k, v, cfg: ModelConfig):
    """Decoder→encoder attention (no mask, no rope)."""
    ct = cdtype(cfg)
    B, S, _ = xq.shape
    q = (xq @ params['wq'].astype(ct)).reshape(B, S, cfg.n_heads, cfg.head_dim)
    kx = _expand_kv(k.astype(q.dtype), cfg.group_size)
    vx = _expand_kv(v.astype(q.dtype), cfg.group_size)
    scale = cfg.head_dim ** -0.5
    if S > cfg.attn_chunk or kx.shape[1] > cfg.attn_chunk:
        out = _chunked_attention(q, kx, vx, False, scale, cfg.attn_chunk)
    else:
        out = _full_attention(q, kx, vx, False, scale)
    return out.reshape(B, S, -1) @ params['wo'].astype(ct)
