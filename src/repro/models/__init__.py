from repro.models.config import ModelConfig
from repro.models.transformer import Model, build_model, init_params

__all__ = ['ModelConfig', 'Model', 'build_model', 'init_params']
