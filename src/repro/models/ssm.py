"""Mamba-1 selective SSM block (Jamba's mixer) — scan form + O(1) decode.

TPU adaptation (DESIGN.md §3): the CUDA selective-scan kernel is replaced by
a chunked `lax.scan` over time with `jax.checkpoint` on chunk interiors —
boundaries are saved, interiors recomputed in the backward pass, keeping the
activation footprint at O(S/chunk · B·d_inner·d_state) instead of O(S · ...).
Decode carries (conv window, ssm state) — constant memory in sequence length,
which is what qualifies Jamba for the long_500k shape.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.config import ModelConfig
from repro.models.layers import cdtype, dense_init, pdtype


def init_mamba(cfg: ModelConfig, rng) -> dict:
    d, di, ds = cfg.d_model, cfg.d_inner, cfg.d_state
    ks = jax.random.split(rng, 6)
    dt = pdtype(cfg)
    return {
        'in_proj': dense_init(ks[0], (d, 2 * di), dt),
        'conv_w': dense_init(ks[1], (cfg.d_conv, di), dt, scale=cfg.d_conv ** -0.5),
        'conv_b': jnp.zeros((di,), dt),
        'x_proj': dense_init(ks[2], (di, 2 * ds + 1), dt),   # → (B, C, dt)
        'dt_proj_w': dense_init(ks[3], (1, di), dt, scale=1.0),
        'dt_proj_b': jnp.log(jnp.expm1(
            jnp.exp(jax.random.uniform(ks[4], (di,), jnp.float32,
                                       jnp.log(1e-3), jnp.log(1e-1))))).astype(dt),
        'A_log': jnp.log(jnp.broadcast_to(
            jnp.arange(1, ds + 1, dtype=jnp.float32), (di, ds))).astype(dt),
        'D': jnp.ones((di,), dt),
        'out_proj': dense_init(ks[5], (di, d), dt),
    }


def _ssm_inputs(params, x, cfg: ModelConfig):
    """Shared front half: conv + selective (Δ, B̄, C) construction.
    x: (B, S, d). Returns u, z, dt_, Bc, Cc and A."""
    ct = cdtype(cfg)
    di, ds = cfg.d_inner, cfg.d_state
    xz = x @ params['in_proj'].astype(ct)                   # (B, S, 2di)
    u, z = jnp.split(xz, 2, axis=-1)

    # depthwise causal conv over time
    w = params['conv_w'].astype(ct)                         # (K, di)
    pad = jnp.pad(u, ((0, 0), (cfg.d_conv - 1, 0), (0, 0)))
    u = sum(pad[:, i:i + u.shape[1], :] * w[i] for i in range(cfg.d_conv))
    u = jax.nn.silu(u + params['conv_b'].astype(ct))

    bcd = u @ params['x_proj'].astype(ct)                   # (B, S, 2ds+1)
    Bc = bcd[..., :ds].astype(jnp.float32)
    Cc = bcd[..., ds:2 * ds].astype(jnp.float32)
    dt_raw = bcd[..., -1:] @ params['dt_proj_w'].astype(ct) # (B, S, di)
    dt_ = jax.nn.softplus(dt_raw.astype(jnp.float32)
                          + params['dt_proj_b'].astype(jnp.float32))
    A = -jnp.exp(params['A_log'].astype(jnp.float32))       # (di, ds)
    return u, z, dt_, Bc, Cc, A


def mamba_scan(params, x, cfg: ModelConfig, chunk: int = 64):
    """Training/prefill path. x: (B,S,d) → (B,S,d)."""
    ct = cdtype(cfg)
    B, S, d = x.shape
    di, ds = cfg.d_inner, cfg.d_state
    u, z, dt_, Bc, Cc, A = _ssm_inputs(params, x, cfg)

    decay = jnp.exp(dt_[..., None] * A)                     # (B,S,di,ds)
    drive = (dt_ * u.astype(jnp.float32))[..., None] * Bc[:, :, None, :]

    def step(h, inp):
        dec, drv, c = inp                                   # (B,di,ds) ×2, (B,ds)
        h = h * dec + drv
        y = jnp.einsum('bdn,bn->bd', h, c)
        return h, y

    def chunk_body(h, inp):
        inner = lambda hh, ii: step(hh, ii)
        h, ys = jax.checkpoint(
            lambda hh, ii: jax.lax.scan(inner, hh, ii))(h, inp)
        return h, ys

    xs = (decay.transpose(1, 0, 2, 3), drive.transpose(1, 0, 2, 3),
          Cc.transpose(1, 0, 2))
    h0 = jnp.zeros((B, di, ds), jnp.float32)
    if S % chunk == 0 and S > chunk:
        xs = jax.tree.map(lambda a: a.reshape(S // chunk, chunk, *a.shape[1:]), xs)
        _, ys = jax.lax.scan(chunk_body, h0, xs)
        ys = ys.reshape(S, B, di)
    else:
        _, ys = jax.lax.scan(step, h0, xs)
    y = ys.transpose(1, 0, 2).astype(ct)                    # (B,S,di)

    y = y + u * params['D'].astype(ct)
    y = y * jax.nn.silu(z)
    return y @ params['out_proj'].astype(ct)


def init_mamba_state(cfg: ModelConfig, batch: int) -> dict:
    return {'conv': jnp.zeros((batch, cfg.d_conv - 1, cfg.d_inner), jnp.float32),
            'ssm': jnp.zeros((batch, cfg.d_inner, cfg.d_state), jnp.float32)}


def mamba_decode(params, x, state, cfg: ModelConfig):
    """Single-step decode. x: (B,1,d); state O(1) in sequence length."""
    ct = cdtype(cfg)
    B = x.shape[0]
    di, ds = cfg.d_inner, cfg.d_state
    xz = x[:, 0, :] @ params['in_proj'].astype(ct)
    u, z = jnp.split(xz, 2, axis=-1)                        # (B, di)

    window = jnp.concatenate([state['conv'].astype(ct), u[:, None, :]], axis=1)
    w = params['conv_w'].astype(ct)
    u_conv = jnp.einsum('bkd,kd->bd', window, w) + params['conv_b'].astype(ct)
    u_conv = jax.nn.silu(u_conv)
    new_conv = window[:, 1:, :].astype(jnp.float32)

    bcd = u_conv @ params['x_proj'].astype(ct)
    Bc = bcd[..., :ds].astype(jnp.float32)
    Cc = bcd[..., ds:2 * ds].astype(jnp.float32)
    dt_raw = bcd[..., -1:] @ params['dt_proj_w'].astype(ct)
    dt_ = jax.nn.softplus(dt_raw.astype(jnp.float32)
                          + params['dt_proj_b'].astype(jnp.float32))
    A = -jnp.exp(params['A_log'].astype(jnp.float32))

    h = state['ssm'] * jnp.exp(dt_[..., None] * A) \
        + (dt_ * u_conv.astype(jnp.float32))[..., None] * Bc[:, None, :]
    y = jnp.einsum('bdn,bn->bd', h, Cc).astype(ct)
    y = y + u_conv * params['D'].astype(ct)
    y = y * jax.nn.silu(z)
    out = (y @ params['out_proj'].astype(ct))[:, None, :]
    return out, {'conv': new_conv, 'ssm': h}
