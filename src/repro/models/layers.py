"""Shared building blocks: init helpers, RMSNorm, RoPE / M-RoPE, SwiGLU MLP.

All modules are purely functional: ``init_*`` returns a param subtree,
``apply`` is a free function. Compute happens in cfg.compute_dtype with f32
accumulation at reductions; params live in cfg.param_dtype.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.config import ModelConfig


def _dtype(name: str):
    return {'float32': jnp.float32, 'bfloat16': jnp.bfloat16,
            'float16': jnp.float16}[name]


def pdtype(cfg: ModelConfig):
    return _dtype(cfg.param_dtype)


def cdtype(cfg: ModelConfig):
    return _dtype(cfg.compute_dtype)


def dense_init(rng, shape, dtype, scale: float | None = None):
    """Truncated-normal fan-in init (what Llama/Mistral releases use)."""
    fan_in = shape[-2] if len(shape) >= 2 else shape[-1]
    std = scale if scale is not None else fan_in ** -0.5
    return (std * jax.random.truncated_normal(rng, -3, 3, shape)).astype(dtype)


# ----------------------------------------------------------------- RMSNorm
def init_rmsnorm(cfg: ModelConfig, dim: int | None = None):
    return {'scale': jnp.ones((dim or cfg.d_model,), pdtype(cfg))}


def rmsnorm(params, x, eps: float, use_pallas: bool = False):
    if use_pallas:
        from repro.kernels.ops import rmsnorm as rmsnorm_kernel
        return rmsnorm_kernel(x, params['scale'], eps)
    dt = x.dtype
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    return (xf * jax.lax.rsqrt(var + eps)).astype(dt) * params['scale'].astype(dt)


# ----------------------------------------------------------------- RoPE
def rope_frequencies(head_dim: int, theta: float) -> jax.Array:
    return 1.0 / (theta ** (jnp.arange(0, head_dim, 2, jnp.float32) / head_dim))


def apply_rope(x: jax.Array, positions: jax.Array, theta: float) -> jax.Array:
    """x: (B, S, H, hd); positions: (B, S) int32."""
    hd = x.shape[-1]
    freqs = rope_frequencies(hd, theta)                    # (hd/2,)
    angles = positions[..., None].astype(jnp.float32) * freqs  # (B, S, hd/2)
    cos = jnp.cos(angles)[:, :, None, :]
    sin = jnp.sin(angles)[:, :, None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


def apply_mrope(x: jax.Array, positions: jax.Array, theta: float,
                sections: tuple[int, int, int]) -> jax.Array:
    """Qwen2-VL multimodal RoPE. positions: (B, 3, S) = (temporal, h, w) ids.

    The hd/2 frequency slots are partitioned into t/h/w sections; each section
    rotates by its own positional component (dynamic-resolution vision needs
    2-D spatial phase; text degenerates to all-three-equal = plain RoPE).
    """
    hd = x.shape[-1]
    freqs = rope_frequencies(hd, theta)                    # (hd/2,)
    sec = jnp.concatenate([jnp.full((s,), i, jnp.int32)
                           for i, s in enumerate(sections)])
    # per-slot positional component: (B, S, hd/2)
    comp = jnp.transpose(positions, (0, 2, 1)).astype(jnp.float32)  # (B, S, 3)
    pos = jnp.take(comp, sec, axis=-1)
    angles = pos * freqs
    cos = jnp.cos(angles)[:, :, None, :]
    sin = jnp.sin(angles)[:, :, None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


# ----------------------------------------------------------------- SwiGLU MLP
def init_mlp(cfg: ModelConfig, rng) -> dict:
    k1, k2, k3 = jax.random.split(rng, 3)
    d, f, dt = cfg.d_model, cfg.d_ff, pdtype(cfg)
    return {'w1': dense_init(k1, (d, f), dt),
            'w3': dense_init(k2, (d, f), dt),
            'w2': dense_init(k3, (f, d), dt)}


def _act(name: str):
    return {'silu': jax.nn.silu, 'gelu': jax.nn.gelu,
            'relu': jax.nn.relu, 'leaky_relu': lambda x: jax.nn.leaky_relu(x, 0.01)}[name]


def mlp(params, x, cfg: ModelConfig):
    from repro.distributed.ctx import constrain
    ct = cdtype(cfg)
    h = _act(cfg.act)(x @ params['w1'].astype(ct)) * (x @ params['w3'].astype(ct))
    h = constrain(h, 'batch', None, 'model')    # col-parallel hidden
    return h @ params['w2'].astype(ct)


# ----------------------------------------------------------------- embeddings
def init_embedding(cfg: ModelConfig, rng) -> dict:
    return {'table': dense_init(rng, (cfg.padded_vocab, cfg.d_model),
                                pdtype(cfg), scale=1.0)}


def embed(params, tokens, cfg: ModelConfig):
    return params['table'].astype(cdtype(cfg))[tokens]


def unembed(params, x, cfg: ModelConfig):
    """Logits against the (padded) vocab; pad slots masked to -inf."""
    logits = x @ params['table'].astype(cdtype(cfg)).T
    if cfg.padded_vocab != cfg.vocab_size:
        mask = jnp.arange(cfg.padded_vocab) < cfg.vocab_size
        logits = jnp.where(mask, logits, jnp.finfo(logits.dtype).min)
    return logits


def cross_entropy(logits: jax.Array, labels: jax.Array,
                  mask: jax.Array | None = None,
                  z_loss: float = 0.0) -> jax.Array:
    """Token-mean CE in f32 with optional z-loss (logit-norm stabilizer)."""
    logits = logits.astype(jnp.float32)
    lse = jax.nn.logsumexp(logits, axis=-1)
    ll = jnp.take_along_axis(logits, labels[..., None], axis=-1)[..., 0]
    loss = lse - ll
    if z_loss:
        loss = loss + z_loss * lse ** 2
    if mask is not None:
        return (loss * mask).sum() / jnp.clip(mask.sum(), 1, None)
    return loss.mean()
