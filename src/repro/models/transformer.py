"""Unified decoder (and encoder-decoder) transformer over all families.

Layers are grouped into *blocks* of ``cfg.block_period`` slots (the lcm of the
attention/MoE interleave cycles), block params are stacked with a leading
``n_blocks`` axis, and depth is a single ``lax.scan`` — HLO size is O(1) in
depth, which keeps 126-layer pod-scale compiles tractable and is how MaxText-
class trainers are built.

Public surface (``build_model``):
  init(rng)                                  → params
  forward(params, batch)                     → logits (+aux)
  train_loss(params, batch, weights)         → scalar  (bilevel inner loss)
  init_cache(batch, max_len)                 → decode cache
  decode_step(params, inputs, cache)         → logits, cache
  encode(params, enc_inputs)                 → encoder states  (enc-dec only)
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, Callable

import jax
import jax.numpy as jnp

from repro.models import attention as attn
from repro.models import moe as moe_lib
from repro.models import rwkv as rwkv_lib
from repro.models import ssm as ssm_lib
from repro.models.config import ModelConfig
from repro.models.layers import (cdtype, cross_entropy, dense_init, embed,
                                 init_embedding, init_mlp, init_rmsnorm, mlp,
                                 pdtype, rmsnorm, unembed)


# ---------------------------------------------------------------------- init
def _init_slot(cfg: ModelConfig, rng, mixer: str, ffn: str,
               with_cross: bool) -> dict:
    ks = jax.random.split(rng, 6)
    p: dict[str, Any] = {'ln1': init_rmsnorm(cfg), 'ln2': init_rmsnorm(cfg)}
    if mixer == 'attn':
        p['mixer'] = attn.init_attention(cfg, ks[0])
    elif mixer == 'mamba':
        p['mixer'] = ssm_lib.init_mamba(cfg, ks[0])
    else:                                   # rwkv: ln2+ffn feed channel-mix
        p['mixer'] = rwkv_lib.init_rwkv_block(cfg, ks[0])
    if mixer != 'rwkv':
        if ffn == 'moe':
            p['ffn'] = moe_lib.init_moe(cfg, ks[1])
        else:
            p['ffn'] = init_mlp(cfg, ks[1])
    if with_cross:
        p['ln_cross'] = init_rmsnorm(cfg)
        p['cross'] = attn.init_attention(cfg, ks[2], cross=True)
    return p


def _init_blocks(cfg: ModelConfig, rng, n_blocks: int, with_cross: bool) -> dict:
    kinds = cfg.layer_kinds()
    slot_keys = jax.random.split(rng, len(kinds))

    def init_block(block_rng):
        sks = jax.random.split(block_rng, len(kinds))
        return {f'slot{i}': _init_slot(cfg, sks[i], m, f, with_cross)
                for i, (m, f) in enumerate(kinds)}

    block_rngs = jax.random.split(rng, n_blocks)
    if cfg.scan_layers:
        return jax.vmap(init_block)(block_rngs)     # leading n_blocks axis
    return [init_block(r) for r in block_rngs]


def init_params(cfg: ModelConfig, rng) -> dict:
    k_emb, k_blocks, k_enc, k_unemb = jax.random.split(rng, 4)
    params: dict[str, Any] = {}
    if cfg.embed_inputs:
        params['embed'] = init_embedding(cfg, k_emb)
        if not cfg.tie_embeddings:
            params['unembed'] = init_embedding(cfg, k_unemb)
    else:
        # modality frontend is a stub: inputs arrive as (B, S, d) embeddings
        params['unembed'] = init_embedding(cfg, k_unemb)
    params['blocks'] = _init_blocks(cfg, k_blocks, cfg.n_blocks, cfg.is_encdec)
    params['final_norm'] = init_rmsnorm(cfg)
    if cfg.is_encdec:
        enc_cfg = dataclasses.replace(cfg, ssm_kind=None, n_experts=0,
                                      moe_every=1, attn_every=1)
        params['enc_blocks'] = _init_blocks(enc_cfg, k_enc,
                                            cfg.n_enc_layers, False)
        params['enc_final_norm'] = init_rmsnorm(cfg)
        params['embed'] = init_embedding(cfg, k_emb)  # decoder text embeddings
    return params


# ------------------------------------------------------------------- forward
def _apply_slot(cfg: ModelConfig, sp: dict, x, positions, mixer: str,
                ffn: str, causal: bool, enc_out=None):
    """One layer slot (pre-norm residual). Returns (x, aux)."""
    aux = jnp.float32(0.0)
    if mixer == 'rwkv':
        B = x.shape[0]
        zeros_prev = jnp.zeros((B, cfg.d_model), x.dtype)
        state = rwkv_lib.init_rwkv_state(cfg, B)
        h, _, _ = rwkv_lib.rwkv_time_mix(sp['mixer'], rmsnorm(
            sp['ln1'], x, cfg.norm_eps), zeros_prev, state['wkv'], cfg)
        x = x + h
        h, _ = rwkv_lib.rwkv_channel_mix(sp['mixer'], rmsnorm(
            sp['ln2'], x, cfg.norm_eps), zeros_prev, cfg)
        return x + h, aux

    h = rmsnorm(sp['ln1'], x, cfg.norm_eps, cfg.use_pallas)
    if mixer == 'attn':
        h = attn.multihead_attention(sp['mixer'], h, cfg,
                                     positions=positions, causal=causal)
    else:
        h = ssm_lib.mamba_scan(sp['mixer'], h, cfg)
    x = x + h
    if enc_out is not None:
        h = rmsnorm(sp['ln_cross'], x, cfg.norm_eps)
        h = attn.cross_attention(
            sp['cross'],
            h,
            *attn.cross_attention_cache(sp['cross'], enc_out, cfg),
            cfg)
        x = x + h
    h = rmsnorm(sp['ln2'], x, cfg.norm_eps, cfg.use_pallas)
    if ffn == 'moe':
        h, aux = moe_lib.moe_ffn(sp['ffn'], h, cfg)
    else:
        h = mlp(sp['ffn'], h, cfg)
    return x + h, aux


def _remat_wrap(cfg: ModelConfig, fn):
    if cfg.remat == 'none':
        return fn
    policy = (jax.checkpoint_policies.dots_with_no_batch_dims_saveable
              if cfg.remat == 'dots' else
              jax.checkpoint_policies.nothing_saveable)
    return jax.checkpoint(fn, policy=policy)


def _run_blocks(cfg: ModelConfig, blocks, x, positions, kinds, causal,
                enc_out=None):
    from repro.distributed.ctx import constrain

    seq_ax = 'model' if cfg.seq_shard else None

    def block_fn(x, block_params):
        aux = jnp.float32(0.0)
        # pin batch → (pod, data); optionally Megatron-SP sequence sharding
        # of the residual stream (checkpointed carries shrink by the model-
        # axis width; attention/FFN re-gather at their TP boundaries)
        x = constrain(x, 'batch', seq_ax, None)
        for i, (mixer, ffn) in enumerate(kinds):
            x, a = _apply_slot(cfg, block_params[f'slot{i}'], x, positions,
                               mixer, ffn, causal, enc_out)
            x = constrain(x, 'batch', seq_ax, None)
            aux = aux + a
        return x, aux

    if cfg.scan_layers:
        body = _remat_wrap(cfg, block_fn)
        x, auxs = jax.lax.scan(lambda c, bp: body(c, bp), x, blocks)
        return x, auxs.sum()
    aux = jnp.float32(0.0)
    for bp in blocks:
        x, a = block_fn(x, bp)
        aux = aux + a
    return x, aux


def forward(cfg: ModelConfig, params, inputs, positions=None,
            enc_inputs=None):
    """inputs: (B,S) int tokens if cfg.embed_inputs else (B,S,d) embeddings.
    Returns (logits (B,S,V_padded), aux_loss)."""
    ct = cdtype(cfg)
    if cfg.is_encdec or cfg.embed_inputs:
        # enc-dec: decoder side consumes text tokens even when the encoder
        # frontend is an embedding stub (embed_inputs=False).
        x = embed(params['embed'], inputs, cfg)
    else:
        x = inputs.astype(ct)
    B, S = x.shape[0], x.shape[1]
    if positions is None:
        positions = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32), (B, S))
        if cfg.mrope:
            positions = jnp.broadcast_to(positions[:, None, :], (B, 3, S))

    enc_out = None
    if cfg.is_encdec:
        assert enc_inputs is not None, 'enc-dec needs encoder inputs'
        enc_out = encode(cfg, params, enc_inputs)

    from repro.distributed.ctx import constrain
    x = constrain(x, 'batch', None, None)
    x, aux = _run_blocks(cfg, params['blocks'], x, positions,
                         cfg.layer_kinds(), causal=True, enc_out=enc_out)
    x = rmsnorm(params['final_norm'], x, cfg.norm_eps)
    table = params['embed'] if cfg.tie_embeddings else params['unembed']
    logits = unembed(table, x, cfg)
    return constrain(logits, 'batch', None, 'model'), aux


def encode(cfg: ModelConfig, params, enc_inputs):
    """Encoder stack over precomputed frame/patch embeddings (B, T, d)."""
    x = enc_inputs.astype(cdtype(cfg))
    B, T = x.shape[0], x.shape[1]
    positions = jnp.broadcast_to(jnp.arange(T, dtype=jnp.int32), (B, T))
    # encoder blocks are built period-1 dense-attention (see init_params)
    x, _ = _run_blocks(cfg, params['enc_blocks'], x, positions,
                       [('attn', 'dense')], causal=False)
    return rmsnorm(params['enc_final_norm'], x, cfg.norm_eps)


# ------------------------------------------------------------------- losses
def train_loss(cfg: ModelConfig, params, batch, example_weights=None):
    """Next-token CE (the bilevel *inner* objective f).

    ``example_weights``: optional (B,) per-example loss weights — the outer
    parameters of the data-reweighting task (§5.4) enter here.
    """
    logits, aux = forward(cfg, params, batch['inputs'],
                          positions=batch.get('positions'),
                          enc_inputs=batch.get('enc_inputs'))
    labels = batch['labels']
    mask = batch.get('mask')
    # Sharded-vocab-safe CE: every reduction below is over the (possibly
    # 'model'-sharded) V axis, which GSPMD lowers to local-reduce + tiny
    # all-reduce; the label pick is a fused select+max (no one-hot buffer,
    # no take_along_axis cross-shard gather). Keeping logits bf16 with f32
    # reduction accumulators avoids a (B,S,V) f32 copy.
    V = logits.shape[-1]
    iota = jax.lax.broadcasted_iota(jnp.int32, logits.shape, 2)
    is_label = iota == labels[..., None]
    m = jnp.max(logits, axis=-1).astype(jnp.float32)         # (B, S)
    sumexp = jnp.sum(jnp.exp(logits.astype(jnp.float32)
                             - m[..., None]), axis=-1)
    lse = m + jnp.log(sumexp)
    ll = jnp.max(jnp.where(is_label, logits,
                           jnp.finfo(logits.dtype).min),
                 axis=-1).astype(jnp.float32)
    tok_loss = lse - ll                                     # (B, S)
    if mask is None:
        mask = jnp.ones_like(tok_loss)
    if example_weights is not None:
        mask = mask * example_weights[:, None]
    loss = (tok_loss * mask).sum() / jnp.clip(mask.sum(), 1e-6, None)
    return loss + aux


# -------------------------------------------------------------------- decode
def init_cache(cfg: ModelConfig, batch: int, max_len: int,
               dtype=None) -> dict:
    """Stacked per-block decode cache (leading n_blocks axis per slot)."""
    dtype = dtype or cdtype(cfg)
    nb = cfg.n_blocks
    cache: dict[str, Any] = {'pos': jnp.zeros((), jnp.int32)}
    slots = {}
    for i, (mixer, _) in enumerate(cfg.layer_kinds()):
        if mixer == 'attn':
            shape = (nb, batch, max_len, cfg.n_kv_heads, cfg.head_dim)
            slots[f'slot{i}'] = {'k': jnp.zeros(shape, dtype),
                                 'v': jnp.zeros(shape, dtype)}
        elif mixer == 'mamba':
            st = ssm_lib.init_mamba_state(cfg, batch)
            slots[f'slot{i}'] = jax.tree.map(
                lambda a: jnp.broadcast_to(a, (nb,) + a.shape), st)
        else:
            st = rwkv_lib.init_rwkv_state(cfg, batch)
            slots[f'slot{i}'] = jax.tree.map(
                lambda a: jnp.broadcast_to(a, (nb,) + a.shape), st)
    cache['slots'] = slots
    if cfg.is_encdec:
        shape = (nb, batch, cfg.cross_len, cfg.n_kv_heads, cfg.head_dim)
        cache['cross'] = {'k': jnp.zeros(shape, dtype),
                          'v': jnp.zeros(shape, dtype)}
    return cache


def fill_cross_cache(cfg: ModelConfig, params, cache, enc_out):
    """Precompute encoder-side K/V for every decoder layer (enc-dec decode)."""
    def per_block(bp):
        ks, vs = [], []
        for i in range(len(cfg.layer_kinds())):
            k, v = attn.cross_attention_cache(bp[f'slot{i}']['cross'],
                                              enc_out, cfg)
            ks.append(k)
            vs.append(v)
        return jnp.stack(ks), jnp.stack(vs)   # (n_slots, B, T, KV, hd)

    if cfg.scan_layers:
        k, v = jax.vmap(per_block)(params['blocks'])    # (nb, n_slots, ...)
        k, v = k[:, 0], v[:, 0]  # period-1 enc-dec: one slot
    else:
        k, v = per_block(params['blocks'][0])
        k, v = k[None, 0], v[None, 0]
    cache = dict(cache)
    cache['cross'] = {'k': k.astype(cache['cross']['k'].dtype),
                      'v': v.astype(cache['cross']['v'].dtype)}
    return cache


def decode_step(cfg: ModelConfig, params, inputs, cache):
    """One token for every sequence. inputs: (B,1) tokens or (B,1,d) embeds.
    Returns (logits (B,1,V), new cache)."""
    ct = cdtype(cfg)
    pos = cache['pos']
    if cfg.embed_inputs or cfg.is_encdec:
        x = embed(params['embed'], inputs, cfg)
    else:
        x = inputs.astype(ct)
    B = x.shape[0]
    kinds = cfg.layer_kinds()

    def block_fn(x, scanned):
        bp, slot_cache, cross_kv = scanned
        new_cache = {}
        for i, (mixer, ffn) in enumerate(kinds):
            sp = bp[f'slot{i}']
            sc = slot_cache[f'slot{i}']
            h = rmsnorm(sp['ln1'], x, cfg.norm_eps)
            if mixer == 'attn':
                h, nk, nv = attn.decode_attention(sp['mixer'], h, sc['k'],
                                                  sc['v'], pos, cfg)
                new_cache[f'slot{i}'] = {'k': nk, 'v': nv}
                x = x + h
            elif mixer == 'mamba':
                h, st = ssm_lib.mamba_decode(sp['mixer'], h, sc, cfg)
                new_cache[f'slot{i}'] = st
                x = x + h
            else:   # rwkv: S=1 scan reuses the train path
                h, tm_prev, wkv = rwkv_lib.rwkv_time_mix(
                    sp['mixer'], h, sc['tm_prev'].astype(h.dtype),
                    sc['wkv'], cfg)
                x = x + h
                h2 = rmsnorm(sp['ln2'], x, cfg.norm_eps)
                h2, cm_prev = rwkv_lib.rwkv_channel_mix(
                    sp['mixer'], h2, sc['cm_prev'].astype(h2.dtype), cfg)
                x = x + h2
                new_cache[f'slot{i}'] = {
                    'tm_prev': tm_prev.astype(jnp.float32),
                    'cm_prev': cm_prev.astype(jnp.float32), 'wkv': wkv}
                continue
            if cross_kv is not None:
                h = rmsnorm(sp['ln_cross'], x, cfg.norm_eps)
                h = attn.cross_attention(sp['cross'], h, cross_kv[0],
                                         cross_kv[1], cfg)
                x = x + h
            h = rmsnorm(sp['ln2'], x, cfg.norm_eps)
            if ffn == 'moe':
                h, _ = moe_lib.moe_ffn(sp['ffn'], h, cfg)
            else:
                h = mlp(sp['ffn'], h, cfg)
            x = x + h
        return x, new_cache

    cross = cache.get('cross')
    if cfg.scan_layers:
        xs = (params['blocks'], cache['slots'],
              (cross['k'], cross['v']) if cross else None)
        x, new_slots = jax.lax.scan(
            lambda c, s: block_fn(c, s), x, xs)
    else:
        new_slots = []
        for b, bp in enumerate(params['blocks']):
            sc = jax.tree.map(lambda a: a[b], cache['slots'])
            ck = jax.tree.map(lambda a: a[b], cross) if cross else None
            x, ns = block_fn(x, (bp, sc, (ck['k'], ck['v']) if ck else None))
            new_slots.append(ns)
        new_slots = jax.tree.map(lambda *a: jnp.stack(a), *new_slots)

    x = rmsnorm(params['final_norm'], x, cfg.norm_eps)
    table = params['embed'] if (cfg.tie_embeddings or cfg.is_encdec) \
        else params['unembed']
    logits = unembed(table, x, cfg)
    new_cache = dict(cache)
    new_cache['slots'] = new_slots
    new_cache['pos'] = pos + 1
    return logits, new_cache


# ----------------------------------------------------------------- factory
@dataclasses.dataclass(frozen=True)
class Model:
    cfg: ModelConfig
    init: Callable
    forward: Callable
    train_loss: Callable
    init_cache: Callable
    decode_step: Callable
    encode: Callable | None = None


def build_model(cfg: ModelConfig) -> Model:
    return Model(
        cfg=cfg,
        init=functools.partial(init_params, cfg),
        forward=functools.partial(forward, cfg),
        train_loss=functools.partial(train_loss, cfg),
        init_cache=functools.partial(init_cache, cfg),
        decode_step=functools.partial(decode_step, cfg),
        encode=functools.partial(encode, cfg) if cfg.is_encdec else None,
    )
