from repro.optim.optimizers import (Optimizer, adam, adamw, adafactor, momentum, sgd,
                                    clip_by_global_norm, chain, scale_by_schedule,
                                    cosine_schedule, warmup_cosine_schedule)

__all__ = ['Optimizer', 'sgd', 'momentum', 'adam', 'adamw', 'adafactor',
           'clip_by_global_norm', 'chain', 'scale_by_schedule',
           'cosine_schedule', 'warmup_cosine_schedule']
