"""Gradient-transform optimizers, built from scratch (no optax in-container).

Same composable design as optax: an ``Optimizer`` is an (init, update) pair
over pytrees; ``chain`` composes transforms. All state is a pytree so it
shards, checkpoints, and donates like parameters.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, NamedTuple, Sequence

import jax
import jax.numpy as jnp

PyTree = Any


@dataclasses.dataclass(frozen=True)
class Optimizer:
    init: Callable[[PyTree], PyTree]
    update: Callable[[PyTree, PyTree, PyTree, jax.Array], tuple[PyTree, PyTree]]
    # update(grads, state, params, step) -> (updates, new_state); updates are
    # *deltas* to be added to params.

    def apply(self, grads: PyTree, state: PyTree, params: PyTree,
              step: jax.Array) -> tuple[PyTree, PyTree]:
        updates, state = self.update(grads, state, params, step)
        params = jax.tree.map(lambda p, u: (p + u).astype(p.dtype), params, updates)
        return params, state


def sgd(lr: float | Callable[[jax.Array], jax.Array]) -> Optimizer:
    def init(params):
        return ()

    def update(grads, state, params, step):
        lr_t = lr(step) if callable(lr) else lr
        return jax.tree.map(lambda g: -lr_t * g, grads), state

    return Optimizer(init, update)


def momentum(lr, beta: float = 0.9, nesterov: bool = False) -> Optimizer:
    def init(params):
        return jax.tree.map(jnp.zeros_like, params)

    def update(grads, m, params, step):
        lr_t = lr(step) if callable(lr) else lr
        m = jax.tree.map(lambda mi, g: beta * mi + g, m, grads)
        if nesterov:
            upd = jax.tree.map(lambda mi, g: -lr_t * (beta * mi + g), m, grads)
        else:
            upd = jax.tree.map(lambda mi: -lr_t * mi, m)
        return upd, m

    return Optimizer(init, update)


class AdamState(NamedTuple):
    mu: PyTree
    nu: PyTree


def adam(lr, b1: float = 0.9, b2: float = 0.999, eps: float = 1e-8,
         mu_dtype=jnp.float32) -> Optimizer:
    def init(params):
        return AdamState(
            mu=jax.tree.map(lambda p: jnp.zeros(p.shape, mu_dtype), params),
            nu=jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params))

    def update(grads, state, params, step):
        lr_t = lr(step) if callable(lr) else lr
        count = step.astype(jnp.float32) + 1.0
        mu = jax.tree.map(lambda m, g: (b1 * m + (1 - b1) * g).astype(mu_dtype),
                          state.mu, grads)
        nu = jax.tree.map(lambda v, g: b2 * v + (1 - b2) * jnp.square(
            g.astype(jnp.float32)), state.nu, grads)
        bc1 = 1 - b1 ** count
        bc2 = 1 - b2 ** count
        upd = jax.tree.map(
            lambda m, v: -lr_t * (m.astype(jnp.float32) / bc1)
            / (jnp.sqrt(v / bc2) + eps), mu, nu)
        return upd, AdamState(mu, nu)

    return Optimizer(init, update)


def adamw(lr, b1: float = 0.9, b2: float = 0.999, eps: float = 1e-8,
          weight_decay: float = 0.0, mu_dtype=jnp.float32) -> Optimizer:
    base = adam(lr, b1, b2, eps, mu_dtype)

    def update(grads, state, params, step):
        lr_t = lr(step) if callable(lr) else lr
        upd, state = base.update(grads, state, params, step)
        upd = jax.tree.map(
            lambda u, p: u - lr_t * weight_decay * p.astype(jnp.float32),
            upd, params)
        return upd, state

    return Optimizer(base.init, update)


class AdafactorState(NamedTuple):
    vr: PyTree    # factored second moment: row accumulator
    vc: PyTree    # col accumulator (scalar-shaped for rank<2 leaves)


def adafactor(lr, decay: float = 0.8, eps: float = 1e-30,
              clip_threshold: float = 1.0) -> Optimizer:
    """Adafactor (Shazeer & Stern) without momentum: the TPU-megamodel
    optimizer (T5/PaLM) — O(rows+cols) second-moment state instead of O(n),
    which is what lets a 400B-class dry-run fit one pod's HBM (DESIGN.md §4).
    """
    def init(params):
        def rows(p):
            if p.ndim < 2:
                return jnp.zeros(p.shape, jnp.float32)
            return jnp.zeros(p.shape[:-1], jnp.float32)

        def cols(p):
            if p.ndim < 2:
                return jnp.zeros((), jnp.float32)
            return jnp.zeros(p.shape[:-2] + p.shape[-1:], jnp.float32)

        return AdafactorState(vr=jax.tree.map(rows, params),
                              vc=jax.tree.map(cols, params))

    def update(grads, state, params, step):
        lr_t = lr(step) if callable(lr) else lr
        t = step.astype(jnp.float32) + 1.0
        beta = 1.0 - t ** -decay

        def upd(g, vr, vc):
            g = g.astype(jnp.float32)
            g2 = g * g + eps
            if g.ndim < 2:
                vr_new = beta * vr + (1 - beta) * g2
                u = g * jax.lax.rsqrt(vr_new + eps)
                return u, vr_new, vc
            vr_new = beta * vr + (1 - beta) * g2.mean(-1)
            vc_new = beta * vc + (1 - beta) * g2.mean(-2)
            r = vr_new / jnp.clip(vr_new.mean(-1, keepdims=True), eps)
            u = g * jax.lax.rsqrt(r[..., None] + eps) \
                * jax.lax.rsqrt(vc_new[..., None, :] + eps) \
                * jnp.sqrt(jnp.clip(vc_new.mean(-1, keepdims=True),
                                    eps))[..., None]
            return u, vr_new, vc_new

        flat_g, treedef = jax.tree.flatten(grads)
        flat_vr = jax.tree.leaves(state.vr)
        flat_vc = jax.tree.leaves(state.vc)
        outs = [upd(g, vr, vc) for g, vr, vc in zip(flat_g, flat_vr, flat_vc)]
        upds = treedef.unflatten([o[0] for o in outs])
        vr = treedef.unflatten([o[1] for o in outs])
        vc = treedef.unflatten([o[2] for o in outs])
        # update clipping (RMS ≤ threshold), then scale by lr
        def clip_scale(u):
            rms = jnp.sqrt(jnp.mean(u * u) + eps)
            return -lr_t * u / jnp.clip(rms / clip_threshold, 1.0)
        upds = jax.tree.map(clip_scale, upds)
        return upds, AdafactorState(vr, vc)

    return Optimizer(init, update)


def clip_by_global_norm(max_norm: float) -> Optimizer:
    """Gradient-transform stage: rescale grads to global-norm ≤ max_norm."""
    def init(params):
        return ()

    def update(grads, state, params, step):
        # sum-of-squares via full reduce (no vdot: flatten of a sharded array
        # all-gathers it — see core.tree_util.tree_vdot)
        sq = sum(jnp.sum(jnp.square(g.astype(jnp.float32)))
                 for g in jax.tree.leaves(grads))
        norm = jnp.sqrt(sq)
        scale = jnp.minimum(1.0, max_norm / (norm + 1e-12))
        return jax.tree.map(lambda g: g * scale, grads), state

    return Optimizer(init, update)


def chain(*stages: Optimizer) -> Optimizer:
    """Compose gradient transforms left-to-right; the last stage should map
    grads → parameter deltas (e.g. ``clip_by_global_norm() | adamw``)."""
    def init(params):
        return tuple(s.init(params) for s in stages)

    def update(grads, states, params, step):
        new_states = []
        for s, st in zip(stages, states):
            grads, st = s.update(grads, st, params, step)
            new_states.append(st)
        return grads, tuple(new_states)

    return Optimizer(init, update)


def scale_by_schedule(base: Optimizer, schedule: Callable) -> Optimizer:
    def update(grads, state, params, step):
        upd, state = base.update(grads, state, params, step)
        s = schedule(step)
        return jax.tree.map(lambda u: u * s, upd), state

    return Optimizer(base.init, update)


def cosine_schedule(base_lr: float, total_steps: int, min_ratio: float = 0.1):
    def sched(step):
        frac = jnp.clip(step.astype(jnp.float32) / total_steps, 0.0, 1.0)
        return base_lr * (min_ratio + (1 - min_ratio) * 0.5 *
                          (1 + jnp.cos(jnp.pi * frac)))
    return sched


def warmup_cosine_schedule(base_lr: float, warmup_steps: int, total_steps: int,
                           min_ratio: float = 0.1):
    cos = cosine_schedule(base_lr, max(total_steps - warmup_steps, 1), min_ratio)

    def sched(step):
        step = step.astype(jnp.float32)
        warm = base_lr * step / max(warmup_steps, 1)
        return jnp.where(step < warmup_steps, warm, cos(step - warmup_steps))
    return sched
