from repro.distributed.sharding import (batch_axes, batch_spec, cache_specs,
                                        named_shardings, param_specs)
from repro.distributed.compression import (ErrorFeedbackInt8, compressed_psum)

__all__ = ['batch_axes', 'batch_spec', 'cache_specs', 'named_shardings',
           'param_specs', 'ErrorFeedbackInt8', 'compressed_psum']
