from repro.distributed.sharding import (batch_axes, batch_spec, cache_specs,
                                        named_shardings, param_specs)
from repro.distributed.compression import (ErrorFeedbackInt8,
                                           compressed_all_reduce,
                                           compressed_psum)
from repro.distributed.ctx import shard_map

__all__ = ['batch_axes', 'batch_spec', 'cache_specs', 'named_shardings',
           'param_specs', 'ErrorFeedbackInt8', 'compressed_all_reduce',
           'compressed_psum', 'shard_map']
