from repro.distributed.sharding import (batch_axes, batch_spec, cache_specs,
                                        local_shape, named_shardings,
                                        param_specs, replication_factor,
                                        sanitize_spec, spec_shard_count)
from repro.distributed.compression import (ErrorFeedbackInt8,
                                           compressed_all_reduce,
                                           compressed_psum)
from repro.distributed.ctx import shard_map, shard_map_unchecked

__all__ = ['batch_axes', 'batch_spec', 'cache_specs', 'local_shape',
           'named_shardings', 'param_specs', 'replication_factor',
           'sanitize_spec', 'spec_shard_count', 'ErrorFeedbackInt8',
           'compressed_all_reduce', 'compressed_psum', 'shard_map',
           'shard_map_unchecked']
