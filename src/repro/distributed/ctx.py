"""Activation-sharding context: explicit with_sharding_constraint hints.

GSPMD's sharding propagation is free to replicate the batch axis of
activations when FSDP-sharded weights pull the contraction dims (measured:
217 GB/chip of temps on yi-9b/train before constraints, 13× over budget —
see EXPERIMENTS.md §Dry-run). Production JAX trainers pin activation layouts
explicitly; model code here calls ``constrain(x, 'batch', None, 'model')``
with *logical* entries that resolve against the ambient mesh:

  'batch' → the (pod, data) axes     'model' → the model axis
  None    → unsharded

Entries whose dim does not divide the mesh axis are dropped automatically,
so one call site is valid for every (arch × mesh) combination. Outside an
``activation_mesh`` context (CPU tests, single-host examples) ``constrain``
is the identity.
"""
from __future__ import annotations

import contextlib

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

# jax.shard_map landed in 0.5.x; on 0.4.x it lives in jax.experimental.
# Every call site in this repo goes through this name so the version split
# stays in one place.
if hasattr(jax, 'shard_map'):
    shard_map = jax.shard_map
else:
    from jax.experimental.shard_map import shard_map  # noqa: F401  (jax<0.5)


def _rep_check_kwarg() -> str | None:
    """The kwarg that disables shard_map's output-replication checker —
    renamed check_rep → check_vma across jax versions; probed once here so
    call sites stay version-agnostic."""
    import inspect
    try:
        params = inspect.signature(shard_map).parameters
    except (TypeError, ValueError):          # pragma: no cover
        return None
    for name in ('check_rep', 'check_vma'):
        if name in params:
            return name
    return None


_REP_KWARG = _rep_check_kwarg()


def shard_map_unchecked(f, mesh: Mesh, in_specs, out_specs):
    """``shard_map`` with the static replication checker off.

    Needed whenever an out_spec *claims* replication the checker cannot
    prove — e.g. un-fusing a flat buffer back into leaves that are
    replicated along some mesh axes (the per-device segments really are
    identical there, but only by a value-level argument: they were computed
    from replicated inputs and psum'd reductions). The collective structure
    is unchanged; only the static proof obligation is waived.
    """
    kw = {_REP_KWARG: False} if _REP_KWARG else {}
    return shard_map(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
                     **kw)


_MESH: Mesh | None = None


@contextlib.contextmanager
def activation_mesh(mesh: Mesh):
    """Activate during tracing (jit/lower) of distributed step functions."""
    global _MESH
    prev, _MESH = _MESH, mesh
    try:
        yield
    finally:
        _MESH = prev


def current_mesh() -> Mesh | None:
    return _MESH


def constrain(x: jax.Array, *entries):
    """with_sharding_constraint with logical entries (see module doc)."""
    mesh = _MESH
    if mesh is None:
        return x
    assert len(entries) == x.ndim, (entries, x.shape)
    resolved = []
    for dim, e in zip(x.shape, entries):
        if e is None:
            resolved.append(None)
            continue
        axes = (tuple(a for a in ('pod', 'data') if a in mesh.axis_names)
                if e == 'batch' else (e,))
        axes = tuple(a for a in axes if a in mesh.axis_names)
        size = 1
        for a in axes:
            size *= mesh.shape[a]
        if size > 1 and dim % size == 0:
            resolved.append(axes if len(axes) > 1 else axes[0])
        else:
            resolved.append(None)
    return jax.lax.with_sharding_constraint(
        x, NamedSharding(mesh, P(*resolved)))
