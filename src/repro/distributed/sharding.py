"""Logical→physical sharding rules (GSPMD PartitionSpecs) for every family.

Policy (DESIGN.md §4):
  * batch            → ('pod', 'data')     [pod present on the 2-pod mesh]
  * vocab (padded)   → 'model'
  * d_ff / d_inner   → 'model'             (Megatron col/row parallel FFN)
  * attention        → flattened q-head dim over 'model' iff H % model == 0
                       (llama3 128, mistral 96, yi 32, phi 32, jamba 32,
                       seamless 16); K/V weights stay replicated when
                       KV % model != 0 (GQA kv=8 vs model=16) — their
                       activations broadcast-expand to q-heads locally.
                       Fallback (qwen2 28H, llama4 40H): row-parallel on
                       d_model for wq, K/V/O replicated — a deliberate,
                       measured baseline inefficiency (see §Perf hillclimb).
  * KV-cache seq     → 'model'             (flash-decoding layout: softmax
                       stats reduce locally + tiny cross-shard all-reduce,
                       and 500k caches fit HBM)
  * FSDP (cfg.fsdp)  → params/opt-state additionally sharded over 'data' on
                       the largest divisible non-'model' dim (ZeRO-3:
                       gather-on-use inside the layer scan, reduce-scatter
                       on grads — inserted by GSPMD)

A dim that does not divide its axis is replicated — rules degrade, never
error, on any (arch × mesh) combination.
"""
from __future__ import annotations

from typing import Any

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.models.config import ModelConfig


# --------------------------------------------------------------- mesh helpers
def batch_axes(mesh: Mesh) -> tuple[str, ...]:
    return tuple(a for a in ('pod', 'data') if a in mesh.axis_names)


def batch_spec(mesh: Mesh, extra_dims: int = 0) -> P:
    return P(batch_axes(mesh), *([None] * extra_dims))


def _axis_size(mesh: Mesh, name: str) -> int:
    return mesh.shape[name] if name in mesh.axis_names else 1


def _div(n: int, mesh: Mesh, axis: str) -> bool:
    return axis in mesh.axis_names and n % _axis_size(mesh, axis) == 0


# --------------------------------------------------------------- param rules
def _attn_specs(cfg: ModelConfig, mesh: Mesh, fsdp: str | None,
                cross: bool = False):
    """Specs for one attention param dict (trailing dims only)."""
    m = _axis_size(mesh, 'model')
    head_ok = cfg.n_heads % m == 0
    kv_ok = cfg.n_kv_heads % m == 0
    f = fsdp
    if head_ok:
        out = {'wq': P(f, 'model'), 'wo': P('model', f),
               'wk': P(f, 'model') if kv_ok else P(f, None),
               'wv': P(f, 'model') if kv_ok else P(f, None)}
        bias = {'bq': P('model'), 'bk': P('model') if kv_ok else P(None),
                'bv': P('model') if kv_ok else P(None)}
    else:
        # fallback: row-parallel QKV on d_model; O replicated (+fsdp)
        out = {'wq': P('model', f), 'wk': P('model', f), 'wv': P('model', f),
               'wo': P(f, None)}
        bias = {'bq': P(None), 'bk': P(None), 'bv': P(None)}
    if cfg.qkv_bias and not cross:
        out.update(bias)
    return out


def _slot_specs(cfg: ModelConfig, mesh: Mesh, mixer: str, ffn: str,
                with_cross: bool, fsdp: str | None):
    f = fsdp
    specs: dict[str, Any] = {'ln1': {'scale': P(None)},
                             'ln2': {'scale': P(None)}}
    if mixer == 'attn':
        specs['mixer'] = _attn_specs(cfg, mesh, f)
    elif mixer == 'mamba':
        di_ok = _div(cfg.d_inner, mesh, 'model')
        dm = 'model' if di_ok else None
        specs['mixer'] = {
            'in_proj': P(f, dm), 'conv_w': P(None, dm), 'conv_b': P(dm),
            'x_proj': P(dm, None), 'dt_proj_w': P(None, dm), 'dt_proj_b': P(dm),
            'A_log': P(dm, None), 'D': P(dm), 'out_proj': P(dm, f)}
    else:  # rwkv
        d_ok = _div(cfg.d_model, mesh, 'model')
        dm = 'model' if d_ok else None
        specs['mixer'] = {
            'mu': P(None, None), 'w_lora_a': P(f, None), 'w_lora_b': P(None, dm),
            'w0': P(dm), 'bonus': P(None, None),
            'wr': P(f, dm), 'wk': P(f, dm), 'wv': P(f, dm), 'wg': P(f, dm),
            'wo': P(dm, f), 'ln_scale': P(None, None),
            'mu_cm': P(None, None), 'ck': P(f, 'model'),
            'cv': P('model', f), 'cr': P(f, dm)}
    if mixer != 'rwkv':
        if ffn == 'moe':
            specs['ffn'] = {'router': P(f, None),
                            'w1': P(None, f, 'model'), 'w3': P(None, f, 'model'),
                            'w2': P(None, 'model', f)}
            if cfg.shared_expert:
                specs['ffn']['shared'] = {'w1': P(f, 'model'),
                                          'w3': P(f, 'model'),
                                          'w2': P('model', f)}
        else:
            specs['ffn'] = {'w1': P(f, 'model'), 'w3': P(f, 'model'),
                            'w2': P('model', f)}
    if with_cross:
        specs['ln_cross'] = {'scale': P(None)}
        specs['cross'] = _attn_specs(cfg, mesh, f, cross=True)
    return specs


def _prepend(spec_tree, n: int = 1):
    """Add leading unsharded dims (the stacked n_blocks axis)."""
    return jax.tree.map(lambda s: P(*([None] * n), *s), spec_tree,
                        is_leaf=lambda x: isinstance(x, P))


def param_specs(cfg: ModelConfig, mesh: Mesh) -> dict:
    """PartitionSpec pytree matching init_params(cfg, ·)'s structure."""
    fsdp = 'data' if (cfg.fsdp and 'data' in mesh.axis_names) else None
    emb = {'table': P('model', None)}   # padded vocab always divides
    specs: dict[str, Any] = {}
    if cfg.embed_inputs or cfg.is_encdec:
        specs['embed'] = emb
    if not (cfg.tie_embeddings and cfg.embed_inputs) or not cfg.embed_inputs:
        specs['unembed'] = emb
    if cfg.tie_embeddings and cfg.embed_inputs:
        specs.pop('unembed', None)

    kinds = cfg.layer_kinds()
    block = {f'slot{i}': _slot_specs(cfg, mesh, m_, f_, cfg.is_encdec, fsdp)
             for i, (m_, f_) in enumerate(kinds)}
    specs['blocks'] = (_prepend(block) if cfg.scan_layers
                       else [block] * cfg.n_blocks)
    specs['final_norm'] = {'scale': P(None)}
    if cfg.is_encdec:
        enc_block = {'slot0': _slot_specs(cfg, mesh, 'attn', 'dense',
                                          False, fsdp)}
        specs['enc_blocks'] = (_prepend(enc_block) if cfg.scan_layers
                               else [enc_block] * cfg.n_enc_layers)
        specs['enc_final_norm'] = {'scale': P(None)}
    return specs


# --------------------------------------------------------------- cache rules
def cache_specs(cfg: ModelConfig, mesh: Mesh) -> dict:
    """Decode-cache specs: KV sequence axis → 'model', batch → (pod, data)."""
    b = batch_axes(mesh)
    slots: dict[str, Any] = {}
    seq_ax = 'model' if 'model' in mesh.axis_names else None
    for i, (mixer, _) in enumerate(cfg.layer_kinds()):
        if mixer == 'attn':
            slots[f'slot{i}'] = {'k': P(None, b, seq_ax, None, None),
                                 'v': P(None, b, seq_ax, None, None)}
        elif mixer == 'mamba':
            di_ax = 'model' if _div(cfg.d_inner, mesh, 'model') else None
            slots[f'slot{i}'] = {'conv': P(None, b, None, di_ax),
                                 'ssm': P(None, b, di_ax, None)}
        else:
            d_ax = 'model' if _div(cfg.d_model, mesh, 'model') else None
            h_ax = 'model' if _div(cfg.d_model // 64, mesh, 'model') else None
            slots[f'slot{i}'] = {'tm_prev': P(None, b, d_ax),
                                 'cm_prev': P(None, b, d_ax),
                                 'wkv': P(None, b, h_ax, None, None)}
    cache = {'pos': P(), 'slots': slots}
    if cfg.is_encdec:
        cache['cross'] = {'k': P(None, b, seq_ax, None, None),
                          'v': P(None, b, seq_ax, None, None)}
    return cache


# --------------------------------------------------- per-leaf shard queries
# (the contraction-backend layer — repro.core.backend.FlatShardedBackend —
# plans its per-device fused buffer from these; they apply the same
# degrade-to-replication policy as the param rules above.)
def sanitize_spec(shape: tuple, spec: P | None, mesh: Mesh) -> P:
    """``spec`` with entries that cannot shard ``shape`` on ``mesh`` dropped.

    An entry is dropped (→ replicated dim) when any of its axes is absent
    from the mesh, the combined axis size is 1, or the dim is not divisible
    by it — the exact policy of ``param_specs``/``ctx.constrain``, applied
    post-hoc so a backend can accept any (spec × mesh × shape) combination.
    The result is padded/truncated to ``len(shape)`` entries.
    """
    entries = list(spec) if spec is not None else []
    entries = entries[:len(shape)] + [None] * (len(shape) - len(entries))
    out = []
    for dim, e in zip(shape, entries):
        if e is None:
            out.append(None)
            continue
        axes = e if isinstance(e, tuple) else (e,)
        size = 1
        for a in axes:
            size *= _axis_size(mesh, a) if a in mesh.axis_names else 0
        out.append(e if size > 1 and dim % size == 0 else None)
    return P(*out)


def spec_shard_count(spec: P, mesh: Mesh) -> int:
    """Number of *distinct* shards a (sanitized) spec produces — the product
    of its mesh-axis sizes."""
    n = 1
    for e in spec:
        if e is None:
            continue
        for a in (e if isinstance(e, tuple) else (e,)):
            n *= _axis_size(mesh, a)
    return n


def replication_factor(spec: P, mesh: Mesh) -> int:
    """How many devices hold each shard: mesh size / distinct shards.

    1 ⇔ fully sharded over every mesh axis; mesh size ⇔ fully replicated.
    This is the overcount weight a cross-device psum over a per-device
    fused buffer must divide out per leaf.
    """
    return mesh.devices.size // spec_shard_count(spec, mesh)


def local_shape(shape: tuple, spec: P, mesh: Mesh) -> tuple:
    """Per-device block shape of a leaf with (sanitized) ``spec``."""
    entries = list(spec) + [None] * (len(shape) - len(spec))
    out = []
    for dim, e in zip(shape, entries):
        size = 1
        if e is not None:
            for a in (e if isinstance(e, tuple) else (e,)):
                size *= _axis_size(mesh, a)
        out.append(dim // size)
    return tuple(out)


# --------------------------------------------------------------- utilities
def named_shardings(mesh: Mesh, spec_tree):
    return jax.tree.map(lambda s: NamedSharding(mesh, s), spec_tree,
                        is_leaf=lambda x: isinstance(x, P))


def mirror_specs(template_tree, spec_tree, state_tree):
    """Give each optimizer-state leaf the spec of the same-shaped param leaf
    (momentum/Adam moments are param-shaped); anything else replicates."""
    by_shape: dict[tuple, P] = {}
    for leaf, spec in zip(jax.tree.leaves(template_tree),
                          jax.tree.leaves(spec_tree,
                                          is_leaf=lambda x: isinstance(x, P))):
        by_shape.setdefault((tuple(leaf.shape)), spec)

    def assign(leaf):
        return by_shape.get(tuple(getattr(leaf, 'shape', ())), P())

    return jax.tree.map(assign, state_tree)
