"""Gradient compression for cross-pod (DCN) reductions.

Int8 block-quantized all-reduce with error feedback: the outer-step
hypergradient (and optionally the inner grads) cross the slow pod-to-pod
links at 1/4 the bytes; the quantization residual is fed back into the next
step (error feedback makes the *accumulated* update unbiased, the standard
convergence-preserving trick from 1-bit SGD / EF-SGD).

Implementation notes: a true int8 wire format is a runtime/transport
property — inside XLA we model it as quantize → psum(int32) → dequantize
with a shared (pmax) scale, which is bit-faithful to what an int8 collective
would compute; the roofline's collective term counts the *int8* bytes for
the compressed path (benchmarks/roofline.py applies the 4× discount to
reductions tagged compressed).
"""
from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp

PyTree = Any
BLOCK = 256


def _quantize(x: jax.Array):
    """Block-wise symmetric int8 quantization. Returns (q int8, scale f32)."""
    flat = x.astype(jnp.float32).ravel()
    pad = (-flat.size) % BLOCK
    flat = jnp.pad(flat, (0, pad))
    blocks = flat.reshape(-1, BLOCK)
    scale = jnp.max(jnp.abs(blocks), axis=1, keepdims=True) / 127.0 + 1e-12
    q = jnp.clip(jnp.round(blocks / scale), -127, 127).astype(jnp.int8)
    return q, scale, pad


def _dequantize(q, scale, pad, shape):
    out = (q.astype(jnp.float32) * scale).ravel()
    if pad:
        out = out[:-pad]
    return out.reshape(shape)


def quantize_roundtrip(x: jax.Array) -> jax.Array:
    q, s, pad = _quantize(x)
    return _dequantize(q, s, pad, x.shape)


def compressed_all_reduce(contribs: jax.Array, mesh,
                          axis_name: str) -> jax.Array:
    """jit-able entry point: int8-wire all-reduce of per-device terms.

    ``contribs``: (n_contributions, *shape) — row i is one device's local
    contribution (the hypergradient cross-pod reduction shape: each pod
    holds its own outer-step gradient term); the leading axis must be a
    multiple of the mesh axis size. Returns the quantized sum of ALL rows,
    replicated on every device: each shard sums its local rows in f32, then
    the int8-wire psum crosses the axis. NOTE: a psum of a *replicated*
    operand multiplies by the axis size — the leading contribution axis is
    what makes this a reduction rather than a scale-by-n."""
    from jax.sharding import PartitionSpec as P

    from repro.distributed.ctx import shard_map
    rest = (None,) * (contribs.ndim - 1)
    return shard_map(
        lambda v: compressed_psum(v.astype(jnp.float32).sum(axis=0),
                                  axis_name),
        mesh=mesh, in_specs=P(axis_name, *rest),
        out_specs=P(*rest))(contribs)


def compressed_psum(x: jax.Array, axis_name: str) -> jax.Array:
    """int8-wire psum (use inside shard_map): shared pmax scale, int32
    accumulate — numerically identical to an int8 ring all-reduce."""
    flat = x.astype(jnp.float32).ravel()
    pad = (-flat.size) % BLOCK
    flat = jnp.pad(flat, (0, pad))
    blocks = flat.reshape(-1, BLOCK)
    local_scale = jnp.max(jnp.abs(blocks), axis=1, keepdims=True) / 127.0 + 1e-12
    scale = jax.lax.pmax(local_scale, axis_name)          # shared scale
    q = jnp.clip(jnp.round(blocks / scale), -127, 127).astype(jnp.int32)
    total = jax.lax.psum(q, axis_name)                    # int accumulation
    out = (total.astype(jnp.float32) * scale).ravel()
    if pad:
        out = out[:-pad]
    return out.reshape(x.shape)


@dataclasses.dataclass(frozen=True)
class ErrorFeedbackInt8:
    """Gradient transform: g ← Q(g + e);  e ← (g + e) − Q(g + e).

    Compose before the optimizer:  chain(ErrorFeedbackInt8().transform(), adamw(...)).
    """

    def init(self, params: PyTree) -> PyTree:
        return jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)

    def update(self, grads: PyTree, residual: PyTree):
        corrected = jax.tree.map(
            lambda g, e: g.astype(jnp.float32) + e, grads, residual)
        quantized = jax.tree.map(quantize_roundtrip, corrected)
        new_residual = jax.tree.map(jnp.subtract, corrected, quantized)
        return quantized, new_residual

    def transform(self):
        from repro.optim.optimizers import Optimizer

        def update(grads, state, params, step):
            q, state = self.update(grads, state)
            return q, state

        return Optimizer(self.init, update)
