"""Fault-tolerant checkpointing: atomic, async, reshard-on-restore.

Checkpoints are written in *logical* (fully-replicated) layout: a flat
{path: array} map + a JSON manifest (step, shapes, dtypes, per-leaf crc32).
Restore device_puts each leaf against the *target* mesh's sharding rules —
i.e. a checkpoint taken on a 2-pod 512-chip mesh restores onto a 1-pod mesh
(or a CPU dev box) untouched. That resharding path is the elastic-scaling /
failover mechanism in DESIGN.md §4.

Write protocol (crash-safe at every point):
  1. serialize into  <dir>/step_<n>.tmp/
  2. fsync files, then atomic os.rename → <dir>/step_<n>/
  3. rewrite <dir>/LATEST (tmp+rename) to point at it
A partially-written step never becomes LATEST; stale .tmp dirs are GC'd.

``CheckpointManager(async_save=True)`` snapshots to host memory synchronously
(jax.device_get) and does the disk I/O on a background thread, bounding the
training-loop stall to the D2H copy (the standard async-checkpoint trick).
"""
from __future__ import annotations

import hashlib
import json
import os
import shutil
import threading
import zlib
from typing import Any

import jax
import numpy as np


def _flatten(tree) -> dict[str, np.ndarray]:
    flat = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        key = '/'.join(str(getattr(p, 'key', getattr(p, 'idx', p)))
                       for p in path)
        flat[key] = np.asarray(jax.device_get(leaf))
    return flat


def params_digest(tree: Any) -> str:
    """Content digest of a parameter pytree (sha256 over path, shape, dtype
    and raw bytes of every leaf, in deterministic path order).

    The checkpoint-identity half of a serving-cache key: two trees digest
    equal iff a checkpoint save/restore round-trip would reproduce one from
    the other, so a cached solver state keyed on the digest is exactly as
    reusable as the checkpoint it was prepared against. Costs one D2H copy
    of the tree (the same copy ``save`` makes) plus a hash pass.
    """
    h = hashlib.sha256()
    flat = _flatten(tree)
    for key in sorted(flat):
        arr = np.ascontiguousarray(flat[key])
        h.update(key.encode())
        h.update(repr((arr.shape, str(arr.dtype))).encode())
        h.update(arr.tobytes())
    return h.hexdigest()[:16]


def save(directory: str, step: int, tree: Any, extra: dict | None = None):
    """Atomic synchronous save. Returns the final step directory."""
    os.makedirs(directory, exist_ok=True)
    final = os.path.join(directory, f'step_{step:010d}')
    tmp = final + '.tmp'
    if os.path.exists(tmp):
        shutil.rmtree(tmp)
    os.makedirs(tmp)

    flat = _flatten(tree)
    manifest = {'step': step, 'extra': extra or {}, 'leaves': {}}
    with open(os.path.join(tmp, 'arrays.npz'), 'wb') as f:
        np.savez(f, **{k: v for k, v in flat.items()})
    for k, v in flat.items():
        manifest['leaves'][k] = {
            'shape': list(v.shape), 'dtype': str(v.dtype),
            'crc32': zlib.crc32(np.ascontiguousarray(v).tobytes())}
    with open(os.path.join(tmp, 'manifest.json'), 'w') as f:
        json.dump(manifest, f)
        f.flush()
        os.fsync(f.fileno())
    os.rename(tmp, final)

    latest_tmp = os.path.join(directory, 'LATEST.tmp')
    with open(latest_tmp, 'w') as f:
        f.write(os.path.basename(final))
        f.flush()
        os.fsync(f.fileno())
    os.rename(latest_tmp, os.path.join(directory, 'LATEST'))
    return final


def latest_step(directory: str) -> int | None:
    latest = os.path.join(directory, 'LATEST')
    if not os.path.exists(latest):
        return None
    with open(latest) as f:
        name = f.read().strip()
    return int(name.split('_')[-1])


def restore(directory: str, template: Any, step: int | None = None,
            shardings: Any = None, verify: bool = True):
    """Restore into ``template``'s structure. ``shardings``: optional pytree
    (same structure) of NamedShardings for the *target* mesh — this is where
    cross-mesh resharding happens. Returns (tree, manifest)."""
    if step is None:
        step = latest_step(directory)
        if step is None:
            raise FileNotFoundError(f'no checkpoint under {directory}')
    d = os.path.join(directory, f'step_{step:010d}')
    with open(os.path.join(d, 'manifest.json')) as f:
        manifest = json.load(f)
    arrays = np.load(os.path.join(d, 'arrays.npz'))

    if verify:
        for k, meta in manifest['leaves'].items():
            crc = zlib.crc32(np.ascontiguousarray(arrays[k]).tobytes())
            if crc != meta['crc32']:
                raise IOError(f'checkpoint corruption at leaf {k!r} '
                              f'(crc {crc} != {meta["crc32"]})')

    paths, treedef = jax.tree_util.tree_flatten_with_path(template)
    shard_leaves = (jax.tree.leaves(shardings) if shardings is not None
                    else [None] * len(paths))
    out = []
    for (path, leaf), shard in zip(paths, shard_leaves):
        key = '/'.join(str(getattr(p, 'key', getattr(p, 'idx', p)))
                       for p in path)
        if key not in arrays:
            raise KeyError(f'checkpoint missing leaf {key!r}')
        arr = arrays[key]
        if tuple(arr.shape) != tuple(leaf.shape):
            raise ValueError(f'shape mismatch at {key!r}: '
                             f'{arr.shape} vs {leaf.shape}')
        arr = arr.astype(leaf.dtype)
        out.append(jax.device_put(arr, shard) if shard is not None
                   else jax.numpy.asarray(arr))
    return treedef.unflatten(out), manifest


class CheckpointManager:
    """Rotating, optionally-async manager with preemption-friendly semantics."""

    def __init__(self, directory: str, keep: int = 3, async_save: bool = True):
        self.directory = directory
        self.keep = keep
        self.async_save = async_save
        self._thread: threading.Thread | None = None
        self._error: Exception | None = None
        os.makedirs(directory, exist_ok=True)
        self._gc_tmp()

    def _gc_tmp(self):
        for name in os.listdir(self.directory):
            if name.endswith('.tmp'):
                p = os.path.join(self.directory, name)
                shutil.rmtree(p, ignore_errors=True)

    def wait(self):
        """Block until any in-flight async save lands (call before exit)."""
        if self._thread is not None:
            self._thread.join()
            self._thread = None
        if self._error is not None:
            err, self._error = self._error, None
            raise err

    def save(self, step: int, tree: Any, extra: dict | None = None):
        self.wait()                           # one in-flight save at a time
        if not self.async_save:
            save(self.directory, step, tree, extra)
            self._rotate()
            return
        # synchronous D2H snapshot, async disk write
        host_tree = jax.tree.map(lambda x: np.asarray(jax.device_get(x)), tree)

        def work():
            try:
                save(self.directory, step, host_tree, extra)
                self._rotate()
            except Exception as e:            # surfaced on next wait()/save()
                self._error = e

        self._thread = threading.Thread(target=work, daemon=True)
        self._thread.start()

    def _rotate(self):
        steps = sorted(int(n.split('_')[-1])
                       for n in os.listdir(self.directory)
                       if n.startswith('step_') and not n.endswith('.tmp'))
        for s in steps[:-self.keep]:
            shutil.rmtree(os.path.join(self.directory, f'step_{s:010d}'),
                          ignore_errors=True)

    def restore_latest(self, template: Any, shardings: Any = None):
        return restore(self.directory, template, shardings=shardings)

    def latest_step(self):
        return latest_step(self.directory)
