from repro.checkpoint.manager import (CheckpointManager, params_digest,
                                      restore, save)

__all__ = ['CheckpointManager', 'params_digest', 'save', 'restore']
