"""Production bilevel LM trainer.

Wires every substrate together: sharded model (pjit over the host mesh or
the production mesh), deterministic domain-mixture data pipeline with
prefetch, AdamW/Adafactor, atomic+async checkpointing with resume, and the
paper's Nyström hypergradient as a first-class outer step — every
``outer_every`` inner steps, per-domain loss weights are updated from a
balanced validation batch (§5.4 at LM scale).

Fault-tolerance drill: kill the process mid-run and relaunch with the same
--ckpt-dir — it resumes from the last durable step (restores across a
*different* device count thanks to reshard-on-restore). See
tests/test_trainer.py for the automated version of that drill.

Usage (CPU smoke):
  PYTHONPATH=src python -m repro.launch.train --arch yi_9b --reduced \
      --steps 50 --outer-every 25 --batch 8 --seq 64
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp

from repro.checkpoint import CheckpointManager
from repro.configs import get_config
from repro.core import SketchPolicy, config_from_cli, implicit_root
from repro.data.loader import Prefetcher, ShardedLoader
from repro.data.synthetic import TokenStream
from repro.distributed.ctx import activation_mesh
from repro.launch.mesh import make_host_mesh, make_production_mesh
from repro.launch.steps import N_DOMAINS, make_optimizer
from repro.models import build_model
from repro.models.transformer import train_loss
from repro.optim import adam


def build_losses(cfg):
    def inner_loss(params, hparams, batch):
        w = jax.nn.softmax(hparams['domain_logits']) * N_DOMAINS
        return train_loss(cfg, params, batch,
                          example_weights=w[batch['domain']])

    def outer_loss(params, hparams, batch):
        return train_loss(cfg, params, batch)

    return inner_loss, outer_loss


def _run_graph(args):
    """``--problem <graph-name>``: a multi-level GRAPHS entry (trilevel
    chains) routed through ``Engine.solve`` — the whole inner-to-outer
    sweep as one jitted program. ``--solver``/``--rho``/
    ``--sketch-refresh-every`` configure every edge uniformly (per-edge
    overrides are a builder-kwarg affair); ``--steps`` counts outer steps."""
    from repro.engine import Engine, EngineConfig, get_graph
    kwargs = {'solver': args.solver}
    if args.rho is not None:
        kwargs['rho'] = args.rho
    if args.sketch_refresh_every is not None:
        kwargs['refresh_every'] = args.sketch_refresh_every
    graph = get_graph(args.problem, **kwargs)
    order = graph.chain_order()
    print(f'[train] graph={args.problem} levels={"<-".join(order)} '
          f'solver={args.solver} n_outer={args.steps}')
    result = Engine().solve(graph, EngineConfig(n_outer=args.steps))
    for i, loss in enumerate(result.losses):
        if i % max(1, args.log_every) == 0 or i == len(result.losses) - 1:
            print(f'[engine] outer {i}: top_loss={loss:.6f}')
    bills = ' '.join(f'{e}={n}' for e, n in result.edge_hvps.items())
    print(f'[train] done: graph={args.problem} hvps={result.hvp_count} '
          f'({bills}) wall_s={result.seconds:.1f}')
    return result


def _run_problem(args):
    """``--problem <name>``: resolve the registry entry and drive it through
    the typed problem API (one entry point; sketch amortization via
    ``--sketch-refresh-every`` comes along for free). An
    :class:`~repro.core.problem.InfluenceProblem` routes to ``influence()``
    instead of ``solve()``; a multi-level graph name (``repro.engine``
    GRAPHS registry) routes to ``Engine.solve`` — ``--steps`` then counts
    training (resp. outer) steps and ``--queries``/``--top-k`` size the
    query block / result."""
    from repro.core.problem import (InfluenceProblem, get_problem, influence,
                                    solve)
    from repro.engine import GRAPHS
    if args.problem in GRAPHS:
        return _run_graph(args)
    hg_cfg = config_from_cli(
        args.solver,
        flags={'k': args.k, 'rho': args.rho,
               'sketch_refresh_every': args.sketch_refresh_every},
        defaults={'k': 8, 'rho': 1e-2})
    problem = get_problem(args.problem)
    if isinstance(problem, InfluenceProblem):
        if args.serve:
            return _serve_problem(problem, hg_cfg, args)
        queries = problem.reference['queries'](args.queries)
        print(f'[train] influence problem={problem.name} '
              f'solver={args.solver} m={args.queries} top_k={args.top_k}')
        result = influence(problem, hg_cfg, queries,
                           top_k=args.top_k, train_steps=args.steps)
        for q in range(result.scores.shape[0]):
            pairs = ' '.join(
                f'{int(i)}:{float(s):+.4f}'
                for s, i in zip(result.scores[q], result.indices[q]))
            print(f'[influence] query {q}: {pairs}')
        print(f'[train] done: problem={problem.name} '
              f'hvps={result.hvp_count} wall_s={result.seconds:.1f}')
        return result
    print(f'[train] problem={problem.name} solver={args.solver} '
          f'n_outer={args.steps}')
    result = solve(problem, hg_cfg, n_outer=args.steps,
                   log_every=args.log_every)
    metrics = ' '.join(f'{k}={v:.4f}' for k, v in result.metrics.items())
    print(f'[train] done: problem={problem.name} '
          f'outer_loss={result.history["outer_loss"][-1]:.4f} '
          f'hvps={result.hvp_count} wall_s={result.seconds:.1f} {metrics}')
    return result


def _serve_problem(problem, hg_cfg, args):
    """``--problem influence --serve``: stand up the serving tier
    (``repro.serve``) instead of a one-shot ``influence()`` call. Trains
    once, calibrates the batcher's block size from a warmup sweep, then
    answers ``--queries`` queries TWICE — a cold pass (first flush builds
    the sketch into the store) and a warm pass (every flush hits the store,
    zero build HVPs) — and prints the per-pass service stats, so the
    amortization the store buys is visible from the CLI."""
    import jax as _jax

    from repro.serve import InfluenceService, SketchStore

    store = SketchStore()
    service = InfluenceService(problem, hg_cfg, store=store,
                               top_k=args.top_k, train_steps=args.steps,
                               max_delay=0.0)
    print(f'[serve] influence problem={problem.name} solver={args.solver} '
          f'queries={args.queries} top_k={args.top_k}')
    rates = service.warmup()
    print(f'[serve] calibrated block_size={service.batcher.block_size} '
          + ' '.join(f'm={m}:{r:.1f}q/s' for m, r in sorted(rates.items())))
    pool = problem.reference['queries'](args.queries)
    for phase in ('cold', 'warm'):
        if phase == 'cold':
            store.clear()                      # forget the warmup's sketch
        service.reset_metrics()                # per-pass latency/HVP stats
        hits0, misses0 = store.hits, store.misses
        tickets = []
        for q in range(args.queries):
            tickets.append(service.submit(
                _jax.tree.map(lambda x: x[q], pool)))
            service.pump()
        service.flush()
        for q, t in enumerate(tickets):
            resp = service.result(t)
            pairs = ' '.join(f'{int(i)}:{float(s):+.4f}'
                             for s, i in zip(resp.scores, resp.indices))
            print(f'[serve:{phase}] query {q} ({resp.latency_s*1e3:.1f}ms '
                  f'm={resp.batched_m} hit={resp.cache_hit}): {pairs}')
        s = service.stats()
        lookups = (store.hits - hits0) + (store.misses - misses0)
        rate = (store.hits - hits0) / lookups if lookups else 0.0
        print(f'[serve:{phase}] p50={s["latency_p50_ms"]:.1f}ms '
              f'p95={s["latency_p95_ms"]:.1f}ms '
              f'hvps={s["build_hvps"] + s["fallback_hvps"]} '
              f'hit_rate={rate:.2f}')
    return service


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument('--arch', default='yi_9b')
    ap.add_argument('--reduced', action='store_true',
                    help='tiny same-family config (CPU smoke / CI)')
    ap.add_argument('--steps', type=int, default=200)
    ap.add_argument('--batch', type=int, default=8)
    ap.add_argument('--seq', type=int, default=128)
    ap.add_argument('--outer-every', type=int, default=50,
                    help='inner steps between Nyström hypergradient updates')
    ap.add_argument('--k', type=int, default=None,
                    help='sketch rank / iterations (default 8)')
    ap.add_argument('--rho', type=float, default=None,
                    help='damping (default 1e-2)')
    ap.add_argument('--sketch-refresh-every', type=int, default=None,
                    help='outer steps between sketch rebuilds (default 1 = '
                         'fresh every outer step; N>1 reuses the sketch for '
                         'N-1 steps, saving k HVPs each)')
    ap.add_argument('--solver', default='nystrom')
    ap.add_argument('--problem', default=None,
                    help='run a registered problem (repro.core PROBLEMS '
                         'registry, e.g. reweighting | distillation | '
                         'logreg_wd | influence) through solve()/influence()'
                         ', or a multi-level graph (repro.engine GRAPHS '
                         'registry: distill_hpo | reweight_maml) through '
                         'Engine.solve, instead of the LM pipeline; --steps '
                         'then counts OUTER (resp. training) steps')
    ap.add_argument('--queries', type=int, default=8,
                    help='influence problems: query-block width m')
    ap.add_argument('--top-k', type=int, default=10,
                    help='influence problems: top-k examples per query')
    ap.add_argument('--serve', action='store_true',
                    help='influence problems: stand up the serving tier '
                         '(sketch store + query batcher, repro.serve) and '
                         'answer --queries queries cold then warm, printing '
                         'latency/cache stats, instead of one influence() '
                         'call')
    ap.add_argument('--ckpt-dir', default=None)
    ap.add_argument('--ckpt-every', type=int, default=100)
    ap.add_argument('--production-mesh', action='store_true')
    ap.add_argument('--log-every', type=int, default=10)
    args = ap.parse_args(argv)

    if args.problem is not None:
        return _run_problem(args)

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    model = build_model(cfg)
    mesh = (make_production_mesh() if args.production_mesh
            else make_host_mesh())
    print(f'[train] arch={cfg.name} params~{cfg.param_count()/1e6:.1f}M '
          f'mesh={dict(mesh.shape)} devices={len(jax.devices())}')

    inner_loss, outer_loss = build_losses(cfg)
    optimizer = make_optimizer(cfg)
    # registry-driven flag forwarding: explicitly-passed flags the solver
    # does not consume are rejected loudly by build(), never silently dropped
    hg_cfg = config_from_cli(
        args.solver,
        flags={'k': args.k, 'rho': args.rho,
               'sketch_refresh_every': args.sketch_refresh_every},
        defaults={'k': 8, 'rho': 1e-2},
        column_chunk=4)

    rng = jax.random.PRNGKey(0)
    params = model.init(rng)
    opt_state = optimizer.init(params)
    hparams = {'domain_logits': jnp.zeros((N_DOMAINS,), jnp.float32)}
    outer_opt = adam(1e-2)
    outer_state = outer_opt.init(hparams)
    step = jnp.int32(0)

    # ---------------- checkpoint/resume (fault tolerance) ----------------
    ckpt = CheckpointManager(args.ckpt_dir) if args.ckpt_dir else None
    start_step = 0
    if ckpt and ckpt.latest_step() is not None:
        tree = {'params': params, 'opt': opt_state, 'h': hparams,
                'houter': outer_state}
        tree, manifest = ckpt.restore_latest(tree)
        params, opt_state = tree['params'], tree['opt']
        hparams, outer_state = tree['h'], tree['houter']
        start_step = manifest['step']
        print(f'[train] resumed from step {start_step}')
        step = jnp.int32(start_step)

    # ---------------- data pipeline (deterministic, step-indexed) --------
    stream = TokenStream(vocab_size=cfg.vocab_size, seq_len=args.seq)
    loader = Prefetcher(ShardedLoader(
        lambda s: stream.batch(s, args.batch), start_step=start_step), depth=2)

    # ---------------- jitted steps ----------------
    @jax.jit
    def inner_step(params, opt_state, hparams, step, batch):
        loss, grads = jax.value_and_grad(inner_loss)(params, hparams, batch)
        params, opt_state = optimizer.apply(grads, opt_state, params, step)
        return params, opt_state, step + 1, loss

    solver = hg_cfg.build()
    # sketch lifecycle: amortizable solvers (Nyström/exact) carry one sketch
    # across outer steps, rebuilt every sketch_refresh_every of them by the
    # policy's lax.cond inside the jitted step; iterative solvers prepare
    # fresh inside the backward pass (nothing to amortize).
    if getattr(type(solver), 'amortizable', False):
        policy = SketchPolicy(solver=solver, inner_loss=inner_loss,
                              refresh_every=hg_cfg.sketch_refresh_every)
    elif hg_cfg.sketch_refresh_every > 1:
        raise TypeError(
            f'--sketch-refresh-every={hg_cfg.sketch_refresh_every} needs an '
            f'amortizable solver; {type(solver).__name__} prepares a '
            'trace-local state with nothing to reuse across outer steps')
    else:
        policy = None

    @jax.jit
    def outer_step(params, hparams, outer_state, step, inner_b, outer_b, key,
                   sketch_state):
        # the warm-started params are the implicit solution; grad through the
        # implicit_root map assembles Eq. 3 in the custom_vjp backward pass
        solve = implicit_root(lambda phi, b: params, inner_loss, solver)
        if policy is not None:
            sketch_state, _ = policy.refresh(
                sketch_state, params, hparams, inner_b, key)

            def outer_obj(phi):
                theta = solve(phi, inner_b, state=sketch_state.sketch)
                return outer_loss(theta, phi, outer_b)
        else:
            def outer_obj(phi):
                return outer_loss(solve(phi, inner_b, rng=key), phi, outer_b)

        val, hg = jax.value_and_grad(outer_obj)(hparams)  # val: pre-update g
        hparams, outer_state = outer_opt.apply(hg, outer_state, hparams, step)
        return hparams, outer_state, val, sketch_state

    # ---------------- loop ----------------
    t0 = time.time()
    sketch_state = None
    with activation_mesh(mesh):
        for i in range(start_step, args.steps):
            batch = next(loader)
            params, opt_state, step, loss = inner_step(
                params, opt_state, hparams, step, batch)
            if args.log_every and (i + 1) % args.log_every == 0:
                rate = (i + 1 - start_step) / (time.time() - t0)
                print(f'[train] step {i+1} loss={float(loss):.4f} '
                      f'({rate:.2f} steps/s)', flush=True)
            if (i + 1) % args.outer_every == 0:
                outer_b = stream.batch(10_000_000 + i, args.batch,
                                       clean_only=True)
                okey = jax.random.PRNGKey(i)
                if policy is not None and sketch_state is None:
                    # structural zeros at max staleness: the first outer
                    # step's lax.cond rebuilds it; costs no HVPs here.
                    # init_state's rng is eval_shape-only, but fold it
                    # anyway so the step key is never handed out twice
                    sketch_state = policy.init_state(
                        params, hparams, batch, jax.random.fold_in(okey, 1))
                hparams, outer_state, val, sketch_state = outer_step(
                    params, hparams, outer_state, jnp.int32(i),
                    batch, outer_b, okey, sketch_state)
                w = jax.nn.softmax(hparams['domain_logits'])
                noisy = float(w[jnp.array(stream.noisy_domains)].sum())
                print(f'[outer] step {i+1} val(pre-update)={float(val):.4f} '
                      f'noisy-domain weight={noisy:.3f} '
                      f'(uniform={len(stream.noisy_domains)/stream.n_domains:.3f})',
                      flush=True)
            if ckpt and (i + 1) % args.ckpt_every == 0:
                ckpt.save(i + 1, {'params': params, 'opt': opt_state,
                                  'h': hparams, 'houter': outer_state})
    if ckpt:
        ckpt.save(args.steps, {'params': params, 'opt': opt_state,
                               'h': hparams, 'houter': outer_state})
        ckpt.wait()
    print(f'[train] done: {args.steps} steps, final loss {float(loss):.4f}')
    return float(loss), hparams


if __name__ == '__main__':
    main()
