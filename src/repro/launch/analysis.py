"""Roofline-term extraction from compiled dry-run artifacts.

Methodology (EXPERIMENTS.md §Roofline):

XLA's HloCostAnalysis visits a `while` body ONCE — a scanned-depth model
reports ~one block of FLOPs regardless of trip count (verified empirically;
see tests/test_analysis.py). We therefore assemble per-device totals from
compiled artifacts as:

  1. full scanned compile          → memory_analysis (fits-HBM proof),
                                     compile feasibility (the dry-run gate)
  2. unrolled 1-block + 2-block    → per-block cost by differencing:
     analysis compiles                inside = C(2) − C(1);
                                      outside = C(1) − inside (clamped ≥ 0);
                                      total = outside + n_blocks · inside
  3. analytic corrections          → interiors of *time* loops, which stay
     (flagged per cell)              `while`s even in the unrolled-block
                                     lowering: chunked-attention streaming,
                                     Mamba/RWKV recurrence flops/bytes.

Collective bytes are parsed from the unrolled compiles' optimized HLO
(result-type bytes of all-gather / all-reduce / reduce-scatter / all-to-all /
collective-permute) and scaled by the same differencing — block-level
collectives (FSDP gathers, row-parallel psums) all live at block scope, and
the time-loop interiors are collective-free by construction (sharding rules
keep recurrences local), so no correction term is needed for comms.

Hardware model (TPU v5e-class, per chip): 197 TFLOP/s bf16, 819 GB/s HBM,
~50 GB/s/link ICI.
"""
from __future__ import annotations

import dataclasses
import re
from typing import Any

import jax
import jax.numpy as jnp

from repro.models.config import ModelConfig

PEAK_FLOPS = 197e12
HBM_BW = 819e9
ICI_BW = 50e9

_COLLECTIVES = ('all-reduce', 'all-gather', 'reduce-scatter', 'all-to-all',
                'collective-permute')
_DTYPE_BYTES = {'f64': 8, 'f32': 4, 'f16': 2, 'bf16': 2, 's64': 8, 'u64': 8,
                's32': 4, 'u32': 4, 's16': 2, 'u16': 2, 's8': 1, 'u8': 1,
                'pred': 1, 'f8e4m3fn': 1, 'f8e5m2': 1}
_SHAPE_RE = re.compile(r'(\w+)\[([\d,]*)\]')
_LINE_RE = re.compile(
    r'^\s*(?:ROOT\s+)?%[\w.-]+\s*=\s*(\([^)]*\)|[^=(]+?)\s+('
    + '|'.join(_COLLECTIVES) + r')(?:-start|-done)?\(')


def _type_bytes(type_str: str) -> int:
    total = 0
    for dtype, dims in _SHAPE_RE.findall(type_str):
        if dtype not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(','):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dtype]
    return total


def collective_bytes(hlo_text: str) -> dict[str, Any]:
    """Per-device bytes by collective kind from optimized HLO text.
    `-start` variants counted, `-done` skipped (same transfer)."""
    out = {k: 0 for k in _COLLECTIVES}
    counts = {k: 0 for k in _COLLECTIVES}
    for line in hlo_text.splitlines():
        if '-done(' in line:
            continue
        m = _LINE_RE.match(line)
        if not m:
            continue
        out[m.group(2)] += _type_bytes(m.group(1))
        counts[m.group(2)] += 1
    return {'bytes': out, 'counts': counts,
            'total_bytes': sum(out.values())}


# --------------------------------------------------------- analytic interiors
def _attention_interior(cfg: ModelConfig, batch_local: int, seq: int,
                        train: bool, heads_local: int) -> dict:
    """Chunked-attention streaming cost per attention layer (per device).
    Dense (non-causal-skipping) baseline — matches the executed code."""
    hd = cfg.head_dim
    flops = 4.0 * batch_local * seq * seq * heads_local * hd   # QKᵀ + PV
    nq = max(seq // cfg.attn_chunk, 1)
    kv_bytes = 2 * batch_local * seq * heads_local * hd * 2    # K+V bf16
    bytes_ = nq * kv_bytes + 2 * batch_local * seq * heads_local * hd * 2
    if train:      # backward ≈ 2× forward flops + remat recompute ≈ 1×
        flops *= 3.5
        bytes_ *= 3.0
    return {'flops': flops, 'bytes': bytes_}


def _ssm_interior(cfg: ModelConfig, batch_local: int, seq: int,
                  train: bool, di_local: int) -> dict:
    ds = cfg.d_state
    flops = 6.0 * batch_local * seq * di_local * ds
    bytes_ = 3.0 * batch_local * seq * di_local * ds * 4       # f32 states
    if train:
        flops *= 3.5
        bytes_ *= 3.0
    return {'flops': flops, 'bytes': bytes_}


def _rwkv_interior(cfg: ModelConfig, batch_local: int, seq: int,
                   train: bool, heads_local: int) -> dict:
    flops = 7.0 * batch_local * seq * heads_local * 64 * 64
    bytes_ = 2.0 * batch_local * seq * heads_local * 64 * 64 * 4
    if train:
        flops *= 3.5
        bytes_ *= 3.0
    return {'flops': flops, 'bytes': bytes_}


def interior_corrections(cfg: ModelConfig, mesh, kind: str,
                         global_batch: int, seq: int) -> dict:
    """Per-device analytic cost of while-loop interiors (see module doc)."""
    from repro.distributed.sharding import batch_axes
    n_b = 1
    for a in batch_axes(mesh):
        n_b *= mesh.shape[a]
    b_local = max(global_batch // n_b, 1) if global_batch % n_b == 0 else global_batch
    m = mesh.shape['model'] if 'model' in mesh.axis_names else 1
    train = kind == 'train'

    flops = 0.0
    bytes_ = 0.0
    if kind == 'decode':     # no time loops at decode; nothing to correct
        return {'flops': 0.0, 'bytes': 0.0}
    for (mixer, _f) in cfg.layer_kinds():
        n_such = cfg.n_layers // cfg.block_period
        if mixer == 'attn':
            # mirrors _project_qkv: TP head-padding makes heads shard even
            # when H % m != 0 (padded to the next multiple of m)
            h_pad = (cfg.n_heads + m - 1) // m * m
            h_local = h_pad // m
            if seq > cfg.attn_chunk:
                c = _attention_interior(cfg, b_local, seq, train, h_local)
                flops += c['flops'] * n_such
                bytes_ += c['bytes'] * n_such
        elif mixer == 'mamba':
            di_local = cfg.d_inner // m if cfg.d_inner % m == 0 else cfg.d_inner
            c = _ssm_interior(cfg, b_local, seq, train, di_local)
            flops += c['flops'] * n_such
            bytes_ += c['bytes'] * n_such
        else:
            H = cfg.d_model // 64
            h_local = H // m if H % m == 0 else H
            c = _rwkv_interior(cfg, b_local, seq, train, h_local)
            flops += c['flops'] * n_such
            bytes_ += c['bytes'] * n_such
    if cfg.is_encdec and kind in ('train', 'prefill') and seq > cfg.attn_chunk:
        h_local = ((cfg.n_heads + m - 1) // m * m) // m
        c = _attention_interior(cfg, b_local, seq, train, h_local)
        flops += c['flops'] * cfg.n_enc_layers
        bytes_ += c['bytes'] * cfg.n_enc_layers
    return {'flops': flops, 'bytes': bytes_}


# ----------------------------------------------------------------- MODEL_FLOPS
def model_flops(cfg: ModelConfig, kind: str, global_batch: int,
                seq: int) -> float:
    """Global 6·N·D (train) / 2·N·D (serve) with N = active params."""
    n_active = cfg.param_count(active_only=True)
    if kind == 'train':
        return 6.0 * n_active * global_batch * seq
    if kind == 'prefill':
        return 2.0 * n_active * global_batch * seq
    if kind == 'decode':
        return 2.0 * n_active * global_batch        # one token per sequence
    if kind == 'hypergrad':
        # k+1 HVPs (~2× fwd+bwd each) + 1 grad + 1 vjp ≈ (4k + 10)·N·D-ish;
        # report the k=8 configuration used by build_hypergrad_step
        return (4 * 8 + 10) * n_active * global_batch * seq
    raise ValueError(kind)


# ------------------------------------------------------------------ assembly
def _cost(compiled) -> dict:
    ca = compiled.cost_analysis()
    return {'flops': float(ca.get('flops', 0.0)),
            'bytes': float(ca.get('bytes accessed', 0.0))}


@dataclasses.dataclass
class CellAnalysis:
    arch: str
    shape: str
    mesh: str
    n_chips: int
    flops_per_chip: float
    bytes_per_chip: float
    coll_bytes_per_chip: float
    coll_detail: dict
    correction: dict
    model_flops_global: float
    memory: dict
    compile_ok: bool
    error: str = ''

    def terms(self) -> dict:
        t_c = self.flops_per_chip / PEAK_FLOPS
        t_m = self.bytes_per_chip / HBM_BW
        t_x = self.coll_bytes_per_chip / ICI_BW
        dominant = max((t_c, 'compute'), (t_m, 'memory'),
                       (t_x, 'collective'))[1]
        useful = self.model_flops_global / max(self.n_chips, 1)
        return {'compute_s': t_c, 'memory_s': t_m, 'collective_s': t_x,
                'dominant': dominant,
                'bound_s': max(t_c, t_m, t_x),
                'roofline_fraction': (t_c / max(t_c, t_m, t_x)
                                      if max(t_c, t_m, t_x) > 0 else 0.0),
                'useful_flop_ratio': (useful / self.flops_per_chip
                                      if self.flops_per_chip else 0.0)}


def assemble(arch: str, shape: str, mesh_name: str, n_chips: int,
             c1: dict, c2: dict, n_blocks: int, coll1: dict, coll2: dict,
             corr: dict, mflops: float, memory: dict) -> CellAnalysis:
    """Differencing: inside = C2 − C1; outside = max(C1 − inside, 0)."""
    def diff(a, b):
        inside = max(b - a, 0.0)
        outside = max(a - inside, 0.0)
        return outside + n_blocks * inside

    flops = diff(c1['flops'], c2['flops']) + corr['flops']
    bytes_ = diff(c1['bytes'], c2['bytes']) + corr['bytes']
    coll = diff(float(coll1['total_bytes']), float(coll2['total_bytes']))
    detail = {k: diff(float(coll1['bytes'][k]), float(coll2['bytes'][k]))
              for k in _COLLECTIVES}
    return CellAnalysis(
        arch=arch, shape=shape, mesh=mesh_name, n_chips=n_chips,
        flops_per_chip=flops, bytes_per_chip=bytes_,
        coll_bytes_per_chip=coll, coll_detail=detail, correction=corr,
        model_flops_global=mflops, memory=memory, compile_ok=True)
