"""The jit-compiled step functions + their input/output sharding specs.

These are shared by the dry-run (lower + compile against ShapeDtypeStructs)
and the real trainer/server. Every (architecture × input-shape × mesh)
combination routes through ``build_step``:

  train_4k    → train_step(params, opt_state, step, batch)
  prefill_32k → prefill_step(params, batch)         (logits for last position)
  decode_32k  → serve_step(params, tokens, cache)   (one token, cache update)
  long_500k   → serve_step with a 524288-entry cache (sub-quadratic archs)

plus the paper's feature as a first-class step:

  hypergrad   → hypergrad_step(params, hparams, batches, rng)
                (Nyström sketch + IHVP + outer update for data reweighting)
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.core import NystromIHVP, implicit_root
from repro.distributed.sharding import (batch_axes, cache_specs, mirror_specs,
                                        named_shardings, param_specs)
from repro.models import build_model
from repro.models.config import ModelConfig
from repro.models.transformer import forward, train_loss
from repro.optim import adafactor, adamw, chain, clip_by_global_norm

N_DOMAINS = 64          # outer-parameter dimension for LM data reweighting


# --------------------------------------------------------------------- specs
def _maybe_batch_spec(mesh, global_batch: int, extra: int = 0) -> P:
    """Batch over (pod, data) when divisible, else replicate (e.g. B=1)."""
    axes = batch_axes(mesh)
    total = 1
    for a in axes:
        total *= mesh.shape[a]
    if axes and global_batch % total == 0:
        return P(axes, *([None] * extra))
    return P(None, *([None] * extra))


def make_optimizer(cfg: ModelConfig):
    """Adafactor for 100B+ (factored state is what fits HBM), AdamW below."""
    if cfg.param_count() > 100e9:
        return chain(clip_by_global_norm(1.0), adafactor(1e-2))
    return chain(clip_by_global_norm(1.0), adamw(3e-4, weight_decay=0.1))


@dataclasses.dataclass
class StepBundle:
    fn: Any                  # the jit-able python callable
    args_sds: tuple          # ShapeDtypeStruct pytree per argument
    in_shardings: tuple
    out_shardings: Any
    donate_argnums: tuple = ()


def _sds(shape, dtype):
    return jax.ShapeDtypeStruct(shape, dtype)


def _param_sds(cfg: ModelConfig, serve: bool = False):
    model = build_model(cfg)
    tree = jax.eval_shape(model.init, jax.random.PRNGKey(0))
    if serve:   # serving casts float params to bf16 at load
        tree = jax.tree.map(
            lambda s: _sds(s.shape, jnp.bfloat16
                           if jnp.issubdtype(s.dtype, jnp.floating) else s.dtype),
            tree)
    return tree


def make_batch_sds(cfg: ModelConfig, batch: int, seq: int):
    b: dict[str, Any] = {'labels': _sds((batch, seq), jnp.int32),
                         'mask': _sds((batch, seq), jnp.float32)}
    if cfg.is_encdec:
        b['inputs'] = _sds((batch, seq), jnp.int32)
        b['enc_inputs'] = _sds((batch, seq, cfg.d_model), jnp.bfloat16)
    elif not cfg.embed_inputs:
        b['inputs'] = _sds((batch, seq, cfg.d_model), jnp.bfloat16)
        if cfg.mrope:
            b['positions'] = _sds((batch, 3, seq), jnp.int32)
    else:
        b['inputs'] = _sds((batch, seq), jnp.int32)
    return b


def batch_specs(cfg: ModelConfig, mesh, batch: int):
    bs = _maybe_batch_spec(mesh, batch)
    specs: dict[str, Any] = {'labels': P(*bs, None), 'mask': P(*bs, None)}
    if cfg.is_encdec:
        specs['inputs'] = P(*bs, None)
        specs['enc_inputs'] = P(*bs, None, None)
    elif not cfg.embed_inputs:
        specs['inputs'] = P(*bs, None, None)
        if cfg.mrope:
            specs['positions'] = P(*bs, None, None)
    else:
        specs['inputs'] = P(*bs, None)
    return specs


# --------------------------------------------------------------------- train
def build_train_step(cfg: ModelConfig, mesh, global_batch: int, seq: int,
                     optimizer=None, microbatches: int | None = None) -> StepBundle:
    optimizer = optimizer or make_optimizer(cfg)
    # §Perf hillclimb: 300B+ dense trains exceed HBM on one pod without
    # gradient accumulation — scan over microbatches keeps one microbatch's
    # remat residuals live at a time (weight gathers repeat per microbatch:
    # a measured collective/memory tradeoff, see EXPERIMENTS.md §Perf).
    if microbatches is None:
        # auto only on the scanned production path — the unrolled analysis
        # lowering must keep collectives outside any loop body so the
        # 1/2-block differencing counts them (launch/analysis.py)
        microbatches = 4 if (cfg.param_count() > 3e11 and cfg.scan_layers) else 1

    def train_step(params, opt_state, step, batch):
        if microbatches > 1:
            def micro(carry, mb):
                acc = carry
                loss, grads = jax.value_and_grad(
                    functools.partial(train_loss, cfg))(params, mb)
                return jax.tree.map(jnp.add, acc, grads), loss

            mbs = jax.tree.map(
                lambda x: x.reshape(microbatches, x.shape[0] // microbatches,
                                    *x.shape[1:]), batch)
            zeros = jax.tree.map(
                lambda p: jnp.zeros(p.shape, jnp.float32), params)
            gsum, losses = jax.lax.scan(micro, zeros, mbs)
            grads = jax.tree.map(lambda g: g / microbatches, gsum)
            loss = losses.mean()
        else:
            loss, grads = jax.value_and_grad(
                functools.partial(train_loss, cfg))(params, batch)
        params, opt_state = optimizer.apply(grads, opt_state, params, step)
        gnorm = jnp.sqrt(sum(jnp.sum(jnp.square(g.astype(jnp.float32)))
                             for g in jax.tree.leaves(grads)))
        metrics = {'loss': loss, 'grad_norm': gnorm}
        return params, opt_state, step + 1, metrics

    params_sds = _param_sds(cfg)
    opt_sds = jax.eval_shape(optimizer.init, params_sds)
    batch_sds = make_batch_sds(cfg, global_batch, seq)

    pspecs = param_specs(cfg, mesh)
    ospecs = mirror_specs(params_sds, pspecs, opt_sds)
    bspecs = batch_specs(cfg, mesh, global_batch)
    ns = functools.partial(named_shardings, mesh)
    in_sh = (ns(pspecs), ns(ospecs), NamedSharding(mesh, P()), ns(bspecs))
    out_sh = (ns(pspecs), ns(ospecs), NamedSharding(mesh, P()),
              {'loss': NamedSharding(mesh, P()),
               'grad_norm': NamedSharding(mesh, P())})
    return StepBundle(
        fn=train_step,
        args_sds=(params_sds, opt_sds, _sds((), jnp.int32), batch_sds),
        in_shardings=in_sh, out_shardings=out_sh, donate_argnums=(0, 1))


# ------------------------------------------------------------------- prefill
def build_prefill_step(cfg: ModelConfig, mesh, global_batch: int,
                       seq: int) -> StepBundle:
    def prefill_step(params, batch):
        logits, _ = forward(cfg, params, batch['inputs'],
                            positions=batch.get('positions'),
                            enc_inputs=batch.get('enc_inputs'))
        return logits[:, -1, :]            # next-token distribution

    params_sds = _param_sds(cfg, serve=True)
    batch_sds = make_batch_sds(cfg, global_batch, seq)
    batch_sds.pop('labels')
    batch_sds.pop('mask')
    pspecs = param_specs(cfg, mesh)
    bspecs = batch_specs(cfg, mesh, global_batch)
    bspecs.pop('labels')
    bspecs.pop('mask')
    ns = functools.partial(named_shardings, mesh)
    out = NamedSharding(mesh, P(*_maybe_batch_spec(mesh, global_batch), 'model'))
    return StepBundle(fn=prefill_step,
                      args_sds=(params_sds, batch_sds),
                      in_shardings=(ns(pspecs), ns(bspecs)),
                      out_shardings=out)


# -------------------------------------------------------------------- decode
def build_serve_step(cfg: ModelConfig, mesh, global_batch: int,
                     cache_len: int) -> StepBundle:
    model = build_model(cfg)

    def serve_step(params, tokens, cache):
        logits, cache = model.decode_step(params, tokens, cache)
        return logits, cache

    params_sds = _param_sds(cfg, serve=True)
    cache_sds = jax.eval_shape(
        functools.partial(model.init_cache, global_batch, cache_len))
    if cfg.embed_inputs or cfg.is_encdec:
        tok_sds = _sds((global_batch, 1), jnp.int32)
        tok_spec = P(*_maybe_batch_spec(mesh, global_batch), None)
    else:
        tok_sds = _sds((global_batch, 1, cfg.d_model), jnp.bfloat16)
        tok_spec = P(*_maybe_batch_spec(mesh, global_batch), None, None)

    pspecs = param_specs(cfg, mesh)
    cspecs = cache_specs(cfg, mesh)
    # batch=1 long-context: replace batch axes with None wherever B indivisible
    bspec = _maybe_batch_spec(mesh, global_batch)
    if bspec == P(None):
        cspecs = jax.tree.map(
            lambda s: P(*[None if isinstance(ax, tuple) or ax in ('pod', 'data')
                          else ax for ax in s]),
            cspecs, is_leaf=lambda x: isinstance(x, P))
    ns = functools.partial(named_shardings, mesh)
    logits_sh = NamedSharding(mesh, P(*bspec, None, 'model'))
    return StepBundle(
        fn=serve_step,
        args_sds=(params_sds, tok_sds, cache_sds),
        in_shardings=(ns(pspecs), NamedSharding(mesh, tok_spec), ns(cspecs)),
        out_shardings=(logits_sh, ns(cspecs)),
        donate_argnums=(2,))


# ----------------------------------------------------------------- hypergrad
def build_hypergrad_step(cfg: ModelConfig, mesh, global_batch: int, seq: int,
                         k: int = 8, rho: float = 1e-2) -> StepBundle:
    """The paper's technique as a pod-scale step: Nyström-IHVP hypergradient
    of balanced-validation loss w.r.t. per-domain loss weights (§5.4 at LM
    scale). Lowered/compiled like any other cell for the roofline."""
    solver = NystromIHVP(k=k, rho=rho, column_chunk=2)

    def inner_loss(params, hparams, batch):
        w = jax.nn.softmax(hparams['domain_logits']) * N_DOMAINS
        return train_loss(cfg, params, batch,
                          example_weights=w[batch['domain']])

    def outer_loss(params, hparams, batch):
        return train_loss(cfg, params, batch)

    def hypergrad_step(params, hparams, inner_batch, outer_batch, rng):
        # the already-trained params are the implicit solution; grad through
        # the implicit_root map assembles Eq. 3 in the custom_vjp backward
        solution = implicit_root(lambda phi, b: params, inner_loss, solver)

        def outer_obj(phi):
            theta = solution(phi, inner_batch, rng=rng)
            return outer_loss(theta, phi, outer_batch)

        hg = jax.grad(outer_obj)(hparams)
        new_h = jax.tree.map(lambda h, g: h - 1e-2 * g, hparams, hg)
        return new_h

    params_sds = _param_sds(cfg)
    hparams_sds = {'domain_logits': _sds((N_DOMAINS,), jnp.float32)}
    batch_sds = make_batch_sds(cfg, global_batch, seq)
    batch_sds['domain'] = _sds((global_batch,), jnp.int32)

    pspecs = param_specs(cfg, mesh)
    bspecs = batch_specs(cfg, mesh, global_batch)
    bspecs['domain'] = _maybe_batch_spec(mesh, global_batch)
    ns = functools.partial(named_shardings, mesh)
    rep = NamedSharding(mesh, P())
    return StepBundle(
        fn=hypergrad_step,
        args_sds=(params_sds, hparams_sds, batch_sds, batch_sds,
                  _sds((2,), jnp.uint32)),
        in_shardings=(ns(pspecs), {'domain_logits': rep}, ns(bspecs),
                      ns(bspecs), rep),
        out_shardings={'domain_logits': rep})


def build_step(cfg: ModelConfig, mesh, kind: str, global_batch: int,
               seq: int) -> StepBundle:
    if kind == 'train':
        return build_train_step(cfg, mesh, global_batch, seq)
    if kind == 'prefill':
        return build_prefill_step(cfg, mesh, global_batch, seq)
    if kind == 'decode':
        return build_serve_step(cfg, mesh, global_batch, seq)
    if kind == 'hypergrad':
        return build_hypergrad_step(cfg, mesh, global_batch, seq)
    raise ValueError(kind)
