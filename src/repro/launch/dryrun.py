import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (arch × shape × mesh) cell.

The two lines above MUST stay first: jax locks the device count at first
init, and the dry-run needs 512 placeholder host devices to build the
production meshes. (Smoke tests / benches must NOT import this module.)

Per cell this produces, from compiled artifacts only (no allocation —
inputs are ShapeDtypeStructs):
  * compile success on the 16×16 single-pod AND 2×16×16 two-pod mesh,
  * memory_analysis (bytes per device — the fits-in-HBM proof),
  * cost_analysis + collective-bytes parse → the three roofline terms
    (methodology in launch/analysis.py docstring).

Results append incrementally to experiments/dryrun/<cell>.json so an
interrupted sweep resumes where it stopped.

Usage:
  python -m repro.launch.dryrun --arch yi_9b --shape train_4k
  python -m repro.launch.dryrun --all [--multi-pod-only-compile]
  python -m repro.launch.dryrun --arch yi_9b --shape train_4k --kind hypergrad
"""
import argparse
import dataclasses
import json
import time
import traceback

import jax

from repro.configs import ALIASES, ARCHS, SHAPES, get_config, shape_applicable
from repro.launch import analysis as an
from repro.launch.mesh import make_production_mesh
from repro.launch.steps import build_step

OUT_DIR = os.path.join(os.path.dirname(__file__), '..', '..', '..',
                       'experiments', 'dryrun')


def _lower_compile(cfg, mesh, kind, batch, seq):
    from repro.distributed.ctx import activation_mesh
    bundle = build_step(cfg, mesh, kind, batch, seq)
    with mesh, activation_mesh(mesh):
        jitted = jax.jit(bundle.fn, in_shardings=bundle.in_shardings,
                         out_shardings=bundle.out_shardings,
                         donate_argnums=bundle.donate_argnums)
        lowered = jitted.lower(*bundle.args_sds)
        compiled = lowered.compile()
    return lowered, compiled


def _memory_dict(compiled):
    try:
        ma = compiled.memory_analysis()
        return {'argument_gb': ma.argument_size_in_bytes / 1e9,
                'output_gb': ma.output_size_in_bytes / 1e9,
                'temp_gb': ma.temp_size_in_bytes / 1e9,
                'alias_gb': ma.alias_size_in_bytes / 1e9,
                'total_gb': (ma.argument_size_in_bytes
                             + ma.output_size_in_bytes
                             + ma.temp_size_in_bytes
                             - ma.alias_size_in_bytes) / 1e9}
    except Exception as e:                        # backend-dependent API
        return {'error': str(e)}


def run_cell(arch: str, shape_spec, kind: str | None = None,
             multi_pod_compile: bool = True, analysis: bool = True) -> dict:
    cfg = get_config(arch)
    kind = kind or shape_spec.kind
    batch, seq = shape_spec.global_batch, shape_spec.seq_len
    rec: dict = {'arch': arch, 'shape': shape_spec.name, 'kind': kind,
                 'global_batch': batch, 'seq_len': seq, 'ts': time.time()}

    # ---- 1. full scanned compile on the single-pod mesh (memory proof) ----
    t0 = time.time()
    mesh1 = make_production_mesh(multi_pod=False)
    lowered, compiled = _lower_compile(cfg, mesh1, kind, batch, seq)
    rec['single_pod'] = {'compile_s': time.time() - t0,
                         'memory': _memory_dict(compiled),
                         'n_chips': 256}

    # ---- 2. two-pod compile (proves the 'pod' axis shards) ----
    if multi_pod_compile:
        t0 = time.time()
        mesh2 = make_production_mesh(multi_pod=True)
        _, compiled2 = _lower_compile(cfg, mesh2, kind, batch, seq)
        rec['multi_pod'] = {'compile_s': time.time() - t0,
                            'memory': _memory_dict(compiled2),
                            'n_chips': 512}
        del compiled2

    # ---- 3. roofline terms via unrolled 1/2-block differencing ----
    if analysis:
        period = cfg.block_period
        costs, colls = [], []
        for blocks in (1, 2):
            # NOTE: attn_chunk stays at the production value — the chunked
            # attention interior is a while loop whose single-visit cost is
            # (under)counted once and corrected analytically; overriding the
            # chunk to unroll it would change the measured program (full S²
            # logits materialization that the real code never does).
            acfg = dataclasses.replace(
                cfg, n_layers=period * blocks, scan_layers=False,
                n_enc_layers=blocks if cfg.is_encdec else 0)
            _, c = _lower_compile(acfg, mesh1, kind, batch, seq)
            costs.append(an._cost(c))
            colls.append(an.collective_bytes(c.as_text()))
            del c
        corr = an.interior_corrections(cfg, mesh1, kind, batch, seq)
        cell = an.assemble(
            arch, shape_spec.name, '16x16', 256,
            costs[0], costs[1], cfg.n_blocks, colls[0], colls[1], corr,
            an.model_flops(cfg, kind, batch, seq),
            rec['single_pod']['memory'])
        rec['analysis'] = dataclasses.asdict(cell)
        rec['analysis']['terms'] = cell.terms()
        # enc-dec: encoder depth scales with n_enc_layers too; differencing
        # already covers it since both lowerings scale encoder blocks.
    return rec


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument('--arch', type=str, default=None)
    ap.add_argument('--shape', type=str, default=None)
    ap.add_argument('--kind', type=str, default=None,
                    help="override step kind (e.g. 'hypergrad')")
    ap.add_argument('--all', action='store_true')
    ap.add_argument('--no-multi-pod', action='store_true')
    ap.add_argument('--no-analysis', action='store_true')
    ap.add_argument('--force', action='store_true')
    ap.add_argument('--out', type=str, default=OUT_DIR)
    args = ap.parse_args()

    os.makedirs(args.out, exist_ok=True)
    shapes = {s.name: s for s in SHAPES}

    cells = []
    if args.all:
        for a in ARCHS:
            for s in SHAPES:
                cells.append((a, s))
    else:
        assert args.arch and args.shape, '--arch/--shape or --all'
        cells.append((ALIASES.get(args.arch, args.arch), shapes[args.shape]))

    failures = []
    for arch, s in cells:
        cfg = get_config(arch)
        ok, why = shape_applicable(cfg, s)
        tag = f'{arch}__{s.name}' + (f'__{args.kind}' if args.kind else '')
        path = os.path.join(args.out, tag + '.json')
        if not ok:
            with open(path, 'w') as f:
                json.dump({'arch': arch, 'shape': s.name, 'skipped': why}, f,
                          indent=1)
            print(f'[skip] {tag}: {why}')
            continue
        if os.path.exists(path) and not args.force:
            with open(path) as f:
                prev = json.load(f)
            if 'error' not in prev:
                print(f'[cached] {tag}')
                continue
        print(f'[run] {tag} ...', flush=True)
        try:
            rec = run_cell(arch, s, kind=args.kind,
                           multi_pod_compile=not args.no_multi_pod,
                           analysis=not args.no_analysis)
            with open(path, 'w') as f:
                json.dump(rec, f, indent=1, default=float)
            t = rec.get('analysis', {}).get('terms', {})
            print(f"  ok: mem={rec['single_pod']['memory'].get('total_gb', -1):.1f}GB/chip "
                  f"compute={t.get('compute_s', 0)*1e3:.2f}ms "
                  f"memory={t.get('memory_s', 0)*1e3:.2f}ms "
                  f"coll={t.get('collective_s', 0)*1e3:.2f}ms "
                  f"dom={t.get('dominant', '?')}", flush=True)
        except Exception as e:
            traceback.print_exc()
            failures.append(tag)
            with open(path, 'w') as f:
                json.dump({'arch': arch, 'shape': s.name,
                           'error': f'{type(e).__name__}: {e}'}, f, indent=1)
    if failures:
        print('FAILED cells:', failures)
        raise SystemExit(1)
    print('dry-run complete.')


if __name__ == '__main__':
    main()
