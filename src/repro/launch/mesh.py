"""Production mesh factories.

``make_production_mesh`` is a FUNCTION (not a module constant) so importing
this module never touches jax device state — device count is locked at first
jax init, and only launch/dryrun.py (which sets XLA_FLAGS before any import)
may see the 512-placeholder topology.
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    """16×16 chips per pod ('data','model'); two pods add a leading 'pod' axis
    (cross-pod traffic = batch-gradient all-reduce over DCN only)."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_host_mesh(data: int | None = None, model: int = 1):
    """Small mesh over whatever devices exist (tests / CPU examples)."""
    n = len(jax.devices())
    data = data or (n // model)
    return jax.make_mesh((data, model), ("data", "model"))
