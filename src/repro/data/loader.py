"""Sharded, prefetching data loader.

``ShardedLoader`` slices each deterministic global batch to this host's rows
of the (pod, data) mesh axes and device_puts with the right sharding;
``Prefetcher`` overlaps host-side generation with device compute (a bounded
background thread — the standard input-pipeline overlap trick, and one of the
straggler mitigations: a slow host never stalls more than `depth` steps).
"""
from __future__ import annotations

import queue
import threading
from typing import Any, Callable, Iterator

import jax


class ShardedLoader:
    """make_batch(step) -> pytree of np/jnp arrays with leading global-batch
    axis; the loader yields device-sharded batches step by step."""

    def __init__(self, make_batch: Callable[[int], Any], mesh=None,
                 batch_axes: tuple[str, ...] = ('data',), start_step: int = 0):
        self.make_batch = make_batch
        self.mesh = mesh
        self.batch_axes = batch_axes
        self.step = start_step

    def _shard(self, batch):
        if self.mesh is None:
            return batch
        from jax.sharding import NamedSharding, PartitionSpec as P

        def put(x):
            spec = P(self.batch_axes) if getattr(x, 'ndim', 0) >= 1 else P()
            return jax.device_put(x, NamedSharding(self.mesh, spec))

        return jax.tree.map(put, batch)

    def __iter__(self) -> Iterator[Any]:
        return self

    def __next__(self):
        batch = self._shard(self.make_batch(self.step))
        self.step += 1
        return batch

    def state_dict(self):
        return {'step': self.step}

    def load_state_dict(self, state):
        self.step = int(state['step'])


class Prefetcher:
    """Bounded background prefetch over any iterator."""

    _SENTINEL = object()

    def __init__(self, it: Iterator[Any], depth: int = 2):
        self.q: queue.Queue = queue.Queue(maxsize=depth)
        self._err = None

        def worker():
            try:
                for item in it:
                    self.q.put(item)
            except Exception as e:          # surface in consumer thread
                self._err = e
            finally:
                self.q.put(self._SENTINEL)

        self.thread = threading.Thread(target=worker, daemon=True)
        self.thread.start()

    def __iter__(self):
        return self

    def __next__(self):
        item = self.q.get()
        if item is self._SENTINEL:
            if self._err:
                raise self._err
            raise StopIteration
        return item
