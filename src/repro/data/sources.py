"""Batch sources: the data half of a :class:`~repro.core.problem.BilevelProblem`.

A *batch source* is anything satisfying the small ``BatchSource`` protocol
(defined structurally in ``repro.core.problem``): deterministic, step-indexed
batch draws for the inner (train) and outer (validation) streams —

    source.train_batch(step, batch_size) -> inner batch
    source.val_batch(step, batch_size)   -> outer batch

Step-indexing keeps the fault-tolerance property of ``repro.data.synthetic``:
batch t is a pure function of (seed, t), so any host can reproduce any batch.

Two concrete sources cover the paper's tasks:

* :class:`ArraySource` — in-memory ``(X, y)`` splits with jax-PRNG sampling.
  The key schedule (``PRNGKey(step)`` train / ``PRNGKey(1000 + step)`` val at
  seed 0) reproduces the seed benchmark streams bit-for-bit, so ports of
  fig2/tab4/tab6 onto ``solve()`` keep their original trajectories.
* :class:`EpisodeSource` — few-shot episodes for meta-problems (iMAML). It
  has no train/val stream; consumers go through ``task_batch`` (the
  ``vmap_tasks=`` path of ``solve()``), which returns a meta-batch of stacked
  (support, query) pairs with a leading task axis.
"""
from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp


@dataclasses.dataclass
class ArraySource:
    """Deterministic sampling over in-memory train/val array tuples.

    ``train`` / ``val`` are ``(X, y)`` pairs, exposed directly for consumers
    that want the full splits (full-batch solves).

    Beyond the step-indexed random streams, the source exposes the
    *ordered-streaming* protocol (``n_train`` / ``train_slice``) that
    :func:`repro.core.problem.influence` sweeps: contiguous, deterministic,
    index-aligned slices of the training split — ``train_slice(start, size)``
    is examples ``[start, min(start + size, n_train))`` in storage order, so
    a returned score index always names the same example.
    """
    train: tuple[jax.Array, jax.Array]
    val: tuple[jax.Array, jax.Array]
    seed: int = 0
    val_key_offset: int = 1000   # seed streams: train keys t, val keys 1000+t

    def _draw(self, arrays, key: int, batch_size: int):
        X, y = arrays
        idx = jax.random.randint(jax.random.PRNGKey(key), (batch_size,), 0,
                                 X.shape[0])
        return X[idx], y[idx]

    def train_batch(self, step: int, batch_size: int):
        return self._draw(self.train, self.seed + step, batch_size)

    def val_batch(self, step: int, batch_size: int):
        return self._draw(self.val, self.seed + self.val_key_offset + step,
                          batch_size)

    # -- ordered streaming (influence sweeps) -------------------------------
    @property
    def n_train(self) -> int:
        return int(self.train[0].shape[0])

    def train_slice(self, start: int, size: int):
        """Examples [start, min(start+size, n_train)) in storage order."""
        X, y = self.train
        stop = min(start + size, X.shape[0])
        if not 0 <= start < X.shape[0]:
            raise IndexError(f'train_slice start {start} outside '
                             f'[0, {X.shape[0]})')
        return X[start:stop], y[start:stop]


@dataclasses.dataclass
class EpisodeSource:
    """Meta-batches of few-shot episodes (iMAML-style meta-problems).

    Wraps an episode sampler (``repro.data.synthetic.FewShotSampler``:
    ``episode(idx) -> (sx, sy, qx, qy)``). ``task_batch`` stacks ``n_tasks``
    consecutive episodes into ((SX, SY), (QX, QY)) with a leading task axis —
    the inner/outer batch pair one vmapped meta-step consumes.
    """
    sampler: Any

    def task_batch(self, step: int, n_tasks: int):
        eps = [self.sampler.episode(step * n_tasks + j)
               for j in range(n_tasks)]
        sx, sy, qx, qy = (jnp.stack(z) for z in zip(*eps))
        return (sx, sy), (qx, qy)

    def _no_stream(self):
        raise TypeError(
            'EpisodeSource is a meta-problem source: it has no flat '
            'train/val stream. Drive it through solve(..., vmap_tasks=N) '
            '(which draws task_batch meta-batches) instead of the '
            'alternating BilevelTrainer path.')

    def train_batch(self, step: int, batch_size: int):
        self._no_stream()

    def val_batch(self, step: int, batch_size: int):
        self._no_stream()
