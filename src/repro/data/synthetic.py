"""Deterministic synthetic datasets (container is offline — DESIGN.md §6.3).

Each generator is seeded and *step-indexed*: batch t is a pure function of
(seed, t), so any host can reproduce any shard's batch — the property the
fault-tolerance story relies on (a restarted/replaced node resumes mid-epoch
without coordination, and stragglers can be re-issued elsewhere).

Tasks mirror the paper's experiment suite:
  make_logreg_problem   — §5.1 synthetic logistic regression (weight-decay HPO)
  DistillationTask      — §5.2 10-class 28×28 "digits" GMM (MNIST analog)
  FewShotSampler        — §5.3 procedural character classes (Omniglot analog)
  LongTailDataset       — §5.4 imbalance-factor-parameterized classification
  TokenStream           — LM-scale domain-mixture corpus for the end-to-end
                          bilevel data-reweighting driver (noisy domains give
                          the outer loop signal to discover).
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np


# ------------------------------------------------------------------ §5.1
def make_logreg_problem(D: int = 100, n: int = 500, seed: int = 0,
                        noise: float = 0.5):
    """y = (w*ᵀ x + ε > 0); returns (train, val) arrays (paper §5.1 setup)."""
    rng = np.random.RandomState(seed)
    w_star = rng.randn(D).astype(np.float32)

    def split(m):
        X = rng.randn(m, D).astype(np.float32)
        y = (X @ w_star + noise * rng.randn(m) > 0).astype(np.float32)
        return jnp.asarray(X), jnp.asarray(y)

    return split(n), split(n)


# ------------------------------------------------------------------ §5.2
@dataclasses.dataclass
class DistillationTask:
    """10-class 28×28 GMM 'digits': class prototypes are smooth random fields;
    the distilled set must compress them into C synthetic images."""
    n_classes: int = 10
    image_size: int = 28
    n_train: int = 2048
    n_test: int = 1024
    seed: int = 0

    def __post_init__(self):
        rng = np.random.RandomState(self.seed)
        s = self.image_size
        # smooth prototypes: low-frequency random fields per class
        freqs = rng.randn(self.n_classes, 4, 4)
        grid = np.linspace(0, 1, s)
        basis = np.stack([np.cos(np.pi * k * grid) for k in range(4)])  # (4, s)
        protos = np.einsum('ckl,ks,lt->cst', freqs, basis, basis)
        self.prototypes = (protos / np.abs(protos).max((1, 2), keepdims=True)
                           ).astype(np.float32)

    def _sample(self, n, seed):
        rng = np.random.RandomState(seed)
        labels = rng.randint(0, self.n_classes, n)
        imgs = self.prototypes[labels] + 0.35 * rng.randn(
            n, self.image_size, self.image_size).astype(np.float32)
        return jnp.asarray(imgs[..., None]), jnp.asarray(labels)

    def train(self):
        return self._sample(self.n_train, self.seed + 1)

    def test(self):
        return self._sample(self.n_test, self.seed + 2)


# ------------------------------------------------------------------ §5.3
@dataclasses.dataclass
class FewShotSampler:
    """N-way K-shot episodes over procedurally generated 'characters':
    each class is a random stroke-field prototype; episodes draw disjoint
    class sets for meta-train/meta-test (Omniglot protocol analog)."""
    n_way: int = 5
    k_shot: int = 1
    k_query: int = 5
    image_size: int = 20
    n_classes: int = 200
    seed: int = 0

    def __post_init__(self):
        rng = np.random.RandomState(self.seed)
        s = self.image_size
        coeff = rng.randn(self.n_classes, 5, 5)
        grid = np.linspace(0, 1, s)
        basis = np.stack([np.sin(np.pi * (k + 1) * grid) for k in range(5)])
        protos = np.einsum('ckl,ks,lt->cst', coeff, basis, basis)
        self.prototypes = (protos / np.abs(protos).max((1, 2), keepdims=True)
                           ).astype(np.float32)
        self.split = int(0.8 * self.n_classes)

    def episode(self, idx: int, test: bool = False):
        """Deterministic episode #idx → (support_x, support_y, query_x, query_y)."""
        rng = np.random.RandomState(self.seed + 7919 * idx + (1 if test else 0))
        pool = (np.arange(self.split, self.n_classes) if test
                else np.arange(self.split))
        classes = rng.choice(pool, self.n_way, replace=False)
        s = self.image_size

        def draw(per_class):
            xs, ys = [], []
            for yi, c in enumerate(classes):
                imgs = self.prototypes[c] + 0.3 * rng.randn(
                    per_class, s, s).astype(np.float32)
                xs.append(imgs)
                ys.append(np.full(per_class, yi))
            return (jnp.asarray(np.concatenate(xs)[..., None]),
                    jnp.asarray(np.concatenate(ys)))

        return draw(self.k_shot) + draw(self.k_query)


# ------------------------------------------------------------------ §5.4
@dataclasses.dataclass
class LongTailDataset:
    """Long-tailed classification: class c has ~ n_max · if^{-c/(C-1)} samples
    (the Cui et al. exponential profile the paper's CIFAR-10-LT uses)."""
    n_classes: int = 10
    imbalance_factor: int = 100
    n_max: int = 500
    d: int = 64
    seed: int = 0

    def __post_init__(self):
        rng = np.random.RandomState(self.seed)
        # mean separation ~1σ + 10% label noise on the tail: keeps Bayes
        # accuracy well below 1 so reweighting gains are measurable
        self.means = 1.0 * rng.randn(self.n_classes, self.d).astype(np.float32)
        counts = [int(self.n_max * self.imbalance_factor
                      ** (-c / (self.n_classes - 1)))
                  for c in range(self.n_classes)]
        xs, ys = [], []
        for c, n in enumerate(counts):
            xs.append(self.means[c] + rng.randn(n, self.d).astype(np.float32))
            lab = np.full(n, c)
            flip = rng.rand(n) < 0.1
            lab[flip] = rng.randint(0, self.n_classes, flip.sum())
            ys.append(lab)
        perm = rng.permutation(sum(counts))
        self.X = jnp.asarray(np.concatenate(xs)[perm])
        self.y = jnp.asarray(np.concatenate(ys)[perm])
        # balanced validation/test splits
        nv = 40
        xs, ys = [], []
        for c in range(self.n_classes):
            xs.append(self.means[c] + rng.randn(nv, self.d).astype(np.float32))
            ys.append(np.full(nv, c))
        self.Xv = jnp.asarray(np.concatenate(xs))
        self.yv = jnp.asarray(np.concatenate(ys))

    def train_batch(self, step: int, batch: int):
        rng = np.random.RandomState(self.seed + 104729 * step)
        idx = rng.randint(0, self.X.shape[0], batch)
        return self.X[idx], self.y[idx]

    def val_batch(self, step: int, batch: int):
        rng = np.random.RandomState(self.seed + 99991 * step + 1)
        idx = rng.randint(0, self.Xv.shape[0], batch)
        return self.Xv[idx], self.yv[idx]


# ------------------------------------------------------------------ LM corpus
@dataclasses.dataclass
class TokenStream:
    """Domain-mixture synthetic corpus for LM training.

    Each domain is a depth-1 Markov chain over the vocab with its own
    transition sharpness; `noisy_domains` emit uniform tokens (no structure) —
    the bilevel data-reweighting driver should learn to down-weight them.
    """
    vocab_size: int
    seq_len: int
    n_domains: int = 8
    noisy_domains: tuple[int, ...] = (6, 7)
    seed: int = 0

    def __post_init__(self):
        rng = np.random.RandomState(self.seed)
        V = min(self.vocab_size, 512)   # structured sub-vocab
        self._V = V
        self.next_tok = rng.randint(0, V, size=(self.n_domains, V))

    def batch(self, step: int, batch_size: int, clean_only: bool = False):
        """→ {'inputs', 'labels', 'domain', 'mask'} for global step `step`."""
        rng = np.random.RandomState((self.seed + 31337 * step
                                     + (7 if clean_only else 0)) % (2**32 - 1))
        V, S = self._V, self.seq_len
        if clean_only:
            domains = rng.choice([d for d in range(self.n_domains)
                                  if d not in self.noisy_domains], batch_size)
        else:
            domains = rng.randint(0, self.n_domains, batch_size)
        toks = np.empty((batch_size, S + 1), np.int32)
        toks[:, 0] = rng.randint(0, V, batch_size)
        for t in range(S):
            nxt = self.next_tok[domains, toks[:, t]]
            noise = rng.randint(0, V, batch_size)
            flip = rng.rand(batch_size) < 0.1
            nxt = np.where(flip, noise, nxt)
            nxt = np.where(np.isin(domains, self.noisy_domains),
                           rng.randint(0, V, batch_size), nxt)
            toks[:, t + 1] = nxt
        return {'inputs': jnp.asarray(toks[:, :-1]),
                'labels': jnp.asarray(toks[:, 1:]),
                'domain': jnp.asarray(domains),
                'mask': jnp.ones((batch_size, S), jnp.float32)}
