from repro.data.synthetic import (DistillationTask, FewShotSampler,
                                  LongTailDataset, TokenStream,
                                  make_logreg_problem)
from repro.data.loader import ShardedLoader, Prefetcher

__all__ = ['DistillationTask', 'FewShotSampler', 'LongTailDataset',
           'TokenStream', 'make_logreg_problem', 'ShardedLoader', 'Prefetcher']
