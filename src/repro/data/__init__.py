from repro.data.sources import ArraySource, EpisodeSource
from repro.data.synthetic import (DistillationTask, FewShotSampler,
                                  LongTailDataset, TokenStream,
                                  make_logreg_problem)
from repro.data.loader import ShardedLoader, Prefetcher

__all__ = ['ArraySource', 'DistillationTask', 'EpisodeSource',
           'FewShotSampler', 'LongTailDataset', 'TokenStream',
           'make_logreg_problem', 'ShardedLoader', 'Prefetcher']
