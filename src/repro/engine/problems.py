"""Registered multi-level problems + the GRAPHS registry.

Two trilevel chains, both toy-scale by construction (the dense oracle
materializes every solved node's Hessian):

* ``distill_hpo`` — dataset distillation under hyperparameter optimization.
  Bottom: a ridge-regression student trained on the synthetic set with a
  learned weight decay (quadratic in the weights, so the bottom Hessian is
  PSD by construction). Middle: the synthetic inputs+targets, tuned so the
  student fits real training data (plus a proximal regularizer that keeps
  the level strongly convex around its solutions). Top: the log weight
  decay, tuned on a validation split. The classic bilevel distillation
  problem (Wang et al. 2018) with the HPO level stacked on top — the
  smallest graph where a sketch's build HVPs themselves differentiate
  through a lower implicit map.

* ``reweight_maml`` — example reweighting over meta-learning. Bottom:
  per-task adapted parameters (proximal to the meta-init, the iMAML inner
  problem, vmapped over a stacked task axis inside the loss). Middle: the
  meta-initialization, trained on softmax-reweighted per-task query losses
  (one task's queries are label-corrupted). Top: the task logits ω, tuned
  so the meta-init does well on clean held-out queries — learning to
  down-weight the corrupted task.

Both register under ``GRAPHS`` and run via ``launch/train.py --problem``.
Sizes are keyword-tunable; defaults keep every level's parameter count
small enough for ``engine_hypergrad_reference`` (tests solve them
end-to-end against it).

Oracle-parity expectations differ by construction, and deliberately so.
``reweight_maml``'s solved levels are quadratic in their own variables, so
the AID derivative rules are *exact* there (constant Hessians, constant
mixed partials) and full-rank-sketch vs dense-oracle parity is tight
(≲1e-3, damping-dominated). ``distill_hpo``'s middle level is genuinely
non-quadratic (the student's curvature depends on the learned inputs), and
under the AID convention — the rules freeze their linearization point with
``stop_gradient``, so second derivatives drop ∂M/∂θ·θ̇ terms — the upper
level's Hessian *estimator* picks up a small non-symmetric part. Different
solvers resolve a non-symmetric operator differently (Nyström symmetrizes
quadratically through its sketch; the dense oracle factorizes the operator
as extracted), leaving a few-1e-3 solver-dependent discrepancy that no
rank or damping setting removes. Tests pin both regimes.
"""
from __future__ import annotations

from typing import Callable

import jax
import jax.numpy as jnp

from repro.core.hypergrad import HypergradConfig
from repro.engine.graph import ProblemEdge, ProblemGraph, ProblemNode

GRAPHS: dict[str, Callable[..., ProblemGraph]] = {}


def register_graph(name: str):
    """Decorator: register a graph builder under ``name`` (the
    ``launch/train.py --problem`` / ``get_graph`` key)."""
    def wrap(builder):
        GRAPHS[name] = builder
        return builder
    return wrap


def get_graph(name: str, **kwargs) -> ProblemGraph:
    """Build a registered problem graph by name (kwargs go to the builder).
    Raises ``ValueError`` naming the known graphs on a miss."""
    try:
        builder = GRAPHS[name]
    except KeyError:
        raise ValueError(f'unknown graph {name!r}; registered: '
                         f'{sorted(GRAPHS)}') from None
    return builder(**kwargs)


def _mse(pred: jax.Array, targets: jax.Array) -> jax.Array:
    """Half mean squared error over rows, summed across output channels
    (f32 accumulation)."""
    err = pred.astype(jnp.float32) - targets.astype(jnp.float32)
    return 0.5 * jnp.mean(jnp.sum(jnp.square(err), axis=-1))


# ---------------------------------------------------------------------------
# distill_hpo — student <- images <- hpo
# ---------------------------------------------------------------------------
@register_graph('distill_hpo')
def distill_hpo(d: int = 6, n_classes: int = 3, n_syn: int = 8,
                n_train: int = 64, n_val: int = 64, seed: int = 0,
                mu_images: float = 0.5, k_student: int | None = None,
                k_images: int | None = None, rho: float = 1e-4,
                refresh_every: int = 1,
                solver: str = 'nystrom') -> ProblemGraph:
    """Trilevel dataset distillation + weight-decay HPO (see module doc).

    Node sizes: student p = d·C + C, images p = n_syn·(d + C), hpo p = 1.
    ``k_student``/``k_images`` set the per-edge Nyström ranks — the default
    is full rank at these toy sizes, so solver error is damping-dominated
    and the dense-oracle parity test has a tight bar; pass smaller ranks for
    the amortization/accuracy trade-off benches. ``mu_images`` is the middle
    level's proximal weight: it keeps the distillation level strongly convex
    around its solutions (the implicit function theorem needs an invertible
    Hessian at every solved node, and a plain-SGD unroll needs a benign
    landscape to reach one). ``solver='exact'`` swaps both edges to dense
    solves."""
    key = jax.random.PRNGKey(seed)
    k_mu, k_tr, k_val, k_n1, k_n2 = jax.random.split(key, 5)
    mu = 2.0 * jax.random.normal(k_mu, (n_classes, d))

    def sample(k, kn, n):
        y = jax.random.randint(k, (n,), 0, n_classes)
        x = mu[y] + jax.random.normal(kn, (n, d))
        return x, jax.nn.one_hot(y, n_classes)

    x_tr, y_tr = sample(k_tr, k_n1, n_train)
    x_val, y_val = sample(k_val, k_n2, n_val)

    def student_loss(w, ctx, batch):
        del batch
        syn = ctx['images']
        wd = jnp.exp(ctx['hpo']['log_wd'])
        sq = sum(jnp.sum(jnp.square(v.astype(jnp.float32)))
                 for v in jax.tree.leaves(w))
        return _mse(syn['x'] @ w['W'] + w['b'], syn['y']) + 0.5 * wd * sq

    def images_loss(syn, ctx, batch):
        del batch
        w = ctx['student']
        fit = _mse(x_tr @ w['W'] + w['b'], y_tr)
        # per-coordinate proximal pull: μ·I dominates the fit term's small
        # negative curvature, keeping the level strongly convex wherever the
        # unroll linearizes (the Nyström whitening needs PSD curvature)
        prox = 0.5 * mu_images * (jnp.sum(jnp.square(syn['x']))
                                  + jnp.sum(jnp.square(syn['y'])))
        return fit + prox

    def hpo_loss(h, ctx, batch):
        del batch
        w = ctx['student']
        return (_mse(x_val @ w['W'] + w['b'], y_val)
                + 1e-2 * jnp.square(h['log_wd']))

    def init_student(rng):
        return {'W': 0.1 * jax.random.normal(rng, (d, n_classes)),
                'b': jnp.zeros((n_classes,))}

    def init_images(rng):
        kx, ky = jax.random.split(rng)
        # seed targets near a balanced one-hot assignment so the student has
        # signal from step 0
        y0 = jax.nn.one_hot(jnp.arange(n_syn) % n_classes, n_classes)
        return {'x': jax.random.normal(kx, (n_syn, d)),
                'y': y0 + 0.1 * jax.random.normal(ky, (n_syn, n_classes))}

    def init_hpo(rng):
        del rng
        return {'log_wd': jnp.float32(-1.0)}

    def cfg(k):
        if solver == 'exact':
            return HypergradConfig(solver='exact', rho=rho)
        return HypergradConfig(solver=solver, k=k, rho=rho)

    p_student = d * n_classes + n_classes
    p_images = n_syn * (d + n_classes)
    return ProblemGraph(
        nodes={
            'student': ProblemNode('student', student_loss, init_student,
                                   unroll_steps=80, unroll_lr=0.3),
            'images': ProblemNode('images', images_loss, init_images,
                                  unroll_steps=60, unroll_lr=0.3),
            'hpo': ProblemNode('hpo', hpo_loss, init_hpo),
        },
        edges=[
            ProblemEdge('student', 'images',
                        config=cfg(k_student or p_student),
                        refresh_every=refresh_every),
            ProblemEdge('images', 'hpo', config=cfg(k_images or p_images),
                        refresh_every=refresh_every),
        ])


# ---------------------------------------------------------------------------
# reweight_maml — adapted <- meta <- weights
# ---------------------------------------------------------------------------
@register_graph('reweight_maml')
def reweight_maml(d: int = 8, n_tasks: int = 3, n_support: int = 16,
                  n_query: int = 16, prox: float = 1.0, corrupt: float = 2.0,
                  seed: int = 0, k_adapted: int | None = None,
                  k_meta: int | None = None,
                  rho: float = 1e-4, refresh_every: int = 1,
                  solver: str = 'nystrom') -> ProblemGraph:
    """Trilevel task reweighting over proximal meta-learning (see module
    doc). The adapted node stacks all tasks on a leading (T, d) axis and
    vmaps the per-task residuals inside its loss, so the whole meta-batch —
    including every edge's sketch HVPs — runs as one batched program. Task 0
    is label-corrupted with ``corrupt``-scaled noise on its reweighting
    queries; the clean top-level query split is uncorrupted."""
    key = jax.random.PRNGKey(seed)
    ka, ks, kq, kc, kn1, kn2, kn3 = jax.random.split(key, 7)
    a_true = jax.random.normal(ka, (n_tasks, d))
    xs = jax.random.normal(ks, (n_tasks, n_support, d))
    xq = jax.random.normal(kq, (n_tasks, n_query, d))
    xc = jax.random.normal(kc, (n_tasks, n_query, d))
    ys = jnp.einsum('tnd,td->tn', xs, a_true) \
        + 0.1 * jax.random.normal(kn1, (n_tasks, n_support))
    yq = jnp.einsum('tnd,td->tn', xq, a_true) \
        + 0.1 * jax.random.normal(kn2, (n_tasks, n_query))
    # the reweighting-level queries: task 0 corrupted
    yq = yq.at[0].add(corrupt * jax.random.normal(kn3, (n_query,)))
    yclean = jnp.einsum('tnd,td->tn', xc, a_true)

    def task_mse(a, x, y):
        return 0.5 * jnp.mean(jnp.square(x @ a - y))

    def adapted_loss(a, ctx, batch):
        del batch
        theta0 = ctx['meta']['theta0']
        fit = jax.vmap(task_mse)(a['a'], xs, ys)
        prox_term = 0.5 * prox * jnp.mean(
            jnp.sum(jnp.square(a['a'] - theta0[None, :]), axis=-1))
        return jnp.sum(fit) / n_tasks + prox_term

    def meta_loss(m, ctx, batch):
        del batch
        a = ctx['adapted']['a']
        w = jax.nn.softmax(ctx['weights']['omega'])
        q = jax.vmap(task_mse)(a, xq, yq)
        return jnp.sum(w * q) + 5e-2 * jnp.sum(jnp.square(m['theta0']))

    def weights_loss(o, ctx, batch):
        del batch
        a = ctx['adapted']['a']
        clean = jnp.mean(jax.vmap(task_mse)(a, xc, yclean))
        return clean + 5e-2 * jnp.sum(jnp.square(o['omega']))

    def init_adapted(rng):
        return {'a': 0.1 * jax.random.normal(rng, (n_tasks, d))}

    def init_meta(rng):
        return {'theta0': 0.1 * jax.random.normal(rng, (d,))}

    def init_weights(rng):
        del rng
        return {'omega': jnp.zeros((n_tasks,))}

    def cfg(k):
        if solver == 'exact':
            return HypergradConfig(solver='exact', rho=rho)
        return HypergradConfig(solver=solver, k=k, rho=rho)

    return ProblemGraph(
        nodes={
            'adapted': ProblemNode('adapted', adapted_loss, init_adapted,
                                   unroll_steps=40, unroll_lr=0.5),
            'meta': ProblemNode('meta', meta_loss, init_meta,
                                unroll_steps=40, unroll_lr=0.3),
            'weights': ProblemNode('weights', weights_loss, init_weights),
        },
        edges=[
            ProblemEdge('adapted', 'meta',
                        config=cfg(k_adapted or n_tasks * d),
                        refresh_every=refresh_every),
            ProblemEdge('meta', 'weights', config=cfg(k_meta or d),
                        refresh_every=refresh_every),
        ])
