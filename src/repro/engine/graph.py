"""Problem dependency graphs — multi-level optimization as a typed DAG.

A :class:`ProblemNode` is one optimization variable with one scalar
objective; a :class:`ProblemEdge` declares that its ``lower`` node is solved
to stationarity and differentiated through — with the edge's *own* IHVP
solver and sketch cadence — whenever an ``upper`` node's objective is
differentiated. A :class:`ProblemGraph` collects both and validates the
shape (no dangling names, no cycles, one solver per solved node) before
:class:`~repro.engine.engine.Engine` lowers it to a single jit-compiled
program.

This is the repo's answer to ROADMAP item 3 (Betty-style multi-level
engine): where Betty runs a Python loop of ``.step()`` calls between
problems, here the whole inner-to-outer sweep is staged through nested
``implicit_root`` maps — one program, vmappable task axes included —
because ``implicit_root`` now carries both a jvp and (by transposition) a
vjp rule, so an interior node can be differentiated from above (reverse,
for the outer update) and from below (forward, inside the HVPs of the
level above it) at once.

The bilevel special case stays a two-node graph::

    graph = from_bilevel(get_problem('logreg_wd'))
    # nodes: {'params', 'hparams'}; one edge params -> hparams

Losses follow the graph-wide signature ``loss(own, ctx, batch)`` where
``ctx`` maps *other* node names to their current values — solved values for
nodes below, live variables for nodes above.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Mapping

import jax

from repro.core.tree_util import PyTree

NodeLoss = Callable[[PyTree, Mapping[str, PyTree], Any], jax.Array]


class GraphError(ValueError):
    """A malformed problem graph (cycle, dangling edge, duplicate solver)."""


@dataclasses.dataclass(frozen=True)
class ProblemNode:
    """One optimization variable + objective in a multi-level graph.

    ``loss(own, ctx, batch)`` — ``own`` is this node's variable, ``ctx``
    maps every other node name in scope to its value. ``init(rng)`` builds
    the variable. ``unroll_steps``/``unroll_lr`` configure the plain-SGD
    inner unroll used when this node is solved implicitly (the forward pass
    of its ``implicit_root`` map; never differentiated through).
    ``data`` is an optional :class:`~repro.core.problem.BatchSource`;
    ``batch_size`` its per-step draw (0 = whole-data, batch is None).
    """
    name: str
    loss: NodeLoss
    init: Callable[[jax.Array], PyTree]
    data: Any = None
    unroll_steps: int = 20
    unroll_lr: float = 0.1
    batch_size: int = 0


@dataclasses.dataclass(frozen=True)
class ProblemEdge:
    """``lower`` is implicitly solved and differentiated through toward
    ``upper``. ``config`` is the edge's IHVP solver — a
    :class:`~repro.core.hypergrad.HypergradConfig` (its ``solver`` field
    names a ``SOLVERS`` entry), a built solver instance, or None for the
    default Nyström configuration. ``refresh_every`` is the edge's sketch
    cadence under engine-managed amortization (ignored for iterative
    solvers, whose state is trace-local)."""
    lower: str
    upper: str
    config: Any = None
    refresh_every: int = 1


@dataclasses.dataclass
class ProblemGraph:
    """Nodes + typed edges; validated before lowering.

    ``validate`` raises :class:`GraphError` naming the offender for:
    dangling edge endpoints, self-loops, more than one edge solving the
    same ``lower`` node toward different uppers is allowed only as multiple
    uppers reading one solved node — but each solved node has exactly ONE
    solver, so duplicate ``lower`` entries are rejected; cycles in the
    lower→upper direction; and graphs with no top (every node solved).
    """
    nodes: dict[str, ProblemNode]
    edges: list[ProblemEdge]

    # ------------------------------------------------------------ checks
    def validate(self) -> None:
        for name, node in self.nodes.items():
            if node.name != name:
                raise GraphError(
                    f'node key {name!r} disagrees with node.name '
                    f'{node.name!r}')
        if not self.edges:
            raise GraphError('graph has no edges — nothing to solve '
                             'implicitly; use solve() for single problems')
        seen_lower: set[str] = set()
        for e in self.edges:
            for end in (e.lower, e.upper):
                if end not in self.nodes:
                    raise GraphError(
                        f'edge {e.lower!r}->{e.upper!r} references unknown '
                        f'node {end!r}; known: {sorted(self.nodes)}')
            if e.lower == e.upper:
                raise GraphError(f'self-loop on node {e.lower!r}')
            if e.lower in seen_lower:
                raise GraphError(
                    f'node {e.lower!r} is the lower end of two edges — a '
                    'solved node carries exactly one IHVP solver')
            seen_lower.add(e.lower)
        order = self.topo_order()          # raises GraphError on cycles
        if set(order[-1:]) & seen_lower and len(self.tops()) == 0:
            raise GraphError('graph has no top node — every node is solved; '
                             'at least one node must own the outer objective')

    def tops(self) -> list[str]:
        """Nodes never implicitly solved (own the outer objective)."""
        lowers = {e.lower for e in self.edges}
        return [n for n in self.nodes if n not in lowers]

    def edge_for(self, lower: str) -> ProblemEdge:
        for e in self.edges:
            if e.lower == lower:
                return e
        raise GraphError(f'no edge solves node {lower!r}')

    def topo_order(self) -> list[str]:
        """Inner-to-outer topological order over lower→upper edges
        (Kahn's algorithm; deterministic by insertion order). Raises
        :class:`GraphError` on a cycle, naming the strongly-tangled nodes."""
        indeg = {n: 0 for n in self.nodes}
        out: dict[str, list[str]] = {n: [] for n in self.nodes}
        for e in self.edges:
            indeg[e.upper] += 1
            out[e.lower].append(e.upper)
        ready = [n for n in self.nodes if indeg[n] == 0]
        order: list[str] = []
        while ready:
            n = ready.pop(0)
            order.append(n)
            for m in out[n]:
                indeg[m] -= 1
                if indeg[m] == 0:
                    ready.append(m)
        if len(order) != len(self.nodes):
            cyc = sorted(n for n in self.nodes if n not in order)
            raise GraphError(f'cycle through nodes {cyc} — the lower->upper '
                             'relation must be a DAG')
        return order

    def chain_order(self) -> list[str]:
        """The topological order, additionally checked to be a single chain
        (exactly one node per level, consecutive levels linked) — the shape
        ``Engine.solve`` currently lowers. General DAGs validate but need
        the chain restriction lifted to solve."""
        order = self.topo_order()
        lowers = {e.lower: e.upper for e in self.edges}
        for a, b in zip(order[:-1], order[1:]):
            if lowers.get(a) != b:
                raise GraphError(
                    f'graph is not a chain: expected an edge {a!r}->{b!r} '
                    f'in topological order {order}; Engine.solve currently '
                    'lowers chains only (general DAGs validate but are not '
                    'yet solvable)')
        return order


def from_bilevel(problem, config: Any = None,
                 unroll_steps: int = 20, unroll_lr: float = 0.1,
                 refresh_every: int = 1) -> ProblemGraph:
    """Wrap a registered :class:`~repro.core.problem.BilevelProblem` as a
    two-node graph (``params`` solved toward ``hparams``) — the adapter that
    makes every existing problem a degenerate multi-level graph, and the
    parity fixture for Engine-vs-``solve()`` tests."""
    inner = ProblemNode(
        name='params',
        loss=lambda own, ctx, batch: problem.inner_loss(
            own, ctx['hparams'], batch),
        init=problem.init_params,
        unroll_steps=unroll_steps, unroll_lr=unroll_lr)
    outer = ProblemNode(
        name='hparams',
        loss=lambda own, ctx, batch: problem.outer_loss(
            ctx['params'], own, batch),
        init=problem.init_hparams)
    return ProblemGraph(
        nodes={'params': inner, 'hparams': outer},
        edges=[ProblemEdge(lower='params', upper='hparams', config=config,
                           refresh_every=refresh_every)])
