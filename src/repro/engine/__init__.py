"""repro.engine — multi-level optimization over a problem dependency graph.

Public API:
  ProblemNode / ProblemEdge / ProblemGraph  — typed DAG of optimization
                                              problems (validate / topo_order)
  from_bilevel                              — wrap a BilevelProblem as a graph
  Engine / EngineConfig / EngineResult      — lower a chain to one jitted
                                              program and drive it
  engine_hypergrad / _reference             — top hypergradient at a point +
                                              dense multi-level oracle
  engine_edge_bills                         — analytic per-edge HVP bills
  GRAPHS / register_graph / get_graph       — registered trilevel problems
                                              (distill_hpo, reweight_maml)
"""
from repro.engine.engine import (Engine, EngineConfig, EngineProgram,
                                 EngineResult, build_maps, engine_edge_bills,
                                 engine_hypergrad,
                                 engine_hypergrad_reference)
from repro.engine.graph import (GraphError, ProblemEdge, ProblemGraph,
                                ProblemNode, from_bilevel)
from repro.engine.problems import (GRAPHS, distill_hpo, get_graph,
                                   register_graph, reweight_maml)

__all__ = [
    'Engine', 'EngineConfig', 'EngineProgram', 'EngineResult',
    'GRAPHS', 'GraphError', 'ProblemEdge', 'ProblemGraph', 'ProblemNode',
    'build_maps', 'distill_hpo', 'engine_edge_bills', 'engine_hypergrad',
    'engine_hypergrad_reference', 'from_bilevel', 'get_graph',
    'register_graph', 'reweight_maml',
]
