"""Engine — lower a ProblemGraph to one jit-compiled multi-level program.

``Engine.solve(graph, config)`` runs the whole inner-to-outer sweep of a
validated chain graph as a single jitted step called ``n_outer`` times:

* every solved node becomes a nested ``implicit_root`` map, built bottom-up
  so a level's inner loss *contains* the solution maps of every level below
  it — an HVP of that loss is jvp-of-grad through the lower maps, which is
  exactly what the forward-mode rule of ``implicit_root`` enables;
* every edge carries its own IHVP solver (a ``SOLVERS`` entry via
  ``HypergradConfig``) and, when amortizable, its own
  :class:`~repro.core.solvers.SketchPolicy` cadence — sketches are carried
  across outer steps in the jitted carry and refreshed inner-to-outer, so a
  lower edge's fresh sketch is already live when the edge above it rebuilds
  (whose build HVPs differentiate through the lower map);
* warm starts are carried per node: each step's unrolls start from the
  previous step's solved values, the same alternating convention as
  ``BilevelTrainer``.

Engine-internal plumbing (warm starts, carried sketches, per-edge rng, data
batches) rides in the ``batch`` slot of ``implicit_root``, which receives
zero tangents/cotangents by contract — gradients flow only through the
node-value arguments, never through the plumbing.

The dense oracle (:func:`engine_hypergrad_reference`) rebuilds the *same*
nested maps with exact ρ=0 IHVPs on every edge, so
``hypergrad_error(engine_hypergrad(...), engine_hypergrad_reference(...))``
isolates solver error: both run an identical primal sweep from identical
warm starts and differ only in the per-edge linear solves.
"""
from __future__ import annotations

import dataclasses
import math
import time
from typing import Any, Callable, Mapping

import jax
import jax.numpy as jnp

from repro.core.hypergrad import HypergradConfig
from repro.core.implicit import implicit_root
from repro.core.solvers import (ExactIHVP, SketchPolicy, SketchState,
                                build_hvp_bill)
from repro.core.tree_util import PyTree, tree_size
from repro.engine.graph import ProblemGraph
from repro.optim import (Optimizer, adam, chain, clip_by_global_norm,
                         momentum, sgd)

# ---------------------------------------------------------------------------
# Config / result
# ---------------------------------------------------------------------------
_OUTER_OPTS = {
    'adam': lambda lr: chain(clip_by_global_norm(10.0), adam(lr)),
    'momentum': lambda lr: chain(clip_by_global_norm(10.0), momentum(lr)),
    'sgd': lambda lr: sgd(lr),
}


@dataclasses.dataclass(frozen=True)
class EngineConfig:
    """Drive parameters for ``Engine.solve``.

    ``amortize=True`` carries a :class:`SketchState` per amortizable edge in
    the jitted carry (each edge's ``refresh_every`` cadence applies);
    ``False`` prepares every edge's state fresh inside each derivative pass
    — the Grazzi-style per-step baseline the bench contrasts against.
    ``outer_opt`` is an ``_OUTER_OPTS`` name or a built
    :class:`repro.optim.Optimizer`."""
    n_outer: int = 10
    outer_lr: float = 1e-2
    outer_opt: Any = 'adam'
    amortize: bool = True
    seed: int = 0
    jit: bool = True

    def build_outer_opt(self) -> Optimizer:
        if isinstance(self.outer_opt, Optimizer):
            return self.outer_opt
        try:
            return _OUTER_OPTS[self.outer_opt](self.outer_lr)
        except KeyError:
            raise ValueError(
                f'unknown outer_opt {self.outer_opt!r}; expected one of '
                f'{sorted(_OUTER_OPTS)} or an Optimizer instance') from None


@dataclasses.dataclass
class EngineResult:
    """Outcome of ``Engine.solve``: final node values, the top objective per
    outer step, and the analytic per-edge HVP bills
    (:func:`engine_edge_bills` at the run's settings — the jitted step hides
    runtime counters, so bills are computed, not measured, exactly as
    ``BilevelResult.hvp_count``)."""
    values: dict[str, PyTree]
    losses: list[float]
    edge_hvps: dict[str, int]
    hvp_count: int
    n_outer: int
    seconds: float
    hypergrad_err: float | None = None


# ---------------------------------------------------------------------------
# Map construction — nested implicit_root, bottom-up
# ---------------------------------------------------------------------------
def _edge_solver(edge):
    cfg = HypergradConfig() if edge.config is None else edge.config
    return cfg.build() if isinstance(cfg, HypergradConfig) else cfg


def _level_loss(graph: ProblemGraph, order: list[str], i: int,
                maps: dict[str, Callable]) -> Callable:
    """The inner loss of level ``i`` in graph-resolved form:
    ``f_i(theta, phi, pack)`` where ``phi`` maps every node strictly above
    level i to its value. Nodes below are resolved top-down through their
    solution maps (already in ``maps`` — construction is bottom-up), so
    differentiating this loss differentiates through every lower level."""
    name = order[i]
    node = graph.nodes[name]

    def inner_loss(theta: PyTree, phi: Mapping[str, PyTree],
                   pack: dict) -> jax.Array:
        ctx = dict(phi)
        ctx[name] = theta
        for j in range(i - 1, -1, -1):
            below = order[j]
            phi_j = {m: ctx[m] for m in order[j + 1:]}
            ctx[below] = maps[below](phi_j, pack)
        own = ctx.pop(name)
        return node.loss(own, ctx, pack['batches'].get(name))

    return inner_loss


def _unroll_solver(node, inner_loss: Callable, name: str) -> Callable:
    """The forward pass of a node's solution map: ``unroll_steps`` plain-SGD
    steps on the level loss from the engine-carried warm start. Matches
    ``sgd_solver`` but draws θ0 from the pack (per-node warm start)."""
    def solver_fn(phi, pack):
        theta0 = pack['warm'][name]

        def step(p, _):
            g = jax.grad(inner_loss)(p, phi, pack)
            return jax.tree.map(
                lambda w, gw: w - node.unroll_lr * gw, p, g), None

        theta, _ = jax.lax.scan(step, theta0, None, length=node.unroll_steps)
        return theta

    return solver_fn


def build_maps(graph: ProblemGraph, order: list[str],
               solvers: Mapping[str, Any] | None = None
               ) -> tuple[dict[str, Callable], dict[str, Callable]]:
    """Build the nested solution maps for a chain, bottom-up.

    Returns ``(maps, losses)``: ``maps[name](phi, pack) -> theta*`` for every
    solved node (``phi`` = values of all nodes strictly above it, ``pack`` =
    engine plumbing riding the zero-tangent batch slot), and
    ``losses[name]`` the graph-resolved level losses (what each edge's
    :class:`SketchPolicy` builds sketches of). ``solvers`` overrides the
    per-edge solver (name → built instance); defaults to each edge's own
    config — the override is how the dense oracle swaps every edge to
    ``ExactIHVP(rho=0)`` without touching the graph."""
    maps: dict[str, Callable] = {}
    losses: dict[str, Callable] = {}
    for i, name in enumerate(order[:-1]):
        node = graph.nodes[name]
        solver = (solvers[name] if solvers is not None
                  else _edge_solver(graph.edge_for(name)))
        inner_loss = _level_loss(graph, order, i, maps)
        root = implicit_root(_unroll_solver(node, inner_loss, name),
                             inner_loss, solver)

        def mapped(phi, pack, _name=name, _root=root):
            return _root(phi, pack, rng=pack['rngs'][_name],
                         state=pack['states'][_name])

        maps[name] = mapped
        losses[name] = inner_loss
    return maps, losses


def _top_objective(graph: ProblemGraph, order: list[str],
                   maps: Mapping[str, Callable]) -> Callable:
    """``(theta_top, pack) -> (loss, solved)``: the outer objective with the
    full chain resolved below it; ``solved`` (the aux) carries every solved
    node's value out for the warm-start carry."""
    top = order[-1]

    def objective(theta_top: PyTree, pack: dict):
        ctx = {top: theta_top}
        for j in range(len(order) - 2, -1, -1):
            below = order[j]
            phi_j = {m: ctx[m] for m in order[j + 1:]}
            ctx[below] = maps[below](phi_j, pack)
        own = ctx.pop(top)
        return graph.nodes[top].loss(own, ctx, pack['batches'].get(top)), ctx

    return objective


# ---------------------------------------------------------------------------
# Engine
# ---------------------------------------------------------------------------
@dataclasses.dataclass
class EngineProgram:
    """The lowered form of a graph: ``init(key) -> carry`` and
    ``step(carry, key) -> (carry, loss)`` — ``step`` is the single function
    ``Engine.solve`` jits (the whole multi-level sweep, sketch refreshes
    included, is inside it), which is what contract tests pin with
    ``assert_compiles(times=1)`` and lint with ``audit``."""
    init: Callable[[jax.Array], tuple]
    step: Callable[[tuple, jax.Array], tuple]
    order: list[str]


class Engine:
    """Lowers a :class:`ProblemGraph` chain and drives it.

    ``lower`` builds the jit-able program (exposed for contract tests);
    ``solve`` runs it. One Engine instance is stateless and reusable."""

    def lower(self, graph: ProblemGraph,
              config: EngineConfig | None = None) -> EngineProgram:
        config = config or EngineConfig()
        graph.validate()
        order = graph.chain_order()
        solved = order[:-1]
        top = order[-1]
        solvers = {n: _edge_solver(graph.edge_for(n)) for n in solved}
        maps, losses = build_maps(graph, order, solvers)
        objective = _top_objective(graph, order, maps)
        outer_opt = config.build_outer_opt()

        policies = {
            n: SketchPolicy(solver=solvers[n], inner_loss=losses[n],
                            refresh_every=graph.edge_for(n).refresh_every)
            for n in solved
            if config.amortize and getattr(type(solvers[n]), 'amortizable',
                                           False)}

        def _pack(values, sketches, keys):
            return {
                'warm': {n: values[n] for n in solved},
                'states': {n: sketches.get(n) for n in solved},
                'rngs': dict(keys),
                'batches': {},          # v1: whole-data losses (batch=None)
            }

        def init(key: jax.Array) -> tuple:
            ks = jax.random.split(key, len(order))
            values = {n: graph.nodes[n].init(k)
                      for n, k in zip(order, ks)}
            keys = {n: jax.random.fold_in(key, idx)
                    for idx, n in enumerate(solved)}
            pack = _pack(values, {}, keys)
            # stale zero states: the first step's refresh rebuilds them, so
            # initialization costs no HVPs and cadence is uniform from step 0
            sk = {n: policies[n].init_state(
                      values[n], {m: values[m] for m in order[order.index(n) + 1:]},
                      pack, keys[n])
                  for n in policies}
            return (values, outer_opt.init(values[top]), sk, jnp.int32(0))

        def step(carry: tuple, key: jax.Array) -> tuple:
            values, opt_state, sk, t = carry
            keys = {n: jax.random.fold_in(key, idx)
                    for idx, n in enumerate(solved)}

            # 1. linearize + refresh, interleaved inner-to-outer. A level's
            #    lin unroll *differentiates* every edge below it (its level
            #    loss contains the lower maps), and an edge's build HVPs do
            #    too — so each edge must see this step's fresh lower
            #    sketches before it is itself unrolled or rebuilt. On
            #    non-refresh steps (cadence > 1) the carried sketch serves,
            #    which is the amortization trade-off.
            new_sk: dict[str, SketchState] = {}
            live = {m: (sk[m].sketch if m in sk else None) for m in solved}
            lin: dict[str, PyTree] = {}
            for j, n in enumerate(solved):
                pack_j = _pack(values, live, keys)
                phi_j = {m: values[m] for m in order[j + 1:]}
                lin[n] = maps[n](phi_j, pack_j)
                if n in policies:
                    new_sk[n], _ = policies[n].refresh(
                        sk[n], lin[n], phi_j, pack_j, keys[n])
                    live[n] = new_sk[n].sketch

            # 2. outer gradient with every edge's live state, then the
            #    outer-optimizer update; solved values (the aux) become the
            #    next step's warm starts
            pack = _pack(values, live, keys)
            (loss, solved_vals), g = jax.value_and_grad(
                objective, has_aux=True)(values[top], pack)
            new_top, opt_state = outer_opt.apply(g, opt_state, values[top], t)
            new_values = {**solved_vals, top: new_top}
            return (new_values, opt_state, new_sk, t + 1), loss

        return EngineProgram(init=init, step=step, order=order)

    def solve(self, graph: ProblemGraph,
              config: EngineConfig | None = None) -> EngineResult:
        """Run the lowered program for ``config.n_outer`` outer steps.

        The step compiles exactly once (same carry structure every call —
        pinned by tests/test_engine.py with ``assert_compiles(times=1)``);
        the Python loop only feeds fresh fold-in keys."""
        config = config or EngineConfig()
        program = self.lower(graph, config)
        key = jax.random.PRNGKey(config.seed)
        carry = program.init(key)
        step = jax.jit(program.step) if config.jit else program.step
        losses: list[float] = []
        t0 = time.perf_counter()
        for i in range(config.n_outer):
            carry, loss = step(carry, jax.random.fold_in(key, 1 + i))
            losses.append(float(loss))
        seconds = time.perf_counter() - t0
        bills = engine_edge_bills(graph, n_outer=config.n_outer,
                                  amortize=config.amortize)
        return EngineResult(values=carry[0], losses=losses, edge_hvps=bills,
                            hvp_count=sum(bills.values()),
                            n_outer=config.n_outer, seconds=seconds)


# ---------------------------------------------------------------------------
# Oracle + accounting
# ---------------------------------------------------------------------------
def engine_hypergrad(graph: ProblemGraph, values: Mapping[str, PyTree],
                     solvers: Mapping[str, Any] | None = None,
                     rng: jax.Array | None = None
                     ) -> tuple[PyTree, jax.Array]:
    """One top-level hypergradient at explicit node ``values``.

    Rebuilds the nested maps (per-edge ``solvers`` override, else the
    graph's own edge configs), warm-starts every unroll from ``values``, and
    differentiates the top objective — the multi-level analogue of
    :func:`repro.core.problem.hypergrad_at`, and the measurement primitive
    behind ``benchmarks/bench_engine.py``'s error column. States are
    prepared fresh inside the derivative pass (no amortization) so the
    result depends only on (graph, values, solvers, rng). Returns
    ``(grad, loss)``."""
    if rng is None:
        rng = jax.random.PRNGKey(0)
    graph.validate()
    order = graph.chain_order()
    solved = order[:-1]
    built = {n: (solvers[n] if solvers is not None
                 else _edge_solver(graph.edge_for(n))) for n in solved}
    maps, _ = build_maps(graph, order, built)
    objective = _top_objective(graph, order, maps)
    pack = {
        'warm': {n: values[n] for n in solved},
        'states': {n: None for n in solved},
        'rngs': {n: jax.random.fold_in(rng, i)
                 for i, n in enumerate(solved)},
        'batches': {},
    }
    (loss, _), g = jax.value_and_grad(objective, has_aux=True)(
        values[order[-1]], pack)
    return g, loss


def engine_hypergrad_reference(graph: ProblemGraph,
                               values: Mapping[str, PyTree],
                               rho: float = 0.0) -> tuple[PyTree, jax.Array]:
    """Dense-oracle top hypergradient: the same nested sweep with every edge
    solved by ``ExactIHVP(rho)`` (full column scan + dense factorization per
    edge). ``rho=0`` is the true multi-level implicit gradient; pass an
    edge's damping to isolate sketch error from damping bias. Toy sizes
    only."""
    order = graph.chain_order()
    oracle = {n: ExactIHVP(rho=rho) for n in order[:-1]}
    return engine_hypergrad(graph, values, solvers=oracle)


def _per_build(graph: ProblemGraph, name: str, solver) -> int:
    """HVPs one state build costs on edge ``name``. Delegates to
    :func:`repro.core.solvers.build_hvp_bill` — the same bill definition
    ``influence()`` and the store's per-entry accounting use, so a k-HVP
    build means the same k on every accounting surface."""
    shapes = jax.eval_shape(graph.nodes[name].init, jax.random.PRNGKey(0))
    return build_hvp_bill(solver, shapes)


def engine_edge_bills(graph: ProblemGraph, n_outer: int,
                      amortize: bool = True) -> dict[str, int]:
    """Analytic per-edge HVP bills for ``n_outer`` engine steps.

    The multi-level extension of :func:`repro.core.problem.accounted_hvps`,
    and the arithmetic behind the engine bench's amortization contrast:

    * **amortized** (default): each amortizable edge pays per *build* —
      ``ceil(n_outer / refresh_every) × k`` — and builds stack *additively*
      across levels, because a lower edge's live sketch makes its derivative
      rule free of prepare HVPs no matter how many times an upper build
      differentiates through it.
    * **fresh** (``amortize=False``): every derivative pass through an edge
      re-prepares, and passes *multiply* down the chain — an upper edge's
      k-probe prepare differentiates the lower map k+1 times, each spawning
      a full lower prepare. The model counts derivative-rule invocations per
      outer step by the recursion below (primal unrolls of a level also
      differentiate every lower map once per SGD step).

    Iterative edges (CG/Neumann) pay ``iters`` sequential HVPs per rule
    invocation in either mode — their state is trace-local, so nesting
    multiplies them regardless. This is a rule-invocation cost model: exact
    for amortized sketch edges, and the same counting convention as the
    paper's cost tables elsewhere.
    """
    order = graph.chain_order()
    solved = order[:-1]
    solvers = {n: _edge_solver(graph.edge_for(n)) for n in solved}
    amortizable = {n: getattr(type(solvers[n]), 'amortizable', False)
                   for n in solved}

    # rule invocations (druns) and primal map evaluations (evals) per outer
    # step, propagated outer -> inner so spawned work cascades down the chain
    evals = {n: 1 for n in solved}   # the top objective resolves every map
    druns = {n: 1 for n in solved}   # ... and the top grad differentiates it
    for i in range(len(solved) - 1, 0, -1):
        n = solved[i]
        spawned = evals[n] * graph.nodes[n].unroll_steps
        if amortizable[n] and amortize:
            deriv_passes = druns[n]              # mixed term only; no probes
        elif amortizable[n]:
            deriv_passes = druns[n] * (_per_build(graph, n, solvers[n]) + 1)
        else:
            deriv_passes = druns[n] * (getattr(solvers[n], 'iters', 0) + 1)
        for m in solved[:i]:
            evals[m] += spawned + deriv_passes
            druns[m] += spawned + deriv_passes

    bills: dict[str, int] = {}
    for n in solved:
        if amortizable[n] and amortize:
            builds = math.ceil(n_outer
                               / max(1, graph.edge_for(n).refresh_every))
            bills[n] = builds * _per_build(graph, n, solvers[n])
        elif amortizable[n]:
            bills[n] = n_outer * druns[n] * _per_build(graph, n, solvers[n])
        else:
            bills[n] = n_outer * druns[n] * getattr(solvers[n], 'iters', 0)
    return bills
