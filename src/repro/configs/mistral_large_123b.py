"""Mistral-Large-2407 123B: dense GQA. [hf:mistralai/Mistral-Large-Instruct-2407; unverified]"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name='mistral-large-123b', family='dense',
    n_layers=88, d_model=12288, n_heads=96, n_kv_heads=8,
    d_ff=28672, vocab_size=32768, head_dim=128,
    rope_theta=1_000_000.0,
    # §Perf: bf16 master params at 100B+ (Adafactor's factored state
    # keeps the update math f32; halves FSDP-gather + grad-reduce bytes)
    param_dtype='bfloat16',
)
