"""SeamlessM4T-large-v2: enc-dec, multimodal (audio frontend stubbed).
[arXiv:2308.11596; hf]

24 encoder + 24 decoder layers, d=1024, 16 heads (kv=16 ⇒ MHA), hd=64.
Decode shapes: decoder self-cache of seq_len, cross-attention to
cfg.cross_len=4096 precomputed encoder states.
"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name='seamless-m4t-large-v2', family='audio',
    n_layers=24, d_model=1024, n_heads=16, n_kv_heads=16,
    d_ff=8192, vocab_size=256206, head_dim=64,
    rope_theta=10_000.0,
    n_enc_layers=24, cross_len=4096,
    embed_inputs=False,
)
