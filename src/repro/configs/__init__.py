"""Architecture registry: ``get_config(arch_id)`` + shape suites.

Every assigned architecture is a selectable config (``--arch <id>``); each is
paired with the LM shape suite from the assignment. ``long_500k`` is only
*runnable* for sub-quadratic families (jamba, rwkv6) — the skip list is
derived from the config and recorded in EXPERIMENTS.md.
"""
from __future__ import annotations

import dataclasses
import importlib

from repro.models.config import ModelConfig

ARCHS = [
    'llama3_405b', 'mistral_large_123b', 'yi_9b', 'qwen2_7b', 'qwen2_vl_7b',
    'llama4_maverick_400b_a17b', 'phi35_moe_42b_a66b', 'seamless_m4t_large_v2',
    'jamba_v01_52b', 'rwkv6_1b6',
]

# canonical external ids (hyphenated) → module names
ALIASES = {a.replace('_', '-'): a for a in ARCHS}


@dataclasses.dataclass(frozen=True)
class ShapeSpec:
    name: str
    kind: str            # 'train' | 'prefill' | 'decode'
    seq_len: int
    global_batch: int


SHAPES = [
    ShapeSpec('train_4k', 'train', 4_096, 256),
    ShapeSpec('prefill_32k', 'prefill', 32_768, 32),
    ShapeSpec('decode_32k', 'decode', 32_768, 128),
    ShapeSpec('long_500k', 'decode', 524_288, 1),
]


def get_config(arch: str) -> ModelConfig:
    name = ALIASES.get(arch, arch)
    if name not in ARCHS:
        raise KeyError(f'unknown arch {arch!r}; known: {sorted(ALIASES)}')
    mod = importlib.import_module(f'repro.configs.{name}')
    return mod.CONFIG


def shape_applicable(cfg: ModelConfig, shape: ShapeSpec) -> tuple[bool, str]:
    """(runs?, reason-if-skipped) per the assignment's skip rules."""
    if shape.name == 'long_500k' and not cfg.subquadratic:
        return False, 'pure full-attention arch: 500k decode is excluded per spec'
    return True, ''


def all_cells():
    """All 40 (arch × shape) cells with applicability flags."""
    out = []
    for a in ARCHS:
        cfg = get_config(a)
        for s in SHAPES:
            ok, why = shape_applicable(cfg, s)
            out.append((a, s, ok, why))
    return out
