"""Jamba-v0.1 52B: Mamba+attention 1:7 interleave, MoE 16e top-2 every other
layer. [arXiv:2403.19887; hf]

Block period = lcm(attn_every=8, moe_every=2) = 8: one attention layer (at
offset 4, matching the released config) + 7 Mamba layers per period, MoE FFN
on odd slots.
"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name='jamba-v0.1-52b', family='hybrid',
    n_layers=32, d_model=4096, n_heads=32, n_kv_heads=8,
    d_ff=14336, vocab_size=65536, head_dim=128,
    rope_theta=10_000.0,
    n_experts=16, top_k=2, moe_every=2,
    attn_every=8, attn_offset=4, ssm_kind='mamba',
    d_state=16, d_conv=4, expand=2,
)
