"""Qwen2-VL-7B backbone: M-RoPE, dynamic resolution. [arXiv:2409.12191; hf]

Backbone only per the assignment: the vision frontend is a stub —
input_specs() feeds precomputed patch embeddings plus (t,h,w) position ids.
"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name='qwen2-vl-7b', family='vlm',
    n_layers=28, d_model=3584, n_heads=28, n_kv_heads=4,
    d_ff=18944, vocab_size=152064, head_dim=128,
    qkv_bias=True, rope_theta=1_000_000.0,
    mrope=True, mrope_sections=(16, 24, 24),
    embed_inputs=False,
)
