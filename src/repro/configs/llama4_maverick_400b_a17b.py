"""Llama-4 Maverick 400B-A17B: MoE 128e top-1 + shared expert, early fusion.
[hf:meta-llama/Llama-4-Scout-17B-16E; unverified]

MoE on every other layer with a shared expert reproduces the published
400B-total / 17B-active split (DESIGN.md): 24 MoE layers × 128 experts ×
3·d·d_ff ≈ 386B routed + ~14B dense/attn; active = top-1 + shared + dense.
Early fusion refers to the multimodal frontend, which is outside the assigned
backbone scope.
"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name='llama4-maverick-400b-a17b', family='moe',
    n_layers=48, d_model=5120, n_heads=40, n_kv_heads=8,
    d_ff=8192, vocab_size=202048, head_dim=128,
    rope_theta=500_000.0,
    n_experts=128, top_k=1, moe_every=2, shared_expert=True,
    # §Perf: bf16 master params at 100B+ (Adafactor's factored state
    # keeps the update math f32; halves FSDP-gather + grad-reduce bytes)
    param_dtype='bfloat16',
)
