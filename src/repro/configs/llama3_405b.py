"""Llama-3.1 405B: dense GQA, 128k vocab. [arXiv:2407.21783; unverified]"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name='llama3-405b', family='dense',
    n_layers=126, d_model=16384, n_heads=128, n_kv_heads=8,
    d_ff=53248, vocab_size=128256, head_dim=128,
    rope_theta=500_000.0,
    # §Perf: bf16 master params at 100B+ (Adafactor's factored state
    # keeps the update math f32; halves FSDP-gather + grad-reduce bytes)
    param_dtype='bfloat16',
)
