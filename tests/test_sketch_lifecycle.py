"""Sketch lifecycle tests: SketchPolicy build/refresh/invalidate, the
policy-driven ``BilevelTrainer.run`` cadence, shared-sketch meta-batches,
and the batch-alignment / config-strictness bugfixes that ride along.

The analytic quadratic bilevel problem (same as test_implicit) has a
θ-independent Hessian, so at k = P (full rank) the sketch is an exact
representation of H regardless of which columns were sampled — any
trajectory difference between refresh cadences is then pure plumbing (or
roundoff), which is what these tests pin down.
"""
import itertools

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (BilevelTrainer, HypergradConfig, NystromIHVP,
                        SketchPolicy, SketchState, config_from_cli,
                        implicit_root)
from repro.optim import sgd


def _quadratic_bilevel(seed=0, P=12, Hdim=5):
    k1, k2, k3, k4 = jax.random.split(jax.random.PRNGKey(seed), 4)
    Am = jax.random.normal(k1, (P, P))
    Am = Am @ Am.T / P + jnp.eye(P)
    Bm = jax.random.normal(k2, (P, Hdim))
    c = jax.random.normal(k3, (P,))
    t = jax.random.normal(k4, (P,))

    def inner(prm, hp, batch):
        th = prm['theta']
        return 0.5 * th @ Am @ th - th @ (Bm @ hp['phi'] + c)

    def outer(prm, hp, batch):
        return 0.5 * jnp.sum((prm['theta'] - t) ** 2)

    def solution_map(hp, batch):
        return {'theta': jnp.linalg.solve(Am, Bm @ hp['phi'] + c)}

    phi0 = {'phi': jnp.ones((Hdim,))}
    return inner, outer, solution_map, phi0, Am, Bm, t


def _trainer(inner, outer, k, rho=1e-3, **cfg):
    return BilevelTrainer(
        inner_loss=inner, outer_loss=outer,
        inner_opt=sgd(0.01), outer_opt=sgd(0.1),
        hypergrad=HypergradConfig(solver='nystrom', k=k, rho=rho, **cfg))


class _CountingIter:
    """Wraps an iterator, counting how many batches were drawn."""

    def __init__(self, it):
        self.it, self.count = iter(it), 0

    def __iter__(self):
        return self

    def __next__(self):
        self.count += 1
        return next(self.it)


class TestRunLifecycle:
    def test_refresh_every_1_matches_outer_step_fn_trajectory(self):
        """run(sketch_refresh_every=1) must reproduce the fresh-prepare
        outer_step_fn trajectory bit-for-bit: the policy splits the same
        vjp_rng stream and builds the same columns, just in the forward
        pass instead of the backward."""
        inner, outer, smap, phi0, Am, Bm, t = _quadratic_bilevel()
        P = Am.shape[0]
        trainer = _trainer(inner, outer, k=P)
        params0 = smap(phi0, None)
        state0 = trainer.init(jax.random.PRNGKey(0), params0, phi0)

        state_a, hist_a = trainer.run(
            state0, itertools.repeat(None), itertools.repeat(None),
            steps_per_outer=2, n_outer=4, sketch_refresh_every=1)

        inner_j = jax.jit(trainer.inner_step_fn)
        outer_j = jax.jit(trainer.outer_step_fn)
        state = state0
        manual_outer = []
        for _ in range(4):
            for _ in range(2):
                state, _ = inner_j(state, None)
            state, lo = outer_j(state, None, None)
            manual_outer.append(float(lo))

        np.testing.assert_array_equal(np.asarray(state_a.hparams['phi']),
                                      np.asarray(state.hparams['phi']))
        np.testing.assert_array_equal(np.asarray(state_a.params['theta']),
                                      np.asarray(state.params['theta']))
        np.testing.assert_array_equal(np.asarray(state_a.vjp_rng),
                                      np.asarray(state.vjp_rng))
        np.testing.assert_allclose(hist_a['outer_loss'], manual_outer,
                                   rtol=0, atol=0)

    def test_stale_sketch_trajectory_within_tolerance(self):
        """refresh_every > 1 linearizes at a stale θ. On the quadratic the
        Hessian is θ-independent and k=P makes the sketch exact, so every
        cadence must land on the same trajectory up to roundoff (the
        different column *order* sampled by the shifted rng stream is the
        only difference)."""
        inner, outer, smap, phi0, Am, Bm, t = _quadratic_bilevel()
        P = Am.shape[0]
        trainer = _trainer(inner, outer, k=P)
        params0 = smap(phi0, None)
        state0 = trainer.init(jax.random.PRNGKey(1), params0, phi0)

        finals = {}
        for every in (1, 2, 5):
            st, _ = trainer.run(
                state0, itertools.repeat(None), itertools.repeat(None),
                steps_per_outer=1, n_outer=6, sketch_refresh_every=every)
            finals[every] = np.asarray(st.hparams['phi'])
        np.testing.assert_allclose(finals[2], finals[1], rtol=1e-4, atol=1e-4)
        np.testing.assert_allclose(finals[5], finals[1], rtol=1e-4, atol=1e-4)

    def test_vjp_rng_consumed_only_on_refresh_steps(self):
        """The lax.cond staleness tracking must not advance the sketch rng
        stream on reuse steps — cadence changes shift *which* keys build
        sketches, not the stream itself."""
        inner, outer, smap, phi0, Am, Bm, t = _quadratic_bilevel()
        trainer = _trainer(inner, outer, k=Am.shape[0])
        state0 = trainer.init(jax.random.PRNGKey(2), smap(phi0, None), phi0)

        st, _ = trainer.run(
            state0, itertools.repeat(None), itertools.repeat(None),
            steps_per_outer=1, n_outer=4, sketch_refresh_every=2)
        # refreshes fire on outer steps 0 and 2 → exactly two splits
        expected = state0.vjp_rng
        for _ in range(2):
            expected, _ = jax.random.split(expected)
        np.testing.assert_array_equal(np.asarray(st.vjp_rng),
                                      np.asarray(expected))

    def test_iterative_solver_rejects_refresh_cadence(self):
        inner, outer, smap, phi0, *_ = _quadratic_bilevel()
        trainer = BilevelTrainer(
            inner_loss=inner, outer_loss=outer,
            inner_opt=sgd(0.01), outer_opt=sgd(0.1),
            hypergrad=HypergradConfig(solver='cg', k=5))
        state0 = trainer.init(jax.random.PRNGKey(3), smap(phi0, None), phi0)
        with pytest.raises(TypeError, match='amortiz'):
            trainer.run(state0, itertools.repeat(None), itertools.repeat(None),
                        steps_per_outer=1, n_outer=1, sketch_refresh_every=2)
        # the config-level knob must raise too, not be a silent dead knob
        trainer_cfg = BilevelTrainer(
            inner_loss=inner, outer_loss=outer,
            inner_opt=sgd(0.01), outer_opt=sgd(0.1),
            hypergrad=HypergradConfig(solver='cg', k=5,
                                      sketch_refresh_every=2))
        with pytest.raises(TypeError, match='amortiz'):
            trainer_cfg.run(state0, itertools.repeat(None),
                            itertools.repeat(None),
                            steps_per_outer=1, n_outer=1)
        # cadence 1 falls back to the fresh-prepare path and runs fine
        trainer.run(state0, itertools.repeat(None), itertools.repeat(None),
                    steps_per_outer=1, n_outer=1)


class TestBatchAlignment:
    def test_outer_step_reuses_last_inner_batch(self):
        """Regression (src/repro/core/bilevel.py): run() used to draw an
        *extra* inner batch per outer step for the Hessian, silently
        shifting data alignment between the curvature and the final θ."""
        inner, outer, smap, phi0, *_ = _quadratic_bilevel()
        trainer = _trainer(inner, outer, k=4)
        state0 = trainer.init(jax.random.PRNGKey(4), smap(phi0, None), phi0)

        it_in = _CountingIter(itertools.repeat(None))
        it_out = _CountingIter(itertools.repeat(None))
        trainer.run(state0, it_in, it_out, steps_per_outer=3, n_outer=2)
        assert it_in.count == 6          # 3 inner steps × 2 outers, no extras
        assert it_out.count == 2

    def test_fresh_inner_batch_opt_in(self):
        inner, outer, smap, phi0, *_ = _quadratic_bilevel()
        trainer = _trainer(inner, outer, k=4)
        state0 = trainer.init(jax.random.PRNGKey(5), smap(phi0, None), phi0)

        it_in = _CountingIter(itertools.repeat(None))
        trainer.run(state0, it_in, itertools.repeat(None),
                    steps_per_outer=3, n_outer=2, fresh_inner_batch=True)
        assert it_in.count == 8          # the pre-fix behavior, now explicit

    def test_zero_inner_steps_still_draws_a_batch(self):
        inner, outer, smap, phi0, *_ = _quadratic_bilevel()
        trainer = _trainer(inner, outer, k=4)
        state0 = trainer.init(jax.random.PRNGKey(6), smap(phi0, None), phi0)
        it_in = _CountingIter(itertools.repeat(None))
        # log_every=1 covers the no-inner-losses log line (regression)
        trainer.run(state0, it_in, itertools.repeat(None),
                    steps_per_outer=0, n_outer=2, log_every=1)
        assert it_in.count == 2          # nothing to reuse → one per outer


class TestSketchPolicy:
    def test_rejects_iterative_solver_at_construction(self):
        from repro.core import CGIHVP
        inner, *_ = _quadratic_bilevel()
        with pytest.raises(TypeError, match='IterativeOperator'):
            SketchPolicy(solver=CGIHVP(iters=5), inner_loss=inner)

    def test_rejects_bad_cadence(self):
        inner, *_ = _quadratic_bilevel()
        with pytest.raises(ValueError, match='refresh_every'):
            SketchPolicy(solver=NystromIHVP(k=4), inner_loss=inner,
                         refresh_every=0)

    def test_init_state_is_structural_and_stale(self):
        """init_state costs no HVPs (eval_shape only) and starts at max
        staleness so the first refresh rebuilds."""
        inner, outer, smap, phi0, Am, *_ = _quadratic_bilevel()
        theta = smap(phi0, None)
        policy = SketchPolicy(solver=NystromIHVP(k=6, rho=1e-2),
                              inner_loss=inner, refresh_every=3)
        rng = jax.random.PRNGKey(7)
        s0 = policy.init_state(theta, phi0, None, rng)
        assert int(s0.age) == 3
        assert all(not x.any() for x in jax.tree.leaves(s0.sketch))

        s1, rebuilt = policy.refresh(s0, theta, phi0, None, rng)
        assert bool(rebuilt) and int(s1.age) == 1
        built = policy.build(theta, phi0, None, rng)
        for a, b in zip(jax.tree.leaves(s1.sketch), jax.tree.leaves(built)):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))

        s2, rebuilt = policy.refresh(s1, theta, phi0, None,
                                     jax.random.PRNGKey(8))
        assert not bool(rebuilt) and int(s2.age) == 2
        for a, b in zip(jax.tree.leaves(s2.sketch),
                        jax.tree.leaves(s1.sketch)):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))

    def test_invalidate_forces_rebuild(self):
        inner, outer, smap, phi0, *_ = _quadratic_bilevel()
        theta = smap(phi0, None)
        policy = SketchPolicy(solver=NystromIHVP(k=6, rho=1e-2),
                              inner_loss=inner, refresh_every=5)
        s = SketchState(
            sketch=policy.build(theta, phi0, None, jax.random.PRNGKey(9)),
            age=jnp.int32(1))
        _, rebuilt = policy.refresh(s, theta, phi0, None,
                                    jax.random.PRNGKey(10))
        assert not bool(rebuilt)
        _, rebuilt = policy.refresh(policy.invalidate(s), theta, phi0, None,
                                    jax.random.PRNGKey(10))
        assert bool(rebuilt)


class TestSharedSketchMetaBatch:
    def test_vmap_broadcast_matches_per_task_loop(self):
        """One prepare_state sketch closed over by the vmapped task-grad ==
        a per-task Python loop applying the same sketch (broadcast
        correctness of the state= path under vmap)."""
        inner, outer, smap, phi0, Am, Bm, t = _quadratic_bilevel()
        solve = implicit_root(smap, inner, NystromIHVP(k=8, rho=1e-3))
        shared = solve.prepare_state(smap(phi0, None), phi0, None,
                                     jax.random.PRNGKey(11))

        def task_grad(hp):
            return jax.grad(lambda h: outer(solve(h, None, state=shared),
                                            h, None))(hp)

        B = 4
        phis = {'phi': jnp.stack([(i + 1.0) * phi0['phi']
                                  for i in range(B)])}
        batched = jax.vmap(task_grad)(phis)
        looped = [task_grad({'phi': phis['phi'][i]})['phi'] for i in range(B)]
        np.testing.assert_allclose(batched['phi'], jnp.stack(looped),
                                   rtol=1e-5, atol=1e-5)

    def test_shared_sketch_matches_per_task_prepare_at_full_rank(self):
        """k = P makes both the shared sketch (built once at θ(φ₀)) and the
        per-task fresh prepares exact representations of the (constant)
        Hessian — the two meta-batch estimators must agree to solver
        tolerance, the test-scale analogue of tab3's cosine row."""
        inner, outer, smap, phi0, Am, Bm, t = _quadratic_bilevel()
        P = Am.shape[0]
        solve = implicit_root(smap, inner, NystromIHVP(k=P, rho=1e-3))
        shared = solve.prepare_state(smap(phi0, None), phi0, None,
                                     jax.random.PRNGKey(12))

        B = 3
        phis = {'phi': jnp.stack([(i + 1.0) * phi0['phi']
                                  for i in range(B)])}
        keys = jax.random.split(jax.random.PRNGKey(13), B)

        hg_shared = jax.vmap(lambda hp: jax.grad(
            lambda h: outer(solve(h, None, state=shared), h, None))(hp))(phis)
        hg_fresh = jax.vmap(lambda hp, key: jax.grad(
            lambda h: outer(solve(h, None, rng=key), h, None))(hp))(phis, keys)
        np.testing.assert_allclose(hg_shared['phi'], hg_fresh['phi'],
                                   rtol=2e-3, atol=2e-3)

    def test_prepare_state_rejects_iterative_solver(self):
        inner, outer, smap, phi0, *_ = _quadratic_bilevel()
        solve = implicit_root(smap, inner, HypergradConfig(solver='cg', k=5))
        with pytest.raises(TypeError, match='IterativeOperator'):
            solve.prepare_state(smap(phi0, None), phi0)


class TestConfigStrictness:
    def test_backend_family_flags_reach_consuming_solver(self):
        """Regression (config_from_cli): backend-family fields were dropped
        — or wrongly rejected — because they live outside SolverSpec.fields
        even for solvers that consume them via builds_backend."""
        cfg = config_from_cli('nystrom',
                              flags={'backend': 'flat',
                                     'sketch_dtype': 'bfloat16'},
                              defaults={})
        assert (cfg.backend, cfg.sketch_dtype) == ('flat', 'bfloat16')
        solver = cfg.build()
        assert solver.backend.name == 'flat'

    def test_backend_family_flags_rejected_for_non_consumers(self):
        with pytest.raises(ValueError, match='not consumed'):
            config_from_cli('cg', flags={'backend': 'flat'}, defaults={})
        with pytest.raises(ValueError, match='not consumed'):
            config_from_cli('exact', flags={'sketch_dtype': 'bfloat16'},
                            defaults={})

    def test_backend_family_extras_forwarded_or_dropped(self):
        """consumed_extras stay the soft solver-agnostic channel, but the
        backend family now rides it to consuming solvers instead of being
        discarded."""
        cfg = config_from_cli('nystrom', flags={}, defaults={},
                              backend='flat')
        assert cfg.backend == 'flat'
        cfg = config_from_cli('cg', flags={}, defaults={}, backend='flat')
        assert cfg.backend == 'tree'      # dropped: cg builds no backend

    def test_sketch_refresh_every_is_trainer_level(self):
        for solver in ('nystrom', 'cg', 'exact'):
            cfg = config_from_cli(solver,
                                  flags={'sketch_refresh_every': 4},
                                  defaults={})
            assert cfg.sketch_refresh_every == 4
            cfg.build()                   # trainer field: never a dead knob
