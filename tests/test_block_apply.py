"""Matrix-valued (query-block) apply path: parity and structure guarantees.

The block contract (see docs/backends.md): a query block V is a pytree
whose every leaf is the parameter shape plus one trailing (m,) axis, and
``solver.apply_matrix(state, V)`` answers all m IHVPs in one sketch pass.
Guarantees pinned here:

  * m=1 BITWISE-matches the vector ``apply`` for all four backends and all
    four solver families (the width-1 block statically dispatches to the
    vector path, so this is equality by construction — and this test keeps
    it that way);
  * m>1 matches the m-column Python loop to f32-roundoff tolerance (the
    direct Eq. 6 path solves a cond²-amplified k×k system, where batched
    multi-RHS LU and per-column solves legitimately differ at ~1e-4 rel —
    hence the looser tolerance there);
  * flat_sharded's block apply issues exactly ONE psum per apply pass —
    enforced via ``repro.core.FLAT_SHARDED_CONTRACT`` over the audited
    program (``repro.analysis.audit``), not m separate psums;
  * ``query_width`` rejects ragged blocks (the symptom of passing a plain
    parameter tree where a block was expected);
  * ``phi_vjp_block`` (the batched-cotangent implicit path) matches the
    per-vector VJP column by column.

Multi-device sharded block parity lives in tests/sharded_parity_check.py.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import Mesh, PartitionSpec as P

from repro.core import (CGIHVP, ExactIHVP, FlatShardedBackend, NeumannIHVP,
                        NystromIHVP, PallasBackend, PyTreeIndexer,
                        flatten_vec, flatten_vecm, get_backend, make_hvp,
                        query_width, tree_random_like, unflatten_vecm)

# same deliberately-awkward tree as test_backend.py: odd sizes, a scalar
PARAMS = {'w': jnp.zeros((8,)), 'm': jnp.zeros((27, 37)),
          'b': jnp.zeros((2, 2)), 's': jnp.zeros(())}


def _mesh1():
    return Mesh(np.array(jax.devices()[:1]), ('model',))


def _backends():
    return {'tree': get_backend('tree'),
            'flat': get_backend('flat'),
            'flat_sharded': FlatShardedBackend(
                mesh=_mesh1(),
                specs={'w': P('model'), 'm': P(None, 'model'),
                       'b': P(), 's': P()}),
            'pallas': PallasBackend(interpret=True, block_p=128)}


def _block(m, seed=0):
    """(p, m) query block: every leaf gets a trailing m axis."""
    cols = [tree_random_like(k, PARAMS)
            for k in jax.random.split(jax.random.PRNGKey(seed), m)]
    return cols, jax.tree.map(lambda *ls: jnp.stack(ls, axis=-1), *cols)


def _quadratic(seed=0):
    idxr = PyTreeIndexer(PARAMS)
    p = idxr.total
    B = jax.random.normal(jax.random.PRNGKey(seed), (p, 16))
    Hm = B @ B.T / p + 0.5 * jnp.eye(p)

    def loss(prm, hp, batch):
        th = flatten_vec(prm)
        return 0.5 * th @ Hm @ th

    return idxr, make_hvp(loss, PARAMS, None, None)


def _solver_grid():
    """(label, solver) for every family × apply-path variant under test."""
    grid = []
    for name, be in _backends().items():
        grid.append((f'nystrom-whitened-{name}',
                     NystromIHVP(k=10, rho=1e-2, backend=be)))
    grid += [
        ('nystrom-direct', NystromIHVP(k=10, rho=1e-2, stabilized=False)),
        ('nystrom-chunked', NystromIHVP(k=10, rho=1e-2, kappa=4)),
        ('cg', CGIHVP(iters=6, rho=1e-2)),
        ('neumann', NeumannIHVP(iters=6, alpha=1e-2)),
        ('exact', ExactIHVP(rho=1e-2)),
    ]
    return grid


# ---------------------------------------------------------------- query_width
class TestQueryWidth:
    def test_reads_trailing_axis(self):
        _, Vm = _block(5)
        assert query_width(Vm) == 5

    def test_scalar_leaf_carries_its_axis(self):
        # the scalar param's block leaf is (m,): still one trailing axis
        _, Vm = _block(3)
        assert Vm['s'].shape == (3,)
        assert query_width(Vm) == 3

    def test_ragged_block_rejected(self):
        bad = {'a': jnp.zeros((4, 3)), 'b': jnp.zeros((4, 2))}
        with pytest.raises(ValueError, match='trailing'):
            query_width(bad)

    def test_plain_param_tree_rejected(self):
        # a parameter tree's "trailing axes" disagree — the classic misuse
        with pytest.raises(ValueError):
            query_width(PARAMS)


# ------------------------------------------------------- backend primitives
@pytest.mark.parametrize('m', [1, 5])
def test_backend_block_primitives_match_tree(m):
    """vecm/unvecm roundtrip + ctm/cm/combinem agree with the tree oracle."""
    k = 9
    keys = jax.random.split(jax.random.PRNGKey(m), 2)
    C_tree = jax.tree.map(lambda l: jax.random.normal(keys[0], (k,) + l.shape),
                          PARAMS)
    _, Vm = _block(m, seed=3)
    W = jax.random.normal(keys[1], (k, m))
    tb = get_backend('tree')
    ref = {'ctm': tb.ctm(C_tree, Vm),
           'cm': flatten_vecm(tb.cm(C_tree, W)),
           'combinem': flatten_vecm(tb.combinem(C_tree, W, Vm, 0.05))}
    for name, be in _backends().items():
        C = be.prepare_operand(C_tree)
        Vb = be.vecm(Vm)
        rt = be.unvecm(Vb, Vm)
        for a, b in zip(jax.tree.leaves(rt), jax.tree.leaves(Vm)):
            np.testing.assert_array_equal(a, b, err_msg=f'{name}:roundtrip')
        got = {'ctm': be.ctm(C, Vb),
               'cm': flatten_vecm(be.unvecm(be.cm(C, W), Vm)),
               'combinem': flatten_vecm(
                   be.unvecm(be.combinem(C, W, Vb, 0.05), Vm))}
        for op in ref:
            tol = 1e-4 * (np.abs(np.asarray(ref[op])).max() + 1.0)
            np.testing.assert_allclose(got[op], ref[op], rtol=1e-4, atol=tol,
                                       err_msg=f'{name}:{op} (m={m})')


# ----------------------------------------------------------- solver parity
@pytest.mark.parametrize('label,solver', _solver_grid(),
                         ids=[lb for lb, _ in _solver_grid()])
def test_m1_bitwise_matches_vector_apply(label, solver):
    """apply_matrix on a width-1 block == apply on the vector, bit for bit."""
    idxr, hvp = _quadratic(seed=11)
    state = solver.prepare(hvp, idxr, jax.random.PRNGKey(12))
    cols, V1 = _block(1, seed=13)
    u_vec = solver.apply(state, cols[0])
    u_blk = solver.apply_matrix(state, V1)
    for a, b in zip(jax.tree.leaves(u_blk), jax.tree.leaves(u_vec)):
        assert a.shape == b.shape + (1,)
        np.testing.assert_array_equal(np.asarray(a)[..., 0], np.asarray(b),
                                      err_msg=label)


@pytest.mark.parametrize('label,solver', _solver_grid(),
                         ids=[lb for lb, _ in _solver_grid()])
def test_block_matches_column_loop(label, solver):
    """m=5 block == the 5-column Python loop to f32-roundoff tolerance."""
    idxr, hvp = _quadratic(seed=21)
    state = solver.prepare(hvp, idxr, jax.random.PRNGKey(22))
    cols, Vm = _block(5, seed=23)
    U = solver.apply_matrix(state, Vm)
    assert query_width(U) == 5
    looped = [solver.apply(state, c) for c in cols]
    for j, u in enumerate(looped):
        got = flatten_vec(jax.tree.map(lambda x: x[..., j], U))
        # direct Eq. 6: batched-LU vs per-column solve differ at ~1e-4 rel
        # on its cond²-amplified k×k system; all other paths sit well below
        np.testing.assert_allclose(got, flatten_vec(u), rtol=2e-4, atol=2e-3,
                                   err_msg=f'{label} col {j}')


def test_block_apply_under_jit():
    idxr, hvp = _quadratic(seed=31)
    solver = NystromIHVP(k=8, rho=1e-2, backend='flat')
    state = solver.prepare(hvp, idxr, jax.random.PRNGKey(32))
    _, Vm = _block(4, seed=33)
    U = jax.jit(solver.apply_matrix)(state, Vm)
    # jit changes fusion order, so agreement is f32-roundoff, not bitwise
    np.testing.assert_allclose(np.asarray(flatten_vecm(U)),
                               np.asarray(flatten_vecm(
                                   solver.apply_matrix(state, Vm))),
                               rtol=1e-5, atol=1e-4)


# ------------------------------------------------------------- psum count
def test_flat_sharded_block_apply_single_psum():
    """The whole m-query apply crosses the mesh once — exactly one psum
    regardless of m, never an all-gather of a parameter shard, f32
    accumulation throughout: FLAT_SHARDED_CONTRACT, checked on the audited
    program instead of grepping lowered text."""
    from repro.analysis import Contract, audit
    from repro.core import FLAT_SHARDED_CONTRACT

    idxr, hvp = _quadratic(seed=41)
    be = _backends()['flat_sharded']
    solver = NystromIHVP(k=8, rho=1e-2, backend=be, refine=0)
    state = solver.prepare(hvp, idxr, jax.random.PRNGKey(42))
    for m in (4, 16):
        _, Vm = _block(m, seed=m)
        report = FLAT_SHARDED_CONTRACT.enforce(
            audit(solver.apply_matrix, state, Vm))
        # the one collective is the (k, m) block psum, not m k-float psums
        (psum,) = report.records('psum', 'jaxpr')
        assert psum.shape == (8, m)
    # each refinement sweep legitimately adds psums (ctm inside the residual
    # and the correction woodbury); the base apply stays at one
    ref = NystromIHVP(k=8, rho=1e-2, backend=be, refine=1)
    _, Vm = _block(4, seed=4)
    report = audit(ref.apply_matrix, state, Vm)
    assert report.count('psum') > 1
    Contract(name='refined block apply', no_all_gather=True,
             min_accum_dtype='float32').enforce(report)


# ------------------------------------------------------------ implicit path
def test_phi_vjp_block_matches_per_vector_columns():
    """The batched-cotangent implicit path == per-column VJPs."""
    from repro.core.implicit import _implicit_phi_vjp, phi_vjp_block

    D, H = 12, 3
    k1, k2, k3 = jax.random.split(jax.random.PRNGKey(50), 3)
    A = jax.random.normal(k1, (D, D))
    A = A @ A.T / D + jnp.eye(D)
    Bm = jax.random.normal(k2, (D, H))

    def inner(theta, phi, batch):
        return (0.5 * theta['t'] @ A @ theta['t']
                - theta['t'] @ (Bm @ phi['p']))

    theta = {'t': jnp.linalg.solve(A, Bm @ jnp.ones((H,)))}
    phi = {'p': jnp.ones((H,))}
    solver = NystromIHVP(k=D, rho=1e-3)   # full-rank sketch: near-exact
    m = 4
    cols = [{'t': jax.random.normal(kk, (D,))}
            for kk in jax.random.split(k3, m)]
    Vm = jax.tree.map(lambda *ls: jnp.stack(ls, -1), *cols)
    rng = jax.random.PRNGKey(51)
    state = solver.prepare(
        make_hvp(inner, theta, phi, None), PyTreeIndexer(theta), rng)
    G = phi_vjp_block(solver, inner, theta, phi, None, Vm, state=state)
    for j, c in enumerate(cols):
        g = _implicit_phi_vjp(solver, inner, theta, phi, None, c, rng, state)
        np.testing.assert_allclose(
            np.asarray(G['p'][..., j]), np.asarray(g['p']),
            rtol=1e-4, atol=1e-5, err_msg=f'col {j}')


def test_exact_multi_rhs_roundtrip_helpers():
    """flatten_vecm/unflatten_vecm invert each other on the block layout."""
    _, Vm = _block(6, seed=61)
    flat = flatten_vecm(Vm)
    assert flat.shape == (PyTreeIndexer(PARAMS).total, 6)
    back = unflatten_vecm(flat, Vm)
    for a, b in zip(jax.tree.leaves(back), jax.tree.leaves(Vm)):
        np.testing.assert_array_equal(a, b)
