"""Pallas kernel tests: shape/dtype sweeps, assert_allclose vs ref.py oracles
(interpret mode executes the kernel bodies in Python on CPU)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip('hypothesis', reason='property tests need the test extra')
from hypothesis import given, settings, strategies as st

from repro.kernels import ops, ref

I = dict(interpret=True)


@pytest.mark.parametrize('p,k', [(64, 5), (1000, 10), (2048, 128), (4096, 33)])
@pytest.mark.parametrize('dtype', [jnp.float32, jnp.bfloat16])
def test_nystrom_gram(p, k, dtype):
    C = jax.random.normal(jax.random.PRNGKey(0), (p, k)).astype(dtype)
    got = ops.nystrom_gram(C, block_p=256, **I)
    want = ref.nystrom_gram(C)
    tol = 1e-4 if dtype == jnp.float32 else 3e-2
    np.testing.assert_allclose(got, want, rtol=tol, atol=tol * np.abs(want).max())


@pytest.mark.parametrize('p,k', [(100, 7), (2048, 64), (3000, 16)])
@pytest.mark.parametrize('dtype', [jnp.float32, jnp.bfloat16])
def test_woodbury_ctv(p, k, dtype):
    key = jax.random.PRNGKey(1)
    C = jax.random.normal(key, (p, k)).astype(dtype)
    v = jax.random.normal(jax.random.PRNGKey(2), (p,)).astype(dtype)
    got = ops.woodbury_ctv(C, v, block_p=512, **I)
    want = ref.woodbury_ctv(C, v)
    tol = 1e-4 if dtype == jnp.float32 else 3e-2
    np.testing.assert_allclose(got, want, rtol=tol, atol=tol * np.abs(want).max())


@pytest.mark.parametrize('p,k,rho', [(100, 7, 0.1), (2048, 64, 0.01),
                                     (999, 5, 1.0)])
def test_woodbury_apply(p, k, rho):
    C = jax.random.normal(jax.random.PRNGKey(3), (p, k))
    w = jax.random.normal(jax.random.PRNGKey(4), (k,))
    v = jax.random.normal(jax.random.PRNGKey(5), (p,))
    got = ops.woodbury_apply(C, w, v, rho, block_p=256, **I)
    want = ref.woodbury_apply(C, w, v, rho)
    np.testing.assert_allclose(got, want, rtol=1e-4,
                               atol=1e-4 * np.abs(want).max())


def test_kernel_ihvp_matches_solver():
    """End-to-end: kernel-pipeline IHVP == the core solver's spectral apply
    (both approximate (H_k + ρI)⁻¹ v; compare against the dense oracle)."""
    p, r, k, rho = 96, 12, 16, 0.05
    A = jax.random.normal(jax.random.PRNGKey(6), (p, r))
    H = A @ A.T
    idx = jax.random.choice(jax.random.PRNGKey(7), p, (k,), replace=False)
    C = H[:, idx]
    H_KK = 0.5 * (C[idx, :] + C[idx, :].T)
    v = jax.random.normal(jax.random.PRNGKey(8), (p,))
    got = ops.nystrom_ihvp_apply(C, H_KK, v, rho, interpret=True)
    H_k = C @ jnp.linalg.pinv(H_KK, rcond=1e-7) @ C.T
    want = jnp.linalg.solve(H_k + rho * jnp.eye(p), v)
    np.testing.assert_allclose(got, want, rtol=5e-3,
                               atol=5e-3 * float(jnp.abs(want).max()))


@pytest.mark.parametrize('shape', [(4, 128), (2, 3, 256), (5, 640)])
@pytest.mark.parametrize('dtype', [jnp.float32, jnp.bfloat16])
def test_rmsnorm(shape, dtype):
    x = jax.random.normal(jax.random.PRNGKey(9), shape).astype(dtype)
    scale = jax.random.normal(jax.random.PRNGKey(10), (shape[-1],)).astype(dtype)
    got = ops.rmsnorm(x, scale, 1e-5, **I)
    want = ref.rmsnorm(x, scale, 1e-5)
    tol = 1e-5 if dtype == jnp.float32 else 2e-2
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32), rtol=tol, atol=tol)


@pytest.mark.parametrize('B,S,H,hd', [(1, 128, 2, 64), (2, 256, 4, 128)])
@pytest.mark.parametrize('causal', [True, False])
@pytest.mark.parametrize('dtype', [jnp.float32, jnp.bfloat16])
def test_flash_attention(B, S, H, hd, causal, dtype):
    ks = jax.random.split(jax.random.PRNGKey(11), 3)
    q = jax.random.normal(ks[0], (B, S, H, hd)).astype(dtype)
    k = jax.random.normal(ks[1], (B, S, H, hd)).astype(dtype)
    v = jax.random.normal(ks[2], (B, S, H, hd)).astype(dtype)
    got = ops.flash_attention(q, k, v, causal=causal, q_block=64, k_block=64, **I)
    want = ref.flash_attention(q, k, v, causal=causal)
    tol = 2e-5 if dtype == jnp.float32 else 2e-2
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32), rtol=tol, atol=tol)


def test_flash_attention_uneven_blocks_rejected():
    q = jnp.zeros((1, 100, 2, 64))
    with pytest.raises(AssertionError):
        ops.flash_attention(q, q, q, q_block=64, k_block=64, **I)


@settings(max_examples=10, deadline=None)
@given(st.integers(1, 7), st.integers(1, 96), st.sampled_from([0.01, 0.5]))
def test_woodbury_apply_property(seed, k, rho):
    """Random (p, k) sweep incl. non-multiples of the block size."""
    p = 37 * k + 11
    C = jax.random.normal(jax.random.PRNGKey(seed), (p, k))
    w = jax.random.normal(jax.random.PRNGKey(seed + 1), (k,))
    v = jax.random.normal(jax.random.PRNGKey(seed + 2), (p,))
    got = ops.woodbury_apply(C, w, v, rho, block_p=128, **I)
    want = ref.woodbury_apply(C, w, v, rho)
    np.testing.assert_allclose(got, want, rtol=1e-4,
                               atol=1e-4 * float(np.abs(want).max() + 1))
