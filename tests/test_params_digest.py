"""checkpoint.params_digest and the digest-drift invalidation it anchors.

The digest is the checkpoint-identity half of every serving-cache key:
two trees digest equal iff a save/restore round-trip reproduces one from
the other. Pinned here: path-order stability, and sensitivity to bytes,
dtype and shape — plus the consumer contract, ``SketchStore``'s
``invalidate_params`` dropping exactly the entries at a drifted digest.
"""
import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import params_digest
from repro.serve.store import SketchKey, SketchStore


def _tree():
    return {'w': jnp.arange(6.0).reshape(2, 3), 'b': jnp.zeros((3,)),
            'nested': {'s': jnp.float32(2.5)}}


class TestParamsDigest:
    def test_deterministic(self):
        assert params_digest(_tree()) == params_digest(_tree())
        assert len(params_digest(_tree())) == 16

    def test_insertion_order_irrelevant(self):
        """Digest walks sorted path order, not dict insertion order."""
        a = {'w': jnp.ones((2,)), 'b': jnp.zeros((3,))}
        b = {'b': jnp.zeros((3,)), 'w': jnp.ones((2,))}
        assert params_digest(a) == params_digest(b)

    def test_byte_sensitivity(self):
        t = _tree()
        bumped = jax.tree.map(lambda x: x, t)
        bumped['w'] = t['w'].at[0, 0].add(1e-7)
        assert params_digest(t) != params_digest(bumped)

    def test_dtype_sensitivity(self):
        """Same bytes, different dtype — f32 zeros vs i32 zeros — differ."""
        assert (params_digest({'x': jnp.zeros((4,), jnp.float32)})
                != params_digest({'x': jnp.zeros((4,), jnp.int32)}))

    def test_shape_sensitivity(self):
        """Same bytes, different shape — a reshape changes the digest."""
        x = jnp.arange(6.0)
        assert (params_digest({'x': x})
                != params_digest({'x': x.reshape(2, 3)}))

    def test_path_sensitivity(self):
        assert (params_digest({'a': jnp.ones((2,))})
                != params_digest({'b': jnp.ones((2,))}))

    def test_numpy_and_device_arrays_agree(self):
        """The digest is content-addressed: host and device copies of the
        same values digest identically (what save would write)."""
        dev = {'w': jnp.arange(4.0)}
        host = {'w': np.arange(4.0, dtype=np.float32)}
        assert params_digest(dev) == params_digest(host)


class TestDigestDriftInvalidation:
    def _stocked_store(self, digest):
        store = SketchStore()
        for fp in ('nystrom/k=4', 'nystrom/k=8'):
            store.get_or_build(SketchKey(params=digest, solver=fp),
                               lambda: {'s': jnp.ones((2,))}, build_hvps=4)
        return store

    def test_invalidate_params_drops_all_solver_configs(self):
        d_old = params_digest({'w': jnp.zeros((4,))})
        store = self._stocked_store(d_old)
        assert len(store) == 2
        assert store.invalidate_params(d_old) == 2
        assert len(store) == 0
        assert store.invalidations == 2

    def test_drift_misses_instead_of_serving_stale(self):
        """After params change, the new digest simply never hits the old
        entries — a retrained model cannot be served a stale sketch."""
        old = {'w': jnp.zeros((4,))}
        new = {'w': jnp.zeros((4,)).at[0].set(1.0)}
        d_old, d_new = params_digest(old), params_digest(new)
        assert d_old != d_new
        store = self._stocked_store(d_old)
        _, built = store.get_or_build(
            SketchKey(params=d_new, solver='nystrom/k=4'),
            lambda: {'s': jnp.ones((2,))})
        assert built                      # miss: the drifted digest is new
        # dropping the NEW digest leaves the old entries untouched
        assert store.invalidate_params(d_new) == 1
        assert len(store) == 2
