"""Roofline-analysis machinery tests: HLO collective parser + differencing."""
import jax.numpy as jnp
import numpy as np

from repro.launch.analysis import (CellAnalysis, assemble, collective_bytes,
                                   interior_corrections, model_flops)
from repro.configs import get_config

HLO = """
ENTRY %main (p0: f32[4,8]) -> f32[4,8] {
  %ag = f32[4,64]{1,0} all-gather(%x), replica_groups=[2,4]<=[8]
  %ar = bf16[16,16]{1,0} all-reduce(%y), channel_id=1
  %ars = f32[8]{0} all-reduce-start(%z), channel_id=2
  %ard = f32[8]{0} all-reduce-done(%ars), channel_id=2
  %rs = (f32[2,2]{1,0}, bf16[4]{0}) reduce-scatter(%a, %b), channel_id=3
  %cp = u8[100]{0} collective-permute(%c), channel_id=4
  %dot = f32[4,8]{1,0} dot(%p0, %w)
}
"""


def test_collective_parser():
    out = collective_bytes(HLO)
    assert out['bytes']['all-gather'] == 4 * 64 * 4
    # plain all-reduce + the -start variant; -done not double counted
    assert out['bytes']['all-reduce'] == 16 * 16 * 2 + 8 * 4
    assert out['counts']['all-reduce'] == 2
    assert out['bytes']['reduce-scatter'] == 2 * 2 * 4 + 4 * 2
    assert out['bytes']['collective-permute'] == 100
    assert out['total_bytes'] == sum(out['bytes'].values())


def test_differencing_assembly():
    """total = outside + n_blocks · (C2 − C1), clamped sanely."""
    c1 = {'flops': 10.0, 'bytes': 100.0}
    c2 = {'flops': 16.0, 'bytes': 160.0}      # inside = 6 / 60, outside = 4 / 40
    coll = {'total_bytes': 0, 'bytes': {k: 0 for k in (
        'all-reduce', 'all-gather', 'reduce-scatter', 'all-to-all',
        'collective-permute')}, 'counts': {}}
    cell = assemble('a', 's', 'm', 4, c1, c2, 10, coll, coll,
                    {'flops': 0.0, 'bytes': 0.0}, 1e9, {})
    assert cell.flops_per_chip == 4 + 10 * 6
    assert cell.bytes_per_chip == 40 + 10 * 60
    t = cell.terms()
    assert t['dominant'] in ('compute', 'memory', 'collective')
    assert 0 <= t['roofline_fraction'] <= 1


def test_model_flops_conventions():
    cfg = get_config('yi_9b')
    n = cfg.param_count(active_only=True)
    assert model_flops(cfg, 'train', 256, 4096) == 6.0 * n * 256 * 4096
    assert model_flops(cfg, 'decode', 128, 32768) == 2.0 * n * 128
    moe = get_config('phi35_moe_42b_a66b')
    # MoE uses ACTIVE params (6.6B, not 42B)
    assert model_flops(moe, 'train', 1, 1) < 6.0 * moe.param_count() * 0.5


def test_interior_corrections_scale_with_seq():
    from repro.launch.mesh import make_host_mesh
    mesh = make_host_mesh()
    cfg = get_config('yi_9b')
    c1 = interior_corrections(cfg, mesh, 'train', 8, 2048)
    c2 = interior_corrections(cfg, mesh, 'train', 8, 4096)
    assert c2['flops'] > 3.5 * c1['flops']     # attention interior ~ S²
    # decode has no time loops → zero correction
    c3 = interior_corrections(cfg, mesh, 'decode', 8, 32768)
    assert c3 == {'flops': 0.0, 'bytes': 0.0}
