"""IHVP solver unit tests: Nyström (all variants) vs dense oracles + baselines."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (CGIHVP, ExactIHVP, NeumannIHVP, NystromIHVP,
                        PyTreeIndexer, make_hvp, nystrom_inverse_dense,
                        tree_random_like)

PARAMS = {'w': jnp.zeros((8,)), 'b': jnp.zeros((2, 2)), 's': jnp.zeros(())}


def _flat(tree):
    return jnp.concatenate([x.ravel() for x in jax.tree.leaves(tree)])


def _quadratic(Hm):
    def loss(prm, hp, batch):
        th = _flat(prm)
        return 0.5 * th @ Hm @ th
    return loss


def _setup(seed=0, rank=None, cond=1.0):
    idxr = PyTreeIndexer(PARAMS)
    p = idxr.total
    r = rank or p
    B = jax.random.normal(jax.random.PRNGKey(seed), (p, r))
    Hm = B @ B.T + cond * jnp.eye(p) * (rank is None)
    hvp = make_hvp(_quadratic(Hm), PARAMS, None, None)
    v = tree_random_like(jax.random.PRNGKey(seed + 1), PARAMS)
    return idxr, p, Hm, hvp, v


class TestNystrom:
    def test_full_rank_k_equals_p(self):
        idxr, p, Hm, hvp, v = _setup()
        rho = 1e-2
        u = NystromIHVP(k=p, rho=rho).solve(hvp, idxr, v, jax.random.PRNGKey(2))
        u_true = jnp.linalg.solve(Hm + rho * jnp.eye(p), _flat(v))
        np.testing.assert_allclose(_flat(u), u_true, rtol=5e-3, atol=5e-3)

    @pytest.mark.parametrize('r', [2, 4, 8])
    def test_lowrank_exact_recovery(self, r):
        """Rank-r PSD Hessian is recovered exactly from k=r columns (Remark 1).

        Compared at the vector scale (as in test_kappa_equivalence): at k=r
        the f32 *reference* solve itself deviates from the f64 truth by up to
        ~3e-3 on small components (ρ=1e-2 amplifies the null-space noise of
        the rank-deficient H by 1/ρ), so a per-component rtol at 1e-3 asserts
        below the reference's own noise floor.
        """
        idxr, p, Hm, hvp, v = _setup(seed=3, rank=r)
        rho = 1e-2
        u = NystromIHVP(k=r, rho=rho).solve(hvp, idxr, v, jax.random.PRNGKey(4))
        u_true = jnp.linalg.solve(Hm + rho * jnp.eye(p), _flat(v))
        scale = jnp.abs(u_true).max()
        np.testing.assert_allclose(_flat(u) / scale, u_true / scale, atol=1e-3)

    @pytest.mark.parametrize('kappa', [1, 2, 3, 5])
    def test_kappa_equivalence(self, kappa):
        """Alg. 1: every κ produces the same result (paper §2.4)."""
        idxr, p, Hm, hvp, v = _setup(seed=5)
        rho = 0.1  # moderate damping keeps the f32 comparison tight
        solver = NystromIHVP(k=p, rho=rho)
        sketch = solver.prepare(hvp, idxr, jax.random.PRNGKey(6))
        ref = _flat(solver.apply(sketch, v))
        out = _flat(NystromIHVP(k=p, rho=rho, kappa=kappa).apply(sketch, v))
        scale = jnp.abs(ref).max()
        np.testing.assert_allclose(out / scale, ref / scale, atol=2e-3)

    def test_kappa_precedence_over_stabilized(self):
        """kappa<k selects the Alg. 1 chunked apply, which carries its own
        deactivated-eigenvalue stabilization: ``stabilized`` must be inert
        (identical results) rather than silently changing numerics, and
        prepare must not build the never-consulted whitened factor."""
        idxr, p, Hm, hvp, v = _setup(seed=25)
        rho = 0.1
        rng = jax.random.PRNGKey(26)
        a = NystromIHVP(k=p, rho=rho, kappa=3, stabilized=True).solve(
            hvp, idxr, v, rng)
        b = NystromIHVP(k=p, rho=rho, kappa=3, stabilized=False).solve(
            hvp, idxr, v, rng)
        for x, y in zip(jax.tree.leaves(a), jax.tree.leaves(b)):
            np.testing.assert_array_equal(np.asarray(x), np.asarray(y))
        sketch = NystromIHVP(k=p, rho=rho, kappa=3).prepare(hvp, idxr, rng)
        assert sketch.B is None            # whitened factor skipped
        assert sketch.gram_C is not None   # Eq. 6 fallback stays 2-pass

    def test_kappa_honors_refine(self):
        """``refine`` is live on the chunked path: residual sweeps against
        H_k + ρI drive the f32 cancellation error (~2e-4 relative at ρ=1e-3
        here) down to roundoff, matching the whitened path's behavior."""
        idxr, p, Hm, hvp, v = _setup(seed=27)
        rho = 1e-3
        truth = jnp.linalg.solve(Hm + rho * jnp.eye(p), _flat(v))
        sketch = NystromIHVP(k=p, rho=rho).prepare(hvp, idxr,
                                                   jax.random.PRNGKey(28))
        errs = []
        for refine in (0, 2):
            u = NystromIHVP(k=p, rho=rho, kappa=3, refine=refine).apply(
                sketch, v)
            errs.append(float(jnp.abs(_flat(u) - truth).max()
                              / jnp.abs(truth).max()))
        assert errs[1] < errs[0] / 10      # measured: 2e-4 → 6e-7
        assert errs[1] < 1e-5

    def test_literal_eq6_matches_stabilized(self):
        idxr, p, Hm, hvp, v = _setup(seed=7)
        rho = 0.5  # well-damped ⇒ Eq. 6's squared conditioning is benign
        a = NystromIHVP(k=p, rho=rho, stabilized=True).solve(
            hvp, idxr, v, jax.random.PRNGKey(8))
        b = NystromIHVP(k=p, rho=rho, stabilized=False).solve(
            hvp, idxr, v, jax.random.PRNGKey(8))
        np.testing.assert_allclose(_flat(a), _flat(b), rtol=2e-2, atol=2e-2)

    def test_column_chunk_equivalence(self):
        """lax.map-chunked column extraction == one-shot vmap extraction."""
        idxr, p, Hm, hvp, v = _setup(seed=9)
        a = NystromIHVP(k=8, rho=1e-2, column_chunk=3).solve(
            hvp, idxr, v, jax.random.PRNGKey(10))
        b = NystromIHVP(k=8, rho=1e-2).solve(hvp, idxr, v, jax.random.PRNGKey(10))
        np.testing.assert_allclose(_flat(a), _flat(b), rtol=1e-5, atol=1e-5)

    def test_sketch_retargets_across_rho(self):
        """The sketch is ρ-free: one prepare, applied under two different
        damping values, matches each value's own dense truth (the amortized
        rho-sweep use the pre-built-sketch hypergradient path supports)."""
        idxr, p, Hm, hvp, v = _setup(seed=23)
        sketch = NystromIHVP(k=p, rho=1e-2).prepare(hvp, idxr,
                                                    jax.random.PRNGKey(24))
        for rho in (1e-2, 1e-1, 1.0):
            u = NystromIHVP(k=p, rho=rho).apply(sketch, v)
            u_true = jnp.linalg.solve(Hm + rho * jnp.eye(p), _flat(v))
            np.testing.assert_allclose(_flat(u), u_true, rtol=5e-3, atol=5e-3,
                                       err_msg=f'rho={rho}')

    def test_zero_hessian_degenerate(self):
        """All-zero H (the ReLU dead-column pathology §5): falls back to v/ρ."""
        idxr = PyTreeIndexer(PARAMS)
        hvp = make_hvp(lambda prm, hp, b: 0.0 * _flat(prm).sum(), PARAMS, None, None)
        v = tree_random_like(jax.random.PRNGKey(11), PARAMS)
        rho = 0.1
        u = NystromIHVP(k=5, rho=rho).solve(hvp, idxr, v, jax.random.PRNGKey(12))
        np.testing.assert_allclose(_flat(u), _flat(v) / rho, rtol=1e-5)
        assert not jnp.isnan(_flat(u)).any()

    def test_dense_fig1_shape(self):
        """Fig. 1 setting: rank-20 40-dim matrix, k=5..40."""
        p, r, rho = 40, 20, 0.1
        A = jax.random.normal(jax.random.PRNGKey(13), (p, r))
        H = A @ A.T
        truth = jnp.linalg.inv(H + rho * jnp.eye(p))
        err_prev = jnp.inf
        for k in (5, 20, 40):
            ny = nystrom_inverse_dense(H, k=k, rho=rho, rng=jax.random.PRNGKey(14))
            err = jnp.abs(ny - truth).max()
            assert err <= err_prev + 1e-5, f'error must not grow with k (k={k})'
            err_prev = err
        assert err_prev < 5e-3  # k=p ⇒ near-exact


class TestBaselines:
    def test_cg_converges(self):
        idxr, p, Hm, hvp, v = _setup(seed=15)
        rho = 1e-2
        u = CGIHVP(iters=4 * p, rho=rho).solve(hvp, idxr, v, None)
        u_true = jnp.linalg.solve(Hm + rho * jnp.eye(p), _flat(v))
        np.testing.assert_allclose(_flat(u), u_true, rtol=1e-3, atol=1e-3)

    def test_neumann_converges_well_conditioned(self):
        """Neumann targets H⁻¹v and needs ‖I−αH‖<1; use a benign spectrum."""
        idxr = PyTreeIndexer(PARAMS)
        p = idxr.total
        evals = jnp.linspace(0.5, 1.5, p)
        Q, _ = jnp.linalg.qr(jax.random.normal(jax.random.PRNGKey(16), (p, p)))
        Hm = (Q * evals) @ Q.T
        hvp = make_hvp(_quadratic(Hm), PARAMS, None, None)
        v = tree_random_like(jax.random.PRNGKey(17), PARAMS)
        u = NeumannIHVP(iters=200, alpha=0.5).solve(hvp, idxr, v, None)
        u_true = jnp.linalg.solve(Hm, _flat(v))
        np.testing.assert_allclose(_flat(u), u_true, rtol=1e-3, atol=1e-3)

    def test_neumann_diverges_when_alpha_violates_norm_bound(self):
        """The instability the paper fixes: ‖αH‖>2 ⇒ series diverges."""
        idxr, p, Hm, hvp, v = _setup(seed=18)  # ‖H‖ ~ tens
        u = NeumannIHVP(iters=100, alpha=1.0).solve(hvp, idxr, v, None)
        assert (~jnp.isfinite(_flat(u))).any() or jnp.abs(_flat(u)).max() > 1e6

    def test_exact_is_oracle(self):
        idxr, p, Hm, hvp, v = _setup(seed=19)
        rho = 1e-2
        u = ExactIHVP(rho=rho).solve(hvp, idxr, v, None)
        u_true = jnp.linalg.solve(Hm + rho * jnp.eye(p), _flat(v))
        np.testing.assert_allclose(_flat(u), u_true, rtol=1e-4, atol=1e-4)


class TestIndexer:
    def test_one_hot_roundtrip(self):
        idxr = PyTreeIndexer(PARAMS)
        for j in (0, 7, 8, 11, idxr.total - 1):
            oh_tree = idxr.one_hot(jax.tree.map(lambda a: a[0],
                                                idxr.from_flat([j])))
            flat = _flat(oh_tree)
            assert flat[j] == 1.0 and flat.sum() == 1.0

    def test_gather_matches_flat_indexing(self):
        idxr = PyTreeIndexer(PARAMS)
        k = 4
        batched = jax.tree.map(
            lambda l: jax.random.normal(jax.random.PRNGKey(20),
                                        (k,) + l.shape), PARAMS)
        flat = jnp.stack([_flat(jax.tree.map(lambda x: x[i], batched))
                          for i in range(k)])
        flat_idx = [0, 5, 9, 12]
        idx = idxr.from_flat(flat_idx)
        np.testing.assert_allclose(idxr.gather(batched, idx),
                                   flat[:, jnp.array(flat_idx)], rtol=1e-6)

    def test_sample_indices_cover_all_leaves(self):
        idxr = PyTreeIndexer(PARAMS)
        idx = idxr.sample_indices(jax.random.PRNGKey(21), 8)
        assert idx['leaf'].shape == (8,)
        assert idx['dims'].shape == (8, idxr.max_rank)
        # distinct below the int32 boundary (replace=False path)
        pairs = {(int(l), tuple(map(int, d)))
                 for l, d in zip(idx['leaf'], idx['dims'])}
        assert len(pairs) == 8
        # every sampled coordinate is in range
        table = np.asarray(idxr._dim_table)[np.asarray(idx['leaf'])]
        assert (np.asarray(idx['dims']) < table).all()

    def test_structured_safe_beyond_int32(self):
        """Index math never forms a global flat offset: a (virtual) tree
        with > 2^31 params samples/one-hots fine (the yi-9b hypergrad cell
        overflowed here before structuring)."""
        big = {'a': jax.ShapeDtypeStruct((50_000, 50_000), jnp.float32),
               'b': jax.ShapeDtypeStruct((126, 16384, 53248), jnp.float32)}
        idxr = PyTreeIndexer(big)
        assert idxr.total > 2 ** 31
        idx = idxr.sample_indices(jax.random.PRNGKey(0), 16)
        assert (np.asarray(idx['dims']) >= 0).all()
