"""Multi-device flat_sharded parity checks — run in a fresh interpreter.

Invoked by tests/test_backend_sharded.py via a subprocess with
``XLA_FLAGS=--xla_force_host_platform_device_count=8`` (the device count
must be fixed before jax initializes, and conftest.py deliberately keeps
the main test process on the 1 real CPU device — see its docstring).
NOT named test_*.py so pytest never collects it directly.

Checks, on a 2×4 ('data', 'model') host mesh:

  * all four contractions (ctv / cv / gram+cross / mul_right) plus the
    fused combine match the tree backend to f32 tolerance, with leaves
    spanning fully-sharded / partially-sharded / replicated / non-divisible
    (the 9-element leaf with P('data') degrades to replication) / scalar;
  * end-to-end NystromIHVP apply parity for stabilized / Eq. 6 / chunked;
  * the compiled prepare→ctv pipeline contains an all-reduce (the psum)
    and NO all-gather — the fused path never rematerializes a leaf
    (checked by ``repro.analysis.audit`` + a declarative Contract, not by
    grepping HLO text);
  * bf16 sketch storage stays within bf16-rounding tolerance of tree/f32;
  * the m-query block apply (``apply_matrix``) matches the tree backend for
    stabilized / Eq. 6 / chunked, and satisfies
    ``repro.core.FLAT_SHARDED_CONTRACT``: exactly ONE psum — a single
    (k, m) block all-reduce, not m k-float psums — no all-gather in any
    layer, f32 accumulation throughout.

Prints one ``OK <name>`` marker per passed check; the pytest wrapper
asserts on the full set, so a silently-skipped check fails the suite.
"""
import sys

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.core import (NystromIHVP, PyTreeIndexer, get_backend, make_hvp,
                        tree_random_like)
from repro.core.backend import flatten_vec
from repro.distributed.sharding import sanitize_spec

PARAMS = {'w': jnp.zeros((16, 8)),    # P('data','model'): fully sharded
          'm': jnp.zeros((27, 37)),   # replicated by spec
          'a': jnp.zeros((8, 4)),     # P('model', None): partially sharded
          'b': jnp.zeros((9,)),       # P('data') but 9 % 2 != 0 → fallback
          's': jnp.zeros(())}         # scalar
SPECS = {'w': P('data', 'model'), 'm': P(None, None), 'a': P('model', None),
         'b': P('data'), 's': P()}
K = 7


def _mesh():
    n = jax.device_count()
    assert n == 8, f'expected 8 host devices, got {n} (XLA_FLAGS not set?)'
    return Mesh(np.array(jax.devices()).reshape(2, 4), ('data', 'model'))


def _sketch_and_vec(seed=0):
    keys = jax.random.split(jax.random.PRNGKey(seed), 2)
    C = jax.tree.map(lambda l: jax.random.normal(keys[0], (K,) + l.shape),
                     PARAMS)
    return C, tree_random_like(keys[1], PARAMS)


def check_primitives(mesh):
    C_tree, v = _sketch_and_vec()
    tb, sb = get_backend('tree'), get_backend('flat_sharded', mesh=mesh,
                                              specs=SPECS)
    Ct, Cs = tb.prepare_operand(C_tree), sb.prepare_operand(C_tree)
    vt, vs = tb.vec(v), sb.vec(v)
    w = jax.random.normal(jax.random.PRNGKey(3), (K,))
    M = jax.random.normal(jax.random.PRNGKey(4), (K, 3))
    cases = {
        'ctv': (sb.ctv(Cs, vs), tb.ctv(Ct, vt)),
        'gram': (sb.gram(Cs), tb.gram(Ct)),
        'cv': (flatten_vec(sb.unvec(sb.cv(Cs, w), v)),
               flatten_vec(tb.cv(Ct, w))),
        'mul_right': (sb.gram(sb.mul_right(Cs, M)),
                      tb.gram(tb.mul_right(Ct, M))),
        'combine': (flatten_vec(sb.unvec(sb.combine(Cs, w, vs, 0.05), v)),
                    flatten_vec(tb.combine(Ct, w, vt, 0.05))),
    }
    for name, (got, ref) in cases.items():
        tol = 2e-4 * (np.abs(np.asarray(ref)).max() + 1.0)
        np.testing.assert_allclose(got, ref, rtol=2e-4, atol=tol,
                                   err_msg=name)
        print(f'OK primitive:{name}')


def _quadratic():
    idxr = PyTreeIndexer(PARAMS)
    p = idxr.total
    B = jax.random.normal(jax.random.PRNGKey(7), (p, 16))
    Hm = B @ B.T / p + 0.5 * jnp.eye(p)

    def loss(prm, hp, batch):
        th = flatten_vec(prm)
        return 0.5 * th @ Hm @ th

    return idxr, make_hvp(loss, PARAMS, None, None)


def check_solver(mesh):
    idxr, hvp = _quadratic()
    _, v = _sketch_and_vec(seed=9)
    sb = get_backend('flat_sharded', mesh=mesh, specs=SPECS)
    rng = jax.random.PRNGKey(12)
    for label, kw in (('stabilized', dict(k=10, rho=1e-2, stabilized=True)),
                      ('eq6', dict(k=10, rho=1e-2, stabilized=False)),
                      ('chunked', dict(k=8, rho=0.1, kappa=3))):
        ut = flatten_vec(NystromIHVP(backend='tree', **kw)
                         .solve(hvp, idxr, v, rng))
        us = flatten_vec(NystromIHVP(backend=sb, **kw)
                         .solve(hvp, idxr, v, rng))
        scale = np.abs(np.asarray(ut)).max()
        np.testing.assert_allclose(us / scale, ut / scale, atol=2e-4,
                                   err_msg=label)
        print(f'OK solver:{label}')


def check_no_all_gather(mesh):
    """The whole sharded pipeline — fuse, whitened apply, un-fuse — must
    compile without a single all-gather of a parameter leaf. Audited with
    ``compile=True``: the GSPMD-partitioned HLO is the only layer where
    inserted gathers exist, and the Contract checks both text layers."""
    from repro.analysis import Contract, audit
    C_tree, v = _sketch_and_vec()
    sb = get_backend('flat_sharded', mesh=mesh, specs=SPECS)
    place = {kk: sanitize_spec(PARAMS[kk].shape, SPECS[kk], mesh)
             for kk in PARAMS}
    Cp = {kk: jax.device_put(C_tree[kk],
                             NamedSharding(mesh, P(None, *place[kk])))
          for kk in PARAMS}
    vp = {kk: jax.device_put(v[kk], NamedSharding(mesh, place[kk]))
          for kk in PARAMS}

    def pipeline(Ct, v_):
        op = sb.prepare_operand(Ct)
        t = sb.ctv(op, sb.vec(v_))
        return t, sb.unvec(sb.combine(op, t, sb.vec(v_), 0.1), v_)

    report = Contract(
        name='flat_sharded pipeline', no_all_gather=True,
        min_collectives={'psum': 1},
        min_accum_dtype='float32').enforce(
            audit(pipeline, Cp, vp, compile=True))
    assert report.count('psum', 'hlo') >= 1, \
        'expected the psum to survive into compiled HLO as an all-reduce'
    print('OK hlo:no-all-gather')


def _query_block(m, seed=30):
    cols = [tree_random_like(kk, PARAMS)
            for kk in jax.random.split(jax.random.PRNGKey(seed), m)]
    return jax.tree.map(lambda *ls: jnp.stack(ls, axis=-1), *cols)


def check_block_apply(mesh):
    """apply_matrix parity on the 8-device mesh for every apply variant."""
    from repro.core.backend import flatten_vecm
    idxr, hvp = _quadratic()
    sb = get_backend('flat_sharded', mesh=mesh, specs=SPECS)
    rng = jax.random.PRNGKey(31)
    Vm = _query_block(5)
    for label, kw in (('stabilized', dict(k=10, rho=1e-2, stabilized=True)),
                      ('eq6', dict(k=10, rho=1e-2, stabilized=False)),
                      ('chunked', dict(k=8, rho=0.1, kappa=3))):
        st = NystromIHVP(backend='tree', **kw)
        ss = NystromIHVP(backend=sb, **kw)
        Ut = flatten_vecm(st.apply_matrix(st.prepare(hvp, idxr, rng), Vm))
        Us = flatten_vecm(ss.apply_matrix(ss.prepare(hvp, idxr, rng), Vm))
        scale = np.abs(np.asarray(Ut)).max()
        np.testing.assert_allclose(np.asarray(Us) / scale,
                                   np.asarray(Ut) / scale, atol=2e-4,
                                   err_msg=label)
        print(f'OK block:{label}')


def check_block_single_psum(mesh):
    """One (k, m) psum per block apply — the whole point of ctm — and never
    an all-gather of a parameter shard: ``FLAT_SHARDED_CONTRACT`` over the
    audited + compiled program, on the real 8-device mesh."""
    from repro.analysis import audit
    from repro.core import FLAT_SHARDED_CONTRACT
    idxr, hvp = _quadratic()
    sb = get_backend('flat_sharded', mesh=mesh, specs=SPECS)
    solver = NystromIHVP(k=8, rho=1e-2, backend=sb, refine=0)
    sketch = solver.prepare(hvp, idxr, jax.random.PRNGKey(32))
    for m in (4, 16):
        report = FLAT_SHARDED_CONTRACT.enforce(
            audit(solver.apply_matrix, sketch, _query_block(m),
                  compile=True))
        (psum,) = report.records('psum', 'jaxpr')
        assert psum.shape == (8, m), \
            f'expected one (k, m) block psum at m={m}, got {psum.render()}'
    print('OK block:single-psum')


def check_bf16(mesh):
    C_tree, v = _sketch_and_vec(seed=21)
    tb = get_backend('tree')
    sb = get_backend('flat_sharded', mesh=mesh, specs=SPECS,
                     sketch_dtype=jnp.bfloat16)
    op = sb.prepare_operand(C_tree)
    assert op.buf.dtype == jnp.bfloat16
    ref = tb.ctv(tb.prepare_operand(C_tree), tb.vec(v))
    got = sb.ctv(op, sb.vec(v))
    assert got.dtype == jnp.float32          # psum accumulates f32
    rel = float(np.max(np.abs(np.asarray(got - ref)))
                / (np.max(np.abs(np.asarray(ref))) + 1e-9))
    assert rel < 2e-2, f'bf16 ctv rel err {rel}'
    gref = tb.gram(tb.prepare_operand(C_tree))
    grel = float(np.max(np.abs(np.asarray(sb.gram(op) - gref)))
                 / (np.max(np.abs(np.asarray(gref))) + 1e-9))
    assert grel < 2e-2, f'bf16 gram rel err {grel}'
    print('OK bf16:tolerance')


EXPECTED = ['primitive:ctv', 'primitive:gram', 'primitive:cv',
            'primitive:mul_right', 'primitive:combine', 'solver:stabilized',
            'solver:eq6', 'solver:chunked', 'hlo:no-all-gather',
            'block:stabilized', 'block:eq6', 'block:chunked',
            'block:single-psum', 'bf16:tolerance']


def main():
    mesh = _mesh()
    check_primitives(mesh)
    check_solver(mesh)
    check_no_all_gather(mesh)
    check_block_apply(mesh)
    check_block_single_psum(mesh)
    check_bf16(mesh)
    print('ALL CHECKS PASSED')
    return 0


if __name__ == '__main__':
    sys.exit(main())
