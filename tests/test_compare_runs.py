"""compare_runs contract tests: clean self-diff, named regressions, schema.

The comparison layer is what turns persisted BENCH files into an
enforceable trajectory, so its failure modes are pinned: identical runs
diff clean (exit 0), each injected regression class exits nonzero *naming
the offending cell*, and cross-schema comparisons refuse with a clear
message instead of a KeyError deep in row access.
"""
import copy
import json

import pytest

from benchmarks.common import bench_row
from benchmarks.compare_runs import main as compare_main
from repro.bench import (CompareError, compare_docs, fit_rates,
                         format_rates, format_report)


def _doc(name='unit'):
    rows = [
        bench_row(solver='nystrom', backend='tree', m=1,
                  applies_per_sec=100.0, wall_seconds=0.01,
                  problem='logreg_wd:D=8', hvp_count=4,
                  hypergrad_error=0.10, grid={'k': 4, 'rho': 0.01}),
        bench_row(solver='cg', backend='tree', m=1,
                  applies_per_sec=50.0, wall_seconds=0.02,
                  problem='logreg_wd:D=8', hvp_count=8,
                  hypergrad_error=0.001, grid={'k': 8, 'rho': 0.01}),
    ]
    return {'schema_version': 2, 'name': name, 'created_unix': 0.0,
            'meta': {}, 'rows': rows}


def _write(tmp_path, name, doc):
    path = tmp_path / f'{name}.json'
    path.write_text(json.dumps(doc))
    return str(path)


class TestCompareDocs:
    def test_identical_runs_diff_clean(self):
        report = compare_docs(_doc(), _doc())
        assert report.ok and not report.regressions and not report.missing

    def test_wall_regression_beyond_tolerance_flags_cell(self):
        new = _doc()
        new['rows'][0]['wall_seconds'] *= 2.0
        report = compare_docs(_doc(), new, tol_wall=0.25)
        assert not report.ok
        (reg,) = [d for d in report.regressions if d.field == 'wall_seconds']
        assert 'solver=nystrom' in reg.cell and 'k=4' in reg.cell

    def test_wall_within_tolerance_passes(self):
        new = _doc()
        new['rows'][0]['wall_seconds'] *= 1.1
        new['rows'][0]['applies_per_sec'] /= 1.1
        assert compare_docs(_doc(), new, tol_wall=0.25).ok

    def test_no_wall_skips_timing_but_not_error(self):
        new = _doc()
        new['rows'][0]['wall_seconds'] *= 100.0
        assert compare_docs(_doc(), new, check_wall=False).ok
        new['rows'][1]['hypergrad_error'] *= 10.0
        report = compare_docs(_doc(), new, check_wall=False)
        (reg,) = report.regressions
        assert reg.field == 'hypergrad_error' and 'solver=cg' in reg.cell

    def test_error_regression_beyond_tolerance_flags_cell(self):
        new = _doc()
        new['rows'][1]['hypergrad_error'] = 0.5
        report = compare_docs(_doc(), new, tol_error=0.25)
        (reg,) = report.regressions
        assert reg.field == 'hypergrad_error'
        assert reg.base == pytest.approx(0.001)
        assert reg.new == pytest.approx(0.5)

    def test_atol_floor_forgives_near_zero_baselines(self):
        base, new = _doc(), _doc()
        base['rows'][1]['hypergrad_error'] = 0.0
        new['rows'][1]['hypergrad_error'] = 1e-9
        assert compare_docs(base, new, atol_error=1e-6).ok

    def test_any_hvp_count_increase_regresses(self):
        new = _doc()
        new['rows'][0]['hvp_count'] += 1
        report = compare_docs(_doc(), new)
        (reg,) = report.regressions
        assert reg.field == 'hvp_count'

    def test_collective_count_increase_regresses(self):
        base, new = _doc(), _doc()
        for doc in (base, new):
            doc['rows'][0]['collective_count'] = 1
            doc['rows'][0]['accum_dtype_ok'] = True
        assert compare_docs(base, new).ok
        new['rows'][0]['collective_count'] = 2
        report = compare_docs(base, new)
        (reg,) = report.regressions
        assert reg.field == 'collective_count'
        assert 'program structure' in reg.note

    def test_accum_dtype_ok_flip_to_false_regresses(self):
        base, new = _doc(), _doc()
        for doc in (base, new):
            doc['rows'][0]['accum_dtype_ok'] = True
        new['rows'][0]['accum_dtype_ok'] = False
        report = compare_docs(base, new)
        (reg,) = report.regressions
        assert reg.field == 'accum_dtype_ok'
        # the reverse flip (a fix) is an improvement, not a regression
        assert compare_docs(new, base).ok

    def test_unaudited_runs_skip_audit_fields(self):
        """Rows without the --audit fields diff exactly as before —
        audited baselines also tolerate an unaudited new run (the field
        check needs both sides)."""
        base = _doc()
        base['rows'][0]['collective_count'] = 3
        base['rows'][0]['accum_dtype_ok'] = True
        report = compare_docs(base, _doc())
        assert report.ok
        assert not [d for d in report.diffs
                    if d.field in ('collective_count', 'accum_dtype_ok')]

    def test_missing_baseline_cell_fails_named(self):
        new = _doc()
        del new['rows'][1]
        report = compare_docs(_doc(), new)
        assert not report.ok
        (cell,) = report.missing
        assert 'solver=cg' in cell
        assert 'MISSING' in format_report(report)

    def test_new_only_cells_are_additions_not_failures(self):
        new = _doc()
        new['rows'].append(bench_row(
            solver='neumann', backend='tree', m=1, applies_per_sec=10.0,
            wall_seconds=0.1, problem='logreg_wd:D=8', hvp_count=4))
        report = compare_docs(_doc(), new)
        assert report.ok and len(report.added) == 1

    def test_schema_mismatch_is_a_clear_error_not_keyerror(self):
        v1 = _doc()
        v1['schema_version'] = 1
        with pytest.raises(CompareError, match='schema_version mismatch'):
            compare_docs(v1, _doc())

    def test_duplicate_cells_refuse_to_diff(self):
        dup = _doc()
        dup['rows'].append(copy.deepcopy(dup['rows'][0]))
        with pytest.raises(CompareError, match='duplicate cell'):
            compare_docs(dup, _doc())


class TestServingIdentity:
    """The serving/quality fields split into identity vs measurement the
    way CI gating needs: backend / phase / cache_hit_rate distinguish
    cells (a drift fails as MISSING, never a silent tolerance pass), while
    latency percentiles are machine-varying measurements waived by
    --no-wall."""

    def test_backend_is_cell_identity(self):
        base = _doc()
        base['rows'].append(bench_row(
            solver='nystrom', backend='flat', m=1, applies_per_sec=120.0,
            wall_seconds=0.008, problem='logreg_wd:D=8', hvp_count=4,
            hypergrad_error=0.10, grid={'k': 4, 'rho': 0.01}))
        assert compare_docs(base, copy.deepcopy(base)).ok
        new = copy.deepcopy(base)
        del new['rows'][-1]            # flat cell vanished, tree cell kept
        report = compare_docs(base, new)
        assert not report.ok
        (cell,) = report.missing
        assert 'backend=flat' in cell

    def test_cache_hit_rate_drift_is_missing_not_tolerance(self):
        base = _doc()
        base['rows'][0]['cache_hit_rate'] = 0.9
        base['rows'][0]['phase'] = 'warm'
        new = copy.deepcopy(base)
        new['rows'][0]['cache_hit_rate'] = 0.5
        report = compare_docs(base, new)
        assert not report.ok
        (cell,) = report.missing       # old identity gone...
        assert 'cache_hit_rate=0.9' in cell
        (added,) = report.added        # ...new identity is an addition
        assert 'cache_hit_rate=0.5' in added

    def test_latency_p95_gated_only_under_check_wall(self):
        base = _doc()
        base['rows'][0]['latency_p95_ms'] = 10.0
        new = copy.deepcopy(base)
        new['rows'][0]['latency_p95_ms'] = 100.0
        report = compare_docs(base, new, tol_wall=0.25)
        (reg,) = [d for d in report.regressions
                  if d.field == 'latency_p95_ms']
        assert 'solver=nystrom' in reg.cell
        assert compare_docs(base, new, check_wall=False).ok

    def test_jaccard_floor_flags_retrieval_quality_loss(self):
        base = _doc()
        base['rows'][0]['jaccard_vs_exact'] = 0.8
        new = copy.deepcopy(base)
        new['rows'][0]['jaccard_vs_exact'] = 0.2
        report = compare_docs(base, new, tol_error=0.25)
        (reg,) = report.regressions
        assert reg.field == 'jaccard_vs_exact'
        assert reg.base == pytest.approx(0.8)
        new['rows'][0]['jaccard_vs_exact'] = 0.75   # within the floor
        assert compare_docs(base, new, tol_error=0.25).ok


class TestCli:
    def test_identical_exit_zero(self, tmp_path, capsys):
        base = _write(tmp_path, 'base', _doc())
        assert compare_main([base, base]) == 0
        assert 'clean' in capsys.readouterr().out

    def test_injected_regression_exits_nonzero_naming_cell(self, tmp_path,
                                                           capsys):
        bad = _doc()
        bad['rows'][0]['wall_seconds'] *= 3.0
        rc = compare_main([_write(tmp_path, 'base', _doc()),
                           _write(tmp_path, 'bad', bad)])
        out = capsys.readouterr().out
        assert rc == 1
        assert 'REGRESSION' in out and 'solver=nystrom' in out

    def test_no_wall_flag(self, tmp_path):
        bad = _doc()
        bad['rows'][0]['wall_seconds'] *= 3.0
        base = _write(tmp_path, 'base', _doc())
        new = _write(tmp_path, 'bad', bad)
        assert compare_main([base, new]) == 1
        assert compare_main([base, new, '--no-wall']) == 0

    def test_v1_vs_v2_exits_two_with_message(self, tmp_path, capsys):
        v1 = _doc()
        v1['schema_version'] = 1
        rc = compare_main([_write(tmp_path, 'v1', v1),
                           _write(tmp_path, 'v2', _doc())])
        out = capsys.readouterr().out
        assert rc == 2
        assert 'schema_version mismatch' in out and 'KeyError' not in out


# ---------------------------------------------------------------------------
# Rate fits (repro.bench.rates)
# ---------------------------------------------------------------------------
def _ladder_doc(slope=-2.0, solver='nystrom', bills=(2, 4, 8, 16)):
    """A doc whose (problem, solver) ladder follows err = hvps^slope."""
    rows = [
        bench_row(solver=solver, backend='tree', m=1, applies_per_sec=1.0,
                  wall_seconds=0.01, problem='quad:D=8', hvp_count=b,
                  hypergrad_error=float(b) ** slope, grid={'k': b})
        for b in bills
    ]
    return {'schema_version': 2, 'name': 'ladder', 'created_unix': 0.0,
            'meta': {}, 'rows': rows}


class TestRateFits:
    def test_recovers_known_power_law(self):
        fits = fit_rates(_ladder_doc(slope=-2.0))
        assert len(fits) == 1
        f = fits[0]
        assert (f.problem, f.solver, f.points) == ('quad:D=8', 'nystrom', 4)
        assert abs(f.slope - (-2.0)) < 1e-9
        assert f.r2 > 0.999999

    def test_ladders_split_by_solver_and_short_ladders_skipped(self):
        doc = _ladder_doc(slope=-2.0, solver='nystrom')
        doc['rows'] += _ladder_doc(slope=-0.5, solver='cg')['rows']
        # a two-point "ladder" fits a line by construction — no rate info
        doc['rows'] += _ladder_doc(solver='neumann', bills=(2, 4))['rows']
        # rows with no error measurement carry nothing to regress
        doc['rows'].append(bench_row(
            solver='exact', backend='tree', m=1, applies_per_sec=1.0,
            wall_seconds=0.01, problem='quad:D=8', hvp_count=64))
        fits = {f.solver: f for f in fit_rates(doc)}
        assert set(fits) == {'nystrom', 'cg'}
        assert abs(fits['cg'].slope - (-0.5)) < 1e-9

    def test_duplicate_bills_averaged_not_double_counted(self):
        doc = _ladder_doc()
        doc['rows'] += _ladder_doc()['rows']       # population repeat
        (f,) = fit_rates(doc)
        assert f.points == 4
        assert abs(f.slope - (-2.0)) < 1e-9

    def test_format_rates_shows_drift_and_new_ladders(self):
        base = fit_rates(_ladder_doc(slope=-2.0))
        new_doc = _ladder_doc(slope=-1.0)
        new_doc['rows'] += _ladder_doc(slope=-0.5, solver='cg')['rows']
        out = format_rates(base, fit_rates(new_doc))
        assert '-2.00 -> -1.00' in out
        assert '[new ladder]' in out

    def test_cli_fit_rates_prints_and_never_gates(self, tmp_path, capsys):
        base = _write(tmp_path, 'base', _ladder_doc(slope=-2.0))
        new = _write(tmp_path, 'new', _ladder_doc(slope=-0.25))
        # a collapsed rate alone is not a regression: same cells, same
        # errors per cell would be needed for that — here errors differ, so
        # compare under a huge tolerance to isolate the flag's behaviour
        rc = compare_main([base, new, '--no-wall', '--tol-error', '1e9',
                           '--fit-rates'])
        out = capsys.readouterr().out
        assert rc == 0
        assert 'rate fits' in out and '-2.00 -> -0.25' in out
