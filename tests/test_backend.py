"""Contraction-backend parity: tree / flat / flat_sharded / pallas agree.

The four backends implement the same four tall-skinny contractions over
different operand representations (per-leaf pytree einsums, one fused XLA
matmul, per-device fused shards + psum, Pallas TPU kernels). Any divergence
beyond f32 accumulation noise is a bug in the fusion or the kernel tiling —
the shapes below deliberately hit the padding edges (k not a multiple of the
128-lane width, p not a multiple of block_p).

flat_sharded runs here on a single-device mesh (the degenerate-but-complete
case: same fuse/psum code path, one shard); the real multi-device parity
suite is tests/test_backend_sharded.py, which re-launches itself under
XLA_FLAGS=--xla_force_host_platform_device_count=8.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import Mesh, PartitionSpec as P

from repro.core import (FlatShardedBackend, NystromIHVP, PallasBackend,
                        PyTreeIndexer, flatten_sketch, flatten_vec,
                        get_backend, make_hvp, tree_random_like,
                        unflatten_vec)

# p = 8 + 999 + 4 + 1 = 1012: not a multiple of any block size; leaves span
# rank 1/2/0 and odd sizes.
PARAMS = {'w': jnp.zeros((8,)), 'm': jnp.zeros((27, 37)), 'b': jnp.zeros((2, 2)),
          's': jnp.zeros(())}


# the canonical flattener is itself under test (test_flatten_roundtrip
# checks it against a hand-rolled oracle); elsewhere it is the comparator.
_flat = flatten_vec


def _random_sketch(k, seed=0):
    """Leading-k pytree + matching v, the raw material of every contraction."""
    keys = jax.random.split(jax.random.PRNGKey(seed), 2)
    C = jax.tree.map(lambda l: jax.random.normal(keys[0], (k,) + l.shape),
                     PARAMS)
    v = tree_random_like(keys[1], PARAMS)
    return C, v


def _mesh1():
    """Single-device mesh: flat_sharded's degenerate case (one shard)."""
    return Mesh(np.array(jax.devices()[:1]), ('model',))


def _instances():
    # small block_p so the 1012-element flat buffer spans several grid steps
    # with a ragged tail; interpret=True keeps pallas runnable off-TPU.
    # flat_sharded's specs name an axis the 1-device mesh can't split —
    # sanitize_spec degrades every entry to replication (size-1 axis).
    return {'tree': get_backend('tree'),
            'flat': get_backend('flat'),
            'flat_sharded': FlatShardedBackend(
                mesh=_mesh1(),
                specs={'w': P('model'), 'm': P(None, 'model'),
                       'b': P(), 's': P()}),
            'pallas': PallasBackend(interpret=True, block_p=128)}


@pytest.mark.parametrize('k', [5, 33, 128])
def test_primitive_parity(k):
    """gram / ctv / cv / mul_right / combine agree across backends."""
    C_tree, v = _random_sketch(k, seed=k)
    w = jax.random.normal(jax.random.PRNGKey(k + 1), (k,))
    M = jax.random.normal(jax.random.PRNGKey(k + 2), (k, 3))
    rho = 0.05
    out = {}
    for name, be in _instances().items():
        C = be.prepare_operand(C_tree)
        vf = be.vec(v)
        out[name] = {
            'gram': be.gram(C),
            'ctv': be.ctv(C, vf),
            'cv': _flat(be.unvec(be.cv(C, w), v)),
            'mul': be.gram(be.mul_right(C, M)),
            'combine': _flat(be.unvec(be.combine(C, w, vf, rho), v)),
        }
    for name in (n for n in out if n != 'tree'):
        for op in out['tree']:
            ref, got = out['tree'][op], out[name][op]
            tol = 1e-4 * (np.abs(np.asarray(ref)).max() + 1.0)
            np.testing.assert_allclose(got, ref, rtol=1e-4, atol=tol,
                                       err_msg=f'{name}:{op} (k={k})')


def test_flatten_roundtrip():
    C_tree, v = _random_sketch(7)

    def oracle_flat(tree):                     # independent of flatten_vec
        return np.concatenate([np.asarray(x, np.float32).ravel()
                               for x in jax.tree.leaves(tree)])

    Cf = flatten_sketch(C_tree)
    assert Cf.shape == (7, oracle_flat(v).size)
    np.testing.assert_allclose(flatten_vec(v), oracle_flat(v))
    back = unflatten_vec(flatten_vec(v), v)
    for a, b in zip(jax.tree.leaves(back), jax.tree.leaves(v)):
        np.testing.assert_array_equal(a, b)
    # row j of the fused buffer == flattened column j of the pytree sketch
    row3 = oracle_flat(jax.tree.map(lambda c: c[3], C_tree))
    np.testing.assert_allclose(Cf[3], row3)


def _quadratic_setup(seed=0):
    idxr = PyTreeIndexer(PARAMS)
    p = idxr.total
    B = jax.random.normal(jax.random.PRNGKey(seed), (p, 16))
    Hm = B @ B.T / p + 0.5 * jnp.eye(p)
    def loss(prm, hp, batch):
        th = _flat(prm)
        return 0.5 * th @ Hm @ th
    hvp = make_hvp(loss, PARAMS, None, None)
    v = tree_random_like(jax.random.PRNGKey(seed + 1), PARAMS)
    return idxr, hvp, v


@pytest.mark.parametrize('stabilized', [True, False])
@pytest.mark.parametrize('k', [10, 33])
def test_solver_apply_parity(stabilized, k):
    """End-to-end: same rng ⇒ same sketch columns ⇒ same IHVP, per backend."""
    idxr, hvp, v = _quadratic_setup(seed=11)
    rng = jax.random.PRNGKey(12)
    outs = {}
    for name, be in _instances().items():
        solver = NystromIHVP(k=k, rho=1e-2, stabilized=stabilized, backend=be)
        outs[name] = _flat(solver.solve(hvp, idxr, v, rng))
    scale = np.abs(np.asarray(outs['tree'])).max()
    for name in (n for n in outs if n != 'tree'):
        np.testing.assert_allclose(outs[name] / scale, outs['tree'] / scale,
                                   atol=2e-5, err_msg=f'{name} k={k}')


@pytest.mark.parametrize('kappa', [1, 4])
def test_solver_chunked_parity(kappa):
    """Alg. 1 chunked-Woodbury path agrees across backends for every κ."""
    idxr, hvp, v = _quadratic_setup(seed=21)
    rng = jax.random.PRNGKey(22)
    outs = {}
    for name, be in _instances().items():
        solver = NystromIHVP(k=12, rho=0.1, kappa=kappa, backend=be)
        outs[name] = _flat(solver.solve(hvp, idxr, v, rng))
    scale = np.abs(np.asarray(outs['tree'])).max()
    for name in (n for n in outs if n != 'tree'):
        np.testing.assert_allclose(outs[name] / scale, outs['tree'] / scale,
                                   atol=2e-4, err_msg=f'{name} kappa={kappa}')


def test_backend_through_hypergrad_config():
    """HypergradConfig(backend=...) reaches the solver and changes nothing
    numerically (f32 tolerance)."""
    from repro.core import HypergradConfig
    idxr, hvp, v = _quadratic_setup(seed=31)
    outs = {}
    for backend in ('tree', 'flat'):
        solver = HypergradConfig(solver='nystrom', k=8, rho=1e-2,
                                 backend=backend).build()
        assert solver.backend == backend
        outs[backend] = _flat(solver.solve(hvp, idxr, v,
                                           jax.random.PRNGKey(32)))
    np.testing.assert_allclose(outs['flat'], outs['tree'], rtol=1e-4,
                               atol=1e-4)


@pytest.mark.parametrize('name', ['flat', 'pallas', 'flat_sharded'])
def test_bf16_sketch_storage(name):
    """sketch_dtype=bf16 halves the fused buffer; contractions accumulate
    f32, so error stays at bf16-rounding (~1e-2 rel), not bf16-accumulation
    scale."""
    C_tree, v = _random_sketch(16, seed=5)
    ref_be = get_backend('tree')
    ref = {'ctv': ref_be.ctv(C_tree, v), 'gram': ref_be.gram(C_tree)}
    if name == 'flat_sharded':
        be = FlatShardedBackend(mesh=_mesh1(), sketch_dtype=jnp.bfloat16)
    elif name == 'pallas':
        be = PallasBackend(interpret=True, block_p=128,
                           sketch_dtype=jnp.bfloat16)
    else:
        be = get_backend(name, sketch_dtype=jnp.bfloat16)
    C = be.prepare_operand(C_tree)
    buf = C.buf if name == 'flat_sharded' else C
    assert buf.dtype == jnp.bfloat16
    assert buf.nbytes * 2 == buf.size * 4          # half of f32 storage
    for op, got in (('ctv', be.ctv(C, be.vec(v))), ('gram', be.gram(C))):
        assert got.dtype == jnp.float32            # f32 accumulation
        scale = np.abs(np.asarray(ref[op])).max() + 1e-9
        np.testing.assert_allclose(np.asarray(got) / scale,
                                   np.asarray(ref[op]) / scale, atol=2e-2,
                                   err_msg=f'{name}:{op}')


def test_hypergrad_config_flat_sharded_and_sketch_dtype():
    """HypergradConfig builds a bound FlatShardedBackend from mesh/specs,
    threads sketch_dtype through, and rejects nonsense combinations."""
    from repro.core import HypergradConfig
    cfg = HypergradConfig(backend='flat_sharded', mesh=_mesh1(),
                          param_specs=None, sketch_dtype='bfloat16')
    be = cfg.build().backend
    assert isinstance(be, FlatShardedBackend)
    assert be.sketch_dtype == jnp.bfloat16
    idxr, hvp, v = _quadratic_setup(seed=51)
    tree_u = _flat(HypergradConfig(k=8).build().solve(
        hvp, idxr, v, jax.random.PRNGKey(52)))
    shrd_u = _flat(HypergradConfig(k=8, backend='flat_sharded',
                                   mesh=_mesh1()).build().solve(
        hvp, idxr, v, jax.random.PRNGKey(52)))
    np.testing.assert_allclose(shrd_u, tree_u, rtol=1e-4, atol=1e-4)
    with pytest.raises(ValueError, match='sketch_dtype'):
        HypergradConfig(backend='tree', sketch_dtype='bfloat16').build()
    with pytest.raises(ValueError, match='pre-built'):
        # config fields must not be silently ignored for instance backends
        HypergradConfig(backend=get_backend('flat'),
                        sketch_dtype='bfloat16').build()
    with pytest.raises(ValueError, match='flat_sharded'):
        HypergradConfig(backend='flat', mesh=_mesh1()).build()
    with pytest.raises(ValueError, match='requires a mesh'):
        get_backend('flat_sharded')


def test_unknown_backend_rejected():
    with pytest.raises(ValueError, match='unknown backend'):
        get_backend('gpu4life')


def test_apply_under_jit_flat():
    """Flat-backend prepare+apply jit cleanly (sketch is a valid pytree)."""
    idxr, hvp, v = _quadratic_setup(seed=41)
    solver = NystromIHVP(k=6, rho=1e-2, backend='flat')

    @jax.jit
    def run(rng):
        sketch = solver.prepare(hvp, idxr, rng)
        return solver.apply(sketch, v)

    u = run(jax.random.PRNGKey(42))
    assert jnp.isfinite(_flat(u)).all()
