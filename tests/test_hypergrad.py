"""Hypergradient assembly tests: analytic quadratic bilevel + bilevel driver."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (BilevelTrainer, CGIHVP, ExactIHVP, HypergradConfig,
                        NystromIHVP, hypergradient, unrolled_hypergradient)
from repro.optim import adam, sgd


def _quadratic_bilevel(seed=0, P=12, Hdim=5):
    k1, k2, k3, k4 = jax.random.split(jax.random.PRNGKey(seed), 4)
    Am = jax.random.normal(k1, (P, P))
    Am = Am @ Am.T / P + jnp.eye(P)
    Bm = jax.random.normal(k2, (P, Hdim))
    c = jax.random.normal(k3, (P,))
    t = jax.random.normal(k4, (P,))

    def inner(prm, hp, batch):
        th = prm['theta']
        return 0.5 * th @ Am @ th - th @ (Bm @ hp['phi'] + c)

    def outer(prm, hp, batch):
        return 0.5 * jnp.sum((prm['theta'] - t) ** 2)

    phi0 = jnp.ones((Hdim,))
    theta_star = jnp.linalg.solve(Am, Bm @ phi0 + c)
    return inner, outer, {'theta': theta_star}, {'phi': phi0}, Am, Bm, t


@pytest.mark.parametrize('solver_name', ['exact', 'nystrom', 'cg'])
def test_hypergrad_matches_analytic(solver_name):
    inner, outer, params, hparams, Am, Bm, t = _quadratic_bilevel()
    P = Am.shape[0]
    rho = 1e-3
    analytic = Bm.T @ jnp.linalg.solve(Am + rho * jnp.eye(P),
                                       params['theta'] - t)
    solver = {'exact': ExactIHVP(rho=rho),
              'nystrom': NystromIHVP(k=P, rho=rho),
              'cg': CGIHVP(iters=5 * P, rho=rho)}[solver_name]
    hg = hypergradient(inner, outer, params, hparams, None, None, solver,
                       jax.random.PRNGKey(1))
    np.testing.assert_allclose(hg['phi'], analytic, rtol=2e-3, atol=2e-3)


def test_unrolled_matches_analytic():
    inner, outer, params, hparams, Am, Bm, t = _quadratic_bilevel()
    analytic = Bm.T @ jnp.linalg.solve(Am, params['theta'] - t)
    hg = unrolled_hypergradient(inner, outer, params, hparams, None, None,
                                steps=800, lr=0.05)
    np.testing.assert_allclose(hg['phi'], analytic, rtol=1e-3, atol=1e-3)


def test_direct_outer_grad_term():
    """∂g/∂φ ≠ 0 must appear additively (Eq. 3's last term)."""
    inner, outer0, params, hparams, Am, Bm, t = _quadratic_bilevel()

    def outer(prm, hp, batch):
        return outer0(prm, hp, batch) + 3.0 * jnp.sum(hp['phi'])

    hg0 = hypergradient(inner, outer0, params, hparams, None, None,
                        ExactIHVP(rho=1e-3), jax.random.PRNGKey(2))
    hg1 = hypergradient(inner, outer, params, hparams, None, None,
                        ExactIHVP(rho=1e-3), jax.random.PRNGKey(2))
    np.testing.assert_allclose(hg1['phi'] - hg0['phi'], 3.0, rtol=1e-5)


def test_hypergrad_under_jit():
    inner, outer, params, hparams, Am, Bm, t = _quadratic_bilevel()
    solver = NystromIHVP(k=8, rho=1e-2)

    @jax.jit
    def hg_fn(params, hparams, rng):
        return hypergradient(inner, outer, params, hparams, None, None,
                             solver, rng)

    hg = hg_fn(params, hparams, jax.random.PRNGKey(3))
    assert jnp.isfinite(hg['phi']).all()
    # dynamic index sampling ⇒ a new rng must NOT retrace
    n0 = hg_fn._cache_size()
    hg_fn(params, hparams, jax.random.PRNGKey(4))
    assert hg_fn._cache_size() == n0


def test_bilevel_trainer_reduces_outer_loss():
    """Weight-decay-style toy bilevel run: outer loss must go down."""
    key = jax.random.PRNGKey(5)
    D = 10
    w_true = jax.random.normal(key, (D,))
    X = jax.random.normal(jax.random.PRNGKey(6), (128, D))
    y = X @ w_true
    Xv = jax.random.normal(jax.random.PRNGKey(7), (128, D))
    yv = Xv @ w_true

    def inner(prm, hp, batch):
        Xb, yb = batch
        pred = Xb @ prm['w']
        decay = jnp.sum(jax.nn.softplus(hp['log_wd']) * prm['w'] ** 2)
        return jnp.mean((pred - yb) ** 2) + decay

    def outer(prm, hp, batch):
        Xb, yb = batch
        return jnp.mean((Xb @ prm['w'] - yb) ** 2)

    trainer = BilevelTrainer(
        inner_loss=inner, outer_loss=outer,
        inner_opt=sgd(0.05), outer_opt=adam(0.05),
        hypergrad=HypergradConfig(solver='nystrom', k=10, rho=1e-2))
    state = trainer.init(jax.random.PRNGKey(8),
                         {'w': jnp.zeros((D,))},
                         {'log_wd': jnp.zeros((D,)) + 1.0})

    def batches(X, y):
        while True:
            yield (X, y)

    state, hist = trainer.run(state, batches(X, y), batches(Xv, yv),
                              steps_per_outer=30, n_outer=10)
    assert hist['outer_loss'][-1] < hist['outer_loss'][0]
    assert np.isfinite(hist['outer_loss']).all()


def test_sketch_reuse_is_consistent():
    """Amortized sketch (outer_step_with_sketch) ≈ fresh-sketch step."""
    inner, outer, params, hparams, Am, Bm, t = _quadratic_bilevel()
    trainer = BilevelTrainer(
        inner_loss=inner, outer_loss=outer,
        inner_opt=sgd(0.01), outer_opt=sgd(0.1),
        hypergrad=HypergradConfig(solver='nystrom', k=12, rho=1e-3))
    state = trainer.init(jax.random.PRNGKey(9), params, hparams)
    sketch, state2 = trainer.build_sketch(state, None)
    s_a, _ = trainer.outer_step_with_sketch(state2, sketch, None, None)
    s_b, _ = trainer.outer_step_fn(state, None, None)
    # quadratic ⇒ H is constant ⇒ sketch reuse is exact (same k=P columns)
    np.testing.assert_allclose(s_a.hparams['phi'], s_b.hparams['phi'],
                               rtol=1e-3, atol=1e-3)
