"""BilevelProblem / solve() tests: registry round-trip, solve-vs-trainer
trajectory equivalence on the quadratic task, the vmap_tasks meta path, and
a shared-sketch tab4-style amortization smoke.
"""
import itertools

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (BilevelProblem, BilevelTrainer, HypergradConfig,
                        PROBLEMS, accounted_hvps, get_problem, solve)
from repro.data.sources import ArraySource, EpisodeSource
from repro.tasks import (build_imaml, build_logreg_weight_decay,
                         build_reweighting)


def _quadratic_problem(P=10, Hdim=4, seed=0):
    """Analytic quadratic bilevel task as a BilevelProblem (batch-free
    losses over a dummy ArraySource)."""
    k1, k2, k3, k4 = jax.random.split(jax.random.PRNGKey(seed), 4)
    Am = jax.random.normal(k1, (P, P))
    Am = Am @ Am.T / P + jnp.eye(P)
    Bm = jax.random.normal(k2, (P, Hdim))
    c = jax.random.normal(k3, (P,))
    t = jax.random.normal(k4, (P,))

    def inner(prm, hp, batch):
        th = prm['theta']
        return 0.5 * th @ Am @ th - th @ (Bm @ hp['phi'] + c)

    def outer(prm, hp, batch):
        return 0.5 * jnp.sum((prm['theta'] - t) ** 2)

    dummy = (jnp.zeros((8, 1)), jnp.zeros((8,), jnp.int32))
    return BilevelProblem(
        name='quadratic', inner_loss=inner, outer_loss=outer,
        init_params=lambda rng: {'theta': jnp.zeros((P,))},
        init_hparams=lambda rng: {'phi': jnp.ones((Hdim,))},
        data=ArraySource(train=dummy, val=dummy),
        defaults=dict(inner_lr=0.05, outer_lr=0.1, steps_per_outer=3,
                      batch_size=4))


class TestRegistry:
    def test_paper_tasks_registered(self):
        assert {'logreg_wd', 'distillation', 'imaml',
                'reweighting'} <= set(PROBLEMS)

    def test_round_trip_with_kwargs(self):
        p = get_problem('reweighting', imbalance=50, d=16)
        assert isinstance(p, BilevelProblem)
        assert p.name == 'reweighting'
        assert callable(p.inner_loss) and callable(p.baseline_loss)
        assert p.data.train[0].shape[1] == 16

    def test_unknown_name_lists_registered(self):
        with pytest.raises(ValueError, match='unknown problem'):
            get_problem('nonexistent_task')


class TestNoLegacyAdapter:
    def test_dict_adapter_is_gone(self):
        """The one-release deprecation window closed: BilevelProblem is a
        plain typed dataclass — no dict-style access, no legacy builders."""
        p = build_logreg_weight_decay(D=12, n=40)
        assert not hasattr(p, 'as_legacy_dict')
        assert not hasattr(BilevelProblem, 'from_legacy_dict')
        with pytest.raises(TypeError):
            p['inner']  # noqa: B018  (subscript must no longer be supported)


class TestSolveTrainerEquivalence:
    @pytest.mark.parametrize('solver_name', ['nystrom', 'cg'])
    def test_solve_matches_manual_trainer_run(self, solver_name):
        """solve() is exactly the from_problem trainer driven over the
        problem's batch streams — same seeds ⇒ identical trajectory."""
        problem = _quadratic_problem()
        cfg = HypergradConfig(solver=solver_name, k=8, rho=1e-2)
        res = solve(problem, cfg, n_outer=4, seed=0)

        trainer = BilevelTrainer.from_problem(problem, cfg)
        rng = jax.random.PRNGKey(0)
        state = trainer.init(rng, problem.init_params(rng),
                             problem.init_hparams(rng))
        train_it = (problem.data.train_batch(i, 4) for i in itertools.count())
        val_it = (problem.data.val_batch(i, 4) for i in itertools.count())
        state, hist = trainer.run(state, train_it, val_it,
                                  steps_per_outer=3, n_outer=4)
        np.testing.assert_allclose(res.hparams['phi'], state.hparams['phi'],
                                   rtol=0, atol=0)
        assert res.history['outer_loss'] == hist['outer_loss']
        assert res.metrics == {}

    def test_defaults_and_overrides_resolve(self):
        problem = _quadratic_problem()
        res = solve(problem, HypergradConfig(solver='exact', rho=1e-2),
                    n_outer=2, steps_per_outer=1, batch_size=2)
        assert len(res.history['outer_loss']) == 2
        assert len(res.history['inner_loss']) == 2   # 1 inner step × 2 outer
        # exact solver: one dense factor build per outer step, p HVPs each
        assert res.hvp_count == 2 * 10


class TestHvpAccounting:
    def test_amortized_cadence_reduces_hvps(self):
        problem = _quadratic_problem()
        cfg = HypergradConfig(solver='nystrom', k=6, rho=1e-2)
        solver = cfg.build()
        assert accounted_hvps(solver, problem, 8) == 8 * 6
        assert accounted_hvps(solver, problem, 8, refresh_every=4) == 2 * 6
        # reset_inner invalidates: one rebuild per outer step regardless
        assert accounted_hvps(solver, problem, 8, refresh_every=4,
                              reset_inner=True) == 8 * 6

    def test_iterative_pays_per_step(self):
        problem = _quadratic_problem()
        solver = HypergradConfig(solver='cg', k=5, rho=0.0).build()
        assert accounted_hvps(solver, problem, 8) == 8 * 5
        assert accounted_hvps(solver, problem, 8, refresh_every=4) == 8 * 5


class TestSharedSketchSmoke:
    def test_tab4_style_amortization(self):
        """tab4 workload shape (reweighting, warm start): amortizing one
        sketch over all outer steps cuts the HVP bill and provably takes
        the reuse path (cadence 1 is bit-for-bit the fresh trajectory, so
        any deviation at cadence N proves the stale sketch was applied)."""
        problem = build_reweighting(imbalance=50, d=16)
        cfg = HypergradConfig(solver='nystrom', k=4, rho=1e-2)
        fresh = solve(problem, cfg, n_outer=4, steps_per_outer=2,
                      batch_size=32, seed=0)
        amort = solve(problem, cfg, n_outer=4, steps_per_outer=2,
                      batch_size=32, seed=0, sketch_refresh_every=4)
        assert fresh.hvp_count == 4 * 4
        assert amort.hvp_count == 4          # one build serves all 4 steps
        assert amort.hvp_count < fresh.hvp_count
        # step 0 shares the build; later steps diverge (stale linearization)
        assert fresh.history['outer_loss'][0] == amort.history['outer_loss'][0]
        fresh_flat = np.concatenate([np.ravel(x) for x in
                                     jax.tree.leaves(fresh.hparams)])
        amort_flat = np.concatenate([np.ravel(x) for x in
                                     jax.tree.leaves(amort.hparams)])
        assert not np.array_equal(fresh_flat, amort_flat)
        # ... but only by the staleness error, not divergence
        np.testing.assert_allclose(fresh_flat, amort_flat, atol=0.05)
        for m in (fresh, amort):
            assert 0.0 <= m.metrics['accuracy'] <= 1.0


class TestVmapTasksMetaPath:
    def test_shared_sketch_cuts_meta_batch_hvps(self):
        problem = build_imaml()
        cfg = HypergradConfig(solver='nystrom', k=4, rho=1e-2)
        shared = solve(problem, cfg, n_outer=2, steps_per_outer=3,
                       vmap_tasks=2, shared_sketch=True, seed=0)
        per_task = solve(problem, cfg, n_outer=2, steps_per_outer=3,
                         vmap_tasks=2, seed=0)
        assert shared.hvp_count == 2 * 4             # k per meta-batch
        assert per_task.hvp_count == 2 * 2 * 4       # k per task
        assert shared.params is None
        for r in (shared, per_task):
            assert len(r.history['outer_loss']) == 2
            assert all(np.isfinite(x) for x in r.history['outer_loss'])
        # same meta-objective: the two estimators stay closely aligned
        a = np.concatenate([np.ravel(x) for x in
                            jax.tree.leaves(shared.hparams)])
        b = np.concatenate([np.ravel(x) for x in
                            jax.tree.leaves(per_task.hparams)])
        cos = float(a @ b / (np.linalg.norm(a) * np.linalg.norm(b) + 1e-30))
        assert cos > 0.99

    def test_meta_source_rejects_flat_stream(self):
        problem = build_imaml()
        with pytest.raises(TypeError, match='vmap_tasks'):
            solve(problem, HypergradConfig(solver='nystrom', k=4),
                  n_outer=1)

    def test_vmap_tasks_needs_episode_source(self):
        problem = _quadratic_problem()
        with pytest.raises(TypeError, match='task_batch'):
            solve(problem, HypergradConfig(solver='nystrom', k=4),
                  n_outer=1, vmap_tasks=2)

    def test_shared_sketch_rejects_iterative_solver(self):
        problem = build_imaml()
        with pytest.raises(TypeError, match='amortizable'):
            solve(problem, HypergradConfig(solver='cg', k=4, rho=0.0),
                  n_outer=1, vmap_tasks=2, shared_sketch=True)


class TestEpisodeSource:
    def test_task_batch_shapes_and_no_flat_stream(self):
        problem = build_imaml()
        src = problem.data
        assert isinstance(src, EpisodeSource)
        (sx, sy), (qx, qy) = src.task_batch(0, 3)
        assert sx.shape[0] == 3 and qx.shape[0] == 3
        assert sy.shape[:1] == (3,)
        with pytest.raises(TypeError, match='meta-problem'):
            src.train_batch(0, 8)
