"""Repo-rule AST lint: each rule on minimal sources, suppression syntax,
and the gate CI enforces — ``lint_paths`` clean over the shipped tree."""
import pathlib
import textwrap

from repro.analysis import lint_paths, lint_source

REPO = pathlib.Path(__file__).resolve().parents[1]


def _rules(findings):
    return [f.rule for f in findings]


# ---------------------------------------------------------------- prng rule
class TestPrngKeyReuse:
    def test_duplicate_literal_key_flagged(self):
        src = textwrap.dedent("""
            import jax
            a = jax.random.PRNGKey(0)
            b = jax.random.PRNGKey(0)
        """)
        findings = lint_source(src)
        assert _rules(findings) == ['prng-key-reuse']
        assert findings[0].line == 4

    def test_distinct_keys_pass(self):
        src = textwrap.dedent("""
            import jax
            a = jax.random.PRNGKey(0)
            b = jax.random.PRNGKey(1)
            c = jax.random.fold_in(a, 1)
        """)
        assert lint_source(src) == []

    def test_scopes_are_independent(self):
        # the same seed in two different functions is two different streams
        src = textwrap.dedent("""
            import jax
            def f():
                return jax.random.PRNGKey(0)
            def g():
                return jax.random.PRNGKey(0)
        """)
        assert lint_source(src) == []

    def test_nonliteral_args_not_tracked(self):
        src = textwrap.dedent("""
            import jax
            for i in range(3):
                k = jax.random.PRNGKey(i)
        """)
        assert lint_source(src) == []


# ----------------------------------------------------------- host-sync rule
class TestTracedHostSync:
    def test_float_inside_jit_flagged(self):
        src = textwrap.dedent("""
            import jax
            @jax.jit
            def step(x):
                return float(x.sum())
        """)
        assert _rules(lint_source(src)) == ['traced-host-sync']

    def test_item_inside_scan_body_flagged(self):
        src = textwrap.dedent("""
            import jax
            def body(c, x):
                return c + x.item(), None
            def run(xs):
                return jax.lax.scan(body, 0.0, xs)
        """)
        assert _rules(lint_source(src)) == ['traced-host-sync']

    def test_float_outside_traced_code_passes(self):
        src = textwrap.dedent("""
            def log(x):
                return float(x)
        """)
        assert lint_source(src) == []

    def test_jax_tree_map_is_not_control_flow(self):
        # regression: jax.tree.map's callee is host code, not a scan body
        src = textwrap.dedent("""
            import jax, numpy as np
            def flatten(tree):
                return jax.tree.map(lambda l: np.asarray(l), tree)
        """)
        assert lint_source(src) == []


# ----------------------------------------------------------- bench-row rule
class TestBenchRowLiteral:
    SRC = textwrap.dedent("""
        def rows():
            return [{'solver': 'nystrom', 'backend': 'flat',
                     'applies_per_sec': 10.0, 'm': 4}]
    """)

    def test_identity_dict_outside_common_flagged(self):
        findings = lint_source(self.SRC, path='benchmarks/rogue.py')
        assert _rules(findings) == ['bench-row-literal']

    def test_common_py_is_the_sanctioned_writer(self):
        assert lint_source(self.SRC, path='benchmarks/common.py') == []

    def test_partial_key_overlap_passes(self):
        src = "row = {'solver': 's', 'backend': 'b'}\n"
        assert lint_source(src, path='benchmarks/x.py') == []


# ------------------------------------------------------ solver-protocol rule
class TestSolverProtocol:
    def test_incomplete_solver_flagged(self):
        src = textwrap.dedent("""
            class HalfIHVP:
                amortizable = True
                def prepare(self, hvp, idxr, rng): ...
                def apply(self, state, v): ...
            SOLVERS = {'half': SolverSpec(HalfIHVP, k=4)}
        """)
        findings = lint_source(src)
        assert _rules(findings) == ['solver-protocol']
        assert 'apply_matrix' in findings[0].message

    def test_complete_solver_passes(self):
        src = textwrap.dedent("""
            class FullIHVP:
                amortizable = True
                def prepare(self, hvp, idxr, rng): ...
                def apply(self, state, v): ...
                def apply_matrix(self, state, V): ...
            SOLVERS = {'full': SolverSpec(FullIHVP, k=4)}
        """)
        assert lint_source(src) == []

    def test_real_registry_satisfies_protocol(self):
        findings = lint_source(
            (REPO / 'src/repro/core/solvers.py').read_text(),
            path='src/repro/core/solvers.py')
        assert [f for f in findings if f.rule == 'solver-protocol'] == []


# -------------------------------------------------------------- suppression
class TestSuppression:
    def test_inline_allow(self):
        src = ("import jax\n"
               "a = jax.random.PRNGKey(0)\n"
               "b = jax.random.PRNGKey(0)  # repro: allow[prng-key-reuse]\n")
        assert lint_source(src) == []

    def test_comment_block_above(self):
        src = ("import jax\n"
               "a = jax.random.PRNGKey(0)\n"
               "# repro: allow[prng-key-reuse] — deliberate shared stream\n"
               "# (both variants must see identical randomness)\n"
               "b = jax.random.PRNGKey(0)\n")
        assert lint_source(src) == []

    def test_wrong_rule_name_does_not_suppress(self):
        src = ("import jax\n"
               "a = jax.random.PRNGKey(0)\n"
               "b = jax.random.PRNGKey(0)  # repro: allow[traced-host-sync]\n")
        assert _rules(lint_source(src)) == ['prng-key-reuse']

    def test_star_suppresses_everything(self):
        src = ("import jax\n"
               "a = jax.random.PRNGKey(0)\n"
               "b = jax.random.PRNGKey(0)  # repro: allow[*]\n")
        assert lint_source(src) == []

    def test_unrelated_code_line_breaks_the_block(self):
        src = ("import jax\n"
               "# repro: allow[prng-key-reuse]\n"
               "a = jax.random.PRNGKey(0)\n"
               "b = jax.random.PRNGKey(0)\n")
        assert _rules(lint_source(src)) == ['prng-key-reuse']


# ------------------------------------------------------------ parse errors
def test_syntax_error_reported_not_raised():
    findings = lint_source('def broken(:\n')
    assert _rules(findings) == ['parse-error']


# ------------------------------------------------------------- the CI gate
def test_repo_lints_clean():
    """Exactly what CI runs: the shipped tree has zero findings."""
    scope = [str(REPO / d) for d in ('src', 'examples', 'benchmarks',
                                     'tools')]
    findings = lint_paths(scope)
    assert findings == [], '\n'.join(f.render() for f in findings)
