"""Per-architecture smoke tests (assignment requirement): every assigned arch
instantiates a REDUCED same-family config, runs one forward/train step on CPU,
asserts output shapes + finiteness, and exercises the decode path.

The model-sweep tests (everything touching the ``built`` fixture) carry the
``slow`` marker: the full matrix takes ~4 minutes on CPU and is excluded from
the default tier-1 run (pyproject addopts ``-m 'not slow'``); CI opts in with
``-m slow``. The config-only checks at the bottom stay in tier-1.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCHS, SHAPES, all_cells, get_config, shape_applicable
from repro.models import build_model
from repro.models.transformer import fill_cross_cache

B, S = 2, 64


def _batch(cfg, rng, seq=S):
    ks = jax.random.split(rng, 3)
    labels = jax.random.randint(ks[0], (B, seq), 0, cfg.vocab_size)
    if cfg.is_encdec:
        return {'inputs': jax.random.randint(ks[1], (B, seq), 0, cfg.vocab_size),
                'labels': labels,
                'enc_inputs': jax.random.normal(ks[2], (B, seq, cfg.d_model))}
    if not cfg.embed_inputs:
        batch = {'inputs': jax.random.normal(ks[1], (B, seq, cfg.d_model)),
                 'labels': labels}
        if cfg.mrope:
            pos = jnp.broadcast_to(jnp.arange(seq), (B, seq))
            batch['positions'] = jnp.broadcast_to(pos[:, None, :], (B, 3, seq))
        return batch
    return {'inputs': jax.random.randint(ks[1], (B, seq), 0, cfg.vocab_size),
            'labels': labels}


@pytest.fixture(scope='module')
def built():
    """Init each reduced arch once per test session (CPU is single-core)."""
    cache = {}

    def get(arch):
        if arch not in cache:
            cfg = get_config(arch).reduced()
            m = build_model(cfg)
            cache[arch] = (cfg, m, m.init(jax.random.PRNGKey(0)))
        return cache[arch]

    return get


@pytest.mark.parametrize('arch', ARCHS)
@pytest.mark.slow
def test_train_step_shapes_and_finiteness(arch, built):
    cfg, m, params = built(arch)
    batch = _batch(cfg, jax.random.PRNGKey(1))
    loss, grads = jax.value_and_grad(m.train_loss)(params, batch)
    assert jnp.isfinite(loss), f'{arch}: non-finite loss'
    leaves = jax.tree.leaves(grads)
    assert all(jnp.isfinite(g).all() for g in leaves), f'{arch}: NaN grads'
    gnorm = jnp.sqrt(sum(jnp.vdot(g, g) for g in leaves))
    assert gnorm > 0, f'{arch}: zero gradient'
    logits, _ = m.forward(params, batch['inputs'],
                          positions=batch.get('positions'),
                          enc_inputs=batch.get('enc_inputs'))
    assert logits.shape == (B, S, cfg.padded_vocab)


@pytest.mark.parametrize('arch', ARCHS)
@pytest.mark.slow
def test_decode_step(arch, built):
    cfg, m, params = built(arch)
    cache = m.init_cache(B, 16)
    if cfg.is_encdec:
        enc = jax.random.normal(jax.random.PRNGKey(2), (B, cfg.cross_len, cfg.d_model))
        cache = fill_cross_cache(cfg, params, cache, m.encode(params, enc))
    if cfg.embed_inputs or cfg.is_encdec:
        tok = jnp.zeros((B, 1), jnp.int32)
    else:
        tok = jax.random.normal(jax.random.PRNGKey(3), (B, 1, cfg.d_model))
    for t in range(3):
        logits, cache = m.decode_step(params, tok, cache)
    assert logits.shape == (B, 1, cfg.padded_vocab)
    assert jnp.isfinite(logits).all()
    assert int(cache['pos']) == 3


@pytest.mark.parametrize('arch', ['yi_9b', 'qwen2_7b', 'phi35_moe_42b_a66b',
                                  'rwkv6_1b6', 'jamba_v01_52b'])
@pytest.mark.slow
def test_decode_matches_forward(arch, built):
    """Incremental decode must reproduce teacher-forced logits exactly —
    catches cache/state threading bugs across attention, MoE, SSM, RWKV."""
    cfg, m, params = built(arch)
    seq = 8
    toks = jax.random.randint(jax.random.PRNGKey(4), (B, seq), 0, cfg.vocab_size)
    full, _ = m.forward(params, toks)
    cache = m.init_cache(B, seq)
    outs = []
    for t in range(seq):
        lg, cache = m.decode_step(params, toks[:, t:t + 1], cache)
        outs.append(lg[:, 0])
    dec = jnp.stack(outs, axis=1)
    np.testing.assert_allclose(np.asarray(dec), np.asarray(full),
                               rtol=2e-3, atol=2e-3)


@pytest.mark.slow
def test_chunked_attention_matches_full(built):
    """Online-softmax path == plain softmax path (the 32k-prefill machinery)."""
    import dataclasses
    cfg, m, params = built('yi_9b')
    toks = jax.random.randint(jax.random.PRNGKey(5), (B, 64), 0, cfg.vocab_size)
    full, _ = m.forward(params, toks)
    cfg_chunked = dataclasses.replace(cfg, attn_chunk=16)
    m2 = build_model(cfg_chunked)
    chunked, _ = m2.forward(params, toks)
    np.testing.assert_allclose(np.asarray(chunked), np.asarray(full),
                               rtol=2e-3, atol=2e-3)


@pytest.mark.slow
def test_scan_layers_matches_python_loop(built):
    import dataclasses
    cfg, m, params = built('qwen2_7b')
    toks = jax.random.randint(jax.random.PRNGKey(6), (B, 16), 0, cfg.vocab_size)
    scanned, _ = m.forward(params, toks)
    cfg_loop = dataclasses.replace(cfg, scan_layers=False)
    m2 = build_model(cfg_loop)
    blocks = params['blocks']
    nb = cfg.n_blocks
    loop_params = dict(params)
    loop_params['blocks'] = [jax.tree.map(lambda a, i=i: a[i], blocks)
                             for i in range(nb)]
    looped, _ = m2.forward(loop_params, toks)
    np.testing.assert_allclose(np.asarray(looped), np.asarray(scanned),
                               rtol=1e-4, atol=1e-4)


@pytest.mark.slow
def test_moe_is_dropless_and_weighted(built):
    """Uniform router ⇒ top-k weights renormalize; output stays finite and
    no token is dropped (loss gradient reaches every expert eventually)."""
    cfg, m, params = built('phi35_moe_42b_a66b')
    batch = _batch(cfg, jax.random.PRNGKey(7))
    loss, grads = jax.value_and_grad(m.train_loss)(params, batch)
    w1g = grads['blocks']['slot0']['ffn']['w1']
    # every expert receives gradient from a 128-token batch w.h.p.
    per_expert = jnp.abs(w1g).sum(axis=(0, 2, 3))
    assert (per_expert > 0).mean() > 0.9


def test_cell_matrix_covers_40():
    cells = all_cells()
    assert len(cells) == 40
    runnable = [c for c in cells if c[2]]
    skipped = [c for c in cells if not c[2]]
    assert len(skipped) == 8          # long_500k × 8 full-attention archs
    assert {a for a, s, ok, w in skipped} == {
        'llama3_405b', 'mistral_large_123b', 'yi_9b', 'qwen2_7b',
        'qwen2_vl_7b', 'llama4_maverick_400b_a17b', 'phi35_moe_42b_a66b',
        'seamless_m4t_large_v2'}
    assert all(s.name == 'long_500k' for a, s, ok, w in skipped)


@pytest.mark.parametrize('arch', ARCHS)
def test_param_count_sanity(arch):
    """Config-derived totals track published sizes (loose 15% band)."""
    published = {
        'llama3_405b': 405e9, 'mistral_large_123b': 123e9, 'yi_9b': 8.8e9,
        'qwen2_7b': 7.6e9, 'qwen2_vl_7b': 7.6e9,
        'llama4_maverick_400b_a17b': 400e9, 'phi35_moe_42b_a66b': 42e9,
        'seamless_m4t_large_v2': 2.3e9, 'jamba_v01_52b': 52e9,
        'rwkv6_1b6': 1.6e9}
    n = get_config(arch).param_count()
    assert abs(n - published[arch]) / published[arch] < 0.15
