"""Engine tests: graph validation, trilevel end-to-end solves, dense-oracle
parity, per-edge HVP accounting, and the compile/program contracts.

The oracle-parity pair pins both accuracy regimes documented in
``repro.engine.problems``: quadratic solved levels (reweight_maml) must
match the dense multi-level oracle to ≤1e-3, while the genuinely
non-quadratic distillation middle level carries a documented few-1e-3
AID-convention discrepancy and gets a looser (but still pinned) bar.
"""
import jax
import jax.numpy as jnp
import pytest

from repro.analysis import Contract, assert_compiles, audit
from repro.core import ExactIHVP, HypergradConfig, hypergrad_error
from repro.engine import (Engine, EngineConfig, GraphError, ProblemEdge,
                          ProblemGraph, ProblemNode, engine_edge_bills,
                          engine_hypergrad, engine_hypergrad_reference,
                          from_bilevel, get_graph)

# compact configurations: small dims keep every HVP and the dense oracles
# cheap; the nesting structure (the thing under test) is size-independent
REWEIGHT_KW = dict(d=4, n_tasks=2, n_support=8, n_query=8)
DISTILL_KW = dict(d=4, n_classes=2, n_syn=4, n_train=16, n_val=16)


# ---------------------------------------------------------------------------
# Graph validation
# ---------------------------------------------------------------------------
def _node(name):
    return ProblemNode(name=name,
                       loss=lambda own, ctx, batch: jnp.sum(own ** 2),
                       init=lambda rng: jnp.zeros(2))


class TestGraphValidation:
    def test_chain_validates_and_orders(self):
        g = ProblemGraph(
            nodes={n: _node(n) for n in ('a', 'b', 'c')},
            edges=[ProblemEdge('a', 'b'), ProblemEdge('b', 'c')])
        g.validate()
        assert g.topo_order() == ['a', 'b', 'c']
        assert g.chain_order() == ['a', 'b', 'c']
        assert g.tops() == ['c']

    def test_dangling_edge_rejected(self):
        g = ProblemGraph(nodes={'a': _node('a')},
                         edges=[ProblemEdge('a', 'ghost')])
        with pytest.raises(GraphError, match='ghost'):
            g.validate()

    def test_cycle_rejected(self):
        g = ProblemGraph(
            nodes={n: _node(n) for n in ('a', 'b')},
            edges=[ProblemEdge('a', 'b'), ProblemEdge('b', 'a')])
        with pytest.raises(GraphError, match='cycle'):
            g.validate()

    def test_duplicate_lower_rejected(self):
        g = ProblemGraph(
            nodes={n: _node(n) for n in ('a', 'b', 'c')},
            edges=[ProblemEdge('a', 'b'), ProblemEdge('a', 'c')])
        with pytest.raises(GraphError, match='exactly one IHVP solver'):
            g.validate()

    def test_self_loop_rejected(self):
        g = ProblemGraph(nodes={'a': _node('a'), 'b': _node('b')},
                         edges=[ProblemEdge('a', 'a')])
        with pytest.raises(GraphError, match='self-loop'):
            g.validate()

    def test_empty_edges_rejected(self):
        g = ProblemGraph(nodes={'a': _node('a')}, edges=[])
        with pytest.raises(GraphError, match='no edges'):
            g.validate()

    def test_non_chain_dag_validates_but_does_not_lower(self):
        # diamond: two lowers feeding one top — a valid DAG, not a chain
        g = ProblemGraph(
            nodes={n: _node(n) for n in ('a', 'b', 'top')},
            edges=[ProblemEdge('a', 'top'), ProblemEdge('b', 'top')])
        g.validate()
        with pytest.raises(GraphError, match='not a chain'):
            g.chain_order()

    def test_registry_miss_names_known_graphs(self):
        with pytest.raises(ValueError, match='distill_hpo'):
            get_graph('nope')


# ---------------------------------------------------------------------------
# Bilevel adapter — the engine's two-level special case stays consistent
# with the single-problem machinery it wraps
# ---------------------------------------------------------------------------
def test_from_bilevel_quadratic_matches_analytic():
    # inner: ½θᵀDθ − θᵀφ with D = diag(d) → θ*(φ) = φ/d; outer: ½‖θ*‖² has
    # the analytic hypergradient φ/d².
    d = jnp.array([1.0, 2.0, 4.0])

    class Quad:
        def inner_loss(self, theta, phi, batch):
            return 0.5 * jnp.sum(d * theta ** 2) - jnp.sum(theta * phi)

        def outer_loss(self, theta, phi, batch):
            return 0.5 * jnp.sum(theta ** 2)

        def init_params(self, rng):
            return jnp.zeros(3)

        def init_hparams(self, rng):
            return jnp.ones(3)

    g = from_bilevel(Quad(), config=HypergradConfig(solver='exact', rho=0.0),
                     unroll_steps=200, unroll_lr=0.2)
    g.validate()
    assert g.chain_order() == ['params', 'hparams']
    phi = jnp.ones(3)
    hg, _ = engine_hypergrad(g, {'params': phi / d, 'hparams': phi})
    assert jnp.allclose(hg, phi / d ** 2, atol=1e-4)


# ---------------------------------------------------------------------------
# Trilevel end-to-end — EngineConfig drives a registered graph through one
# jitted step; dense-oracle parity at the solved point
# ---------------------------------------------------------------------------
class TestTrilevelSolve:
    def test_reweight_maml_solves_and_matches_oracle(self):
        g = get_graph('reweight_maml', **REWEIGHT_KW)
        res = Engine().solve(g, EngineConfig(n_outer=3, outer_lr=0.05))
        assert len(res.losses) == 3
        assert all(jnp.isfinite(l) for l in res.losses)
        assert res.losses[-1] < res.losses[0]
        assert set(res.values) == {'adapted', 'meta', 'weights'}
        assert res.edge_hvps == engine_edge_bills(g, n_outer=3)
        assert res.hvp_count == sum(res.edge_hvps.values())

        # quadratic solved levels: full-rank sketches vs the dense oracle is
        # damping-dominated — the ≤1e-3 acceptance bar
        hg, _ = engine_hypergrad(g, res.values)
        ref, _ = engine_hypergrad_reference(g, res.values, rho=0.0)
        assert float(hypergrad_error(hg, ref)) < 1e-3

    def test_distill_hpo_solves_with_documented_parity(self):
        g = get_graph('distill_hpo', **DISTILL_KW)
        # adam needs a few steps to point the scalar top level downhill, so
        # this runs a slightly longer loop than the reweight test (the extra
        # steps reuse the one compiled program and cost milliseconds)
        res = Engine().solve(g, EngineConfig(n_outer=6, outer_lr=0.1))
        assert all(jnp.isfinite(l) for l in res.losses)
        assert res.losses[-1] < res.losses[0]

        # machinery parity is exact: the same graph solved with dense edges
        # matches the oracle bit-for-bit at matched damping
        g_exact = get_graph('distill_hpo', solver='exact', **DISTILL_KW)
        hx, _ = engine_hypergrad(g_exact, res.values)
        refd, _ = engine_hypergrad_reference(g_exact, res.values, rho=1e-4)
        assert float(hypergrad_error(hx, refd)) == 0.0

        # the non-quadratic middle level leaves a few-1e-3 *absolute*
        # Nyström-vs-dense gap under the AID convention (see
        # repro.engine.problems); against this size's small scalar top
        # gradient that reads as a few-1e-2 relative error, pinned here so
        # a regression past 5e-2 still fails loudly
        hg, _ = engine_hypergrad(g, res.values)
        ref, _ = engine_hypergrad_reference(g, res.values, rho=0.0)
        assert float(hypergrad_error(hg, ref)) < 5e-2

    def test_oracle_parity_is_exact_for_matched_solvers(self):
        # same machinery both sides: engine_hypergrad with the oracle's own
        # solver must agree bit-for-bit with engine_hypergrad_reference
        g = get_graph('reweight_maml', **REWEIGHT_KW)
        key = jax.random.PRNGKey(0)
        ks = jax.random.split(key, 3)
        values = {n: g.nodes[n].init(k)
                  for n, k in zip(g.chain_order(), ks)}
        ex = {n: ExactIHVP(rho=1e-4) for n in g.chain_order()[:-1]}
        hg, _ = engine_hypergrad(g, values, solvers=ex)
        ref, _ = engine_hypergrad_reference(g, values, rho=1e-4)
        assert float(hypergrad_error(hg, ref)) == 0.0


# ---------------------------------------------------------------------------
# Program contracts — one compile for the whole multi-level loop, and a
# lowering free of all-gathers / host transfers
# ---------------------------------------------------------------------------
class TestEngineContracts:
    def test_step_compiles_once_across_outer_steps(self):
        g = get_graph('reweight_maml', **REWEIGHT_KW)
        prog = Engine().lower(g, EngineConfig(n_outer=3))
        key = jax.random.PRNGKey(0)
        carry = prog.init(key)
        step = jax.jit(prog.step)
        assert_compiles(step, carry, jax.random.fold_in(key, 1),
                        times=1, calls=3)

    def test_step_program_is_device_resident(self):
        g = get_graph('reweight_maml', **REWEIGHT_KW)
        prog = Engine().lower(g, EngineConfig(n_outer=2))
        key = jax.random.PRNGKey(0)
        carry = prog.init(key)
        report = audit(prog.step, carry, jax.random.fold_in(key, 1))
        Contract(name='engine step', no_all_gather=True,
                 no_host_transfer=True).enforce(report)


# ---------------------------------------------------------------------------
# Accounting — amortization must survive nesting (additive bills), fresh
# prepares must not (multiplicative bills)
# ---------------------------------------------------------------------------
class TestEdgeBills:
    def test_amortized_bills_are_additive(self):
        g = get_graph('reweight_maml', **REWEIGHT_KW)
        bills = engine_edge_bills(g, n_outer=4)
        # full-rank defaults: k_adapted = T·d, k_meta = d; one build per step
        assert bills == {'adapted': 4 * 2 * 4, 'meta': 4 * 4}

    def test_refresh_cadence_divides_builds(self):
        g = get_graph('reweight_maml', refresh_every=2, **REWEIGHT_KW)
        bills = engine_edge_bills(g, n_outer=4)
        assert bills == {'adapted': 2 * 2 * 4, 'meta': 2 * 4}

    def test_fresh_bills_multiply_down_the_chain(self):
        g = get_graph('reweight_maml', **REWEIGHT_KW)
        amortized = engine_edge_bills(g, n_outer=4, amortize=True)
        fresh = engine_edge_bills(g, n_outer=4, amortize=False)
        # the top edge pays per-step prepares either way ...
        assert fresh['meta'] == 4 * 4
        # ... but the bottom edge is differentiated by every upper unroll
        # step and every upper prepare probe: orders of magnitude beyond the
        # additive amortized bill
        assert fresh['adapted'] > 10 * amortized['adapted']
