"""Observatory contract tests: error monotonicity, HVP bills, schema, filters.

One toy sweep (module-scoped: logreg at D=8, every registered solver, a
k-ladder at fixed damping, oracle damped identically) backs the accuracy
contracts; the parsing/filter tests are pure. The monotone-error contract
is the scientific core: more sketch columns / more iterations must not make
the hypergradient *worse* against the exact-IHVP oracle — if it does, a
solver regression slipped into the apply path.
"""
import json

import pytest

from benchmarks.check_bench_schema import check_file
from benchmarks.common import bench_row, write_bench
from repro.bench import (build_population, parse_grid, parse_problem_spec,
                         parse_vary, run_sweep, solver_grid_points)
from repro.bench.observatory import measure_cell

SPEC = 'logreg_wd:D=8:n=60'
RHO = 1e-2
KS = (2, 4, 8)


@pytest.fixture(scope='module')
def cells():
    return run_sweep((SPEC,), ('nystrom', 'cg', 'neumann', 'exact'),
                     {'k': KS, 'rho': (RHO,)}, tasks=2, oracle_rho=RHO,
                     reps=1, seed=0)


def _errs(cells, solver):
    by_k = {c.grid['k']: c.hypergrad_error for c in cells
            if c.solver == solver}
    return [by_k[k] for k in KS]


class TestErrorContract:
    def test_nystrom_error_nonincreasing_in_k(self, cells):
        errs = _errs(cells, 'nystrom')
        # 5% slack + absolute floor: the sketch draws different columns per
        # k, so adjacent rungs may tie — but more rank must never hurt
        for lo, hi in zip(errs[1:], errs[:-1]):
            assert lo <= hi * 1.05 + 1e-6, errs
        assert errs[-1] < errs[0], errs

    def test_cg_error_nonincreasing_in_iters(self, cells):
        errs = _errs(cells, 'cg')
        for lo, hi in zip(errs[1:], errs[:-1]):
            assert lo <= hi * 1.05 + 1e-6, errs
        assert errs[-1] < errs[0] * 1e-2, errs     # CG converges fast at p=8

    def test_full_rank_nystrom_matches_oracle(self, cells):
        # k = p = 8: the sketch spans the whole space, so the only residual
        # is roundoff (the oracle uses the same rho)
        assert _errs(cells, 'nystrom')[-1] < 1e-4

    def test_exact_solver_matches_oracle_exactly(self, cells):
        (cell,) = [c for c in cells if c.solver == 'exact']
        assert cell.hypergrad_error < 1e-6
        assert cell.err_max < 1e-6


class TestHvpBill:
    """Per-cell hvp_count is the analytic per-hypergradient bill."""

    def test_nystrom_bills_k(self, cells):
        for c in cells:
            if c.solver == 'nystrom':
                assert c.hvp_count == c.grid['k']

    def test_iterative_solvers_bill_their_iterations(self, cells):
        for c in cells:
            if c.solver in ('cg', 'neumann'):
                assert c.hvp_count == c.grid['k']

    def test_exact_bills_p(self, cells):
        (cell,) = [c for c in cells if c.solver == 'exact']
        assert cell.hvp_count == 8                  # p = D for logreg_wd


class TestPersistence:
    def test_cells_round_trip_through_schema_check(self, cells, tmp_path,
                                                   monkeypatch):
        monkeypatch.setenv('BENCH_OUT_DIR', str(tmp_path))
        rows = [bench_row(solver=c.solver, backend='tree', m=1,
                          applies_per_sec=c.applies_per_sec,
                          wall_seconds=c.wall_seconds, problem=c.problem,
                          hvp_count=c.hvp_count,
                          hypergrad_error=c.hypergrad_error, grid=c.grid,
                          err_max=c.err_max, tasks=c.tasks)
                for c in cells]
        path = write_bench('observatory_test', rows)
        assert check_file(path) == []
        doc = json.loads(open(path).read())
        assert doc['schema_version'] == 2
        assert all(r['problem'] == SPEC for r in doc['rows'])


class TestFiltersAndParsing:
    def test_solver_filter_selects_exactly_named_entries(self, cells):
        assert {c.solver for c in cells} == {'nystrom', 'cg', 'neumann',
                                            'exact'}
        only = run_sweep((SPEC,), ('cg',), {'k': (2,), 'rho': (RHO,)},
                         tasks=1, oracle_rho=RHO, reps=1)
        assert [c.solver for c in only] == ['cg']

    def test_unknown_solver_raises_before_measurement(self):
        with pytest.raises(ValueError, match="unknown solver 'sgd'"):
            run_sweep((SPEC,), ('sgd',), {'k': (2,)}, tasks=1)

    def test_unknown_problem_raises_with_registry(self):
        with pytest.raises(ValueError, match='unknown problem'):
            run_sweep(('not_a_problem',), ('cg',), {'k': (2,)}, tasks=1)

    def test_grid_points_follow_solver_spec_fields(self):
        grid = {'k': (2, 4), 'rho': (0.01, 0.1), 'alpha': (0.1,)}
        assert solver_grid_points('exact', grid) == [{'rho': 0.01},
                                                     {'rho': 0.1}]
        assert solver_grid_points('neumann', grid) == [
            {'k': 2, 'alpha': 0.1}, {'k': 4, 'alpha': 0.1}]
        assert len(solver_grid_points('nystrom', grid)) == 4
        assert solver_grid_points('cg', {}) == [{}]

    def test_parse_problem_spec(self):
        assert parse_problem_spec('reweighting:d=8:width=16') == (
            'reweighting', {'d': 8, 'width': 16})
        assert parse_problem_spec('imaml') == ('imaml', {})
        with pytest.raises(ValueError, match='bad problem spec'):
            parse_problem_spec('logreg_wd:D8')

    def test_parse_grid_and_vary(self):
        assert parse_grid('k=2:4,rho=0.01') == {'k': (2, 4), 'rho': (0.01,)}
        assert parse_vary('imbalance=10,100') == ('imbalance', (10, 100))
        with pytest.raises(ValueError, match='bad grid axis'):
            parse_grid('k')


class TestSolveIntegration:
    """solve() exposes the same oracle scoring on its solved state."""

    def test_solve_records_hypergrad_error_when_requested(self):
        from repro.core import HypergradConfig, get_problem, solve
        problem = get_problem('logreg_wd', D=8, n=60)
        cfg = HypergradConfig(solver='cg', k=8, rho=RHO)
        res = solve(problem, cfg, n_outer=1, steps_per_outer=5)
        assert res.hypergrad_error is None
        res = solve(problem, cfg, n_outer=1, steps_per_outer=5,
                    with_hypergrad_error=True, oracle_rho=RHO)
        assert res.hypergrad_error is not None
        assert 0.0 <= res.hypergrad_error < 1e-3   # CG at l=p converges

    def test_solve_rejects_error_scoring_on_meta_path(self):
        from repro.core import get_problem, solve
        problem = get_problem('imaml', image_size=8, width=8)
        with pytest.raises(ValueError, match='vmap_tasks'):
            solve(problem, None, n_outer=1, vmap_tasks=2,
                  with_hypergrad_error=True)


class TestBackendAxis:
    """--backends fans out per-backend cells, but only for solvers that
    actually build a contraction backend (Nyström); the others have no
    backend dial and must appear exactly once, tagged 'tree'."""

    @pytest.fixture(scope='class')
    def backend_cells(self):
        return run_sweep((SPEC,), ('nystrom', 'cg'),
                         {'k': (4,), 'rho': (RHO,)}, tasks=2,
                         oracle_rho=RHO, reps=1, seed=0,
                         backends=('tree', 'flat'))

    def test_backend_fanout_only_for_backend_building_solvers(
            self, backend_cells):
        nystrom = sorted(c.backend for c in backend_cells
                         if c.solver == 'nystrom')
        assert nystrom == ['flat', 'tree']
        cg = [c.backend for c in backend_cells if c.solver == 'cg']
        assert cg == ['tree']           # no dial → one cell, tree-tagged

    def test_backends_agree_on_error_and_bill(self, backend_cells):
        tree, flat = [c for c in backend_cells if c.solver == 'nystrom']
        if tree.backend != 'tree':
            tree, flat = flat, tree
        # same sketch math, different operand layout: identical analytic
        # bill, errors equal to layout roundoff
        assert tree.hvp_count == flat.hvp_count == 4
        assert flat.hypergrad_error == pytest.approx(
            tree.hypergrad_error, rel=1e-3, abs=1e-6)

    def test_measure_cell_records_requested_backend(self):
        bundle = build_population(SPEC, tasks=1)
        cell = measure_cell(bundle, 'nystrom', {'k': 2, 'rho': RHO},
                            backend='flat', reps=1)
        assert cell.backend == 'flat'
        # backend-less solver: the tag is recorded but nothing is routed
        cell = measure_cell(bundle, 'cg', {'k': 2, 'rho': RHO}, reps=1)
        assert cell.backend == 'tree'


class TestAuditFields:
    """--audit: each cell's timed program audited via repro.analysis."""

    def test_audit_off_leaves_fields_none(self):
        bundle = build_population(SPEC, tasks=1)
        cell = measure_cell(bundle, 'cg', {'k': 2, 'rho': RHO}, reps=1)
        assert cell.collective_count is None
        assert cell.accum_dtype_ok is None

    def test_audit_fills_structure_fields(self):
        bundle = build_population(SPEC, tasks=1)
        cell = measure_cell(bundle, 'nystrom', {'k': 2, 'rho': RHO},
                            reps=1, audit=True)
        # single-device, f32 throughout: no collectives, clean accumulation
        assert cell.collective_count == 0
        assert cell.accum_dtype_ok is True

    def test_audited_rows_round_trip_through_schema_check(self, tmp_path,
                                                          monkeypatch):
        monkeypatch.setenv('BENCH_OUT_DIR', str(tmp_path))
        bundle = build_population(SPEC, tasks=1)
        cell = measure_cell(bundle, 'cg', {'k': 2, 'rho': RHO}, reps=1,
                            audit=True)
        rows = [bench_row(solver=cell.solver, backend=cell.backend, m=1,
                          applies_per_sec=cell.applies_per_sec,
                          wall_seconds=cell.wall_seconds,
                          problem=cell.problem, hvp_count=cell.hvp_count,
                          collective_count=cell.collective_count,
                          accum_dtype_ok=cell.accum_dtype_ok)]
        path = write_bench('observatory_audit_test', rows)
        assert check_file(path) == []
        # the checker types the optional fields, not just presence
        doc = json.loads(open(path).read())
        doc['rows'][0]['accum_dtype_ok'] = 'yes'
        bad = tmp_path / 'BENCH_bad.json'
        bad.write_text(json.dumps(doc))
        assert any('accum_dtype_ok' in e for e in check_file(str(bad)))


class TestPopulation:
    def test_oracle_guard_refuses_large_p(self):
        with pytest.raises(ValueError, match='max_oracle_p'):
            build_population(SPEC, tasks=1, max_oracle_p=4)

    def test_vary_axis_sets_population(self):
        bundle = build_population('reweighting:d=8:width=16', tasks=1,
                                  vary=('imbalance', (10, 100)),
                                  batch_size=16, steps=3)
        assert bundle.tasks == 2
        cell = measure_cell(bundle, 'cg', {'k': 2, 'rho': RHO}, reps=1)
        assert cell.tasks == 2 and cell.hvp_count == 2
