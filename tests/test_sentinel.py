"""Retrace sentinel: the compile-once guarantees of the repo's hot loops.

``assert_compiles(fn, times=1, calls=3)`` pins the property the loops are
fast because of: the first call pays the compile, every later call
replays. Applied here to the primitives and then to the two loops the
ISSUE names — a 3-outer-step ``BilevelTrainer`` loop over its jitted step
pair, and the warm ``InfluenceService`` query path (submit → flush).
"""
import jax
import jax.numpy as jnp
import pytest

from repro.analysis import (CompileMonitor, RetraceError, assert_compiles,
                            count_compiles)


# -------------------------------------------------------------- primitives
class TestMonitor:
    def test_counts_a_fresh_compile_then_none(self):
        @jax.jit
        def f(x):
            return x * 2.0

        x = jnp.ones((4,))
        first = count_compiles(lambda: f(x))
        assert first >= 1
        assert count_compiles(lambda: f(x)) == 0

    def test_monitors_nest(self):
        @jax.jit
        def g(x):
            return x + 1.0

        with CompileMonitor() as outer:
            with CompileMonitor() as inner:
                g(jnp.ones((3,)))
            assert inner.compiles >= 1
        assert outer.compiles == inner.compiles


class TestAssertCompiles:
    def test_stable_jit_passes(self):
        @jax.jit
        def step(x):
            return x * x + 1.0

        assert_compiles(step, jnp.ones((8,)), times=1, calls=4)

    def test_per_call_fresh_jit_raises(self):
        def retracer(x):
            # a fresh closure per call: the classic silent-retrace bug
            return jax.jit(lambda v: v * 2.0)(x)

        with pytest.raises(RetraceError, match='retraces'):
            assert_compiles(retracer, jnp.ones((4,)), times=1, calls=3)

    def test_warm_path_with_warmup(self):
        @jax.jit
        def step(x):
            return x - 1.0

        assert_compiles(step, jnp.ones((5,)), times=0, warmup=1, calls=2)

    def test_shape_dependent_branch_raises(self):
        calls = []

        @jax.jit
        def step(x):
            return x.sum()

        def drifting():
            # growing shapes force a retrace per call
            calls.append(None)
            return step(jnp.ones((len(calls),)))

        with pytest.raises(RetraceError):
            assert_compiles(drifting, times=1, calls=3)


# ------------------------------------------------------------ repo's loops
def _toy_trainer():
    from repro.core import BilevelTrainer, HypergradConfig
    from repro.optim import sgd

    D = 6

    def inner(prm, hp, batch):
        return (jnp.sum((prm['w'] - 1.0) ** 2)
                + jnp.sum(jax.nn.softplus(hp['wd']) * prm['w'] ** 2))

    def outer(prm, hp, batch):
        return jnp.sum(prm['w'] ** 2)

    trainer = BilevelTrainer(
        inner_loss=inner, outer_loss=outer,
        inner_opt=sgd(0.05), outer_opt=sgd(0.05),
        hypergrad=HypergradConfig(solver='nystrom', k=4, rho=1e-2))
    state = trainer.init(jax.random.PRNGKey(0),
                         {'w': jnp.zeros((D,))}, {'wd': jnp.zeros((D,))})
    return trainer, state


def test_three_outer_step_loop_compiles_once():
    """The jitted (inner, outer) step pair driven 3 outer steps: all
    compilation lands in the first iteration; iterations 2 and 3 replay."""
    trainer, state0 = _toy_trainer()
    inner = jax.jit(trainer.inner_step_fn)
    outer = jax.jit(trainer.outer_step_fn)
    carry = {'state': state0}

    def one_outer_step():
        st = carry['state']
        for _ in range(2):
            st, _ = inner(st, None)
        st, _ = outer(st, None, None)
        carry['state'] = st

    assert_compiles(one_outer_step, times=1, calls=3)


def test_trainer_run_recompiles_at_most_once_per_call():
    """``run`` jits its step pair per invocation, so a second 3-outer-step
    run costs no MORE compiles than a 1-outer-step run — the loop body
    inside one run never retraces."""
    trainer, state0 = _toy_trainer()

    def run(n_outer):
        batches = iter(lambda: None, object())   # endless None batches
        trainer.run(state0, batches, iter(lambda: None, object()),
                    steps_per_outer=2, n_outer=n_outer)

    run(1)                                       # shared caches warm
    c1 = count_compiles(lambda: run(1))
    c3 = count_compiles(lambda: run(3))
    assert c3 <= c1, (c1, c3)


def test_warm_serve_query_path_compiles_once():
    """submit → flush on a sketch-warm InfluenceService: the first query
    traces qgrad / apply_matrix / the top-k scan, every later query
    replays. A retrace here bills a compile per request."""
    from repro.core import NystromIHVP, get_problem, train_influence_params
    from repro.serve.service import InfluenceService

    problem = get_problem('influence', d=8, width=8)
    params = train_influence_params(problem, train_steps=3)
    svc = InfluenceService(problem, NystromIHVP(k=4, rho=1e-2),
                           params=params, top_k=5, block_size=1)
    svc.prepare()                               # sketch warm, off-path
    q = jax.tree.map(lambda x: x[0], problem.reference['queries'](1))

    def query():
        t = svc.submit(q)
        svc.flush()
        svc.result(t)

    assert_compiles(query, times=1, calls=3)
