"""Program auditor + Contract: the declarative replacement for HLO greps.

Covers the three report layers (jaxpr / lowered StableHLO / compiled HLO),
every Contract field's violation rendering, and — because the whole point
is retiring substring asserts — one legacy-vs-contract equivalence test
that runs the OLD ``txt.count('all_reduce')`` methodology and the Contract
on the same lowered program and demands they agree. The sharded structural
guarantees themselves are enforced in tests/test_block_apply.py and
tests/sharded_parity_check.py via ``repro.core.FLAT_SHARDED_CONTRACT``.
"""
import functools

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import Mesh, PartitionSpec as P

from repro.analysis import (Contract, ContractViolation, audit, audit_jaxpr,
                            canonical_collective)

PARAMS = {'w': jnp.zeros((8,)), 'm': jnp.zeros((27, 37)),
          'b': jnp.zeros((2, 2)), 's': jnp.zeros(())}


def _mesh1():
    return Mesh(np.array(jax.devices()[:1]), ('model',))


def _psum_fn(mesh):
    from repro.distributed.ctx import shard_map_unchecked

    def local(x):
        return jax.lax.psum(x.sum(axis=-1), ('model',))

    return shard_map_unchecked(local, mesh, (P(None, 'model'),), P())


# ---------------------------------------------------------------- reports
class TestAudit:
    def test_psum_counted_in_every_layer(self):
        fn = _psum_fn(_mesh1())
        x = jnp.ones((4, 8))
        report = audit(fn, x, compile=True)
        assert report.sources == ('jaxpr', 'stablehlo', 'hlo')
        for src in report.sources:
            assert report.count('psum', src) == 1, src
        # aliases all resolve to the same canonical kind
        for alias in ('psum', 'psum2', 'all_reduce', 'all-reduce'):
            assert canonical_collective(alias) == 'all-reduce'
            assert report.count(alias) == 1

    def test_jaxpr_record_carries_axes_and_shape(self):
        report = audit(_psum_fn(_mesh1()), jnp.ones((4, 8)))
        (rec,) = report.records('psum', 'jaxpr')
        assert rec.shape == (4,) and rec.dtype == 'float32'
        assert 'model' in rec.detail

    def test_collective_bytes_from_compiled_hlo(self):
        report = audit(_psum_fn(_mesh1()), jnp.ones((4, 8)), compile=True)
        assert report.collective_nbytes is not None
        assert report.collective_nbytes.get('all-reduce', 0) >= 4 * 4

    def test_walks_sub_jaxprs(self):
        """Collectives inside scan/pjit bodies are found recursively."""
        fn = _psum_fn(_mesh1())

        def scanned(x):
            def body(c, _):
                return c + fn(x), None
            out, _ = jax.lax.scan(body, jnp.zeros((4,)), jnp.arange(3))
            return jax.jit(fn)(x) + out

        report = audit_jaxpr(jax.make_jaxpr(scanned)(jnp.ones((4, 8))))
        assert report.count('psum', 'jaxpr') == 2   # scan body + nested jit

    def test_custom_vjp_boundary_counted(self):
        @jax.custom_vjp
        def f(x):
            return x * 2.0

        f.defvjp(lambda x: (x * 2.0, None), lambda _, g: (g * 2.0,))
        report = audit(lambda x: f(x).sum(), jnp.ones((3,)))
        assert report.custom_vjp_calls == 1

    def test_dot_records_accumulation_dtype(self):
        def good(a, b):
            return jnp.einsum('kp,p->k', a, b,
                              preferred_element_type=jnp.float32)

        report = audit(good, jnp.ones((4, 8), jnp.bfloat16),
                       jnp.ones((8,), jnp.bfloat16))
        (dot,) = report.dots
        assert dot.accum_dtype == 'float32' and dot.preferred

    def test_host_callback_flagged_in_jaxpr_and_stablehlo(self):
        def f(x):
            y = jax.pure_callback(
                lambda v: np.asarray(v) * 2,
                jax.ShapeDtypeStruct((3,), jnp.float32), x)
            return y.sum()

        report = audit(f, jnp.ones((3,)))
        sources = {t.source for t in report.host_transfers}
        assert 'jaxpr' in sources and 'stablehlo' in sources


# --------------------------------------------------------------- contracts
class TestContract:
    def test_clean_program_passes(self):
        c = Contract(name='clean', no_all_gather=True, no_host_transfer=True,
                     max_collectives={'psum': 1},
                     min_accum_dtype='float32')
        report = c.check_fn(_psum_fn(_mesh1()), jnp.ones((4, 8)))
        assert c.check(report) == []

    def test_no_all_gather_renders_the_offending_op(self):
        from repro.distributed.ctx import shard_map_unchecked
        mesh = _mesh1()
        gather = shard_map_unchecked(
            lambda x: jax.lax.all_gather(x, 'model', tiled=True),
            mesh, (P('model'),), P())
        report = audit(gather, jnp.ones((8,)))
        violations = Contract(no_all_gather=True).check(report)
        assert violations and violations[0].rule == 'no_all_gather'
        with pytest.raises(ContractViolation, match='all-gather'):
            Contract(name='gatherless', no_all_gather=True).enforce(report)

    def test_collective_count_bounds(self):
        fn = _psum_fn(_mesh1())

        def twice(x):
            return fn(x) + fn(x + 1.0)

        report = audit(twice, jnp.ones((4, 8)))
        assert Contract(exact_collectives={'psum': 2}).check(report) == []
        bad = Contract(exact_collectives={'psum': 1}).check(report)
        assert bad and 'exact 1' in bad[0].message
        assert Contract(max_collectives={'psum': 1}).check(report)
        assert Contract(min_collectives={'psum': 3}).check(report)
        # a kind that never appears violates min but satisfies max
        assert Contract(min_collectives={'all_gather': 1}).check(report)
        assert Contract(max_collectives={'all_gather': 0}).check(report) == []

    def test_min_accum_dtype_catches_bf16_accumulation(self):
        def bad(a, b):
            return jax.lax.dot(a, b)    # bf16 x bf16 -> bf16, no preferred

        report = audit(bad, jnp.ones((4, 8), jnp.bfloat16),
                       jnp.ones((8, 2), jnp.bfloat16))
        v = Contract(min_accum_dtype='float32').check(report)
        assert v and v[0].rule == 'min_accum_dtype'
        assert 'bfloat16' in v[0].message

    def test_min_reduction_dtype_catches_bf16_psum(self):
        from repro.distributed.ctx import shard_map_unchecked
        mesh = _mesh1()
        fn = shard_map_unchecked(
            lambda x: jax.lax.psum(x.sum(axis=-1), ('model',)),
            mesh, (P(None, 'model'),), P())
        report = audit(fn, jnp.ones((4, 8), jnp.bfloat16))
        v = Contract(min_reduction_dtype='float32').check(report)
        assert v and v[0].rule == 'min_reduction_dtype'

    def test_no_host_transfer_violation(self):
        def f(x):
            return jax.pure_callback(
                lambda v: np.asarray(v), jax.ShapeDtypeStruct((3,),
                                                              jnp.float32), x)

        v = Contract(no_host_transfer=True).check(audit(f, jnp.ones((3,))))
        assert v and v[0].rule == 'no_host_transfer'

    def test_max_constant_bytes(self):
        baked = jnp.arange(4096, dtype=jnp.float32)

        def f(x):
            return x + baked

        report = audit(f, jnp.ones((4096,)))
        assert Contract(max_constant_bytes=100).check(report)
        assert Contract(max_constant_bytes=1 << 20).check(report) == []


# ----------------------------------------------- legacy-vs-contract parity
def test_contract_agrees_with_legacy_substring_method():
    """THE one sanctioned substring grep left: run the retired
    ``txt.count('all_reduce')`` methodology and the Contract on the same
    lowered flat_sharded apply and demand the same verdict — the port in
    test_block_apply.py / sharded_parity_check.py changed the mechanism,
    not the guarantee."""
    from repro.core import (FLAT_SHARDED_CONTRACT, FlatShardedBackend,
                            NystromIHVP, PyTreeIndexer, flatten_vec,
                            make_hvp, tree_random_like)

    idxr = PyTreeIndexer(PARAMS)
    B = jax.random.normal(jax.random.PRNGKey(7), (idxr.total, 16))
    Hm = B @ B.T / idxr.total + 0.5 * jnp.eye(idxr.total)
    hvp = make_hvp(lambda prm, hp, b: 0.5 * flatten_vec(prm) @ Hm
                   @ flatten_vec(prm), PARAMS, None, None)
    be = FlatShardedBackend(mesh=_mesh1(),
                            specs={'w': P('model'), 'm': P(None, 'model'),
                                   'b': P(), 's': P()})
    solver = NystromIHVP(k=8, rho=1e-2, backend=be, refine=0)
    state = solver.prepare(hvp, idxr, jax.random.PRNGKey(42))
    cols = [tree_random_like(k, PARAMS)
            for k in jax.random.split(jax.random.PRNGKey(1), 4)]
    Vm = jax.tree.map(lambda *ls: jnp.stack(ls, axis=-1), *cols)

    txt = jax.jit(solver.apply_matrix).lower(state, Vm).as_text()
    legacy_psums = txt.count('all_reduce')
    legacy_gathers = txt.count('all_gather')

    report = audit(solver.apply_matrix, state, Vm)
    assert report.count('psum') == legacy_psums == 1
    assert report.count('all_gather') == legacy_gathers == 0
    assert FLAT_SHARDED_CONTRACT.check(report) == []


# ------------------------------------------------------- wired-in contracts
def test_kernel_contract_holds_in_interpret_mode():
    """KERNEL_CONTRACT checks dots inside the pallas_call kernel jaxpr —
    bf16 slabs must upcast before the MXU dot."""
    from repro.kernels import ops

    C = jnp.asarray(np.random.default_rng(0).normal(size=(256, 8)),
                    jnp.float32)
    for dtype in (jnp.float32, jnp.bfloat16):
        gram = functools.partial(ops.nystrom_gram, interpret=True)
        report = ops.KERNEL_CONTRACT.check_fn(gram, C.astype(dtype))
        assert report.dots, 'expected the kernel dot to be visible'

def test_bf16_sketch_contract_on_flat_backend():
    from repro.core import BF16_SKETCH_CONTRACT, get_backend

    be = get_backend('flat', sketch_dtype=jnp.bfloat16)
    C = {'w': jnp.ones((4, 8)), 'b': jnp.ones((4, 2))}
    op = be.prepare_operand(C)
    v = be.vec({'w': jnp.ones((8,)), 'b': jnp.ones((2,))})
    report = BF16_SKETCH_CONTRACT.check_fn(be.ctv, op, v)
    assert any(d.accum_dtype == 'float32' for d in report.dots)
    # the same contraction WITHOUT the f32 accumulation request violates
    bad = audit(lambda c, x: jnp.einsum('kp,p->k', c, x.astype(jnp.bfloat16)),
                op, v)
    assert BF16_SKETCH_CONTRACT.check(bad)


def test_serve_query_path_contract(monkeypatch):
    """InfluenceService.audit_query_path enforces SERVE_QUERY_CONTRACT on
    the real warm flush computation (apply_matrix + top-k scan)."""
    from repro.core import NystromIHVP, get_problem, train_influence_params
    from repro.serve.service import InfluenceService

    problem = get_problem('influence', d=8, width=8)
    params = train_influence_params(problem, train_steps=3)
    svc = InfluenceService(problem, NystromIHVP(k=4, rho=1e-2),
                           params=params, top_k=5, block_size=2)
    report = svc.audit_query_path()
    assert report.host_transfers == []
    assert all(d.accum_dtype in ('float32', 'float64') or
               d.accum_dtype not in ('bfloat16', 'float16')
               for d in report.dots)
