"""Shared fixtures. NOTE: no XLA_FLAGS device-count override here — smoke
tests and benches must see the 1 real CPU device; only launch/dryrun.py
creates the 512-device placeholder topology (per its module docstring)."""
import jax
import numpy as np
import pytest


@pytest.fixture(scope='session', autouse=True)
def _determinism():
    np.random.seed(0)


@pytest.fixture
def rng():
    return jax.random.PRNGKey(0)
