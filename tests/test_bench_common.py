"""benchmarks/common.py contract tests: row stamping, persistence, configs.

The BENCH_*.json schema is what makes the perf trajectory diffable across
sessions — these tests pin the v2 row contract (bench_row / write_bench),
the $BENCH_OUT_DIR resolution, and the named solver-config table (including
the nystrom-vs-nystrom_eq6 distinction that used to be silently collapsed).
"""
import json

import pytest

from benchmarks.common import (BENCH_SCHEMA_KEYS, BENCH_SCHEMA_VERSION,
                               bench_row, solver_cfg, write_bench)


def _row(**over):
    base = dict(solver='nystrom', backend='tree', m=1, applies_per_sec=10.0,
                wall_seconds=0.1, problem='logreg_wd', hvp_count=5)
    base.update(over)
    return bench_row(**base)


class TestBenchRow:
    def test_required_fields_stamped_and_typed(self):
        row = _row()
        for key in BENCH_SCHEMA_KEYS[BENCH_SCHEMA_VERSION]:
            assert key in row
        assert isinstance(row['m'], int)
        assert isinstance(row['hvp_count'], int)
        assert isinstance(row['applies_per_sec'], float)

    def test_optional_fields_omitted_when_none(self):
        row = _row()
        assert 'hypergrad_error' not in row
        assert 'grid' not in row

    def test_optional_fields_included_when_given(self):
        row = _row(hypergrad_error=0.25, grid={'k': 4, 'rho': 0.01})
        assert row['hypergrad_error'] == 0.25
        assert row['grid'] == {'k': 4, 'rho': 0.01}

    def test_extra_fields_pass_through(self):
        row = _row(imb=100, acc=0.91)
        assert row['imb'] == 100 and row['acc'] == 0.91


class TestWriteBench:
    def test_writes_schema_stamped_doc_to_bench_out_dir(self, tmp_path,
                                                        monkeypatch):
        monkeypatch.setenv('BENCH_OUT_DIR', str(tmp_path))
        path = write_bench('unit', [_row()], meta={'note': 'test'})
        assert path == str(tmp_path / 'BENCH_unit.json')
        doc = json.loads((tmp_path / 'BENCH_unit.json').read_text())
        assert doc['schema_version'] == BENCH_SCHEMA_VERSION == 2
        assert doc['name'] == 'unit' and doc['meta'] == {'note': 'test'}
        assert len(doc['rows']) == 1

    def test_explicit_out_dir_wins_over_env(self, tmp_path, monkeypatch):
        monkeypatch.setenv('BENCH_OUT_DIR', str(tmp_path / 'env'))
        (tmp_path / 'arg').mkdir()
        path = write_bench('unit', [_row()], out_dir=str(tmp_path / 'arg'))
        assert path == str(tmp_path / 'arg' / 'BENCH_unit.json')

    def test_rejects_rows_missing_required_keys(self, tmp_path, monkeypatch):
        monkeypatch.setenv('BENCH_OUT_DIR', str(tmp_path))
        bad = _row()
        del bad['problem'], bad['hvp_count']
        with pytest.raises(ValueError, match='missing required keys'):
            write_bench('unit', [bad])
        assert not (tmp_path / 'BENCH_unit.json').exists()


class TestSolverCfg:
    def test_unknown_name_raises_with_known_set(self):
        with pytest.raises(ValueError, match="unknown solver config 'sgd'"):
            solver_cfg('sgd')
        with pytest.raises(ValueError, match='nystrom_eq6'):
            solver_cfg('sgd')      # the message lists the known names

    def test_exact_entry_builds(self):
        from repro.core.solvers import ExactIHVP
        solver = solver_cfg('exact', rho=0.5).build()
        assert isinstance(solver, ExactIHVP) and solver.rho == 0.5

    def test_nystrom_eq6_is_the_literal_eq6_apply(self):
        """Regression pin: solver_cfg('nystrom_eq6') used to return a config
        identical to 'nystrom' — the eq6 variant must build the
        unstabilized, no-refinement apply."""
        eq6 = solver_cfg('nystrom_eq6', k=4).build()
        prod = solver_cfg('nystrom', k=4).build()
        assert eq6.stabilized is False and eq6.refine == 0
        assert prod.stabilized is True
        assert solver_cfg('nystrom_eq6') != solver_cfg('nystrom')

    def test_stabilized_knob_is_nystrom_only(self):
        from repro.core import HypergradConfig
        with pytest.raises(ValueError, match='stabilized'):
            HypergradConfig(solver='cg', stabilized=False).build()
