"""implicit_root tests: grad/vmap/jit composition, oracle + legacy parity,
and the uniform solver protocol.

The analytic quadratic bilevel problem (same as test_hypergrad) gives an
*exact* solution map θ*(φ) = A⁻¹(Bφ + c), so ``jax.grad`` through
``implicit_root`` can be checked against the closed-form hypergradient, the
unrolled-SGD oracle, and the legacy ``hypergradient()`` wrapper.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (CGIHVP, ExactIHVP, HypergradConfig, NeumannIHVP,
                        NystromIHVP, PyTreeIndexer, SOLVERS, hypergradient,
                        implicit_root, make_hvp, sgd_solver,
                        tree_random_like, unrolled_hypergradient)


def _quadratic_bilevel(seed=0, P=12, Hdim=5):
    k1, k2, k3, k4 = jax.random.split(jax.random.PRNGKey(seed), 4)
    Am = jax.random.normal(k1, (P, P))
    Am = Am @ Am.T / P + jnp.eye(P)
    Bm = jax.random.normal(k2, (P, Hdim))
    c = jax.random.normal(k3, (P,))
    t = jax.random.normal(k4, (P,))

    def inner(prm, hp, batch):
        th = prm['theta']
        return 0.5 * th @ Am @ th - th @ (Bm @ hp['phi'] + c)

    def outer(prm, hp, batch):
        return 0.5 * jnp.sum((prm['theta'] - t) ** 2)

    def solution_map(hp, batch):
        return {'theta': jnp.linalg.solve(Am, Bm @ hp['phi'] + c)}

    phi0 = {'phi': jnp.ones((Hdim,))}
    return inner, outer, solution_map, phi0, Am, Bm, t


def _analytic_hypergrad(Am, Bm, t, theta, rho):
    P = Am.shape[0]
    return Bm.T @ jnp.linalg.solve(Am + rho * jnp.eye(P), theta - t)


class TestGradComposition:
    @pytest.mark.parametrize('solver_name', ['exact', 'nystrom', 'cg'])
    def test_grad_matches_analytic(self, solver_name):
        inner, outer, smap, phi0, Am, Bm, t = _quadratic_bilevel()
        P = Am.shape[0]
        rho = 1e-3
        cfg = {'exact': HypergradConfig(solver='exact', rho=rho),
               'nystrom': HypergradConfig(solver='nystrom', k=P, rho=rho),
               'cg': HypergradConfig(solver='cg', k=5 * P, rho=rho)}[solver_name]
        solve = implicit_root(smap, inner, cfg)

        def obj(hp):
            theta = solve(hp, None, rng=jax.random.PRNGKey(1))
            return outer(theta, hp, None)

        hg = jax.grad(obj)(phi0)
        analytic = _analytic_hypergrad(Am, Bm, t, smap(phi0, None)['theta'],
                                       rho)
        np.testing.assert_allclose(hg['phi'], analytic, rtol=2e-3, atol=2e-3)

    def test_grad_matches_unrolled_oracle(self):
        """Implicit grad ≈ differentiating through the inner unroll (ρ→0)."""
        inner, outer, smap, phi0, Am, Bm, t = _quadratic_bilevel()
        theta_star = smap(phi0, None)
        solve = implicit_root(smap, inner,
                              HypergradConfig(solver='exact', rho=0.0))
        hg = jax.grad(lambda hp: outer(solve(hp, None), hp, None))(phi0)
        oracle = unrolled_hypergradient(inner, outer, theta_star, phi0,
                                        None, None, steps=800, lr=0.05)
        np.testing.assert_allclose(hg['phi'], oracle['phi'], rtol=2e-3,
                                   atol=2e-3)

    def test_matches_legacy_hypergradient_path(self):
        """Same solver + same rng ⇒ identical columns ⇒ same hypergradient."""
        inner, outer, smap, phi0, Am, Bm, t = _quadratic_bilevel()
        theta_star = smap(phi0, None)
        solver = NystromIHVP(k=8, rho=1e-2)
        rng = jax.random.PRNGKey(7)
        legacy = hypergradient(inner, outer, theta_star, phi0, None, None,
                               solver, rng)
        solve = implicit_root(smap, inner, solver)
        new = jax.grad(lambda hp: outer(solve(hp, None, rng=rng),
                                        hp, None))(phi0)
        np.testing.assert_allclose(new['phi'], legacy['phi'], rtol=1e-6,
                                   atol=1e-6)

    def test_direct_term_included(self):
        """∂g/∂φ flows through plain autodiff alongside the implicit VJP."""
        inner, outer0, smap, phi0, Am, Bm, t = _quadratic_bilevel()
        solve = implicit_root(smap, inner,
                              HypergradConfig(solver='exact', rho=1e-3))

        def outer1(prm, hp, batch):
            return outer0(prm, hp, batch) + 3.0 * jnp.sum(hp['phi'])

        g0 = jax.grad(lambda hp: outer0(solve(hp, None), hp, None))(phi0)
        g1 = jax.grad(lambda hp: outer1(solve(hp, None), hp, None))(phi0)
        np.testing.assert_allclose(g1['phi'] - g0['phi'], 3.0, rtol=1e-5)

    def test_logreg_task_parity(self):
        """Real task (§5.1 logreg weight decay): implicit grad through a
        100-step SGD solve agrees with the legacy path at the same point."""
        from repro.tasks import build_logreg_weight_decay
        task = build_logreg_weight_decay(D=20, n=100)
        inner_solver = sgd_solver(task.inner_loss, steps=100, lr=0.1,
                                  init=lambda phi, b: {'w': jnp.zeros((20,))})

        phi = {'wd': jnp.full((20,), 0.5)}
        rng = jax.random.PRNGKey(3)
        solver = NystromIHVP(k=10, rho=1e-2)
        solve = implicit_root(inner_solver, task.inner_loss, solver)
        new = jax.grad(lambda p: task.outer_loss(
            solve(p, task.data.train, rng=rng), p, task.data.val))(phi)
        theta_star = inner_solver(phi, task.data.train)
        legacy = hypergradient(task.inner_loss, task.outer_loss, theta_star, phi,
                               task.data.train, task.data.val, solver, rng)
        np.testing.assert_allclose(new['wd'], legacy['wd'], rtol=1e-5,
                                   atol=1e-6)


class TestForwardMode:
    """The custom_jvp rule: tangents of the solution map against the
    closed-form dθ*/dφ = (A + ρI)⁻¹B of the quadratic fixture, plus the
    compositions the engine's nested lowering leans on (jvp-of-vmap,
    jvp∘vjp)."""

    @pytest.mark.parametrize('solver_name', ['exact', 'nystrom'])
    def test_jvp_matches_analytic_tangent(self, solver_name):
        inner, outer, smap, phi0, Am, Bm, t = _quadratic_bilevel()
        P = Am.shape[0]
        rho = 1e-3
        cfg = {'exact': HypergradConfig(solver='exact', rho=rho),
               'nystrom': HypergradConfig(solver='nystrom', k=P,
                                          rho=rho)}[solver_name]
        solve = implicit_root(smap, inner, cfg)
        dphi = {'phi': jnp.linspace(-1.0, 1.0, Bm.shape[1])}
        theta, dtheta = jax.jvp(
            lambda hp: solve(hp, None, rng=jax.random.PRNGKey(1)),
            (phi0,), (dphi,))
        want = jnp.linalg.solve(Am + rho * jnp.eye(P), Bm @ dphi['phi'])
        np.testing.assert_allclose(theta['theta'], smap(phi0, None)['theta'],
                                   rtol=1e-5, atol=1e-5)
        np.testing.assert_allclose(dtheta['theta'], want, rtol=2e-3,
                                   atol=2e-3)

    def test_jacfwd_matches_dense_oracle(self):
        """Whole forward-mode Jacobian at ρ=0 == the exact A⁻¹B."""
        inner, outer, smap, phi0, Am, Bm, t = _quadratic_bilevel()
        solve = implicit_root(smap, inner,
                              HypergradConfig(solver='exact', rho=0.0))
        J = jax.jacfwd(lambda hp: solve(hp, None)['theta'])(phi0)['phi']
        np.testing.assert_allclose(J, jnp.linalg.solve(Am, Bm), rtol=1e-4,
                                   atol=1e-4)

    def test_jvp_of_vmap_matches_per_task(self):
        """jvp through a vmapped meta-batch of solves == per-task jvp — the
        composition a sketch build inside an upper level's HVP runs."""
        inner, outer, smap, phi0, Am, Bm, t = _quadratic_bilevel()
        solve = implicit_root(smap, inner,
                              HypergradConfig(solver='exact', rho=0.0))
        B = 3
        phis = {'phi': jnp.stack([(i + 1.0) * phi0['phi']
                                  for i in range(B)])}
        dphis = {'phi': 0.1 * jnp.ones_like(phis['phi'])}
        batched = jax.vmap(lambda hp: solve(hp, None)['theta'])
        _, dtheta = jax.jvp(batched, (phis,), (dphis,))
        for i in range(B):
            _, want = jax.jvp(lambda hp: solve(hp, None)['theta'],
                              ({'phi': phis['phi'][i]},),
                              ({'phi': dphis['phi'][i]},))
            np.testing.assert_allclose(dtheta[i], want, rtol=1e-5, atol=1e-5)

    def test_jvp_of_vjp_hyper_hessian(self):
        """jacfwd-of-grad through the solve (the hyper-Hessian) against the
        closed form. For the quadratic inner problem the AID rules are exact
        (constant curvature — nothing for stop_gradient to drop), so at ρ=0
        the outer Hessian is exactly (A⁻¹B)ᵀ(A⁻¹B)."""
        inner, outer, smap, phi0, Am, Bm, t = _quadratic_bilevel()
        solve = implicit_root(smap, inner,
                              HypergradConfig(solver='exact', rho=0.0))

        def obj(hp):
            return outer(solve(hp, None), hp, None)

        H = jax.jacfwd(jax.grad(obj))(phi0)['phi']['phi']
        S = jnp.linalg.solve(Am, Bm)
        np.testing.assert_allclose(H, S.T @ S, rtol=2e-3, atol=2e-3)


class TestVmapComposition:
    def test_vmap_matches_per_task_loop(self):
        """Batched per-task hypergradients == per-task Python loop."""
        inner, outer, smap, phi0, Am, Bm, t = _quadratic_bilevel()
        solve = implicit_root(smap, inner,
                              HypergradConfig(solver='nystrom', k=12,
                                              rho=1e-3))

        def task_grad(hp, rng):
            return jax.grad(lambda h: outer(solve(h, None, rng=rng),
                                            h, None))(hp)

        B = 4
        phis = {'phi': jnp.stack([(i + 1.0) * phi0['phi']
                                  for i in range(B)])}
        keys = jax.random.split(jax.random.PRNGKey(11), B)
        batched = jax.vmap(task_grad)(phis, keys)
        looped = [task_grad({'phi': phis['phi'][i]}, keys[i])['phi']
                  for i in range(B)]
        # same columns per task (same key) ⇒ same estimator; batched linalg
        # kernels differ from looped ones only at ULP level
        np.testing.assert_allclose(batched['phi'], jnp.stack(looped),
                                   rtol=1e-5, atol=1e-5)

    def test_vmap_imaml_style_shared_meta(self):
        """vmap with a shared (unbatched) φ and batched task data — the
        iMAML meta-batch pattern (benchmarks/tab3_imaml.py)."""
        from repro.tasks import build_imaml
        task = build_imaml()
        sampler = task.reference['sampler']
        meta = task.init_params(jax.random.PRNGKey(0))
        solver = NystromIHVP(k=6, rho=1e-2)
        adapt = sgd_solver(task.inner_loss, steps=5, lr=0.1)  # meta is θ0
        solve = implicit_root(adapt, task.inner_loss, solver)

        def task_grad(sx, sy, qx, qy, key):
            def obj(m):
                return task.outer_loss(solve(m, (sx, sy), rng=key), m, (qx, qy))
            return jax.grad(obj)(meta)

        eps = [sampler.episode(i) for i in range(3)]
        SX, SY, QX, QY = (jnp.stack(z) for z in zip(*eps))
        keys = jax.random.split(jax.random.PRNGKey(5), 3)
        batched = jax.vmap(task_grad)(SX, SY, QX, QY, keys)
        for i in range(3):
            single = task_grad(SX[i], SY[i], QX[i], QY[i], keys[i])
            for a, b in zip(jax.tree.leaves(batched), jax.tree.leaves(single)):
                np.testing.assert_allclose(a[i], b, rtol=2e-4, atol=2e-5)


class TestJitComposition:
    def test_jit_of_grad_compiles_once(self):
        """Fresh rng / batch *values* must not retrace the compiled step."""
        inner, outer, smap, phi0, Am, Bm, t = _quadratic_bilevel()
        solve = implicit_root(smap, inner,
                              HypergradConfig(solver='nystrom', k=8,
                                              rho=1e-2))

        @jax.jit
        def hg_fn(hp, rng):
            return jax.grad(lambda h: outer(solve(h, None, rng=rng),
                                            h, None))(hp)

        hg_fn(phi0, jax.random.PRNGKey(0))
        n0 = hg_fn._cache_size()
        hg_fn(phi0, jax.random.PRNGKey(1))
        hg_fn(jax.tree.map(lambda x: 2 * x, phi0), jax.random.PRNGKey(2))
        assert hg_fn._cache_size() == n0

    def test_amortized_state_path(self):
        """Passing a pre-built sketch skips prepare and matches it."""
        inner, outer, smap, phi0, Am, Bm, t = _quadratic_bilevel()
        theta_star = smap(phi0, None)
        solver = NystromIHVP(k=12, rho=1e-3)
        hvp = make_hvp(inner, theta_star, phi0, None)
        rng = jax.random.PRNGKey(2)
        sketch = solver.prepare(hvp, PyTreeIndexer(theta_star), rng)
        solve = implicit_root(smap, inner, solver)
        g_state = jax.grad(lambda hp: outer(
            solve(hp, None, state=sketch), hp, None))(phi0)
        g_fresh = jax.grad(lambda hp: outer(
            solve(hp, None, rng=rng), hp, None))(phi0)
        np.testing.assert_allclose(g_state['phi'], g_fresh['phi'], rtol=1e-5,
                                   atol=1e-5)


class TestSolverProtocol:
    def test_every_registered_solver_has_prepare_apply(self):
        for name, spec in SOLVERS.items():
            assert hasattr(spec.cls, 'prepare'), name
            assert hasattr(spec.cls, 'apply'), name
            assert hasattr(spec.cls, 'solve'), name

    @pytest.mark.parametrize('solver', [
        CGIHVP(iters=40, rho=1e-2),
        NeumannIHVP(iters=100, alpha=0.2),
        ExactIHVP(rho=1e-2),
        NystromIHVP(k=8, rho=1e-2),
    ])
    def test_prepare_apply_equals_solve(self, solver):
        params = {'w': jnp.zeros((6,)), 'b': jnp.zeros((2,))}
        idxr = PyTreeIndexer(params)
        p = idxr.total
        B = jax.random.normal(jax.random.PRNGKey(0), (p, p))
        Hm = B @ B.T / p + jnp.eye(p)

        def loss(prm, hp, batch):
            th = jnp.concatenate([x.ravel() for x in jax.tree.leaves(prm)])
            return 0.5 * th @ Hm @ th

        hvp = make_hvp(loss, params, None, None)
        v = tree_random_like(jax.random.PRNGKey(1), params)
        rng = jax.random.PRNGKey(2)
        via_protocol = solver.apply(solver.prepare(hvp, idxr, rng), v)
        via_solve = solver.solve(hvp, idxr, v, rng)
        for a, b in zip(jax.tree.leaves(via_protocol),
                        jax.tree.leaves(via_solve)):
            np.testing.assert_allclose(a, b, rtol=1e-6, atol=1e-7)


class TestBuildSketchGuard:
    def test_iterative_solver_rejected_loudly(self):
        """CG/Neumann states close over the trace's hvp — build_sketch must
        reject them up front, not fail opaquely inside the next jitted
        outer step."""
        from repro.core import BilevelTrainer
        from repro.optim import sgd
        inner, outer, smap, phi0, Am, Bm, t = _quadratic_bilevel()
        trainer = BilevelTrainer(
            inner_loss=inner, outer_loss=outer,
            inner_opt=sgd(0.01), outer_opt=sgd(0.1),
            hypergrad=HypergradConfig(solver='cg', k=5))
        state = trainer.init(jax.random.PRNGKey(0), smap(phi0, None), phi0)
        with pytest.raises(TypeError, match='IterativeOperator'):
            trainer.build_sketch(state, None)


class TestConfigRegistry:
    def test_unknown_solver_errors(self):
        with pytest.raises(ValueError, match='unknown solver'):
            HypergradConfig(solver='bfgs').build()

    @pytest.mark.parametrize('cfg', [
        HypergradConfig(solver='cg', alpha=0.5),           # cg has no alpha
        HypergradConfig(solver='neumann', rho=0.5),        # neumann: no rho
        HypergradConfig(solver='exact', k=3),              # exact: no k
        HypergradConfig(solver='cg', backend='flat'),      # backend: nystrom
        HypergradConfig(solver='exact', refine=2),         # refine: nystrom
    ])
    def test_ignored_fields_error_loudly(self, cfg):
        with pytest.raises(ValueError, match='not consumed'):
            cfg.build()

    def test_config_from_cli_rejects_even_default_valued_flags(self):
        """An explicitly passed CLI flag the solver ignores errors even when
        its value coincides with the config default (which build()'s own
        default-comparison cannot distinguish)."""
        from repro.core import config_from_cli
        with pytest.raises(ValueError, match='not consumed'):
            config_from_cli('exact', flags={'k': 10, 'rho': None},
                            defaults={'rho': 1e-2})
        cfg = config_from_cli('exact', flags={'k': None, 'rho': None},
                              defaults={'k': 8, 'rho': 0.5})
        assert cfg.build() == ExactIHVP(rho=0.5)
        cfg = config_from_cli('nystrom', flags={'k': 4, 'rho': None},
                              defaults={'rho': 1e-2}, column_chunk=2)
        assert (cfg.k, cfg.column_chunk) == (4, 2)

    def test_consumed_fields_build(self):
        assert HypergradConfig(solver='cg', k=7, rho=0.0).build() == \
            CGIHVP(iters=7, rho=0.0)
        assert HypergradConfig(solver='neumann', k=9, alpha=0.1).build() == \
            NeumannIHVP(iters=9, alpha=0.1)
        assert HypergradConfig(solver='exact', rho=0.5).build() == \
            ExactIHVP(rho=0.5)
        s = HypergradConfig(solver='nystrom', k=4, kappa=2, refine=0,
                            backend='flat').build()
        assert (s.k, s.kappa, s.refine) == (4, 2, 0)
