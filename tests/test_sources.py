"""Batch-source contracts: determinism, sizing edges, streaming protocol.

The sources carry two load-bearing guarantees the rest of the repo builds
on: (1) step-indexed draws are pure functions of (seed, step) — the
fault-tolerance property every resume/replay path relies on — and (2) the
ordered-streaming protocol (``n_train`` / ``train_slice``) keeps influence
scores' global indices aligned with storage order. EpisodeSource's
meta-batch shape contract (and its refusal to serve a flat stream) rounds
out the set.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.data.sources import ArraySource, EpisodeSource
from repro.data.synthetic import FewShotSampler


def _source(n=12, d=3, n_val=5, seed=0):
    key = jax.random.PRNGKey(99)
    X = jax.random.normal(key, (n, d))
    y = jnp.arange(n) % 2
    Xv = jax.random.normal(key, (n_val, d)) + 1.0
    yv = jnp.arange(n_val) % 2
    return ArraySource(train=(X, y), val=(Xv, yv), seed=seed)


class TestArraySourceDeterminism:
    def test_same_seed_same_stream(self):
        a, b = _source(seed=7), _source(seed=7)
        for step in (0, 1, 5):
            for draw in ('train_batch', 'val_batch'):
                xa, ya = getattr(a, draw)(step, 4)
                xb, yb = getattr(b, draw)(step, 4)
                np.testing.assert_array_equal(xa, xb, err_msg=f'{draw}@{step}')
                np.testing.assert_array_equal(ya, yb)

    def test_different_seed_or_step_differs(self):
        a = _source(n=64, seed=0)
        x0, _ = a.train_batch(0, 8)
        x1, _ = a.train_batch(1, 8)
        assert not np.array_equal(np.asarray(x0), np.asarray(x1))
        b = _source(n=64, seed=1)
        xb, _ = b.train_batch(0, 8)
        assert not np.array_equal(np.asarray(x0), np.asarray(xb))

    def test_train_and_val_streams_independent(self):
        """val keys live at seed+1000+step — step t of each stream must not
        collide (the t vs 1000+t offset)."""
        a = _source(n=64)
        xt, _ = a.train_batch(0, 8)
        xv, _ = a.val_batch(0, 8)
        assert xt.shape == xv.shape == (8, 3)
        assert not np.array_equal(np.asarray(xt), np.asarray(xv))


class TestArraySourceSizing:
    def test_batch_larger_than_split_resamples(self):
        """Draws sample with replacement: a batch bigger than the split is
        served (rows repeat) rather than truncated or raising."""
        src = _source(n=4)
        X, y = src.train_batch(0, 50)
        assert X.shape == (50, 3) and y.shape == (50,)
        # every served row is one of the 4 training rows
        train_rows = np.asarray(src.train[0])
        for row in np.asarray(X):
            assert any(np.array_equal(row, t) for t in train_rows)

    def test_train_slice_contract(self):
        """Storage order, tail clamp, start bounds — the influence-index
        alignment guarantees."""
        src = _source(n=12)
        assert src.n_train == 12
        X, y = src.train_slice(3, 4)
        np.testing.assert_array_equal(X, src.train[0][3:7])
        np.testing.assert_array_equal(y, src.train[1][3:7])
        Xt, yt = src.train_slice(10, 4)            # clamps at the tail
        assert Xt.shape == (2, 3) and yt.shape == (2,)
        np.testing.assert_array_equal(Xt, src.train[0][10:])
        for bad in (-1, 12, 99):
            with pytest.raises(IndexError, match='train_slice'):
                src.train_slice(bad, 4)

    def test_slices_tile_the_split_exactly(self):
        """Concatenated ragged tiles == the split (what the influence sweep
        actually iterates)."""
        src = _source(n=12)
        tiles = [src.train_slice(s, 5) for s in range(0, 12, 5)]  # 5+5+2
        np.testing.assert_array_equal(
            np.concatenate([np.asarray(t[0]) for t in tiles]),
            np.asarray(src.train[0]))


class TestEpisodeSource:
    def test_task_batch_shapes(self):
        sampler = FewShotSampler(n_way=5, k_shot=1, seed=0)
        src = EpisodeSource(sampler)
        (sx, sy), (qx, qy) = src.task_batch(0, 3)
        assert sx.shape[0] == sy.shape[0] == 3       # leading task axis
        assert qx.shape[0] == qy.shape[0] == 3
        assert sx.shape[1] == sy.shape[1]            # support examples align
        assert qx.shape[1] == qy.shape[1]
        assert sx.shape[2:] == qx.shape[2:]          # same image shape

    def test_task_batches_deterministic_and_non_overlapping(self):
        sampler = FewShotSampler(n_way=5, k_shot=1, seed=0)
        src = EpisodeSource(sampler)
        (sx0, _), _ = src.task_batch(0, 2)
        (sx0b, _), _ = src.task_batch(0, 2)
        np.testing.assert_array_equal(sx0, sx0b)
        # step 1 draws episodes 2..3, not 0..1 (consecutive, not reused)
        (sx1, _), _ = src.task_batch(1, 2)
        assert not np.array_equal(np.asarray(sx0), np.asarray(sx1))

    def test_flat_stream_refused(self):
        src = EpisodeSource(FewShotSampler(n_way=5, k_shot=1, seed=0))
        for draw in ('train_batch', 'val_batch'):
            with pytest.raises(TypeError, match='meta-problem'):
                getattr(src, draw)(0, 8)
