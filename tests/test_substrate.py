"""Substrate tests: checkpointing (atomic/restart/corruption), data
determinism, loader prefetch, gradient compression, optimizers."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import CheckpointManager, restore, save
from repro.checkpoint.manager import latest_step
from repro.data.loader import Prefetcher, ShardedLoader
from repro.data.synthetic import LongTailDataset, TokenStream
from repro.distributed.compression import (ErrorFeedbackInt8,
                                           quantize_roundtrip)
from repro.optim import adafactor, adam, adamw, chain, clip_by_global_norm, sgd


# ------------------------------------------------------------- checkpointing
class TestCheckpoint:
    def _tree(self, seed=0):
        k = jax.random.PRNGKey(seed)
        return {'w': jax.random.normal(k, (8, 4)),
                'nested': {'b': jnp.arange(6, dtype=jnp.int32)},
                'scalar': jnp.float32(3.5)}

    def test_roundtrip(self, tmp_path):
        tree = self._tree()
        save(str(tmp_path), 7, tree, extra={'note': 'x'})
        out, manifest = restore(str(tmp_path), tree)
        assert manifest['step'] == 7
        for a, b in zip(jax.tree.leaves(out), jax.tree.leaves(tree)):
            np.testing.assert_array_equal(a, b)

    def test_latest_pointer_and_rotation(self, tmp_path):
        mgr = CheckpointManager(str(tmp_path), keep=2, async_save=False)
        for s in (1, 2, 3):
            mgr.save(s, self._tree(s))
        assert mgr.latest_step() == 3
        kept = sorted(n for n in os.listdir(tmp_path) if n.startswith('step_'))
        assert len(kept) == 2                       # rotation

    def test_async_save_then_restore(self, tmp_path):
        mgr = CheckpointManager(str(tmp_path), async_save=True)
        tree = self._tree(4)
        mgr.save(11, tree)
        mgr.wait()
        out, m = mgr.restore_latest(tree)
        assert m['step'] == 11

    def test_corruption_detected(self, tmp_path):
        tree = self._tree()
        d = save(str(tmp_path), 1, tree)
        path = os.path.join(d, 'arrays.npz')
        raw = bytearray(open(path, 'rb').read())
        raw[-9] ^= 0xFF                              # flip a payload byte
        open(path, 'wb').write(bytes(raw))
        with pytest.raises(Exception):
            restore(str(tmp_path), tree)

    def test_partial_write_never_becomes_latest(self, tmp_path):
        tree = self._tree()
        save(str(tmp_path), 1, tree)
        # simulate a crash mid-write of step 2: tmp dir exists, no rename
        os.makedirs(os.path.join(tmp_path, 'step_0000000002.tmp'))
        assert latest_step(str(tmp_path)) == 1
        CheckpointManager(str(tmp_path))             # GC cleans the .tmp
        assert not os.path.exists(
            os.path.join(tmp_path, 'step_0000000002.tmp'))

    def test_trainer_restart_resumes(self, tmp_path):
        """The fault-tolerance drill: train 60 steps, 'crash', relaunch with
        the same ckpt dir, verify it resumes past the checkpoint."""
        from repro.launch import train
        argv = ['--arch', 'yi_9b', '--reduced', '--batch', '2', '--seq', '32',
                '--outer-every', '1000', '--ckpt-every', '30',
                '--ckpt-dir', str(tmp_path), '--log-every', '0']
        train.main(argv + ['--steps', '35'])
        assert latest_step(str(tmp_path)) == 35
        loss, _ = train.main(argv + ['--steps', '45'])  # resumes at 35
        assert latest_step(str(tmp_path)) == 45
        assert np.isfinite(loss)


# --------------------------------------------------------------------- data
class TestData:
    def test_token_stream_deterministic(self):
        s1 = TokenStream(vocab_size=512, seq_len=16)
        s2 = TokenStream(vocab_size=512, seq_len=16)
        b1, b2 = s1.batch(5, 4), s2.batch(5, 4)
        np.testing.assert_array_equal(b1['inputs'], b2['inputs'])

    def test_noisy_domains_are_harder(self):
        """Next-token predictability differs between clean/noisy domains —
        the signal the bilevel reweighting driver must find."""
        s = TokenStream(vocab_size=512, seq_len=64)
        b = s.batch(0, 256)
        inputs, labels, dom = (np.asarray(b['inputs']), np.asarray(b['labels']),
                               np.asarray(b['domain']))
        match = (s.next_tok[dom[:, None].repeat(64, 1),
                            inputs] == labels).mean(1)
        noisy = np.isin(dom, s.noisy_domains)
        assert match[~noisy].mean() > match[noisy].mean() + 0.3

    def test_longtail_profile(self):
        data = LongTailDataset(imbalance_factor=100)
        counts = np.bincount(np.asarray(data.y), minlength=10)
        assert counts[0] > 5 * counts[-1]            # heavy head (label noise
        # keeps tail counts nonzero)

    def test_loader_resume_state(self):
        stream = TokenStream(vocab_size=128, seq_len=8)
        l1 = ShardedLoader(lambda s: stream.batch(s, 2))
        next(l1)
        next(l1)
        st = l1.state_dict()
        l2 = ShardedLoader(lambda s: stream.batch(s, 2))
        l2.load_state_dict(st)
        np.testing.assert_array_equal(next(l1)['inputs'], next(l2)['inputs'])

    def test_prefetcher_order_and_errors(self):
        pf = Prefetcher(iter(range(5)), depth=2)
        assert list(pf) == list(range(5))

        def bad():
            yield 1
            raise RuntimeError('boom')

        pf = Prefetcher(bad(), depth=2)
        assert next(pf) == 1
        with pytest.raises(RuntimeError):
            next(pf)


# -------------------------------------------------------------- compression
class TestCompression:
    def test_quantize_roundtrip_error_bound(self):
        x = jax.random.normal(jax.random.PRNGKey(0), (1000,)) * 5
        y = quantize_roundtrip(x)
        blk_max = float(jnp.abs(x).max())
        assert float(jnp.abs(x - y).max()) <= blk_max / 127 + 1e-6

    def test_error_feedback_accumulates(self):
        """With error feedback, the accumulated compressed sum tracks the
        accumulated true sum (the EF-SGD convergence ingredient)."""
        ef = ErrorFeedbackInt8()
        g = {'w': jnp.full((256,), 1e-3)}            # tiny: quantizes to ~0
        state = ef.init(g)
        total = jnp.zeros((256,))
        for _ in range(50):
            q, state = ef.update(g, state)
            total = total + q['w']
        np.testing.assert_allclose(total, 50e-3, rtol=0.15)

    def test_compressed_psum_matches_plain(self):
        """One contribution row per device; on the 1-device CPU mesh the
        quantized sum must round-trip the single contribution."""
        mesh = jax.make_mesh((1,), ('x',))
        from repro.distributed.compression import compressed_all_reduce
        x = jax.random.normal(jax.random.PRNGKey(1), (512,))
        out = jax.jit(lambda v: compressed_all_reduce(v, mesh, 'x'))(x[None])
        assert out.shape == x.shape
        np.testing.assert_allclose(out, x, atol=float(jnp.abs(x).max()) / 100)

    def test_compressed_all_reduce_sums_every_row(self):
        """More contribution rows than devices: every row must reach the
        sum (a 3-row stack on the 1-device mesh returns row0+row1+row2)."""
        mesh = jax.make_mesh((1,), ('x',))
        from repro.distributed.compression import compressed_all_reduce
        contribs = jnp.stack([jnp.full((64,), 1.0), jnp.full((64,), 2.0),
                              jnp.full((64,), 4.0)])
        out = compressed_all_reduce(contribs, mesh, 'x')
        np.testing.assert_allclose(out, 7.0, atol=0.1)


# ---------------------------------------------------------------- optimizers
@pytest.mark.parametrize('make', [lambda: sgd(0.1), lambda: adam(0.1),
                                  lambda: adamw(0.1, weight_decay=0.01),
                                  lambda: adafactor(0.1),
                                  lambda: chain(clip_by_global_norm(1.0),
                                                adam(0.1))])
def test_optimizers_minimize_quadratic(make):
    opt = make()
    params = {'w': jnp.ones((6, 3)) * 4.0, 'b': jnp.ones((3,))}
    st = opt.init(params)
    for i in range(300):
        g = jax.tree.map(lambda p: 2 * p, params)
        params, st = opt.apply(g, st, params, jnp.int32(i))
    norm = jnp.sqrt(sum(jnp.sum(p * p) for p in jax.tree.leaves(params)))
    assert norm < 0.2
