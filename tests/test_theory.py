"""Property-based tests of the paper's theory (Theorem 1, Remark 1).

Theorem 1:  ‖h* − h‖₂ ≤ ‖g‖₂ ‖F‖op · (1/ρ) ‖E‖op / (ρ + ‖E‖op),  E = H − H_k.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip('hypothesis', reason='property tests need the test extra')
from hypothesis import given, settings, strategies as st


def _nystrom_pieces(H, k, rho, seed):
    p = H.shape[0]
    idx = jax.random.choice(jax.random.PRNGKey(seed), p, (k,), replace=False)
    C = H[:, idx]
    H_KK = 0.5 * (C[idx, :] + C[idx, :].T)
    H_k = C @ jnp.linalg.pinv(H_KK, rcond=1e-6) @ C.T
    inv_true = jnp.linalg.inv(H + rho * jnp.eye(p))
    inv_ny = jnp.linalg.inv(H_k + rho * jnp.eye(p))
    return H_k, inv_true, inv_ny


@settings(max_examples=25, deadline=None)
@given(st.integers(0, 10_000), st.integers(2, 10), st.integers(1, 12),
       st.sampled_from([1e-2, 1e-1, 1.0]))
def test_theorem1_bound(seed, r, h_dim, rho):
    """The hypergradient error never exceeds the Theorem 1 bound."""
    p = 24
    key = jax.random.PRNGKey(seed)
    k1, k2, k3 = jax.random.split(key, 3)
    A = jax.random.normal(k1, (p, r))
    H = A @ A.T                                   # PSD, rank r
    k = min(r + 2, p)
    H_k, inv_true, inv_ny = _nystrom_pieces(H, k, rho, seed + 1)

    g = jax.random.normal(k2, (p,))
    F = jax.random.normal(k3, (p, h_dim))

    h_star = -g @ inv_true @ F
    h_ny = -g @ inv_ny @ F

    E_op = jnp.linalg.norm(H - H_k, ord=2)
    bound = (jnp.linalg.norm(g) * jnp.linalg.norm(F, ord=2)
             * (1.0 / rho) * E_op / (rho + E_op))
    lhs = jnp.linalg.norm(h_star - h_ny)
    assert lhs <= bound * (1 + 1e-4) + 1e-5, (float(lhs), float(bound))


@settings(max_examples=20, deadline=None)
@given(st.integers(0, 10_000), st.integers(1, 8))
def test_exact_recovery_rank_k(seed, r):
    """Remark 1 corollary: rank-r H ⇒ E[‖H − H_r‖] → 0 with r independent
    columns; for random PSD H the recovery is exact a.s."""
    p = 20
    A = jax.random.normal(jax.random.PRNGKey(seed), (p, r))
    H = A @ A.T
    H_k, _, _ = _nystrom_pieces(H, r, 1e-2, seed + 1)
    scale = jnp.abs(H).max() + 1e-9
    assert jnp.abs(H - H_k).max() / scale < 5e-3


@settings(max_examples=20, deadline=None)
@given(st.integers(0, 10_000))
def test_nystrom_error_monotone_in_k_on_average(seed):
    """More columns ⇒ (weakly) better sketch, measured in operator norm."""
    p, r = 24, 12
    A = jax.random.normal(jax.random.PRNGKey(seed), (p, r))
    H = A @ A.T
    errs = []
    for k in (2, 6, 12):
        H_k, _, _ = _nystrom_pieces(H, k, 1e-2, seed + 7)
        errs.append(float(jnp.linalg.norm(H - H_k, ord=2)))
    assert errs[2] <= errs[0] + 1e-3


@settings(max_examples=15, deadline=None)
@given(st.integers(0, 10_000), st.sampled_from([1e-2, 1e-1, 1.0]))
def test_psd_preserved(seed, rho):
    """(H_k + ρI) stays PD ⇒ the IHVP never flips the gradient direction
    on the sketched subspace (the stability property §2.2 claims)."""
    p, r = 20, 8
    A = jax.random.normal(jax.random.PRNGKey(seed), (p, r))
    H = A @ A.T
    H_k, _, inv_ny = _nystrom_pieces(H, r + 2, rho, seed + 3)
    eigs = jnp.linalg.eigvalsh(0.5 * (inv_ny + inv_ny.T))
    assert eigs.min() > 0.0
