"""Serving tier: sketch store, query batcher, and the service loop.

What is pinned here, layer by layer:

  SketchStore    content keying (params digest × ρ-free solver fingerprint),
                 hit/miss accounting, LRU eviction ORDER under the byte
                 budget, explicit invalidation, and policy-wired staleness
                 (refresh_every as max-serves);
  QueryBatcher   stack/split roundtrip is exact; a single query flushed
                 through a (p, 1) block is BITWISE-equal to the vector
                 apply (the same m=1 static dispatch tests/test_block_apply
                 pins); interleaved submissions through a batched (p, m)
                 flush match per-vector applies column by column; deadline
                 and block-full flush triggers under an injected clock;
  InfluenceService  the PR's headline regression test — a second
                 influence() call with identical params/config bills ZERO
                 sketch-build HVPs through the store — plus backpressure
                 (bounded queue raises), graceful degradation (failing
                 prepare falls back to CG with a warning logged), and
                 schema-valid bench rows.
"""
import dataclasses
import logging

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (CGIHVP, NystromIHVP, PyTreeIndexer, SketchPolicy,
                        get_problem, influence, make_hvp, solver_fingerprint,
                        state_nbytes, train_influence_params,
                        tree_random_like)
from repro.core.solvers import ExactIHVP
from repro.serve import (InfluenceService, QueryBatcher, ServiceOverloaded,
                         SketchKey, SketchStore, sketch_key)
from repro.serve.batcher import split_block, stack_block

PARAMS = {'w': jnp.zeros((8,)), 'm': jnp.zeros((13, 7)), 's': jnp.zeros(())}


def _quadratic(seed=0):
    from repro.core import flatten_vec
    idxr = PyTreeIndexer(PARAMS)
    p = idxr.total
    B = jax.random.normal(jax.random.PRNGKey(seed), (p, 16))
    Hm = B @ B.T / p + 0.5 * jnp.eye(p)

    def loss(prm, hp, batch):
        th = flatten_vec(prm)
        return 0.5 * th @ Hm @ th

    return idxr, make_hvp(loss, PARAMS, None, None)


def _prepared(seed=0, k=6):
    idxr, hvp = _quadratic(seed)
    solver = NystromIHVP(k=k, rho=1e-2)
    return solver, solver.prepare(hvp, idxr, jax.random.PRNGKey(seed))


@pytest.fixture(scope='module')
def toy():
    """One tiny trained influence problem shared by the service tests."""
    problem = get_problem('influence', d=8, width=8)
    params = train_influence_params(problem, train_steps=5)
    return problem, params


# ---------------------------------------------------------------------------
# SketchKey / fingerprints
# ---------------------------------------------------------------------------
class TestSketchKey:
    def test_content_addressed_not_identity(self):
        a = {'w': jnp.ones((3,))}
        b = {'w': jnp.ones((3,))}          # distinct object, same content
        s = NystromIHVP(k=4)
        assert sketch_key(a, s) == sketch_key(b, s)

    def test_params_change_changes_key(self):
        s = NystromIHVP(k=4)
        assert (sketch_key({'w': jnp.ones((3,))}, s)
                != sketch_key({'w': jnp.zeros((3,))}, s))

    def test_rho_free(self):
        """One sketch serves a damping sweep: rho is NOT part of the key."""
        p = {'w': jnp.ones((3,))}
        assert (sketch_key(p, NystromIHVP(k=4, rho=1e-3))
                == sketch_key(p, NystromIHVP(k=4, rho=10.0)))

    def test_k_and_backend_split_keys(self):
        p = {'w': jnp.ones((3,))}
        base = sketch_key(p, NystromIHVP(k=4))
        assert sketch_key(p, NystromIHVP(k=8)) != base
        assert sketch_key(p, NystromIHVP(k=4, backend='flat')) != base

    def test_iterative_solver_rejected(self):
        with pytest.raises(TypeError, match='trace-local'):
            sketch_key({'w': jnp.ones((3,))}, CGIHVP(iters=5))

    def test_fingerprint_distinguishes_solver_types(self):
        assert (solver_fingerprint(ExactIHVP(rho=1e-2))
                != solver_fingerprint(NystromIHVP(k=4, rho=1e-2)))


# ---------------------------------------------------------------------------
# SketchStore
# ---------------------------------------------------------------------------
def _key(tag: str) -> SketchKey:
    return SketchKey(params=tag, solver='nystrom;k=4')


class TestSketchStore:
    def test_miss_builds_hit_reuses(self):
        _, state = _prepared()
        store = SketchStore()
        calls = []
        build = lambda: (calls.append(1), state)[1]
        s1, built1 = store.get_or_build(_key('a'), build, build_hvps=6)
        s2, built2 = store.get_or_build(_key('a'), build, build_hvps=6)
        assert built1 and not built2
        assert len(calls) == 1             # the hit ran NO build
        assert s1 is s2
        assert (store.hits, store.misses) == (1, 1)
        assert store.hit_rate == 0.5

    def test_lru_eviction_order(self):
        """Oldest-touched entry goes first; a hit refreshes recency."""
        _, state = _prepared()
        nbytes = state_nbytes(state)
        store = SketchStore(byte_budget=3 * nbytes)
        for tag in ('a', 'b', 'c'):
            store.get_or_build(_key(tag), lambda: state)
        store.get_or_build(_key('a'), lambda: state)   # touch a → b is LRU
        store.get_or_build(_key('d'), lambda: state)   # over budget: evict b
        assert store.evictions == 1
        assert _key('b') not in store
        assert store.keys() == [_key('c'), _key('a'), _key('d')]

    def test_single_entry_over_budget_is_kept(self):
        _, state = _prepared()
        store = SketchStore(byte_budget=1)   # smaller than any sketch
        store.get_or_build(_key('a'), lambda: state)
        assert _key('a') in store            # never evict the only entry
        _, built = store.get_or_build(_key('a'), lambda: state)
        assert not built

    def test_invalidate_forces_rebuild(self):
        _, state = _prepared()
        store = SketchStore()
        store.get_or_build(_key('a'), lambda: state)
        assert store.invalidate(_key('a'))
        assert not store.invalidate(_key('a'))      # already gone
        _, built = store.get_or_build(_key('a'), lambda: state)
        assert built
        assert store.invalidations == 1

    def test_invalidate_params_drops_all_solver_variants(self):
        """The checkpoint-refresh hook: new params digest kills every sketch
        prepared at the old one, whatever the solver config."""
        _, state = _prepared()
        store = SketchStore()
        store.get_or_build(SketchKey('old', 'k=4'), lambda: state)
        store.get_or_build(SketchKey('old', 'k=8'), lambda: state)
        store.get_or_build(SketchKey('new', 'k=4'), lambda: state)
        assert store.invalidate_params('old') == 2
        assert store.keys() == [SketchKey('new', 'k=4')]

    def test_policy_refresh_every_is_max_serves(self):
        """invalidation-on-refresh: a policy with refresh_every=N ages a
        cached state out after N serves, same definition of stale as the
        trainer loop."""
        solver, state = _prepared()
        policy = SketchPolicy(solver=solver, inner_loss=lambda p, h, b: 0.0,
                              refresh_every=2)
        store = SketchStore(policy=policy)
        assert store.max_serves == 2
        _, b1 = store.get_or_build(_key('a'), lambda: state)
        _, b2 = store.get_or_build(_key('a'), lambda: state)   # serve 2
        _, b3 = store.get_or_build(_key('a'), lambda: state)   # stale → build
        assert (b1, b2, b3) == (True, False, True)
        assert store.expirations == 1

    def test_always_fresh_policy_does_not_disable_caching(self):
        solver, _ = _prepared()
        policy = SketchPolicy(solver=solver, inner_loss=lambda p, h, b: 0.0,
                              refresh_every=1)
        assert SketchStore(policy=policy).max_serves is None

    def test_failed_build_caches_nothing(self):
        store = SketchStore()

        def boom():
            raise RuntimeError('numerical fire')

        with pytest.raises(RuntimeError):
            store.get_or_build(_key('a'), boom)
        assert len(store) == 0 and store.misses == 1

    def test_bytes_accounting_matches_state_nbytes(self):
        _, state = _prepared()
        store = SketchStore()
        store.get_or_build(_key('a'), lambda: state)
        assert store.total_bytes == state_nbytes(state)


class TestSketchStoreSpill:
    def test_spill_roundtrip_serves_without_rebuilding(self, tmp_path):
        idxr, hvp = _quadratic()
        solver = NystromIHVP(k=6, rho=1e-2)
        build = lambda: solver.prepare(hvp, idxr, jax.random.PRNGKey(0))
        key = _key('a')

        writer = SketchStore(spill_dir=tmp_path)
        state, built = writer.get_or_build(key, build, build_hvps=6)
        assert built
        path = writer.save_entry(key)
        assert path.exists() and path.name == f'{key.params}__{key.solver}.npz'

        # a cold store over the same directory resolves the key from disk:
        # no build thunk runs, zero HVPs are billed, built=False like a
        # warm memory hit
        def poisoned():
            raise AssertionError('disk hit must not run the build')

        reader = SketchStore(spill_dir=tmp_path)
        like = jax.eval_shape(build)
        loaded, built2 = reader.get_or_build(key, poisoned, like=like)
        assert not built2
        assert reader.disk_hits == 1 and reader.misses == 0
        assert reader._entries[key].build_hvps == 0
        for a, b in zip(jax.tree.leaves(state), jax.tree.leaves(loaded)):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))

        # and the re-entered state is a normal memory entry afterwards
        again, built3 = reader.get_or_build(key, poisoned, like=like)
        assert not built3 and reader.hits == 1

    def test_template_mismatch_rejected(self, tmp_path):
        idxr, hvp = _quadratic()
        solver = NystromIHVP(k=6, rho=1e-2)
        store = SketchStore(spill_dir=tmp_path)
        key = _key('a')
        store.get_or_build(
            key, lambda: solver.prepare(hvp, idxr, jax.random.PRNGKey(0)))
        store.save_entry(key)
        wrong = NystromIHVP(k=4, rho=1e-2)
        bad_like = jax.eval_shape(
            lambda: wrong.prepare(hvp, idxr, jax.random.PRNGKey(0)))
        with pytest.raises(ValueError, match='template'):
            store.load_entry(key, bad_like)

    def test_missing_spill_and_no_dir(self, tmp_path):
        _, state = _prepared()
        store = SketchStore(spill_dir=tmp_path)
        like = jax.tree.map(
            lambda a: jax.ShapeDtypeStruct(a.shape, a.dtype), state)
        with pytest.raises(FileNotFoundError):
            store.load_entry(_key('ghost'), like)
        assert store.load_entry(_key('ghost'), like, missing_ok=True) is None
        bare = SketchStore()
        with pytest.raises(ValueError, match='spill_dir'):
            bare.save_entry(_key('a'))


# ---------------------------------------------------------------------------
# QueryBatcher
# ---------------------------------------------------------------------------
class TestQueryBatcher:
    def test_stack_split_roundtrip_bitwise(self):
        cols = [tree_random_like(k, PARAMS)
                for k in jax.random.split(jax.random.PRNGKey(0), 5)]
        back = split_block(stack_block(cols), 5)
        for orig, rt in zip(cols, back):
            for a, b in zip(jax.tree.leaves(orig), jax.tree.leaves(rt)):
                np.testing.assert_array_equal(a, b)

    def test_m1_flush_bitwise_matches_vector_apply(self):
        """A single query through the batcher's (p, 1) block == the direct
        vector apply, bit for bit (the m=1 static dispatch)."""
        solver, state = _prepared(seed=7)
        batcher = QueryBatcher(block_size=4, max_delay=0.0)
        v = tree_random_like(jax.random.PRNGKey(8), PARAMS)
        batcher.submit(v)
        block, taken = batcher.take_block()
        assert len(taken) == 1
        [u_col] = split_block(solver.apply_matrix(state, block), 1)
        u_vec = solver.apply(state, v)
        for a, b in zip(jax.tree.leaves(u_col), jax.tree.leaves(u_vec)):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))

    def test_interleaved_submissions_match_per_vector_applies(self):
        """Queries submitted one by one, answered through one batched (p, m)
        flush, match applying each vector individually — the batcher adds
        batching, not error. Whitened-path block algebra is exact per
        column here; the shared assertion is the block-apply contract's
        f32-roundoff bound."""
        solver, state = _prepared(seed=9)
        batcher = QueryBatcher(block_size=4, max_delay=10.0)
        vecs = [tree_random_like(k, PARAMS)
                for k in jax.random.split(jax.random.PRNGKey(10), 4)]
        for v in vecs:
            batcher.submit(v)
        assert batcher.due()               # full block
        block, taken = batcher.take_block()
        assert [q.ticket for q in taken] == [0, 1, 2, 3]
        cols = split_block(solver.apply_matrix(state, block), 4)
        from repro.core import flatten_vec
        for v, got in zip(vecs, cols):
            want = solver.apply(state, v)
            np.testing.assert_allclose(
                np.asarray(flatten_vec(got)), np.asarray(flatten_vec(want)),
                rtol=2e-4, atol=2e-3)

    def test_flush_triggers_under_injected_clock(self):
        now = [0.0]
        batcher = QueryBatcher(block_size=3, max_delay=1.0,
                               clock=lambda: now[0])
        v = tree_random_like(jax.random.PRNGKey(0), PARAMS)
        assert not batcher.due()           # empty
        batcher.submit(v)
        assert not batcher.due()           # young, not full
        now[0] = 0.5
        assert not batcher.due()
        now[0] = 1.0                       # oldest aged out
        assert batcher.due()
        assert batcher.next_due_at() == 1.0
        batcher.take_block()
        # deadline flush: due the moment (deadline - slack) passes, even
        # though max_delay has not elapsed
        batcher.deadline_slack = 0.25
        batcher.submit(v, deadline=now[0] + 0.5)
        assert not batcher.due()
        now[0] += 0.25
        assert batcher.due()

    def test_block_full_flushes_regardless_of_clock(self):
        batcher = QueryBatcher(block_size=2, max_delay=1e9)
        v = tree_random_like(jax.random.PRNGKey(0), PARAMS)
        batcher.submit(v)
        assert not batcher.due()
        batcher.submit(v)
        assert batcher.due()
        block, taken = batcher.take_block()
        assert len(taken) == 2 and len(batcher) == 0

    def test_take_block_pops_oldest_first(self):
        batcher = QueryBatcher(block_size=2, max_delay=0.0)
        v = tree_random_like(jax.random.PRNGKey(0), PARAMS)
        tickets = [batcher.submit(v) for _ in range(3)]
        _, taken = batcher.take_block()
        assert [q.ticket for q in taken] == tickets[:2]
        assert len(batcher) == 1

    def test_empty_take_rejected(self):
        with pytest.raises(ValueError, match='empty'):
            QueryBatcher().take_block()


# ---------------------------------------------------------------------------
# influence() through the store — the warm-path-zero-HVPs regression test
# ---------------------------------------------------------------------------
class TestInfluenceThroughStore:
    def test_warm_call_bills_zero_build_hvps(self, toy):
        """THE satellite fix: repeated influence() with identical params and
        config used to silently redo the k sketch HVPs; through the store
        the second call is a warm hit and bills hvp_count == 0."""
        problem, params = toy
        solver = NystromIHVP(k=4, rho=1e-2)
        store = SketchStore()
        queries = problem.reference['queries'](2)
        cold = influence(problem, solver, queries, params=params, top_k=5,
                         store=store)
        warm = influence(problem, solver, queries, params=params, top_k=5,
                         store=store)
        assert cold.hvp_count == 4         # one k-HVP build
        assert warm.hvp_count == 0         # the whole point of the store
        assert (store.hits, store.misses) == (1, 1)
        np.testing.assert_array_equal(np.asarray(cold.scores),
                                      np.asarray(warm.scores))
        np.testing.assert_array_equal(np.asarray(cold.indices),
                                      np.asarray(warm.indices))

    def test_rho_sweep_reuses_one_sketch(self, toy):
        """ρ-free keying end to end: a damping sweep pays ONE build."""
        problem, params = toy
        store = SketchStore()
        queries = problem.reference['queries'](1)
        for rho in (1e-3, 1e-2, 1e-1):
            influence(problem, NystromIHVP(k=4, rho=rho), queries,
                      params=params, top_k=5, store=store)
        assert store.misses == 1 and store.hits == 2

    def test_iterative_solver_bypasses_store(self, toy):
        problem, params = toy
        store = SketchStore()
        res = influence(problem, CGIHVP(iters=3, rho=1e-2),
                        problem.reference['queries'](2), params=params,
                        top_k=5, store=store)
        assert len(store) == 0             # nothing cacheable
        assert res.hvp_count == 6          # iters × m, as before

    def test_disk_restart_serves_with_zero_hvps(self, toy, tmp_path):
        """Server-restart warm start: spill after the cold call, then a
        fresh store over the same directory answers from disk — zero build
        HVPs, identical scores, no prepare run at all."""
        problem, params = toy
        solver = NystromIHVP(k=4, rho=1e-2)
        queries = problem.reference['queries'](2)
        first = SketchStore(spill_dir=tmp_path)
        cold = influence(problem, solver, queries, params=params, top_k=5,
                         store=first)
        first.save_entry(sketch_key(params, solver))

        restarted = SketchStore(spill_dir=tmp_path)
        warm = influence(problem, solver, queries, params=params, top_k=5,
                         store=restarted)
        assert cold.hvp_count == 4
        assert warm.hvp_count == 0
        assert restarted.disk_hits == 1 and restarted.misses == 0
        np.testing.assert_array_equal(np.asarray(cold.scores),
                                      np.asarray(warm.scores))
        np.testing.assert_array_equal(np.asarray(cold.indices),
                                      np.asarray(warm.indices))

    def test_influence_and_engine_bills_share_one_definition(self, toy):
        """The accounting invariant across paths: influence()'s per-build
        bill, the store's per-entry build_hvps, and the engine's per-edge
        bills all come from repro.core.build_hvp_bill — k HVPs per Nyström
        build, p per exact column scan, and a reused state bills zero."""
        from repro.core import build_hvp_bill, tree_size
        from repro.core.hypergrad import HypergradConfig
        from repro.core.problem import influence_build_hvps
        from repro.engine import engine_edge_bills, from_bilevel

        problem, params = toy
        ny = NystromIHVP(k=4, rho=1e-2)
        assert influence_build_hvps(ny, params) == build_hvp_bill(ny, params) == 4
        assert (influence_build_hvps(ExactIHVP(), params)
                == build_hvp_bill(ExactIHVP(), params) == tree_size(params))

        # the engine's amortized bill on a bilevel wrap is builds × the SAME
        # per-build: one build per outer step at refresh_every=1
        class Quad:
            def inner_loss(self, theta, phi, batch):
                return 0.5 * jnp.sum(theta ** 2) - jnp.sum(theta * phi)

            def outer_loss(self, theta, phi, batch):
                return 0.5 * jnp.sum(theta ** 2)

            def init_params(self, rng):
                return jnp.zeros(3)

            def init_hparams(self, rng):
                return jnp.ones(3)

        g = from_bilevel(Quad(), config=HypergradConfig(solver='nystrom',
                                                        k=2, rho=1e-2))
        assert engine_edge_bills(g, n_outer=5) == {'params': 5 * 2}

        # and a store entry's bill is the same number influence() reports
        store = SketchStore()
        cold = influence(problem, ny, problem.reference['queries'](1),
                         params=params, top_k=5, store=store)
        (entry,) = store._entries.values()
        assert entry.build_hvps == cold.hvp_count == 4


# ---------------------------------------------------------------------------
# InfluenceService
# ---------------------------------------------------------------------------
class TestInfluenceService:
    def test_batched_answers_match_oneshot_influence(self, toy):
        problem, params = toy
        solver = NystromIHVP(k=4, rho=1e-2)
        queries = problem.reference['queries'](3)
        ref = influence(problem, solver, queries, params=params, top_k=5)
        svc = InfluenceService(problem, solver, params=params, top_k=5,
                               block_size=3, max_delay=60.0)
        tickets = [svc.submit(jax.tree.map(lambda x: x[q], queries))
                   for q in range(3)]
        assert svc.pump() == 3             # block full → one flush
        for q, t in enumerate(tickets):
            resp = svc.result(t)
            assert resp.batched_m == 3
            # query grads are computed per request (not vmapped as a batch),
            # so scores agree to f32 roundoff; top-k identity is exact
            np.testing.assert_allclose(np.asarray(resp.scores),
                                       np.asarray(ref.scores[q]), rtol=1e-4)
            np.testing.assert_array_equal(np.asarray(resp.indices),
                                          np.asarray(ref.indices[q]))

    def test_warm_requests_run_zero_build_hvps(self, toy):
        problem, params = toy
        svc = InfluenceService(problem, NystromIHVP(k=4, rho=1e-2),
                               params=params, top_k=5, block_size=1)
        svc.prepare()                      # the one build, off-path
        svc.reset_metrics()
        q = jax.tree.map(lambda x: x[0], problem.reference['queries'](1))
        for _ in range(3):
            svc.submit(q)
            svc.flush()
        row = svc.bench_rows(phase='warm')[0]
        assert row['hvp_count'] == 0
        assert svc.store.hits == 3

    def test_backpressure(self, toy):
        problem, params = toy
        svc = InfluenceService(problem, NystromIHVP(k=4, rho=1e-2),
                               params=params, top_k=5, block_size=8,
                               max_delay=60.0, max_queue=2)
        q = jax.tree.map(lambda x: x[0], problem.reference['queries'](1))
        svc.submit(q)
        svc.submit(q)
        with pytest.raises(ServiceOverloaded, match='queue full'):
            svc.submit(q)
        svc.flush()                        # draining restores capacity
        svc.submit(q)

    def test_degrades_to_cg_on_build_failure(self, toy, caplog):
        problem, params = toy

        @dataclasses.dataclass(frozen=True)
        class Broken(NystromIHVP):
            def prepare(self, *a, **k):
                raise RuntimeError('sketch factorization blew up')

        svc = InfluenceService(problem, Broken(k=4, rho=1e-2), params=params,
                               top_k=5, block_size=1)
        q = jax.tree.map(lambda x: x[0], problem.reference['queries'](1))
        with caplog.at_level(logging.WARNING, logger='repro.serve.service'):
            t = svc.submit(q)
            svc.flush()
        assert any('degrading' in r.message for r in caplog.records)
        resp = svc.result(t)
        assert resp.degraded and not resp.cache_hit
        assert resp.scores.shape == (5,)   # still answered
        assert svc.degraded_flushes == 1
        assert svc.bench_rows()[0]['hvp_count'] == svc._fallback.iters

    def test_deadline_miss_is_recorded(self, toy):
        problem, params = toy
        now = [0.0]
        svc = InfluenceService(problem, NystromIHVP(k=4, rho=1e-2),
                               params=params, top_k=5, block_size=1,
                               clock=lambda: now[0])
        q = jax.tree.map(lambda x: x[0], problem.reference['queries'](1))
        t = svc.submit(q, deadline_s=0.5)
        now[0] = 1.0                       # the deadline passes unanswered
        svc.flush()
        assert svc.result(t).deadline_missed and svc.deadline_misses == 1

    def test_bench_rows_are_schema_valid(self, toy):
        from benchmarks.common import BENCH_V2_REQUIRED_KEYS
        problem, params = toy
        svc = InfluenceService(problem, NystromIHVP(k=4, rho=1e-2),
                               params=params, top_k=5, block_size=1)
        q = jax.tree.map(lambda x: x[0], problem.reference['queries'](1))
        svc.submit(q)
        svc.flush()
        [row] = svc.bench_rows()
        for key in BENCH_V2_REQUIRED_KEYS:
            assert key in row, key
        assert row['phase'] == 'serve'
        assert 0.0 <= row['cache_hit_rate'] <= 1.0
        assert row['latency_p95_ms'] >= row['latency_p50_ms'] >= 0.0

    def test_result_before_flush_raises(self, toy):
        problem, params = toy
        svc = InfluenceService(problem, NystromIHVP(k=4, rho=1e-2),
                               params=params, top_k=5, block_size=4,
                               max_delay=60.0)
        q = jax.tree.map(lambda x: x[0], problem.reference['queries'](1))
        t = svc.submit(q)
        with pytest.raises(KeyError, match='not answered'):
            svc.result(t)
        svc.flush()
        svc.result(t)
