"""influence(): the matrix-IHVP service against a dense oracle.

The oracle materializes what the service must never: the full (m, n_train)
score matrix s(q, i) = −∇L(q)ᵀ (H+ρI)⁻¹ ∇L(zᵢ) from an explicit dense
Hessian. ``influence`` streams (m, b) tiles through a running top-k merge
instead — these tests pin that the streamed top-k (values AND global
indices, across ragged batch boundaries) equals the oracle's, for the exact
solver and for a full-rank Nyström sketch, plus the protocol errors and the
HVP accounting the result reports.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (CGIHVP, ExactIHVP, HypergradConfig, InfluenceProblem,
                        NystromIHVP, influence)
from repro.data.sources import ArraySource

N, D, M = 40, 5, 6        # train examples / features / queries
RHO = 1e-2


def _toy(seed=0):
    """Binary logistic regression, params one flat vector (w ++ bias)."""
    k1, k2, k3 = jax.random.split(jax.random.PRNGKey(seed), 3)
    X = jax.random.normal(k1, (N, D))
    w_true = jax.random.normal(k2, (D,))
    y = (X @ w_true > 0).astype(jnp.float32)
    Xq = jax.random.normal(k3, (M, D))
    yq = (Xq @ w_true > 0).astype(jnp.float32)

    def loss(params, batch):
        Xb, yb = batch
        z = Xb @ params['w'][:D] + params['w'][D]
        return jnp.mean(jnp.maximum(z, 0) - z * yb
                        + jnp.log1p(jnp.exp(-jnp.abs(z))))

    problem = InfluenceProblem(
        name='toy', loss=loss,
        init_params=lambda rng: {'w': jnp.zeros((D + 1,))},
        data=ArraySource(train=(X, y), val=(Xq, yq)))
    params = {'w': 0.1 * jax.random.normal(jax.random.PRNGKey(9), (D + 1,))}
    return problem, params, (X, y), (Xq, yq)


def _oracle(problem, params, train, queries, rho=RHO):
    """Full (m, n) score matrix from the dense Hessian — no streaming."""
    X, y = train
    H = jax.hessian(lambda w: problem.loss({'w': w}, train))(params['w'])
    g = lambda batch: jax.vmap(lambda Xi, yi: jax.grad(
        lambda w: problem.loss({'w': w}, (Xi[None], yi[None])))(
            params['w']))(*batch)
    G_t, G_q = g(train), g(queries)                      # (n, p), (m, p)
    S = jnp.linalg.solve(H + rho * jnp.eye(H.shape[0]), G_q.T)   # (p, m)
    return -(S.T @ G_t.T)                                # (m, n)


def _topk(scores, k):
    idx = np.argsort(-np.asarray(scores), axis=1)[:, :k]
    return np.take_along_axis(np.asarray(scores), idx, axis=1), idx


class TestDenseOracle:
    def test_exact_solver_matches_oracle(self):
        """Streamed top-k == dense-matrix top-k, across ragged tiles
        (batch_size=7 over n=40 ⇒ a 5-example tail tile)."""
        problem, params, train, queries = _toy()
        res = influence(problem, ExactIHVP(rho=RHO), queries, params=params,
                        top_k=4, batch_size=7, self_influence=True)
        ref_v, ref_i = _topk(_oracle(problem, params, train, queries), 4)
        np.testing.assert_array_equal(np.asarray(res.indices), ref_i)
        np.testing.assert_allclose(np.asarray(res.scores), ref_v,
                                   rtol=1e-4, atol=1e-5)
        # self-influence ∇L(q)ᵀ(H+ρI)⁻¹∇L(q) > 0 (damped PSD quadratic form)
        assert res.self_scores.shape == (M,)
        assert (np.asarray(res.self_scores) > 0).all()

    def test_full_rank_nystrom_matches_exact(self):
        """k = p Nyström is the exact inverse up to f32: same top-k."""
        problem, params, train, queries = _toy(seed=3)
        ny = influence(problem, NystromIHVP(k=D + 1, rho=RHO), queries,
                       params=params, top_k=4, batch_size=16)
        ref_v, ref_i = _topk(_oracle(problem, params, train, queries), 4)
        np.testing.assert_array_equal(np.asarray(ny.indices), ref_i)
        np.testing.assert_allclose(np.asarray(ny.scores), ref_v,
                                   rtol=1e-3, atol=1e-4)

    def test_config_path_equals_built_solver(self):
        problem, params, _, queries = _toy(seed=5)
        via_cfg = influence(problem, HypergradConfig(solver='exact', rho=RHO),
                            queries, params=params, top_k=3)
        direct = influence(problem, ExactIHVP(rho=RHO), queries,
                           params=params, top_k=3)
        np.testing.assert_array_equal(np.asarray(via_cfg.indices),
                                      np.asarray(direct.indices))
        np.testing.assert_allclose(np.asarray(via_cfg.scores),
                                   np.asarray(direct.scores), rtol=1e-6)


class TestResultContract:
    def test_shapes_and_topk_clamp(self):
        problem, params, _, queries = _toy()
        res = influence(problem, ExactIHVP(rho=RHO), queries, params=params,
                        top_k=1000)             # clamps to n_train
        assert res.scores.shape == (M, N)
        assert res.indices.shape == (M, N)
        assert res.self_scores is None
        assert res.problem == 'toy'
        # every training index appears exactly once per query row
        for row in np.asarray(res.indices):
            assert sorted(row.tolist()) == list(range(N))
        # rows are sorted descending
        s = np.asarray(res.scores)
        assert (np.diff(s, axis=1) <= 1e-6).all()

    def test_hvp_accounting(self):
        problem, params, _, queries = _toy()
        kw = dict(queries=queries, params=params, top_k=2)
        assert influence(problem, ExactIHVP(rho=RHO),
                         **kw).hvp_count == D + 1          # dense column scan
        assert influence(problem, NystromIHVP(k=4, rho=RHO),
                         **kw).hvp_count == 4              # k, amortized
        assert influence(problem, CGIHVP(iters=3, rho=RHO),
                         **kw).hvp_count == 3 * M          # per-query chains

    def test_queries_required(self):
        problem, params, _, _ = _toy()
        with pytest.raises(ValueError, match='queries'):
            influence(problem, ExactIHVP(rho=RHO), params=params)

    def test_streaming_source_protocol_enforced(self):
        problem, params, _, queries = _toy()

        class StepOnly:                      # train_batch but no streaming
            def train_batch(self, i, bs):
                raise AssertionError('should not be reached')

        with pytest.raises(TypeError, match='n_train'):
            influence(problem, ExactIHVP(rho=RHO), queries,
                      source=StepOnly(), params=params)

    def test_training_path_runs_and_improves(self):
        """params=None trains first (SGD on problem.data) — scores are then
        computed at the trained params."""
        problem, _, train, queries = _toy(seed=7)
        res = influence(problem, NystromIHVP(k=4, rho=RHO), queries,
                        train_steps=60, batch_size=16, top_k=3)
        trained = res.params
        init = problem.init_params(jax.random.PRNGKey(0))
        assert float(problem.loss(trained, train)) < float(
            problem.loss(init, train))
        assert res.scores.shape == (M, 3)
        assert np.isfinite(np.asarray(res.scores)).all()
