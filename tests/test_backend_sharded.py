"""flat_sharded on a real multi-device mesh: subprocess-launched parity.

The host-platform device count is a process-wide XLA flag that must be set
before jax initializes, and conftest.py intentionally keeps this process on
the single real CPU device (smoke tests and benches depend on it). So the
8-device parity suite — tests/sharded_parity_check.py — runs in a fresh
interpreter with XLA_FLAGS=--xla_force_host_platform_device_count=8, and
this wrapper asserts on its ``OK <name>`` markers so a check that silently
vanished fails loudly here. The structural checks in that suite (single
psum per block apply, no all-gather of a parameter shard) go through
``repro.analysis.audit`` + ``repro.core.FLAT_SHARDED_CONTRACT`` rather
than HLO-substring greps, so failures name the offending op.
"""
import os
import subprocess
import sys
from pathlib import Path

_TESTS_DIR = Path(__file__).resolve().parent
_SCRIPT = _TESTS_DIR / 'sharded_parity_check.py'
_SRC = _TESTS_DIR.parent / 'src'


def test_flat_sharded_8device_parity():
    env = dict(os.environ)
    env['XLA_FLAGS'] = ('--xla_force_host_platform_device_count=8 '
                        + env.get('XLA_FLAGS', '')).strip()
    env['PYTHONPATH'] = str(_SRC) + (
        os.pathsep + env['PYTHONPATH'] if env.get('PYTHONPATH') else '')
    # NOTE: deliberately keep JAX_PLATFORMS from the parent env — clearing
    # it makes the child probe for accelerator plugins (minutes of timeout
    # on hosts with libtpu installed and no TPU).
    res = subprocess.run([sys.executable, str(_SCRIPT)], env=env,
                         capture_output=True, text=True, timeout=900)
    report = f'--- stdout ---\n{res.stdout}\n--- stderr ---\n{res.stderr}'
    assert res.returncode == 0, report

    import sharded_parity_check as spc
    for marker in spc.EXPECTED:
        assert f'OK {marker}' in res.stdout, f'missing {marker}\n{report}'
    assert 'ALL CHECKS PASSED' in res.stdout, report
