"""Roofline table from the dry-run artifacts (experiments/dryrun/*.json).

Prints per (arch × shape): the three terms in seconds, the dominant
bottleneck, MODEL_FLOPS/HLO_FLOPs useful ratio, memory per chip, and the
roofline fraction (compute term / binding term). Methodology:
launch/analysis.py docstring.
"""
import glob
import json
import os

from benchmarks.common import emit

DRYRUN_DIR = os.path.join(os.path.dirname(__file__), '..', 'experiments',
                          'dryrun')


def load_cells(pattern='*.json'):
    cells = []
    for path in sorted(glob.glob(os.path.join(DRYRUN_DIR, pattern))):
        with open(path) as f:
            cells.append((os.path.basename(path)[:-5], json.load(f)))
    return cells


def run():
    rows = []
    for tag, rec in load_cells():
        if 'skipped' in rec:
            emit('roofline', 0.0, f'{tag} SKIPPED ({rec["skipped"]})')
            continue
        if 'error' in rec:
            emit('roofline', 0.0, f'{tag} ERROR {rec["error"][:60]}')
            continue
        if 'analysis' not in rec:
            continue
        t = rec['analysis']['terms']
        mem = rec['single_pod']['memory'].get('total_gb', -1)
        mp = rec.get('multi_pod', {}).get('memory', {}).get('total_gb', -1)
        emit('roofline', t['bound_s'] * 1e6,
             f"{tag} compute={t['compute_s']*1e3:.1f}ms "
             f"memory={t['memory_s']*1e3:.1f}ms "
             f"coll={t['collective_s']*1e3:.1f}ms dom={t['dominant']} "
             f"frac={t['roofline_fraction']:.3f} "
             f"useful={t['useful_flop_ratio']:.3f} "
             f"mem1pod={mem:.1f}GB mem2pod={mp:.1f}GB")
        rows.append((tag, t))
    return rows
