"""Roofline table from the dry-run artifacts (experiments/dryrun/*.json),
plus the analytic IHVP-apply roofline by contraction backend.

Prints per (arch × shape): the three terms in seconds, the dominant
bottleneck, MODEL_FLOPS/HLO_FLOPs useful ratio, memory per chip, and the
roofline fraction (compute term / binding term). Methodology:
launch/analysis.py docstring.

``run_ihvp_backend_model`` models the Nyström apply (two tall-skinny
C-passes) on TPU-class hardware for the contraction backends. At k ≤ 128
the arithmetic intensity of a (p, k) contraction is ~k/4 FLOP/byte — far
below the ~240 FLOP/byte ridge — so the apply is HBM-bound and the model
is bytes/BW + launch overhead (+ collective latency when sharded):

  tree          2 C-passes as 2·n_leaves einsum dispatches + n_leaves
                (k,)/(p_i,) partials re-reduced on host-side tree sum
  flat          2 C-passes as 2 fused matmuls over the (k, p) buffer
  flat_sharded  per-device traffic is flat's divided by n_shards (each chip
                streams only its (k, p/n_shards) local buffer, plus one
                read of the (p/n_shards,) psum-weight vector per reduction
                pass); each sweep's Cᵀv finishes with a k-float psum whose
                latency (_PSUM_LAT_S, small-message all-reduce) does not
                shrink with n_shards — the scaling floor
  pallas        2 pallas_call grids with the k-tile accumulator
                VMEM-resident: exactly one HBM read of C per pass and one
                (k,)/(p,) write — the floor for this shape
"""
import glob
import json
import os

from benchmarks.common import emit

# v5e-class chip: HBM bandwidth and a conservative per-dispatch overhead.
_HBM_GBPS = 819.0
_DISPATCH_S = 2e-6
# small-message (k ≤ 128 floats) all-reduce latency on an ICI ring — wire
# latency, not bandwidth, so it is independent of n_shards and of k.
_PSUM_LAT_S = 5e-6

DRYRUN_DIR = os.path.join(os.path.dirname(__file__), '..', 'experiments',
                          'dryrun')


def load_cells(pattern='*.json'):
    cells = []
    for path in sorted(glob.glob(os.path.join(DRYRUN_DIR, pattern))):
        with open(path) as f:
            cells.append((os.path.basename(path)[:-5], json.load(f)))
    return cells


def run():
    rows = []
    for tag, rec in load_cells():
        if 'skipped' in rec:
            emit('roofline', 0.0, f'{tag} SKIPPED ({rec["skipped"]})')
            continue
        if 'error' in rec:
            emit('roofline', 0.0, f'{tag} ERROR {rec["error"][:60]}')
            continue
        if 'analysis' not in rec:
            continue
        t = rec['analysis']['terms']
        mem = rec['single_pod']['memory'].get('total_gb', -1)
        mp = rec.get('multi_pod', {}).get('memory', {}).get('total_gb', -1)
        emit('roofline', t['bound_s'] * 1e6,
             f"{tag} compute={t['compute_s']*1e3:.1f}ms "
             f"memory={t['memory_s']*1e3:.1f}ms "
             f"coll={t['collective_s']*1e3:.1f}ms dom={t['dominant']} "
             f"frac={t['roofline_fraction']:.3f} "
             f"useful={t['useful_flop_ratio']:.3f} "
             f"mem1pod={mem:.1f}GB mem2pod={mp:.1f}GB")
        rows.append((tag, t))
    rows.append(('ihvp_backend_model', run_ihvp_backend_model()))
    return rows


def _apply_model_s(p: int, k: int, n_leaves: int, backend: str,
                   refine: int = 1, n_shards: int = 1) -> float:
    """Modeled seconds for one Nyström apply.

    The stabilized apply is (1 + 2·refine) two-C-pass sweeps: the Woodbury
    pair (Cᵀv + fused v/ρ + Cw), plus per refinement sweep a forward
    H_k·u pair and another Woodbury pair. refine=0 is the literal
    two-pass apply; the shipped solver default is refine=1 (6 C-passes) —
    see NystromIHVP.refine.
    """
    sweeps = 1 + 2 * refine            # two C-passes each
    c_bytes = p * k * 4
    vec_bytes = p * 4
    if backend == 'tree':
        # per sweep: 2 C-passes leaf by leaf, plus the unfused epilogue —
        # the Cw correction is materialized (write+read) before tree_axpy
        # combines it with v/ρ: 5 vector passes (v read ×2, corr
        # write+read, u write) — and every leaf is its own einsum dispatch
        # plus a partial-sum reduction.
        bytes_moved = sweeps * (2 * c_bytes + 5 * vec_bytes
                                + n_leaves * k * 4)
        dispatches = sweeps * 3 * n_leaves
    elif backend == 'flat':
        # per sweep: 2 fused matmuls; XLA fuses v/ρ + Cw into the second
        # pass: v read ×2, u write.
        bytes_moved = sweeps * (2 * c_bytes + 3 * vec_bytes)
        dispatches = sweeps * 2
    elif backend == 'flat_sharded':
        # flat's per-sweep traffic over the local (k, p/n_shards) buffer,
        # plus one read of the (p/n_shards,) psum-weight vector in the
        # reduction pass; the k-float psum closing each sweep's Cᵀv is
        # latency-bound and does NOT scale down with n_shards.
        bytes_moved = sweeps * (2 * c_bytes + 4 * vec_bytes) / max(1, n_shards)
        dispatches = sweeps * 3                   # fuse-local ops/shard_map
        return (bytes_moved / (_HBM_GBPS * 1e9) + dispatches * _DISPATCH_S
                + sweeps * _PSUM_LAT_S)
    elif backend == 'pallas':
        # same traffic floor as flat, with the (k,) accumulator pinned in
        # VMEM across the grid (flat relies on XLA picking that schedule;
        # the kernel guarantees it).
        bytes_moved = sweeps * (2 * c_bytes + 3 * vec_bytes)
        dispatches = sweeps * 2
    else:
        raise ValueError(backend)
    return bytes_moved / (_HBM_GBPS * 1e9) + dispatches * _DISPATCH_S


def run_ihvp_backend_model(shapes=((1 << 22, 32, 8), (1 << 27, 64, 128),
                                   (1 << 30, 128, 512)), refine: int = 1,
                           n_shards: int = 8):
    """Backend apply-time model over (p, k, n_leaves) production shapes,
    at the solver's default refinement level (matches what tab5 measures).
    flat_sharded is modeled at ``n_shards`` chips: per-chip traffic divides
    by n_shards while the per-sweep k-float psum latency stays fixed, so
    its advantage saturates once psum latency dominates (visible at the
    smallest shape)."""
    out = {}
    for p, k, n_leaves in shapes:
        per = {b: _apply_model_s(p, k, n_leaves, b, refine,
                                 n_shards=n_shards if b == 'flat_sharded'
                                 else 1)
               for b in ('tree', 'flat', 'flat_sharded', 'pallas')}
        out[(p, k, n_leaves)] = per
        emit('roofline_ihvp_backend', per['pallas'] * 1e6,
             f'p={p} k={k} n_leaves={n_leaves} refine={refine} '
             f"tree={per['tree']*1e3:.3f}ms flat={per['flat']*1e3:.3f}ms "
             f"flat_sharded(x{n_shards})={per['flat_sharded']*1e3:.3f}ms "
             f"pallas={per['pallas']*1e3:.3f}ms "
             f"tree/pallas={per['tree']/per['pallas']:.2f}x")
    return out
