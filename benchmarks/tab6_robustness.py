"""Tab. 6 + Fig. 4: Nyström robustness over the (ρ, k) grid.

Runs through the typed problem API: the problem's ``BatchSource`` feeds the
train/val streams directly — no more rebuilding the task dict just to
smuggle the full splits in next to ``data``.
"""
from benchmarks.common import emit, solver_cfg
from repro.core import solve
from repro.tasks import build_reweighting


def run(n_outer: int = 15):
    problem = build_reweighting(imbalance=50)
    accs = {}
    for k in (5, 10, 20):
        for rho in (0.01, 0.1, 1.0):
            res = solve(problem, solver_cfg('nystrom', k=k, rho=rho),
                        n_outer=n_outer)
            accs[(k, rho)] = res.metrics['accuracy']
            emit('tab6_robustness', res.seconds * 1e6 / n_outer,
                 f'k={k} rho={rho} acc={accs[(k, rho)]:.3f} '
                 f'hvps={res.hvp_count}')
    spread = max(accs.values()) - min(accs.values())
    emit('tab6_robustness', 0.0, f'acc_spread={spread:.3f} (paper: marginal)')
    return accs
