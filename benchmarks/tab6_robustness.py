"""Tab. 6 + Fig. 4: Nyström robustness over the (ρ, k) grid."""
from benchmarks.common import emit, run_bilevel
from repro.tasks import build_reweighting


def run(n_outer: int = 15):
    task = build_reweighting(imbalance=50)
    data = task['data']
    task = dict(task, train=(data.X, data.y), val=(data.Xv, data.yv))
    accs = {}
    for k in (5, 10, 20):
        for rho in (0.01, 0.1, 1.0):
            state, hist, secs = run_bilevel(
                task, 'nystrom', n_outer=n_outer, steps_per_outer=20,
                inner_lr=0.1, inner_momentum=0.9, outer_lr=1e-3,
                k=k, rho=rho, batch=128)
            accs[(k, rho)] = task['accuracy'](state.params)
            emit('tab6_robustness', secs * 1e6 / n_outer,
                 f'k={k} rho={rho} acc={accs[(k, rho)]:.3f}')
    spread = max(accs.values()) - min(accs.values())
    emit('tab6_robustness', 0.0, f'acc_spread={spread:.3f} (paper: marginal)')
    return accs
