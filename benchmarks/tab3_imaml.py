"""Tab. 3: iMAML few-shot classification with pluggable IHVP backends.

Paper protocol: inner SGD lr=0.1 × 10 steps with proximal regularization,
outer Adam 1e-3 on the meta-init, k=l=10, α=ρ=0.01. Synthetic Omniglot
analog (DESIGN §6.3); shortened episode count for CPU.
"""
import jax
import jax.numpy as jnp

from benchmarks.common import emit, solver_cfg
from repro.core import PyTreeIndexer, hypergradient
from repro.optim import adam
from repro.tasks import build_imaml
import time


def run(n_episodes: int = 60, n_eval: int = 20):
    task = build_imaml()
    sampler = task['sampler']
    rng = jax.random.PRNGKey(0)
    results = {}
    for method in ('nystrom', 'cg', 'neumann'):
        meta = task['init_params'](rng)
        opt = adam(1e-3)
        ost = opt.init(meta)
        cfg = solver_cfg(method, k=10, rho=1e-2, alpha=1e-2)
        solver = cfg.build()
        t0 = time.time()

        @jax.jit
        def meta_step(meta, ost, sx, sy, qx, qy, key, step):
            # inner adaptation (unrolled 10 SGD steps)
            params = jax.tree.map(lambda p: p, meta)
            for i in range(10):
                g = jax.grad(task['inner'])(params, meta, (sx, sy))
                params = jax.tree.map(lambda p, gg: p - 0.1 * gg, params, g)
            hg = hypergradient(task['inner'], task['outer'], params, meta,
                               (sx, sy), (qx, qy), solver, key,
                               PyTreeIndexer(params))
            upd, ost2 = opt.update(hg, ost, meta, step)
            meta2 = jax.tree.map(lambda p, u: p + u, meta, upd)
            return meta2, ost2

        for ep in range(n_episodes):
            sx, sy, qx, qy = sampler.episode(ep)
            key = jax.random.PRNGKey(ep)
            meta, ost = meta_step(meta, ost, sx, sy, qx, qy, key,
                                  jnp.int32(ep))
        # eval: adapt on held-out episodes, measure query accuracy
        accs = []
        for ep in range(n_eval):
            sx, sy, qx, qy = sampler.episode(10_000 + ep, test=True)
            params = jax.tree.map(lambda p: p, meta)
            for i in range(10):
                g = jax.grad(task['inner'])(params, meta, (sx, sy))
                params = jax.tree.map(lambda p, gg: p - 0.1 * gg, params, g)
            from repro.tasks import mlp_apply
            accs.append(float((mlp_apply(params, qx).argmax(-1) == qy).mean()))
        results[method] = sum(accs) / len(accs)
        emit('tab3_imaml', (time.time() - t0) * 1e6 / n_episodes,
             f'method={method} 1shot_test_acc={results[method]:.3f}')
    return results
