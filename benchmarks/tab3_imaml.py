"""Tab. 3: iMAML few-shot classification through the implicit_root API.

Paper protocol: inner SGD lr=0.1 × 10 steps with proximal regularization,
outer Adam 1e-3 on the meta-init, k=l=10, α=ρ=0.01. Synthetic Omniglot
analog (DESIGN §6.3); shortened episode count for CPU.

The inner adaptation is wrapped as an ``implicit_root`` solution map, so the
per-task hypergradient is ``jax.grad`` of the query loss — and a meta-batch
of tasks is just ``jax.vmap`` over it: the k sketch HVPs of every task run
as one batched program. ``meta_batch=1`` (the default) keeps the paper's
per-episode Adam updates for comparable accuracy rows; ``meta_batch>1`` is
the beyond-paper throughput mode (mean-of-batch hypergradient, fewer outer
updates). ``bench_batched_vs_loop`` times the vmapped program against the
pre-redesign structure (per-task Python loop over the imperative
``hypergradient()``) and emits the speedup row.

``shared_sketch=True`` turns on the shared-sketch meta-batch mode: one
Nyström sketch is prepared at the meta-initialization on the meta-batch's
pooled support data (``solve.prepare_state``) and broadcast to every task's
backward pass as ``state=`` under the vmap — k HVPs per *meta-batch*
instead of k per *task*. The curvature is then the meta-batch's average at
the meta-init rather than each task's own at its adapted θ*; at iMAML's
proximal regularization (H ≈ ∇²ce + reg·I) the two estimators stay closely
aligned — ``bench_shared_sketch`` measures that alignment (hypergradient
cosine similarity of the meta-updates) next to the HVP-count reduction.
"""
import time

import jax
import jax.numpy as jnp

from benchmarks.common import emit, solver_cfg
from repro.core import (PyTreeIndexer, hypergradient, implicit_root,
                        sgd_solver)
from repro.optim import adam
from repro.tasks import build_imaml, mlp_apply

INNER_STEPS = 10
INNER_LR = 0.1


def make_adapt(task):
    """inner_solver_fn for implicit_root: INNER_STEPS proximal-SGD steps
    from the meta-initialization (which is also the proximal anchor)."""
    return sgd_solver(task.inner_loss, INNER_STEPS, INNER_LR)


def _stack_episodes(eps):
    sx, sy, qx, qy = zip(*eps)
    return tuple(map(jnp.stack, (sx, sy, qx, qy)))


def _pool_support(SX, SY):
    """Concatenate a meta-batch's support sets along the example axis: the
    Hessian batch for the shared sketch (equal-sized tasks, so the pooled
    cross-entropy mean is the mean of per-task means — the meta-batch's
    average curvature)."""
    return (SX.reshape((-1,) + SX.shape[2:]), SY.reshape(-1))


def _cosine(a, b):
    af = jnp.concatenate([x.ravel() for x in jax.tree.leaves(a)])
    bf = jnp.concatenate([x.ravel() for x in jax.tree.leaves(b)])
    return float(af @ bf /
                 (jnp.linalg.norm(af) * jnp.linalg.norm(bf) + 1e-30))


def run(n_episodes: int = 60, n_eval: int = 20, meta_batch: int = 1,
        bench_tasks: int = 8, shared_sketch: bool = False):
    task = build_imaml()
    sampler = task.reference['sampler']
    rng = jax.random.PRNGKey(0)
    adapt_fn = make_adapt(task)
    results = {}
    for method in ('nystrom', 'cg', 'neumann'):
        meta = task.init_params(rng)
        opt = adam(1e-3)
        ost = opt.init(meta)
        solver = solver_cfg(method, k=10, rho=1e-2, alpha=1e-2).build()
        solve = implicit_root(adapt_fn, task.inner_loss, solver)
        # shared-sketch mode needs an amortizable (pytree-of-arrays) state;
        # the iterative baselines keep per-task backward-pass prepares
        shared = shared_sketch and getattr(type(solver), 'amortizable', False)
        t0 = time.time()

        @jax.jit
        def meta_step(meta, ost, SX, SY, QX, QY, keys, step):
            if shared:
                # one sketch at the meta-init for the whole meta-batch:
                # k HVPs total instead of k per task
                sketch = solve.prepare_state(meta, meta,
                                             _pool_support(SX, SY), keys[0])

                def task_grad(sx, sy, qx, qy, key):
                    def obj(m):
                        theta = solve(m, (sx, sy), state=sketch)
                        return task.outer_loss(theta, m, (qx, qy))
                    return jax.grad(obj)(meta)
            else:
                def task_grad(sx, sy, qx, qy, key):
                    def obj(m):
                        theta = solve(m, (sx, sy), rng=key)
                        return task.outer_loss(theta, m, (qx, qy))
                    return jax.grad(obj)(meta)

            hg = jax.vmap(task_grad)(SX, SY, QX, QY, keys)   # per-task Eq. 3
            hg = jax.tree.map(lambda x: x.mean(0), hg)
            upd, ost2 = opt.update(hg, ost, meta, step)
            return jax.tree.map(lambda p, u: p + u, meta, upd), ost2

        # exactly n_episodes episodes; a non-divisible count gets one smaller
        # final meta-batch (one extra compile, but the us/episode emit and
        # cross-meta_batch comparability stay honest)
        ep_idx, s = 0, 0
        while ep_idx < n_episodes:
            b = min(meta_batch, n_episodes - ep_idx)
            eps = [sampler.episode(ep_idx + j) for j in range(b)]
            SX, SY, QX, QY = _stack_episodes(eps)
            keys = jax.random.split(jax.random.PRNGKey(s), b)
            meta, ost = meta_step(meta, ost, SX, SY, QX, QY, keys,
                                  jnp.int32(s))
            ep_idx += b
            s += 1
        # eval: adapt on held-out episodes, measure query accuracy
        adapt_j = jax.jit(adapt_fn)
        accs = []
        for ep in range(n_eval):
            sx, sy, qx, qy = sampler.episode(10_000 + ep, test=True)
            params = adapt_j(meta, (sx, sy))
            accs.append(float((mlp_apply(params, qx).argmax(-1) == qy).mean()))
        results[method] = sum(accs) / len(accs)
        emit('tab3_imaml', (time.time() - t0) * 1e6 / n_episodes,
             f'method={method} 1shot_test_acc={results[method]:.3f} '
             f'meta_batch={meta_batch} shared_sketch={shared}')
    if bench_tasks:
        bench_batched_vs_loop(n_tasks=bench_tasks)
        bench_shared_sketch(n_tasks=bench_tasks)
    return results


def bench_batched_vs_loop(n_tasks: int = 8, iters: int = 3,
                          method: str = 'nystrom'):
    """Meta-batch hypergradient throughput: vmap-batched implicit_root vs
    the per-task Python loop over the imperative ``hypergradient()`` (the
    pre-redesign structure). Both paths do the full per-task work (inner
    adaptation + k sketch HVPs + apply + mixed VJP); the loop pays one
    dispatch per task where vmap runs one batched program."""
    task = build_imaml()
    sampler = task.reference['sampler']
    meta = task.init_params(jax.random.PRNGKey(0))
    solver = solver_cfg(method).build()
    adapt_fn = make_adapt(task)
    solve = implicit_root(adapt_fn, task.inner_loss, solver)

    SX, SY, QX, QY = _stack_episodes(
        [sampler.episode(i) for i in range(n_tasks)])
    keys = jax.random.split(jax.random.PRNGKey(1), n_tasks)

    @jax.jit
    def batched(meta, SX, SY, QX, QY, keys):
        def task_grad(sx, sy, qx, qy, key):
            def obj(m):
                return task.outer_loss(solve(m, (sx, sy), rng=key), m, (qx, qy))
            return jax.grad(obj)(meta)
        return jax.vmap(task_grad)(SX, SY, QX, QY, keys)

    @jax.jit
    def single(meta, sx, sy, qx, qy, key):
        params = adapt_fn(meta, (sx, sy))
        return hypergradient(task.inner_loss, task.outer_loss, params, meta,
                             (sx, sy), (qx, qy), solver, key,
                             PyTreeIndexer(params))

    jax.block_until_ready(batched(meta, SX, SY, QX, QY, keys))
    jax.block_until_ready(single(meta, SX[0], SY[0], QX[0], QY[0], keys[0]))

    t0 = time.time()
    for _ in range(iters):
        jax.block_until_ready(batched(meta, SX, SY, QX, QY, keys))
    t_vmap = (time.time() - t0) / iters

    t0 = time.time()
    for _ in range(iters):
        jax.block_until_ready([single(meta, SX[i], SY[i], QX[i], QY[i],
                                      keys[i]) for i in range(n_tasks)])
    t_loop = (time.time() - t0) / iters

    emit('tab3_imaml_hypergrad_loop', t_loop * 1e6,
         f'method={method} tasks={n_tasks} path=per_task_python_loop')
    emit('tab3_imaml_hypergrad_vmap', t_vmap * 1e6,
         f'method={method} tasks={n_tasks} path=vmap_batched '
         f'speedup={t_loop / t_vmap:.2f}x')
    return t_loop, t_vmap


def bench_shared_sketch(n_tasks: int = 8, iters: int = 3, k: int = 10,
                        method: str = 'nystrom'):
    """Shared-sketch meta-batch row: one sketch prepared at the meta-init
    (``solve.prepare_state``, k HVPs per meta-batch) and broadcast as
    ``state=`` under the vmap, vs the per-task backward-pass prepare
    (n_tasks × k HVPs). Emits the HVP-count reduction, the wall-time
    speedup, and the cosine similarity of the two meta-updates (the
    staleness+pooling cost of sharing — acceptance floor 0.99)."""
    task = build_imaml()
    sampler = task.reference['sampler']
    meta = task.init_params(jax.random.PRNGKey(0))
    solver = solver_cfg(method, k=k).build()
    adapt_fn = make_adapt(task)
    solve = implicit_root(adapt_fn, task.inner_loss, solver)

    SX, SY, QX, QY = _stack_episodes(
        [sampler.episode(i) for i in range(n_tasks)])
    keys = jax.random.split(jax.random.PRNGKey(1), n_tasks)

    def mean_grad(task_grad, *extra):
        hg = jax.vmap(task_grad)(SX, SY, QX, QY, *extra)
        return jax.tree.map(lambda x: x.mean(0), hg)

    @jax.jit
    def per_task(meta, keys):
        def task_grad(sx, sy, qx, qy, key):
            def obj(m):
                return task.outer_loss(solve(m, (sx, sy), rng=key), m, (qx, qy))
            return jax.grad(obj)(meta)
        return mean_grad(task_grad, keys)

    @jax.jit
    def shared(meta, key):
        sketch = solve.prepare_state(meta, meta, _pool_support(SX, SY), key)

        def task_grad(sx, sy, qx, qy):
            def obj(m):
                theta = solve(m, (sx, sy), state=sketch)
                return task.outer_loss(theta, m, (qx, qy))
            return jax.grad(obj)(meta)
        return mean_grad(task_grad)

    g_pt = jax.block_until_ready(per_task(meta, keys))
    g_sh = jax.block_until_ready(shared(meta, keys[0]))
    cos = _cosine(g_pt, g_sh)

    t0 = time.time()
    for _ in range(iters):
        jax.block_until_ready(per_task(meta, keys))
    t_pt = (time.time() - t0) / iters

    t0 = time.time()
    for _ in range(iters):
        jax.block_until_ready(shared(meta, keys[0]))
    t_sh = (time.time() - t0) / iters

    emit('tab3_imaml_shared_sketch', t_sh * 1e6,
         f'method={method} tasks={n_tasks} k={k} '
         f'hvps_per_meta_batch={k} (per_task_prepare={n_tasks * k}) '
         f'cosine_vs_per_task={cos:.4f} speedup={t_pt / t_sh:.2f}x')
    return t_pt, t_sh, cos
