"""Shared benchmark machinery: solver configs + persisted-result emission.

The benchmark modules drive ``repro.core.problem.solve`` /
``repro.core.problem.influence`` directly (one typed entry point from task
definition to result, HVP-count accounting included).

Results are persisted as ``BENCH_<name>.json`` next to the printed CSV:
``bench_rows`` accumulates structured rows (solver, backend, m, applies/sec,
wall time, ...) and ``write_bench`` flushes them with a schema stamp that
``benchmarks/check_bench_schema.py`` validates in CI's bench-smoke job.
"""
from __future__ import annotations

import json
import os
import time

from repro.core import HypergradConfig

# BENCH_*.json schema contract (validated by benchmarks/check_bench_schema.py)
BENCH_SCHEMA_VERSION = 1
BENCH_REQUIRED_KEYS = ('solver', 'backend', 'm', 'applies_per_sec',
                       'wall_seconds')


def solver_cfg(name: str, k: int = 10, rho: float = 1e-2,
               alpha: float = 1e-2) -> HypergradConfig:
    return {
        'nystrom': HypergradConfig(solver='nystrom', k=k, rho=rho),
        'nystrom_eq6': HypergradConfig(solver='nystrom', k=k, rho=rho),
        'cg': HypergradConfig(solver='cg', k=k, rho=0.0),
        'neumann': HypergradConfig(solver='neumann', k=k, alpha=alpha),
    }[name]


def emit(name: str, us_per_call: float, derived: str):
    print(f'{name},{us_per_call:.1f},{derived}')


def bench_row(*, solver: str, backend: str, m: int, applies_per_sec: float,
              wall_seconds: float, **extra) -> dict:
    """One structured benchmark row (the BENCH_*.json unit).

    ``solver``/``backend`` name what ran, ``m`` is the query-block width
    (1 = the vector apply), ``applies_per_sec`` counts *queries* served per
    second (so block-vs-loop rows are directly comparable), and
    ``wall_seconds`` the measured wall time of the timed region. ``extra``
    carries bench-specific fields (p, k, leaf count, ...).
    """
    row = dict(solver=solver, backend=backend, m=int(m),
               applies_per_sec=float(applies_per_sec),
               wall_seconds=float(wall_seconds))
    row.update(extra)
    return row


def write_bench(name: str, rows: list[dict], out_dir: str | None = None,
                meta: dict | None = None) -> str:
    """Persist rows as ``BENCH_<name>.json`` (schema-stamped) and return the
    path. ``out_dir`` defaults to $BENCH_OUT_DIR or the repo root."""
    for row in rows:
        missing = [k for k in BENCH_REQUIRED_KEYS if k not in row]
        if missing:
            raise ValueError(
                f'bench row missing required keys {missing}: {row}')
    out_dir = out_dir or os.environ.get('BENCH_OUT_DIR') or os.path.dirname(
        os.path.dirname(os.path.abspath(__file__)))
    path = os.path.join(out_dir, f'BENCH_{name}.json')
    doc = {'schema_version': BENCH_SCHEMA_VERSION, 'name': name,
           'created_unix': time.time(), 'meta': meta or {}, 'rows': rows}
    with open(path, 'w') as f:
        json.dump(doc, f, indent=2)
    print(f'[bench] wrote {path} ({len(rows)} rows)')
    return path
