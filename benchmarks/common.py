"""Shared benchmark machinery: solver configs + persisted-result emission.

The benchmark modules drive ``repro.core.problem.solve`` /
``repro.core.problem.influence`` directly (one typed entry point from task
definition to result, HVP-count accounting included).

Results are persisted as ``BENCH_<name>.json`` next to the printed CSV:
``bench_row`` builds structured rows and ``write_bench`` flushes them with a
schema stamp that ``benchmarks/check_bench_schema.py`` validates in CI's
bench-smoke job, and that ``benchmarks/compare_runs.py`` diffs across runs
(the enforceable perf trajectory).

Schema history:
  v1 — solver/backend/m/applies_per_sec/wall_seconds per row (PR 6).
  v2 — v1 plus required ``problem`` (which workload produced the row) and
       ``hvp_count`` (the row's HVP bill; 0 for pure apply-path microbenches
       that run no HVPs), and two schema-known optional fields:
       ``hypergrad_error`` (relative error vs the exact-IHVP oracle,
       observatory cells) and ``grid`` (the accuracy-knob dict of a sweep
       cell, e.g. ``{"k": 4, "rho": 0.01}``).

``write_bench`` always stamps the current version; the checker validates
both (old baselines stay readable), and ``compare_runs.py`` refuses to diff
across versions rather than miscompare.
"""
from __future__ import annotations

import json
import os
import time

from repro.core import HypergradConfig

# BENCH_*.json schema contract (validated by benchmarks/check_bench_schema.py)
BENCH_SCHEMA_VERSION = 2
BENCH_REQUIRED_KEYS = ('solver', 'backend', 'm', 'applies_per_sec',
                       'wall_seconds')
BENCH_V2_REQUIRED_KEYS = BENCH_REQUIRED_KEYS + ('problem', 'hvp_count')
# per-version required row keys — the checker accepts any version listed here
BENCH_SCHEMA_KEYS = {1: BENCH_REQUIRED_KEYS, 2: BENCH_V2_REQUIRED_KEYS}


def solver_cfg(name: str, k: int = 10, rho: float = 1e-2,
               alpha: float = 1e-2) -> HypergradConfig:
    """The benchmark suite's named solver configurations.

    ``nystrom_eq6`` is the paper-faithful literal Eq. 6 apply
    (``stabilized=False``, no refinement sweeps) — distinct from ``nystrom``,
    whose whitened-Woodbury apply is the backward-stable production path.
    Unknown names raise with the known set (never a bare KeyError).
    """
    cfgs = {
        'nystrom': lambda: HypergradConfig(solver='nystrom', k=k, rho=rho),
        'nystrom_eq6': lambda: HypergradConfig(
            solver='nystrom', k=k, rho=rho, stabilized=False, refine=0),
        'cg': lambda: HypergradConfig(solver='cg', k=k, rho=0.0),
        'neumann': lambda: HypergradConfig(solver='neumann', k=k, alpha=alpha),
        'exact': lambda: HypergradConfig(solver='exact', rho=rho),
    }
    if name not in cfgs:
        raise ValueError(f'unknown solver config {name!r}; known: '
                         f'{sorted(cfgs)}')
    return cfgs[name]()


def emit(name: str, us_per_call: float, derived: str):
    print(f'{name},{us_per_call:.1f},{derived}')


def bench_row(*, solver: str, backend: str, m: int, applies_per_sec: float,
              wall_seconds: float, problem: str, hvp_count: int,
              hypergrad_error: float | None = None, grid: dict | None = None,
              **extra) -> dict:
    """One structured benchmark row (the BENCH_*.json unit, schema v2).

    ``solver``/``backend`` name what ran, ``problem`` the workload (a
    registry name or a bench-local label), ``m`` is the query-block width
    (1 = the vector apply), ``applies_per_sec`` counts *queries* served per
    second (so block-vs-loop rows are directly comparable), ``wall_seconds``
    the measured wall time of the timed region, and ``hvp_count`` the row's
    HVP bill (0 when the timed region runs no HVPs). ``hypergrad_error`` and
    ``grid`` are the observatory's per-cell accuracy fields (omitted from
    the row when None). ``extra`` carries bench-specific fields (p, k, leaf
    count, ...) — including, for audited observatory runs (``--audit``),
    the typed-optional program-structure measurements ``collective_count``
    and ``accum_dtype_ok`` that ``compare_runs.py`` diffs when both runs
    carry them.
    """
    row = dict(solver=solver, backend=backend, m=int(m),
               applies_per_sec=float(applies_per_sec),
               wall_seconds=float(wall_seconds), problem=problem,
               hvp_count=int(hvp_count))
    if hypergrad_error is not None:
        row['hypergrad_error'] = float(hypergrad_error)
    if grid is not None:
        row['grid'] = dict(grid)
    row.update(extra)
    return row


def write_bench(name: str, rows: list[dict], out_dir: str | None = None,
                meta: dict | None = None) -> str:
    """Persist rows as ``BENCH_<name>.json`` (schema-stamped) and return the
    path. ``out_dir`` defaults to $BENCH_OUT_DIR or the repo root."""
    for row in rows:
        missing = [k for k in BENCH_V2_REQUIRED_KEYS if k not in row]
        if missing:
            raise ValueError(
                f'bench row missing required keys {missing}: {row}')
    out_dir = out_dir or os.environ.get('BENCH_OUT_DIR') or os.path.dirname(
        os.path.dirname(os.path.abspath(__file__)))
    path = os.path.join(out_dir, f'BENCH_{name}.json')
    doc = {'schema_version': BENCH_SCHEMA_VERSION, 'name': name,
           'created_unix': time.time(), 'meta': meta or {}, 'rows': rows}
    with open(path, 'w') as f:
        json.dump(doc, f, indent=2)
    print(f'[bench] wrote {path} ({len(rows)} rows)')
    return path
