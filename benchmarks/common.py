"""Shared benchmark machinery: solver configs + the legacy runner shim.

The benchmark modules now drive ``repro.core.problem.solve`` directly (one
typed entry point from task definition to solved hypergradient, HVP-count
accounting included). ``run_bilevel`` remains as a deprecated thin shim for
unported callers.
"""
from __future__ import annotations

import dataclasses
import warnings

from repro.core import BilevelProblem, HypergradConfig, solve
from repro.optim import momentum, sgd


def solver_cfg(name: str, k: int = 10, rho: float = 1e-2,
               alpha: float = 1e-2) -> HypergradConfig:
    return {
        'nystrom': HypergradConfig(solver='nystrom', k=k, rho=rho),
        'nystrom_eq6': HypergradConfig(solver='nystrom', k=k, rho=rho),
        'cg': HypergradConfig(solver='cg', k=k, rho=0.0),
        'neumann': HypergradConfig(solver='neumann', k=k, alpha=alpha),
    }[name]


def run_bilevel(task, method: str, *, n_outer: int, steps_per_outer: int,
                inner_lr: float, outer_lr: float, k: int = 10,
                rho: float = 1e-2, alpha: float = 1e-2,
                reset_inner: bool = False, outer_opt: str = 'adam',
                inner_momentum: float = 0.0, batch: int = 100,
                seed: int = 0):
    """Deprecated shim over ``repro.core.problem.solve`` — returns the old
    (final state, history, wall seconds) triple. ``task`` may be a
    ``BilevelProblem`` or a legacy task dict."""
    warnings.warn(
        'benchmarks.common.run_bilevel is a legacy shim; call '
        'repro.core.problem.solve(problem, config, ...) directly',
        DeprecationWarning, stacklevel=2)
    problem = (task if isinstance(task, BilevelProblem)
               else BilevelProblem.from_legacy_dict(task))
    inner = (momentum(inner_lr, inner_momentum) if inner_momentum
             else sgd(inner_lr))
    # outer optimizer (clipped) comes from the problem-level default
    # construction; only the lr/kind knobs are forwarded
    overrides = dict(problem.defaults)
    overrides.update(outer_lr=outer_lr, outer_opt=(
        'adam' if outer_opt == 'adam' else 'sgd_momentum'))
    problem = dataclasses.replace(problem, defaults=overrides)
    res = solve(problem, solver_cfg(method, k=k, rho=rho, alpha=alpha),
                n_outer=n_outer, steps_per_outer=steps_per_outer,
                batch_size=batch, inner_opt=inner, reset_inner=reset_inner,
                seed=seed)
    return res.state, res.history, res.seconds


def emit(name: str, us_per_call: float, derived: str):
    print(f'{name},{us_per_call:.1f},{derived}')
