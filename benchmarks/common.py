"""Shared benchmark machinery: solver configs and a generic bilevel runner."""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp

from repro.core import BilevelTrainer, HypergradConfig
from repro.optim import adam, chain, clip_by_global_norm, momentum, sgd


def solver_cfg(name: str, k: int = 10, rho: float = 1e-2,
               alpha: float = 1e-2) -> HypergradConfig:
    return {
        'nystrom': HypergradConfig(solver='nystrom', k=k, rho=rho),
        'nystrom_eq6': HypergradConfig(solver='nystrom', k=k, rho=rho),
        'cg': HypergradConfig(solver='cg', k=k, rho=0.0),
        'neumann': HypergradConfig(solver='neumann', k=k, alpha=alpha),
    }[name]


def run_bilevel(task, method: str, *, n_outer: int, steps_per_outer: int,
                inner_lr: float, outer_lr: float, k: int = 10,
                rho: float = 1e-2, alpha: float = 1e-2,
                reset_inner: bool = False, outer_opt: str = 'adam',
                inner_momentum: float = 0.0, batch: int = 100,
                seed: int = 0):
    """Alternating bilevel run on a task dict from repro.tasks — returns
    (final state, outer-loss history, wall seconds)."""
    inner_opt = (momentum(inner_lr, inner_momentum) if inner_momentum
                 else sgd(inner_lr))
    # hypergradient clipping: standard outer-loop hygiene; uniform across
    # methods so comparisons stay fair (Nyström's more-accurate IHVP takes
    # larger raw steps than truncated CG/Neumann and diverges without it at
    # the paper's outer lr=1.0+momentum)
    base = adam(outer_lr) if outer_opt == 'adam' else momentum(outer_lr, 0.9)
    outer = chain(clip_by_global_norm(10.0), base)
    trainer = BilevelTrainer(
        inner_loss=task['inner'], outer_loss=task['outer'],
        inner_opt=inner_opt, outer_opt=outer,
        hypergrad=solver_cfg(method, k=k, rho=rho, alpha=alpha),
        init_params=task['init_params'], reset_inner=reset_inner)

    rng = jax.random.PRNGKey(seed)
    hp = task['init_hparams']
    hp = hp(rng) if callable(hp) and hp.__code__.co_argcount else hp()
    state = trainer.init(rng, task['init_params'](rng), hp)

    Xt, yt = task['train']
    Xv, yv = task['val']
    nt = Xt.shape[0]

    def train_batches():
        i = 0
        while True:
            idx = jax.random.randint(jax.random.PRNGKey(i), (batch,), 0, nt)
            yield (Xt[idx], yt[idx])
            i += 1

    def val_batches():
        i = 1000
        while True:
            idx = jax.random.randint(jax.random.PRNGKey(i), (batch,), 0,
                                     Xv.shape[0])
            yield (Xv[idx], yv[idx])
            i += 1

    t0 = time.time()
    state, hist = trainer.run(state, train_batches(), val_batches(),
                              steps_per_outer=steps_per_outer,
                              n_outer=n_outer)
    return state, hist, time.time() - t0


def emit(name: str, us_per_call: float, derived: str):
    print(f'{name},{us_per_call:.1f},{derived}')
