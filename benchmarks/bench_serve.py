"""Serving-tier benchmark: cold per-request influence vs the warm store path.

Measures exactly the amortization the serving tier exists for. Two phases
on one toy influence problem (trained once, shared):

  cold   every query is a standalone ``influence()`` call — fresh sketch
         (k HVPs), fresh jitted top-k scan, per query. What callers paid
         before ``repro.serve``.
  warm   queries go through :class:`repro.serve.InfluenceService`: the
         sketch comes from the :class:`SketchStore` (ZERO build HVPs on
         the request path — the warm rows pin ``hvp_count == 0``), the
         top-k scan's jit caches persist across flushes, and queries ride
         ``apply_matrix`` in (p, m) blocks. One warm row per ``--block-sizes``
         entry; flushing is driven explicitly (submit-all-then-flush) so
         flush counts — and therefore ``cache_hit_rate`` — are
         deterministic and CI-gateable as cell identity.

``meta.warm_vs_cold_qps`` records the best warm/cold throughput ratio (the
PR 8 acceptance floor is 5× on this toy problem, tree backend, CPU).

Rows are persisted as ``BENCH_serve.json``; latency percentiles and queue
depths are measurement fields (waived across machines by compare_runs),
while phase/m/cache_hit_rate are identity — a vanished warm cell or a
changed hit rate fails the CI gate.

CLI (CI bench-smoke runs this at toy size):
  PYTHONPATH=src python -m benchmarks.bench_serve --queries 8 --k 4 \
      --train-steps 10 --d 8 --width 8 --block-sizes 1 4
"""
import sys
import time

if __package__ in (None, ''):          # `python benchmarks/bench_serve.py`
    import os
    _ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    for _p in (_ROOT, os.path.join(_ROOT, 'src')):
        if _p not in sys.path:
            sys.path.insert(0, _p)

from benchmarks.common import bench_row, emit, write_bench


def run(queries: int = 8, k: int = 4, top_k: int = 5, train_steps: int = 10,
        d: int = 8, width: int = 8, block_sizes=(1, 4), rho: float = 1e-2):
    import jax

    from repro.core import (HypergradConfig, get_problem, influence,
                            train_influence_params)
    from repro.serve import InfluenceService, SketchStore

    problem = get_problem('influence', d=d, width=width)
    params = train_influence_params(problem, train_steps=train_steps)
    pool = problem.reference['queries'](queries)
    cfg = HypergradConfig(solver='nystrom', k=k, rho=rho)
    rows = []

    # ---- cold: a fresh influence() call per query (no store) ----
    t0 = time.perf_counter()
    cold_hvps = 0
    cold_indices = []
    for q in range(queries):
        one = jax.tree.map(lambda x: x[q:q + 1], pool)
        res = influence(problem, cfg, one, params=params, top_k=top_k)
        cold_hvps += res.hvp_count
        cold_indices.append(res.indices[0])
    cold_wall = time.perf_counter() - t0
    cold_qps = queries / cold_wall
    rows.append(bench_row(
        solver='nystrom', backend='tree', m=1,
        applies_per_sec=cold_qps, wall_seconds=cold_wall,
        problem='influence', hvp_count=cold_hvps,
        phase='cold', cache_hit_rate=0.0,
        queries=queries, k=k, top_k=top_k, d=d, width=width))
    emit('bench_serve', cold_wall * 1e6,
         f'phase=cold queries={queries} k={k} hvps={cold_hvps} '
         f'qps={cold_qps:.2f}')

    # ---- warm: the serving tier, one row per block size ----
    store = SketchStore()
    service = InfluenceService(problem, cfg, params=params, store=store,
                               top_k=top_k, max_delay=60.0,
                               max_queue=max(64, queries))
    service.prepare()                  # the ONE build; off the request path
    warm_qps_by_m = {}
    for bs in block_sizes:
        service.batcher.block_size = int(bs)
        service.reset_metrics()
        tickets = [service.submit(jax.tree.map(lambda x: x[q], pool))
                   for q in range(queries)]
        service.flush()                # deterministic ceil(queries/bs) flushes
        for q, t in enumerate(tickets):
            resp = service.result(t)
            assert not resp.degraded and resp.cache_hit
        row = service.bench_rows(phase='warm')[0]
        assert row['hvp_count'] == 0, (
            f'warm path billed {row["hvp_count"]} HVPs — the store missed')
        row['m'] = int(bs)             # the swept width, not the calibrated
        rows.append(bench_row(**row, queries=queries, k=k, top_k=top_k,
                              d=d, width=width))
        warm_qps_by_m[int(bs)] = row['applies_per_sec']
        emit('bench_serve', row['wall_seconds'] * 1e6,
             f'phase=warm m={bs} queries={queries} hvps=0 '
             f'hit_rate={row["cache_hit_rate"]:.3f} '
             f'qps={row["applies_per_sec"]:.2f} '
             f'p95={row["latency_p95_ms"]:.1f}ms')

    ratio = max(warm_qps_by_m.values()) / cold_qps
    emit('bench_serve', 0.0,
         f'warm_vs_cold_qps={ratio:.1f}x (best warm m='
         f'{max(warm_qps_by_m, key=warm_qps_by_m.get)})')
    write_bench('serve', rows,
                meta=dict(queries=queries, k=k, top_k=top_k, d=d,
                          width=width, block_sizes=list(block_sizes),
                          train_steps=train_steps,
                          warm_vs_cold_qps=round(ratio, 3)))
    return rows, ratio


def main(argv=None):
    import argparse
    ap = argparse.ArgumentParser()
    ap.add_argument('--queries', type=int, default=8,
                    help='query pool size (each phase answers all of them)')
    ap.add_argument('--k', type=int, default=4, help='sketch rank')
    ap.add_argument('--top-k', type=int, default=5)
    ap.add_argument('--train-steps', type=int, default=10)
    ap.add_argument('--d', type=int, default=8, help='input dim')
    ap.add_argument('--width', type=int, default=8, help='MLP hidden width')
    ap.add_argument('--block-sizes', type=int, nargs='+', default=[1, 4],
                    help='batcher block widths for the warm sweep')
    args = ap.parse_args(argv)
    run(queries=args.queries, k=args.k, top_k=args.top_k,
        train_steps=args.train_steps, d=args.d, width=args.width,
        block_sizes=tuple(args.block_sizes))


if __name__ == '__main__':
    main()
