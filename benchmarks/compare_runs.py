"""Diff two BENCH_*.json runs into a regression report (nonzero on regress).

The enforceable half of the perf trajectory: cells are matched by identity
(problem, solver, grid, every non-measurement field) and their measurements
compared under tolerances. Any regression — wall time or throughput beyond
``--tol-wall``, hypergradient error beyond ``--tol-error`` (+``--atol-error``
floor), ANY hvp_count increase, or a baseline cell missing from the new
run — is named and the exit code is 1. Schema-version mismatches refuse to
diff (exit 2) rather than miscompare.

  python benchmarks/compare_runs.py BENCH_baseline.json BENCH_new.json
  python benchmarks/compare_runs.py old.json new.json --no-wall   # cross-machine

``--no-wall`` skips the wall/throughput checks — use it whenever the two
runs came from different machines (e.g. CI vs a committed baseline), where
absolute timings are not comparable but error and HVP bills are.
"""
import argparse
import sys

if __package__ in (None, ''):          # `python benchmarks/compare_runs.py`
    import os
    _ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    for _p in (_ROOT, os.path.join(_ROOT, 'src')):
        if _p not in sys.path:
            sys.path.insert(0, _p)


def main(argv=None) -> int:
    from repro.bench import CompareError, format_report
    from repro.bench.compare import compare_files

    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument('baseline', help='baseline BENCH_*.json')
    ap.add_argument('new', help='new-run BENCH_*.json')
    ap.add_argument('--tol-wall', type=float, default=0.25,
                    help='relative wall/throughput slack (default 25%%)')
    ap.add_argument('--tol-error', type=float, default=0.25,
                    help='relative hypergrad_error slack (default 25%%)')
    ap.add_argument('--atol-error', type=float, default=1e-6,
                    help='absolute hypergrad_error floor (keeps near-zero '
                         'baselines from flagging roundoff)')
    ap.add_argument('--no-wall', action='store_true',
                    help='skip wall/throughput checks (cross-machine runs)')
    ap.add_argument('--verbose', action='store_true',
                    help='also print non-regressed cell deltas')
    ap.add_argument('--fit-rates', action='store_true',
                    help='append Grazzi-style empirical rate fits (log '
                         'hypergrad_error vs log hvp_count per cell ladder) '
                         'for both runs — descriptive, never gates the exit '
                         'code')
    args = ap.parse_args(argv)

    try:
        report = compare_files(
            args.baseline, args.new, tol_wall=args.tol_wall,
            tol_error=args.tol_error, atol_error=args.atol_error,
            check_wall=not args.no_wall)
    except CompareError as e:
        print(f'compare_runs: {e}')
        return 2
    print(format_report(report, verbose=args.verbose))
    if args.fit_rates:
        from repro.bench import fit_rates_file, format_rates
        print()
        print(format_rates(fit_rates_file(args.baseline),
                           fit_rates_file(args.new)))
    return 0 if report.ok else 1


if __name__ == '__main__':
    sys.exit(main())
