"""Benchmark orchestrator — one module per paper table/figure.

Prints ``name,us_per_call,derived`` CSV lines (harness contract). Modules:
  fig1_inverse_quality  — Fig. 1
  fig2_logreg_hpo       — Figs. 2/3 (+ ρ robustness sweep)
  tab2_distillation     — Tab. 2
  tab3_imaml            — Tab. 3
  tab4_reweighting      — Tab. 4
  tab5_speed_memory     — Tab. 5
  tab6_robustness       — Tab. 6 / Fig. 4
  bench_influence       — influence-service queries/sec vs m
  observatory           — solver × problem × accuracy-knob complexity sweep
  roofline              — EXPERIMENTS.md §Roofline source (dry-run artifacts)

FAST=1 env shrinks horizons for CI smoke. The apply/influence benches also
persist machine-readable BENCH_*.json rows (benchmarks/common.py schema;
benchmarks/check_bench_schema.py validates them in CI).
"""
import os
import time
import traceback


def _observatory(fast: bool = False) -> None:
    """The solver observatory sweep at orchestrator scale: every solver over
    the toy problem set (shrunk to a logreg 2×2 micro-sweep under FAST)."""
    from benchmarks import observatory
    argv = ['--oracle-rho', '0.01']
    if fast:
        argv += ['--problems', 'logreg_wd:D=8:n=60',
                 '--grid', 'k=2:5,rho=0.01', '--tasks', '2']
    observatory.main(argv)


def main() -> None:
    fast = bool(int(os.environ.get('FAST', '0')))
    from benchmarks import (bench_influence, fig1_inverse_quality,
                            fig2_logreg_hpo, roofline, tab2_distillation,
                            tab3_imaml, tab4_reweighting, tab5_speed_memory,
                            tab6_robustness)
    jobs = [
        ('fig1', fig1_inverse_quality.run, {}),
        ('fig2', fig2_logreg_hpo.run, {'n_outer': 4 if fast else 12}),
        ('fig3', fig2_logreg_hpo.run_rho_sweep, {'n_outer': 2 if fast else 8}),
        ('tab2', tab2_distillation.run, {'n_outer': 3 if fast else 25}),
        ('tab3', tab3_imaml.run, {'n_episodes': 10 if fast else 60,
                                  'n_eval': 5 if fast else 20}),
        ('tab4', tab4_reweighting.run,
         {'imbalances': (100,) if fast else (200, 100, 50),
          'n_outer': 5 if fast else 30}),
        ('tab5', tab5_speed_memory.run,
         {'sizes': (5,) if fast else (5, 10, 20)}),
        ('tab6', tab6_robustness.run, {'n_outer': 3 if fast else 15}),
        ('influence', bench_influence.run,
         {'m_values': (1, 4) if fast else (1, 8, 32),
          'k': 4 if fast else 16,
          'train_steps': 10 if fast else 100}),
        ('observatory', _observatory, {'fast': fast}),
        ('roofline', roofline.run, {}),
    ]
    t00 = time.time()
    for name, fn, kw in jobs:
        t0 = time.time()
        try:
            fn(**kw)
            print(f'# {name} done in {time.time()-t0:.1f}s', flush=True)
        except Exception as e:
            traceback.print_exc()
            print(f'{name},0.0,ERROR {type(e).__name__}: {e}', flush=True)
    print(f'# total {time.time()-t00:.1f}s')


if __name__ == '__main__':
    main()
