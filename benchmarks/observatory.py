"""The solver observatory CLI: one command, the whole complexity picture.

Sweeps registered problems × registered solvers × accuracy knobs through
the ``repro.bench.observatory`` engine and persists every cell as a
schema-v2 BENCH row: hypergradient error vs the exact-IHVP oracle, the
analytic HVP bill, and measured wall time, with the population axis
(seeds or an explicit ``--vary`` kwarg) under one ``jax.vmap``.

  python benchmarks/observatory.py                         # default toy sweep
  python benchmarks/observatory.py --problems logreg_wd:D=8:n=60 \\
      --solvers nystrom,cg --grid k=2:5:10,rho=0.01 --tasks 3
  python benchmarks/observatory.py --problems reweighting:d=8:width=16 \\
      --vary imbalance=10,100

Writes ``BENCH_<out>.json`` (default ``BENCH_observatory.json``) to
$BENCH_OUT_DIR or the repo root; validate with
``benchmarks/check_bench_schema.py``, diff two runs with
``benchmarks/compare_runs.py``. See docs/benchmarks.md.
"""
import argparse
import sys

if __package__ in (None, ''):          # `python benchmarks/observatory.py`
    import os
    _ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    for _p in (_ROOT, os.path.join(_ROOT, 'src')):
        if _p not in sys.path:
            sys.path.insert(0, _p)

from benchmarks.common import bench_row, write_bench


def main(argv=None) -> int:
    from repro.bench import (DEFAULT_GRID, DEFAULT_PROBLEM_SPECS, parse_grid,
                             parse_vary, run_sweep)
    from repro.bench.observatory import DEFAULT_MAX_ORACLE_P

    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument('--problems', default=','.join(DEFAULT_PROBLEM_SPECS),
                    help="comma-separated problem specs, 'name:kw=v:kw=v' "
                         '(colons separate kwargs; registry names)')
    ap.add_argument('--solvers', default='nystrom,cg,neumann,exact',
                    help='comma-separated SOLVERS registry names')
    ap.add_argument('--backends', default='tree',
                    help="comma-separated backend grid axis (e.g. "
                         "'tree,flat'); applies to solvers that build a "
                         'backend (nystrom) — others measure once per grid '
                         'point. Backend is part of compare_runs cell '
                         'identity, so tree and flat cells diff '
                         'independently')
    ap.add_argument('--grid', default=None,
                    help="accuracy knobs, 'k=2:5:10,rho=0.01' (commas "
                         'separate axes, colons values); default '
                         + ','.join(f'{k}={":".join(str(x) for x in v)}'
                                    for k, v in DEFAULT_GRID.items()))
    ap.add_argument('--tasks', type=int, default=3,
                    help='population size (seed variants per problem)')
    ap.add_argument('--vary', default=None,
                    help="population axis as 'builder_kwarg=v1,v2' (e.g. "
                         "'imbalance=10,100') instead of seeds")
    ap.add_argument('--steps-per-outer', type=int, default=None,
                    help='inner-SGD adaptation steps to θ_T (default: the '
                         "problem's own protocol)")
    ap.add_argument('--batch-size', type=int, default=None)
    ap.add_argument('--oracle-rho', type=float, default=0.0,
                    help='oracle damping: 0.0 = true implicit hypergradient; '
                         "set to the solvers' rho to isolate sketch/"
                         'truncation error from damping bias')
    ap.add_argument('--reps', type=int, default=2,
                    help='timing repetitions per cell (best-of)')
    ap.add_argument('--seed', type=int, default=0)
    ap.add_argument('--max-oracle-p', type=int, default=DEFAULT_MAX_ORACLE_P,
                    help='refuse problems whose oracle needs more than this '
                         'many HVPs per task')
    ap.add_argument('--audit', action='store_true',
                    help='audit each cell\'s timed program '
                         '(repro.analysis.audit) and record '
                         'collective_count / accum_dtype_ok in its row, so '
                         'compare_runs.py flags program-structure '
                         'regressions; rows written without --audit omit '
                         'the fields and still diff cleanly')
    ap.add_argument('--out', default='observatory',
                    help='artifact name: writes BENCH_<out>.json')
    args = ap.parse_args(argv)

    cells = run_sweep(
        problem_specs=[s for s in args.problems.split(',') if s],
        solvers=[s for s in args.solvers.split(',') if s],
        grid=parse_grid(args.grid) if args.grid else None,
        tasks=args.tasks,
        backends=tuple(b for b in args.backends.split(',') if b),
        vary=parse_vary(args.vary) if args.vary else None,
        steps=args.steps_per_outer, batch_size=args.batch_size,
        seed=args.seed, oracle_rho=args.oracle_rho, reps=args.reps,
        max_oracle_p=args.max_oracle_p, audit=args.audit, progress=print)

    rows = [bench_row(solver=c.solver, backend=c.backend, m=1,
                      applies_per_sec=c.applies_per_sec,
                      wall_seconds=c.wall_seconds, problem=c.problem,
                      hvp_count=c.hvp_count,
                      hypergrad_error=c.hypergrad_error, grid=c.grid,
                      err_max=c.err_max, tasks=c.tasks,
                      **({'collective_count': c.collective_count,
                          'accum_dtype_ok': c.accum_dtype_ok}
                         if c.collective_count is not None else {}))
            for c in cells]
    write_bench(args.out, rows,
                meta={'argv': list(argv if argv is not None else sys.argv[1:]),
                      'oracle_rho': args.oracle_rho, 'tasks': args.tasks})
    return 0


if __name__ == '__main__':
    sys.exit(main())
