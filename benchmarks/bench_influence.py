"""Influence-service benchmark: one amortized sketch, an m-query block.

The workload the block apply path was built for: train once, prepare one
Nyström sketch, then serve a growing block of influence queries through a
single ``apply_matrix`` call and a streamed top-k scan over the training
set. ``applies_per_sec`` counts queries scored per second (training and
sketch construction excluded — they amortize over every query), so the m
sweep shows the amortization directly: Nyström's per-query cost falls with
m while CG pays its full iteration chain per query.

Rows are persisted as ``BENCH_influence.json`` (schema in
benchmarks/common.py; validated by benchmarks/check_bench_schema.py).

CLI (CI bench-smoke runs this at toy size):
  PYTHONPATH=src python -m benchmarks.bench_influence --k 4 \
      --train-steps 10 --m 1 4
"""
import time

from benchmarks.common import bench_row, emit, write_bench
from repro.core import HypergradConfig, get_problem, influence


def run(m_values=(1, 8, 32), k: int = 16, top_k: int = 5,
        train_steps: int = 100, d: int = 16):
    problem = get_problem('influence', d=d)
    rows = []
    for solver_name in ('nystrom', 'cg'):
        cfg = (HypergradConfig(solver='nystrom', k=k, rho=1e-2)
               if solver_name == 'nystrom'
               else HypergradConfig(solver='cg', k=k, rho=1e-2))
        # train once; the query sweep reuses the converged params so the
        # timed region is the per-query serving cost only
        base = influence(problem, cfg, problem.reference['queries'](1),
                         top_k=top_k, train_steps=train_steps)
        for m in m_values:
            queries = problem.reference['queries'](m)
            t0 = time.time()
            res = influence(problem, cfg, queries, params=base.params,
                            top_k=top_k)
            wall = time.time() - t0
            rows.append(bench_row(
                solver=solver_name, backend='tree', m=m,
                applies_per_sec=m / wall, wall_seconds=wall,
                problem='influence', hvp_count=res.hvp_count,
                top_k=top_k, k=k, d=d))
            emit('bench_influence', wall * 1e6,
                 f'solver={solver_name} m={m} k={k} top_k={top_k} '
                 f'hvps={res.hvp_count} queries_per_s={m / wall:.1f}')
    write_bench('influence', rows,
                meta=dict(train_steps=train_steps, d=d))
    return rows


def main(argv=None):
    import argparse
    ap = argparse.ArgumentParser()
    ap.add_argument('--m', type=int, nargs='+', default=[1, 8, 32])
    ap.add_argument('--k', type=int, default=16)
    ap.add_argument('--top-k', type=int, default=5)
    ap.add_argument('--train-steps', type=int, default=100)
    ap.add_argument('--d', type=int, default=16)
    args = ap.parse_args(argv)
    run(m_values=tuple(args.m), k=args.k, top_k=args.top_k,
        train_steps=args.train_steps, d=args.d)


if __name__ == '__main__':
    main()
