"""Influence-service benchmark: one amortized sketch, an m-query block.

The workload the block apply path was built for: train once, prepare one
Nyström sketch, then serve a growing block of influence queries through a
single ``apply_matrix`` call and a streamed top-k scan over the training
set. ``applies_per_sec`` counts queries scored per second (training and
sketch construction excluded — they amortize over every query), so the m
sweep shows the amortization directly: Nyström's per-query cost falls with
m while CG pays its full iteration chain per query.

A second table measures attribution *quality*: on a separate problem sized
so the exact IHVP is affordable (p HVPs — ``--quality-d/--quality-width``),
each solver's top-k retrieved training examples are scored by Jaccard@k
overlap against the exact solver's retrieval, per query, averaged. These
rows carry ``jaccard_vs_exact`` (exact's own row is 1.0 by construction)
and ``phase='quality'`` so compare_runs diffs them as their own cells.

Rows are persisted as ``BENCH_influence.json`` (schema in
benchmarks/common.py; validated by benchmarks/check_bench_schema.py).

CLI (CI bench-smoke runs this at toy size):
  PYTHONPATH=src python -m benchmarks.bench_influence --k 4 \
      --train-steps 10 --m 1 4
"""
import time

from benchmarks.common import bench_row, emit, write_bench
from repro.core import HypergradConfig, get_problem, influence


def jaccard_at_k(a, b) -> float:
    """|A ∩ B| / |A ∪ B| of two index sets (rows of retrieved indices)."""
    sa, sb = set(int(i) for i in a), set(int(i) for i in b)
    union = sa | sb
    return len(sa & sb) / len(union) if union else 1.0


def run_quality(m: int = 4, k: int = 8, top_k: int = 10,
                train_steps: int = 50, d: int = 8, width: int = 8,
                rho: float = 1e-1):
    """Nyström-vs-CG-vs-exact retrieval agreement on one reweighting-substrate
    influence problem, small enough that the exact oracle (p HVPs) runs in
    CI. Returns quality rows keyed ``phase='quality'``.

    Default ρ=1e-1: at non-converged params the Hessian has near-null
    directions, and at tiny damping the *exact* inverse is dominated by
    them — every approximate solver then disagrees with the oracle roughly
    equally (Jaccard ≈ noise) and the table says nothing. Moderate damping
    is the regime influence functions are actually run in, and where the
    Nyström-vs-CG fidelity ordering is visible.
    """
    problem = get_problem('influence', d=d, width=width)
    queries = problem.reference['queries'](m)
    configs = {
        'exact': HypergradConfig(solver='exact', rho=rho),
        'nystrom': HypergradConfig(solver='nystrom', k=k, rho=rho),
        'cg': HypergradConfig(solver='cg', k=k, rho=rho),
    }
    results, walls = {}, {}
    params = None
    for name, cfg in configs.items():
        t0 = time.time()
        res = influence(problem, cfg, queries, params=params,
                        top_k=top_k, train_steps=train_steps)
        walls[name] = time.time() - t0
        params = res.params              # train once, share across solvers
        results[name] = res
    rows = []
    for name, res in results.items():
        jac = sum(jaccard_at_k(res.indices[q], results['exact'].indices[q])
                  for q in range(m)) / m
        rows.append(bench_row(
            solver=name, backend='tree', m=m,
            applies_per_sec=m / walls[name], wall_seconds=walls[name],
            problem='influence', hvp_count=res.hvp_count,
            phase='quality', jaccard_vs_exact=round(jac, 6),
            top_k=top_k, k=k, d=d, width=width))
        emit('bench_influence_quality', walls[name] * 1e6,
             f'solver={name} m={m} top_k={top_k} '
             f'jaccard_vs_exact={jac:.3f} hvps={res.hvp_count}')
    return rows


def run(m_values=(1, 8, 32), k: int = 16, top_k: int = 5,
        train_steps: int = 100, d: int = 16, quality: bool = True,
        quality_d: int = 8, quality_width: int = 8):
    problem = get_problem('influence', d=d)
    rows = []
    for solver_name in ('nystrom', 'cg'):
        cfg = (HypergradConfig(solver='nystrom', k=k, rho=1e-2)
               if solver_name == 'nystrom'
               else HypergradConfig(solver='cg', k=k, rho=1e-2))
        # train once; the query sweep reuses the converged params so the
        # timed region is the per-query serving cost only
        base = influence(problem, cfg, problem.reference['queries'](1),
                         top_k=top_k, train_steps=train_steps)
        for m in m_values:
            queries = problem.reference['queries'](m)
            t0 = time.time()
            res = influence(problem, cfg, queries, params=base.params,
                            top_k=top_k)
            wall = time.time() - t0
            rows.append(bench_row(
                solver=solver_name, backend='tree', m=m,
                applies_per_sec=m / wall, wall_seconds=wall,
                problem='influence', hvp_count=res.hvp_count,
                top_k=top_k, k=k, d=d))
            emit('bench_influence', wall * 1e6,
                 f'solver={solver_name} m={m} k={k} top_k={top_k} '
                 f'hvps={res.hvp_count} queries_per_s={m / wall:.1f}')
    if quality:
        rows += run_quality(k=min(k, 8), train_steps=min(train_steps, 50),
                            d=quality_d, width=quality_width)
    write_bench('influence', rows,
                meta=dict(train_steps=train_steps, d=d))
    return rows


def main(argv=None):
    import argparse
    ap = argparse.ArgumentParser()
    ap.add_argument('--m', type=int, nargs='+', default=[1, 8, 32])
    ap.add_argument('--k', type=int, default=16)
    ap.add_argument('--top-k', type=int, default=5)
    ap.add_argument('--train-steps', type=int, default=100)
    ap.add_argument('--d', type=int, default=16)
    ap.add_argument('--no-quality', action='store_true',
                    help='skip the Nyström-vs-CG-vs-exact Jaccard@k table')
    ap.add_argument('--quality-d', type=int, default=8,
                    help='input dim of the small quality problem (the exact '
                         'oracle pays p HVPs, so keep p modest)')
    ap.add_argument('--quality-width', type=int, default=8,
                    help='MLP hidden width of the small quality problem')
    args = ap.parse_args(argv)
    run(m_values=tuple(args.m), k=args.k, top_k=args.top_k,
        train_steps=args.train_steps, d=args.d, quality=not args.no_quality,
        quality_d=args.quality_d, quality_width=args.quality_width)


if __name__ == '__main__':
    main()
