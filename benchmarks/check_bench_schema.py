"""Validate persisted ``BENCH_*.json`` artifacts against the schema contract.

CI's bench-smoke job runs the benches at toy size and then this checker over
whatever they wrote — a perf-trajectory artifact that fails loudly the
moment a bench drifts from the row contract in benchmarks/common.py
(schema_version, and per-row solver/backend/m/applies_per_sec/wall_seconds).

Usage:
  PYTHONPATH=src python -m benchmarks.check_bench_schema [paths...]
With no paths, checks every BENCH_*.json in $BENCH_OUT_DIR (default: the
repo root) and fails if there are none.
"""
import glob
import json
import os
import sys

from benchmarks.common import BENCH_REQUIRED_KEYS, BENCH_SCHEMA_VERSION


def check_file(path: str) -> list[str]:
    """Return a list of human-readable schema violations (empty = valid)."""
    errs = []
    with open(path) as f:
        doc = json.load(f)
    if doc.get('schema_version') != BENCH_SCHEMA_VERSION:
        errs.append(f"schema_version={doc.get('schema_version')!r} "
                    f'(expected {BENCH_SCHEMA_VERSION})')
    for key in ('name', 'created_unix', 'rows'):
        if key not in doc:
            errs.append(f'missing top-level key {key!r}')
    rows = doc.get('rows', [])
    if not isinstance(rows, list) or not rows:
        errs.append('rows must be a non-empty list')
        rows = []
    for i, row in enumerate(rows):
        missing = [k for k in BENCH_REQUIRED_KEYS if k not in row]
        if missing:
            errs.append(f'row {i} missing {missing}')
            continue
        if not isinstance(row['m'], int) or row['m'] < 1:
            errs.append(f"row {i}: m={row['m']!r} must be an int >= 1")
        for k in ('applies_per_sec', 'wall_seconds'):
            if not isinstance(row[k], (int, float)) or row[k] < 0:
                errs.append(f'row {i}: {k}={row[k]!r} must be a number >= 0')
        for k in ('solver', 'backend'):
            if not isinstance(row[k], str) or not row[k]:
                errs.append(f'row {i}: {k}={row[k]!r} must be a non-empty '
                            'string')
    return errs


def main(argv=None) -> int:
    paths = list(argv if argv is not None else sys.argv[1:])
    if not paths:
        out_dir = os.environ.get('BENCH_OUT_DIR') or os.path.dirname(
            os.path.dirname(os.path.abspath(__file__)))
        paths = sorted(glob.glob(os.path.join(out_dir, 'BENCH_*.json')))
        if not paths:
            print(f'check_bench_schema: no BENCH_*.json under {out_dir}')
            return 1
    failed = False
    for path in paths:
        errs = check_file(path)
        if errs:
            failed = True
            print(f'FAIL {path}')
            for e in errs:
                print(f'  - {e}')
        else:
            with open(path) as f:
                n = len(json.load(f)['rows'])
            print(f'OK   {path} ({n} rows)')
    return 1 if failed else 0


if __name__ == '__main__':
    sys.exit(main())
