"""Validate persisted ``BENCH_*.json`` artifacts against the schema contract.

CI's bench-smoke job runs the benches at toy size and then this checker over
whatever they wrote — a perf-trajectory artifact that fails loudly the
moment a bench drifts from the row contract in benchmarks/common.py.

Both schema versions validate (``BENCH_SCHEMA_KEYS``): v1 rows carry
solver/backend/m/applies_per_sec/wall_seconds; v2 rows additionally carry
``problem`` and ``hvp_count``, plus type-checked optional
``hypergrad_error`` / ``grid`` fields (the observatory's accuracy cells)
and ``collective_count`` / ``accum_dtype_ok`` (its ``--audit``
program-structure fields).
Old baselines therefore stay checkable after the bump — only
``compare_runs.py`` insists both sides of a diff share one version.

Usage:
  PYTHONPATH=src python -m benchmarks.check_bench_schema [paths...]
With no paths, checks every BENCH_*.json in $BENCH_OUT_DIR (default: the
repo root) and fails if there are none.
"""
import glob
import json
import os
import sys

from benchmarks.common import BENCH_SCHEMA_KEYS


def check_file(path: str) -> list[str]:
    """Return a list of human-readable schema violations (empty = valid)."""
    errs = []
    with open(path) as f:
        doc = json.load(f)
    version = doc.get('schema_version')
    if version not in BENCH_SCHEMA_KEYS:
        errs.append(f'schema_version={version!r} '
                    f'(expected one of {sorted(BENCH_SCHEMA_KEYS)})')
        return errs
    required = BENCH_SCHEMA_KEYS[version]
    for key in ('name', 'created_unix', 'rows'):
        if key not in doc:
            errs.append(f'missing top-level key {key!r}')
    rows = doc.get('rows', [])
    if not isinstance(rows, list) or not rows:
        errs.append('rows must be a non-empty list')
        rows = []
    for i, row in enumerate(rows):
        missing = [k for k in required if k not in row]
        if missing:
            errs.append(f'row {i} missing {missing}')
            continue
        if not isinstance(row['m'], int) or row['m'] < 1:
            errs.append(f"row {i}: m={row['m']!r} must be an int >= 1")
        for k in ('applies_per_sec', 'wall_seconds'):
            if not isinstance(row[k], (int, float)) or row[k] < 0:
                errs.append(f'row {i}: {k}={row[k]!r} must be a number >= 0')
        for k in ('solver', 'backend'):
            if not isinstance(row[k], str) or not row[k]:
                errs.append(f'row {i}: {k}={row[k]!r} must be a non-empty '
                            'string')
        if version >= 2:
            errs.extend(_check_v2_row(i, row))
    return errs


def _check_v2_row(i: int, row: dict) -> list[str]:
    """v2 additions: required problem/hvp_count + typed optional fields."""
    errs = []
    if not isinstance(row['problem'], str) or not row['problem']:
        errs.append(f"row {i}: problem={row['problem']!r} must be a "
                    'non-empty string')
    if (not isinstance(row['hvp_count'], int)
            or isinstance(row['hvp_count'], bool) or row['hvp_count'] < 0):
        errs.append(f"row {i}: hvp_count={row['hvp_count']!r} must be an "
                    'int >= 0')
    if 'hypergrad_error' in row:
        v = row['hypergrad_error']
        if not isinstance(v, (int, float)) or isinstance(v, bool) or v < 0:
            errs.append(f'row {i}: hypergrad_error={v!r} must be a '
                        'number >= 0')
    if 'grid' in row and not isinstance(row['grid'], dict):
        errs.append(f"row {i}: grid={row['grid']!r} must be a dict of "
                    'accuracy-knob values')
    if 'collective_count' in row:
        v = row['collective_count']
        if not isinstance(v, int) or isinstance(v, bool) or v < 0:
            errs.append(f'row {i}: collective_count={v!r} must be an '
                        'int >= 0')
    if 'accum_dtype_ok' in row and not isinstance(row['accum_dtype_ok'],
                                                  bool):
        errs.append(f"row {i}: accum_dtype_ok={row['accum_dtype_ok']!r} "
                    'must be a bool')
    return errs


def main(argv=None) -> int:
    paths = list(argv if argv is not None else sys.argv[1:])
    if not paths:
        out_dir = os.environ.get('BENCH_OUT_DIR') or os.path.dirname(
            os.path.dirname(os.path.abspath(__file__)))
        paths = sorted(glob.glob(os.path.join(out_dir, 'BENCH_*.json')))
        if not paths:
            print(f'check_bench_schema: no BENCH_*.json under {out_dir}')
            return 1
    failed = False
    for path in paths:
        errs = check_file(path)
        if errs:
            failed = True
            print(f'FAIL {path}')
            for e in errs:
                print(f'  - {e}')
        else:
            with open(path) as f:
                doc = json.load(f)
            print(f"OK   {path} (schema v{doc['schema_version']}, "
                  f"{len(doc['rows'])} rows)")
    return 1 if failed else 0


if __name__ == '__main__':
    sys.exit(main())
