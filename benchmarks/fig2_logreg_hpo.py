"""Fig. 2/3: per-parameter weight-decay HPO on synthetic logistic regression.

Paper protocol: inner SGD lr=0.1 (reset every 100 steps), outer SGD momentum
0.9, l=k=5, α=ρ=0.01, D=100, 500 points. Deviation (recorded in
EXPERIMENTS.md): outer lr 1.0 → 0.1 + hypergradient clipping — at lr 1.0 the
*inner* SGD destabilizes once accumulated weight decay exceeds 2/inner_lr,
and Nyström hits that first precisely because its IHVP is the most accurate
(truncated CG/Neumann underestimate). Identical settings for all methods.

Runs through the typed problem API (``repro.core.problem.solve``); the
paper-protocol training hyperparameters live on the problem's ``defaults``.
"""
from benchmarks.common import emit, solver_cfg
from repro.core import solve
from repro.tasks import build_logreg_weight_decay


def run(n_outer: int = 12):
    problem = build_logreg_weight_decay()
    results = {}
    for method in ('nystrom', 'cg', 'neumann'):
        res = solve(problem, solver_cfg(method, k=5, rho=1e-2, alpha=1e-2),
                    n_outer=n_outer)
        results[method] = res.history['outer_loss'][-1]
        emit('fig2_logreg_hpo', res.seconds * 1e6 / n_outer,
             f'method={method} final_val_loss={results[method]:.4f} '
             f'hvps={res.hvp_count}')
    # paper claim: Nyström optimizes at least as fast as baselines
    assert results['nystrom'] <= min(results.values()) + 0.05
    return results


def run_rho_sweep(n_outer: int = 8):
    """Fig. 3 companion: robustness over ρ ∈ {0.01, 0.1, 1.0}."""
    problem = build_logreg_weight_decay()
    out = {}
    for rho in (0.01, 0.1, 1.0):
        res = solve(problem, solver_cfg('nystrom', k=5, rho=rho),
                    n_outer=n_outer)
        out[rho] = res.history['outer_loss'][-1]
        emit('fig3_rho_sweep', res.seconds * 1e6 / n_outer,
             f'rho={rho} final_val_loss={out[rho]:.4f}')
    spread = max(out.values()) - min(out.values())
    emit('fig3_rho_sweep', 0.0, f'spread={spread:.4f} (robustness claim)')
    return out
