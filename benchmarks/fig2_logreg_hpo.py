"""Fig. 2/3: per-parameter weight-decay HPO on synthetic logistic regression.

Paper protocol: inner SGD lr=0.1 (reset every 100 steps), outer SGD momentum
0.9, l=k=5, α=ρ=0.01, D=100, 500 points. Deviation (recorded in
EXPERIMENTS.md): outer lr 1.0 → 0.1 + hypergradient clipping — at lr 1.0 the
*inner* SGD destabilizes once accumulated weight decay exceeds 2/inner_lr,
and Nyström hits that first precisely because its IHVP is the most accurate
(truncated CG/Neumann underestimate). Identical settings for all methods.
"""
import time

from benchmarks.common import emit, run_bilevel
from repro.tasks import build_logreg_weight_decay


def run(n_outer: int = 12):
    task = build_logreg_weight_decay()
    results = {}
    for method in ('nystrom', 'cg', 'neumann'):
        t0 = time.time()
        _, hist, secs = run_bilevel(
            task, method, n_outer=n_outer, steps_per_outer=100,
            inner_lr=0.1, outer_lr=0.1, outer_opt='sgd_momentum',
            k=5, rho=1e-2, alpha=1e-2, reset_inner=True, batch=500)
        results[method] = hist['outer_loss'][-1]
        emit('fig2_logreg_hpo', secs * 1e6 / n_outer,
             f'method={method} final_val_loss={hist["outer_loss"][-1]:.4f}')
    # paper claim: Nyström optimizes at least as fast as baselines
    assert results['nystrom'] <= min(results.values()) + 0.05
    return results


def run_rho_sweep(n_outer: int = 8):
    """Fig. 3 companion: robustness over ρ ∈ {0.01, 0.1, 1.0}."""
    task = build_logreg_weight_decay()
    out = {}
    for rho in (0.01, 0.1, 1.0):
        _, hist, secs = run_bilevel(
            task, 'nystrom', n_outer=n_outer, steps_per_outer=100,
            inner_lr=0.1, outer_lr=0.1, outer_opt='sgd_momentum',
            k=5, rho=rho, reset_inner=True, batch=500)
        out[rho] = hist['outer_loss'][-1]
        emit('fig3_rho_sweep', secs * 1e6 / n_outer,
             f'rho={rho} final_val_loss={hist["outer_loss"][-1]:.4f}')
    spread = max(out.values()) - min(out.values())
    emit('fig3_rho_sweep', 0.0, f'spread={spread:.4f} (robustness claim)')
    return out
