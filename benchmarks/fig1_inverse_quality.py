"""Fig. 1: inverse-quality of (H_k + ρI)⁻¹ on a rank-20 40-dim matrix."""
import time

import jax
import jax.numpy as jnp

from benchmarks.common import emit
from repro.core import nystrom_inverse_dense


def run():
    p, r, rho = 40, 20, 0.1
    A = jax.random.normal(jax.random.PRNGKey(0), (p, r))
    H = A @ A.T
    truth = jnp.linalg.inv(H + rho * jnp.eye(p))

    t0 = time.time()
    rows = []
    for k in (5, 10, 20, 40):
        ny = nystrom_inverse_dense(H, k=k, rho=rho, rng=jax.random.PRNGKey(1))
        err_ny = float(jnp.linalg.norm(ny - truth) / jnp.linalg.norm(truth))
        # Neumann series truncated at l=k (α set to 0.9/λmax for validity)
        alpha = 0.9 / float(jnp.linalg.eigvalsh(H)[-1])
        acc = jnp.eye(p)
        term = jnp.eye(p)
        for _ in range(k):
            term = term @ (jnp.eye(p) - alpha * (H + rho * jnp.eye(p)))
            acc = acc + term
        err_ne = float(jnp.linalg.norm(alpha * acc - truth) / jnp.linalg.norm(truth))
        rows.append((k, err_ny, err_ne))
    us = (time.time() - t0) * 1e6 / len(rows)
    for k, e1, e2 in rows:
        emit('fig1_inverse_quality', us,
             f'k={k} rel_err nystrom={e1:.4f} neumann={e2:.4f}')
    # paper claim: accurate already at k=r/4 (k=5 on rank-20)
    assert rows[-1][1] < 1e-2, 'k=p must be near-exact'
    return rows
