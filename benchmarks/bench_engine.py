"""Engine benchmark: trilevel solves, dense-oracle parity, per-edge bills.

Two tables over the registered multi-level graphs (``repro.engine``):

* **trilevel rows** (``phase='trilevel'``): each graph solved end-to-end
  through ``Engine.solve`` — one jitted program for the whole
  inner-to-outer sweep — with ``hypergrad_error`` measured against the
  dense multi-level oracle (``engine_hypergrad_reference``, ρ=0) at the
  solved point, and ``hvp_count`` the run's total amortized bill. Toy
  sizes by construction: the oracle materializes every solved node's
  Hessian.
* **per-edge bill rows** (``phase='edge_bill'``): the analytic
  amortized-vs-fresh contrast per edge. Amortized bills are *additive*
  across levels (one live sketch per edge, refreshed on cadence); fresh
  bills are *multiplicative* down the chain (every upper derivative pass
  re-prepares every lower edge). The ratio is the nesting analogue of the
  paper's amortization argument, and any ``hvp_count`` growth here fails
  the CI gate — the bills are analytic, so growth is a real complexity
  regression, never noise.

Rows are persisted as ``BENCH_engine.json`` (schema v2, validated by
benchmarks/check_bench_schema.py) and gated in CI against
``benchmarks/baselines/engine_ci.json`` via ``compare_runs.py --no-wall``.

CLI (CI bench-smoke runs this):
  PYTHONPATH=src python -m benchmarks.bench_engine \
      --problems distill_hpo --n-outer 2
"""
import time

from benchmarks.common import bench_row, emit, write_bench
from repro.core import hypergrad_error
from repro.engine import (Engine, EngineConfig, engine_edge_bills,
                          engine_hypergrad, engine_hypergrad_reference,
                          get_graph)

# compact builder kwargs: small enough for the dense oracle in CI, large
# enough that every level is a genuine (non-scalar) problem
COMPACT = {
    'distill_hpo': dict(d=4, n_classes=2, n_syn=4, n_train=16, n_val=16),
    'reweight_maml': dict(d=4, n_tasks=2, n_support=8, n_query=8),
}


def run(problems=('distill_hpo', 'reweight_maml'), n_outer: int = 2,
        solver: str = 'nystrom', refresh_every: int = 1,
        oracle: bool = True):
    rows = []
    for name in problems:
        g = get_graph(name, solver=solver, refresh_every=refresh_every,
                      **COMPACT.get(name, {}))
        order = g.chain_order()
        t0 = time.time()
        res = Engine().solve(g, EngineConfig(n_outer=n_outer))
        wall = time.time() - t0

        err = None
        if oracle:
            hg, _ = engine_hypergrad(g, res.values)
            ref, _ = engine_hypergrad_reference(g, res.values, rho=0.0)
            err = float(hypergrad_error(hg, ref))

        rows.append(bench_row(
            solver=solver, backend='tree', m=1,
            applies_per_sec=n_outer / wall, wall_seconds=wall,
            problem=name, hvp_count=res.hvp_count, hypergrad_error=err,
            phase='trilevel', levels=len(order), n_outer=n_outer))
        emit('bench_engine', wall * 1e6,
             f'graph={name} levels={len(order)} n_outer={n_outer} '
             f'hvps={res.hvp_count} '
             + (f'err_vs_oracle={err:.2e}' if err is not None else ''))

        # amortized vs fresh, per edge — the bills are analytic (the jitted
        # step hides runtime counters), computed by the same arithmetic the
        # engine result reports
        fresh = engine_edge_bills(g, n_outer, amortize=False)
        for edge, bill in res.edge_hvps.items():
            for mode, count in (('amortized', bill), ('fresh', fresh[edge])):
                rows.append(bench_row(
                    solver=solver, backend='tree', m=1,
                    applies_per_sec=0.0, wall_seconds=0.0, problem=name,
                    hvp_count=count, phase='edge_bill', edge=edge,
                    mode=mode, n_outer=n_outer))
            emit('bench_engine_bills', 0.0,
                 f'graph={name} edge={edge} amortized={bill} '
                 f'fresh={fresh[edge]} ratio={fresh[edge] / max(1, bill):.1f}x')
    write_bench('engine', rows,
                meta=dict(problems=list(problems), n_outer=n_outer,
                          solver=solver, refresh_every=refresh_every))
    return rows


def main(argv=None):
    import argparse
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument('--problems', nargs='+',
                    default=['distill_hpo', 'reweight_maml'],
                    help='registered graph names (repro.engine GRAPHS)')
    ap.add_argument('--n-outer', type=int, default=2)
    ap.add_argument('--solver', default='nystrom')
    ap.add_argument('--refresh-every', type=int, default=1)
    ap.add_argument('--no-oracle', action='store_true',
                    help='skip the dense-oracle parity column (rows then '
                         'carry bills + wall only)')
    args = ap.parse_args(argv)
    run(problems=tuple(args.problems), n_outer=args.n_outer,
        solver=args.solver, refresh_every=args.refresh_every,
        oracle=not args.no_oracle)


if __name__ == '__main__':
    main()
