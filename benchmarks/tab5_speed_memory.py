"""Tab. 5: hypergradient speed & memory by backend and l/k.

No GPU in-container: we report (a) CPU wall-clock per hypergradient on a
~0.3M-param MLP (relative speeds are meaningful: the same HVP primitives
dominate), and (b) the analytic cost model that transfers to TPU —
sequential-HVP count (latency-critical: CG/Neumann chain l HVPs; Nyström's
k column-HVPs are batchable) and sketch-memory bytes (Nyström's O(kp) vs
O(p) — the paper's Tab. 5 memory column).
"""
import time

import jax
import jax.numpy as jnp

from benchmarks.common import emit, solver_cfg
from repro.core import PyTreeIndexer, hypergradient
from repro.tasks import build_reweighting


def run(sizes=(5, 10, 20), reps: int = 3):
    task = build_reweighting(imbalance=50)
    params = task['init_params'](jax.random.PRNGKey(0))
    hp = task['init_hparams'](jax.random.PRNGKey(1))
    p_count = sum(x.size for x in jax.tree.leaves(params))
    batch = task['data'].train_batch(0, 128)
    vbatch = task['data'].val_batch(0, 128)
    idxr = PyTreeIndexer(params)
    out = {}
    for method in ('cg', 'neumann', 'nystrom'):
        for lk in sizes:
            cfg = solver_cfg(method, k=lk, rho=1e-2, alpha=1e-2)
            solver = cfg.build()

            @jax.jit
            def hg(params, hp, key):
                return hypergradient(task['inner'], task['outer'], params,
                                     hp, batch, vbatch, solver, key, idxr)

            hg(params, hp, jax.random.PRNGKey(2))  # warmup/compile
            t0 = time.time()
            for r in range(reps):
                jax.block_until_ready(hg(params, hp, jax.random.PRNGKey(r)))
            per = (time.time() - t0) / reps
            seq_hvps = lk if method in ('cg', 'neumann') else 0  # Nyström: k parallel
            sketch_mb = (lk * p_count * 4 / 1e6) if method == 'nystrom' else 0.0
            out[(method, lk)] = per
            emit('tab5_speed_memory', per * 1e6,
                 f'method={method} l_or_k={lk} wall_s={per:.4f} '
                 f'sequential_hvps={seq_hvps} sketch_MB={sketch_mb:.1f}')
    # space-efficient variant timing (κ=1): same sketch, chunked apply
    from repro.core import NystromIHVP
    for lk in sizes:
        solver = NystromIHVP(k=lk, rho=1e-2, kappa=1)

        @jax.jit
        def hg2(params, hp, key):
            return hypergradient(task['inner'], task['outer'], params, hp,
                                 batch, vbatch, solver, key, idxr)

        hg2(params, hp, jax.random.PRNGKey(2))
        t0 = time.time()
        for r in range(reps):
            jax.block_until_ready(hg2(params, hp, jax.random.PRNGKey(r)))
        per = (time.time() - t0) / reps
        emit('tab5_speed_memory', per * 1e6,
             f'method=nystrom_kappa1 l_or_k={lk} wall_s={per:.4f} '
             f'sequential_hvps=0 sketch_MB={4*p_count/1e6:.1f}(peak κp)')
    return out
