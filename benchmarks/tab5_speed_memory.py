"""Tab. 5: hypergradient speed & memory by solver, and IHVP apply-time by
contraction backend.

No GPU in-container: we report (a) CPU wall-clock per hypergradient on a
~0.3M-param MLP (relative speeds are meaningful: the same HVP primitives
dominate), and (b) the analytic cost model that transfers to TPU —
sequential-HVP count (latency-critical: CG/Neumann chain l HVPs; Nyström's
k column-HVPs are batchable) and sketch-memory bytes (Nyström's O(kp) vs
O(p) — the paper's Tab. 5 memory column).

``run_backend_apply`` times the Nyström apply under the contraction
backends (tree | flat | pallas) over pytrees of growing leaf count at fixed
total p: the tree backend pays per-leaf einsum dispatch that grows with leaf
count, the flat backend is one fused matmul per pass regardless, and pallas
off-TPU runs in interpret mode (correctness reference, not a speed number —
its compiled-TPU cost model is in benchmarks/roofline.py). Each row also
reports the resident sketch-buffer memory (C plus the whitened factor B),
for f32 and — on the flat family — bf16 sketch storage, so the
docs/backends.md table cites reproducible numbers. All apply timings go
through ``apply_matrix`` (the block path; a width-1 block statically
dispatches to the vector apply, so m=1 rows are the old numbers).

``run_block_apply`` is the headline loop-vs-block measurement: m IHVP
queries served by m jitted vector applies in a Python loop (the
pre-block-path idiom this bench used to hard-code) vs ONE
``apply_matrix`` call on a ``(p, m)`` query block. ``applies_per_sec``
counts queries served per second, so the two rows are directly comparable
at each m — the block path re-reads the O(kp) sketch once instead of m
times, which is where the ≥3× win at m≥32 comes from on CPU.

``run_sharded_backend_apply`` times flat_sharded vs tree on a mesh over all
visible devices; on a 1-device host it emits a SKIPPED row with the
XLA_FLAGS incantation instead (the host device count is fixed before jax
initializes, so this process cannot grow a mesh itself).

All apply rows are persisted as ``BENCH_tab5_apply.json`` (see
benchmarks/common.py for the schema contract).
"""
import time

import jax
import jax.numpy as jnp

from benchmarks.common import bench_row, emit, solver_cfg, write_bench
from repro.core import (FlatBackend, FlatShardedBackend, NystromIHVP,
                        PallasBackend, PyTreeIndexer, hypergradient,
                        make_hvp, tree_random_like)
from repro.tasks import build_reweighting


def run(sizes=(5, 10, 20), reps: int = 3):
    task = build_reweighting(imbalance=50)
    params = task.init_params(jax.random.PRNGKey(0))
    hp = task.init_hparams(jax.random.PRNGKey(1))
    p_count = sum(x.size for x in jax.tree.leaves(params))
    data = task.reference['dataset']          # the raw seed-stream dataset
    batch = data.train_batch(0, 128)
    vbatch = data.val_batch(0, 128)
    idxr = PyTreeIndexer(params)
    out = {}
    for method in ('cg', 'neumann', 'nystrom'):
        for lk in sizes:
            cfg = solver_cfg(method, k=lk, rho=1e-2, alpha=1e-2)
            solver = cfg.build()

            @jax.jit
            def hg(params, hp, key):
                return hypergradient(task.inner_loss, task.outer_loss, params,
                                     hp, batch, vbatch, solver, key, idxr)

            hg(params, hp, jax.random.PRNGKey(2))  # warmup/compile
            t0 = time.time()
            for r in range(reps):
                jax.block_until_ready(hg(params, hp, jax.random.PRNGKey(r)))
            per = (time.time() - t0) / reps
            seq_hvps = lk if method in ('cg', 'neumann') else 0  # Nyström: k parallel
            sketch_mb = (lk * p_count * 4 / 1e6) if method == 'nystrom' else 0.0
            out[(method, lk)] = per
            emit('tab5_speed_memory', per * 1e6,
                 f'method={method} l_or_k={lk} wall_s={per:.4f} '
                 f'sequential_hvps={seq_hvps} sketch_MB={sketch_mb:.1f}')
    # space-efficient variant timing (κ=1): same sketch, chunked apply
    from repro.core import NystromIHVP
    for lk in sizes:
        solver = NystromIHVP(k=lk, rho=1e-2, kappa=1)

        @jax.jit
        def hg2(params, hp, key):
            return hypergradient(task.inner_loss, task.outer_loss, params, hp,
                                 batch, vbatch, solver, key, idxr)

        # repro: allow[prng-key-reuse] — same keys as the method loop above,
        # deliberately: identical sketch draws make the timings comparable
        hg2(params, hp, jax.random.PRNGKey(2))
        t0 = time.time()
        for r in range(reps):
            # repro: allow[prng-key-reuse] — see above: shared keys by design
            jax.block_until_ready(hg2(params, hp, jax.random.PRNGKey(r)))
        per = (time.time() - t0) / reps
        emit('tab5_speed_memory', per * 1e6,
             f'method=nystrom_kappa1 l_or_k={lk} wall_s={per:.4f} '
             f'sequential_hvps=0 sketch_MB={4*p_count/1e6:.1f}(peak κp)')
    rows = []
    out.update(run_block_apply(rows=rows))
    out.update(run_backend_apply(rows=rows))
    out.update(run_sharded_backend_apply(rows=rows))
    write_bench('tab5_apply', rows,
                meta=dict(device=jax.default_backend(),
                          n_devices=jax.device_count()))
    return out


def _sketch_bytes(sketch) -> int:
    """Resident bytes of the prepared sketch's p-sized state: the operand C
    plus the whitened factor B (flat_sharded's ShardedOperand counts its
    per-device rows once each — replicated leaves genuinely occupy a copy
    per device there)."""
    return sum(x.nbytes for part in (sketch.C, sketch.B) if part is not None
               for x in jax.tree.leaves(part))


def _leafy_params(n_leaves: int, p_total: int) -> dict:
    """n_leaves equal 2-D leaves summing to ~p_total params (an MLP-shaped
    tree: the multi-leaf case the tree backend pays per-leaf dispatch on)."""
    rows = max(1, p_total // (n_leaves * 64))
    return {f'layer{i:02d}': jnp.zeros((rows, 64)) for i in range(n_leaves)}


def _diag_quadratic_hvp(params, idxr):
    """HVP of a diagonal quadratic over ``params`` — sketch construction is
    cheap, so apply-path timing is isolated (what amortization makes hot)."""
    p_count = idxr.total
    d = 1.0 + jnp.arange(p_count, dtype=jnp.float32) / p_count

    def inner(prm, hp, batch):
        th = jnp.concatenate([x.ravel() for x in jax.tree.leaves(prm)])
        return 0.5 * jnp.sum(d * th * th)

    return make_hvp(inner, params, None, None)


def run_block_apply(m_values=(1, 8, 32), n_leaves=8, p_total=1 << 18, k=32,
                    reps: int = 5, rows=None):
    """Headline loop-vs-block row: m queries via m jitted vector applies
    (Python loop — the old idiom) vs one ``apply_matrix`` on a (p, m) block.
    """
    params = _leafy_params(n_leaves, p_total)
    idxr = PyTreeIndexer(params)
    p_count = idxr.total
    hvp = _diag_quadratic_hvp(params, idxr)
    out = {}
    for backend, be in (('tree', 'tree'), ('flat', 'flat')):
        solver = NystromIHVP(k=k, rho=1e-2, backend=be)
        sketch = jax.block_until_ready(
            solver.prepare(hvp, idxr, jax.random.PRNGKey(1)))
        apply_vec = jax.jit(solver.apply)
        apply_blk = jax.jit(solver.apply_matrix)
        for m in m_values:
            cols = [tree_random_like(kk, params)
                    for kk in jax.random.split(jax.random.PRNGKey(2), m)]
            Vm = jax.tree.map(lambda *ls: jnp.stack(ls, axis=-1), *cols)

            def loop_once():
                return [apply_vec(sketch, c) for c in cols]

            jax.block_until_ready(loop_once())           # warmup/compile
            jax.block_until_ready(apply_blk(sketch, Vm))
            t0 = time.time()
            for _ in range(reps):
                jax.block_until_ready(loop_once())
            loop_per = (time.time() - t0) / reps
            t0 = time.time()
            for _ in range(reps):
                jax.block_until_ready(apply_blk(sketch, Vm))
            blk_per = (time.time() - t0) / reps
            if rows is not None:
                # hvp_count=0: the timed region is the pure apply path — the
                # sketch (and its k HVPs) amortizes outside the clock
                rows.append(bench_row(
                    solver='nystrom', backend=backend, m=m,
                    applies_per_sec=m / loop_per, wall_seconds=loop_per,
                    problem='synthetic_quadratic', hvp_count=0,
                    path='loop', p=p_count, k=k, n_leaves=n_leaves))
                rows.append(bench_row(
                    solver='nystrom', backend=backend, m=m,
                    applies_per_sec=m / blk_per, wall_seconds=blk_per,
                    problem='synthetic_quadratic', hvp_count=0,
                    path='block', p=p_count, k=k, n_leaves=n_leaves))
            out[('block_apply', backend, m)] = (loop_per, blk_per)
            emit('tab5_block_apply', blk_per * 1e6,
                 f'backend={backend} m={m} p={p_count} k={k} '
                 f'loop_s={loop_per:.5f} block_s={blk_per:.5f} '
                 f'block_speedup={loop_per / blk_per:.2f}x')
    best = max(loop / blk for (_, _, m), (loop, blk) in out.items()
               if m >= 32)
    emit('tab5_block_apply', 0.0,
         f'headline m>=32 block_vs_loop_speedup={best:.2f}x')
    return out


def run_backend_apply(leaf_counts=(2, 8, 32), p_total=1 << 18, k=32,
                      reps: int = 20, include_pallas: bool = True,
                      rows=None):
    """Apply-time by contraction backend at fixed p, growing leaf count.

    The quadratic inner loss is diagonal so sketch construction is cheap and
    the timing isolates the apply path (two tall-skinny contractions) —
    which is what sketch amortization makes hot in production. Timed through
    ``apply_matrix`` on a width-1 block (statically the vector apply).
    """
    out = {}
    for n_leaves in leaf_counts:
        params = _leafy_params(n_leaves, p_total)
        idxr = PyTreeIndexer(params)
        p_count = idxr.total
        hvp = _diag_quadratic_hvp(params, idxr)
        v1 = jax.tree.map(lambda x: x[..., None],
                          tree_random_like(jax.random.PRNGKey(0), params))
        backends = [('tree', 'tree'), ('flat', 'flat'),
                    ('flat_bf16', FlatBackend(sketch_dtype=jnp.bfloat16))]
        # off-TPU, pallas runs in interpret mode (~13 s/apply): one
        # correctness data point at the largest tree is enough there.
        if include_pallas and (jax.default_backend() == 'tpu'
                               or n_leaves == leaf_counts[-1]):
            backends.append(('pallas', PallasBackend(interpret=None,
                                                     block_p=4096)))
        for backend, be in backends:
            solver = NystromIHVP(k=k, rho=1e-2, backend=be)
            sketch = solver.prepare(hvp, idxr, jax.random.PRNGKey(1))
            sketch = jax.block_until_ready(sketch)
            apply_fn = jax.jit(solver.apply_matrix)
            jax.block_until_ready(apply_fn(sketch, v1))     # warmup/compile
            # interpret-mode pallas is a correctness path; don't loop on it
            n = 1 if (backend == 'pallas'
                      and jax.default_backend() != 'tpu') else reps
            t0 = time.time()
            for _ in range(n):
                jax.block_until_ready(apply_fn(sketch, v1))
            per = (time.time() - t0) / n
            out[('apply', backend, n_leaves)] = per
            if rows is not None:
                rows.append(bench_row(
                    solver='nystrom', backend=backend, m=1,
                    applies_per_sec=1.0 / per, wall_seconds=per,
                    problem='synthetic_quadratic', hvp_count=0,
                    path='block', p=p_count, k=k, n_leaves=n_leaves,
                    sketch_mb=_sketch_bytes(sketch) / 1e6))
            emit('tab5_backend_apply', per * 1e6,
                 f'backend={backend} n_leaves={n_leaves} p={p_count} k={k} '
                 f'apply_wall_s={per:.6f} '
                 f'sketch_MB={_sketch_bytes(sketch) / 1e6:.1f}'
                 + (' (interpret mode)' if n == 1 else ''))
        tree_t = out[('apply', 'tree', n_leaves)]
        flat_t = out[('apply', 'flat', n_leaves)]
        emit('tab5_backend_apply', 0.0,
             f'summary n_leaves={n_leaves} flat_speedup_vs_tree='
             f'{tree_t / flat_t:.2f}x')
    return out


def run_sharded_backend_apply(n_leaves: int = 16, p_total=1 << 18, k: int = 32,
                              reps: int = 20, rows=None):
    """flat_sharded vs tree apply-time on a mesh over every visible device.

    Every leaf's rows shard over the single 'model' axis except one
    deliberately replicated leaf, so the psum down-weighting path is always
    exercised. Emits f32 and bf16 sketch rows. On 1 visible device this
    emits a SKIPPED pointer instead — relaunch under
    ``XLA_FLAGS=--xla_force_host_platform_device_count=8`` for the
    host-mesh numbers quoted in docs/backends.md.
    """
    import numpy as np
    from jax.sharding import Mesh, PartitionSpec as P

    n_dev = jax.device_count()
    out = {}
    if n_dev < 2:
        emit('tab5_sharded_apply', 0.0,
             'SKIPPED (1 device): rerun under '
             'XLA_FLAGS=--xla_force_host_platform_device_count=8')
        return out
    mesh = Mesh(np.array(jax.devices()), ('model',))
    params = _leafy_params(n_leaves, p_total)
    # rows divide n_dev for every leaf but one: 'layer00' stays replicated
    # so the 1/replication psum weighting is part of the measured path.
    specs = {name: (P() if name == 'layer00' else P('model', None))
             for name in params}
    idxr = PyTreeIndexer(params)
    p_count = idxr.total
    hvp = _diag_quadratic_hvp(params, idxr)
    v1 = jax.tree.map(lambda x: x[..., None],
                      tree_random_like(jax.random.PRNGKey(0), params))
    cases = {
        'tree': 'tree',
        'flat_sharded': FlatShardedBackend(mesh=mesh, specs=specs),
        'flat_sharded_bf16': FlatShardedBackend(mesh=mesh, specs=specs,
                                                sketch_dtype=jnp.bfloat16),
    }
    for name, be in cases.items():
        solver = NystromIHVP(k=k, rho=1e-2, backend=be)
        sketch = jax.block_until_ready(
            solver.prepare(hvp, idxr, jax.random.PRNGKey(1)))
        apply_fn = jax.jit(solver.apply_matrix)
        jax.block_until_ready(apply_fn(sketch, v1))         # warmup/compile
        t0 = time.time()
        for _ in range(reps):
            jax.block_until_ready(apply_fn(sketch, v1))
        per = (time.time() - t0) / reps
        out[('sharded_apply', name)] = per
        if rows is not None:
            rows.append(bench_row(
                solver='nystrom', backend=name, m=1,
                applies_per_sec=1.0 / per, wall_seconds=per,
                problem='synthetic_quadratic', hvp_count=0, path='block',
                p=p_count, k=k, n_leaves=n_leaves, n_dev=n_dev))
        emit('tab5_sharded_apply', per * 1e6,
             f'backend={name} n_dev={n_dev} n_leaves={n_leaves} p={p_count} '
             f'k={k} apply_wall_s={per:.6f} '
             f'sketch_MB={_sketch_bytes(sketch) / 1e6:.1f}')
    emit('tab5_sharded_apply', 0.0,
         f'summary n_dev={n_dev} sharded_speedup_vs_tree='
         f"{out[('sharded_apply', 'tree')] / out[('sharded_apply', 'flat_sharded')]:.2f}x")
    return out
