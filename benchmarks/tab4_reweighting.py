"""Tab. 4: data reweighting on long-tailed data (imbalance 200/100/50).

Paper protocol: warm-start (no reset), inner SGD 0.1 momentum 0.9 wd 5e-4,
outer Adam 1e-5 (we use 1e-3 at our 1000× smaller scale), l=k=10, α=ρ=0.01.
Validated claim: reweighting ≥ no-reweighting baseline, Nyström matches or
beats the iterative backends.

Runs through the typed problem API (``repro.core.problem.solve``), which
makes sketch amortization available here for free: the
``sketch_refresh_every`` row reuses one Nyström sketch across N warm-start
outer steps and emits the HVP-count + wall-time economics next to the
fresh-prepare protocol rows (tab3's shared-sketch row, for the alternating
workload).

    python -m benchmarks.tab4_reweighting --n-outer 2 --shared-sketch
"""
import argparse

import jax
import jax.numpy as jnp

from benchmarks.common import bench_row, emit, solver_cfg, write_bench
from repro.core import solve
from repro.optim import momentum
from repro.tasks import build_reweighting

SKETCH_REFRESH = 5          # default amortization cadence for the HVP row


def _baseline(problem, steps=600):
    """Plain training on the imbalanced stream — no reweighting, no bilevel.
    Uses the problem's own ``baseline_loss`` (the hparam-free training
    objective) instead of re-importing model pieces."""
    params = problem.init_params(jax.random.PRNGKey(0))
    opt = momentum(0.1, 0.9)
    st = opt.init(params)

    @jax.jit
    def step(params, st, X, y, i):
        g = jax.grad(problem.baseline_loss)(params, (X, y))
        return opt.apply(g, st, params, i)

    # the dataset's own np.RandomState stream, not the ArraySource stream:
    # keeps this row's draws (and hence the baseline accuracy the table is
    # compared against) identical to the seed benchmark
    data = problem.reference['dataset']
    for i in range(steps):
        X, y = data.train_batch(i, 128)
        params, st = step(params, st, X, y, jnp.int32(i))
    return problem.metrics['accuracy'](params, None)


def run(imbalances=(200, 100, 50), n_outer: int = 30,
        sketch_refresh_every: int | None = None, baseline_steps: int = 600):
    out = {}
    rows = []
    for imb in imbalances:
        problem = build_reweighting(imbalance=imb)
        base = _baseline(problem, steps=baseline_steps)
        emit('tab4_reweighting', 0.0, f'imb={imb} baseline acc={base:.3f}')
        for method in ('nystrom', 'cg', 'neumann'):
            res = solve(problem, solver_cfg(method, k=10, rho=1e-2,
                                            alpha=1e-2), n_outer=n_outer)
            out[(imb, method)] = res.metrics['accuracy']
            rows.append(bench_row(
                solver=method, backend='tree', m=1,
                applies_per_sec=n_outer / max(res.seconds, 1e-12),
                wall_seconds=res.seconds, problem='reweighting',
                hvp_count=res.hvp_count, imb=imb, n_outer=n_outer,
                acc=res.metrics['accuracy']))
            emit('tab4_reweighting', res.seconds * 1e6 / n_outer,
                 f'imb={imb} method={method} '
                 f'acc={res.metrics["accuracy"]:.3f} hvps={res.hvp_count}')
        # amortized-sketch row: the reweighting protocol is warm-start, so
        # one sketch legitimately serves several outer steps — k HVPs per
        # refresh instead of per step (the nystrom row above is the
        # refresh_every=1 counterpart at identical settings)
        refresh = sketch_refresh_every or SKETCH_REFRESH
        res_am = solve(problem, solver_cfg('nystrom', k=10, rho=1e-2),
                       n_outer=n_outer, sketch_refresh_every=refresh)
        fresh_hvps = n_outer * 10
        out[(imb, 'nystrom_amortized')] = res_am.metrics['accuracy']
        rows.append(bench_row(
            solver='nystrom', backend='tree', m=1,
            applies_per_sec=n_outer / max(res_am.seconds, 1e-12),
            wall_seconds=res_am.seconds, problem='reweighting',
            hvp_count=res_am.hvp_count, imb=imb, n_outer=n_outer,
            refresh_every=refresh, acc=res_am.metrics['accuracy']))
        emit('tab4_reweighting_sketch', res_am.seconds * 1e6 / n_outer,
             f'imb={imb} method=nystrom refresh_every={refresh} '
             f'hvps={res_am.hvp_count} (fresh_prepare={fresh_hvps}) '
             f'wall_s={res_am.seconds:.2f} '
             f'acc={res_am.metrics["accuracy"]:.3f}')
        out[(imb, 'baseline')] = base
    write_bench('tab4', rows, meta=dict(n_outer=n_outer))
    return out


def main(argv=None):
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument('--imbalances', type=int, nargs='+',
                    default=[200, 100, 50])
    ap.add_argument('--n-outer', type=int, default=30)
    ap.add_argument('--baseline-steps', type=int, default=600)
    ap.add_argument('--shared-sketch', action='store_true',
                    help='amortize one Nyström sketch across '
                         '--sketch-refresh-every warm-start outer steps')
    ap.add_argument('--sketch-refresh-every', type=int, default=None)
    args = ap.parse_args(argv)
    refresh = args.sketch_refresh_every
    if args.shared_sketch and refresh is None:
        refresh = min(SKETCH_REFRESH, max(2, args.n_outer))
    run(imbalances=tuple(args.imbalances), n_outer=args.n_outer,
        sketch_refresh_every=refresh, baseline_steps=args.baseline_steps)


if __name__ == '__main__':
    main()
