"""Tab. 4: data reweighting on long-tailed data (imbalance 200/100/50).

Paper protocol: warm-start (no reset), inner SGD 0.1 momentum 0.9 wd 5e-4,
outer Adam 1e-5 (we use 1e-3 at our 1000× smaller scale), l=k=10, α=ρ=0.01.
Validated claim: reweighting ≥ no-reweighting baseline, Nyström matches or
beats the iterative backends.
"""
import jax
import jax.numpy as jnp
import time

from benchmarks.common import emit, run_bilevel
from repro.optim import momentum
from repro.tasks import build_reweighting


def _baseline(task, steps=600):
    params = task['init_params'](jax.random.PRNGKey(0))
    opt = momentum(0.1, 0.9)
    st = opt.init(params)
    hp = task['init_hparams'](jax.random.PRNGKey(1))

    @jax.jit
    def step(params, st, X, y, i):
        def plain(p, b):
            from repro.tasks.paper import mlp_apply, _xent
            return _xent(mlp_apply(p, b[0]), b[1])
        g = jax.grad(plain)(params, (X, y))
        return opt.apply(g, st, params, i)

    for i in range(steps):
        X, y = task['data'].train_batch(i, 128)
        params, st = step(params, st, X, y, jnp.int32(i))
    return task['accuracy'](params)


def run(imbalances=(200, 100, 50), n_outer: int = 30):
    out = {}
    for imb in imbalances:
        task = build_reweighting(imbalance=imb)
        base = _baseline(task)
        emit('tab4_reweighting', 0.0, f'imb={imb} baseline acc={base:.3f}')
        data = task['data']
        task = dict(task, train=(data.X, data.y), val=(data.Xv, data.yv))
        for method in ('nystrom', 'cg', 'neumann'):
            t0 = time.time()
            state, hist, secs = run_bilevel(
                task, method, n_outer=n_outer, steps_per_outer=20,
                inner_lr=0.1, inner_momentum=0.9, outer_lr=1e-3,
                k=10, rho=1e-2, alpha=1e-2, batch=128)
            acc = task['accuracy'](state.params)
            out[(imb, method)] = acc
            emit('tab4_reweighting', secs * 1e6 / n_outer,
                 f'imb={imb} method={method} acc={acc:.3f}')
        out[(imb, 'baseline')] = base
    return out
