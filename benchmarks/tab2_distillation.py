"""Tab. 2: dataset distillation — distilled synthetic images via bilevel opt.

Paper protocol: fixed-known init (reset every 100 updates), inner SGD 0.01,
outer Adam 1e-3, α=ρ=0.01, l=k=10. Shortened outer horizon for CPU; the
claim validated is the ORDERING nystrom ≳ neumann ≫ cg (cg fails: Tab. 2).
"""
import jax

from benchmarks.common import emit, run_bilevel
from repro.tasks import build_distillation


def run(n_outer: int = 25):
    task = build_distillation()
    accs = {}
    for method in ('nystrom', 'neumann', 'cg'):
        state, hist, secs = run_bilevel(
            task, method, n_outer=n_outer, steps_per_outer=100,
            inner_lr=0.01, outer_lr=1e-3, k=10, rho=1e-2, alpha=1e-2,
            reset_inner=True, batch=256)
        # final eval: train a fresh model on the distilled set
        from repro.optim import sgd
        params = task['init_params'](jax.random.PRNGKey(7))
        opt = sgd(0.01)
        st = opt.init(params)
        import jax.numpy as jnp
        for i in range(100):
            g = jax.grad(task['inner'])(params, state.hparams, None)
            params, st = opt.apply(g, st, params, jnp.int32(i))
        accs[method] = task['accuracy'](params)
        emit('tab2_distillation', secs * 1e6 / n_outer,
             f'method={method} test_acc={accs[method]:.3f}')
    return accs
