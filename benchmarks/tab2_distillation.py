"""Tab. 2: dataset distillation — distilled synthetic images via bilevel opt.

Paper protocol: fixed-known init (reset every 100 updates), inner SGD 0.01,
outer Adam 1e-3, α=ρ=0.01, l=k=10. Shortened outer horizon for CPU; the
claim validated is the ORDERING nystrom ≳ neumann ≫ cg (cg fails: Tab. 2).

Runs through the typed problem API (``repro.core.problem.solve``); the final
score is the problem's ``distilled_accuracy`` metric (train a fresh model on
the distilled set). The sketch-amortization row runs the *warm-start*
variant: the paper protocol resets θ every outer step, which auto-invalidates
the sketch (one rebuild per step by design), so the amortization economics
are only measurable without resets — the row says so explicitly.

    python -m benchmarks.tab2_distillation --n-outer 2 --shared-sketch
"""
import argparse

from benchmarks.common import bench_row, emit, solver_cfg, write_bench
from repro.core import solve
from repro.tasks import build_distillation

SKETCH_REFRESH = 5          # default amortization cadence for the HVP row


def run(n_outer: int = 25, sketch_refresh_every: int | None = None):
    problem = build_distillation()
    accs = {}
    rows = []
    for method in ('nystrom', 'neumann', 'cg'):
        res = solve(problem, solver_cfg(method, k=10, rho=1e-2, alpha=1e-2),
                    n_outer=n_outer)
        accs[method] = res.metrics['distilled_accuracy']
        rows.append(bench_row(
            solver=method, backend='tree', m=1,
            applies_per_sec=n_outer / max(res.seconds, 1e-12),
            wall_seconds=res.seconds, problem='distillation',
            hvp_count=res.hvp_count, n_outer=n_outer,
            test_acc=accs[method]))
        emit('tab2_distillation', res.seconds * 1e6 / n_outer,
             f'method={method} test_acc={accs[method]:.3f} '
             f'hvps={res.hvp_count}')
    # amortized-sketch row (warm-start: reset_inner would invalidate the
    # sketch every outer step, making refresh_every a no-op — see docstring)
    refresh = sketch_refresh_every or SKETCH_REFRESH
    res_am = solve(problem, solver_cfg('nystrom', k=10, rho=1e-2),
                   n_outer=n_outer, reset_inner=False,
                   sketch_refresh_every=refresh)
    accs['nystrom_amortized'] = res_am.metrics['distilled_accuracy']
    rows.append(bench_row(
        solver='nystrom', backend='tree', m=1,
        applies_per_sec=n_outer / max(res_am.seconds, 1e-12),
        wall_seconds=res_am.seconds, problem='distillation',
        hvp_count=res_am.hvp_count, n_outer=n_outer,
        refresh_every=refresh, test_acc=accs['nystrom_amortized']))
    emit('tab2_distillation_sketch', res_am.seconds * 1e6 / n_outer,
         f'method=nystrom protocol=warm_start refresh_every={refresh} '
         f'hvps={res_am.hvp_count} (fresh_prepare={n_outer * 10}) '
         f'wall_s={res_am.seconds:.2f} '
         f'test_acc={accs["nystrom_amortized"]:.3f}')
    write_bench('tab2', rows, meta=dict(n_outer=n_outer))
    return accs


def main(argv=None):
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument('--n-outer', type=int, default=25)
    ap.add_argument('--shared-sketch', action='store_true',
                    help='amortize one Nyström sketch across '
                         '--sketch-refresh-every warm-start outer steps')
    ap.add_argument('--sketch-refresh-every', type=int, default=None)
    args = ap.parse_args(argv)
    refresh = args.sketch_refresh_every
    if args.shared_sketch and refresh is None:
        refresh = min(SKETCH_REFRESH, max(2, args.n_outer))
    run(n_outer=args.n_outer, sketch_refresh_every=refresh)


if __name__ == '__main__':
    main()
